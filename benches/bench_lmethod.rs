//! L-method cost vs evaluation-graph size, including the iterative
//! refinement loop — runs once per subset per iteration, so it must be
//! negligible next to the O(n²) distance build.

use mahc::ahc::l_method;
use mahc::util::bench::Bench;
use mahc::util::rng::Rng;

fn synthetic_heights(n: usize, knee_at: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from(seed);
    let mut h: Vec<f32> = Vec::with_capacity(n - 1);
    for i in 0..n - 1 {
        let base = if i < n - knee_at {
            0.1 + 0.002 * i as f32
        } else {
            5.0 + (i - (n - knee_at)) as f32
        };
        h.push(base + rng.f32() * 0.01);
    }
    h
}

fn main() {
    println!("== bench_lmethod: knee detection vs graph size ==");
    for &n in &[50usize, 200, 1000, 5000] {
        let heights = synthetic_heights(n, (n / 10).max(3), n as u64);
        Bench::new(&format!("l_method/n={n}"))
            .quick()
            .run(|| l_method(&heights, n));
    }
}
