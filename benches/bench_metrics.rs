//! Vector-metric vs DTW per-pair cost on comparable corpora, in
//! pair-distances per second.
//!
//! The metric-generic API's economic claim is that embedding workloads
//! are *cheap*: a cosine or Euclidean pair is one O(D) sweep where a
//! DTW pair is an O(T²·D) dynamic program.  This harness first proves
//! the vector kernels' scalar/blocked bitwise parity (a cheap subset
//! of `rust/tests/metric_parity.rs`), then measures cosine, Euclidean,
//! and DTW on same-size pair tiles and asserts the cosine-vs-DTW
//! pairs/sec floor recorded in EXPERIMENTS.md §Metrics.
//!
//! CI hooks: `MAHC_BENCH_QUICK=1` shortens the sampling windows for
//! the perf-smoke job, and `MAHC_BENCH_JSON=path` writes the
//! measurements (pairs/sec per metric, the cosine/DTW ratio, the
//! enforced floor) as a JSON fragment for the `BENCH_ci.json`
//! artifact.

use mahc::config::DatasetSpec;
use mahc::corpus::{generate, generate_embeddings, EmbeddingSpec, Segment};
use mahc::distance::{NativeBackend, PairwiseBackend, VectorBackend, VectorMetric};
use mahc::util::bench::{quick_mode, write_json_report, Bench};
use mahc::util::json;

fn bench(name: &str, pairs: u64) -> Bench {
    let b = Bench::new(name).throughput(pairs);
    if quick_mode() {
        b.quick()
    } else {
        b
    }
}

fn main() {
    // Embedding corpus: 96 segments of one 39-dim frame each, so a
    // vector pair reads exactly as many features as one DTW *frame*
    // comparison does.
    let mut espec = EmbeddingSpec::tiny(96, 8, 11);
    espec.dim = 39;
    let eset = generate_embeddings(&espec);
    let erefs: Vec<&Segment> = eset.segments.iter().collect();
    let (exs, eys) = (&erefs[..32], &erefs[32..96]);
    let pairs = (exs.len() * eys.len()) as u64;

    // The DTW reference corpus from bench_backends: same segment
    // count, 39-dim features, paper-realistic lengths.
    let mut dspec = DatasetSpec::tiny(96, 8, 11);
    dspec.feat_dim = 39;
    dspec.len_range = (6, 60);
    let dset = generate(&dspec);
    let drefs: Vec<&Segment> = dset.segments.iter().collect();
    let (dxs, dys) = (&drefs[..32], &drefs[32..96]);

    let cos_s = VectorBackend::native(VectorMetric::Cosine);
    let cos_b = VectorBackend::blocked(VectorMetric::Cosine);
    let euc_s = VectorBackend::native(VectorMetric::Euclidean);
    let dtw = NativeBackend::new();

    // Parity before speed: a benchmark of wrong answers is worthless.
    let a = cos_s.pairwise(exs, eys).unwrap();
    let b = cos_b.pairwise(exs, eys).unwrap();
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "pair {i}: {x} vs {y}");
    }

    println!("== bench_metrics: 32x64 pair tiles, D=39 ==");
    let rc = bench("cosine/tile32x64", pairs).run(|| cos_s.pairwise(exs, eys).unwrap());
    let rcb = bench("cosine_blocked/tile32x64", pairs).run(|| cos_b.pairwise(exs, eys).unwrap());
    let re = bench("euclidean/tile32x64", pairs).run(|| euc_s.pairwise(exs, eys).unwrap());
    let rd = bench("dtw/tile32x64", pairs).run(|| dtw.pairwise(dxs, dys).unwrap());

    let cosine_vs_dtw_ratio = rc.throughput.unwrap() / rd.throughput.unwrap();
    let euclidean_vs_dtw_ratio = re.throughput.unwrap() / rd.throughput.unwrap();

    println!();
    println!("vector/dtw pairs-per-sec ratio (same tile, same dim):");
    println!("  cosine     {cosine_vs_dtw_ratio:.1}x");
    println!("  euclidean  {euclidean_vs_dtw_ratio:.1}x");

    // The acceptance floor from EXPERIMENTS.md §Metrics: with segment
    // lengths averaging ~30 frames, a DTW pair costs hundreds of frame
    // comparisons where a cosine pair costs one — any honest kernel
    // clears 3x with an order of magnitude to spare.  Override via
    // MAHC_BENCH_FLOOR (e.g. 0 to record numbers only).
    let floor: f64 = std::env::var("MAHC_BENCH_FLOOR")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3.0);

    write_json_report(&json::obj(vec![
        ("quick", json::Json::Bool(quick_mode())),
        ("floor", json::num(floor)),
        ("cosine_vs_dtw_ratio", json::num(cosine_vs_dtw_ratio)),
        ("euclidean_vs_dtw_ratio", json::num(euclidean_vs_dtw_ratio)),
        (
            "series",
            json::arr(vec![rc.to_json(), rcb.to_json(), re.to_json(), rd.to_json()]),
        ),
    ]))
    .expect("writing MAHC_BENCH_JSON fragment");

    assert!(
        cosine_vs_dtw_ratio >= floor,
        "cosine must deliver >= {floor}x DTW pairs/sec on the same tile \
         (got {cosine_vs_dtw_ratio:.1}x) — see EXPERIMENTS.md §Metrics"
    );
}
