//! DTW backend throughput: native rolling-row DP vs the AOT Pallas
//! kernel through PJRT, plus the banded-DTW ablation.
//!
//! Paper context: the pairwise DTW matrix is the dominant cost of every
//! MAHC iteration (Fig. 6's wall-clock is mostly this).  Throughput is
//! reported in pair-alignments per second.

use mahc::config::DatasetSpec;
use mahc::corpus::{generate, Segment};
use mahc::distance::{PairwiseBackend, NativeBackend};
use mahc::runtime::{Runtime, XlaDtwBackend};
use mahc::util::bench::Bench;
use std::path::Path;

fn main() {
    let mut spec = DatasetSpec::tiny(64, 6, 11);
    spec.feat_dim = 39;
    spec.len_range = (6, 60);
    let set = generate(&spec);
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let (xs, ys) = (&refs[..32], &refs[32..64]);
    let pairs = (xs.len() * ys.len()) as u64;

    println!("== bench_dtw: 32x32 pair tile, T<=60, D=39 ==");
    let native = NativeBackend::new();
    Bench::new("native/tile32x32")
        .throughput(pairs)
        .run(|| native.pairwise(xs, ys).unwrap());

    let banded = NativeBackend::banded(16);
    Bench::new("native-band16/tile32x32")
        .throughput(pairs)
        .run(|| banded.pairwise(xs, ys).unwrap());

    if Path::new("artifacts/manifest.json").exists() {
        let rt = Runtime::new(Path::new("artifacts")).unwrap();
        let xla = XlaDtwBackend::new(&rt).unwrap();
        Bench::new("xla-pallas/tile32x32")
            .throughput(pairs)
            .run(|| xla.pairwise(xs, ys).unwrap());

        // Small-tile dispatch (the medoid-stage shape).
        let (sx, sy) = (&refs[..8], &refs[8..16]);
        Bench::new("xla-pallas/tile8x8")
            .throughput(64)
            .run(|| xla.pairwise(sx, sy).unwrap());
        Bench::new("native/tile8x8")
            .throughput(64)
            .run(|| native.pairwise(sx, sy).unwrap());
    } else {
        eprintln!("(artifacts not built; skipping xla backend)");
    }
}
