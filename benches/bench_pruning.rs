//! Lower-bound cascade cost/benefit: how many exact DTW evaluations
//! the LB_Keogh-style envelope cascade avoids, at what wall cost, and
//! whether the pruned path still makes bitwise-exact decisions.
//!
//! Two workloads:
//!
//! 1. **Generated corpus sweep** — threshold-carrying rectangle queries
//!    over a `DatasetSpec::tiny` corpus at pair-distance quantile
//!    radii (p05/p25/p50).  Reported, not floored: how much a loose
//!    global envelope prunes on MFCC-like data is a measurement, not a
//!    promise.  Decision parity against the exact rectangle *is*
//!    asserted at every radius.
//! 2. **ε ≪ separation join** — well-separated synthetic clusters with
//!    the threshold set between the intra-cluster diameter and the
//!    inter-cluster gap: the regime stage-0 aggregation actually runs
//!    in.  Here the prune rate is pinned: the cascade must skip at
//!    least 30% of DP calls (`PRUNE_FLOOR`), and the committed
//!    `BENCH_baseline.json` floors `pruning.prune_fraction` at the same
//!    value.
//!
//! End-to-end pin: a full aggregated `MahcDriver` run with `prune = on`
//! reproduces the `prune = off` oracle bitwise while its first
//! iteration records a non-zero `lb_pairs`.
//!
//! CI hooks: `MAHC_BENCH_QUICK=1` shrinks both workloads, and
//! `MAHC_BENCH_JSON=path` writes the fragment assembled into
//! `BENCH_ci.json` (diffed against `BENCH_baseline.json`).

use mahc::aggregate::quantile_of_sorted;
use mahc::config::{AggregateConfig, AlgoConfig, Convergence, DatasetSpec, PruneMode};
use mahc::corpus::{generate, Segment, SegmentSet};
use mahc::distance::{CascadeBackend, CascadeMode, PairwiseBackend, NativeBackend};
use mahc::mahc::MahcDriver;
use mahc::util::bench::{quick_mode, write_json_report, Bench};
use mahc::util::json;

/// The acceptance floor: at the join radius the cascade must avoid at
/// least this fraction of exact DP calls.
const PRUNE_FLOOR: f64 = 0.30;

/// `classes` well-separated clusters: per-class feature centres spaced
/// `10.0` apart per dimension with a small deterministic wobble, so the
/// intra-cluster diameter and the inter-cluster gap differ by orders of
/// magnitude — the shape an ε-join sees when ε is set from a low pair-
/// distance quantile.
fn clustered_set(classes: usize, per_class: usize, dim: usize) -> SegmentSet {
    let mut segments = Vec::with_capacity(classes * per_class);
    for c in 0..classes {
        for m in 0..per_class {
            let i = c * per_class + m;
            let len = 8 + (i % 5) * 3;
            let mut feats = Vec::with_capacity(len * dim);
            for t in 0..len {
                for d in 0..dim {
                    let centre = (c * 10) as f32;
                    let wobble = ((t * (d + 2) + m) as f32 * 0.7).sin() * 0.25;
                    feats.push(centre + wobble);
                }
            }
            segments.push(Segment {
                id: i,
                class_id: c,
                len,
                dim,
                feats,
            });
        }
    }
    SegmentSet {
        name: "separated-clusters".to_string(),
        dim,
        segments,
        num_classes: classes,
    }
}

/// Assert the cascade's decision parity against the exact rectangle:
/// survivors are bitwise exact, pruned values sit strictly above the
/// threshold, and `value ≤ threshold` agrees pair for pair with the
/// exact backend's verdict.
fn assert_decision_parity(vals: &[f32], flags: &[bool], exact: &[f32], threshold: f32, ctx: &str) {
    assert_eq!(vals.len(), exact.len(), "{ctx}: rectangle shape diverged");
    for ((&v, &f), &ex) in vals.iter().zip(flags).zip(exact) {
        if f {
            assert_eq!(v.to_bits(), ex.to_bits(), "{ctx}: survivor not exact");
        } else {
            assert!(v > threshold, "{ctx}: pruned value at or below threshold");
            assert!(v <= ex, "{ctx}: inadmissible bound {v} > exact {ex}");
        }
        assert_eq!(
            v <= threshold,
            ex <= threshold,
            "{ctx}: ε-decision diverged (got {v}, exact {ex}, t {threshold})"
        );
    }
}

fn main() {
    let n = if quick_mode() { 100 } else { 180 };
    let set = generate(&DatasetSpec::tiny(n, 10, 21));
    let backend = NativeBackend::new();
    println!("== bench_pruning: tiny corpus at N={n} ==");

    // Workload 1: threshold sweep over a cross rectangle of the
    // generated corpus, radii from the rectangle's own distance
    // quantiles.
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let (xs, ys) = (&refs[..40], &refs[40..]);
    let exact_rect = backend.pairwise(xs, ys).unwrap();
    let mut sorted = exact_rect.clone();
    sorted.sort_unstable_by(f32::total_cmp);

    let cascade = CascadeBackend::borrowed(&backend, &set, CascadeMode::On);
    println!("\n  radius   threshold  lb_pairs  pruned  prune_rate");
    let mut sweep_rows: Vec<json::Json> = Vec::new();
    for (tag, q) in [("p05", 0.05), ("p25", 0.25), ("p50", 0.50)] {
        let threshold = quantile_of_sorted(&sorted, q);
        let before = cascade.stats();
        let (vals, flags) = cascade.pairwise_pruned(xs, ys, threshold).unwrap();
        let d = cascade.stats().delta(&before);
        assert_decision_parity(&vals, &flags, &exact_rect, threshold, tag);
        println!(
            "  {tag}   {threshold:>9.3} {:>9} {:>7}  {:>9.3}",
            d.lb_pairs,
            d.lb_pruned,
            d.prune_rate()
        );
        sweep_rows.push(json::obj(vec![
            ("tag", json::s(tag)),
            ("threshold", json::num(threshold as f64)),
            ("lb_pairs", json::num(d.lb_pairs as f64)),
            ("lb_pruned", json::num(d.lb_pruned as f64)),
            ("exact_pairs", json::num(d.exact_pairs as f64)),
            ("prune_fraction", json::num(d.prune_rate())),
        ]));
    }
    println!("  decision parity vs the exact rectangle: MATCH at every radius");

    // Workload 2: the ε-join regime.  Threshold = 1.5× the measured
    // intra-cluster diameter, far below the inter-cluster gap, so
    // same-cluster pairs survive (and compute exactly) while
    // cross-cluster pairs are bounded out.
    let classes = 4;
    let per_class = if quick_mode() { 24 } else { 40 };
    let join_set = clustered_set(classes, per_class, 3);
    let jn = join_set.len();
    let jrefs: Vec<&Segment> = join_set.segments.iter().collect();
    let join_exact = backend.pairwise(&jrefs, &jrefs).unwrap();
    let mut intra_max = 0.0f32;
    for (i, a) in join_set.segments.iter().enumerate() {
        for (j, b) in join_set.segments.iter().enumerate() {
            if a.class_id == b.class_id {
                intra_max = intra_max.max(join_exact[i * jn + j]);
            }
        }
    }
    let join_threshold = intra_max * 1.5;

    let join_cascade = CascadeBackend::borrowed(&backend, &join_set, CascadeMode::On);
    let before = join_cascade.stats();
    let (jvals, jflags) = join_cascade
        .pairwise_pruned(&jrefs, &jrefs, join_threshold)
        .unwrap();
    let jd = join_cascade.stats().delta(&before);
    assert_decision_parity(&jvals, &jflags, &join_exact, join_threshold, "join");
    let prune_fraction = jd.prune_rate();
    println!(
        "\nε-join over {classes}x{per_class} separated clusters (t={join_threshold:.3}):"
    );
    println!(
        "  {} bounded, {} pruned, {} exact DP calls — {:.1}% of the DP avoided",
        jd.lb_pairs,
        jd.lb_pruned,
        jd.exact_pairs,
        prune_fraction * 100.0
    );

    // The acceptance floor (EXPERIMENTS.md §Pruning): the committed
    // baseline pins the same number via `pruning.prune_fraction`.
    assert!(
        prune_fraction >= PRUNE_FLOOR,
        "cascade avoided only {:.1}% of DP calls at the join radius (floor {:.0}%)",
        prune_fraction * 100.0,
        PRUNE_FLOOR * 100.0
    );

    // Wall cost of the two paths over the same join rectangle.
    let pairs = (jn * jn) as u64;
    let exact_wall = Bench::new("pruning/join-exact")
        .quick()
        .throughput(pairs)
        .run(|| backend.pairwise(&jrefs, &jrefs).unwrap());
    let cascade_wall = Bench::new("pruning/join-cascade")
        .quick()
        .throughput(pairs)
        .run(|| {
            join_cascade
                .pairwise_pruned(&jrefs, &jrefs, join_threshold)
                .unwrap()
        });
    let speedup = exact_wall.mean.as_secs_f64() / cascade_wall.mean.as_secs_f64().max(1e-12);
    println!(
        "  exact {:.4}s vs cascade {:.4}s per rectangle — {speedup:.2}x",
        exact_wall.mean.as_secs_f64(),
        cascade_wall.mean.as_secs_f64()
    );

    // End-to-end pin: the aggregated driver with prune=on reproduces
    // the prune=off oracle bitwise and actually exercised the cascade.
    let eps = {
        let cond = mahc::distance::build_condensed(&refs, &backend, 4).unwrap();
        let mut d: Vec<f32> = cond.as_slice().to_vec();
        d.sort_unstable_by(f32::total_cmp);
        quantile_of_sorted(&d, 0.25)
    };
    let base = AlgoConfig {
        p0: 3,
        beta: Some((n as f64 / 3.0 * 1.25).ceil() as usize),
        convergence: Convergence::FixedIters(2),
        aggregate: AggregateConfig::new(eps),
        ..Default::default()
    };
    let off = MahcDriver::new(&set, base.clone(), &backend)
        .unwrap()
        .run()
        .unwrap();
    let on_cfg = AlgoConfig {
        prune: PruneMode::On,
        ..base
    };
    let on = MahcDriver::new(&set, on_cfg, &backend).unwrap().run().unwrap();
    assert_eq!(on.labels, off.labels, "prune=on must be bitwise the oracle");
    assert_eq!(on.k, off.k);
    assert_eq!(on.f_measure.to_bits(), off.f_measure.to_bits());
    let driver_lb_pairs: u64 = on.history.records.iter().map(|r| r.lb_pairs).sum();
    assert!(
        driver_lb_pairs > 0,
        "prune=on driver run never engaged the cascade"
    );
    println!(
        "\ndriver prune=on reproduces prune=off bitwise ({driver_lb_pairs} pairs bounded): MATCH"
    );

    write_json_report(&json::obj(vec![
        ("quick", json::Json::Bool(quick_mode())),
        ("n", json::num(n as f64)),
        ("sweep", json::arr(sweep_rows)),
        (
            "join",
            json::obj(vec![
                ("classes", json::num(classes as f64)),
                ("n", json::num(jn as f64)),
                ("threshold", json::num(join_threshold as f64)),
                ("lb_pairs", json::num(jd.lb_pairs as f64)),
                ("lb_pruned", json::num(jd.lb_pruned as f64)),
                ("exact_pairs", json::num(jd.exact_pairs as f64)),
            ]),
        ),
        ("prune_fraction", json::num(prune_fraction)),
        ("driver_lb_pairs", json::num(driver_lb_pairs as f64)),
        (
            "walls",
            json::obj(vec![
                ("exact", exact_wall.to_json()),
                ("cascade", cascade_wall.to_json()),
                ("speedup", json::num(speedup)),
            ]),
        ),
    ]))
    .expect("writing MAHC_BENCH_JSON fragment");
}
