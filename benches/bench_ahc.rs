//! Ward NN-chain scaling: merge throughput vs subset size n.
//!
//! Paper context: stage 1 runs one AHC per subset and β bounds n, so
//! this bench maps β to per-subset clustering cost.  NN-chain is O(n²):
//! doubling n should roughly 4x the time, visible in the series.

use mahc::ahc::{l_method, ward_linkage};
use mahc::distance::Condensed;
use mahc::util::bench::Bench;
use mahc::util::rng::Rng;

fn blobby_condensed(n: usize, seed: u64) -> Condensed {
    let mut rng = Rng::seed_from(seed);
    // Clustered structure: 8 blobs on a line (realistic merge heights).
    let pts: Vec<f32> = (0..n)
        .map(|i| (i % 8) as f32 * 10.0 + rng.f32())
        .collect();
    let mut c = Condensed::zeros(n);
    for i in 0..n {
        for j in 0..i {
            c.set(i, j, (pts[i] - pts[j]).abs());
        }
    }
    c
}

fn main() {
    println!("== bench_ahc: Ward NN-chain + L-method vs n ==");
    for &n in &[100usize, 200, 400, 800, 1600] {
        let cond = blobby_condensed(n, n as u64);
        Bench::new(&format!("ward_nnchain/n={n}"))
            .quick()
            .throughput((n * n / 2) as u64)
            .run(|| ward_linkage(&cond));
    }
    let cond = blobby_condensed(800, 9);
    let dendro = ward_linkage(&cond);
    let heights = dendro.merge_heights();
    Bench::new("l_method/n=800").run(|| l_method(&heights, 800));
    Bench::new("cut_k64/n=800").run(|| dendro.cut(64));
}
