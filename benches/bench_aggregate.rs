//! Stage-0 aggregation cost/benefit: leader-pass wall, compression
//! ratio, and end-to-end quality across the ε sweep.
//!
//! ε is data-dependent, so the harness derives the sweep from the
//! corpus itself: it builds the full condensed matrix once, takes pair-
//! distance quantiles as radii, and for each one reports the number of
//! representatives, the compression ratio m/N, and the aggregated run's
//! F-measure against the unaggregated reference.  Two pins are
//! *provable* and asserted on every run: ε = 0 reproduces the
//! unaggregated run bitwise, and ε beyond the largest pair distance
//! collapses the corpus onto a single representative (every segment is
//! within ε of the first leader).
//!
//! CI hooks: `MAHC_BENCH_QUICK=1` shrinks the corpus for the perf-smoke
//! job, and `MAHC_BENCH_JSON=path` writes the sweep (compression ratio
//! per ε, F deltas, leader wall) as a JSON fragment for `BENCH_ci.json`.

use std::time::Instant;

use mahc::aggregate::aggregate;
use mahc::config::{AggregateConfig, AlgoConfig, Convergence, DatasetSpec};
use mahc::corpus::{generate, Segment};
use mahc::distance::{build_condensed, NativeBackend};
use mahc::mahc::MahcDriver;
use mahc::util::bench::{quick_mode, write_json_report, Bench};
use mahc::util::json;

fn main() {
    let n = if quick_mode() { 120 } else { 240 };
    let set = generate(&DatasetSpec::tiny(n, 12, 13));
    let backend = NativeBackend::new();
    println!("== bench_aggregate: tiny corpus at N={n} ==");

    // Pair-distance quantiles → the ε sweep.
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let cond = build_condensed(&refs, &backend, 4).unwrap();
    let mut dists: Vec<f32> = cond.as_slice().to_vec();
    dists.sort_unstable_by(f32::total_cmp);
    let quantile = |q: f64| dists[((dists.len() - 1) as f64 * q) as usize];
    let d_max = *dists.last().unwrap();

    let algo = AlgoConfig {
        p0: 4,
        beta: Some((n as f64 / 4.0 * 1.25).ceil() as usize),
        convergence: Convergence::FixedIters(3),
        ..Default::default()
    };

    let t0 = Instant::now();
    let plain = MahcDriver::new(&set, algo.clone(), &backend)
        .unwrap()
        .run()
        .unwrap();
    let plain_wall = t0.elapsed().as_secs_f64();
    println!(
        "unaggregated: K={} F={:.4} wall={plain_wall:.2}s",
        plain.k, plain.f_measure
    );

    // Pin 1: ε = 0 is the unaggregated run, bit for bit.
    let zero = MahcDriver::new(
        &set,
        AlgoConfig {
            aggregate: AggregateConfig::new(0.0),
            ..algo.clone()
        },
        &backend,
    )
    .unwrap()
    .run()
    .unwrap();
    assert_eq!(zero.labels, plain.labels, "ε=0 must be bitwise the plain run");
    assert_eq!(zero.k, plain.k);
    assert_eq!(zero.f_measure.to_bits(), plain.f_measure.to_bits());
    println!("ε=0 reproduces the unaggregated run: MATCH");

    println!("\n     ε        reps   m/N     K      F      ΔF%    wall_s");
    let mut rows: Vec<json::Json> = Vec::new();
    for (tag, eps) in [
        ("p05", quantile(0.05)),
        ("p25", quantile(0.25)),
        ("p50", quantile(0.50)),
    ] {
        let cfg = AlgoConfig {
            aggregate: AggregateConfig::new(eps),
            ..algo.clone()
        };
        let t0 = Instant::now();
        let res = MahcDriver::new(&set, cfg, &backend).unwrap().run().unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let r0 = &res.history.records[0];
        let delta = (res.f_measure - plain.f_measure) / plain.f_measure * 100.0;
        println!(
            "{tag} {eps:>9.3} {:>5} {:.3} {:>5} {:.4} {delta:>6.1} {wall:>8.2}",
            r0.representatives, r0.compression_ratio, res.k, res.f_measure
        );
        assert_eq!(res.labels.len(), n, "aggregated labels must cover all N");
        rows.push(json::obj(vec![
            ("tag", json::s(tag)),
            ("epsilon", json::num(eps as f64)),
            ("representatives", json::num(r0.representatives as f64)),
            ("compression_ratio", json::num(r0.compression_ratio)),
            ("k", json::num(res.k as f64)),
            ("f_measure", json::num(res.f_measure)),
            ("f_delta_pct", json::num(delta)),
            ("wall_secs", json::num(wall)),
        ]));
    }

    // Pin 2: a radius past the largest pair distance leaves exactly one
    // representative (every segment is within ε of the first leader).
    let top = aggregate(&set, &AggregateConfig::new(d_max * 1.01), &backend, None).unwrap();
    assert_eq!(top.reps(), 1, "ε > max pair distance must collapse to one");
    assert!(top.compression_ratio() < 1.0);
    println!("\nε past max distance collapses to 1 representative: OK");

    // Leader-pass wall at the p25 radius (the sweet-spot shape).
    let cfg25 = AggregateConfig::new(quantile(0.25));
    let leader = Bench::new("aggregate/leader@p25")
        .quick()
        .run(|| aggregate(&set, &cfg25, &backend, None).unwrap());

    write_json_report(&json::obj(vec![
        ("quick", json::Json::Bool(quick_mode())),
        ("n", json::num(n as f64)),
        ("plain_f", json::num(plain.f_measure)),
        ("plain_wall_secs", json::num(plain_wall)),
        ("sweep", json::arr(rows)),
        ("leader_wall", leader.to_json()),
    ]))
    .expect("writing MAHC_BENCH_JSON fragment");
}
