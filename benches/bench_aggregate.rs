//! Stage-0 aggregation cost/benefit: leader-pass wall, compression
//! ratio, end-to-end quality across the ε sweep, and the probe-engine
//! showdown (flat-serial vs rectangle-batched vs batched+tree).
//!
//! ε is data-dependent, so the harness derives the sweep from the
//! corpus itself: it builds the full condensed matrix once, takes pair-
//! distance quantiles as radii, and for each one reports the number of
//! representatives, the compression ratio m/N, and the aggregated run's
//! F-measure against the unaggregated reference.  Pins asserted on
//! every run: ε = 0 reproduces the unaggregated run bitwise, ε beyond
//! the largest pair distance collapses the corpus onto a single
//! representative, the rectangle-batched pass groups bitwise like the
//! per-row reference, the quantile-derived radius equals the harness's
//! own quantile bit for bit, and the batched+tree pass issues fewer
//! probe DTWs than the leaders × segments ceiling.
//!
//! CI hooks: `MAHC_BENCH_QUICK=1` shrinks the corpus for the perf-smoke
//! job, and `MAHC_BENCH_JSON=path` writes the sweep and the probe-mode
//! counts as a JSON fragment for `BENCH_ci.json` (diffed against the
//! committed `BENCH_baseline.json`).

use std::time::Instant;

use mahc::aggregate::{aggregate, derive_epsilon, quantile_of_sorted, Aggregation};
use mahc::config::{AggregateConfig, AlgoConfig, Convergence, DatasetSpec};
use mahc::corpus::{generate, Segment};
use mahc::distance::{build_condensed, NativeBackend};
use mahc::mahc::MahcDriver;
use mahc::util::bench::{quick_mode, write_json_report, Bench};
use mahc::util::json;

fn probe_mode_row(tag: &str, agg: &Aggregation, wall_secs: f64, n: usize) -> json::Json {
    let full = agg.reps() * n;
    json::obj(vec![
        ("tag", json::s(tag)),
        ("reps", json::num(agg.reps() as f64)),
        ("probe_pairs", json::num(agg.probe_pairs as f64)),
        ("probe_rounds", json::num(agg.probe_rounds as f64)),
        ("rect_rows", json::num(agg.rect_rows as f64)),
        ("rect_cols", json::num(agg.rect_cols as f64)),
        ("super_leaders", json::num(agg.super_leaders as f64)),
        ("full_pairs", json::num(full as f64)),
        (
            "probe_vs_full",
            json::num(agg.probe_pairs as f64 / full.max(1) as f64),
        ),
        ("wall_secs", json::num(wall_secs)),
    ])
}

fn main() {
    let n = if quick_mode() { 120 } else { 240 };
    let set = generate(&DatasetSpec::tiny(n, 12, 13));
    let backend = NativeBackend::new();
    println!("== bench_aggregate: tiny corpus at N={n} ==");

    // Pair-distance quantiles → the ε sweep.
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let cond = build_condensed(&refs, &backend, 4).unwrap();
    let mut dists: Vec<f32> = cond.as_slice().to_vec();
    dists.sort_unstable_by(f32::total_cmp);
    let quantile = |q: f64| quantile_of_sorted(&dists, q);
    let d_max = *dists.last().unwrap();

    let algo = AlgoConfig {
        p0: 4,
        beta: Some((n as f64 / 4.0 * 1.25).ceil() as usize),
        convergence: Convergence::FixedIters(3),
        ..Default::default()
    };

    let t0 = Instant::now();
    let plain = MahcDriver::new(&set, algo.clone(), &backend)
        .unwrap()
        .run()
        .unwrap();
    let plain_wall = t0.elapsed().as_secs_f64();
    println!(
        "unaggregated: K={} F={:.4} wall={plain_wall:.2}s",
        plain.k, plain.f_measure
    );

    // Pin 1: ε = 0 is the unaggregated run, bit for bit.
    let zero = MahcDriver::new(
        &set,
        AlgoConfig {
            aggregate: AggregateConfig::new(0.0),
            ..algo.clone()
        },
        &backend,
    )
    .unwrap()
    .run()
    .unwrap();
    assert_eq!(zero.labels, plain.labels, "ε=0 must be bitwise the plain run");
    assert_eq!(zero.k, plain.k);
    assert_eq!(zero.f_measure.to_bits(), plain.f_measure.to_bits());
    println!("ε=0 reproduces the unaggregated run: MATCH");

    println!("\n     ε        reps   m/N     K      F      ΔF%    wall_s");
    let mut rows: Vec<json::Json> = Vec::new();
    for (tag, eps) in [
        ("p05", quantile(0.05)),
        ("p25", quantile(0.25)),
        ("p50", quantile(0.50)),
    ] {
        let cfg = AlgoConfig {
            aggregate: AggregateConfig::new(eps),
            ..algo.clone()
        };
        let t0 = Instant::now();
        let res = MahcDriver::new(&set, cfg, &backend).unwrap().run().unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let r0 = &res.history.records[0];
        let delta = (res.f_measure - plain.f_measure) / plain.f_measure * 100.0;
        println!(
            "{tag} {eps:>9.3} {:>5} {:.3} {:>5} {:.4} {delta:>6.1} {wall:>8.2}",
            r0.representatives, r0.compression_ratio, res.k, res.f_measure
        );
        assert_eq!(res.labels.len(), n, "aggregated labels must cover all N");
        rows.push(json::obj(vec![
            ("tag", json::s(tag)),
            ("epsilon", json::num(eps as f64)),
            ("representatives", json::num(r0.representatives as f64)),
            ("compression_ratio", json::num(r0.compression_ratio)),
            ("k", json::num(res.k as f64)),
            ("f_measure", json::num(res.f_measure)),
            ("f_delta_pct", json::num(delta)),
            ("wall_secs", json::num(wall)),
        ]));
    }

    // Pin 2: a radius past the largest pair distance leaves exactly one
    // representative (every segment is within ε of the first leader).
    let top = aggregate(
        &set,
        &AggregateConfig::new(d_max * 1.01),
        &backend,
        4,
        None,
    )
    .unwrap();
    assert_eq!(top.reps(), 1, "ε > max pair distance must collapse to one");
    assert!(top.compression_ratio() < 1.0);
    println!("\nε past max distance collapses to 1 representative: OK");

    // Pin 3: the quantile-derived radius (full sample) equals this
    // harness's own p25 bit for bit — the documented estimator rule.
    let seed = AggregateConfig::default().quantile_seed;
    let est = derive_epsilon(&set, 0.25, n, seed, &backend, 4, None).unwrap();
    assert_eq!(
        est.epsilon.to_bits(),
        quantile(0.25).to_bits(),
        "full-sample quantile estimate must be exact"
    );
    assert_eq!(est.sample_pairs, dists.len());
    assert_eq!(est.sample_segments, n);
    println!("quantile-derived ε (q=0.25, full sample) is exact: MATCH");

    // Probe-engine showdown at the p25 radius: flat-serial (per-row
    // reference) vs rectangle-batched vs batched + two-level tree.
    let eps25 = quantile(0.25);
    let serial_cfg = AggregateConfig::new(eps25).with_batch_rows(1);
    let batched_cfg = AggregateConfig::new(eps25).with_batch_rows(64);
    let tree_cfg = batched_cfg.with_tree(3.0, 2);

    let t0 = Instant::now();
    let serial = aggregate(&set, &serial_cfg, &backend, 4, None).unwrap();
    let serial_wall = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let batched = aggregate(&set, &batched_cfg, &backend, 4, None).unwrap();
    let batched_wall = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let tree = aggregate(&set, &tree_cfg, &backend, 4, None).unwrap();
    let tree_wall = t0.elapsed().as_secs_f64();

    // Pin 4: batching is a dispatch-shape change only.
    assert_eq!(batched.rep_ids, serial.rep_ids, "batched rep set diverged");
    assert_eq!(batched.members, serial.members, "batched memberships diverged");

    // Pin 5: the batched+tree pass must issue measurably fewer probe
    // DTWs than the leaders × segments ceiling the flat pass is bounded
    // by (the acceptance floor; the committed baseline tracks the
    // actual ratio).
    let full = tree.reps() * n;
    assert!(
        tree.probe_pairs < full,
        "tree probes {} did not beat leaders × segments = {full}",
        tree.probe_pairs
    );

    println!("\nprobe engine at p25 (m={} leaders):", serial.reps());
    println!("  mode          probes   rounds  rect        supers  wall_s");
    for (tag, a, w) in [
        ("flat-serial", &serial, serial_wall),
        ("batched", &batched, batched_wall),
        ("batched+tree", &tree, tree_wall),
    ] {
        println!(
            "  {tag:<13} {:>6} {:>8}  {:>4}x{:<5} {:>6} {w:>7.3}",
            a.probe_pairs, a.probe_rounds, a.rect_rows, a.rect_cols, a.super_leaders
        );
    }
    println!(
        "  leaders × segments ceiling: {full} (tree issues {:.1}%)",
        tree.probe_pairs as f64 / full as f64 * 100.0
    );

    // Cluster-feature summaries at the p25 radius: the stage-1 cost of
    // clustering m count-weighted representatives instead of the N raw
    // segments, and the summary shape that prices the substitution
    // (max radius, max count, the 2·r_max·√(2·c_max) deviation bound).
    let m = batched.reps();
    let rep_pairs = m * (m - 1) / 2;
    let raw_pairs = n * (n - 1) / 2;
    let max_count = batched.summaries.iter().map(|s| s.count).max().unwrap_or(0);
    let max_radius = batched
        .summaries
        .iter()
        .map(|s| s.radius)
        .fold(0.0f32, f32::max);
    let spread_total: f64 = batched.summaries.iter().map(|s| s.spread as f64).sum();
    assert_eq!(
        batched.summaries.iter().map(|s| s.count).sum::<usize>(),
        n,
        "summary counts must partition the corpus"
    );
    assert!(
        max_radius <= eps25,
        "flat-pass radius {max_radius} exceeded ε {eps25}"
    );
    assert!(
        rep_pairs < raw_pairs,
        "p25 aggregation left no stage-1 pair savings ({rep_pairs} vs {raw_pairs})"
    );
    println!(
        "\nsummaries at p25: {m} groups, max_count={max_count}, \
         max_radius={max_radius:.4}, deviation_bound={:.4}; \
         stage-1 pairs {rep_pairs} vs raw {raw_pairs} ({:.1}%)",
        batched.deviation_bound(),
        rep_pairs as f64 / raw_pairs.max(1) as f64 * 100.0
    );

    // Leader-pass wall at the p25 radius (the sweet-spot shape),
    // batched dispatch as the drivers run it.
    let leader = Bench::new("aggregate/leader@p25")
        .quick()
        .run(|| aggregate(&set, &batched_cfg, &backend, 4, None).unwrap());

    write_json_report(&json::obj(vec![
        ("quick", json::Json::Bool(quick_mode())),
        ("n", json::num(n as f64)),
        ("plain_f", json::num(plain.f_measure)),
        ("plain_wall_secs", json::num(plain_wall)),
        ("sweep", json::arr(rows)),
        (
            "quantile",
            json::obj(vec![
                ("q", json::num(0.25)),
                ("derived_eps", json::num(est.epsilon as f64)),
                ("sample_pairs", json::num(est.sample_pairs as f64)),
                ("sample_segments", json::num(est.sample_segments as f64)),
            ]),
        ),
        (
            "probe_modes",
            json::obj(vec![
                ("serial", probe_mode_row("flat-serial", &serial, serial_wall, n)),
                ("batched", probe_mode_row("batched", &batched, batched_wall, n)),
                ("tree", probe_mode_row("batched+tree", &tree, tree_wall, n)),
            ]),
        ),
        (
            "summaries",
            json::obj(vec![
                ("groups", json::num(m as f64)),
                ("max_count", json::num(max_count as f64)),
                ("max_radius", json::num(max_radius as f64)),
                ("spread_total", json::num(spread_total)),
                ("deviation_bound", json::num(batched.deviation_bound())),
                ("rep_pairs", json::num(rep_pairs as f64)),
                ("raw_pairs", json::num(raw_pairs as f64)),
                (
                    "pair_ratio",
                    json::num(rep_pairs as f64 / raw_pairs.max(1) as f64),
                ),
            ]),
        ),
        ("leader_wall", leader.to_json()),
    ]))
    .expect("writing MAHC_BENCH_JSON fragment");
}
