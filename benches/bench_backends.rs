//! Scalar vs lane-parallel DTW backend throughput on the default
//! generator corpus, in pair-alignments per second.
//!
//! The blocked backend's whole claim is "same bits, more pairs per
//! second": this harness first proves the bits (full-tile bitwise
//! parity, a cheap subset of `rust/tests/backend_parity.rs`), then
//! measures both backends on the same tiles and asserts the ≥1.5×
//! pairs/sec floor recorded in EXPERIMENTS.md §Backends.  Banded
//! alignments share the scalar kernel, so only the full-band path is
//! raced.
//!
//! CI hooks: `MAHC_BENCH_QUICK=1` shortens the sampling windows for the
//! perf-smoke job, and `MAHC_BENCH_JSON=path` writes the measurements
//! (pairs/sec per backend, ratios, the enforced floor) as a JSON
//! fragment for the `BENCH_ci.json` artifact.

use mahc::config::DatasetSpec;
use mahc::corpus::{generate, Segment};
use mahc::distance::{build_condensed, BlockedBackend, PairwiseBackend, NativeBackend};
use mahc::util::bench::{quick_mode, write_json_report, Bench};
use mahc::util::json;

fn bench(name: &str, pairs: u64) -> Bench {
    let b = Bench::new(name).throughput(pairs);
    if quick_mode() {
        b.quick()
    } else {
        b
    }
}

fn main() {
    // The default generator corpus shape: 39-dim MFCC-like features,
    // paper-realistic segment lengths.
    let mut spec = DatasetSpec::tiny(96, 8, 11);
    spec.feat_dim = 39;
    spec.len_range = (6, 60);
    let set = generate(&spec);
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let (xs, ys) = (&refs[..32], &refs[32..96]);
    let pairs = (xs.len() * ys.len()) as u64;

    let native = NativeBackend::new();
    let blocked = BlockedBackend::new();

    // Parity before speed: a benchmark of wrong answers is worthless.
    let a = native.pairwise(xs, ys).unwrap();
    let b = blocked.pairwise(xs, ys).unwrap();
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "pair {i}: {x} vs {y}");
    }

    println!("== bench_backends: 32x64 pair tile, T in 6..60, D=39 ==");
    let rn = bench("native/tile32x64", pairs).run(|| native.pairwise(xs, ys).unwrap());
    let rb = bench("blocked/tile32x64", pairs).run(|| blocked.pairwise(xs, ys).unwrap());
    let tile_ratio = rb.throughput.unwrap() / rn.throughput.unwrap();

    // The production shape: a full condensed build through the parallel
    // builder (same 16-row blocking for both backends).
    let cond_pairs = (refs.len() * (refs.len() - 1) / 2) as u64;
    let cn =
        bench("native/condensed96", cond_pairs).run(|| build_condensed(&refs, &native, 4).unwrap());
    let cb = bench("blocked/condensed96", cond_pairs)
        .run(|| build_condensed(&refs, &blocked, 4).unwrap());
    let cond_ratio = cb.throughput.unwrap() / cn.throughput.unwrap();

    println!();
    println!("blocked/native pairs-per-sec ratio:");
    println!("  tile32x64    {tile_ratio:.2}x");
    println!("  condensed96  {cond_ratio:.2}x");

    // The acceptance floor from EXPERIMENTS.md §Backends.  Override via
    // MAHC_BENCH_FLOOR (e.g. 0 to record numbers on hardware whose
    // vector units can't honour the default — correctness parity above
    // has already passed by this point either way).
    let floor: f64 = std::env::var("MAHC_BENCH_FLOOR")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.5);

    write_json_report(&json::obj(vec![
        ("quick", json::Json::Bool(quick_mode())),
        ("floor", json::num(floor)),
        ("tile_ratio", json::num(tile_ratio)),
        ("condensed_ratio", json::num(cond_ratio)),
        (
            "series",
            json::arr(vec![rn.to_json(), rb.to_json(), cn.to_json(), cb.to_json()]),
        ),
    ]))
    .expect("writing MAHC_BENCH_JSON fragment");

    assert!(
        tile_ratio >= floor,
        "blocked backend must deliver >= {floor}x pairs/sec on the default \
         corpus tile (got {tile_ratio:.2}x) — see EXPERIMENTS.md §Backends"
    );
}
