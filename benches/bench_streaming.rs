//! Streaming vs batch MAHC cost, and the shard-size knob.
//!
//! One sample = one complete run over the same corpus, so batch and
//! stream numbers are directly comparable.  Alongside wall-clock the
//! harness prints the memory story — peak condensed-matrix bytes per
//! configuration — which is the quantity the β bound (and therefore
//! the shard size) controls.
//!
//! CI hooks: `MAHC_BENCH_QUICK=1` shrinks the corpus and sampling
//! windows for the perf-smoke job, and `MAHC_BENCH_JSON=path` writes
//! the per-phase walls, peak bytes and quality table as a JSON fragment
//! for the `BENCH_ci.json` artifact.

use mahc::config::{AlgoConfig, Convergence, DatasetSpec, NamedDataset, StreamConfig};
use mahc::corpus::generate;
use mahc::distance::NativeBackend;
use mahc::mahc::{MahcDriver, StreamingDriver};
use mahc::util::bench::{quick_mode, write_json_report, Bench};
use mahc::util::json;

fn main() {
    let scale = if quick_mode() { 0.01 } else { 0.02 };
    let set = generate(&DatasetSpec::named(NamedDataset::SmallA, scale));
    let n = set.len();
    println!("== bench_streaming: small_a at N={n} ==");
    let backend = NativeBackend::new();

    let beta = (n as f64 / 4.0 * 1.25).ceil() as usize;
    let algo = AlgoConfig {
        p0: 4,
        beta: Some(beta),
        convergence: Convergence::FixedIters(3),
        cache_bytes: 64 << 20,
        ..Default::default()
    };

    let mut walls: Vec<json::Json> = Vec::new();
    let rb = Bench::new("batch/3iters").quick().run(|| {
        MahcDriver::new(&set, algo.clone(), &backend)
            .unwrap()
            .run()
            .unwrap()
    });
    walls.push(rb.to_json());

    for shard_size in [n, n.div_ceil(2), n.div_ceil(4)] {
        let cfg = StreamConfig::new(algo.clone(), shard_size);
        let name = format!("stream/shard={shard_size}");
        let r = Bench::new(&name).quick().run(|| {
            StreamingDriver::new(&set, cfg.clone(), &backend)
                .unwrap()
                .run()
                .unwrap()
        });
        walls.push(r.to_json());
    }

    // Memory + quality story at each shard size (one run each).
    let batch = MahcDriver::new(&set, algo.clone(), &backend)
        .unwrap()
        .run()
        .unwrap();
    println!(
        "\nβ={beta}  batch: K={} F={:.4} peak_B={}",
        batch.k,
        batch.f_measure,
        batch.history.peak_matrix_bytes()
    );
    let mut table: Vec<json::Json> = Vec::new();
    println!("shard_size shards  K     F      peak_B  cache_hit%  assign_hit%");
    for shard_size in [n, n.div_ceil(2), n.div_ceil(4), n.div_ceil(8)] {
        let cfg = StreamConfig::new(algo.clone(), shard_size);
        let res = StreamingDriver::new(&set, cfg, &backend)
            .unwrap()
            .run()
            .unwrap();
        for r in &res.history.records {
            assert!(
                r.max_occupancy <= beta,
                "β bound violated in shard {}",
                r.iteration
            );
        }
        println!(
            "{:>10} {:>6} {:>4} {:.4} {:>8} {:>11.1} {:>12.1}",
            shard_size,
            res.shards,
            res.k,
            res.f_measure,
            res.history.peak_matrix_bytes(),
            res.history.cache_total().hit_rate() * 100.0,
            res.assign_cache.hit_rate() * 100.0
        );
        table.push(json::obj(vec![
            ("shard_size", json::num(shard_size as f64)),
            ("shards", json::num(res.shards as f64)),
            ("k", json::num(res.k as f64)),
            ("f_measure", json::num(res.f_measure)),
            ("peak_bytes", json::num(res.history.peak_matrix_bytes() as f64)),
            (
                "cache_hit_rate",
                json::num(res.history.cache_total().hit_rate()),
            ),
            ("assign_hit_rate", json::num(res.assign_cache.hit_rate())),
        ]));
    }

    // The single-shard stream must be the batch run, bit for bit.
    let one = StreamingDriver::new(&set, StreamConfig::new(algo, n), &backend)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(one.labels, batch.labels, "single-shard stream diverged");
    assert_eq!(one.k, batch.k);
    println!("\nsingle-shard stream reproduces the batch run: MATCH");

    write_json_report(&json::obj(vec![
        ("quick", json::Json::Bool(quick_mode())),
        ("n", json::num(n as f64)),
        ("beta", json::num(beta as f64)),
        ("batch_f", json::num(batch.f_measure)),
        (
            "batch_peak_bytes",
            json::num(batch.history.peak_matrix_bytes() as f64),
        ),
        ("walls", json::arr(walls)),
        ("shard_table", json::arr(table)),
    ]))
    .expect("writing MAHC_BENCH_JSON fragment");
}
