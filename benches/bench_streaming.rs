//! Streaming vs batch MAHC cost, and the shard-size knob.
//!
//! One sample = one complete run over the same corpus, so batch and
//! stream numbers are directly comparable.  Alongside wall-clock the
//! harness prints the memory story — peak condensed-matrix bytes per
//! configuration — which is the quantity the β bound (and therefore
//! the shard size) controls.

use mahc::config::{AlgoConfig, Convergence, DatasetSpec, NamedDataset, StreamConfig};
use mahc::corpus::generate;
use mahc::distance::NativeBackend;
use mahc::mahc::{MahcDriver, StreamingDriver};
use mahc::util::bench::Bench;

fn main() {
    let set = generate(&DatasetSpec::named(NamedDataset::SmallA, 0.02));
    let n = set.len();
    println!("== bench_streaming: small_a at N={n} ==");
    let backend = NativeBackend::new();

    let beta = (n as f64 / 4.0 * 1.25).ceil() as usize;
    let algo = AlgoConfig {
        p0: 4,
        beta: Some(beta),
        convergence: Convergence::FixedIters(3),
        cache_bytes: 64 << 20,
        ..Default::default()
    };

    Bench::new("batch/3iters").quick().run(|| {
        MahcDriver::new(&set, algo.clone(), &backend)
            .unwrap()
            .run()
            .unwrap()
    });

    for shard_size in [n, n.div_ceil(2), n.div_ceil(4)] {
        let cfg = StreamConfig::new(algo.clone(), shard_size);
        let name = format!("stream/shard={shard_size}");
        Bench::new(&name).quick().run(|| {
            StreamingDriver::new(&set, cfg.clone(), &backend)
                .unwrap()
                .run()
                .unwrap()
        });
    }

    // Memory + quality story at each shard size (one run each).
    let batch = MahcDriver::new(&set, algo.clone(), &backend)
        .unwrap()
        .run()
        .unwrap();
    println!("\nβ={beta}  batch: K={} F={:.4} peak_B={}", batch.k, batch.f_measure, batch.history.peak_bytes());
    println!("shard_size shards  K     F      peak_B  cache_hit%  assign_hit%");
    for shard_size in [n, n.div_ceil(2), n.div_ceil(4), n.div_ceil(8)] {
        let cfg = StreamConfig::new(algo.clone(), shard_size);
        let res = StreamingDriver::new(&set, cfg, &backend)
            .unwrap()
            .run()
            .unwrap();
        for r in &res.history.records {
            assert!(
                r.max_occupancy <= beta,
                "β bound violated in shard {}",
                r.iteration
            );
        }
        println!(
            "{:>10} {:>6} {:>4} {:.4} {:>8} {:>11.1} {:>12.1}",
            shard_size,
            res.shards,
            res.k,
            res.f_measure,
            res.history.peak_bytes(),
            res.history.cache_total().hit_rate() * 100.0,
            res.assign_cache.hit_rate() * 100.0
        );
    }

    // The single-shard stream must be the batch run, bit for bit.
    let one = StreamingDriver::new(&set, StreamConfig::new(algo, n), &backend)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(one.labels, batch.labels, "single-shard stream diverged");
    assert_eq!(one.k, batch.k);
    println!("\nsingle-shard stream reproduces the batch run: MATCH");
}
