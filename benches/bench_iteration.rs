//! End-to-end MAHC iteration cost — the paper's Fig. 6 quantity — and
//! the MAHC-vs-MAHC+M wall-clock comparison, plus a full-AHC reference.
//!
//! One sample = one complete clustering run (fixed iterations), so the
//! numbers are directly comparable across algorithms on the same data.

use mahc::baselines::full_ahc;
use mahc::config::{AlgoConfig, Convergence, DatasetSpec, NamedDataset};
use mahc::corpus::generate;
use mahc::distance::NativeBackend;
use mahc::mahc::MahcDriver;
use mahc::util::bench::Bench;

fn main() {
    let set = generate(&DatasetSpec::named(NamedDataset::SmallA, 0.02));
    let n = set.len();
    println!("== bench_iteration: small_a at N={n} ==");
    let backend = NativeBackend::new();

    let base = AlgoConfig {
        p0: 4,
        convergence: Convergence::FixedIters(3),
        ..Default::default()
    };

    let cfg_plain = AlgoConfig {
        beta: None,
        ..base.clone()
    };
    Bench::new("mahc/3iters")
        .quick()
        .run(|| MahcDriver::new(&set, cfg_plain.clone(), &backend).unwrap().run().unwrap());

    let beta = (n as f64 / 4.0 * 1.25).ceil() as usize;
    let cfg_managed = AlgoConfig {
        beta: Some(beta),
        ..base
    };
    Bench::new("mahc+m/3iters")
        .quick()
        .run(|| MahcDriver::new(&set, cfg_managed.clone(), &backend).unwrap().run().unwrap());

    Bench::new("full_ahc")
        .quick()
        .run(|| full_ahc(&set, &backend, 4, None, 0.25).unwrap());
}
