//! End-to-end MAHC iteration cost — the paper's Fig. 6 quantity — and
//! the MAHC-vs-MAHC+M wall-clock comparison, plus a full-AHC reference
//! and the cross-iteration pair-cache ablation (cache off vs on, with
//! per-iteration hit-rate telemetry).
//!
//! One sample = one complete clustering run (fixed iterations), so the
//! numbers are directly comparable across algorithms on the same data.

use mahc::baselines::full_ahc;
use mahc::config::{AlgoConfig, Convergence, DatasetSpec, NamedDataset};
use mahc::corpus::generate;
use mahc::distance::NativeBackend;
use mahc::mahc::MahcDriver;
use mahc::util::bench::Bench;

fn main() {
    let set = generate(&DatasetSpec::named(NamedDataset::SmallA, 0.02));
    let n = set.len();
    println!("== bench_iteration: small_a at N={n} ==");
    let backend = NativeBackend::new();

    let base = AlgoConfig {
        p0: 4,
        convergence: Convergence::FixedIters(3),
        ..Default::default()
    };

    let cfg_plain = AlgoConfig {
        beta: None,
        ..base.clone()
    };
    Bench::new("mahc/3iters")
        .quick()
        .run(|| MahcDriver::new(&set, cfg_plain.clone(), &backend).unwrap().run().unwrap());

    let beta = (n as f64 / 4.0 * 1.25).ceil() as usize;
    let cfg_managed = AlgoConfig {
        beta: Some(beta),
        ..base.clone()
    };
    Bench::new("mahc+m/3iters")
        .quick()
        .run(|| MahcDriver::new(&set, cfg_managed.clone(), &backend).unwrap().run().unwrap());

    // Cache ablation: identical run with the cross-iteration pair
    // cache enabled.  Results are bitwise identical (asserted below);
    // only wall-clock and the hit-rate telemetry differ.
    let cfg_cached = AlgoConfig {
        cache_bytes: 64 << 20,
        ..cfg_managed.clone()
    };
    Bench::new("mahc+m-cached/3iters")
        .quick()
        .run(|| MahcDriver::new(&set, cfg_cached.clone(), &backend).unwrap().run().unwrap());

    let plain = MahcDriver::new(&set, cfg_managed.clone(), &backend)
        .unwrap()
        .run()
        .unwrap();
    let cached = MahcDriver::new(&set, cfg_cached.clone(), &backend)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(
        plain.labels, cached.labels,
        "cache must not change clustering results"
    );
    println!("cache telemetry (mahc+m-cached, β={beta}):");
    for r in &cached.history.records {
        println!(
            "  iter {}: {:>5.1}% hit rate ({} hits, {} misses, {} evictions)",
            r.iteration,
            r.cache.hit_rate() * 100.0,
            r.cache.hits,
            r.cache.misses,
            r.cache.evictions
        );
    }
    let total = cached.history.cache_total();
    println!(
        "  run total: {:.1}% of pair distances served from cache",
        total.hit_rate() * 100.0
    );
    if let Some(third) = cached.history.records.get(2) {
        assert!(
            third.cache.hit_rate() >= 0.30,
            "expected >=30% of pair distances from cache by iteration 3, got {:.1}%",
            third.cache.hit_rate() * 100.0
        );
    }

    Bench::new("full_ahc")
        .quick()
        .run(|| full_ahc(&set, &backend, 4, None, 0.25).unwrap());
}
