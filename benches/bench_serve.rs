//! Fleet throughput: N concurrent streaming sessions vs the same
//! sessions run back to back.
//!
//! One sample = one complete serve run (admission through the last
//! session's resolve), so the serve walls are directly comparable to
//! the summed sequential walls.  Alongside wall-clock the harness
//! reports the fleet counters the serve telemetry layer samples:
//! aggregate pairs/sec, scheduler stalls (backpressure), and peak
//! shared-cache residency against the per-session budgets.
//!
//! CI hooks: `MAHC_BENCH_QUICK=1` shrinks corpora and sampling windows
//! for the perf-smoke job, and `MAHC_BENCH_JSON=path` writes the
//! fleet-throughput table as a JSON fragment for `BENCH_ci.json`.

use std::sync::Arc;

use mahc::config::{AlgoConfig, Convergence, DatasetSpec, ServeConfig, StreamConfig};
use mahc::corpus::{generate, SegmentSet};
use mahc::distance::{PairwiseBackend, NativeBackend};
use mahc::mahc::{ServeDriver, SessionSpec, StreamingDriver};
use mahc::util::bench::{quick_mode, write_json_report, Bench};
use mahc::util::json;

fn main() {
    let sessions = 4usize;
    let n = if quick_mode() { 70 } else { 220 };
    let budget = 64 << 10;
    println!("== bench_serve: {sessions} sessions over tiny corpora of ~{n} segments ==");

    let sets: Vec<Arc<SegmentSet>> = (0..sessions)
        .map(|i| Arc::new(generate(&DatasetSpec::tiny(n + 10 * i, 5, 7000 + i as u64))))
        .collect();
    let cfg = StreamConfig::new(
        AlgoConfig {
            p0: 2,
            beta: Some(if quick_mode() { 28 } else { 64 }),
            convergence: Convergence::FixedIters(2),
            cache_bytes: budget,
            ..Default::default()
        },
        if quick_mode() { 28 } else { 72 },
    );
    let backend: Arc<dyn PairwiseBackend + Send + Sync> = Arc::new(NativeBackend::new());
    let specs = || -> Vec<SessionSpec> {
        sets.iter()
            .enumerate()
            .map(|(i, set)| SessionSpec::new(&format!("s{i}"), Arc::clone(set), cfg.clone()))
            .collect()
    };

    let mut walls: Vec<json::Json> = Vec::new();

    // Baseline: the same sessions one after another on this thread.
    let rs = Bench::new("sequential/4sessions").quick().run(|| {
        sets.iter()
            .map(|set| {
                StreamingDriver::new(set, cfg.clone(), &NativeBackend::new())
                    .unwrap()
                    .run()
                    .unwrap()
            })
            .collect::<Vec<_>>()
    });
    walls.push(rs.to_json());

    // The fleet at increasing pool sizes.
    let mut fleet_rows: Vec<json::Json> = Vec::new();
    println!("workers  peak_active  stalls  peak_cache_B  pairs/s");
    for workers in [1usize, 2, 4] {
        let serve_cfg = ServeConfig {
            workers,
            fleet_cap: sessions,
            queue_cap: 0,
            cache_bytes: 8 << 20,
        };
        let name = format!("serve/workers={workers}");
        let r = Bench::new(&name).quick().run(|| {
            ServeDriver::new(serve_cfg.clone(), Arc::clone(&backend))
                .unwrap()
                .run(specs())
                .unwrap()
        });
        walls.push(r.to_json());

        let report = ServeDriver::new(serve_cfg, Arc::clone(&backend))
            .unwrap()
            .run(specs())
            .unwrap();
        assert_eq!(report.completed(), sessions, "a session failed");
        let peak_cache = report.fleet.peak_cache_bytes();
        assert!(
            peak_cache <= sessions * budget,
            "residency {peak_cache} exceeds session budgets"
        );
        let stalls = report.fleet.records.last().map_or(0, |rec| rec.stalls);
        println!(
            "{:>7} {:>12} {:>7} {:>13} {:>8.0}",
            workers,
            report.fleet.peak_active(),
            stalls,
            peak_cache,
            report.fleet.final_pairs_per_sec()
        );
        fleet_rows.push(json::obj(vec![
            ("workers", json::num(workers as f64)),
            ("peak_active", json::num(report.fleet.peak_active() as f64)),
            ("stalls", json::num(stalls as f64)),
            ("peak_cache_bytes", json::num(peak_cache as f64)),
            (
                "fleet_pairs_per_sec",
                json::num(report.fleet.final_pairs_per_sec()),
            ),
        ]));
    }

    write_json_report(&json::obj(vec![
        ("quick", json::Json::Bool(quick_mode())),
        ("sessions", json::num(sessions as f64)),
        ("n_base", json::num(n as f64)),
        ("session_budget_bytes", json::num(budget as f64)),
        ("walls", json::arr(walls)),
        ("fleet", json::arr(fleet_rows)),
    ]))
    .expect("writing MAHC_BENCH_JSON fragment");
}
