#!/usr/bin/env python3
"""Toolchain-free mirror of tools/lint/src/lib.rs (mahc-lint).

An exact Python transliteration of the analyzer, for environments
without a Rust toolchain (the Rust crate and its fixture tests remain
the source of truth — if the two disagree, the mirror is wrong).
Every helper mirrors the Rust function of the same name, operating on
bytes; keep them in lockstep when editing lib.rs.

Usage:
  python3 tools/lint/mirror.py ROOT                  # list findings
  python3 tools/lint/mirror.py ROOT --apply          # apply ROOT/tools/lint/allowlist.toml, exit 0/1
  python3 tools/lint/mirror.py ROOT --emit-allowlist # print grouped TOML skeleton
"""
import os
import sys

R001_DIRS = ["ahc", "mahc", "aggregate", "distance", "corpus"]
ITER_CALLS = [b"iter()", b"iter_mut()", b"into_iter()", b"keys()",
              b"values()", b"values_mut()", b"drain(", b"retain("]
R004_PATTERNS = [b"Instant::now", b"SystemTime", b"thread_rng", b"rand::random"]
RULES = ["R001", "R002", "R003", "R004", "R005", "R006"]
ALIASES = {"R001": b"order-insensitive", "R002": b"in-bounds", "R003": b"fixed-order"}
PANIC_MACROS = ["panic", "unreachable", "todo", "unimplemented"]


def is_ident(b):
    return (48 <= b <= 57) or (65 <= b <= 90) or (97 <= b <= 122) or b == 95


def find_from(hay, needle, start):
    if not needle or start > len(hay):
        return None
    p = hay.find(needle, start)
    return None if p < 0 else p


def contains(hay, needle):
    return find_from(hay, needle, 0) is not None


def trim_end(b):
    end = len(b)
    while end > 0 and chr(b[end - 1]).isspace() and b[end - 1] < 128:
        end -= 1
    return b[:end]


def trim(b):
    t = trim_end(b)
    start = 0
    while start < len(t) and t[start] < 128 and chr(t[start]).isspace():
        start += 1
    return t[start:]


def trailing_ident(b):
    start = len(b)
    while start > 0 and is_ident(b[start - 1]):
        start -= 1
    return b[start:]


def strip_literals(text):
    out = bytearray()
    i, n = 0, len(text)
    while i < n:
        c = text[i]
        if c == ord('r') and (i == 0 or not is_ident(text[i - 1])):
            j = i + 1
            hashes = 0
            while j < n and text[j] == ord('#'):
                hashes += 1
                j += 1
            if j < n and text[j] == ord('"'):
                k = j + 1
                end = None
                while True:
                    q = find_from(text, b'"', k)
                    if q is None:
                        end = n
                        break
                    if len(text) - (q + 1) >= hashes and all(
                            b == ord('#') for b in text[q + 1:q + 1 + hashes]):
                        end = q + 1 + hashes
                        break
                    k = q + 1
                out += text[i:j + 1]
                for b in text[j + 1:min(end, n)]:
                    if b == ord('\n'):
                        out.append(b)
                out.append(ord('"'))
                out += b'#' * hashes
                i = end
                continue
            out.append(c)
            i += 1
        elif c == ord('"'):
            j = i + 1
            while j < n:
                if text[j] == ord('\\'):
                    j += 2
                    continue
                if text[j] == ord('"'):
                    break
                j += 1
            out.append(ord('"'))
            for b in text[i + 1:min(j, n)]:
                if b == ord('\n'):
                    out.append(b)
            out.append(ord('"'))
            i = j + 1
        elif c == ord("'"):
            if i + 3 < n and text[i + 1] == ord('\\') and text[i + 3] == ord("'"):
                out += b"''"
                i += 4
            elif i + 2 < n and text[i + 2] == ord("'"):
                out += b"''"
                i += 3
            else:
                out.append(c)
                i += 1
        else:
            out.append(c)
            i += 1
    return bytes(out)


def split_comment(line):
    idx = find_from(line, b"//", 0)
    if idx is None:
        return line, b""
    return line[:idx], line[idx:]


def suppressions(comment):
    pos = find_from(comment, b"lint:", 0)
    if pos is None:
        return []
    text = comment[pos + 5:]
    out = []
    start = 0
    while True:
        p = find_from(text, b"allow(", start)
        if p is None:
            break
        rest = text[p + 6:]
        if (len(rest) >= 5 and rest[0] == ord('R')
                and all(48 <= b <= 57 for b in rest[1:4]) and rest[4] == ord(')')):
            rid = rest[:4].decode()
            if rid in RULES and rid not in out:
                out.append(rid)
        start = p + 6
    for rule in RULES:
        alias = ALIASES.get(rule)
        if alias is None:
            continue
        start = 0
        while True:
            p = find_from(text, alias, start)
            if p is None:
                break
            before_ok = p == 0 or (not is_ident(text[p - 1]) and text[p - 1] != ord('-'))
            end = p + len(alias)
            after_ok = end >= len(text) or (not is_ident(text[end]) and text[end] != ord('-'))
            if before_ok and after_ok:
                if rule not in out:
                    out.append(rule)
                break
            start = p + 1
    return out


def ident_occurrences(code, name):
    out = []
    start = 0
    while True:
        p = find_from(code, name, start)
        if p is None:
            break
        before_ok = p == 0 or not is_ident(code[p - 1])
        end = p + len(name)
        after_ok = end >= len(code) or not is_ident(code[end])
        if before_ok and after_ok:
            out.append(p)
        start = p + 1
    return out


def skip_spaces(code, i):
    while i < len(code) and code[i] == ord(' '):
        i += 1
    return i


def brace_balance(code):
    return code.count(b'{') - code.count(b'}')


def ident_tokens(text):
    out = []
    i, n = 0, len(text)
    while i < n:
        if is_ident(text[i]):
            start = i
            while i < n and is_ident(text[i]):
                i += 1
            run = text[start:i]
            while run and 48 <= run[0] <= 57:
                run = run[1:]
            if run:
                out.append(run)
        else:
            i += 1
    return out


class Classified:
    def __init__(self, text):
        raw = strip_literals(text).split(b"\n")
        self.codes, self.sups = [], []
        for line in raw:
            code, comment = split_comment(line)
            self.sups.append(suppressions(comment))
            self.codes.append(code)
        self.exempt = [False] * len(self.codes)
        i = 0
        while i < len(self.codes):
            t = trim(self.codes[i])
            if t.startswith(b"#[cfg(test)]") or t.startswith(b"#[test]"):
                j = i
                bal = 0
                seen_open = False
                while j < len(self.codes):
                    self.exempt[j] = True
                    bal += brace_balance(self.codes[j])
                    if contains(self.codes[j], b"{"):
                        seen_open = True
                    if seen_open and bal <= 0:
                        break
                    j += 1
                i = j + 1
                continue
            i += 1

    def suppressed(self, i, rule):
        if rule in self.sups[i]:
            return True
        return i > 0 and rule in self.sups[i - 1] and not trim(self.codes[i - 1])


def hash_decl_names(code):
    out = []
    for kw in (b"HashMap", b"HashSet"):
        start = 0
        while True:
            p = find_from(code, kw, start)
            if p is None:
                break
            start = p + len(kw)
            k = p
            if code[:k].endswith(b"std::collections::"):
                k -= len(b"std::collections::")
            before = trim_end(code[:k])
            if not before:
                continue
            sep = before[-1]
            if sep != ord(':') and sep != ord('='):
                continue
            lhs = before[:-1]
            if sep == ord(':') and lhs.endswith(b":"):
                continue
            name = trailing_ident(trim_end(lhs))
            if not name:
                continue
            if not (97 <= name[0] <= 122 or name[0] == ord('_')):
                continue
            if name not in out:
                out.append(name)
    return out


def iterating_call(code, var):
    for p in ident_occurrences(code, var):
        i = skip_spaces(code, p + len(var))
        if i >= len(code) or code[i] != ord('.'):
            continue
        i = skip_spaces(code, i + 1)
        for call in ITER_CALLS:
            if code[i:].startswith(call):
                return call.decode()
    return None


def for_in_var(code, var):
    if not ident_occurrences(code, b"for"):
        return False
    for p in ident_occurrences(code, var):
        pre = trim_end(code[:p])
        if pre.endswith(b"mut"):
            pre = trim_end(pre[:-3])
        if pre.endswith(b"&"):
            pre = trim_end(pre[:-1])
        if pre.endswith(b"in") and (len(pre) == 2 or not is_ident(pre[-3])):
            return True
    return False


def collects_then_iterates(code):
    c0 = find_from(code, b"collect::<", 0)
    if c0 is None:
        return False
    rest = code[c0:]
    g = find_from(rest, b">>()", 0)
    if g is None:
        return False
    generic = rest[:g]
    if not contains(generic, b"HashMap") and not contains(generic, b"HashSet"):
        return False
    i = skip_spaces(rest, g + 4)
    if i >= len(rest) or rest[i] != ord('.'):
        return False
    i = skip_spaces(rest, i + 1)
    return any(rest[i:].startswith(c) for c in (b"iter()", b"into_iter()", b"keys()", b"values()"))


def macro_invoked(code, name):
    for p in ident_occurrences(code, name.encode()):
        i = p + len(name)
        if i < len(code) and code[i] == ord('!'):
            j = skip_spaces(code, i + 1)
            if j < len(code) and code[j] == ord('('):
                return True
    return False


def strip_assert_macros(code):
    cut = len(code)
    for name in ("assert", "debug_assert"):
        nb = name.encode()
        start = 0
        while True:
            p = find_from(code, nb, start)
            if p is None:
                break
            start = p + 1
            if p > 0 and is_ident(code[p - 1]):
                continue
            i = p + len(nb)
            while i < len(code) and (97 <= code[i] <= 122 or code[i] == ord('_')):
                i += 1
            if i < len(code) and code[i] == ord('!'):
                cut = min(cut, p)
    return code[:cut]


def indexing_sites(code):
    stripped = strip_assert_macros(code)
    out = []
    for i, b in enumerate(stripped):
        if b != ord('['):
            continue
        before = trim_end(stripped[:i])
        if not before:
            continue
        prev = before[-1]
        if not (is_ident(prev) or prev == ord(')') or prev == ord(']')):
            continue
        word = trailing_ident(before)
        if word == b"vec":
            continue
        word_start = len(before) - len(word)
        if word_start > 0 and before[word_start - 1] == ord("'"):
            continue
        out.append(word)
    return out


def in_dirs(rel, dirs):
    return any(rel.startswith("rust/src/" + d + "/") for d in dirs)


def scan_file(rel, text):
    lines = Classified(text)
    findings = []

    def emit(i, rule, message):
        if not lines.exempt[i] and not lines.suppressed(i, rule):
            findings.append((rule, rel, i + 1, message))

    if in_dirs(rel, R001_DIRS):
        hash_vars = []
        for code in lines.codes:
            for name in hash_decl_names(code):
                if name not in hash_vars:
                    hash_vars.append(name)
        for i, code in enumerate(lines.codes):
            for var in hash_vars:
                v = var.decode("utf-8", "replace")
                call = iterating_call(code, var)
                if call is not None:
                    emit(i, "R001", f"`{v}.{call}` iterates a hash collection in hasher order")
                if for_in_var(code, var):
                    emit(i, "R001", f"`for .. in {v}` iterates a hash collection in hasher order")
            if collects_then_iterates(code):
                emit(i, "R001", "iterating a freshly collected hash container")

    r002_exempt = rel == "rust/src/main.rs" or rel.startswith("rust/src/bin/")
    if not r002_exempt:
        for i, code in enumerate(lines.codes):
            t = trim(code)
            if t.startswith(b"debug_assert") or t.startswith(b"assert"):
                continue
            if contains(code, b".unwrap()"):
                emit(i, "R002", "panicking call `.unwrap()` in library code")
            if contains(code, b".expect("):
                emit(i, "R002", "panicking call `.expect(..)` in library code")
            for name in PANIC_MACROS:
                if macro_invoked(code, name):
                    emit(i, "R002", f"panicking macro `{name}!` in library code")
            for word in indexing_sites(code):
                w = word.decode("utf-8", "replace")
                emit(i, "R002", f"unchecked indexing `{w}[..]` without a bound justification")

    if in_dirs(rel, ["distance", "ahc"]):
        for i, code in enumerate(lines.codes):
            if contains(code, b".sum::<f32>()"):
                emit(i, "R003", "f32 `.sum()` outside the fixed-order kernels")
            elif contains(code, b".sum()") or contains(code, b".fold("):
                ctx = bytearray()
                if i > 0:
                    ctx += lines.codes[i - 1]
                    ctx += b" "
                ctx += code
                if contains(bytes(ctx), b"f32") and not contains(bytes(ctx), b"f64"):
                    emit(i, "R003", "possible f32 reduction outside the fixed-order kernels")

    for i, code in enumerate(lines.codes):
        if ident_occurrences(code, b"DtwBackend"):
            emit(i, "R006",
                 "removed alias `DtwBackend` — the shared trait is `PairwiseBackend`")

    r004_exempt = (in_dirs(rel, ["telemetry"]) or rel == "rust/src/util/bench.rs"
                   or rel == "rust/src/util/rng.rs")
    if not r004_exempt:
        for i, code in enumerate(lines.codes):
            for pat in R004_PATTERNS:
                if contains(code, pat):
                    emit(i, "R004",
                         f"nondeterministic source `{pat.decode()}` outside telemetry/bench/rng")

    return findings


def pub_field_name(code):
    t = trim(code)
    if not t.startswith(b"pub "):
        return None
    rest = trim(t[4:])
    end = 0
    while end < len(rest) and is_ident(rest[end]):
        end += 1
    if end == 0:
        return None
    after = skip_spaces(rest, end)
    if after < len(rest) and rest[after] == ord(':'):
        return rest[:end]
    return None


def scan_telemetry(root):
    tpath = os.path.join(root, "rust/src/telemetry/mod.rs")
    mpath = os.path.join(root, "rust/src/main.rs")
    if not os.path.isfile(tpath) or not os.path.isfile(mpath):
        return []
    with open(tpath, "rb") as f:
        ttext = f.read()
    codes = [split_comment(l)[0] for l in strip_literals(ttext).split(b"\n")]

    fields = []
    struct_line = None
    in_struct = False
    depth = 0
    for i, code in enumerate(codes):
        if struct_line is None and contains(code, b"struct IterationRecord"):
            struct_line = i
            in_struct = True
            depth = 0
        if in_struct:
            name = pub_field_name(code)
            if name is not None:
                fields.append((name, i + 1))
            depth += brace_balance(code)
            if depth <= 0 and struct_line is not None and i > struct_line:
                in_struct = False

    to_json_body = bytearray()
    if struct_line is not None:
        j = None
        for i in range(struct_line, len(codes)):
            if contains(codes[i], b"fn to_json"):
                j = i
                break
        if j is not None:
            for code in codes[j:j + 60]:
                to_json_body += code
                to_json_body += b"\n"
    to_json_body = bytes(to_json_body)

    with open(mpath, "rb") as f:
        mtext = f.read()
    tokens = ident_tokens(mtext)

    findings = []
    for name, line in fields:
        n = name.decode()
        if not contains(to_json_body, b"self." + name):
            findings.append(("R005", "rust/src/telemetry/mod.rs", line,
                             f"IterationRecord field `{n}` missing from the JSON writer"))
        prefix = name + b"_"
        in_cli = any(t == name or t.startswith(prefix) for t in tokens)
        if not in_cli:
            findings.append(("R005", "rust/src/telemetry/mod.rs", line,
                             f"IterationRecord field `{n}` missing from the CLI summaries"))
    return findings


def walk_sorted(d, out):
    entries = sorted(os.path.join(d, e) for e in os.listdir(d))
    for path in entries:
        if os.path.isdir(path):
            walk_sorted(path, out)
        elif path.endswith(".rs"):
            out.append(path)


def scan_root(root):
    src = os.path.join(root, "rust/src")
    files = []
    walk_sorted(src, files)
    findings = []
    for path in files:
        rel = os.path.relpath(path, root).replace("\\", "/")
        with open(path, "rb") as f:
            findings.extend(scan_file(rel, f.read()))
    findings.extend(scan_telemetry(root))
    findings.sort(key=lambda f: (f[1], f[2], f[0]))
    return findings


def parse_allowlist(text):
    entries = []
    cur = None

    def finish(p):
        if p["rule"] is None or p["path"] is None or p["reason"] is None:
            raise SystemExit(f"allowlist entry at line {p['line']} incomplete")
        c = p["count"] if p["count"] is not None else 1
        if c < 1 or not p["reason"].strip():
            raise SystemExit(f"allowlist entry at line {p['line']} invalid")
        entries.append((p["rule"], p["path"], c, p["reason"]))

    for idx, raw in enumerate(text.split("\n")):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line == "[[allow]]":
            if cur is not None:
                finish(cur)
            cur = {"rule": None, "path": None, "count": None, "reason": None, "line": idx + 1}
            continue
        key, _, value = line.partition("=")
        key, value = key.strip(), value.strip()
        if cur is None:
            raise SystemExit(f"allowlist line {idx + 1}: key before [[allow]]")
        if key == "count":
            cur["count"] = int(value)
        else:
            assert value.startswith('"') and value.endswith('"'), (idx + 1, value)
            cur[key] = value[1:-1].replace('\\"', '"')
    if cur is not None:
        finish(cur)
    return entries


def apply_allowlist(findings, entries):
    errors = []
    seen = set()
    for rule, path, count, _ in entries:
        if (rule, path) in seen:
            errors.append(f"duplicate allowlist entry for {rule} {path}")
        seen.add((rule, path))
    actual = {}
    for f in findings:
        actual[(f[0], f[1])] = actual.get((f[0], f[1]), 0) + 1
    covered = set()
    for rule, path, count, _ in entries:
        n = actual.get((rule, path), 0)
        if n == 0:
            errors.append(f"stale allowlist entry: no {rule} finding remains in {path}")
        elif n > count:
            errors.append(f"allowlist exceeded: {path} has {n} {rule} findings, entry allows {count}")
        else:
            covered.add((rule, path))
    remaining = [f for f in findings if (f[0], f[1]) not in covered]
    allowlisted = len(findings) - len(remaining)
    return remaining, allowlisted, errors


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else "/root/repo"
    mode = sys.argv[2] if len(sys.argv) > 2 else ""
    findings = scan_root(root)
    if mode == "--emit-allowlist":
        groups = {}
        for rule, path, line, msg in findings:
            groups.setdefault((rule, path), []).append((line, msg))
        for (rule, path), items in sorted(groups.items()):
            print("[[allow]]")
            print(f'rule = "{rule}"')
            print(f'path = "{path}"')
            print(f"count = {len(items)}")
            print('reason = "TODO"')
            print()
        return
    if mode == "--apply":
        al = os.path.join(root, "tools/lint/allowlist.toml")
        entries = parse_allowlist(open(al).read()) if os.path.isfile(al) else []
        remaining, allowlisted, errors = apply_allowlist(findings, entries)
        for rule, path, line, msg in remaining:
            print(f"{path}:{line}: {rule} {msg}")
        for e in errors:
            print(f"allowlist: {e}")
        print(f"mahc-lint(mirror): {len(remaining)} violation(s), "
              f"{allowlisted} allowlisted, {len(errors)} allowlist error(s)")
        sys.exit(0 if not remaining and not errors else 1)
    by_rule = {}
    for f in findings:
        by_rule.setdefault(f[0], []).append(f)
    for rule in sorted(by_rule):
        print(f"== {rule}: {len(by_rule[rule])} findings")
        if rule != "R002" or os.environ.get("VERBOSE"):
            for _, path, line, msg in by_rule[rule]:
                print(f"  {path}:{line}: {msg}")
        else:
            byfile = {}
            for _, path, line, msg in by_rule[rule]:
                kind = "index" if "indexing" in msg else "panic"
                byfile.setdefault((path, kind), []).append(line)
            for (path, kind), ls in sorted(byfile.items()):
                print(f"  {path} [{kind}] x{len(ls)}: lines {ls[:25]}{'...' if len(ls) > 25 else ''}")
    print(f"total: {len(findings)}")


if __name__ == "__main__":
    main()
