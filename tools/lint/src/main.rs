//! `mahc-lint` CLI: run the determinism/soundness rule catalogue over a
//! repo checkout and exit nonzero on any unallowlisted violation or any
//! allowlist integrity error (stale / exceeded / duplicate entries).
//!
//! Usage:
//!   cargo run -p mahc-lint                  # lint the current checkout
//!   cargo xtask lint                        # alias (see .cargo/config.toml)
//!   cargo run -p mahc-lint -- --root DIR    # lint another tree
//!   cargo run -p mahc-lint -- --no-allowlist  # show every finding

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "\
mahc-lint: determinism/soundness static analysis over rust/src/**

USAGE:
    cargo run -p mahc-lint -- [lint] [OPTIONS]

OPTIONS:
    --root <DIR>        repo root to scan (default: .)
    --allowlist <FILE>  burn-down file (default: <root>/tools/lint/allowlist.toml)
    --no-allowlist      report every finding, ignoring the burn-down file
    -h, --help          print this help

RULES:
    R001  hash-collection iteration in result-affecting code
    R002  panicking call / unchecked indexing in library code
    R003  f32 reduction outside the fixed-order kernels
    R004  wall-clock / entropy source outside telemetry, bench, rng
    R005  IterationRecord schema drift (JSON writer vs CLI summary)
    R006  resurrected `DtwBackend` alias (removed; use `PairwiseBackend`)

Suppress inline with `// lint: allow(RXXX) <reason>` on the violating
line or the comment line directly above it.";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("mahc-lint: error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &[String]) -> anyhow::Result<bool> {
    let mut root = PathBuf::from(".");
    let mut allowlist_path: Option<PathBuf> = None;
    let mut use_allowlist = true;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            // Tolerate the subcommand word injected by `cargo xtask lint`.
            "lint" if i == 0 => {}
            "--root" => {
                i += 1;
                let v = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--root needs a directory argument"))?;
                root = PathBuf::from(v);
            }
            "--allowlist" => {
                i += 1;
                let v = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--allowlist needs a file argument"))?;
                allowlist_path = Some(PathBuf::from(v));
            }
            "--no-allowlist" => use_allowlist = false,
            "-h" | "--help" => {
                println!("{USAGE}");
                return Ok(true);
            }
            other => anyhow::bail!("unknown argument `{other}` (try --help)"),
        }
        i += 1;
    }

    let findings = mahc_lint::scan_root(&root)?;
    let entries = if use_allowlist {
        let path = allowlist_path.unwrap_or_else(|| root.join("tools/lint/allowlist.toml"));
        if path.is_file() {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
            mahc_lint::parse_allowlist(&text)?
        } else {
            Vec::new()
        }
    } else {
        Vec::new()
    };

    let out = mahc_lint::apply_allowlist(findings, &entries);
    for f in &out.remaining {
        println!("{f}");
    }
    for e in &out.errors {
        println!("allowlist: {e}");
    }
    let clean = out.remaining.is_empty() && out.errors.is_empty();
    eprintln!(
        "mahc-lint: {} violation(s), {} allowlisted, {} allowlist error(s)",
        out.remaining.len(),
        out.allowlisted,
        out.errors.len()
    );
    Ok(clean)
}
