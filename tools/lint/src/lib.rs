//! mahc-lint: the repo-specific determinism/soundness static-analysis
//! pass over `rust/src/**`.
//!
//! Every bitwise-parity guarantee the conformance suites check
//! dynamically (threads, backends, batch shapes, shard sizes) rests on
//! source-level invariants that nothing used to enforce: no
//! order-nondeterministic iteration on result paths, no panicking calls
//! in library code, no reassociated float reductions, no wall-clock or
//! entropy reads outside the sanctioned modules, and a telemetry schema
//! that the JSON writer and the CLI tables present in full.  This crate
//! checks those invariants statically, before any test runs.
//!
//! The rule catalogue (see also EXPERIMENTS.md §Static-analysis):
//!
//! * **R001** — `HashMap`/`HashSet` iteration (`.iter()`, `.keys()`,
//!   `.values()`, `.drain()`, `.retain()`, `for .. in`) is denied in
//!   `ahc/`, `mahc/`, `aggregate/`, `distance/` and `corpus/`: iteration
//!   order depends on the hasher, so anything it feeds can differ run to
//!   run.  Telemetry and figure modules are exempt by path.
//! * **R002** — panicking calls (`unwrap`/`expect`/`panic!`/
//!   `unreachable!`/`todo!`/`unimplemented!`) and unchecked indexing are
//!   denied in library code (everywhere except `main.rs`, `rust/src/bin/`,
//!   tests, benches and examples).  `assert!`/`debug_assert!` lines are
//!   contract checks and are not flagged.
//! * **R003** — f32 `sum()`/`fold` reductions in `distance/` and `ahc/`
//!   must route through the fixed-order kernels
//!   ([`fixed_order_sum`](../../../rust/src/distance/mod.rs)):
//!   reassociation is exactly what the ≤16-ulp linkage caveat guards.
//! * **R004** — `Instant::now`/`SystemTime`/`thread_rng`/`rand::random`
//!   are denied outside `telemetry/`, `util/bench.rs` and the seeded
//!   `util/rng.rs`.
//! * **R005** — every `IterationRecord` field must appear in both the
//!   JSON writer (`self.<field>` inside `to_json`) and the CLI summary
//!   (an identifier token in `main.rs` equal to the field name or
//!   starting with `<field>_`).
//! * **R006** — the removed pre-metric-generic alias `DtwBackend` must
//!   not reappear anywhere in `rust/src/**`: the shared trait is
//!   `PairwiseBackend`.  Matched as a whole identifier, so the concrete
//!   `XlaDtwBackend` executor is untouched.
//!
//! Suppression syntax: `// lint: allow(RXXX) <reason>` on the violating
//! line or on a comment-only line immediately above it.  Aliases:
//! `order-insensitive` (R001), `in-bounds` (R002), `fixed-order` (R003).
//! Justified legacy sites live in `tools/lint/allowlist.toml`
//! ([`parse_allowlist`] / [`apply_allowlist`]), a burn-down file: an
//! entry whose site no longer exists fails the run.
//!
//! The scanner is a hand-rolled lexer-level pass (string/char-literal
//! stripping, comment splitting, brace-tracked `#[cfg(test)]`/`#[test]`
//! exemption) rather than a `syn` AST walk: the container builds fully
//! offline against the vendored crate set, which has no `syn`.  The
//! module layout mirrors a visitor architecture — each rule is an
//! independent per-line visitor over classified lines — so a `syn`
//! backend can replace the lexer without touching the rule logic.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// Directories (under `rust/src/`) where R001 applies.
const R001_DIRS: &[&str] = &["ahc", "mahc", "aggregate", "distance", "corpus"];

/// Method calls that iterate a hash collection in nondeterministic order.
const ITER_CALLS: &[&str] = &[
    "iter()",
    "iter_mut()",
    "into_iter()",
    "keys()",
    "values()",
    "values_mut()",
    "drain(",
    "retain(",
];

/// Source patterns R004 denies outside the sanctioned modules.
const R004_PATTERNS: &[&str] = &["Instant::now", "SystemTime", "thread_rng", "rand::random"];

/// Identifiers of the six lint rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Rule {
    /// Order-nondeterministic hash iteration on a result path.
    R001,
    /// Panicking call / unchecked indexing in library code.
    R002,
    /// f32 reduction outside the fixed-order kernels.
    R003,
    /// Wall-clock / entropy read outside telemetry, bench, rng.
    R004,
    /// Telemetry schema drift between JSON writer and CLI summary.
    R005,
    /// Resurrected `DtwBackend` alias (removed; use `PairwiseBackend`).
    R006,
}

impl Rule {
    pub const ALL: [Rule; 6] = [
        Rule::R001,
        Rule::R002,
        Rule::R003,
        Rule::R004,
        Rule::R005,
        Rule::R006,
    ];

    pub fn id(self) -> &'static str {
        match self {
            Rule::R001 => "R001",
            Rule::R002 => "R002",
            Rule::R003 => "R003",
            Rule::R004 => "R004",
            Rule::R005 => "R005",
            Rule::R006 => "R006",
        }
    }

    pub fn from_id(s: &str) -> Option<Rule> {
        Rule::ALL.into_iter().find(|r| r.id() == s)
    }

    /// The inline suppression alias, if the rule has one.
    pub fn alias(self) -> Option<&'static str> {
        match self {
            Rule::R001 => Some("order-insensitive"),
            Rule::R002 => Some("in-bounds"),
            Rule::R003 => Some("fixed-order"),
            _ => None,
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One span-accurate diagnostic: rule, repo-relative path, 1-based line.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: Rule,
    pub path: String,
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {} {}", self.path, self.line, self.rule, self.message)
    }
}

// ---------------------------------------------------------------------
// Lexer-level primitives.  All scanning is byte-oriented so multi-byte
// UTF-8 in comments or literals can never split a match.
// ---------------------------------------------------------------------

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn find_from(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if needle.is_empty() || from > hay.len() {
        return None;
    }
    hay[from..]
        .windows(needle.len())
        .position(|w| w == needle)
        .map(|p| p + from)
}

fn contains(hay: &[u8], needle: &[u8]) -> bool {
    find_from(hay, needle, 0).is_some()
}

fn trim_end(b: &[u8]) -> &[u8] {
    let mut end = b.len();
    while end > 0 && b[end - 1].is_ascii_whitespace() {
        end -= 1;
    }
    &b[..end]
}

fn trim(b: &[u8]) -> &[u8] {
    let mut start = 0;
    let t = trim_end(b);
    while start < t.len() && t[start].is_ascii_whitespace() {
        start += 1;
    }
    &t[start..]
}

/// Trailing identifier run of `b` (possibly empty).
fn trailing_ident(b: &[u8]) -> &[u8] {
    let mut start = b.len();
    while start > 0 && is_ident(b[start - 1]) {
        start -= 1;
    }
    &b[start..]
}

/// Replace string and char literal contents with empty literals so no
/// pattern can match inside them.  Operates on the whole file so
/// multi-line literals (plain or `r#".."#` raw strings) cannot leak
/// braces or panic-lookalike text into the per-line code view; newlines
/// inside literals are preserved to keep line numbers aligned.
/// Lifetimes (`'a`) pass through.
fn strip_literals(text: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(text.len());
    let mut i = 0;
    while i < text.len() {
        let c = text[i];
        if c == b'r' && (i == 0 || !is_ident(text[i - 1])) {
            // Possible raw string r"..." / r#"..."# / r##"..."## ...
            let mut j = i + 1;
            let mut hashes = 0;
            while j < text.len() && text[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if j < text.len() && text[j] == b'"' {
                let mut k = j + 1;
                let end = loop {
                    match find_from(text, b"\"", k) {
                        Some(q) if text[q + 1..].len() >= hashes
                            && text[q + 1..q + 1 + hashes].iter().all(|&b| b == b'#') =>
                        {
                            break q + 1 + hashes;
                        }
                        Some(q) => k = q + 1,
                        None => break text.len(),
                    }
                };
                out.extend_from_slice(&text[i..=j]);
                for &b in &text[j + 1..end.min(text.len())] {
                    if b == b'\n' {
                        out.push(b'\n');
                    }
                }
                out.push(b'"');
                out.resize(out.len() + hashes, b'#');
                i = end;
                continue;
            }
            out.push(c);
            i += 1;
        } else if c == b'"' {
            let mut j = i + 1;
            while j < text.len() {
                if text[j] == b'\\' {
                    j += 2;
                    continue;
                }
                if text[j] == b'"' {
                    break;
                }
                j += 1;
            }
            out.push(b'"');
            for &b in &text[i + 1..j.min(text.len())] {
                if b == b'\n' {
                    out.push(b'\n');
                }
            }
            out.push(b'"');
            i = j + 1;
        } else if c == b'\'' {
            if i + 3 < text.len() && text[i + 1] == b'\\' && text[i + 3] == b'\'' {
                out.extend_from_slice(b"''");
                i += 4;
            } else if i + 2 < text.len() && text[i + 2] == b'\'' {
                out.extend_from_slice(b"''");
                i += 3;
            } else {
                // A lifetime (or an exotic literal the cheap lexer does
                // not model) — pass the quote through.
                out.push(c);
                i += 1;
            }
        } else {
            out.push(c);
            i += 1;
        }
    }
    out
}

/// Split a literal-stripped line at its first `//`.  Returns
/// (code, comment).
fn split_comment(line: &[u8]) -> (Vec<u8>, Vec<u8>) {
    match find_from(line, b"//", 0) {
        Some(idx) => (line[..idx].to_vec(), line[idx..].to_vec()),
        None => (line.to_vec(), Vec::new()),
    }
}

/// Rules a `// lint: ...` comment suppresses: `allow(RXXX)` plus the
/// per-rule aliases, each matched as a standalone word.
fn suppressions(comment: &[u8]) -> Vec<Rule> {
    let Some(pos) = find_from(comment, b"lint:", 0) else {
        return Vec::new();
    };
    let text = &comment[pos + 5..];
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = find_from(text, b"allow(", from) {
        let rest = &text[p + 6..];
        if rest.len() >= 5
            && rest[0] == b'R'
            && rest[1..4].iter().all(u8::is_ascii_digit)
            && rest[4] == b')'
        {
            if let Some(id) = std::str::from_utf8(&rest[..4]).ok().and_then(Rule::from_id) {
                if !out.contains(&id) {
                    out.push(id);
                }
            }
        }
        from = p + 6;
    }
    for rule in Rule::ALL {
        let Some(alias) = rule.alias() else { continue };
        let a = alias.as_bytes();
        let mut from = 0;
        while let Some(p) = find_from(text, a, from) {
            let before_ok = p == 0 || (!is_ident(text[p - 1]) && text[p - 1] != b'-');
            let end = p + a.len();
            let after_ok = end >= text.len() || (!is_ident(text[end]) && text[end] != b'-');
            if before_ok && after_ok {
                if !out.contains(&rule) {
                    out.push(rule);
                }
                break;
            }
            from = p + 1;
        }
    }
    out
}

/// Byte-position occurrences of identifier `name` with non-ident
/// boundaries on both sides.
fn ident_occurrences(code: &[u8], name: &[u8]) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = find_from(code, name, from) {
        let before_ok = p == 0 || !is_ident(code[p - 1]);
        let end = p + name.len();
        let after_ok = end >= code.len() || !is_ident(code[end]);
        if before_ok && after_ok {
            out.push(p);
        }
        from = p + 1;
    }
    out
}

fn skip_spaces(code: &[u8], mut i: usize) -> usize {
    while i < code.len() && code[i] == b' ' {
        i += 1;
    }
    i
}

fn brace_balance(code: &[u8]) -> i64 {
    let open = code.iter().filter(|&&b| b == b'{').count() as i64;
    let close = code.iter().filter(|&&b| b == b'}').count() as i64;
    open - close
}

/// Identifier tokens of `text`, as the R005 CLI cross-check consumes
/// them (leading digits of a run are dropped, mirroring `[A-Za-z_]\w*`).
fn ident_tokens(text: &[u8]) -> Vec<Vec<u8>> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < text.len() {
        if is_ident(text[i]) {
            let start = i;
            while i < text.len() && is_ident(text[i]) {
                i += 1;
            }
            let mut run = &text[start..i];
            while !run.is_empty() && run[0].is_ascii_digit() {
                run = &run[1..];
            }
            if !run.is_empty() {
                out.push(run.to_vec());
            }
        } else {
            i += 1;
        }
    }
    out
}

// ---------------------------------------------------------------------
// Per-file line classification.
// ---------------------------------------------------------------------

struct Classified {
    codes: Vec<Vec<u8>>,
    sups: Vec<Vec<Rule>>,
    exempt: Vec<bool>,
}

fn classify(text: &[u8]) -> Classified {
    let stripped = strip_literals(text);
    let raw: Vec<&[u8]> = stripped.split(|&b| b == b'\n').collect();
    let mut codes = Vec::with_capacity(raw.len());
    let mut sups = Vec::with_capacity(raw.len());
    for line in &raw {
        let (code, comment) = split_comment(line);
        sups.push(suppressions(&comment));
        codes.push(code);
    }
    // `#[cfg(test)]` / `#[test]` exempt the brace-balanced item that
    // follows (the attribute line through the matching close brace).
    let mut exempt = vec![false; codes.len()];
    let mut i = 0;
    while i < codes.len() {
        let t = trim(&codes[i]);
        if t.starts_with(b"#[cfg(test)]") || t.starts_with(b"#[test]") {
            let mut j = i;
            let mut bal = 0i64;
            let mut seen_open = false;
            while j < codes.len() {
                exempt[j] = true;
                bal += brace_balance(&codes[j]);
                if contains(&codes[j], b"{") {
                    seen_open = true;
                }
                if seen_open && bal <= 0 {
                    break;
                }
                j += 1;
            }
            i = j + 1;
            continue;
        }
        i += 1;
    }
    Classified { codes, sups, exempt }
}

impl Classified {
    /// Whether `rule` is suppressed at line index `i`: a `lint:` comment
    /// on the line itself, or on a comment-only line directly above.
    fn suppressed(&self, i: usize, rule: Rule) -> bool {
        if self.sups[i].contains(&rule) {
            return true;
        }
        i > 0 && self.sups[i - 1].contains(&rule) && trim(&self.codes[i - 1]).is_empty()
    }
}

// ---------------------------------------------------------------------
// R001: hash-collection iteration.
// ---------------------------------------------------------------------

/// Names bound to a `HashMap`/`HashSet` on this line: `name: HashMap<..>`
/// (let bindings, struct fields) and `name = HashMap::new()` forms, with
/// an optional `std::collections::` path prefix.
fn hash_decl_names(code: &[u8]) -> Vec<Vec<u8>> {
    let mut out = Vec::new();
    for kw in [&b"HashMap"[..], &b"HashSet"[..]] {
        let mut from = 0;
        while let Some(p) = find_from(code, kw, from) {
            from = p + kw.len();
            let mut k = p;
            if code[..k].ends_with(b"std::collections::") {
                k -= b"std::collections::".len();
            }
            let before = trim_end(&code[..k]);
            let Some(&sep) = before.last() else { continue };
            if sep != b':' && sep != b'=' {
                continue;
            }
            let lhs = &before[..before.len() - 1];
            if sep == b':' && lhs.ends_with(b":") {
                continue; // a `::` path, not a type ascription
            }
            let name = trailing_ident(trim_end(lhs));
            if name.is_empty() {
                continue;
            }
            if !(name[0].is_ascii_lowercase() || name[0] == b'_') {
                continue;
            }
            if !out.contains(&name.to_vec()) {
                out.push(name.to_vec());
            }
        }
    }
    out
}

/// The iterating call chained onto `var` on this line, if any.
fn iterating_call(code: &[u8], var: &[u8]) -> Option<&'static str> {
    for p in ident_occurrences(code, var) {
        let mut i = skip_spaces(code, p + var.len());
        if i >= code.len() || code[i] != b'.' {
            continue;
        }
        i = skip_spaces(code, i + 1);
        for call in ITER_CALLS {
            if code[i..].starts_with(call.as_bytes()) {
                return Some(call);
            }
        }
    }
    None
}

/// Whether this line iterates `var` via `for .. in [&[mut ]]var`.
fn for_in_var(code: &[u8], var: &[u8]) -> bool {
    if ident_occurrences(code, b"for").is_empty() {
        return false;
    }
    for p in ident_occurrences(code, var) {
        let mut pre = trim_end(&code[..p]);
        if pre.ends_with(b"mut") {
            pre = trim_end(&pre[..pre.len() - 3]);
        }
        if pre.ends_with(b"&") {
            pre = trim_end(&pre[..pre.len() - 1]);
        }
        if pre.ends_with(b"in") && (pre.len() == 2 || !is_ident(pre[pre.len() - 3])) {
            return true;
        }
    }
    false
}

/// `..collect::<HashMap<..>>().iter()`-style immediate iteration over a
/// freshly collected hash container.
fn collects_then_iterates(code: &[u8]) -> bool {
    let Some(c0) = find_from(code, b"collect::<", 0) else {
        return false;
    };
    let rest = &code[c0..];
    let Some(g) = find_from(rest, b">>()", 0) else {
        return false;
    };
    let generic = &rest[..g];
    if !contains(generic, b"HashMap") && !contains(generic, b"HashSet") {
        return false;
    }
    let mut i = skip_spaces(rest, g + 4);
    if i >= rest.len() || rest[i] != b'.' {
        return false;
    }
    i = skip_spaces(rest, i + 1);
    ["iter()", "into_iter()", "keys()", "values()"]
        .iter()
        .any(|call| rest[i..].starts_with(call.as_bytes()))
}

// ---------------------------------------------------------------------
// R002: panic-free library code.
// ---------------------------------------------------------------------

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

fn macro_invoked(code: &[u8], name: &str) -> bool {
    for p in ident_occurrences(code, name.as_bytes()) {
        let i = p + name.len();
        if i < code.len() && code[i] == b'!' {
            let j = skip_spaces(code, i + 1);
            if j < code.len() && code[j] == b'(' {
                return true;
            }
        }
    }
    false
}

/// Truncate `code` at the first `assert*!`/`debug_assert*!` invocation:
/// indexing inside a contract check is part of the check.
fn strip_assert_macros(code: &[u8]) -> Vec<u8> {
    let mut cut = code.len();
    for name in ["assert", "debug_assert"] {
        let mut from = 0;
        while let Some(p) = find_from(code, name.as_bytes(), from) {
            from = p + 1;
            if p > 0 && is_ident(code[p - 1]) {
                continue;
            }
            let mut i = p + name.len();
            while i < code.len() && (code[i].is_ascii_lowercase() || code[i] == b'_') {
                i += 1;
            }
            if i < code.len() && code[i] == b'!' {
                cut = cut.min(p);
            }
        }
    }
    code[..cut].to_vec()
}

/// Unchecked-indexing sites: `[` directly preceded (modulo spaces) by an
/// identifier character, `)` or `]`.  Returns the indexed word per site.
fn indexing_sites(code: &[u8]) -> Vec<Vec<u8>> {
    let stripped = strip_assert_macros(code);
    let mut out = Vec::new();
    for (i, &b) in stripped.iter().enumerate() {
        if b != b'[' {
            continue;
        }
        let before = trim_end(&stripped[..i]);
        let Some(&prev) = before.last() else { continue };
        if !(is_ident(prev) || prev == b')' || prev == b']') {
            continue;
        }
        let word = trailing_ident(before);
        if word == b"vec" {
            continue; // `vec![..]` literal
        }
        let word_start = before.len() - word.len();
        if word_start > 0 && before[word_start - 1] == b'\'' {
            continue; // lifetime before a slice type: `&'a [T]`
        }
        out.push(word.to_vec());
    }
    out
}

// ---------------------------------------------------------------------
// File scanning.
// ---------------------------------------------------------------------

fn in_dirs(rel: &str, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| rel.starts_with(&format!("rust/src/{d}/")))
}

fn scan_file(rel: &str, text: &[u8]) -> Vec<Finding> {
    let lines = classify(text);
    let mut findings = Vec::new();
    let mut emit = |i: usize, rule: Rule, message: String, lines: &Classified| {
        if !lines.exempt[i] && !lines.suppressed(i, rule) {
            findings.push(Finding {
                rule,
                path: rel.to_string(),
                line: i + 1,
                message,
            });
        }
    };

    // R001 — order-nondeterministic iteration.
    if in_dirs(rel, R001_DIRS) {
        let mut hash_vars: Vec<Vec<u8>> = Vec::new();
        for code in &lines.codes {
            for name in hash_decl_names(code) {
                if !hash_vars.contains(&name) {
                    hash_vars.push(name);
                }
            }
        }
        for (i, code) in lines.codes.iter().enumerate() {
            for var in &hash_vars {
                let v = String::from_utf8_lossy(var);
                if let Some(call) = iterating_call(code, var) {
                    emit(
                        i,
                        Rule::R001,
                        format!("`{v}.{call}` iterates a hash collection in hasher order"),
                        &lines,
                    );
                }
                if for_in_var(code, var) {
                    emit(
                        i,
                        Rule::R001,
                        format!("`for .. in {v}` iterates a hash collection in hasher order"),
                        &lines,
                    );
                }
            }
            if collects_then_iterates(code) {
                emit(
                    i,
                    Rule::R001,
                    "iterating a freshly collected hash container".to_string(),
                    &lines,
                );
            }
        }
    }

    // R002 — panic-free library code.
    let r002_exempt = rel == "rust/src/main.rs" || rel.starts_with("rust/src/bin/");
    if !r002_exempt {
        for (i, code) in lines.codes.iter().enumerate() {
            let t = trim(code);
            if t.starts_with(b"debug_assert") || t.starts_with(b"assert") {
                continue;
            }
            if contains(code, b".unwrap()") {
                emit(
                    i,
                    Rule::R002,
                    "panicking call `.unwrap()` in library code".to_string(),
                    &lines,
                );
            }
            if contains(code, b".expect(") {
                emit(
                    i,
                    Rule::R002,
                    "panicking call `.expect(..)` in library code".to_string(),
                    &lines,
                );
            }
            for name in PANIC_MACROS {
                if macro_invoked(code, name) {
                    emit(
                        i,
                        Rule::R002,
                        format!("panicking macro `{name}!` in library code"),
                        &lines,
                    );
                }
            }
            for word in indexing_sites(code) {
                let w = String::from_utf8_lossy(&word);
                emit(
                    i,
                    Rule::R002,
                    format!("unchecked indexing `{w}[..]` without a bound justification"),
                    &lines,
                );
            }
        }
    }

    // R003 — float-reduction discipline.
    if in_dirs(rel, &["distance", "ahc"]) {
        for (i, code) in lines.codes.iter().enumerate() {
            if contains(code, b".sum::<f32>()") {
                emit(
                    i,
                    Rule::R003,
                    "f32 `.sum()` outside the fixed-order kernels".to_string(),
                    &lines,
                );
            } else if contains(code, b".sum()") || contains(code, b".fold(") {
                let mut ctx = Vec::new();
                if i > 0 {
                    ctx.extend_from_slice(&lines.codes[i - 1]);
                    ctx.push(b' ');
                }
                ctx.extend_from_slice(code);
                if contains(&ctx, b"f32") && !contains(&ctx, b"f64") {
                    emit(
                        i,
                        Rule::R003,
                        "possible f32 reduction outside the fixed-order kernels".to_string(),
                        &lines,
                    );
                }
            }
        }
    }

    // R006 — the removed `DtwBackend` alias must stay removed.  Whole-
    // identifier match, so `XlaDtwBackend` (a concrete executor type)
    // never trips it; comments and strings are already stripped.
    for (i, code) in lines.codes.iter().enumerate() {
        if !ident_occurrences(code, b"DtwBackend").is_empty() {
            emit(
                i,
                Rule::R006,
                "removed alias `DtwBackend` — the shared trait is `PairwiseBackend`".to_string(),
                &lines,
            );
        }
    }

    // R004 — wall-clock / entropy hygiene.
    let r004_exempt = in_dirs(rel, &["telemetry"])
        || rel == "rust/src/util/bench.rs"
        || rel == "rust/src/util/rng.rs";
    if !r004_exempt {
        for (i, code) in lines.codes.iter().enumerate() {
            for pat in R004_PATTERNS {
                if contains(code, pat.as_bytes()) {
                    emit(
                        i,
                        Rule::R004,
                        format!("nondeterministic source `{pat}` outside telemetry/bench/rng"),
                        &lines,
                    );
                }
            }
        }
    }

    findings
}

// ---------------------------------------------------------------------
// R005: telemetry schema parity.
// ---------------------------------------------------------------------

fn pub_field_name(code: &[u8]) -> Option<Vec<u8>> {
    let t = trim(code);
    let rest = t.strip_prefix(b"pub ")?;
    let rest = trim(rest);
    let mut end = 0;
    while end < rest.len() && is_ident(rest[end]) {
        end += 1;
    }
    if end == 0 {
        return None;
    }
    let after = skip_spaces(rest, end);
    if after < rest.len() && rest[after] == b':' {
        Some(rest[..end].to_vec())
    } else {
        None
    }
}

fn scan_telemetry(root: &Path) -> anyhow::Result<Vec<Finding>> {
    let tpath = root.join("rust/src/telemetry/mod.rs");
    let mpath = root.join("rust/src/main.rs");
    if !tpath.is_file() || !mpath.is_file() {
        return Ok(Vec::new());
    }
    let ttext = std::fs::read(&tpath)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", tpath.display()))?;
    let codes: Vec<Vec<u8>> = strip_literals(&ttext)
        .split(|&b| b == b'\n')
        .map(|l| split_comment(l).0)
        .collect();

    let mut fields: Vec<(Vec<u8>, usize)> = Vec::new();
    let mut struct_line: Option<usize> = None;
    let mut in_struct = false;
    let mut depth = 0i64;
    for (i, code) in codes.iter().enumerate() {
        if struct_line.is_none() && contains(code, b"struct IterationRecord") {
            struct_line = Some(i);
            in_struct = true;
            depth = 0;
        }
        if in_struct {
            if let Some(name) = pub_field_name(code) {
                fields.push((name, i + 1));
            }
            depth += brace_balance(code);
            if depth <= 0 && struct_line.is_some_and(|s| i > s) {
                in_struct = false;
            }
        }
    }

    let mut to_json_body = Vec::new();
    if let Some(s) = struct_line {
        if let Some(j) = (s..codes.len()).find(|&i| contains(&codes[i], b"fn to_json")) {
            for code in codes.iter().skip(j).take(60) {
                to_json_body.extend_from_slice(code);
                to_json_body.push(b'\n');
            }
        }
    }

    let mtext = std::fs::read(&mpath)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", mpath.display()))?;
    let tokens = ident_tokens(&mtext);

    let mut findings = Vec::new();
    for (name, line) in &fields {
        let n = String::from_utf8_lossy(name);
        let mut probe = b"self.".to_vec();
        probe.extend_from_slice(name);
        if !contains(&to_json_body, &probe) {
            findings.push(Finding {
                rule: Rule::R005,
                path: "rust/src/telemetry/mod.rs".to_string(),
                line: *line,
                message: format!("IterationRecord field `{n}` missing from the JSON writer"),
            });
        }
        let mut prefix = name.clone();
        prefix.push(b'_');
        let in_cli = tokens
            .iter()
            .any(|t| t == name || t.starts_with(prefix.as_slice()));
        if !in_cli {
            findings.push(Finding {
                rule: Rule::R005,
                path: "rust/src/telemetry/mod.rs".to_string(),
                line: *line,
                message: format!("IterationRecord field `{n}` missing from the CLI summaries"),
            });
        }
    }
    Ok(findings)
}

// ---------------------------------------------------------------------
// Tree walking.
// ---------------------------------------------------------------------

fn walk_sorted(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> anyhow::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("read_dir {}: {e}", dir.display()))?
        .collect::<Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("read_dir {}: {e}", dir.display()))?;
    entries.sort_by_key(std::fs::DirEntry::path);
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            walk_sorted(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Scan `<root>/rust/src/**` with rules R001–R004 and run the R005
/// schema cross-check; findings are ordered by path then line.
pub fn scan_root(root: &Path) -> anyhow::Result<Vec<Finding>> {
    let src = root.join("rust/src");
    anyhow::ensure!(src.is_dir(), "no rust/src directory under {}", root.display());
    let mut files = Vec::new();
    walk_sorted(&src, &mut files)?;
    let mut findings = Vec::new();
    for path in files {
        let rel = path
            .strip_prefix(root)
            .map_err(|_| anyhow::anyhow!("path {} escapes root", path.display()))?
            .to_string_lossy()
            .replace('\\', "/");
        let text =
            std::fs::read(&path).map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        findings.extend(scan_file(&rel, &text));
    }
    findings.extend(scan_telemetry(root)?);
    findings.sort_by(|a, b| {
        (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule))
    });
    Ok(findings)
}

// ---------------------------------------------------------------------
// Allowlist (burn-down file).
// ---------------------------------------------------------------------

/// One justified suppression: up to `count` findings of `rule` in `path`.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub rule: Rule,
    pub path: String,
    pub count: usize,
    pub reason: String,
}

/// Parse `allowlist.toml` (the `[[allow]]` table-array subset of TOML
/// the burn-down file uses; the container has no `toml` crate).
pub fn parse_allowlist(text: &str) -> anyhow::Result<Vec<AllowEntry>> {
    struct Partial {
        rule: Option<Rule>,
        path: Option<String>,
        count: Option<usize>,
        reason: Option<String>,
        line: usize,
    }
    let mut entries = Vec::new();
    let mut cur: Option<Partial> = None;
    let finish = |p: Partial, entries: &mut Vec<AllowEntry>| -> anyhow::Result<()> {
        let entry = AllowEntry {
            rule: p
                .rule
                .ok_or_else(|| anyhow::anyhow!("allowlist entry at line {} has no rule", p.line))?,
            path: p
                .path
                .ok_or_else(|| anyhow::anyhow!("allowlist entry at line {} has no path", p.line))?,
            count: p.count.unwrap_or(1),
            reason: p
                .reason
                .ok_or_else(|| anyhow::anyhow!("allowlist entry at line {} has no reason", p.line))?,
        };
        anyhow::ensure!(
            entry.count > 0,
            "allowlist entry at line {}: count must be >= 1",
            p.line
        );
        anyhow::ensure!(
            !entry.reason.trim().is_empty(),
            "allowlist entry at line {}: empty reason",
            p.line
        );
        entries.push(entry);
        Ok(())
    };
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "[[allow]]" {
            if let Some(p) = cur.take() {
                finish(p, &mut entries)?;
            }
            cur = Some(Partial {
                rule: None,
                path: None,
                count: None,
                reason: None,
                line: idx + 1,
            });
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            anyhow::bail!("allowlist line {}: expected `key = value`, got `{line}`", idx + 1);
        };
        let p = cur
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("allowlist line {}: key before [[allow]]", idx + 1))?;
        let key = key.trim();
        let value = value.trim();
        let unquote = |v: &str| -> anyhow::Result<String> {
            let inner = v
                .strip_prefix('"')
                .and_then(|v| v.strip_suffix('"'))
                .ok_or_else(|| anyhow::anyhow!("allowlist line {}: expected a quoted string", idx + 1))?;
            Ok(inner.replace("\\\"", "\""))
        };
        match key {
            "rule" => {
                let id = unquote(value)?;
                p.rule = Some(Rule::from_id(&id).ok_or_else(|| {
                    anyhow::anyhow!("allowlist line {}: unknown rule `{id}`", idx + 1)
                })?);
            }
            "path" => p.path = Some(unquote(value)?),
            "reason" => p.reason = Some(unquote(value)?),
            "count" => {
                p.count = Some(value.parse().map_err(|_| {
                    anyhow::anyhow!("allowlist line {}: bad count `{value}`", idx + 1)
                })?);
            }
            other => anyhow::bail!("allowlist line {}: unknown key `{other}`", idx + 1),
        }
    }
    if let Some(p) = cur.take() {
        finish(p, &mut entries)?;
    }
    Ok(entries)
}

/// Result of subtracting the allowlist from a finding set.
#[derive(Debug)]
pub struct AllowOutcome {
    /// Findings not covered by any entry — real violations.
    pub remaining: Vec<Finding>,
    /// Findings absorbed by allowlist entries.
    pub allowlisted: usize,
    /// Burn-down integrity errors: stale entries (site no longer
    /// exists), exceeded counts, duplicates.  Any error fails the run.
    pub errors: Vec<String>,
}

/// Apply the burn-down allowlist: an entry absorbs up to `count`
/// findings of its `(rule, path)`; a stale entry (zero findings) or an
/// exceeded one (more findings than `count`) is an error, so the file
/// can only ever shrink.
pub fn apply_allowlist(findings: Vec<Finding>, entries: &[AllowEntry]) -> AllowOutcome {
    let mut errors = Vec::new();
    let mut by_key: BTreeMap<(Rule, &str), usize> = BTreeMap::new();
    for e in entries {
        if by_key.insert((e.rule, e.path.as_str()), e.count).is_some() {
            errors.push(format!(
                "duplicate allowlist entry for {} {}",
                e.rule.id(),
                e.path
            ));
        }
    }
    let mut actual: BTreeMap<(Rule, &str), usize> = BTreeMap::new();
    for f in &findings {
        *actual.entry((f.rule, f.path.as_str())).or_insert(0) += 1;
    }
    let mut covered: Vec<(Rule, String)> = Vec::new();
    for e in entries {
        let n = actual.get(&(e.rule, e.path.as_str())).copied().unwrap_or(0);
        if n == 0 {
            errors.push(format!(
                "stale allowlist entry: no {} finding remains in {} — delete the entry",
                e.rule.id(),
                e.path
            ));
        } else if n > e.count {
            errors.push(format!(
                "allowlist exceeded: {} has {} {} findings, entry allows {} — fix the new sites",
                e.path,
                n,
                e.rule.id(),
                e.count
            ));
        } else {
            covered.push((e.rule, e.path.clone()));
        }
    }
    let mut remaining = Vec::new();
    let mut allowlisted = 0usize;
    for f in findings {
        if covered.iter().any(|(r, p)| *r == f.rule && *p == f.path) {
            allowlisted += 1;
        } else {
            remaining.push(f);
        }
    }
    AllowOutcome {
        remaining,
        allowlisted,
        errors,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan_str(rel: &str, text: &str) -> Vec<Finding> {
        scan_file(rel, text.as_bytes())
    }

    #[test]
    fn strings_and_chars_are_opaque() {
        let s = strip_literals(br#"let x = "a.unwrap() // not code"; y"#);
        assert_eq!(s, br#"let x = ""; y"#.to_vec());
        let s = strip_literals(br"match c { b'\\' => 1, 'x' => 2, _ => 3 }");
        assert!(!contains(&s, b"'x'"));
        // Lifetimes survive.
        let s = strip_literals(b"fn f<'a>(x: &'a str) {}");
        assert_eq!(s, b"fn f<'a>(x: &'a str) {}".to_vec());
    }

    #[test]
    fn raw_and_multiline_strings_are_opaque() {
        // Raw string with inner quotes and braces.
        let s = strip_literals(br##"let t = r#"{"a": 1}"#; z"##);
        assert_eq!(s, br##"let t = r#""#; z"##.to_vec());
        // Multi-line literal: newlines survive, braces do not.
        let s = strip_literals(b"let t = r#\"{\n}\"#;\nnext()");
        assert_eq!(s, b"let t = r#\"\n\"#;\nnext()".to_vec());
        assert!(!contains(&s, b"{"));
        // Multi-line plain string.
        let s = strip_literals(b"let t = \"a\nb.unwrap()\";\nok()");
        assert_eq!(s, b"let t = \"\n\";\nok()".to_vec());
    }

    #[test]
    fn comment_split_ignores_string_slashes() {
        let stripped = strip_literals(br#"let url = "https://x"; // real comment"#);
        let (code, comment) = split_comment(&stripped);
        assert!(contains(&code, b"let url"));
        assert!(!contains(&code, b"real comment"));
        assert!(contains(&comment, b"real comment"));
    }

    #[test]
    fn lifetime_slice_is_not_indexing() {
        let src = "struct P<'a> {\n\x20   bytes: &'a [u8],\n}\n";
        assert!(scan_str("rust/src/util/x.rs", src).is_empty());
    }

    #[test]
    fn suppression_parsing() {
        assert_eq!(suppressions(b"// lint: allow(R001) reason"), vec![Rule::R001]);
        assert_eq!(
            suppressions(b"// lint: allow(R001) allow(R003)"),
            vec![Rule::R001, Rule::R003]
        );
        assert_eq!(suppressions(b"// lint: order-insensitive"), vec![Rule::R001]);
        assert_eq!(suppressions(b"// lint: in-bounds by loop guard"), vec![Rule::R002]);
        assert_eq!(suppressions(b"// lint: fixed-order"), vec![Rule::R003]);
        assert!(suppressions(b"// plain comment").is_empty());
        assert!(suppressions(b"// lint: allow(R999)").is_empty());
        // Alias must be a standalone word.
        assert!(suppressions(b"// lint: non-order-insensitive-ish").is_empty());
    }

    #[test]
    fn r001_flags_iteration_not_membership() {
        let src = "use std::collections::HashMap;\n\
                   pub fn f() -> usize {\n\
                   \x20   let mut m: HashMap<u32, u32> = HashMap::new();\n\
                   \x20   m.insert(1, 2);\n\
                   \x20   let mut t = 0;\n\
                   \x20   for (k, v) in &m {\n\
                   \x20       t += (k + v) as usize;\n\
                   \x20   }\n\
                   \x20   t + m.len() + m.keys().count()\n\
                   }\n";
        let f = scan_str("rust/src/ahc/x.rs", src);
        let r001: Vec<_> = f.iter().filter(|f| f.rule == Rule::R001).collect();
        assert_eq!(r001.len(), 2, "{r001:?}");
        assert_eq!(r001[0].line, 6);
        assert_eq!(r001[1].line, 9);
        // Same file outside the result-affecting dirs: clean.
        assert!(scan_str("rust/src/figures/x.rs", src)
            .iter()
            .all(|f| f.rule != Rule::R001));
    }

    #[test]
    fn r002_panics_and_indexing() {
        let src = "pub fn f(xs: &[u32]) -> u32 {\n\
                   \x20   let a = xs.first().unwrap();\n\
                   \x20   let b = xs.get(0).expect(\"x\");\n\
                   \x20   assert!(xs[0] > 0);\n\
                   \x20   if xs.is_empty() { panic!(\"empty\") }\n\
                   \x20   a + b + xs[1]\n\
                   }\n";
        let f = scan_str("rust/src/util/x.rs", src);
        let lines: Vec<usize> = f.iter().filter(|f| f.rule == Rule::R002).map(|f| f.line).collect();
        assert_eq!(lines, vec![2, 3, 5, 6], "{f:?}");
        // main.rs is exempt.
        assert!(scan_str("rust/src/main.rs", src).is_empty());
    }

    #[test]
    fn r002_ignores_result_returning_expect_method() {
        // A parser method named `expect_byte` is not Option::expect.
        let src = "fn lit(&mut self) -> anyhow::Result<()> {\n\
                   \x20   self.expect_byte(b'{')?;\n\
                   \x20   Ok(())\n\
                   }\n";
        assert!(scan_str("rust/src/util/x.rs", src).is_empty());
    }

    #[test]
    fn r002_test_blocks_exempt() {
        let src = "pub fn ok() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   \x20   #[test]\n\
                   \x20   fn t() { Some(1).unwrap(); }\n\
                   }\n";
        assert!(scan_str("rust/src/util/x.rs", src).is_empty());
    }

    #[test]
    fn r003_flags_f32_reductions_in_scope() {
        let src = "pub fn m(d: &[f32]) -> f32 {\n\
                   \x20   d.iter().sum::<f32>() / d.len() as f32\n\
                   }\n";
        let f = scan_str("rust/src/distance/x.rs", src);
        assert_eq!(f.iter().filter(|f| f.rule == Rule::R003).count(), 1);
        assert!(scan_str("rust/src/corpus/x.rs", src)
            .iter()
            .all(|f| f.rule != Rule::R003));
        // f64 reductions are fine.
        let src64 = "pub fn m(d: &[f64]) -> f64 {\n\x20   d.iter().sum()\n}\n";
        assert!(scan_str("rust/src/distance/x.rs", src64)
            .iter()
            .all(|f| f.rule != Rule::R003));
    }

    #[test]
    fn r004_denies_clock_outside_sanctioned_modules() {
        let src = "pub fn t() { let _ = std::time::Instant::now(); }\n";
        assert_eq!(scan_str("rust/src/mahc/x.rs", src).len(), 1);
        assert!(scan_str("rust/src/telemetry/x.rs", src).is_empty());
        assert!(scan_str("rust/src/util/bench.rs", src).is_empty());
        assert!(scan_str("rust/src/util/rng.rs", src).is_empty());
    }

    #[test]
    fn r006_bans_the_alias_but_not_the_xla_type() {
        let src = "pub fn f(b: &dyn DtwBackend) { let _ = b; }\n";
        let f = scan_str("rust/src/mahc/x.rs", src);
        assert_eq!(f.iter().filter(|f| f.rule == Rule::R006).count(), 1);
        assert_eq!(f[0].line, 1);
        // The concrete executor type shares the suffix but is a
        // different identifier.
        let ok = "pub fn g(b: &XlaDtwBackend) { let _ = b; }\n";
        assert!(scan_str("rust/src/distance/x.rs", ok)
            .iter()
            .all(|f| f.rule != Rule::R006));
        // Comment mentions do not count.
        let doc = "//! The old `DtwBackend` alias is gone.\npub fn h() {}\n";
        assert!(scan_str("rust/src/distance/x.rs", doc).is_empty());
    }

    #[test]
    fn suppression_silences_exactly_its_rule() {
        let src = "use std::collections::HashSet;\n\
                   pub fn f(xs: &[usize]) -> usize {\n\
                   \x20   let tags: HashSet<usize> = HashSet::new();\n\
                   \x20   tags.iter().count() + xs[0] // lint: allow(R001) commutative count\n\
                   }\n";
        let f = scan_str("rust/src/ahc/x.rs", src);
        assert!(f.iter().all(|f| f.rule != Rule::R001), "{f:?}");
        assert_eq!(f.iter().filter(|f| f.rule == Rule::R002).count(), 1);
        // Preceding comment-only line also suppresses.
        let src2 = "use std::collections::HashSet;\n\
                    pub fn f() -> usize {\n\
                    \x20   let tags: HashSet<usize> = HashSet::new();\n\
                    \x20   // lint: order-insensitive — count commutes\n\
                    \x20   tags.iter().count()\n\
                    }\n";
        assert!(scan_str("rust/src/ahc/x.rs", src2).is_empty());
    }

    #[test]
    fn allowlist_round_trip_and_burn_down() {
        let text = "# burn-down\n\n[[allow]]\nrule = \"R002\"\npath = \"rust/src/a.rs\"\ncount = 2\nreason = \"legacy\"\n\n[[allow]]\nrule = \"R004\"\npath = \"rust/src/b.rs\"\nreason = \"gated\"\n";
        let entries = parse_allowlist(text).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].count, 2);
        assert_eq!(entries[1].count, 1);

        let mk = |rule, path: &str, line| Finding {
            rule,
            path: path.to_string(),
            line,
            message: String::new(),
        };
        // Exact coverage: both a.rs findings absorbed; b.rs entry stale.
        let out = apply_allowlist(
            vec![mk(Rule::R002, "rust/src/a.rs", 1), mk(Rule::R002, "rust/src/a.rs", 9)],
            &entries,
        );
        assert_eq!(out.allowlisted, 2);
        assert!(out.remaining.is_empty());
        assert_eq!(out.errors.len(), 1, "{:?}", out.errors);
        assert!(out.errors[0].contains("stale"));

        // Exceeded count keeps every finding and reports the overflow.
        let out = apply_allowlist(
            vec![
                mk(Rule::R002, "rust/src/a.rs", 1),
                mk(Rule::R002, "rust/src/a.rs", 2),
                mk(Rule::R002, "rust/src/a.rs", 3),
                mk(Rule::R004, "rust/src/b.rs", 4),
            ],
            &entries,
        );
        assert_eq!(out.allowlisted, 1);
        assert_eq!(out.remaining.len(), 3);
        assert!(out.errors.iter().any(|e| e.contains("exceeded")));

        assert!(parse_allowlist("[[allow]]\nrule = \"R002\"\npath = \"x\"\ncount = 0\nreason = \"r\"\n").is_err());
        assert!(parse_allowlist("[[allow]]\npath = \"x\"\nreason = \"r\"\n").is_err());
    }
}
