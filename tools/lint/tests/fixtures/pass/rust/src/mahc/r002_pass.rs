pub fn safe(xs: &[u32]) -> anyhow::Result<u32> {
    let a = *xs.first().ok_or_else(|| anyhow::anyhow!("empty input"))?;
    let b = xs.get(1).copied().unwrap_or(0);
    Ok(a + b)
}
