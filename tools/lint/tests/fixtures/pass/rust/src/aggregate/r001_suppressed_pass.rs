use std::collections::HashMap;

pub fn total(pairs: &[(usize, usize)]) -> usize {
    let map: HashMap<usize, usize> = pairs.iter().copied().collect();
    // lint: order-insensitive — commutative integer sum
    map.values().sum()
}
