fn main() {
    println!("iteration wall_secs metric silhouette_score");
}
