pub struct IterationRecord {
    pub iteration: usize,
    pub wall_secs: f64,
}

impl IterationRecord {
    pub fn to_json(&self) -> String {
        format!("{{\"iteration\":{},\"wall_secs\":{}}}", self.iteration, self.wall_secs)
    }
}
