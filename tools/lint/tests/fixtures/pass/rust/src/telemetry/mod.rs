pub struct IterationRecord {
    pub iteration: usize,
    pub wall_secs: f64,
    pub metric: String,
    pub silhouette_score: f64,
}

impl IterationRecord {
    pub fn to_json(&self) -> String {
        format!(
            "{{\"iteration\":{},\"wall_secs\":{},\"metric\":\"{}\",\"silhouette_score\":{}}}",
            self.iteration, self.wall_secs, self.metric, self.silhouette_score
        )
    }
}
