//! The removed `DtwBackend` alias must not come back; the concrete
//! `XlaDtwBackend` executor shares the suffix but is a different
//! identifier, and comment mentions (like this one) never count.

pub struct XlaDtwBackend;

pub fn tag(_b: &XlaDtwBackend) -> u8 {
    0
}
