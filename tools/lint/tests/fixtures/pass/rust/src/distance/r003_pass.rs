pub fn mean(data: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for &x in data {
        acc += x;
    }
    acc / data.len().max(1) as f32
}
