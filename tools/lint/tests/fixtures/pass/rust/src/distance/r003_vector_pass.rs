pub fn cosine_parts(xs: &[f32], ys: &[f32]) -> f32 {
    let mut dot = 0.0f32;
    let mut norm = 0.0f32;
    for (&x, &y) in xs.iter().zip(ys) {
        dot += x * y;
        norm += x * x;
    }
    dot / norm.sqrt().max(1e-12)
}
