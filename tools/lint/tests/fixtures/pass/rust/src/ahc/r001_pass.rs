use std::collections::BTreeMap;

pub fn collapse() -> usize {
    let mut label_of: BTreeMap<usize, usize> = BTreeMap::new();
    label_of.insert(1, 2);
    label_of.iter().map(|(k, v)| k + v).sum()
}
