pub fn broken(xs: &[u32]) -> u32 {
    let a = *xs.first().unwrap();
    let b: u32 = xs.last().copied().expect("nonempty");
    if a > 10 {
        panic!("too big");
    }
    a + b + xs[1]
}
