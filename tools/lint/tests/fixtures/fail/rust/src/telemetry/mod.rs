pub struct IterationRecord {
    pub iteration: usize,
    pub wall_secs: f64,
    pub ghost_metric: f64,
    pub metric: String,
    pub silhouette_score: f64,
}

impl IterationRecord {
    pub fn to_json(&self) -> String {
        format!(
            "{{\"iteration\":{},\"wall_secs\":{},\"metric\":\"{}\"}}",
            self.iteration, self.wall_secs, self.metric
        )
    }
}
