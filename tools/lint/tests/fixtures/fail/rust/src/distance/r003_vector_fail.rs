pub fn cosine_parts(xs: &[f32], ys: &[f32]) -> f32 {
    let dot = xs.iter().zip(ys).map(|(&x, &y)| x * y).sum::<f32>();
    let norm = xs.iter().fold(0.0f32, |acc, &x| acc + x * x);
    dot / norm.sqrt()
}
