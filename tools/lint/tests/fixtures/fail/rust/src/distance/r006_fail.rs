pub fn probe(backend: &dyn DtwBackend) -> &'static str {
    backend.metric_name()
}
