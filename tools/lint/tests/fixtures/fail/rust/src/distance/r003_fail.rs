pub fn mean(data: &[f32]) -> f32 {
    data.iter().sum::<f32>() / data.len() as f32
}
