fn main() {
    println!("iteration wall_secs");
}
