use std::collections::HashMap;

pub fn collapse() -> usize {
    let mut label_of: HashMap<usize, usize> = HashMap::new();
    label_of.insert(1, 2);
    let mut total = 0;
    for (k, v) in &label_of {
        total += k + v;
    }
    total
}
