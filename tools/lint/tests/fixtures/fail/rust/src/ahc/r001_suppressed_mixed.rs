use std::collections::HashSet;

pub fn count_plus_head(xs: &[usize]) -> usize {
    let tags: HashSet<usize> = HashSet::new();
    tags.iter().count() + xs[0] // lint: allow(R001) fixture: count is order-free
}
