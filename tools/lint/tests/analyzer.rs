//! Integration tests: the analyzer against the fixture corpus (one
//! failing and one passing snippet per rule), and the binary's exit
//! codes with and without the burn-down allowlist.

use std::path::{Path, PathBuf};
use std::process::Command;

use mahc_lint::{apply_allowlist, parse_allowlist, scan_root, Finding, Rule};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

fn keys(findings: &[Finding]) -> Vec<(Rule, String, usize)> {
    findings
        .iter()
        .map(|f| (f.rule, f.path.clone(), f.line))
        .collect()
}

#[test]
fn fail_tree_reports_every_rule_span_accurately() {
    let findings = scan_root(&fixture("fail")).unwrap();
    // Sorted the way scan_root reports: (path, line, rule).
    let expected: Vec<(Rule, String, usize)> = vec![
        (Rule::R001, "rust/src/ahc/r001_fail.rs".into(), 7),
        (Rule::R002, "rust/src/ahc/r001_suppressed_mixed.rs".into(), 5),
        (Rule::R004, "rust/src/corpus/r004_fail.rs".into(), 2),
        (Rule::R003, "rust/src/distance/r003_fail.rs".into(), 2),
        (Rule::R003, "rust/src/distance/r003_vector_fail.rs".into(), 2),
        (Rule::R003, "rust/src/distance/r003_vector_fail.rs".into(), 3),
        (Rule::R006, "rust/src/distance/r006_fail.rs".into(), 1),
        (Rule::R002, "rust/src/mahc/r002_fail.rs".into(), 2),
        (Rule::R002, "rust/src/mahc/r002_fail.rs".into(), 3),
        (Rule::R002, "rust/src/mahc/r002_fail.rs".into(), 5),
        (Rule::R002, "rust/src/mahc/r002_fail.rs".into(), 7),
        // ghost_metric: missing from the JSON writer AND the CLI summary.
        (Rule::R005, "rust/src/telemetry/mod.rs".into(), 4),
        (Rule::R005, "rust/src/telemetry/mod.rs".into(), 4),
        // metric: serialized by to_json but never surfaced on the CLI.
        (Rule::R005, "rust/src/telemetry/mod.rs".into(), 5),
        // silhouette_score: missing from both, like ghost_metric.
        (Rule::R005, "rust/src/telemetry/mod.rs".into(), 6),
        (Rule::R005, "rust/src/telemetry/mod.rs".into(), 6),
    ];
    assert_eq!(keys(&findings), expected, "{findings:#?}");
}

#[test]
fn pass_tree_is_clean() {
    let findings = scan_root(&fixture("pass")).unwrap();
    assert!(findings.is_empty(), "{findings:#?}");
}

#[test]
fn suppression_silences_exactly_its_own_rule() {
    // The mixed fixture carries `// lint: allow(R001)` on a line with
    // both a hash iteration (suppressed) and an unchecked index (not).
    let findings = scan_root(&fixture("fail")).unwrap();
    let mixed: Vec<&Finding> = findings
        .iter()
        .filter(|f| f.path.ends_with("r001_suppressed_mixed.rs"))
        .collect();
    assert_eq!(mixed.len(), 1, "{mixed:#?}");
    assert_eq!(mixed[0].rule, Rule::R002);
    assert_eq!(mixed[0].line, 5);
    // The pass tree exercises the alias form (`order-insensitive`) on a
    // preceding comment-only line; pass_tree_is_clean pins that it
    // silences the R001 hit.  Both trees together prove the suppression
    // is rule-specific, not line-wide.
}

#[test]
fn allowlist_covers_exactly_and_flags_stale_and_exceeded() {
    let findings = scan_root(&fixture("fail")).unwrap();

    let ok = parse_allowlist(&std::fs::read_to_string(fixture("allowlists/ok.toml")).unwrap())
        .unwrap();
    let out = apply_allowlist(findings.clone(), &ok);
    assert!(out.remaining.is_empty(), "{:#?}", out.remaining);
    assert_eq!(out.allowlisted, 16);
    assert!(out.errors.is_empty(), "{:?}", out.errors);

    let stale =
        parse_allowlist(&std::fs::read_to_string(fixture("allowlists/stale.toml")).unwrap())
            .unwrap();
    let out = apply_allowlist(findings.clone(), &stale);
    assert_eq!(out.errors.len(), 1, "{:?}", out.errors);
    assert!(out.errors[0].contains("stale"), "{:?}", out.errors);

    let exceeded =
        parse_allowlist(&std::fs::read_to_string(fixture("allowlists/exceeded.toml")).unwrap())
            .unwrap();
    let out = apply_allowlist(findings, &exceeded);
    assert!(
        out.errors.iter().any(|e| e.contains("exceeded")),
        "{:?}",
        out.errors
    );
    // The undercounted entry absorbs nothing: all four stay visible.
    assert_eq!(out.remaining.len(), 4, "{:#?}", out.remaining);
}

fn run_binary(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_mahc-lint"))
        .args(args)
        .output()
        .expect("spawn mahc-lint");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
    )
}

#[test]
fn binary_exits_nonzero_on_fail_tree_with_every_rule_reported() {
    let root = fixture("fail");
    let (ok, stdout) = run_binary(&["--root", root.to_str().unwrap()]);
    assert!(!ok, "fail tree must exit nonzero\n{stdout}");
    for rule in Rule::ALL {
        assert!(
            stdout.contains(rule.id()),
            "missing {} in:\n{stdout}",
            rule.id()
        );
    }
    // Diagnostics are span-accurate `path:line: RXXX message` lines.
    assert!(
        stdout.contains("rust/src/mahc/r002_fail.rs:7: R002"),
        "{stdout}"
    );
}

#[test]
fn binary_exits_zero_on_pass_tree_and_accepts_xtask_word() {
    let root = fixture("pass");
    let (ok, stdout) = run_binary(&["--root", root.to_str().unwrap()]);
    assert!(ok, "pass tree must exit zero\n{stdout}");
    // `cargo xtask lint` prepends the literal word `lint`.
    let (ok, stdout) = run_binary(&["lint", "--root", root.to_str().unwrap()]);
    assert!(ok, "xtask form must exit zero\n{stdout}");
}

#[test]
fn binary_allowlist_modes() {
    let root = fixture("fail");
    let root = root.to_str().unwrap();
    let ok_list = fixture("allowlists/ok.toml");
    let (ok, stdout) = run_binary(&["--root", root, "--allowlist", ok_list.to_str().unwrap()]);
    assert!(ok, "fully allowlisted tree must exit zero\n{stdout}");

    let stale = fixture("allowlists/stale.toml");
    let (ok, stdout) = run_binary(&["--root", root, "--allowlist", stale.to_str().unwrap()]);
    assert!(!ok, "stale allowlist must exit nonzero\n{stdout}");
    assert!(stdout.contains("stale"), "{stdout}");

    let exceeded = fixture("allowlists/exceeded.toml");
    let (ok, stdout) = run_binary(&["--root", root, "--allowlist", exceeded.to_str().unwrap()]);
    assert!(!ok, "exceeded allowlist must exit nonzero\n{stdout}");
    assert!(stdout.contains("exceeded"), "{stdout}");

    // --no-allowlist surfaces everything even with a covering file present.
    let (ok, stdout) = run_binary(&["--root", root, "--no-allowlist"]);
    assert!(!ok);
    assert!(stdout.lines().filter(|l| l.contains(": R")).count() >= 15, "{stdout}");
}

#[test]
fn real_repo_is_clean_under_its_allowlist() {
    // The repo root is two levels up from tools/lint.  This is the same
    // invocation CI's static-analysis job runs; it must stay green.
    let repo = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../..")
        .canonicalize()
        .unwrap();
    let (ok, stdout) = run_binary(&["--root", repo.to_str().unwrap()]);
    assert!(ok, "repo must lint clean under its allowlist\n{stdout}");
}
