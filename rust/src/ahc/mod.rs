//! Agglomerative hierarchical clustering substrate.
//!
//! Classic AHC over a condensed DTW distance matrix, as the paper's §3
//! prescribes: Ward linkage (the Murtagh-Legendre "Ward2" Lance-
//! Williams form, applicable to a non-Euclidean DTW matrix), computed
//! exactly in O(n²) time with the nearest-neighbour-chain algorithm
//! ([`nnchain`]).  [`dendrogram`] turns the merge list into labelled
//! cuts; [`lmethod`] finds the number of clusters per subset (Salvador
//! & Chan, as in the paper's Step 4) with [`silhouette`]-based
//! selection as the diarization-style alternative
//! ([`SelectionMethod`]); [`medoid`] picks each cluster's
//! representative for the second stage.

pub mod dendrogram;
pub mod lmethod;
pub mod medoid;
pub mod nnchain;
pub mod silhouette;

pub use dendrogram::Dendrogram;
pub use lmethod::l_method;
pub use medoid::medoids;
pub use nnchain::{ward_linkage, ward_linkage_weighted};
pub use silhouette::{mean_silhouette, silhouette_k};

use crate::distance::Condensed;

/// How the number of clusters is chosen when no override is given.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SelectionMethod {
    /// The paper's L-method knee over merge heights (Salvador & Chan).
    #[default]
    LMethod,
    /// Mean-silhouette argmax over candidate cuts (`silhouette`), the
    /// convention of the diarization exemplars.  Falls back to the
    /// L-method on corpora too small for a silhouette (n < 3).
    Silhouette,
}

impl SelectionMethod {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "lmethod" | "l-method" => Ok(SelectionMethod::LMethod),
            "silhouette" => Ok(SelectionMethod::Silhouette),
            other => anyhow::bail!("unknown selection method '{other}' (lmethod|silhouette)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SelectionMethod::LMethod => "lmethod",
            SelectionMethod::Silhouette => "silhouette",
        }
    }
}

/// Result of clustering one subset: flat labels in `0..k`, the chosen
/// k, and the medoid (index into the subset) of each cluster.
#[derive(Debug, Clone)]
pub struct SubsetClustering {
    pub labels: Vec<usize>,
    pub k: usize,
    pub medoids: Vec<usize>,
}

/// Cluster one subset end-to-end with the default L-method selection:
/// Ward AHC → L-method k → cut → medoids.  Thin wrapper over
/// [`cluster_subset_with`], kept for the historical call sites.
///
/// `max_k` caps the selection's answer (the driver passes
/// `max_clusters_frac * n`); `k_override` forces a specific cut (used
/// by the final stage, Algorithm 1 step 13).
pub fn cluster_subset(
    cond: &Condensed,
    max_k: usize,
    k_override: Option<usize>,
) -> SubsetClustering {
    cluster_subset_with(cond, max_k, k_override, SelectionMethod::LMethod)
}

/// Cluster one subset end-to-end: Ward AHC → `selection`-chosen k →
/// cut → medoids.
pub fn cluster_subset_with(
    cond: &Condensed,
    max_k: usize,
    k_override: Option<usize>,
    selection: SelectionMethod,
) -> SubsetClustering {
    cluster_subset_sized(cond, max_k, k_override, selection, None)
}

/// [`cluster_subset_with`] where object `i` stands for a pre-merged
/// group of `sizes[i]` members (the cluster-feature path): linkage runs
/// count-weighted over `cond`, which must already be on the Ward2 scale
/// for those sizes.  `sizes: None` is the historical unweighted path,
/// bitwise.
pub fn cluster_subset_sized(
    cond: &Condensed,
    max_k: usize,
    k_override: Option<usize>,
    selection: SelectionMethod,
    sizes: Option<&[usize]>,
) -> SubsetClustering {
    let n = cond.n();
    if n == 0 {
        return SubsetClustering {
            labels: Vec::new(),
            k: 0,
            medoids: Vec::new(),
        };
    }
    if n == 1 {
        return SubsetClustering {
            labels: vec![0],
            k: 1,
            medoids: vec![0],
        };
    }
    let dendro = match sizes {
        Some(s) => ward_linkage_weighted(cond, s),
        None => ward_linkage(cond),
    };
    let k = match k_override {
        Some(k) => k.clamp(1, n),
        None => {
            let chosen = match selection {
                SelectionMethod::Silhouette => silhouette_k(cond, &dendro, max_k.max(1)),
                SelectionMethod::LMethod => None,
            };
            match chosen {
                Some(k) => k.clamp(1, max_k.max(1)).min(n),
                // L-method proper, and the silhouette fallback for
                // corpora with no candidate cut (n < 3).
                None => {
                    let heights = dendro.merge_heights();
                    l_method(&heights, n).clamp(1, max_k.max(1)).min(n)
                }
            }
        }
    };
    let labels = dendro.cut(k);
    let medoids = medoids(&labels, k, cond);
    SubsetClustering { labels, k, medoids }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three well-separated blobs on a line, 4 points each.
    fn blob_condensed() -> (Condensed, Vec<usize>) {
        let centers = [0.0f32, 10.0, 20.0];
        let mut pts = Vec::new();
        let mut truth = Vec::new();
        for (c, &center) in centers.iter().enumerate() {
            for k in 0..4 {
                pts.push(center + k as f32 * 0.1);
                truth.push(c);
            }
        }
        let n = pts.len();
        let mut cond = Condensed::zeros(n);
        for i in 0..n {
            for j in 0..i {
                cond.set(i, j, (pts[i] - pts[j]).abs());
            }
        }
        (cond, truth)
    }

    #[test]
    fn recovers_blobs_end_to_end() {
        let (cond, truth) = blob_condensed();
        let out = cluster_subset(&cond, 6, None);
        assert_eq!(out.k, 3, "L-method should find 3 blobs");
        // Same-truth pairs share labels; different-truth pairs don't.
        for i in 0..truth.len() {
            for j in 0..i {
                assert_eq!(
                    out.labels[i] == out.labels[j],
                    truth[i] == truth[j],
                    "pair ({i},{j})"
                );
            }
        }
        assert_eq!(out.medoids.len(), 3);
        // Each medoid belongs to the cluster it represents.
        for (c, &m) in out.medoids.iter().enumerate() {
            assert_eq!(out.labels[m], c);
        }
    }

    #[test]
    fn k_override_respected() {
        let (cond, _) = blob_condensed();
        let out = cluster_subset(&cond, 12, Some(5));
        assert_eq!(out.k, 5);
        assert_eq!(
            out.labels.iter().collect::<std::collections::HashSet<_>>().len(),
            5
        );
    }

    #[test]
    fn degenerate_sizes() {
        let out = cluster_subset(&Condensed::zeros(1), 4, None);
        assert_eq!(out.k, 1);
        assert_eq!(out.labels, vec![0]);
        let out = cluster_subset(&Condensed::zeros(0), 4, None);
        assert_eq!(out.k, 0);
    }

    #[test]
    fn silhouette_selection_agrees_with_lmethod_on_separated_blobs() {
        let (cond, _) = blob_condensed();
        let l = cluster_subset(&cond, 6, None);
        let s = cluster_subset_with(&cond, 6, None, SelectionMethod::Silhouette);
        assert_eq!(l.k, s.k, "both selectors must find the 3 blobs");
        assert_eq!(l.labels, s.labels, "same dendrogram, same cut");
    }

    #[test]
    fn silhouette_selection_falls_back_below_three_points() {
        let mut cond = Condensed::zeros(2);
        cond.set(1, 0, 1.0);
        let l = cluster_subset(&cond, 2, None);
        let s = cluster_subset_with(&cond, 2, None, SelectionMethod::Silhouette);
        assert_eq!(l.k, s.k);
    }

    #[test]
    fn two_objects() {
        let mut cond = Condensed::zeros(2);
        cond.set(1, 0, 1.0);
        let out = cluster_subset(&cond, 2, None);
        assert!(out.k == 1 || out.k == 2);
        assert_eq!(out.labels.len(), 2);
    }
}
