//! Silhouette-based cluster-count selection — the model-selection
//! method every SNIPPETS.md diarization exemplar uses, offered beside
//! the paper's L-method knee (`lmethod.rs`).
//!
//! For a candidate cut into k clusters, each point's silhouette is
//! `s(i) = (b(i) − a(i)) / max(a(i), b(i))` where `a(i)` is the mean
//! distance to the point's own cluster (excluding itself) and `b(i)`
//! the smallest mean distance to any other cluster; the cut's score is
//! the mean over all points.  [`silhouette_k`] scans every cut
//! `k ∈ [2, min(max_k, n−1)]` of one dendrogram and keeps the argmax
//! (smaller k on ties, so the scan is deterministic).
//!
//! Determinism: all accumulation is widened to f64 in explicit
//! fixed-order loops (ascending point, then ascending cluster), so a
//! score — and with it the chosen k — is a pure function of the
//! condensed matrix, independent of thread count or backend.

use super::Dendrogram;
use crate::distance::Condensed;

/// Mean silhouette of one labelling over a condensed distance matrix.
///
/// `labels` must be dense in `0..k` (the [`Dendrogram::cut`]
/// convention).  Degenerate inputs score 0: fewer than two clusters, a
/// labelling length that does not match the matrix, or an all-zero
/// matrix.  Points in singleton clusters contribute `s(i) = 0`, the
/// standard convention.
pub fn mean_silhouette(cond: &Condensed, labels: &[usize], k: usize) -> f64 {
    let n = cond.n();
    if k < 2 || n < 2 || labels.len() != n {
        return 0.0;
    }
    let mut counts = vec![0usize; k];
    for &l in labels {
        if let Some(c) = counts.get_mut(l) {
            *c += 1;
        } else {
            // Out-of-range label: the cut contract is broken; score the
            // labelling as uninformative rather than panicking.
            return 0.0;
        }
    }

    let mut total = 0.0f64;
    let mut sums = vec![0.0f64; k];
    for (i, &own) in labels.iter().enumerate() {
        for s in sums.iter_mut() {
            *s = 0.0;
        }
        for (j, &lj) in labels.iter().enumerate() {
            if i != j {
                if let Some(s) = sums.get_mut(lj) {
                    *s += cond.get(i, j) as f64;
                }
            }
        }
        let own_count = counts.get(own).copied().unwrap_or(0);
        if own_count <= 1 {
            // Singleton cluster: s(i) = 0 by convention.
            continue;
        }
        let a = sums.get(own).copied().unwrap_or(0.0) / (own_count - 1) as f64;
        let mut b = f64::INFINITY;
        for (c, (&s, &cnt)) in sums.iter().zip(counts.iter()).enumerate() {
            if c != own && cnt > 0 {
                let mean = s / cnt as f64;
                if mean < b {
                    b = mean;
                }
            }
        }
        if !b.is_finite() {
            continue;
        }
        let denom = a.max(b);
        if denom > 0.0 {
            total += (b - a) / denom;
        }
    }
    total / n as f64
}

/// Pick the cluster count by maximising the mean silhouette over cuts
/// of `dendro`, scanning `k ∈ [2, min(max_k, n−1)]` in ascending order
/// (strict improvement required, so ties keep the smaller k).
///
/// Returns `None` when no candidate cut exists (n < 3 or `max_k` < 2):
/// the caller falls back to the L-method path, which owns the
/// degenerate cases.
pub fn silhouette_k(cond: &Condensed, dendro: &Dendrogram, max_k: usize) -> Option<usize> {
    let n = cond.n();
    let hi = max_k.min(n.saturating_sub(1));
    if hi < 2 {
        return None;
    }
    let mut best_k = None;
    let mut best_score = f64::NEG_INFINITY;
    for k in 2..=hi {
        let labels = dendro.cut(k);
        let score = mean_silhouette(cond, &labels, k);
        if score > best_score {
            best_score = score;
            best_k = Some(k);
        }
    }
    best_k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ahc::ward_linkage;

    /// Well-separated blobs on a line, `per` points each.
    fn blobs(centers: &[f32], per: usize) -> (Condensed, Vec<usize>) {
        let mut pts = Vec::new();
        let mut truth = Vec::new();
        for (c, &center) in centers.iter().enumerate() {
            for k in 0..per {
                pts.push(center + k as f32 * 0.1);
                truth.push(c);
            }
        }
        let n = pts.len();
        let mut cond = Condensed::zeros(n);
        for i in 0..n {
            for j in 0..i {
                cond.set(i, j, (pts[i] - pts[j]).abs());
            }
        }
        (cond, truth)
    }

    #[test]
    fn separated_blobs_score_near_one_at_true_k() {
        let (cond, truth) = blobs(&[0.0, 10.0, 20.0], 4);
        let s = mean_silhouette(&cond, &truth, 3);
        assert!(s > 0.9, "tight separated blobs should score near 1, got {s}");
    }

    #[test]
    fn wrong_k_scores_below_true_k() {
        let (cond, truth) = blobs(&[0.0, 10.0, 20.0], 4);
        let dendro = ward_linkage(&cond);
        let s_true = mean_silhouette(&cond, &truth, 3);
        for k in [2usize, 4, 6] {
            let s = mean_silhouette(&cond, &dendro.cut(k), k);
            assert!(s < s_true, "k={k} ({s}) must score below true k ({s_true})");
        }
    }

    #[test]
    fn selection_recovers_true_k() {
        let (cond, _) = blobs(&[0.0, 10.0, 20.0, 30.0], 5);
        let dendro = ward_linkage(&cond);
        assert_eq!(silhouette_k(&cond, &dendro, 10), Some(4));
    }

    #[test]
    fn degenerate_inputs_fall_back() {
        let (cond, _) = blobs(&[0.0, 10.0], 1);
        let dendro = ward_linkage(&cond);
        // n = 2: no candidate in [2, n−1].
        assert_eq!(silhouette_k(&cond, &dendro, 8), None);
        assert_eq!(mean_silhouette(&cond, &[0, 0], 1), 0.0);
        assert_eq!(mean_silhouette(&Condensed::zeros(0), &[], 2), 0.0);
    }

    #[test]
    fn singleton_clusters_contribute_zero() {
        let (cond, _) = blobs(&[0.0, 10.0], 2);
        // 0,1 together; 2 and 3 singletons.
        let s = mean_silhouette(&cond, &[0, 0, 1, 2], 3);
        // Points 2 and 3 contribute 0; points 0 and 1 are near-perfect.
        assert!(s > 0.0 && s < 0.75, "partial credit only, got {s}");
    }
}
