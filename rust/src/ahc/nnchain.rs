//! Exact Ward linkage via the nearest-neighbour-chain algorithm.
//!
//! NN-chain computes the same dendrogram as naive O(n³) agglomeration
//! in O(n²) time and O(n) extra space, for any *reducible* linkage —
//! Ward is reducible.  The inter-cluster distance is maintained with
//! the Lance-Williams "Ward2" update (Murtagh & Legendre 2014), which
//! operates on the distances themselves and is therefore applicable to
//! the paper's DTW (non-Euclidean) similarity matrix:
//!
//!   d(i∪j, k) = √[((nᵢ+nₖ)d²ᵢₖ + (nⱼ+nₖ)d²ⱼₖ − nₖd²ᵢⱼ) / (nᵢ+nⱼ+nₖ)]
//!
//! The working matrix is a mutable copy of the condensed input; merged-
//! away clusters are tombstoned.  Merges can come off the chain out of
//! height order, so the final merge list is sorted by height and
//! relabelled union-find style (as scipy's `linkage` does).

use crate::distance::Condensed;

use super::dendrogram::Dendrogram;

/// Compute the Ward dendrogram of a condensed distance matrix.
pub fn ward_linkage(cond: &Condensed) -> Dendrogram {
    ward_linkage_with_sizes(cond, None)
}

/// Ward dendrogram where object `i` stands for a pre-merged cluster of
/// `sizes[i]` members (the cluster-feature path: stage-0 groups enter
/// linkage with their member counts, per Schubert & Lang).  The input
/// distances must already be on the Ward2 scale for those sizes — see
/// [`crate::aggregate::summary::scale_condensed_by_counts`].  All-ones
/// sizes (or `ward_linkage`) is the historical unweighted path, bitwise.
pub fn ward_linkage_weighted(cond: &Condensed, sizes: &[usize]) -> Dendrogram {
    ward_linkage_with_sizes(cond, Some(sizes))
}

fn ward_linkage_with_sizes(cond: &Condensed, sizes: Option<&[usize]>) -> Dendrogram {
    let n = cond.n();
    if n < 2 {
        return Dendrogram::new(n, Vec::new());
    }

    // Working copy of distances + cluster sizes; `alive[c]` marks
    // clusters not yet merged away.  Indices 0..n are the original
    // objects throughout; a merged cluster keeps the *smaller* index.
    let mut d = cond.clone();
    let mut size = match sizes {
        Some(s) => {
            debug_assert_eq!(s.len(), n);
            s.to_vec()
        }
        None => vec![1usize; n],
    };
    let mut alive = vec![true; n];

    let mut raw: Vec<(usize, usize, f32)> = Vec::with_capacity(n - 1);
    let mut chain: Vec<usize> = Vec::with_capacity(n);

    for _ in 0..n - 1 {
        // (Re)start the chain from any living cluster.  The outer loop
        // runs exactly n-1 merges, so a living cluster always exists;
        // breaking covers the impossible empty case without a panic.
        if chain.is_empty() {
            let Some(start) = alive.iter().position(|&a| a) else {
                break;
            };
            chain.push(start);
        }

        // Grow the chain until two clusters are mutual nearest
        // neighbours.
        loop {
            let Some(&c) = chain.last() else {
                break; // chain was (re)seeded above; never empty here
            };
            // Nearest living neighbour of c, preferring the previous
            // chain element on ties (guarantees termination).
            let prev = if chain.len() >= 2 {
                Some(chain[chain.len() - 2])
            } else {
                None
            };
            let mut best = usize::MAX;
            let mut best_d = f32::INFINITY;
            for k in 0..n {
                if k == c || !alive[k] {
                    continue;
                }
                let dist = d.get(c, k);
                if dist < best_d || (dist == best_d && Some(k) == prev) {
                    best_d = dist;
                    best = k;
                }
            }
            debug_assert!(best != usize::MAX);
            if Some(best) == prev {
                // Mutual pair found: merge c and best.
                chain.pop();
                chain.pop();
                let (a, b) = (c.min(best), c.max(best));
                merge_into(&mut d, &mut size, &alive, a, b, best_d);
                alive[b] = false;
                size[a] += size[b];
                raw.push((a, b, best_d));
                break;
            }
            chain.push(best);
        }
    }

    Dendrogram::from_raw_merges(n, raw)
}

/// Lance-Williams Ward2 update: fold cluster `b` into `a`, updating
/// row/column `a` of the working matrix for all living k ∉ {a, b}.
fn merge_into(
    d: &mut Condensed,
    size: &mut [usize],
    alive: &[bool],
    a: usize,
    b: usize,
    dab: f32,
) {
    let (na, nb) = (size[a] as f64, size[b] as f64);
    let dab2 = (dab as f64) * (dab as f64);
    for k in 0..d.n() {
        if k == a || k == b || !alive[k] {
            continue;
        }
        let nk = size[k] as f64;
        let dak = d.get(a, k) as f64;
        let dbk = d.get(b, k) as f64;
        let num = (na + nk) * dak * dak + (nb + nk) * dbk * dbk - nk * dab2;
        let new = (num / (na + nb + nk)).max(0.0).sqrt();
        d.set(a, k, new as f32);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cond_from_points(pts: &[f32]) -> Condensed {
        let n = pts.len();
        let mut c = Condensed::zeros(n);
        for i in 0..n {
            for j in 0..i {
                c.set(i, j, (pts[i] - pts[j]).abs());
            }
        }
        c
    }

    /// Naive O(n³) Ward agglomeration with the same LW update, as a
    /// correctness oracle for the chain algorithm.
    fn naive_ward(cond: &Condensed) -> Vec<f32> {
        let n = cond.n();
        let mut d = cond.clone();
        let mut size = vec![1usize; n];
        let mut alive = vec![true; n];
        let mut heights = Vec::new();
        for _ in 0..n - 1 {
            let mut best = (0, 0, f32::INFINITY);
            for i in 0..n {
                if !alive[i] {
                    continue;
                }
                for j in 0..i {
                    if !alive[j] {
                        continue;
                    }
                    let v = d.get(i, j);
                    if v < best.2 {
                        best = (j, i, v);
                    }
                }
            }
            let (a, b, h) = best;
            heights.push(h);
            super::merge_into(&mut d, &mut size, &alive, a, b, h);
            alive[b] = false;
            size[a] += size[b];
        }
        heights.sort_by(|x, y| x.partial_cmp(y).unwrap());
        heights
    }

    #[test]
    fn chain_matches_naive_heights() {
        // Heights (sorted) must agree between NN-chain and naive Ward;
        // merge *order* may differ but the dendrogram is the same.
        for seed in 0..5u64 {
            let mut rng = crate::util::rng::Rng::seed_from(seed);
            let pts: Vec<f32> = (0..24).map(|_| rng.normal() as f32 * 3.0).collect();
            let cond = cond_from_points(&pts);
            let dendro = ward_linkage(&cond);
            let mut got = dendro.merge_heights();
            got.sort_by(|x, y| x.partial_cmp(y).unwrap());
            let want = naive_ward(&cond);
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g - w).abs() <= 1e-4 * w.abs().max(1.0),
                    "seed {seed}: {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn merges_count_and_monotonicity() {
        let pts: Vec<f32> = vec![0.0, 0.1, 5.0, 5.1, 10.0, 10.1, 10.2];
        let dendro = ward_linkage(&cond_from_points(&pts));
        assert_eq!(dendro.merges().len(), pts.len() - 1);
        // Ward heights are monotone non-decreasing after sorting —
        // verify the stored order is already sorted (from_raw_merges).
        let h = dendro.merge_heights();
        for w in h.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn pair_merges_first() {
        // The two closest points must be the first merge.
        let pts = vec![0.0f32, 100.0, 100.05, 200.0];
        let dendro = ward_linkage(&cond_from_points(&pts));
        let first = &dendro.merges()[0];
        let mut ab = [first.a, first.b];
        ab.sort_unstable();
        assert_eq!(ab, [1, 2]);
    }

    #[test]
    fn tiny_inputs() {
        assert_eq!(ward_linkage(&Condensed::zeros(0)).merges().len(), 0);
        assert_eq!(ward_linkage(&Condensed::zeros(1)).merges().len(), 0);
        let mut c = Condensed::zeros(2);
        c.set(1, 0, 3.0);
        let d = ward_linkage(&c);
        assert_eq!(d.merges().len(), 1);
        assert_eq!(d.merges()[0].height, 3.0);
    }

    #[test]
    fn equal_distances_dont_hang() {
        // Fully tied matrix: chain must still terminate with n-1 merges.
        let n = 12;
        let mut c = Condensed::zeros(n);
        for i in 0..n {
            for j in 0..i {
                c.set(i, j, 1.0);
            }
        }
        let d = ward_linkage(&c);
        assert_eq!(d.merges().len(), n - 1);
    }
}
