//! Medoid extraction (Algorithm 1 step 5): the member of each cluster
//! minimising its summed distance to the other members.  Exact — the
//! within-subset distances are already resident in the condensed
//! matrix from stage 1, so no extra DTW work is needed.

use crate::distance::Condensed;

/// Medoid of each cluster under `labels` (values in 0..k).  Returns one
/// index per cluster; empty clusters (possible only if `labels` never
/// uses some value < k) get `usize::MAX`.
pub fn medoids(labels: &[usize], k: usize, cond: &Condensed) -> Vec<usize> {
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); k];
    for (i, &l) in labels.iter().enumerate() {
        members[l].push(i);
    }
    members
        .iter()
        .map(|m| medoid_of(m, cond))
        .collect()
}

/// Medoid of an explicit member list.
pub fn medoid_of(members: &[usize], cond: &Condensed) -> usize {
    match members.len() {
        0 => usize::MAX,
        1 => members[0],
        _ => {
            let mut best = (members[0], f64::INFINITY);
            for &i in members {
                let total: f64 = members
                    .iter()
                    .filter(|&&j| j != i)
                    .map(|&j| cond.get(i, j) as f64)
                    .sum();
                if total < best.1 {
                    best = (i, total);
                }
            }
            best.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line_condensed(pts: &[f32]) -> Condensed {
        let n = pts.len();
        let mut c = Condensed::zeros(n);
        for i in 0..n {
            for j in 0..i {
                c.set(i, j, (pts[i] - pts[j]).abs());
            }
        }
        c
    }

    #[test]
    fn picks_central_member() {
        // Points 0, 1, 10: medoid is 1 (total 1+9=10 beats 0's 1+10).
        let cond = line_condensed(&[0.0, 1.0, 10.0]);
        assert_eq!(medoid_of(&[0, 1, 2], &cond), 1);
    }

    #[test]
    fn per_cluster_medoids() {
        let cond = line_condensed(&[0.0, 1.0, 2.0, 100.0, 101.0]);
        let labels = vec![0, 0, 0, 1, 1];
        let m = medoids(&labels, 2, &cond);
        assert_eq!(m[0], 1); // centre of {0,1,2}
        assert!(m[1] == 3 || m[1] == 4); // tie between the pair
    }

    #[test]
    fn singleton_and_empty() {
        let cond = line_condensed(&[0.0, 5.0]);
        assert_eq!(medoid_of(&[1], &cond), 1);
        assert_eq!(medoid_of(&[], &cond), usize::MAX);
    }

    #[test]
    fn deterministic_on_ties() {
        // Symmetric pair: first member wins (stable iteration order).
        let cond = line_condensed(&[0.0, 2.0]);
        assert_eq!(medoid_of(&[0, 1], &cond), 0);
    }
}
