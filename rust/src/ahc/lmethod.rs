//! The L method (Salvador & Chan 2004): automatic number-of-clusters
//! selection from the dendrogram's evaluation graph.
//!
//! The evaluation graph plots merge distance (y) against number of
//! clusters (x = n−1 … 1 read off the merge sequence).  The method fits
//! two least-squares lines — left of a candidate knee c and right of it
//! — and picks the c minimising the length-weighted total RMSE:
//!
//!   RMSE(c) = (c−1)/(b−1) · RMSE_left + (b−c)/(b−1) · RMSE_right
//!
//! The iterative-refinement variant repeatedly truncates the x-range to
//! 2·knee (large flat tails otherwise drag the knee right), which is
//! the form the MAHC papers use.

/// Fit y = α + βx over the given points, returning RMSE.
fn line_rmse(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    debug_assert!(xs.len() >= 2);
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    let (alpha, beta) = if denom.abs() < 1e-12 {
        (sy / n, 0.0)
    } else {
        let beta = (n * sxy - sx * sy) / denom;
        ((sy - beta * sx) / n, beta)
    };
    let sse: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (alpha + beta * x);
            e * e
        })
        .sum();
    (sse / n).sqrt()
}

/// One pass of the L method over points (xs[i], ys[i]); returns the knee
/// x value.  Needs at least 4 points (2 per line).
fn knee_once(xs: &[f64], ys: &[f64]) -> usize {
    let b = xs.len();
    debug_assert!(b >= 4);
    let mut best_c = 2;
    let mut best_err = f64::INFINITY;
    // Knee index c partitions [0, c) | [c, b); both sides >= 2 points.
    for c in 2..=b - 2 {
        let left = line_rmse(&xs[..c], &ys[..c]);
        let right = line_rmse(&xs[c..], &ys[c..]);
        // Salvador & Chan's length weighting (module header): the knee
        // candidate c sits between the two fitted ranges, so the usable
        // x-extent is b−1 intervals of which c−1 lie left of c.
        let err = ((c - 1) as f64 / (b - 1) as f64) * left
            + ((b - c) as f64 / (b - 1) as f64) * right;
        if err < best_err {
            best_err = err;
            best_c = c;
        }
    }
    xs[best_c - 1].round() as usize
}

/// Determine the number of clusters from merge heights (ascending, as
/// [`super::Dendrogram::merge_heights`] returns them).
///
/// `n` is the number of objects.  Returns a value in [2, n−1] for
/// n ≥ 4; degenerate inputs fall back to small constants.
pub fn l_method(heights_ascending: &[f32], n: usize) -> usize {
    let m = heights_ascending.len();
    if n < 2 || m == 0 {
        return 1;
    }
    if n < 6 {
        // Too few points for two regression lines; the merge sequence
        // gives at best a coarse answer — pick the largest height gap.
        return largest_gap_k(heights_ascending, n);
    }

    // Evaluation graph: x = number of clusters after undoing merge i,
    // ordered by increasing x. Undoing the last merge leaves 2 clusters:
    // x = 2..=n-? ; y = merge height at that point.
    // heights_ascending[m-1] corresponds to x = 2, [m-2] to 3, etc.
    let mut xs: Vec<f64> = Vec::with_capacity(m);
    let mut ys: Vec<f64> = Vec::with_capacity(m);
    for i in 0..m {
        xs.push((i + 2) as f64); // clusters
        ys.push(heights_ascending[m - 1 - i] as f64);
    }

    // Iterative refinement (Salvador & Chan §3.3): shrink the x-range
    // to twice the current knee until it stops moving.
    let mut cutoff = xs.len();
    let mut knee = knee_once(&xs, &ys);
    for _ in 0..32 {
        let new_cutoff = (2 * knee).clamp(4, xs.len());
        if new_cutoff >= cutoff {
            break;
        }
        cutoff = new_cutoff;
        let new_knee = knee_once(&xs[..cutoff], &ys[..cutoff]);
        if new_knee == knee {
            break;
        }
        knee = new_knee;
    }
    knee.clamp(2, n - 1)
}

/// Fallback for tiny inputs: k just after the largest height jump.
fn largest_gap_k(heights_ascending: &[f32], n: usize) -> usize {
    let m = heights_ascending.len();
    if m < 2 {
        return 1.max(n.min(2));
    }
    let mut best = (0usize, -1.0f32);
    for i in 0..m - 1 {
        let gap = heights_ascending[i + 1] - heights_ascending[i];
        if gap > best.1 {
            best = (i, gap);
        }
    }
    // Undoing merges above the gap leaves (m - best.0) clusters... +1
    // because m = n-1 merges produce 1 cluster when all applied.
    (m - best.0).clamp(1, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ahc::ward_linkage;
    use crate::distance::Condensed;

    #[test]
    fn finds_knee_on_synthetic_graph() {
        // Construct heights whose evaluation graph has an obvious knee
        // at 4 clusters: within-cluster merges cheap, between expensive.
        // n = 40 objects, 39 merges: 36 small then 3 big (joining 4 blobs).
        let mut heights: Vec<f32> = (0..36).map(|i| 0.1 + 0.002 * i as f32).collect();
        heights.extend_from_slice(&[8.0, 9.0, 10.0]);
        let k = l_method(&heights, 40);
        assert!(
            (3..=6).contains(&k),
            "expected knee near 4 clusters, got {k}"
        );
    }

    #[test]
    fn blob_dendrogram_end_to_end() {
        // 5 well-separated blobs of 6 points each on a line.
        let mut pts = Vec::new();
        for c in 0..5 {
            for j in 0..6 {
                pts.push(c as f32 * 50.0 + j as f32 * 0.2);
            }
        }
        let n = pts.len();
        let mut cond = Condensed::zeros(n);
        for i in 0..n {
            for j in 0..i {
                cond.set(i, j, (pts[i] - pts[j]).abs());
            }
        }
        let dendro = ward_linkage(&cond);
        let k = l_method(&dendro.merge_heights(), n);
        assert_eq!(k, 5);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(l_method(&[], 1), 1);
        assert_eq!(l_method(&[1.0], 2), 2); // largest-gap fallback
        let k = l_method(&[0.1, 0.2, 5.0], 4);
        assert!(k >= 1 && k <= 4);
    }

    #[test]
    fn flat_heights_give_small_k() {
        // No structure at all: knee lands at the left edge.
        let heights = vec![1.0f32; 59];
        let k = l_method(&heights, 60);
        assert!(k <= 5, "flat graph should give small k, got {k}");
    }

    #[test]
    fn knee_weights_follow_salvador_chan() {
        // Fixture where the documented (c−1)/(b−1) weighting and the
        // old c/b weighting disagree.  b = 5 points, candidates c ∈
        // {2, 3}; two-point fits are exact (RMSE 0) and a three-point
        // fit over equally spaced xs has RMSE |y0 − 2y1 + y2| / (3√2):
        //   ys = [1.2, 0, 0, 0, 1]:
        //     c=2: left RMSE 0,          right RMSE 1.0/(3√2)
        //     c=3: left RMSE 1.2/(3√2),  right RMSE 0
        //   correct weights:  W(2) = (3/4)·R ≈ 0.177 > W(3) = (2/4)·L ≈ 0.141
        //   old weights:      W(2) = (3/5)·R ≈ 0.141 < W(3) = (3/5)·L ≈ 0.170
        // so the documented formula picks c = 3 (knee x = xs[2] = 4)
        // where the old weighting picked c = 2 (knee x = 3).
        let xs = [2.0, 3.0, 4.0, 5.0, 6.0];
        let ys = [1.2, 0.0, 0.0, 0.0, 1.0];
        assert_eq!(knee_once(&xs, &ys), 4);

        // Cross-check the fixture's premise with the building blocks.
        let r3 = line_rmse(&xs[2..], &ys[2..]);
        let l3 = line_rmse(&xs[..3], &ys[..3]);
        assert!((r3 - 1.0 / (3.0 * 2f64.sqrt())).abs() < 1e-12);
        assert!((l3 - 1.2 / (3.0 * 2f64.sqrt())).abs() < 1e-12);
        // Documented weighting prefers c=3; the old one preferred c=2.
        assert!(0.5 * l3 < 0.75 * r3);
        assert!(0.6 * l3 > 0.6 * r3);
    }

    #[test]
    fn line_rmse_exact_fit_is_zero() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x - 1.0).collect();
        assert!(line_rmse(&xs, &ys) < 1e-12);
    }

    #[test]
    fn result_clamped_to_valid_range() {
        let heights: Vec<f32> = (0..99).map(|i| i as f32).collect();
        let k = l_method(&heights, 100);
        assert!((2..100).contains(&k));
    }
}
