//! Dendrogram: the binary merge tree AHC produces, with cut extraction.

/// One agglomeration step: clusters containing objects `a` and `b`
/// merged at `height` into a cluster of `size` objects.
#[derive(Debug, Clone)]
pub struct Merge {
    pub a: usize,
    pub b: usize,
    pub height: f32,
    pub size: usize,
}

/// The full merge sequence over `n` leaves, stored in non-decreasing
/// height order (heights are the "evaluation graph" the L-method reads).
#[derive(Debug, Clone)]
pub struct Dendrogram {
    n: usize,
    merges: Vec<Merge>,
}

/// Union-find with path halving.
struct Dsu {
    parent: Vec<usize>,
}

impl Dsu {
    fn new(n: usize) -> Self {
        Dsu {
            parent: (0..n).collect(),
        }
    }

    fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] != x {
            self.parent[x] = self.parent[self.parent[x]];
            x = self.parent[x];
        }
        x
    }

    fn union(&mut self, a: usize, b: usize) -> usize {
        let (ra, rb) = (self.find(a), self.find(b));
        // Keep the smaller root as representative (deterministic).
        let (keep, drop) = if ra < rb { (ra, rb) } else { (rb, ra) };
        self.parent[drop] = keep;
        keep
    }
}

impl Dendrogram {
    pub fn new(n: usize, merges: Vec<Merge>) -> Self {
        Dendrogram { n, merges }
    }

    /// Build from raw NN-chain output: (surviving index, absorbed index,
    /// height) triples in *chain emission order* (possibly height-
    /// unsorted).  Sorting by height and re-resolving representatives
    /// with union-find yields the canonical merge sequence (reducible
    /// linkages guarantee this is consistent).
    pub fn from_raw_merges(n: usize, mut raw: Vec<(usize, usize, f32)>) -> Self {
        raw.sort_by(|x, y| x.2.total_cmp(&y.2));
        let mut dsu = Dsu::new(n);
        let mut sizes = vec![1usize; n];
        let merges = raw
            .into_iter()
            .map(|(a, b, h)| {
                let (ra, rb) = (dsu.find(a), dsu.find(b));
                debug_assert_ne!(ra, rb, "merge joins an already-joined pair");
                let size = sizes[ra] + sizes[rb];
                let keep = dsu.union(ra, rb);
                sizes[keep] = size;
                Merge {
                    a: ra,
                    b: rb,
                    height: h,
                    size,
                }
            })
            .collect();
        Dendrogram { n, merges }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn merges(&self) -> &[Merge] {
        &self.merges
    }

    /// Merge heights in stored (non-decreasing) order.
    pub fn merge_heights(&self) -> Vec<f32> {
        self.merges.iter().map(|m| m.height).collect()
    }

    /// Cut into `k` clusters: apply the first n−k merges, label the
    /// resulting components 0..k densely (in order of first appearance).
    pub fn cut(&self, k: usize) -> Vec<usize> {
        let k = k.clamp(1, self.n.max(1));
        let mut dsu = Dsu::new(self.n);
        for m in self.merges.iter().take(self.n - k) {
            dsu.union(m.a, m.b);
        }
        // Dense root→label table indexed by object id: first-appearance
        // order is a structural property of the scan (no hash involved),
        // so labels are reproducible by construction.
        let mut label_of_root = vec![usize::MAX; self.n];
        let mut next = 0usize;
        let mut labels = Vec::with_capacity(self.n);
        for i in 0..self.n {
            let r = dsu.find(i);
            if label_of_root[r] == usize::MAX {
                label_of_root[r] = next;
                next += 1;
            }
            labels.push(label_of_root[r]);
        }
        debug_assert_eq!(next, k.min(self.n));
        labels
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain_dendro() -> Dendrogram {
        // 4 leaves: (0,1)@1, (2,3)@2, ((01),(23))@5
        Dendrogram::from_raw_merges(4, vec![(0, 1, 1.0), (2, 3, 2.0), (0, 2, 5.0)])
    }

    #[test]
    fn heights_sorted_and_sizes_tracked() {
        let d = chain_dendro();
        assert_eq!(d.merge_heights(), vec![1.0, 2.0, 5.0]);
        assert_eq!(d.merges()[2].size, 4);
    }

    #[test]
    fn cuts_at_every_k() {
        let d = chain_dendro();
        assert_eq!(d.cut(1), vec![0, 0, 0, 0]);
        let c2 = d.cut(2);
        assert_eq!(c2[0], c2[1]);
        assert_eq!(c2[2], c2[3]);
        assert_ne!(c2[0], c2[2]);
        let c4 = d.cut(4);
        assert_eq!(c4, vec![0, 1, 2, 3]);
    }

    #[test]
    fn unsorted_raw_merges_are_canonicalised() {
        // Same tree, emitted out of height order (as NN-chain may).
        let d = Dendrogram::from_raw_merges(4, vec![(2, 3, 2.0), (0, 1, 1.0), (0, 2, 5.0)]);
        assert_eq!(d.merge_heights(), vec![1.0, 2.0, 5.0]);
        let c2 = d.cut(2);
        assert_eq!(c2[0], c2[1]);
        assert_eq!(c2[2], c2[3]);
    }

    #[test]
    fn representative_indices_resolve_through_unions() {
        // Merge (0,1) then raw says (1, 2): 1's root is 0 by then.
        let d = Dendrogram::from_raw_merges(3, vec![(0, 1, 1.0), (1, 2, 2.0)]);
        assert_eq!(d.merges()[1].size, 3);
        assert_eq!(d.cut(1), vec![0, 0, 0]);
    }

    #[test]
    fn cut_clamps_k() {
        let d = chain_dendro();
        assert_eq!(d.cut(0), vec![0, 0, 0, 0]); // clamped to 1
        assert_eq!(d.cut(99), vec![0, 1, 2, 3]); // clamped to n
    }

    #[test]
    fn labels_dense_and_stable() {
        let d = chain_dendro();
        let c3 = d.cut(3);
        let max = *c3.iter().max().unwrap();
        assert_eq!(max, 2);
        // First appearance order: object 0 gets label 0.
        assert_eq!(c3[0], 0);
    }
}
