//! `figures` — regenerate every table and figure of the paper.
//!
//! ```text
//! figures <table1|fig1|fig3|fig4|fig5|fig6|fig7|fig8|fig9|fig10|fig11|all>
//!         [--scale F] [--seed N] [--threads N] [--iters N] [--out DIR]
//! ```
//!
//! Series are printed to stdout and written as CSV under `--out`
//! (default `results/`).  See DESIGN.md §3 for the experiment index and
//! expected curve shapes.

use mahc::figures::{self, ExpCtx};
use mahc::util::cli::Args;

const VALUE_KEYS: &[&str] = &["scale", "seed", "threads", "iters", "out"];

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env(VALUE_KEYS)?;
    let mut ctx = ExpCtx::default();
    ctx.scale = args.get_parsed::<f64>("scale")?;
    ctx.seed = args.get_or("seed", ctx.seed)?;
    ctx.threads = args.get_or("threads", ctx.threads)?;
    ctx.iters = args.get_or("iters", ctx.iters)?;
    if let Some(out) = args.get("out") {
        ctx.outdir = out.into();
    }

    match args.subcommand() {
        Some("table1") => figures::table1(&ctx),
        Some("fig1") => figures::fig1(&ctx),
        Some("fig3") => figures::fig3(&ctx),
        Some("fig4") => figures::fig4(&ctx),
        Some("fig5") => figures::fig5(&ctx),
        Some("fig6") => figures::fig6(&ctx),
        Some("fig7") => figures::fig7(&ctx),
        Some("fig8") => figures::fig8(&ctx),
        Some("fig9") => figures::fig9(&ctx),
        Some("fig10") => figures::fig10(&ctx),
        Some("fig11") => figures::fig11(&ctx),
        Some("ablation") => figures::ablation(&ctx),
        Some("all") => figures::all(&ctx),
        other => {
            anyhow::bail!(
                "usage: figures <table1|fig1|fig3..fig11|all> [--scale F] [--seed N] \
                 [--threads N] [--iters N] [--out DIR] (got {other:?})"
            )
        }
    }
}
