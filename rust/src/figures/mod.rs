//! Figure/table regeneration harness — one function per paper artefact.
//!
//! Each `figN` function runs the experiment behind the corresponding
//! figure of the paper (see DESIGN.md §3 for the index), prints the
//! series the paper plots, and writes a CSV under `results/`.  Scales
//! default to fractions of the paper's dataset sizes that run in
//! minutes on a workstation (the *shape* of each curve is the
//! reproduction target — see DESIGN.md §5); `--scale` overrides.
//!
//! The functions are library code (not buried in the binary) so the
//! test suite can exercise them at tiny scale.

use std::path::PathBuf;

use crate::baselines::full_ahc;
use crate::config::{AlgoConfig, Convergence, DatasetSpec, NamedDataset};
use crate::corpus::{generate, CompositionStats, SegmentSet};
use crate::distance::{PairwiseBackend, NativeBackend};
use crate::mahc::MahcDriver;
use crate::util::csv::CsvWriter;

/// Shared experiment context.
pub struct ExpCtx {
    /// Scale override (None = per-figure default).
    pub scale: Option<f64>,
    pub seed: u64,
    pub threads: usize,
    pub outdir: PathBuf,
    /// Iterations for fixed-iteration runs.
    pub iters: usize,
}

impl Default for ExpCtx {
    fn default() -> Self {
        ExpCtx {
            scale: None,
            seed: 1234,
            threads: crate::util::pool::default_threads(),
            outdir: PathBuf::from("results"),
            iters: 8,
        }
    }
}

impl ExpCtx {
    fn scale_or(&self, default: f64) -> f64 {
        self.scale.unwrap_or(default)
    }

    fn gen(&self, which: NamedDataset, default_scale: f64) -> SegmentSet {
        let spec = DatasetSpec::named(which, self.scale_or(default_scale));
        generate(&spec)
    }

    fn algo(&self, p0: usize, beta: Option<usize>, iters: usize) -> AlgoConfig {
        AlgoConfig {
            p0,
            beta,
            convergence: Convergence::FixedIters(iters),
            threads: self.threads,
            seed: self.seed,
            ..Default::default()
        }
    }

    fn write(&self, name: &str, csv: &CsvWriter) -> anyhow::Result<()> {
        let path = self.outdir.join(format!("{name}.csv"));
        csv.write_to(&path)?;
        eprintln!("wrote {} ({} rows)", path.display(), csv.num_rows());
        Ok(())
    }
}

/// β used throughout the figures: 1.25 × the even-partition size, the
/// "slightly above N/P" placement visible in the paper's Fig. 7.
pub fn default_beta(n: usize, p0: usize) -> usize {
    ((n as f64 / p0 as f64) * 1.25).ceil() as usize
}

fn run(
    set: &SegmentSet,
    cfg: AlgoConfig,
    backend: &dyn PairwiseBackend,
) -> anyhow::Result<crate::mahc::MahcResult> {
    MahcDriver::new(set, cfg, backend)?.run()
}

// ---------------------------------------------------------------------
// Table 1 — dataset composition
// ---------------------------------------------------------------------

pub fn table1(ctx: &ExpCtx) -> anyhow::Result<()> {
    println!("Table 1: composition of experimental data (scaled)");
    println!(
        "{:<12} {:>9} {:>8} {:>13} {:>10} {:>14}",
        "Dataset", "Segments", "Classes", "Frequency", "Vectors", "Similarities"
    );
    let mut csv = CsvWriter::new(&[
        "dataset", "segments", "classes", "freq_min", "freq_max", "vectors", "similarities",
    ]);
    for which in NamedDataset::all() {
        let set = ctx.gen(which, 0.1);
        let st = CompositionStats::of(&set);
        println!("{}", st.table_row());
        csv.rowf(&[
            &st.name,
            &st.segments,
            &st.classes,
            &st.freq_range.0,
            &st.freq_range.1,
            &st.vectors,
            &st.similarities,
        ]);
    }
    ctx.write("table1", &csv)
}

// ---------------------------------------------------------------------
// Fig. 1 — largest-subset growth under plain MAHC
// ---------------------------------------------------------------------

pub fn fig1(ctx: &ExpCtx) -> anyhow::Result<()> {
    println!("Fig. 1: max subset occupancy per iteration, plain MAHC (β=∞)");
    let cases = [
        (NamedDataset::SmallA, 4usize, 0.1),
        (NamedDataset::SmallB, 4, 0.1),
        (NamedDataset::Medium, 6, 0.05),
        (NamedDataset::Large, 8, 0.03),
    ];
    let backend = NativeBackend::new();
    let mut csv = CsvWriter::new(&["dataset", "p0", "iteration", "max_occupancy", "even_share"]);
    for (which, p0, scale) in cases {
        let set = ctx.gen(which, scale);
        let res = run(&set, ctx.algo(p0, None, 6.min(ctx.iters)), &backend)?;
        let even = set.len() / p0;
        let series = res.history.max_occupancy_series();
        println!(
            "  {:<8} P={p0} N={} even={} -> {:?}",
            set.name,
            set.len(),
            even,
            series
        );
        for r in &res.history.records {
            csv.rowf(&[&set.name, &p0, &r.iteration, &r.max_occupancy, &even]);
        }
    }
    ctx.write("fig1", &csv)
}

// ---------------------------------------------------------------------
// Fig. 3 — class cardinality distributions, Small A vs Small B
// ---------------------------------------------------------------------

pub fn fig3(ctx: &ExpCtx) -> anyhow::Result<()> {
    println!("Fig. 3: segments-per-class distribution, Small A vs Small B");
    let mut csv = CsvWriter::new(&["dataset", "class_rank", "class_size"]);
    for which in [NamedDataset::SmallA, NamedDataset::SmallB] {
        let set = ctx.gen(which, 0.1);
        let st = CompositionStats::of(&set);
        println!(
            "  {:<8}: classes={} sizes(max..min)={}..{}",
            st.name,
            st.classes,
            st.class_sizes.first().unwrap_or(&0),
            st.class_sizes.last().unwrap_or(&0),
        );
        for (rank, &size) in st.class_sizes.iter().enumerate() {
            csv.rowf(&[&st.name, &rank, &size]);
        }
    }
    ctx.write("fig3", &csv)
}

// ---------------------------------------------------------------------
// Figs. 4 & 5 — Pᵢ and F per iteration: AHC vs MAHC vs MAHC+M
// ---------------------------------------------------------------------

fn fig_small(ctx: &ExpCtx, which: NamedDataset, figname: &str) -> anyhow::Result<()> {
    let set = ctx.gen(which, 0.1);
    let backend = NativeBackend::new();
    println!(
        "{figname}: {} (N={}), AHC vs MAHC vs MAHC+M, P0 ∈ {{2, 6}}",
        set.name,
        set.len()
    );

    let ahc = full_ahc(&set, &backend, ctx.threads, None, 0.25)?;
    println!("  AHC baseline: K={} F={:.4}", ahc.k, ahc.f_measure);

    let mut csv = CsvWriter::new(&["algo", "p0", "iteration", "subsets", "f_measure"]);
    csv.rowf(&[&"ahc", &0, &0, &1, &ahc.f_measure]);
    for p0 in [2usize, 6] {
        for (algo, beta) in [
            ("mahc", None),
            ("mahc+m", Some(default_beta(set.len(), p0))),
        ] {
            let res = run(&set, ctx.algo(p0, beta, ctx.iters), &backend)?;
            println!(
                "  {algo:<7} P0={p0}: P_i={:?} F={:?}",
                res.history.subsets_series(),
                res.history
                    .f_series()
                    .iter()
                    .map(|f| (f * 1000.0).round() / 1000.0)
                    .collect::<Vec<_>>()
            );
            for r in &res.history.records {
                csv.rowf(&[&algo, &p0, &r.iteration, &r.subsets, &r.f_measure]);
            }
        }
    }
    ctx.write(figname, &csv)
}

pub fn fig4(ctx: &ExpCtx) -> anyhow::Result<()> {
    fig_small(ctx, NamedDataset::SmallA, "fig4")
}

pub fn fig5(ctx: &ExpCtx) -> anyhow::Result<()> {
    fig_small(ctx, NamedDataset::SmallB, "fig5")
}

// ---------------------------------------------------------------------
// Fig. 6 — per-iteration wall-clock, MAHC vs MAHC+M, P0 = 6
// ---------------------------------------------------------------------

pub fn fig6(ctx: &ExpCtx) -> anyhow::Result<()> {
    println!("Fig. 6: per-iteration execution time, MAHC vs MAHC+M (P0=6)");
    let backend = NativeBackend::new();
    let mut csv = CsvWriter::new(&["dataset", "algo", "iteration", "wall_secs"]);
    for which in [NamedDataset::SmallA, NamedDataset::SmallB] {
        let set = ctx.gen(which, 0.1);
        for (algo, beta) in [
            ("mahc", None),
            ("mahc+m", Some(default_beta(set.len(), 6))),
        ] {
            let res = run(&set, ctx.algo(6, beta, ctx.iters.min(6)), &backend)?;
            let walls = res.history.wall_series();
            println!("  {:<8} {algo:<7}: {:?}", set.name, walls);
            for r in &res.history.records {
                csv.rowf(&[&set.name, &algo, &r.iteration, &r.wall.as_secs_f64()]);
            }
        }
    }
    ctx.write("fig6", &csv)
}

// ---------------------------------------------------------------------
// Fig. 7 — Medium set: Pᵢ, occupancy (split/refine visible), F
// ---------------------------------------------------------------------

pub fn fig7(ctx: &ExpCtx) -> anyhow::Result<()> {
    println!("Fig. 7: Medium set, P0 ∈ {{6, 10}} — P_i, max occupancy vs β, F");
    let set = ctx.gen(NamedDataset::Medium, 0.05);
    let backend = NativeBackend::new();
    let ahc = full_ahc(&set, &backend, ctx.threads, None, 0.25)?;
    println!("  AHC baseline: K={} F={:.4}", ahc.k, ahc.f_measure);
    let mut csv = CsvWriter::new(&[
        "algo",
        "p0",
        "beta",
        "iteration",
        "subsets",
        "max_occ_pre_split",
        "max_occupancy",
        "splits",
        "f_measure",
    ]);
    csv.rowf(&[&"ahc", &0, &0, &0, &1, &0, &0, &0, &ahc.f_measure]);
    for p0 in [6usize, 10] {
        let beta = default_beta(set.len(), p0);
        for (algo, b) in [("mahc", None), ("mahc+m", Some(beta))] {
            let res = run(&set, ctx.algo(p0, b, ctx.iters), &backend)?;
            println!(
                "  {algo:<7} P0={p0} β={beta}: pre-split={:?} post={:?} F_last={:.4}",
                res.history
                    .records
                    .iter()
                    .map(|r| r.max_occupancy_pre_split)
                    .collect::<Vec<_>>(),
                res.history.max_occupancy_series(),
                res.history.f_series().last().unwrap_or(&0.0)
            );
            for r in &res.history.records {
                csv.rowf(&[
                    &algo,
                    &p0,
                    &beta,
                    &r.iteration,
                    &r.subsets,
                    &r.max_occupancy_pre_split,
                    &r.max_occupancy,
                    &r.splits,
                    &r.f_measure,
                ]);
            }
        }
    }
    ctx.write("fig7", &csv)
}

// ---------------------------------------------------------------------
// Figs. 8-10 — Large set: Pᵢ and F for several P₀
// ---------------------------------------------------------------------

fn fig_large(ctx: &ExpCtx, p0s: &[usize], figname: &str) -> anyhow::Result<()> {
    println!("{figname}: Large set, P0 ∈ {p0s:?} — P_i and F per iteration");
    let set = ctx.gen(NamedDataset::Large, 0.03);
    let backend = NativeBackend::new();
    let mut csv = CsvWriter::new(&[
        "algo", "p0", "iteration", "subsets", "max_occupancy", "f_measure",
    ]);
    for &p0 in p0s {
        let beta = default_beta(set.len(), p0);
        for (algo, b) in [("mahc", None), ("mahc+m", Some(beta))] {
            let res = run(&set, ctx.algo(p0, b, ctx.iters), &backend)?;
            println!(
                "  {algo:<7} P0={p0}: P_i={:?} F_last={:.4}",
                res.history.subsets_series(),
                res.history.f_series().last().unwrap_or(&0.0)
            );
            for r in &res.history.records {
                csv.rowf(&[
                    &algo,
                    &p0,
                    &r.iteration,
                    &r.subsets,
                    &r.max_occupancy,
                    &r.f_measure,
                ]);
            }
        }
    }
    ctx.write(figname, &csv)
}

pub fn fig8(ctx: &ExpCtx) -> anyhow::Result<()> {
    fig_large(ctx, &[8, 10], "fig8")
}

pub fn fig9(ctx: &ExpCtx) -> anyhow::Result<()> {
    fig_large(ctx, &[15], "fig9")
}

pub fn fig10(ctx: &ExpCtx) -> anyhow::Result<()> {
    // Pᵢ trajectories overlaid for several P₀ (MAHC+M only).
    println!("fig10: Large set, P_i trajectories for P0 ∈ {{8, 10, 15}} (MAHC+M)");
    let set = ctx.gen(NamedDataset::Large, 0.03);
    let backend = NativeBackend::new();
    let mut csv = CsvWriter::new(&["p0", "iteration", "subsets"]);
    for p0 in [8usize, 10, 15] {
        let beta = default_beta(set.len(), p0);
        let res = run(&set, ctx.algo(p0, Some(beta), ctx.iters), &backend)?;
        println!("  P0={p0}: {:?}", res.history.subsets_series());
        for r in &res.history.records {
            csv.rowf(&[&p0, &r.iteration, &r.subsets]);
        }
    }
    ctx.write("fig10", &csv)
}

// ---------------------------------------------------------------------
// Fig. 11 — minimum occupancy per iteration (merge ablation)
// ---------------------------------------------------------------------

pub fn fig11(ctx: &ExpCtx) -> anyhow::Result<()> {
    println!("Fig. 11: min subset occupancy per iteration (is a merge step needed?)");
    let backend = NativeBackend::new();
    let mut csv = CsvWriter::new(&["dataset", "p0", "iteration", "min_occupancy"]);
    for (which, p0, scale) in [
        (NamedDataset::Medium, 6usize, 0.05),
        (NamedDataset::Large, 8, 0.03),
    ] {
        let set = ctx.gen(which, scale);
        let beta = default_beta(set.len(), p0);
        let res = run(&set, ctx.algo(p0, Some(beta), ctx.iters), &backend)?;
        let series = res.history.min_occupancy_series();
        println!("  {:<8} P0={p0}: {:?}", set.name, series);
        assert!(
            series.iter().all(|&m| m > 0),
            "paper claim: minimum occupancy never vanishes"
        );
        for r in &res.history.records {
            csv.rowf(&[&set.name, &p0, &r.iteration, &r.min_occupancy]);
        }
    }
    ctx.write("fig11", &csv)
}

// ---------------------------------------------------------------------
// Ablations — design choices DESIGN.md calls out
// ---------------------------------------------------------------------

/// Ablation study over the design choices around the split step:
///
/// * split granularity — contiguous (cluster-preserving) vs shuffled
///   pieces;
/// * the merge step the paper rejects (re-absorb subsets < β/10);
/// * plain MAHC and full AHC as anchors.
pub fn ablation(ctx: &ExpCtx) -> anyhow::Result<()> {
    println!("ablation: split granularity / merge step, Small A");
    let set = ctx.gen(NamedDataset::SmallA, 0.1);
    let backend = NativeBackend::new();
    let p0 = 6;
    let beta = default_beta(set.len(), p0);
    let mut csv = CsvWriter::new(&["variant", "final_f", "final_k", "peak_occ", "peak_bytes"]);

    let mut run_variant = |name: &str,
                           beta: Option<usize>,
                           shuffle: bool,
                           merge: Option<usize>|
     -> anyhow::Result<()> {
        let mut cfg = ctx.algo(p0, beta, ctx.iters.min(6));
        cfg.split_shuffle = shuffle;
        cfg.merge_min = merge;
        let res = run(&set, cfg, &backend)?;
        let peak_occ = res
            .history
            .records
            .iter()
            .map(|r| r.max_occupancy)
            .max()
            .unwrap_or(0);
        println!(
            "  {name:<22} F={:.4} K={} peak_occ={} peak_mem={:.2} MiB",
            res.f_measure,
            res.k,
            peak_occ,
            res.history.peak_matrix_bytes() as f64 / (1 << 20) as f64
        );
        csv.rowf(&[
            &name,
            &res.f_measure,
            &res.k,
            &peak_occ,
            &res.history.peak_matrix_bytes(),
        ]);
        Ok(())
    };

    run_variant("mahc (no management)", None, false, None)?;
    run_variant("mahc+m contiguous", Some(beta), false, None)?;
    run_variant("mahc+m shuffled", Some(beta), true, None)?;
    run_variant("mahc+m + merge", Some(beta), false, Some(beta / 10))?;
    let ahc = full_ahc(&set, &backend, ctx.threads, None, 0.25)?;
    println!("  {:<22} F={:.4} K={}", "full ahc", ahc.f_measure, ahc.k);
    csv.rowf(&[
        &"full ahc",
        &ahc.f_measure,
        &ahc.k,
        &set.len(),
        &ahc.matrix_bytes,
    ]);
    ctx.write("ablation", &csv)
}

/// Run every table/figure in sequence.
pub fn all(ctx: &ExpCtx) -> anyhow::Result<()> {
    table1(ctx)?;
    fig1(ctx)?;
    fig3(ctx)?;
    fig4(ctx)?;
    fig5(ctx)?;
    fig6(ctx)?;
    fig7(ctx)?;
    fig8(ctx)?;
    fig9(ctx)?;
    fig10(ctx)?;
    fig11(ctx)?;
    ablation(ctx)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_ctx(dir: &str) -> ExpCtx {
        ExpCtx {
            scale: Some(0.004),
            seed: 1,
            threads: 4,
            outdir: std::env::temp_dir().join(dir),
            iters: 2,
        }
    }

    #[test]
    fn table1_and_fig3_run_at_tiny_scale() {
        let ctx = tiny_ctx("mahc_fig_t1");
        table1(&ctx).unwrap();
        fig3(&ctx).unwrap();
        assert!(ctx.outdir.join("table1.csv").exists());
        assert!(ctx.outdir.join("fig3.csv").exists());
    }

    #[test]
    fn fig1_runs_at_tiny_scale() {
        let ctx = tiny_ctx("mahc_fig_f1");
        fig1(&ctx).unwrap();
        let text = std::fs::read_to_string(ctx.outdir.join("fig1.csv")).unwrap();
        assert!(text.lines().count() > 4);
    }

    #[test]
    fn default_beta_above_even_share() {
        assert!(default_beta(1000, 4) > 250);
        assert_eq!(default_beta(1000, 4), 313);
    }
}
