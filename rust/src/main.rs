//! `mahc` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!
//! * `cluster` — run MAHC+M (or plain MAHC / full AHC) on one of the
//!   paper's dataset compositions, print per-iteration telemetry and
//!   the final F-measure, optionally dump run JSON.
//! * `stream` — shard-at-a-time MAHC: consume the corpus as a stream of
//!   `--shard-size` batches, carrying medoids forward under the β
//!   bound; prints per-shard telemetry.
//! * `serve` — concurrent multi-stream mode: `--sessions` streaming
//!   sessions interleaved over one worker pool (and optionally one
//!   shared pair cache), with admission control and per-session
//!   budgets; prints per-session outcomes and fleet telemetry.
//! * `datagen` — generate a dataset and print its Table-1 composition.
//! * `inspect` — validate the artifact manifest and report entries.
//!
//! Examples:
//!
//! ```text
//! mahc cluster --dataset small_a --scale 0.05 --p0 6 --beta 200 --iters 5
//! mahc cluster --dataset small_a --scale 0.05 --aggregate-eps 12.5 --aggregate-cap 64
//! mahc cluster --dataset small_b --scale 0.05 --algo ahc
//! mahc stream --dataset small_a --scale 0.05 --shard-size 300 --beta 150 --cache-mb 64
//! mahc serve --dataset small_a --scale 0.05 --sessions 6 --fleet-cap 4 --fleet-cache-mb 64
//! mahc datagen --dataset medium --scale 0.1
//! mahc inspect --artifacts artifacts
//! ```

use std::sync::Arc;

use mahc::baselines;
use mahc::config::{
    apply_overrides, AlgoConfig, Convergence, DatasetSpec, DeviationMode, FinalK, NamedDataset,
    PruneMode, RetireMode, ServeConfig, StreamConfig,
};
use mahc::ahc::SelectionMethod;
use mahc::corpus::{
    diarization, generate, generate_embeddings, CompositionStats, DiarizationSpec, EmbeddingSpec,
};
use mahc::distance::{
    BackendKind, BlockedBackend, MetricKind, PairwiseBackend, NativeBackend, VectorBackend,
    VectorMetric,
};
use mahc::mahc::{MahcDriver, ServeDriver, SessionSpec, StreamingDriver};
use mahc::runtime::{Runtime, XlaDtwBackend};
use mahc::util::cli::Args;

const VALUE_KEYS: &[&str] = &[
    "dataset", "scale", "p0", "beta", "iters", "max-iters", "k", "seed", "threads", "backend",
    "algo", "artifacts", "out", "config", "merge-min", "cache-mb", "shard-size", "shard-seed",
    "aggregate-eps", "aggregate-cap", "aggregate-batch", "aggregate-tree", "aggregate-probe",
    "aggregate-quantile", "aggregate-sample", "aggregate-quantile-seed", "aggregate-depth",
    "sessions", "fleet-cap", "queue-cap", "workers", "fleet-cache-mb", "fault-session", "prune",
    "metric", "selection", "deviation", "retire",
];

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env(VALUE_KEYS)?;
    match args.subcommand() {
        Some("cluster") => cluster(&args),
        Some("stream") => stream(&args),
        Some("serve") => serve(&args),
        Some("datagen") => datagen(&args),
        Some("inspect") => inspect(&args),
        Some(other) => {
            anyhow::bail!("unknown subcommand '{other}' (cluster|stream|serve|datagen|inspect)")
        }
        None => {
            eprintln!("usage: mahc <cluster|stream|serve|datagen|inspect> [options]");
            eprintln!("  cluster --dataset <small_a|small_b|medium|large|embeddings|diarization>");
            eprintln!("          [--scale F] [--algo mahc+m|mahc|ahc] [--p0 N] [--beta N] [--iters N]");
            eprintln!("          [--backend native|blocked|xla] [--threads N] [--seed N] [--out FILE]");
            eprintln!("          [--metric dtw|cosine|euclidean  pairwise distance; the vector");
            eprintln!("                     metrics need a fixed-dim corpus (embeddings|diarization)]");
            eprintln!("          [--selection lmethod|silhouette  per-subset cluster-count choice]");
            eprintln!("          [--cache-mb N   cross-iteration DTW pair cache budget]");
            eprintln!("          [--prune off|on|debug  lower-bound cascade for threshold queries");
            eprintln!("                     (off = exact oracle; debug verifies admissibility)]");
            eprintln!("          [--aggregate-eps F  stage-0 leader radius (0 = off)]");
            eprintln!("          [--aggregate-cap N  stage-0 per-group occupancy cap]");
            eprintln!("          [--aggregate-quantile Q  derive the radius from the pair-distance");
            eprintln!("                     quantile Q in (0,1) of a seeded corpus sample]");
            eprintln!("          [--aggregate-sample N  segments sampled for the quantile estimate]");
            eprintln!("          [--aggregate-quantile-seed N  seed of the quantile sampler]");
            eprintln!("          [--aggregate-batch N  segments probed per rectangle round (1 = serial)]");
            eprintln!("          [--aggregate-tree K  leader tree, per-level radius factor K (0 = flat)]");
            eprintln!("          [--aggregate-depth D  leader-tree levels (1 = flat, 2 = classic tree)]");
            eprintln!("          [--aggregate-probe N  nearest super-groups each segment descends into]");
            eprintln!("          [--deviation report|debug  report the stage-0 deviation bound, or");
            eprintln!("                     recluster the full corpus and verify it (debug, O(N^2))]");
            eprintln!("  stream  --dataset <name> [--scale F] --shard-size N [--shard-seed N]");
            eprintln!("          [--p0 N] [--beta N] [--iters N] [--backend native|blocked|xla]");
            eprintln!("          [--cache-mb N] [--aggregate-eps F] [--aggregate-cap N] [--out FILE]");
            eprintln!("          [--aggregate-quantile Q] [--aggregate-sample N] [--aggregate-batch N]");
            eprintln!("          [--aggregate-tree K] [--aggregate-depth D] [--aggregate-probe N]");
            eprintln!("          [--prune off|on|debug] [--deviation report|debug]");
            eprintln!("          [--retire leader|medoid  aggregated members inherit their leader's");
            eprintln!("                     label (bitwise oracle) or re-home to the nearest final medoid]");
            eprintln!("  serve   --dataset <name> [--scale F] [--sessions N   concurrent streams]");
            eprintln!("          [--fleet-cap N    max concurrently-active sessions]");
            eprintln!("          [--queue-cap N    sessions allowed to wait behind the cap]");
            eprintln!("          [--workers N      shared pool size]");
            eprintln!("          [--fleet-cache-mb N  shared pair cache (0 = private caches)]");
            eprintln!("          [--cache-mb N     per-session residency budget in the fleet cache]");
            eprintln!("          [--fault-session I  inject a panic into session I (robustness demo)]");
            eprintln!("          [--shard-size N] [--p0 N] [--beta N] [--iters N] [--out FILE]");
            eprintln!("          [--backend native|blocked   (xla holds host handles; rejected)]");
            eprintln!("  datagen --dataset <name> [--scale F]");
            eprintln!("  inspect [--artifacts DIR]");
            Ok(())
        }
    }
}

/// Generate the corpus named by `--dataset`: one of the paper's
/// triphone compositions, or a fixed-dim embedding corpus
/// (`embeddings` | `diarization`) for the vector metrics.  `--scale`
/// scales the embedding corpora off a nominal 2000-segment session.
fn corpus_from(args: &Args) -> anyhow::Result<mahc::corpus::SegmentSet> {
    let name = args.get("dataset").unwrap_or("small_a");
    let scale: f64 = args.get_or("scale", 0.05)?;
    let seed: u64 = args.get_or("seed", AlgoConfig::default().seed)?;
    match name {
        "embeddings" | "embedding" => {
            let segments = ((2000.0 * scale).round() as usize).max(40);
            let classes = (segments / 12).clamp(4, 32);
            let mut spec = EmbeddingSpec::tiny(segments, classes, seed);
            spec.name = format!("embeddings_{segments}x{classes}");
            Ok(generate_embeddings(&spec))
        }
        "diarization" => {
            let utterances = ((2000.0 * scale).round() as usize).max(40);
            Ok(diarization(&DiarizationSpec::tiny(utterances, 8, seed)))
        }
        _ => {
            let spec = DatasetSpec::named(NamedDataset::parse(name)?, scale);
            Ok(generate(&spec))
        }
    }
}

/// The [`VectorMetric`] a non-DTW [`MetricKind`] instantiates
/// (config validation has already rejected DTW-only combinations).
fn vector_metric(kind: MetricKind) -> VectorMetric {
    match kind {
        MetricKind::Cosine => VectorMetric::Cosine,
        MetricKind::Euclidean => VectorMetric::Euclidean,
        MetricKind::Dtw => unreachable!("vector_metric is never asked for dtw"),
    }
}

fn algo_config_from(args: &Args) -> anyhow::Result<AlgoConfig> {
    let mut cfg = AlgoConfig::default();
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)?;
        let kv = mahc::config::parse_kv(&text)?;
        apply_overrides(&mut cfg, &kv)?;
    }
    cfg.p0 = args.get_or("p0", cfg.p0)?;
    if let Some(beta) = args.get_parsed::<usize>("beta")? {
        cfg.beta = Some(beta);
    }
    if let Some(iters) = args.get_parsed::<usize>("iters")? {
        cfg.convergence = Convergence::FixedIters(iters);
    }
    if let Some(max) = args.get_parsed::<usize>("max-iters")? {
        cfg.convergence = Convergence::SettledSubsets { max_iters: max };
    }
    if let Some(k) = args.get_parsed::<usize>("k")? {
        cfg.final_k = FinalK::Fixed(k);
    }
    if let Some(m) = args.get_parsed::<usize>("merge-min")? {
        cfg.merge_min = Some(m);
    }
    if let Some(mb) = args.get_parsed::<usize>("cache-mb")? {
        cfg.cache_bytes = mb << 20;
    }
    if let Some(p) = args.get("prune") {
        cfg.prune = PruneMode::parse(p)?;
    }
    if let Some(eps) = args.get_parsed::<f32>("aggregate-eps")? {
        cfg.aggregate.epsilon = eps;
    }
    if let Some(cap) = args.get_parsed::<usize>("aggregate-cap")? {
        cfg.aggregate.cap = Some(cap);
    }
    if let Some(q) = args.get_parsed::<f64>("aggregate-quantile")? {
        cfg.aggregate.quantile = Some(q);
    }
    if let Some(s) = args.get_parsed::<usize>("aggregate-sample")? {
        cfg.aggregate.quantile_sample = s;
    }
    if let Some(s) = args.get_parsed::<u64>("aggregate-quantile-seed")? {
        cfg.aggregate.quantile_seed = s;
    }
    if let Some(b) = args.get_parsed::<usize>("aggregate-batch")? {
        cfg.aggregate.batch_rows = b;
    }
    if let Some(k) = args.get_parsed::<f32>("aggregate-tree")? {
        cfg.aggregate.tree_factor = k;
    }
    if let Some(p) = args.get_parsed::<usize>("aggregate-probe")? {
        cfg.aggregate.tree_probe = p;
    }
    if let Some(d) = args.get_parsed::<usize>("aggregate-depth")? {
        cfg.aggregate.tree_depth = d;
    }
    if let Some(d) = args.get("deviation") {
        cfg.deviation = DeviationMode::parse(d)?;
    }
    if let Some(r) = args.get("retire") {
        cfg.retire = RetireMode::parse(r)?;
    }
    cfg.seed = args.get_or("seed", cfg.seed)?;
    cfg.threads = args.get_or("threads", cfg.threads)?;
    if let Some(b) = args.get("backend") {
        cfg.backend = BackendKind::parse(b)?;
    }
    if let Some(m) = args.get("metric") {
        cfg.metric = MetricKind::parse(m)?;
    }
    if let Some(s) = args.get("selection") {
        cfg.selection = SelectionMethod::parse(s)?;
    }
    // Surface incoherent combinations (vector metric + xla, active
    // prune on a bound-less metric) as typed config errors here,
    // before any backend or runtime is constructed.
    cfg.validate()?;
    Ok(cfg)
}

fn cluster(args: &Args) -> anyhow::Result<()> {
    let cfg = algo_config_from(args)?;
    let algo = args
        .get("algo")
        .unwrap_or(if cfg.beta.is_some() { "mahc+m" } else { "mahc" })
        .to_string();

    let set = corpus_from(args)?;
    eprintln!(
        "generated {} (N={}, classes={})",
        set.name,
        set.len(),
        set.num_classes
    );
    let stats = CompositionStats::of(&set);
    eprintln!("  composition: {}", stats.table_row());

    match cfg.metric {
        MetricKind::Dtw => match cfg.backend {
            BackendKind::Native => {
                let backend = NativeBackend::new();
                cluster_with(&set, cfg, &algo, &backend, args)
            }
            BackendKind::Blocked => {
                let backend = BlockedBackend::new();
                cluster_with(&set, cfg, &algo, &backend, args)
            }
            BackendKind::Xla => {
                let dir = args.get("artifacts").unwrap_or("artifacts");
                let rt = Runtime::new(std::path::Path::new(dir))?;
                let backend = XlaDtwBackend::new(&rt)?;
                cluster_with(&set, cfg, &algo, &backend, args)
            }
        },
        kind => match cfg.backend {
            BackendKind::Native => {
                let backend = VectorBackend::native(vector_metric(kind));
                cluster_with(&set, cfg, &algo, &backend, args)
            }
            BackendKind::Blocked => {
                let backend = VectorBackend::blocked(vector_metric(kind));
                cluster_with(&set, cfg, &algo, &backend, args)
            }
            // validate() already rejected this pairing with a typed
            // error; keep a defensive arm for direct callers.
            BackendKind::Xla => anyhow::bail!(
                "--backend xla computes DTW only; use --metric dtw or a cpu backend"
            ),
        },
    }
}

/// One-line cascade summary, printed only when a run actually routed
/// pair queries through the lower bound (`--prune on|debug`).
fn print_prune_summary(records: &[mahc::telemetry::IterationRecord]) {
    let lb_pairs: u64 = records.iter().map(|r| r.lb_pairs).sum();
    if lb_pairs == 0 {
        return;
    }
    let lb_pruned: u64 = records.iter().map(|r| r.lb_pruned).sum();
    let exact_pairs: u64 = records.iter().map(|r| r.exact_pairs).sum();
    println!(
        "pruning: {:.1}% of bounded pairs skipped the DP \
         ({lb_pairs} bounded, {lb_pruned} pruned, {exact_pairs} exact DP calls)",
        lb_pruned as f64 / lb_pairs as f64 * 100.0
    );
}

/// One-line model-selection summary, printed only when silhouette
/// selection actually scored the final evaluation cut.
fn print_selection_summary(records: &[mahc::telemetry::IterationRecord]) {
    let Some(last) = records.last() else { return };
    if last.silhouette_score != 0.0 {
        println!(
            "selection: silhouette scored the final cut at {:.4} (metric {})",
            last.silhouette_score, last.metric
        );
    }
}

fn cluster_with(
    set: &mahc::corpus::SegmentSet,
    cfg: AlgoConfig,
    algo: &str,
    backend: &dyn PairwiseBackend,
    args: &Args,
) -> anyhow::Result<()> {
    match algo {
        "ahc" => {
            let t0 = mahc::telemetry::Stopwatch::start();
            let out = baselines::full_ahc(set, backend, cfg.threads, None, cfg.max_clusters_frac)?;
            println!(
                "AHC: K={} F={:.4} matrix={:.1} MiB wall={:.2}s",
                out.k,
                out.f_measure,
                out.matrix_bytes as f64 / (1 << 20) as f64,
                t0.elapsed().as_secs_f64()
            );
        }
        "mahc" | "mahc+m" => {
            let mut cfg = cfg;
            let cache_on = cfg.cache_bytes > 0;
            if algo == "mahc" {
                cfg.beta = None;
            } else if cfg.beta.is_none() {
                // Default β: twice the even-partition size — the shape
                // the paper's memory-budget argument suggests.
                cfg.beta = Some((2 * set.len() / cfg.p0.max(1)).max(8));
            }
            let driver = MahcDriver::new(set, cfg, backend)?;
            let res = driver.run()?;
            println!(
                "iter  P_i   maxOcc minOcc preOcc splits   K_tot   F       wall_s   pairs/s"
            );
            for r in &res.history.records {
                println!(
                    "{:>4} {:>4} {:>8} {:>6} {:>6} {:>6} {:>7} {:.4} {:>8.2} {:>9.0}",
                    r.iteration,
                    r.subsets,
                    r.max_occupancy,
                    r.min_occupancy,
                    r.max_occupancy_pre_split,
                    r.splits,
                    r.total_clusters,
                    r.f_measure,
                    r.wall.as_secs_f64(),
                    r.pairs_per_sec
                );
            }
            println!(
                "final: K={} F={:.4} peak_matrix={:.1} MiB backend={} metric={}",
                res.k,
                res.f_measure,
                res.history.peak_matrix_bytes() as f64 / (1 << 20) as f64,
                backend.name(),
                backend.metric_name()
            );
            if let Some(r0) = res.history.records.first() {
                if r0.representatives > 0 {
                    println!(
                        "stage-0 aggregation: {} representatives over N={} \
                         (eps={:.4}, compression {:.3}, {} probe pairs)",
                        r0.representatives,
                        set.len(),
                        r0.aggregate_epsilon,
                        r0.compression_ratio,
                        res.history.assignment_pairs_total()
                    );
                    println!(
                        "  probe engine: {} rounds, largest rectangle {}x{}, \
                         {} super-leaders, {} quantile sample pairs over {} segments",
                        r0.probe_rounds,
                        r0.probe_rect_rows,
                        r0.probe_rect_cols,
                        r0.super_leaders,
                        r0.sample_pairs,
                        r0.sample_segments
                    );
                    let deviation_bound = r0.deviation_bound;
                    println!(
                        "  quality: stage-1 merge heights deviate from the full corpus \
                         by at most {deviation_bound:.4} (2*r_max*sqrt(2*c_max))"
                    );
                }
            }
            if cache_on {
                let t = res.history.cache_total();
                println!(
                    "cache: {:.1}% of pair distances served from cache \
                     ({} hits, {} misses, {} evictions)",
                    t.hit_rate() * 100.0,
                    t.hits,
                    t.misses,
                    t.evictions
                );
            }
            print_prune_summary(&res.history.records);
            print_selection_summary(&res.history.records);
            if let Some(path) = args.get("out") {
                std::fs::write(path, res.history.to_json().to_string())?;
                eprintln!("wrote {path}");
            }
        }
        other => anyhow::bail!("unknown algo '{other}' (ahc|mahc|mahc+m)"),
    }
    Ok(())
}

fn stream(args: &Args) -> anyhow::Result<()> {
    let mut algo = algo_config_from(args)?;

    let set = corpus_from(args)?;
    eprintln!(
        "generated {} (N={}, classes={})",
        set.name,
        set.len(),
        set.num_classes
    );
    let stats = CompositionStats::of(&set);
    eprintln!("  composition: {}", stats.table_row());

    // Default shard: a quarter of the corpus (so the bare subcommand
    // demonstrates a real multi-shard stream).
    let shard_size: usize = args.get_or("shard-size", set.len().div_ceil(4).max(1))?;
    if algo.beta.is_none() {
        // Default β scales with the *shard*, not the corpus: the active
        // set of an episode is one shard plus the carried medoids.
        algo.beta = Some((2 * shard_size / algo.p0.max(1)).max(8));
    }
    let mut cfg = StreamConfig::new(algo, shard_size);
    if let Some(s) = args.get_parsed::<u64>("shard-seed")? {
        cfg.shard_seed = Some(s);
    }

    match cfg.algo.metric {
        MetricKind::Dtw => match cfg.algo.backend {
            BackendKind::Native => {
                let backend = NativeBackend::new();
                stream_with(&set, cfg, &backend, args)
            }
            BackendKind::Blocked => {
                let backend = BlockedBackend::new();
                stream_with(&set, cfg, &backend, args)
            }
            BackendKind::Xla => {
                let dir = args.get("artifacts").unwrap_or("artifacts");
                let rt = Runtime::new(std::path::Path::new(dir))?;
                let backend = XlaDtwBackend::new(&rt)?;
                stream_with(&set, cfg, &backend, args)
            }
        },
        kind => match cfg.algo.backend {
            BackendKind::Native => {
                let backend = VectorBackend::native(vector_metric(kind));
                stream_with(&set, cfg, &backend, args)
            }
            BackendKind::Blocked => {
                let backend = VectorBackend::blocked(vector_metric(kind));
                stream_with(&set, cfg, &backend, args)
            }
            BackendKind::Xla => anyhow::bail!(
                "--backend xla computes DTW only; use --metric dtw or a cpu backend"
            ),
        },
    }
}

fn stream_with(
    set: &mahc::corpus::SegmentSet,
    cfg: StreamConfig,
    backend: &dyn PairwiseBackend,
    args: &Args,
) -> anyhow::Result<()> {
    let cache_on = cfg.algo.cache_bytes > 0;
    let beta = cfg.algo.beta;
    let retire = cfg.algo.retire;
    let driver = StreamingDriver::new(set, cfg, backend)?;
    let res = driver.run()?;
    println!("shard carried  P_f  maxOcc preOcc splits   K_tot   F       wall_s   pairs/s");
    for r in &res.history.records {
        println!(
            "{:>5} {:>7} {:>4} {:>7} {:>6} {:>6} {:>7} {:.4} {:>8.2} {:>9.0}",
            r.iteration,
            r.carried_medoids,
            r.subsets,
            r.max_occupancy,
            r.max_occupancy_pre_split,
            r.splits,
            r.total_clusters,
            r.f_measure,
            r.wall.as_secs_f64(),
            r.pairs_per_sec
        );
    }
    println!(
        "final: K={} F={:.4} peak_matrix={:.1} MiB over {} shards (β={}) backend={} metric={}",
        res.k,
        res.f_measure,
        res.history.peak_matrix_bytes() as f64 / (1 << 20) as f64,
        res.shards,
        beta.map_or("off".to_string(), |b| b.to_string()),
        backend.name(),
        backend.metric_name()
    );
    if let Some(r0) = res.history.records.first() {
        if r0.representatives > 0 {
            println!(
                "stage-0 aggregation: {} representatives over N={} \
                 (eps={:.4}, compression {:.3}, {} probe pairs)",
                r0.representatives,
                set.len(),
                r0.aggregate_epsilon,
                r0.compression_ratio,
                res.history.assignment_pairs_total()
            );
            println!(
                "  probe engine: {} rounds, largest rectangle {}x{}, \
                 {} super-leaders, {} quantile sample pairs over {} segments",
                r0.probe_rounds,
                r0.probe_rect_rows,
                r0.probe_rect_cols,
                r0.super_leaders,
                r0.sample_pairs,
                r0.sample_segments
            );
            let deviation_bound = r0.deviation_bound;
            println!(
                "  quality: stage-1 merge heights deviate from the full corpus \
                 by at most {deviation_bound:.4} (2*r_max*sqrt(2*c_max)); \
                 retire mode {}",
                retire.name()
            );
        }
    }
    if cache_on {
        let t = res.history.cache_total();
        println!(
            "cache: {:.1}% of pair distances served from cache \
             ({} hits, {} misses, {} evictions)",
            t.hit_rate() * 100.0,
            t.hits,
            t.misses,
            t.evictions
        );
        println!(
            "assignment rectangles: {:.1}% from cache ({} hits, {} misses)",
            res.assign_cache.hit_rate() * 100.0,
            res.assign_cache.hits,
            res.assign_cache.misses
        );
    }
    print_prune_summary(&res.history.records);
    print_selection_summary(&res.history.records);
    if let Some(path) = args.get("out") {
        std::fs::write(path, res.history.to_json().to_string())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn serve(args: &Args) -> anyhow::Result<()> {
    let mut algo = algo_config_from(args)?;
    let sessions: usize = args.get_or("sessions", 4)?;
    anyhow::ensure!(sessions >= 1, "--sessions must be >= 1");

    let set = Arc::new(corpus_from(args)?);
    eprintln!(
        "generated {} (N={}, classes={})",
        set.name,
        set.len(),
        set.num_classes
    );
    let stats = CompositionStats::of(&set);
    eprintln!("  composition: {}", stats.table_row());

    let shard_size: usize = args.get_or("shard-size", set.len().div_ceil(4).max(1))?;
    if algo.beta.is_none() {
        algo.beta = Some((2 * shard_size / algo.p0.max(1)).max(8));
    }

    let defaults = ServeConfig::default();
    let serve_cfg = ServeConfig {
        workers: args.get_or("workers", defaults.workers)?,
        fleet_cap: args.get_or("fleet-cap", defaults.fleet_cap)?,
        queue_cap: args.get_or("queue-cap", defaults.queue_cap)?,
        cache_bytes: args
            .get_parsed::<usize>("fleet-cache-mb")?
            .map_or(defaults.cache_bytes, |mb| mb << 20),
    };
    let fault: Option<usize> = args.get_parsed::<usize>("fault-session")?;

    // Sessions hop across pool workers between steps, so the backend
    // must be Send + Sync; the XLA backend's host handles are not.
    let backend: Arc<dyn PairwiseBackend + Send + Sync> = match (algo.metric, algo.backend) {
        (_, BackendKind::Xla) => anyhow::bail!(
            "serve requires a Send + Sync backend; --backend xla holds host handles \
             (use native or blocked)"
        ),
        (MetricKind::Dtw, BackendKind::Native) => Arc::new(NativeBackend::new()),
        (MetricKind::Dtw, BackendKind::Blocked) => Arc::new(BlockedBackend::new()),
        (kind, BackendKind::Native) => Arc::new(VectorBackend::native(vector_metric(kind))),
        (kind, BackendKind::Blocked) => Arc::new(VectorBackend::blocked(vector_metric(kind))),
    };

    // One corpus, many streams: session i consumes it in its own
    // shuffled arrival order, so the fleet exercises distinct episode
    // sequences while every session stays individually reproducible.
    let base_seed = algo.seed;
    let mut specs = Vec::with_capacity(sessions);
    for i in 0..sessions {
        let cfg =
            StreamConfig::new(algo.clone(), shard_size).with_shard_seed(base_seed + i as u64);
        let mut s = SessionSpec::new(&format!("s{i}"), Arc::clone(&set), cfg);
        if fault == Some(i) {
            s.panic_after_shards = Some(1);
        }
        specs.push(s);
    }

    let t0 = mahc::telemetry::Stopwatch::start();
    let report = ServeDriver::new(serve_cfg, backend)?.run(specs)?;
    println!("session  status      K        F  shards       pairs");
    for s in &report.sessions {
        match &s.result {
            Ok(r) => println!(
                "{:<8} {:<7} {:>5} {:>8.4} {:>7} {:>11}",
                s.name, "ok", r.k, r.f_measure, r.shards, r.pairs
            ),
            Err(e) => println!("{:<8} {:<7} {e}", s.name, "failed"),
        }
    }
    let stalls = report.fleet.records.last().map_or(0, |r| r.stalls);
    println!(
        "fleet: {} ok / {} failed; peak active {}, peak cache {:.1} MiB, \
         {} stalls, {:.0} pairs/s, wall {:.2}s",
        report.completed(),
        report.failed(),
        report.fleet.peak_active(),
        report.fleet.peak_cache_bytes() as f64 / (1 << 20) as f64,
        stalls,
        report.fleet.final_pairs_per_sec(),
        t0.elapsed().as_secs_f64()
    );
    if let Some(path) = args.get("out") {
        std::fs::write(path, report.to_json().to_string())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn datagen(args: &Args) -> anyhow::Result<()> {
    let set = corpus_from(args)?;
    let stats = CompositionStats::of(&set);
    println!(
        "{:<12} {:>9} {:>8} {:>13} {:>10} {:>14}",
        "Dataset", "Segments", "Classes", "Frequency", "Vectors", "Similarities"
    );
    println!("{}", stats.table_row());
    Ok(())
}

fn inspect(args: &Args) -> anyhow::Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let rt = Runtime::new(std::path::Path::new(dir))?;
    let m = rt.manifest();
    println!(
        "artifacts in {dir}: {} dtw, {} mfcc",
        m.dtw.len(),
        m.mfcc.len()
    );
    for e in &m.dtw {
        println!(
            "  dtw  {:<28} tile {}x{} T={} D={} band={:?}",
            e.name, e.bx, e.by, e.t, e.d, e.band
        );
    }
    for e in &m.mfcc {
        println!(
            "  mfcc {:<28} batch {} S={} -> T={} F={}",
            e.name, e.b, e.s, e.t_out, e.feat
        );
    }
    Ok(())
}
