//! Per-iteration telemetry: the observable series every paper figure is
//! drawn from.
//!
//! The MAHC driver appends one [`IterationRecord`] per iteration; the
//! figure harness reads the resulting [`RunHistory`] to regenerate
//! Figs. 1 and 4-11, and the JSON emitter makes runs machine-readable
//! for EXPERIMENTS.md bookkeeping.

use crate::util::json::{self, Json};
use std::time::{Duration, Instant};

/// Monotonic wall-clock stopwatch for driver timing.
///
/// Telemetry is the one sanctioned home for wall-clock reads (lint rule
/// R004): the drivers measure elapsed time only through this type, so
/// the nondeterministic `Instant::now` source stays confined to the
/// module whose output is explicitly excluded from bitwise-parity
/// comparisons (`wall_secs`, `pairs_per_sec`).
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch { t0: Instant::now() }
    }

    /// Time elapsed since [`Stopwatch::start`].
    pub fn elapsed(&self) -> Duration {
        self.t0.elapsed()
    }
}

/// Counters of the cross-iteration DTW pair cache
/// ([`crate::distance::PairCache`]).  A value is either a cumulative
/// snapshot (as [`crate::distance::PairCache::stats`] returns) or a
/// per-iteration delta (as stored on [`IterationRecord`]) — the
/// [`CacheStats::delta`] helper converts the former into the latter.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Pair lookups answered from the cache.
    pub hits: u64,
    /// Pair lookups that fell through to the DTW backend.
    pub misses: u64,
    /// Entries displaced by the capacity bound.
    pub evictions: u64,
}

impl CacheStats {
    /// Counter movement since an `earlier` snapshot.
    pub fn delta(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            evictions: self.evictions - earlier.evictions,
        }
    }

    /// Fraction of lookups served from the cache (0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("hits", json::num(self.hits as f64)),
            ("misses", json::num(self.misses as f64)),
            ("evictions", json::num(self.evictions as f64)),
            ("hit_rate", json::num(self.hit_rate())),
        ])
    }
}

/// Counters of the lower-bound pruning cascade
/// ([`crate::distance::CascadeBackend`]).  Like [`CacheStats`], a value
/// is either a cumulative snapshot (what the backend reports) or a
/// per-iteration delta (what [`IterationRecord`] stores); all zero when
/// pruning is off.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// Pairs the cascade evaluated a lower bound for.
    pub lb_pairs: u64,
    /// Pairs the bound decided above the threshold — no DTW was run.
    pub lb_pruned: u64,
    /// Pairs that reached the exact DP (cascade survivors plus
    /// threshold-free queries answered exactly).
    pub exact_pairs: u64,
}

impl PruneStats {
    /// Counter movement since an `earlier` snapshot.
    pub fn delta(&self, earlier: &PruneStats) -> PruneStats {
        PruneStats {
            lb_pairs: self.lb_pairs - earlier.lb_pairs,
            lb_pruned: self.lb_pruned - earlier.lb_pruned,
            exact_pairs: self.exact_pairs - earlier.exact_pairs,
        }
    }

    /// Fraction of bounded pairs the cascade pruned (0 when idle).
    pub fn prune_rate(&self) -> f64 {
        if self.lb_pairs == 0 {
            0.0
        } else {
            self.lb_pruned as f64 / self.lb_pairs as f64
        }
    }
}

/// Everything observable about one MAHC iteration.
#[derive(Debug, Clone)]
pub struct IterationRecord {
    pub iteration: usize,
    /// Number of subsets Pᵢ entering stage 1.
    pub subsets: usize,
    /// Largest subset occupancy (Fig. 1 / Fig. 7 series).
    pub max_occupancy: usize,
    /// Smallest subset occupancy (Fig. 11 series).
    pub min_occupancy: usize,
    /// Occupancy of the largest subset *after* refine, *before* split —
    /// shows the β-violation that split then repairs (Fig. 7 marks).
    pub max_occupancy_pre_split: usize,
    /// Subsets split this iteration (0 when size management is off).
    pub splits: usize,
    /// ΣKⱼ — total stage-1 clusters (the paper's K estimate).
    pub total_clusters: usize,
    /// F-measure of the *current* global clustering against truth.
    pub f_measure: f64,
    /// Wall-clock spent in this iteration (Fig. 6 series).
    pub wall: Duration,
    /// Peak condensed-matrix bytes across concurrent subset jobs.
    pub peak_matrix_bytes: usize,
    /// Pair-cache counter movement during this iteration (all zero when
    /// the cache is disabled).
    pub cache: CacheStats,
    /// Medoids carried into this step from earlier work.  Always 0 for
    /// the batch driver; the streaming driver records the size of the
    /// carried-forward medoid set entering each shard's episode here.
    pub carried_medoids: usize,
    /// Stage-0 representatives the step's pipeline ran over
    /// ([`crate::aggregate`]).  0 when aggregation is off — the
    /// pipeline then clusters raw segments.
    pub representatives: usize,
    /// Representatives / total segments (m / N).  1.0 when aggregation
    /// is off; smaller means more stage-0 compression.
    pub compression_ratio: f64,
    /// DTW pair probes the stage-0 leader pass performed, attributed to
    /// the record that follows it (the first iteration / shard); 0
    /// elsewhere and whenever aggregation is off.
    pub assignment_pairs: usize,
    /// Pair distances the stage-0 quantile-ε estimate consumed (first
    /// record only; 0 when ε was given absolutely or aggregation is
    /// off).
    pub sample_pairs: usize,
    /// Segments the quantile-ε estimate actually sampled after clamping
    /// to the corpus size (first record only; companion to
    /// `sample_pairs`).
    pub sample_segments: usize,
    /// Lower-bound evaluations the pruning cascade ran during this step
    /// (0 when pruning is off).
    pub lb_pairs: u64,
    /// Pairs the cascade's bound rejected without running DTW.
    pub lb_pruned: u64,
    /// Pairs that reached the exact DP kernel through the cascade.
    pub exact_pairs: u64,
    /// Probe rounds the stage-0 pass ran — rectangle dispatches, N on
    /// the per-row reference path.  Stamped on the first record of an
    /// aggregated run; 0 elsewhere.
    pub probe_rounds: usize,
    /// Rows of the largest probe rectangle the pass dispatched (first
    /// record only; 0 when aggregation is off or probing never met a
    /// candidate column).
    pub probe_rect_rows: usize,
    /// Columns of the largest probe rectangle (companion to
    /// `probe_rect_rows`).
    pub probe_rect_cols: usize,
    /// Super-leaders of the stage-0 two-level leader tree (first record
    /// only; 0 = flat probing or aggregation off).
    pub super_leaders: usize,
    /// Effective stage-0 leader radius ε — quantile-derived when
    /// `aggregate_quantile` is configured (first record only; 0.0 when
    /// aggregation is off).
    pub aggregate_epsilon: f64,
    /// Linkage-height deviation bound vs full AHC, computed from the
    /// stage-0 cluster-feature summaries
    /// ([`crate::aggregate::summary`]); first record only, 0.0 when
    /// aggregation is off or the pass collapsed nothing.
    pub deviation_bound: f64,
    /// Name of the DTW backend that served this step's distances
    /// ([`crate::distance::PairwiseBackend::name`]).
    pub backend: String,
    /// Pair distances the step's builders produced (stage-1 condensed
    /// matrices + the medoid matrix; cache hits included since a hit
    /// still yields a pair distance) per wall-clock second.
    pub pairs_per_sec: f64,
    /// Name of the distance metric the backend computes
    /// ([`crate::distance::PairwiseBackend::metric_name`]): `dtw`,
    /// `cosine` or `euclidean`.
    pub metric: String,
    /// Mean silhouette of this step's evaluation cut — the
    /// model-selection quality signal.  0.0 under L-method selection,
    /// where the medoid matrix is not retained for scoring.
    pub silhouette_score: f64,
}

impl IterationRecord {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("iteration", json::num(self.iteration as f64)),
            ("subsets", json::num(self.subsets as f64)),
            ("max_occupancy", json::num(self.max_occupancy as f64)),
            ("min_occupancy", json::num(self.min_occupancy as f64)),
            (
                "max_occupancy_pre_split",
                json::num(self.max_occupancy_pre_split as f64),
            ),
            ("splits", json::num(self.splits as f64)),
            ("total_clusters", json::num(self.total_clusters as f64)),
            ("f_measure", json::num(self.f_measure)),
            ("wall_secs", json::num(self.wall.as_secs_f64())),
            (
                "peak_matrix_bytes",
                json::num(self.peak_matrix_bytes as f64),
            ),
            ("cache", self.cache.to_json()),
            ("carried_medoids", json::num(self.carried_medoids as f64)),
            ("representatives", json::num(self.representatives as f64)),
            ("compression_ratio", json::num(self.compression_ratio)),
            ("assignment_pairs", json::num(self.assignment_pairs as f64)),
            ("sample_pairs", json::num(self.sample_pairs as f64)),
            ("sample_segments", json::num(self.sample_segments as f64)),
            ("lb_pairs", json::num(self.lb_pairs as f64)),
            ("lb_pruned", json::num(self.lb_pruned as f64)),
            ("exact_pairs", json::num(self.exact_pairs as f64)),
            ("probe_rounds", json::num(self.probe_rounds as f64)),
            ("probe_rect_rows", json::num(self.probe_rect_rows as f64)),
            ("probe_rect_cols", json::num(self.probe_rect_cols as f64)),
            ("super_leaders", json::num(self.super_leaders as f64)),
            ("aggregate_epsilon", json::num(self.aggregate_epsilon)),
            ("deviation_bound", json::num(self.deviation_bound)),
            ("backend", json::s(&self.backend)),
            ("pairs_per_sec", json::num(self.pairs_per_sec)),
            ("metric", json::s(&self.metric)),
            ("silhouette_score", json::num(self.silhouette_score)),
        ])
    }
}

/// Pair throughput over a wall-clock interval (0 when the clock did not
/// advance, so degenerate timings never divide by zero).
pub fn pairs_rate(pairs: usize, wall: Duration) -> f64 {
    let secs = wall.as_secs_f64();
    if secs > 0.0 {
        pairs as f64 / secs
    } else {
        0.0
    }
}

/// Full history of one clustering run.
#[derive(Debug, Clone, Default)]
pub struct RunHistory {
    pub dataset: String,
    pub algo: String,
    pub records: Vec<IterationRecord>,
}

impl RunHistory {
    pub fn new(dataset: &str, algo: &str) -> Self {
        RunHistory {
            dataset: dataset.to_string(),
            algo: algo.to_string(),
            records: Vec::new(),
        }
    }

    pub fn push(&mut self, r: IterationRecord) {
        self.records.push(r);
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("dataset", json::s(&self.dataset)),
            ("algo", json::s(&self.algo)),
            (
                "iterations",
                json::arr(self.records.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }

    /// Series accessors for the figure harness.
    pub fn subsets_series(&self) -> Vec<usize> {
        self.records.iter().map(|r| r.subsets).collect()
    }

    pub fn f_series(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.f_measure).collect()
    }

    pub fn max_occupancy_series(&self) -> Vec<usize> {
        self.records.iter().map(|r| r.max_occupancy).collect()
    }

    pub fn min_occupancy_series(&self) -> Vec<usize> {
        self.records.iter().map(|r| r.min_occupancy).collect()
    }

    pub fn wall_series(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.wall.as_secs_f64()).collect()
    }

    /// Per-iteration cache counters (Fig-6-style series for the cache).
    pub fn cache_series(&self) -> Vec<CacheStats> {
        self.records.iter().map(|r| r.cache).collect()
    }

    /// Carried-medoid counts per record (all zero for batch runs; the
    /// streaming driver's warm-state series).
    pub fn carried_series(&self) -> Vec<usize> {
        self.records.iter().map(|r| r.carried_medoids).collect()
    }

    /// Per-record pair throughput (the §Backends comparison series).
    pub fn pairs_per_sec_series(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.pairs_per_sec).collect()
    }

    /// Stage-0 representative counts per record (all zero when
    /// aggregation is off).
    pub fn representatives_series(&self) -> Vec<usize> {
        self.records.iter().map(|r| r.representatives).collect()
    }

    /// Stage-0 compression ratio of the run (m / N; 1.0 when
    /// aggregation is off or the history is empty).
    pub fn compression_ratio(&self) -> f64 {
        self.records.first().map_or(1.0, |r| r.compression_ratio)
    }

    /// Total stage-0 probe pairs over the run.
    pub fn assignment_pairs_total(&self) -> usize {
        self.records.iter().map(|r| r.assignment_pairs).sum()
    }

    /// Pair distances the run's stage-0 quantile-ε estimate consumed
    /// (0 when ε was absolute or aggregation is off).
    pub fn sample_pairs(&self) -> usize {
        self.records.first().map_or(0, |r| r.sample_pairs)
    }

    /// Probe rounds of the run's stage-0 pass (0 when aggregation is
    /// off; the pass runs once, so this is the first record's stamp).
    pub fn probe_rounds(&self) -> usize {
        self.records.first().map_or(0, |r| r.probe_rounds)
    }

    /// Largest stage-0 probe rectangle of the run, rows then columns.
    pub fn probe_rect(&self) -> (usize, usize) {
        self.records
            .first()
            .map_or((0, 0), |r| (r.probe_rect_rows, r.probe_rect_cols))
    }

    /// Super-leaders of the run's stage-0 leader tree (0 = flat/off).
    pub fn super_leaders(&self) -> usize {
        self.records.first().map_or(0, |r| r.super_leaders)
    }

    /// Effective stage-0 leader radius of the run (0.0 when off).
    pub fn aggregate_epsilon(&self) -> f64 {
        self.records.first().map_or(0.0, |r| r.aggregate_epsilon)
    }

    /// Aggregation deviation bound of the run (0.0 when aggregation is
    /// off or the pass collapsed nothing).
    pub fn deviation_bound(&self) -> f64 {
        self.records.first().map_or(0.0, |r| r.deviation_bound)
    }

    /// Whole-run cache counters (sum of per-iteration deltas).
    pub fn cache_total(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for r in &self.records {
            total.hits += r.cache.hits;
            total.misses += r.cache.misses;
            total.evictions += r.cache.evictions;
        }
        total
    }

    /// Peak matrix bytes over the whole run — the memory-guarantee
    /// number the β threshold must bound.
    pub fn peak_matrix_bytes(&self) -> usize {
        self.records
            .iter()
            .map(|r| r.peak_matrix_bytes)
            .max()
            .unwrap_or(0)
    }
}

/// One sample of the serve multiplexer's fleet-wide state, taken at a
/// scheduling event (admission, step completion, session end, …).  The
/// per-session layer stays [`IterationRecord`]; this layer observes the
/// fleet: occupancy, queueing, cache pressure, and aggregate throughput
/// across sessions.
#[derive(Debug, Clone)]
pub struct FleetRecord {
    /// Monotone event sequence number within the serve run.
    pub seq: usize,
    /// What triggered the sample: `admit`, `queue`, `reject`, `step`,
    /// `done`, `failed`.
    pub event: String,
    /// Name of the session the event concerns.
    pub session: String,
    /// Sessions admitted and not yet finished.
    pub active: usize,
    /// Sessions queued behind the fleet cap.
    pub waiting: usize,
    /// Session steps currently running on the worker pool.
    pub inflight: usize,
    /// Sessions finished successfully so far.
    pub completed: usize,
    /// Sessions failed (error or panic) so far.
    pub failed: usize,
    /// Sessions rejected at admission so far.
    pub rejected: usize,
    /// Times the scheduler stalled waiting for pool capacity so far.
    pub stalls: usize,
    /// Resident bytes of the shared fleet cache (0 when absent).
    pub cache_resident_bytes: usize,
    /// Pair distances produced by all sessions so far.
    pub pairs_total: usize,
    /// Wall seconds since the serve run started.
    pub wall_secs: f64,
    /// Fleet throughput: `pairs_total / wall_secs` (0 when idle).
    pub pairs_per_sec: f64,
}

impl FleetRecord {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("seq", json::num(self.seq as f64)),
            ("event", json::s(&self.event)),
            ("session", json::s(&self.session)),
            ("active", json::num(self.active as f64)),
            ("waiting", json::num(self.waiting as f64)),
            ("inflight", json::num(self.inflight as f64)),
            ("completed", json::num(self.completed as f64)),
            ("failed", json::num(self.failed as f64)),
            ("rejected", json::num(self.rejected as f64)),
            ("stalls", json::num(self.stalls as f64)),
            (
                "cache_resident_bytes",
                json::num(self.cache_resident_bytes as f64),
            ),
            ("pairs_total", json::num(self.pairs_total as f64)),
            ("wall_secs", json::num(self.wall_secs)),
            ("pairs_per_sec", json::num(self.pairs_per_sec)),
        ])
    }
}

/// Event log of one serve run — the fleet-wide companion of
/// [`RunHistory`], serialised through the same JSON machinery.
#[derive(Debug, Clone, Default)]
pub struct FleetHistory {
    pub records: Vec<FleetRecord>,
}

impl FleetHistory {
    pub fn new() -> Self {
        FleetHistory::default()
    }

    pub fn push(&mut self, r: FleetRecord) {
        self.records.push(r);
    }

    /// Peak concurrently-active session count over the run.
    pub fn peak_active(&self) -> usize {
        self.records.iter().map(|r| r.active).max().unwrap_or(0)
    }

    /// Peak resident bytes of the shared fleet cache over the run.
    pub fn peak_cache_bytes(&self) -> usize {
        self.records
            .iter()
            .map(|r| r.cache_resident_bytes)
            .max()
            .unwrap_or(0)
    }

    /// Final fleet throughput (last sample's pairs/sec; 0 when empty).
    pub fn final_pairs_per_sec(&self) -> f64 {
        self.records.last().map_or(0.0, |r| r.pairs_per_sec)
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![(
            "fleet",
            json::arr(self.records.iter().map(|r| r.to_json()).collect()),
        )])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(i: usize, subsets: usize, maxo: usize) -> IterationRecord {
        IterationRecord {
            iteration: i,
            subsets,
            max_occupancy: maxo,
            min_occupancy: 1,
            max_occupancy_pre_split: maxo + 5,
            splits: 1,
            total_clusters: 10,
            f_measure: 0.5,
            wall: Duration::from_millis(100),
            peak_matrix_bytes: maxo * maxo * 2,
            cache: CacheStats {
                hits: 3,
                misses: 7,
                evictions: 1,
            },
            carried_medoids: subsets * 2,
            representatives: maxo * 2,
            compression_ratio: 0.5,
            assignment_pairs: if i == 0 { 42 } else { 0 },
            sample_pairs: if i == 0 { 11 } else { 0 },
            sample_segments: if i == 0 { 5 } else { 0 },
            lb_pairs: 20 * (i as u64 + 1),
            lb_pruned: 15 * (i as u64 + 1),
            exact_pairs: 5 * (i as u64 + 1),
            probe_rounds: if i == 0 { 6 } else { 0 },
            probe_rect_rows: if i == 0 { 16 } else { 0 },
            probe_rect_cols: if i == 0 { 9 } else { 0 },
            super_leaders: if i == 0 { 3 } else { 0 },
            aggregate_epsilon: if i == 0 { 1.25 } else { 0.0 },
            deviation_bound: if i == 0 { 0.75 } else { 0.0 },
            backend: "native".to_string(),
            pairs_per_sec: 1000.0 * (i + 1) as f64,
            metric: "dtw".to_string(),
            silhouette_score: 0.25 * (i + 1) as f64,
        }
    }

    #[test]
    fn series_extraction() {
        let mut h = RunHistory::new("small_a", "mahc+m");
        h.push(rec(0, 4, 100));
        h.push(rec(1, 6, 80));
        assert_eq!(h.subsets_series(), vec![4, 6]);
        assert_eq!(h.max_occupancy_series(), vec![100, 80]);
        assert_eq!(h.carried_series(), vec![8, 12]);
        assert_eq!(h.pairs_per_sec_series(), vec![1000.0, 2000.0]);
        assert_eq!(h.representatives_series(), vec![200, 160]);
        assert_eq!(h.compression_ratio(), 0.5);
        assert_eq!(h.assignment_pairs_total(), 42);
        assert_eq!(h.sample_pairs(), 11);
        assert_eq!(h.probe_rounds(), 6);
        assert_eq!(h.probe_rect(), (16, 9));
        assert_eq!(h.super_leaders(), 3);
        assert_eq!(h.aggregate_epsilon(), 1.25);
        assert_eq!(h.deviation_bound(), 0.75);
        assert_eq!(h.peak_matrix_bytes(), 100 * 100 * 2);
        let total = h.cache_total();
        assert_eq!(total.hits, 6);
        assert_eq!(total.misses, 14);
        assert_eq!(total.evictions, 2);
        assert!((total.hit_rate() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn cache_stats_delta_and_rate() {
        let early = CacheStats {
            hits: 10,
            misses: 30,
            evictions: 1,
        };
        let late = CacheStats {
            hits: 40,
            misses: 50,
            evictions: 4,
        };
        let d = late.delta(&early);
        assert_eq!(
            d,
            CacheStats {
                hits: 30,
                misses: 20,
                evictions: 3
            }
        );
        assert!((d.hit_rate() - 0.6).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn json_round_trip() {
        let mut h = RunHistory::new("d", "a");
        h.push(rec(0, 2, 10));
        let text = h.to_json().to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(parsed.get("dataset").unwrap().as_str().unwrap(), "d");
        let iters = parsed.get("iterations").unwrap().as_arr().unwrap();
        assert_eq!(iters.len(), 1);
        assert_eq!(
            iters[0].get("max_occupancy").unwrap().as_usize().unwrap(),
            10
        );
        assert_eq!(
            iters[0].get("carried_medoids").unwrap().as_usize().unwrap(),
            4
        );
        assert_eq!(
            iters[0].get("backend").unwrap().as_str().unwrap(),
            "native"
        );
        assert_eq!(
            iters[0].get("pairs_per_sec").unwrap().as_usize().unwrap(),
            1000
        );
        assert_eq!(
            iters[0].get("representatives").unwrap().as_usize().unwrap(),
            20
        );
        assert_eq!(
            iters[0].get("compression_ratio").unwrap().as_f64().unwrap(),
            0.5
        );
        assert_eq!(
            iters[0].get("assignment_pairs").unwrap().as_usize().unwrap(),
            42
        );
        assert_eq!(
            iters[0].get("sample_pairs").unwrap().as_usize().unwrap(),
            11
        );
        assert_eq!(
            iters[0].get("probe_rounds").unwrap().as_usize().unwrap(),
            6
        );
        assert_eq!(
            iters[0].get("probe_rect_rows").unwrap().as_usize().unwrap(),
            16
        );
        assert_eq!(
            iters[0].get("probe_rect_cols").unwrap().as_usize().unwrap(),
            9
        );
        assert_eq!(
            iters[0].get("super_leaders").unwrap().as_usize().unwrap(),
            3
        );
        assert_eq!(
            iters[0].get("aggregate_epsilon").unwrap().as_f64().unwrap(),
            1.25
        );
        assert_eq!(
            iters[0].get("deviation_bound").unwrap().as_f64().unwrap(),
            0.75
        );
        assert_eq!(
            iters[0].get("sample_segments").unwrap().as_usize().unwrap(),
            5
        );
        assert_eq!(iters[0].get("lb_pairs").unwrap().as_usize().unwrap(), 20);
        assert_eq!(iters[0].get("lb_pruned").unwrap().as_usize().unwrap(), 15);
        assert_eq!(
            iters[0].get("exact_pairs").unwrap().as_usize().unwrap(),
            5
        );
        assert_eq!(iters[0].get("metric").unwrap().as_str().unwrap(), "dtw");
        assert_eq!(
            iters[0]
                .get("silhouette_score")
                .unwrap()
                .as_f64()
                .unwrap(),
            0.25
        );
    }

    #[test]
    fn prune_stats_delta_and_rate() {
        let early = PruneStats {
            lb_pairs: 100,
            lb_pruned: 60,
            exact_pairs: 40,
        };
        let late = PruneStats {
            lb_pairs: 300,
            lb_pruned: 210,
            exact_pairs: 90,
        };
        let d = late.delta(&early);
        assert_eq!(
            d,
            PruneStats {
                lb_pairs: 200,
                lb_pruned: 150,
                exact_pairs: 50
            }
        );
        assert!((d.prune_rate() - 0.75).abs() < 1e-12);
        assert_eq!(PruneStats::default().prune_rate(), 0.0);
    }

    #[test]
    fn pairs_rate_handles_degenerate_walls() {
        assert_eq!(pairs_rate(500, Duration::from_secs(2)), 250.0);
        assert_eq!(pairs_rate(500, Duration::ZERO), 0.0);
        assert_eq!(pairs_rate(0, Duration::from_secs(1)), 0.0);
    }

    #[test]
    fn pairs_rate_is_finite_and_json_safe_for_all_degenerate_inputs() {
        // Pin: zero-duration and zero-pair iterations must never leak a
        // NaN or infinity into the run JSON — `util::json` writes f64s
        // with `{}` formatting, so a non-finite value would emit the
        // literal `NaN`/`inf` and corrupt the document.
        for (pairs, wall) in [
            (0usize, Duration::ZERO),
            (0, Duration::from_secs(1)),
            (usize::MAX >> 12, Duration::ZERO),
            (1, Duration::from_nanos(1)),
        ] {
            let rate = pairs_rate(pairs, wall);
            assert!(
                rate.is_finite(),
                "pairs_rate({pairs}, {wall:?}) = {rate} not finite"
            );
        }
        // End to end: a record from a degenerate (instantaneous, empty)
        // iteration serialises to parseable JSON.
        let mut r = rec(0, 1, 1);
        r.wall = Duration::ZERO;
        r.pairs_per_sec = pairs_rate(0, Duration::ZERO);
        let mut h = RunHistory::new("degenerate", "mahc");
        h.push(r);
        let text = h.to_json().to_string();
        assert!(!text.contains("NaN") && !text.contains("inf"), "{text}");
        let parsed = crate::util::json::parse(&text).unwrap();
        let iters = parsed.get("iterations").unwrap().as_arr().unwrap();
        assert_eq!(
            iters[0].get("pairs_per_sec").unwrap().as_f64().unwrap(),
            0.0
        );
        assert_eq!(iters[0].get("wall_secs").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn fleet_history_serialises_and_summarises() {
        let mut h = FleetHistory::new();
        for (seq, (event, active, bytes, pps)) in [
            ("admit", 1usize, 0usize, 0.0f64),
            ("step", 2, 4096, 125.0),
            ("done", 1, 2048, 250.0),
        ]
        .into_iter()
        .enumerate()
        {
            h.push(FleetRecord {
                seq,
                event: event.to_string(),
                session: format!("s{seq}"),
                active,
                waiting: 0,
                inflight: active,
                completed: usize::from(event == "done"),
                failed: 0,
                rejected: 0,
                stalls: 0,
                cache_resident_bytes: bytes,
                pairs_total: seq * 100,
                wall_secs: seq as f64,
                pairs_per_sec: pps,
            });
        }
        assert_eq!(h.peak_active(), 2);
        assert_eq!(h.peak_cache_bytes(), 4096);
        assert_eq!(h.final_pairs_per_sec(), 250.0);
        let text = h.to_json().to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let fleet = parsed.get("fleet").unwrap().as_arr().unwrap();
        assert_eq!(fleet.len(), 3);
        assert_eq!(fleet[1].get("event").unwrap().as_str().unwrap(), "step");
        assert_eq!(
            fleet[2].get("pairs_per_sec").unwrap().as_f64().unwrap(),
            250.0
        );
    }
}
