//! Comparison baselines for the evaluation figures.
//!
//! * [`full_ahc`] — classical single-matrix AHC over the whole dataset
//!   (the flat reference lines in Figs. 4, 5, 7); O(N²) space, which is
//!   exactly what MAHC exists to avoid.
//! * Plain MAHC (no size management) is not a separate implementation:
//!   it is the [`crate::mahc::MahcDriver`] with `beta = None`, so both
//!   variants share every line of machinery except the split step —
//!   the comparison isolates the contribution.

use crate::ahc;
use crate::corpus::{Segment, SegmentSet};
use crate::distance::{build_condensed, PairwiseBackend};
use crate::metrics;

/// Result of the classical-AHC baseline.
#[derive(Debug, Clone)]
pub struct AhcBaseline {
    pub labels: Vec<usize>,
    pub k: usize,
    pub f_measure: f64,
    /// Bytes of the full condensed matrix — the O(N²) cost MAHC avoids.
    pub matrix_bytes: usize,
}

/// Classical AHC over the full dataset.  `k` of `None` lets the
/// L method choose (capped at `max_clusters_frac`·N like the subsets).
pub fn full_ahc(
    set: &SegmentSet,
    backend: &dyn PairwiseBackend,
    threads: usize,
    k: Option<usize>,
    max_clusters_frac: f64,
) -> anyhow::Result<AhcBaseline> {
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let cond = build_condensed(&refs, backend, threads)?;
    let max_k = ((set.len() as f64 * max_clusters_frac).ceil() as usize).max(2);
    let clustering = ahc::cluster_subset(&cond, max_k, k);
    let truth = set.labels();
    let f_measure = metrics::f_measure(&clustering.labels, &truth);
    Ok(AhcBaseline {
        labels: clustering.labels,
        k: clustering.k,
        f_measure,
        matrix_bytes: cond.bytes(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetSpec;
    use crate::corpus::generate;
    use crate::distance::NativeBackend;

    #[test]
    fn recovers_structure_on_separable_data() {
        let set = generate(&DatasetSpec::tiny(80, 5, 31));
        let out = full_ahc(&set, &NativeBackend::new(), 4, None, 0.3).unwrap();
        assert!(out.f_measure > 0.5, "F {:.3}", out.f_measure);
        assert_eq!(out.labels.len(), 80);
        assert_eq!(out.matrix_bytes, 80 * 79 / 2 * 4);
    }

    #[test]
    fn fixed_k_override() {
        let set = generate(&DatasetSpec::tiny(40, 4, 32));
        let out = full_ahc(&set, &NativeBackend::new(), 2, Some(4), 0.5).unwrap();
        assert_eq!(out.k, 4);
    }
}
