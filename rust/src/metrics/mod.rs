//! Clustering quality metrics: F-measure (the paper's §6.2 criterion),
//! plus purity and NMI as secondary checks.

/// Contingency counts between predicted clusters and true classes.
struct Contingency {
    /// n_kl: [cluster][class] co-occurrence counts.
    table: Vec<Vec<usize>>,
    cluster_sizes: Vec<usize>,
    class_sizes: Vec<usize>,
    n: usize,
}

fn contingency(pred: &[usize], truth: &[usize]) -> Contingency {
    assert_eq!(pred.len(), truth.len());
    let k = pred.iter().copied().max().map_or(0, |m| m + 1);
    let l = truth.iter().copied().max().map_or(0, |m| m + 1);
    let mut table = vec![vec![0usize; l]; k];
    let mut cluster_sizes = vec![0usize; k];
    let mut class_sizes = vec![0usize; l];
    for (&p, &t) in pred.iter().zip(truth) {
        table[p][t] += 1;
        cluster_sizes[p] += 1;
        class_sizes[t] += 1;
    }
    Contingency {
        table,
        cluster_sizes,
        class_sizes,
        n: pred.len(),
    }
}

/// Paper Eq. 2-4 with the Larsen-Aone aggregation: for each class l,
/// take the best F(k, l) over clusters, weight by class prevalence.
///
/// F = Σ_l (n_l / N) · max_k F(k, l);  F = 1 iff every class occupies
/// exactly one cluster exclusively.
pub fn f_measure(pred: &[usize], truth: &[usize]) -> f64 {
    if pred.is_empty() {
        return 0.0;
    }
    let c = contingency(pred, truth);
    let mut total = 0.0;
    for l in 0..c.class_sizes.len() {
        let nl = c.class_sizes[l];
        if nl == 0 {
            continue;
        }
        let mut best = 0.0f64;
        for k in 0..c.cluster_sizes.len() {
            let nkl = c.table[k][l];
            if nkl == 0 {
                continue;
            }
            let pr = nkl as f64 / c.cluster_sizes[k] as f64; // Eq. 2
            let re = nkl as f64 / nl as f64; // Eq. 3
            let f = 2.0 * re * pr / (re + pr); // Eq. 4
            if f > best {
                best = f;
            }
        }
        total += (nl as f64 / c.n as f64) * best;
    }
    total
}

/// Purity: fraction of objects in their cluster's majority class.
pub fn purity(pred: &[usize], truth: &[usize]) -> f64 {
    if pred.is_empty() {
        return 0.0;
    }
    let c = contingency(pred, truth);
    let correct: usize = c
        .table
        .iter()
        .map(|row| row.iter().copied().max().unwrap_or(0))
        .sum();
    correct as f64 / c.n as f64
}

/// Normalised mutual information, NMI = 2·I(P;T) / (H(P) + H(T)).
/// Returns 1.0 for identical partitions, →0 for independent ones.
pub fn nmi(pred: &[usize], truth: &[usize]) -> f64 {
    if pred.is_empty() {
        return 0.0;
    }
    let c = contingency(pred, truth);
    let n = c.n as f64;
    let h = |sizes: &[usize]| -> f64 {
        sizes
            .iter()
            .filter(|&&s| s > 0)
            .map(|&s| {
                let p = s as f64 / n;
                -p * p.ln()
            })
            .sum()
    };
    let hp = h(&c.cluster_sizes);
    let ht = h(&c.class_sizes);
    if hp == 0.0 && ht == 0.0 {
        return 1.0; // both single-block partitions: identical
    }
    let mut mi = 0.0;
    for k in 0..c.cluster_sizes.len() {
        for l in 0..c.class_sizes.len() {
            let nkl = c.table[k][l];
            if nkl == 0 {
                continue;
            }
            let pkl = nkl as f64 / n;
            let pk = c.cluster_sizes[k] as f64 / n;
            let pl = c.class_sizes[l] as f64 / n;
            mi += pkl * (pkl / (pk * pl)).ln();
        }
    }
    (2.0 * mi / (hp + ht)).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_clustering_scores_one() {
        let truth = vec![0, 0, 1, 1, 2, 2];
        let pred = vec![2, 2, 0, 0, 1, 1]; // same partition, renamed
        assert!((f_measure(&pred, &truth) - 1.0).abs() < 1e-12);
        assert!((purity(&pred, &truth) - 1.0).abs() < 1e-12);
        assert!((nmi(&pred, &truth) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn single_cluster_scores() {
        let truth = vec![0, 0, 1, 1];
        let pred = vec![0, 0, 0, 0];
        // Per class: pr = 1/2, re = 1 -> F = 2/3; weighted -> 2/3.
        assert!((f_measure(&pred, &truth) - 2.0 / 3.0).abs() < 1e-12);
        assert!((purity(&pred, &truth) - 0.5).abs() < 1e-12);
        assert!(nmi(&pred, &truth) < 1e-9);
    }

    #[test]
    fn all_singletons() {
        let truth = vec![0, 0, 1, 1];
        let pred = vec![0, 1, 2, 3];
        // Per class: best F with a singleton = 2·(1/2·1)/(3/2) = 2/3.
        assert!((f_measure(&pred, &truth) - 2.0 / 3.0).abs() < 1e-12);
        assert!((purity(&pred, &truth) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn hand_computed_mixed_case() {
        // clusters: {a,a,b}, {b,b,a}
        let truth = vec![0, 0, 1, 1, 1, 0];
        let pred = vec![0, 0, 0, 1, 1, 1];
        // class 0 (n=3): cluster0 pr=2/3 re=2/3 F=2/3; cluster1 pr=1/3 re=1/3 F=1/3 -> best 2/3
        // class 1 (n=3): symmetric -> 2/3.  Weighted: 2/3.
        assert!((f_measure(&pred, &truth) - 2.0 / 3.0).abs() < 1e-12);
        assert!((purity(&pred, &truth) - 2.0 / 3.0).abs() < 1e-12);
        let v = nmi(&pred, &truth);
        assert!(v > 0.0 && v < 1.0);
    }

    #[test]
    fn f_measure_matches_hand_computed_fixture() {
        // External fixture for the headline metric, worked through the
        // paper's Eq. 2-4 by hand (not derived from this code):
        //
        //   truth: class A = {0,1,2,3,4} (n=5), class B = {5,6,7} (n=3)
        //   pred:  cluster 0 = {0,1,2}, cluster 1 = {3,4,5,6}, cluster 2 = {7}
        //
        //   class A: vs c0: pr = 3/3, re = 3/5 → F = 2·(3/5)/(8/5) = 3/4
        //            vs c1: pr = 2/4, re = 2/5 → F = 2·(1/5)/(9/10) = 4/9
        //            best = 3/4
        //   class B: vs c1: pr = 2/4, re = 2/3 → F = 2·(1/3)/(7/6) = 4/7
        //            vs c2: pr = 1/1, re = 1/3 → F = 2·(1/3)/(4/3) = 1/2
        //            best = 4/7
        //
        //   F = (5/8)·(3/4) + (3/8)·(4/7) = 15/32 + 3/14 = 153/224
        let truth = vec![0, 0, 0, 0, 0, 1, 1, 1];
        let pred = vec![0, 0, 0, 1, 1, 1, 1, 2];
        assert!((f_measure(&pred, &truth) - 153.0 / 224.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(f_measure(&[], &[]), 0.0);
        assert_eq!(purity(&[], &[]), 0.0);
        assert_eq!(nmi(&[], &[]), 0.0);
    }

    #[test]
    fn better_clustering_scores_higher() {
        let truth: Vec<usize> = (0..30).map(|i| i / 10).collect();
        let good: Vec<usize> = truth.clone();
        let mut ok = truth.clone();
        ok[0] = 1;
        ok[10] = 2;
        ok[20] = 0; // 3 mistakes
        let bad: Vec<usize> = (0..30).map(|i| i % 3).collect(); // shredded
        let (fg, fo, fb) = (
            f_measure(&good, &truth),
            f_measure(&ok, &truth),
            f_measure(&bad, &truth),
        );
        assert!(fg > fo && fo > fb, "{fg} {fo} {fb}");
        assert!(nmi(&good, &truth) > nmi(&ok, &truth));
        assert!(nmi(&ok, &truth) > nmi(&bad, &truth));
    }

    #[test]
    fn metrics_invariant_to_label_permutation() {
        let truth = vec![0, 1, 2, 0, 1, 2, 0, 1];
        let pred = vec![1, 0, 2, 1, 0, 2, 1, 2];
        let renamed: Vec<usize> = pred.iter().map(|&p| (p + 1) % 3).collect();
        assert!((f_measure(&pred, &truth) - f_measure(&renamed, &truth)).abs() < 1e-12);
        assert!((nmi(&pred, &truth) - nmi(&renamed, &truth)).abs() < 1e-12);
        assert!((purity(&pred, &truth) - purity(&renamed, &truth)).abs() < 1e-12);
    }

    #[test]
    fn non_dense_label_ids_score_like_their_dense_relabelling() {
        // The contingency table is indexed by max(label)+1, so sparse
        // ids produce empty rows/columns.  Pinned behaviour: empty
        // slots are skipped everywhere, making sparse ids score exactly
        // like the dense relabelling — on both the pred and truth side.
        let truth_dense = vec![0, 0, 1, 1, 2, 2];
        let pred_dense = vec![0, 0, 1, 2, 2, 2];
        let truth_sparse = vec![3, 3, 9, 9, 14, 14];
        let pred_sparse = vec![5, 5, 11, 40, 40, 40];
        for (a, b) in [
            (
                f_measure(&pred_dense, &truth_dense),
                f_measure(&pred_sparse, &truth_sparse),
            ),
            (
                purity(&pred_dense, &truth_dense),
                purity(&pred_sparse, &truth_sparse),
            ),
            (
                nmi(&pred_dense, &truth_dense),
                nmi(&pred_sparse, &truth_sparse),
            ),
        ] {
            assert!(
                (a - b).abs() < 1e-12,
                "sparse ids must not change the score: {a} vs {b}"
            );
        }
    }

    #[test]
    fn single_class_truth_degenerates_gracefully() {
        // One ground-truth class, shredded prediction: F is the best
        // per-cluster harmonic mean, purity is trivially 1, NMI is 0
        // (no information to share with a zero-entropy partition).
        let truth = vec![0, 0, 0, 0];
        let pred = vec![0, 1, 2, 3];
        // Each singleton cluster: pr = 1, re = 1/4 -> F = 2/5.
        assert!((f_measure(&pred, &truth) - 0.4).abs() < 1e-12);
        assert!((purity(&pred, &truth) - 1.0).abs() < 1e-12);
        assert!(nmi(&pred, &truth).abs() < 1e-12);
    }

    #[test]
    fn single_cluster_single_class_is_perfect() {
        // Both partitions are one block: identical, so every metric is
        // at its maximum (NMI's 0/0 is defined as 1 for this reason).
        let truth = vec![0, 0, 0];
        let pred = vec![0, 0, 0];
        assert!((f_measure(&pred, &truth) - 1.0).abs() < 1e-12);
        assert!((purity(&pred, &truth) - 1.0).abs() < 1e-12);
        assert!((nmi(&pred, &truth) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sparse_ids_do_not_panic_at_any_alignment() {
        // Large sparse ids on one side only, every degenerate pairing:
        // nothing here may panic or leave the [0, 1] range.
        let cases = [
            (vec![100, 100, 200], vec![0, 1, 1]),
            (vec![0, 1, 1], vec![100, 100, 200]),
            (vec![7], vec![3]),
            (vec![0, 50], vec![50, 0]),
        ];
        for (pred, truth) in cases {
            for v in [
                f_measure(&pred, &truth),
                purity(&pred, &truth),
                nmi(&pred, &truth),
            ] {
                assert!((0.0..=1.0).contains(&v), "{pred:?} vs {truth:?} -> {v}");
            }
        }
    }
}
