//! # mahc — Multi-stage Agglomerative Hierarchical Clustering with Cluster Size Management
//!
//! Production-oriented reproduction of *"Cluster Size Management in
//! Multi-Stage Agglomerative Hierarchical Clustering of Acoustic Speech
//! Segments"* (Lerato & Niesler, 2018).
//!
//! The crate is the Layer-3 **Rust coordinator** of a three-layer stack:
//!
//! * **Layer 1** — a Pallas wavefront DTW kernel (`python/compile/kernels/`),
//!   AOT-lowered at build time;
//! * **Layer 2** — JAX compute graphs (pairwise-DTW tile, MFCC front-end)
//!   exported as HLO-text artifacts (`python/compile/model.py`);
//! * **Layer 3** — this crate: loads the artifacts through PJRT
//!   ([`runtime`]), builds DTW distance matrices ([`distance`]), runs
//!   per-subset AHC ([`ahc`]) and the paper's iterative MAHC+M
//!   coordinator ([`mahc`]).
//!
//! Python never runs on the request path; once `make artifacts` has been
//! executed the binaries are self-contained.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`util`] | from-scratch substrates: PRNG, JSON, CSV, thread pool, CLI |
//! | [`config`] | typed experiment/algorithm configuration |
//! | [`aggregate`] | stage-0 distance-space aggregation: leader pass → m ≪ N representatives |
//! | [`dsp`] | HTK-style MFCC front-end (FFT, mel filterbank, DCT, deltas) |
//! | [`corpus`] | synthetic TIMIT-like triphone segment corpus (see DESIGN.md §5) |
//! | [`dtw`] | native DTW reference backend (classic + Sakoe-Chiba band) |
//! | [`runtime`] | PJRT client wrapper: artifact registry + executable cache |
//! | [`distance`] | condensed distance-matrix builder over pluggable backends + the cross-iteration pair cache |
//! | [`ahc`] | Ward NN-chain AHC, dendrogram, L-method, medoids |
//! | [`mahc`] | the paper's contribution: MAHC+M iterative coordinator, batch and streaming |
//! | [`metrics`] | F-measure, purity, NMI |
//! | [`telemetry`] | per-iteration history records + CSV/JSON emitters |
//! | [`baselines`] | full AHC and MAHC-without-management baselines |
//! | [`figures`] | regeneration harness for every paper table/figure |

// Style lints that fight deliberate choices in this crate: inherent
// `to_string` on the serialisers (no Display round-trip intended),
// explicit Default impls kept next to their constructors, test-local
// config mutation, and the builder's block-result tuples.
#![allow(
    clippy::inherent_to_string,
    clippy::derivable_impls,
    clippy::field_reassign_with_default,
    clippy::type_complexity
)]

pub mod aggregate;
pub mod ahc;
pub mod baselines;
pub mod config;
pub mod figures;
pub mod corpus;
pub mod distance;
pub mod dsp;
pub mod dtw;
pub mod mahc;
pub mod metrics;
pub mod runtime;
pub mod telemetry;
pub mod util;

pub use aggregate::Aggregation;
pub use config::{AggregateConfig, AlgoConfig, DatasetSpec, ServeConfig, StreamConfig};
pub use mahc::{
    MahcDriver, MahcResult, ServeDriver, ServeReport, SessionOutcome, SessionSpec, StreamResult,
    StreamSession, StreamingDriver,
};
