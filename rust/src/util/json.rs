//! Minimal JSON reader/writer (no serde in the vendor set).
//!
//! Scope: exactly what the crate needs — parsing `artifacts/manifest.json`
//! (objects, arrays, strings, numbers, booleans, null) and emitting
//! telemetry/result JSON.  Not a general-purpose validator; on malformed
//! input it returns a descriptive error rather than panicking.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Serialise to a compact JSON string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for emit-side code.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Parse a JSON document.
pub fn parse(text: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> anyhow::Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input at byte {}", self.pos))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> anyhow::Result<()> {
        let got = self.bump()?;
        if got != b {
            anyhow::bail!(
                "expected '{}' at byte {}, found '{}'",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        for &b in word.as_bytes() {
            self.expect_byte(b)?;
        }
        Ok(v)
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => anyhow::bail!("unexpected '{}' at byte {}", c as char, self.pos),
            None => anyhow::bail!("unexpected end of input"),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                c => anyhow::bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                c => anyhow::bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    c => anyhow::bail!("bad escape '\\{}'", c as char),
                },
                c if c < 0x80 => out.push(c as char),
                c => {
                    // Multi-byte UTF-8: re-decode from the raw slice.
                    let start = self.pos - 1;
                    let len = match c {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| anyhow::anyhow!("invalid utf-8 in string"))?;
                    out.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| anyhow::anyhow!("invalid utf-8 in number at byte {start}"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| anyhow::anyhow!("bad number '{text}': {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_manifest_shape() {
        let text = r#"{"format": "hlo-text", "entries": [
            {"name": "dtw_b8x8_t64_d39", "kind": "dtw", "bx": 8, "band": null},
            {"name": "mfcc_b16_s5200", "kind": "mfcc", "s": 5200}
        ]}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("format").unwrap().as_str().unwrap(), "hlo-text");
        let entries = v.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].get("bx").unwrap().as_usize().unwrap(), 8);
        assert!(entries[0].get("band").unwrap().is_null());
    }

    #[test]
    fn parse_nested_and_escapes() {
        let v = parse(r#"{"a": [1, -2.5, 3e2], "b": "x\ny\"z", "c": {"d": true}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[2].as_f64().unwrap(), 300.0);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "x\ny\"z");
        assert_eq!(v.get("c").unwrap().get("d").unwrap(), &Json::Bool(true));
    }

    #[test]
    fn emit_round_trips() {
        let v = obj(vec![
            ("iter", num(3.0)),
            ("f", num(0.5125)),
            ("name", s("small_a")),
            ("flags", arr(vec![Json::Bool(true), Json::Null])),
        ]);
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("01x").is_err());
        assert!(parse("{}extra").is_err());
    }

    #[test]
    fn unicode_string() {
        let v = parse(r#""café déjà""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café déjà");
    }
}
