//! Measurement harness used by `benches/` (no criterion in the vendor
//! set).
//!
//! Deliberately criterion-shaped: warmup phase, fixed-duration sampling,
//! and a report with mean / median / p95 plus optional throughput.  Wall
//! clock via `Instant`; each sample is one closure invocation (callers
//! batch internally when an iteration is very short).

use crate::util::json::{self, Json};
use std::time::{Duration, Instant};

/// True when env var `name` holds a truthy flag (set, non-empty, not
/// `0`).  One definition of flag truthiness for every harness knob
/// (`MAHC_BENCH_QUICK`, `MAHC_EXAMPLE_QUICK`, ...).
pub fn env_flag(name: &str) -> bool {
    std::env::var(name).is_ok_and(|v| !v.is_empty() && v != "0")
}

/// True when the CI perf-smoke quick mode is requested via
/// `MAHC_BENCH_QUICK`.  Harnesses shrink corpora / sampling windows
/// under it so the whole bench suite fits in a smoke job.
pub fn quick_mode() -> bool {
    env_flag("MAHC_BENCH_QUICK")
}

/// Write a harness's JSON report to the path named by
/// `MAHC_BENCH_JSON` (no-op when the variable is unset or empty).  The
/// CI perf-smoke job points each harness at its own fragment file and
/// assembles them into the `BENCH_ci.json` artifact.
pub fn write_json_report(report: &Json) -> std::io::Result<()> {
    if let Ok(path) = std::env::var("MAHC_BENCH_JSON") {
        if !path.is_empty() {
            std::fs::write(path, report.to_string())?;
        }
    }
    Ok(())
}

#[derive(Debug, Clone)]
pub struct BenchReport {
    pub name: String,
    pub samples: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Optional items-per-second throughput (set via [`Bench::throughput`]).
    pub throughput: Option<f64>,
}

impl BenchReport {
    /// Machine-readable form for the `BENCH_ci.json` trajectory:
    /// wall-clock stats in seconds plus throughput when declared.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("samples", json::num(self.samples as f64)),
            ("mean_secs", json::num(self.mean.as_secs_f64())),
            ("median_secs", json::num(self.median.as_secs_f64())),
            ("p95_secs", json::num(self.p95.as_secs_f64())),
            (
                "throughput",
                match self.throughput {
                    Some(t) => json::num(t),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn print(&self) {
        let tput = match self.throughput {
            Some(t) if t >= 1e6 => format!("  {:>10.2} Melem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>10.2} Kelem/s", t / 1e3),
            Some(t) => format!("  {t:>10.2} elem/s"),
            None => String::new(),
        };
        println!(
            "{:<44} {:>10} {:>10} {:>10}  n={}{}",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.median),
            fmt_dur(self.p95),
            self.samples,
            tput
        );
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Builder-style bench runner.
pub struct Bench {
    name: String,
    warmup: Duration,
    measure: Duration,
    max_samples: usize,
    elements: Option<u64>,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            max_samples: 200,
            elements: None,
        }
    }

    /// Shorter warmup/measure for expensive end-to-end cases.
    pub fn quick(mut self) -> Self {
        self.warmup = Duration::from_millis(50);
        self.measure = Duration::from_millis(700);
        self.max_samples = 30;
        self
    }

    /// Declare items processed per invocation for throughput reporting.
    pub fn throughput(mut self, elements: u64) -> Self {
        self.elements = Some(elements);
        self
    }

    pub fn warmup_time(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    pub fn measure_time(mut self, d: Duration) -> Self {
        self.measure = d;
        self
    }

    /// Run the bench.  `f` should return something observable to keep
    /// the optimiser honest; its result is black-boxed here.
    pub fn run<T, F: FnMut() -> T>(self, mut f: F) -> BenchReport {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Measure.
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && samples.len() < self.max_samples {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        if samples.is_empty() {
            // Guarantee at least one sample even for very slow cases.
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort_unstable();
        let n = samples.len();
        let mean = samples.iter().sum::<Duration>() / n as u32;
        let report = BenchReport {
            name: self.name,
            samples: n,
            mean,
            median: samples[n / 2],
            p95: samples[((n as f64 * 0.95) as usize).min(n - 1)],
            min: samples[0],
            max: samples[n - 1],
            throughput: self
                .elements
                .map(|e| e as f64 / mean.as_secs_f64()),
        };
        report.print();
        report
    }
}

/// Optimisation barrier (stable-rust equivalent of `std::hint::black_box`,
/// which we use directly since it is stable now).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_sane_stats() {
        let r = Bench::new("noop")
            .warmup_time(Duration::from_millis(5))
            .measure_time(Duration::from_millis(50))
            .run(|| 1 + 1);
        assert!(r.samples >= 1);
        assert!(r.min <= r.median && r.median <= r.max);
    }

    #[test]
    fn report_serialises_for_the_bench_trajectory() {
        let r = Bench::new("json")
            .warmup_time(Duration::from_millis(1))
            .measure_time(Duration::from_millis(10))
            .throughput(100)
            .run(|| 2 + 2);
        let j = r.to_json();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "json");
        assert!(j.get("mean_secs").unwrap().as_f64().unwrap() >= 0.0);
        assert!(j.get("throughput").unwrap().as_f64().unwrap() > 0.0);
        // A throughput-less report serialises its slot as null.
        let r2 = Bench::new("nothroughput")
            .warmup_time(Duration::from_millis(1))
            .measure_time(Duration::from_millis(5))
            .run(|| ());
        assert!(r2.to_json().get("throughput").unwrap().is_null());
        // And the whole thing parses back.
        assert!(crate::util::json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn throughput_reported() {
        let r = Bench::new("tp")
            .warmup_time(Duration::from_millis(1))
            .measure_time(Duration::from_millis(20))
            .throughput(1000)
            .run(|| std::thread::sleep(Duration::from_micros(100)));
        assert!(r.throughput.unwrap() > 0.0);
    }
}
