//! Scoped worker pool for data-parallel subset jobs (no rayon in the
//! vendor set).
//!
//! Two primitives cover every parallel site in the crate:
//!
//! * [`parallel_map`] — run a closure over an indexed range on a bounded
//!   number of OS threads and collect results in order.  Used for
//!   per-subset stage-1 AHC jobs (the paper runs the P subsets "either
//!   sequentially or in parallel") and for tile rows in the distance
//!   builder.
//! * [`WorkerPool`] — a long-lived pool with a job queue, used by the
//!   serve multiplexer (`mahc::serve`) so thread spawn cost is not paid
//!   per session step.
//!
//! The pool is built for multi-tenant use: a job that panics is caught
//! at the job boundary ([`std::panic::catch_unwind`]), so the worker
//! thread survives and the panic surfaces as an [`anyhow::Error`] to
//! the one caller that submitted the poisoned job — never as a dead
//! worker or a crash in an unrelated session.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// Number of worker threads to use by default: physical parallelism,
/// clamped to at least 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Apply `f` to every index in `0..n` on up to `threads` OS threads,
/// returning results in index order.  `f` must be `Sync` (it is shared,
/// not cloned).  Panics in `f` propagate to the caller through the
/// scope join — callers that need isolation run under a [`WorkerPool`]
/// job, whose boundary catches the unwind.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> anyhow::Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return Ok(Vec::new());
    }
    if threads == 1 {
        return Ok((0..n).map(f).collect());
    }

    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                // Work-stealing by atomic counter: cheap dynamic load
                // balance for heterogeneous subset sizes.
                let mut local: Vec<(usize, T)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i)));
                }
                let mut guard = slots.lock().unwrap_or_else(|p| p.into_inner());
                for (i, v) in local {
                    if let Some(slot) = guard.get_mut(i) {
                        *slot = Some(v);
                    }
                }
            });
        }
    });

    // The scope joins every worker before returning, and each worker
    // fills every index it claimed, so an empty slot is unreachable —
    // but degrade to an error rather than a panic if the invariant is
    // ever broken.
    out.into_iter()
        .enumerate()
        .map(|(i, v)| v.ok_or_else(|| anyhow::anyhow!("parallel_map worker missed slot {i}")))
        .collect()
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Awaitable result of one [`WorkerPool::submit`] job.
///
/// [`JobHandle::join`] blocks until the worker finishes the job and
/// returns its value — or an error if the job panicked (the panic is
/// caught at the job boundary; the worker itself survives) or the
/// worker died before reporting.
pub struct JobHandle<T> {
    rx: mpsc::Receiver<Result<T, String>>,
}

impl<T> JobHandle<T> {
    /// Wait for the job and return its result.  A panicking job yields
    /// `Err` with the panic payload; the pool keeps serving other jobs
    /// at full size either way.
    pub fn join(self) -> anyhow::Result<T> {
        match self.rx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(panic)) => Err(anyhow::anyhow!("worker job panicked: {panic}")),
            Err(_) => Err(anyhow::anyhow!(
                "worker dropped the job result before reporting"
            )),
        }
    }
}

/// Render a caught panic payload for the error path (payloads are
/// `&str` or `String` in practice; anything else gets a placeholder).
pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// A long-lived worker pool with a shared job queue.
///
/// The serve multiplexer owns one of these for a whole fleet of
/// streaming sessions; per-step jobs are submitted as closures and
/// awaited via [`JobHandle`]s.  Every job runs inside
/// [`catch_unwind`], so one session's panic cannot kill a worker or
/// leak into another session — the documented foundation of the serve
/// mode's failure-isolation contract.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl WorkerPool {
    /// Spawn `size` workers (at least one).  Fails only if the OS
    /// refuses to spawn a thread.
    pub fn new(size: usize) -> anyhow::Result<Self> {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            let handle = std::thread::Builder::new()
                .name(format!("mahc-worker-{i}"))
                .spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap_or_else(|p| p.into_inner());
                        guard.recv()
                    };
                    match job {
                        // Defence in depth: `submit` already wraps the
                        // user closure in catch_unwind, but the worker
                        // loop guards itself too so no future job
                        // constructor can re-introduce worker death.
                        Ok(job) => {
                            let _ = catch_unwind(AssertUnwindSafe(job));
                        }
                        Err(_) => break, // queue closed
                    }
                })
                .map_err(|e| anyhow::anyhow!("failed to spawn mahc-worker-{i}: {e}"))?;
            handles.push(handle);
        }
        Ok(WorkerPool {
            tx: Some(tx),
            handles,
            size,
        })
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job returning `T`; await it via [`JobHandle::join`].
    ///
    /// Errors if the pool has been [`WorkerPool::shutdown`] or every
    /// worker has exited.  A panic *inside* `f` is not an error here —
    /// it surfaces from `join` on this job's handle only.
    pub fn submit<T, F>(&self, f: F) -> anyhow::Result<JobHandle<T>>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = mpsc::channel();
        let job: Job = Box::new(move || {
            // AssertUnwindSafe: `f` is moved into the job, so a panic
            // can only abandon state the unwind itself drops; shared
            // structures the closure reaches (e.g. the pair cache)
            // recover their lock poisoning internally.
            let out = catch_unwind(AssertUnwindSafe(f)).map_err(panic_message);
            // The receiver may have been dropped; ignore send failure.
            let _ = tx.send(out);
        });
        self.queue()?
            .send(job)
            .map_err(|_| anyhow::anyhow!("worker queue closed: every worker has exited"))?;
        Ok(JobHandle { rx })
    }

    /// Submit a fire-and-forget job (no result channel).  Panics in `f`
    /// are caught at the job boundary like [`WorkerPool::submit`];
    /// callers that need completion signals send them from inside `f`.
    pub fn execute<F>(&self, f: F) -> anyhow::Result<()>
    where
        F: FnOnce() + Send + 'static,
    {
        let job: Job = Box::new(move || {
            let _ = catch_unwind(AssertUnwindSafe(f));
        });
        self.queue()?
            .send(job)
            .map_err(|_| anyhow::anyhow!("worker queue closed: every worker has exited"))
    }

    fn queue(&self) -> anyhow::Result<&mpsc::Sender<Job>> {
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("worker pool is shut down"))
    }

    /// Map a closure over `0..n` through the pool, in index order.
    /// Any panicking index fails the whole map (the caller's unit of
    /// work), but the pool itself stays healthy for other callers.
    pub fn map<T, F>(&self, n: usize, f: F) -> anyhow::Result<Vec<T>>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + Clone + 'static,
    {
        let handles: Vec<JobHandle<T>> = (0..n)
            .map(|i| {
                let f = f.clone();
                self.submit(move || f(i))
            })
            .collect::<anyhow::Result<_>>()?;
        handles.into_iter().map(|h| h.join()).collect()
    }

    /// Close the queue and join every worker.  Subsequent `submit` /
    /// `execute` / `map` calls return errors.  Called implicitly on
    /// drop; explicit shutdown lets the serve driver bound teardown.
    pub fn shutdown(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(100, 8, |i| i * i).unwrap();
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty_and_single() {
        assert!(parallel_map(0, 4, |i| i).unwrap().is_empty());
        assert_eq!(parallel_map(1, 4, |i| i + 1).unwrap(), vec![1]);
    }

    #[test]
    fn parallel_map_single_thread_fallback() {
        assert_eq!(parallel_map(5, 1, |i| i).unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn pool_executes_all_jobs() {
        let pool = WorkerPool::new(4).unwrap();
        let out = pool.map(50, |i| i * 2).unwrap();
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn pool_submit_individual() {
        let pool = WorkerPool::new(2).unwrap();
        let handle = pool.submit(|| 7).unwrap();
        assert_eq!(handle.join().unwrap(), 7);
    }

    #[test]
    fn pool_survives_many_rounds() {
        let pool = WorkerPool::new(3).unwrap();
        for round in 0..10 {
            let out = pool.map(10, move |i| i + round).unwrap();
            assert_eq!(out[9], 9 + round);
        }
    }

    #[test]
    fn panicking_job_errors_only_its_own_handle() {
        let pool = WorkerPool::new(2).unwrap();
        let bad = pool.submit(|| -> usize { panic!("injected job failure") }).unwrap();
        let good = pool.submit(|| 41usize).unwrap();
        let err = bad.join().expect_err("panicking job must surface as Err");
        assert!(err.to_string().contains("injected job failure"), "{err}");
        assert_eq!(good.join().unwrap(), 41, "sibling job is undisturbed");
    }

    #[test]
    fn pool_serves_at_full_size_after_a_panic() {
        // Regression for the pre-serve behaviour where a panicking job
        // killed its worker thread forever: afterwards, all `size`
        // workers must still be able to run jobs *concurrently*.
        let size = 4;
        let pool = WorkerPool::new(size).unwrap();
        for _ in 0..size {
            let h = pool.submit(|| -> usize { panic!("kill attempt") }).unwrap();
            assert!(h.join().is_err());
        }
        // Each job blocks until all `size` jobs have started; if any
        // worker died above, fewer than `size` can run at once and the
        // rendezvous times out.
        let started = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..size)
            .map(|_| {
                let started = Arc::clone(&started);
                pool.submit(move || {
                    started.fetch_add(1, Ordering::SeqCst);
                    let t0 = crate::telemetry::Stopwatch::start();
                    while started.load(Ordering::SeqCst) < size {
                        if t0.elapsed().as_secs() > 10 {
                            return false;
                        }
                        std::thread::yield_now();
                    }
                    true
                })
                .unwrap()
            })
            .collect();
        for h in handles {
            assert!(
                h.join().unwrap(),
                "pool lost workers after panicking jobs (rendezvous timed out)"
            );
        }
    }

    #[test]
    fn panicking_index_fails_map_but_not_the_pool() {
        let pool = WorkerPool::new(3).unwrap();
        let err = pool
            .map(8, |i| {
                if i == 5 {
                    panic!("poisoned index");
                }
                i
            })
            .expect_err("a panicking index must fail the map");
        assert!(err.to_string().contains("panicked"), "{err}");
        // The pool remains usable for the next caller.
        assert_eq!(pool.map(4, |i| i + 1).unwrap(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn submit_after_shutdown_is_an_error_not_a_panic() {
        let mut pool = WorkerPool::new(2).unwrap();
        pool.shutdown();
        let err = pool.submit(|| 1).err().expect("submit must fail");
        assert!(err.to_string().contains("shut down"), "{err}");
        assert!(pool.execute(|| ()).is_err());
        assert!(pool.map(3, |i| i).is_err());
        // Shutdown is idempotent.
        pool.shutdown();
    }

    #[test]
    fn string_and_str_panic_payloads_are_reported() {
        let pool = WorkerPool::new(1).unwrap();
        let h = pool.submit(|| -> () { panic!("{}", format!("dyn {}", 42)) }).unwrap();
        let err = h.join().unwrap_err();
        assert!(err.to_string().contains("dyn 42"), "{err}");
    }
}
