//! Scoped worker pool for data-parallel subset jobs (no rayon in the
//! vendor set).
//!
//! Two primitives cover every parallel site in the crate:
//!
//! * [`parallel_map`] — run a closure over an indexed range on a bounded
//!   number of OS threads and collect results in order.  Used for
//!   per-subset stage-1 AHC jobs (the paper runs the P subsets "either
//!   sequentially or in parallel") and for tile rows in the distance
//!   builder.
//! * [`WorkerPool`] — a long-lived pool with a job queue, used by the
//!   MAHC driver so thread spawn cost is not paid per iteration.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// Number of worker threads to use by default: physical parallelism,
/// clamped to at least 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Apply `f` to every index in `0..n` on up to `threads` OS threads,
/// returning results in index order.  `f` must be `Sync` (it is shared,
/// not cloned).  Panics in `f` propagate.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        return (0..n).map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                // Work-stealing by atomic counter: cheap dynamic load
                // balance for heterogeneous subset sizes.
                let mut local: Vec<(usize, T)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i)));
                }
                let mut guard = slots.lock().unwrap_or_else(|p| p.into_inner());
                for (i, v) in local {
                    guard[i] = Some(v);
                }
            });
        }
    });

    out.into_iter().map(|v| v.expect("worker missed slot")).collect()
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A long-lived worker pool with a shared job queue.
///
/// The MAHC driver owns one of these for the whole clustering run;
/// per-iteration stage-1 jobs are submitted as closures and awaited via
/// the returned receivers.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl WorkerPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("mahc-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap_or_else(|p| p.into_inner());
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // queue closed
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        WorkerPool {
            tx: Some(tx),
            handles,
            size,
        }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job returning `T`; await it on the returned receiver.
    pub fn submit<T, F>(&self, f: F) -> mpsc::Receiver<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = mpsc::channel();
        let job: Job = Box::new(move || {
            // The receiver may have been dropped; ignore send failure.
            let _ = tx.send(f());
        });
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(job)
            .expect("worker queue closed");
        rx
    }

    /// Map a closure over `0..n` through the pool, in index order.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + Clone + 'static,
    {
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                let f = f.clone();
                self.submit(move || f(i))
            })
            .collect();
        rxs.into_iter()
            .map(|rx| rx.recv().expect("worker dropped result"))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty_and_single() {
        assert!(parallel_map(0, 4, |i| i).is_empty());
        assert_eq!(parallel_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn parallel_map_single_thread_fallback() {
        assert_eq!(parallel_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn pool_executes_all_jobs() {
        let pool = WorkerPool::new(4);
        let out = pool.map(50, |i| i * 2);
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn pool_submit_individual() {
        let pool = WorkerPool::new(2);
        let rx = pool.submit(|| 7);
        assert_eq!(rx.recv().unwrap(), 7);
    }

    #[test]
    fn pool_survives_many_rounds() {
        let pool = WorkerPool::new(3);
        for round in 0..10 {
            let out = pool.map(10, move |i| i + round);
            assert_eq!(out[9], 9 + round);
        }
    }
}
