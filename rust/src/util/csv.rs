//! Tiny CSV emitter for the figure harness (`results/*.csv`).
//!
//! The figure harness emits one CSV per paper table/figure so series can
//! be re-plotted; fields never contain commas in practice but quoting is
//! handled anyway for robustness.

use std::io::Write;
use std::path::Path;

/// A CSV writer with a fixed header.
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new(header: &[&str]) -> Self {
        CsvWriter {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; panics if the arity does not match the header (a
    /// programming error in a harness, not a runtime condition).
    pub fn row(&mut self, fields: &[String]) {
        assert_eq!(
            fields.len(),
            self.header.len(),
            "CSV row arity {} != header arity {}",
            fields.len(),
            self.header.len()
        );
        self.rows.push(fields.to_vec());
    }

    /// Convenience: format heterogeneous displayables into a row.
    pub fn rowf(&mut self, fields: &[&dyn std::fmt::Display]) {
        let v: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&v);
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        out.push_str(&join(&self.header));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&join(r));
            out.push('\n');
        }
        out
    }

    pub fn write_to(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())?;
        Ok(())
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }
}

fn join(fields: &[String]) -> String {
    fields
        .iter()
        .map(|f| quote(f))
        .collect::<Vec<_>>()
        .join(",")
}

fn quote(f: &str) -> String {
    if f.contains(',') || f.contains('"') || f.contains('\n') {
        format!("\"{}\"", f.replace('"', "\"\""))
    } else {
        f.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_emit() {
        let mut w = CsvWriter::new(&["iter", "p", "f"]);
        w.rowf(&[&0, &4, &0.41]);
        w.rowf(&[&1, &6, &0.52]);
        assert_eq!(w.to_string(), "iter,p,f\n0,4,0.41\n1,6,0.52\n");
        assert_eq!(w.num_rows(), 2);
    }

    #[test]
    fn quoting() {
        let mut w = CsvWriter::new(&["a"]);
        w.row(&["x,y".to_string()]);
        w.row(&["he said \"hi\"".to_string()]);
        assert_eq!(w.to_string(), "a\n\"x,y\"\n\"he said \"\"hi\"\"\"\n");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["only-one".to_string()]);
    }
}
