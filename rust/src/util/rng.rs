//! Seedable PRNG + distributions (no external `rand` available).
//!
//! Core generator is xoshiro256** (Blackman & Vigna), seeded through
//! SplitMix64 so that any u64 seed produces a well-mixed state.  On top
//! of it: uniform ranges, Box-Muller normals, and the bounded Zipf
//! sampler the corpus generator uses to reproduce the paper's skewed
//! class-cardinality distributions (Fig. 3).

/// xoshiro256** — fast, high-quality, seedable; deterministic across
/// platforms (pure integer arithmetic).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box-Muller.
    spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a u64 seed (SplitMix64-expanded).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent child stream (used to give each worker /
    /// subset its own deterministic stream).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::seed_from(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) (half-open). Panics if lo >= hi.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        let span = (hi - lo) as u64;
        // Rejection sampling to avoid modulo bias.
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + (v % span) as usize;
            }
        }
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal deviate (Box-Muller, with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.range(0, i + 1);
            items.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick a uniformly random element reference.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range(0, items.len())]
    }
}

/// Bounded Zipf sampler over ranks 1..=n with exponent `s`.
///
/// Used by the corpus generator to draw class cardinalities with the
/// heavy skew of Small Set A / Medium / Large (paper Fig. 3, Table 1);
/// `s = 0` degenerates to uniform (the Small Set B shape).
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n >= 1);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap_or(&1.0); // n >= 1 asserted above
        for v in &mut cdf {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Draw a rank in [1, n].
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|p| p.total_cmp(&u)) {
            Ok(i) | Err(i) => (i + 1).min(self.cdf.len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_bounds_and_coverage() {
        let mut r = Rng::seed_from(4);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.range(0, 10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(5);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sum2 += v * v;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(6);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_skew_orders_ranks() {
        let mut r = Rng::seed_from(7);
        let z = Zipf::new(50, 1.2);
        let mut counts = [0usize; 50];
        for _ in 0..50_000 {
            counts[z.sample(&mut r) - 1] += 1;
        }
        // Rank 1 must dominate rank 10 must dominate rank 40.
        assert!(counts[0] > counts[9] && counts[9] > counts[39]);
    }

    #[test]
    fn zipf_zero_exponent_is_uniformish() {
        let mut r = Rng::seed_from(8);
        let z = Zipf::new(10, 0.0);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[z.sample(&mut r) - 1] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count {c}");
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from(9);
        let idx = r.sample_indices(100, 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }
}
