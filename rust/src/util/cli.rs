//! Tiny CLI argument parser (no clap in the vendor set).
//!
//! Supports the shapes the binaries need: a positional subcommand,
//! `--flag`, `--key value` and `--key=value`.  Unknown flags are
//! reported as errors so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments (subcommand first).
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    flags: Vec<String>,
}

impl Args {
    /// Parse raw args (excluding argv[0]).  `value_keys` lists options
    /// that take a value; anything else starting with `--` is a flag.
    pub fn parse(raw: &[String], value_keys: &[&str]) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    if !value_keys.contains(&k) {
                        anyhow::bail!("unknown option --{k}");
                    }
                    out.options.insert(k.to_string(), v.to_string());
                } else if value_keys.contains(&rest) {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("--{rest} requires a value"))?;
                    out.options.insert(rest.to_string(), v.clone());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn from_env(value_keys: &[&str]) -> anyhow::Result<Args> {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw, value_keys)
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key}={v}: {e}")),
        }
    }

    /// Parsed option with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parsed(key)?.unwrap_or(default))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(
            &raw(&["fig4", "--scale", "0.5", "--seed=7", "--verbose"]),
            &["scale", "seed"],
        )
        .unwrap();
        assert_eq!(a.subcommand(), Some("fig4"));
        assert_eq!(a.get_or("scale", 1.0).unwrap(), 0.5);
        assert_eq!(a.get_or("seed", 0u64).unwrap(), 7);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&raw(&["--scale"]), &["scale"]).is_err());
    }

    #[test]
    fn unknown_kv_option_errors() {
        assert!(Args::parse(&raw(&["--bogus=1"]), &["scale"]).is_err());
    }

    #[test]
    fn bad_parse_reports_key() {
        let a = Args::parse(&raw(&["--seed", "abc"]), &["seed"]).unwrap();
        let err = a.get_or("seed", 0u64).unwrap_err().to_string();
        assert!(err.contains("seed"), "{err}");
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&raw(&[]), &["scale"]).unwrap();
        assert_eq!(a.get_or("scale", 1.0).unwrap(), 1.0);
        assert_eq!(a.subcommand(), None);
    }
}
