//! From-scratch utility substrates.
//!
//! The build environment vendors only `xla`, `anyhow`, `thiserror` and
//! `log`, so the crate carries its own implementations of the plumbing a
//! project of this shape usually pulls from crates.io: a seedable PRNG
//! with the distributions the corpus generator needs ([`rng`]), a JSON
//! reader/writer for the artifact manifest and telemetry ([`json`]), a
//! CSV emitter for the figure harness ([`csv`]), a scoped worker pool
//! for per-subset parallelism ([`pool`]), a tiny CLI argument parser
//! ([`cli`]), and a measurement harness used by `benches/` ([`bench`]).

pub mod bench;
pub mod cli;
pub mod csv;
pub mod json;
pub mod pool;
pub mod rng;
