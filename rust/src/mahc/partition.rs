//! Initial partitioning (Algorithm 1, step 2) and the even-subdivision
//! primitive shared with the split step.

use crate::util::rng::Rng;

/// Divide ids `0..n` into `p` subsets of near-equal size, randomised by
/// `rng` (the paper divides "in accordance with available memory and
//  processors"; contents are arbitrary, so a seeded shuffle keeps runs
/// reproducible while avoiding any accidental ordering structure).
pub fn initial_partition(n: usize, p: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let ids: Vec<usize> = (0..n).collect();
    partition_ids(&ids, p, rng)
}

/// [`initial_partition`] over an explicit id list: shuffle a copy of
/// `ids` and divide it into `p` near-equal subsets.  The streaming
/// driver partitions (shard ∪ carried medoids) id sets this way; with
/// `ids == 0..n` it is exactly [`initial_partition`].
pub fn partition_ids(ids: &[usize], p: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let mut ids = ids.to_vec();
    rng.shuffle(&mut ids);
    even_partition(&ids, p)
}

/// Split an id list into `p` contiguous chunks whose sizes differ by at
/// most one.  `p` is clamped to `ids.len()` so no chunk is empty.
pub fn even_partition(ids: &[usize], p: usize) -> Vec<Vec<usize>> {
    let n = ids.len();
    if n == 0 {
        return Vec::new();
    }
    let p = p.clamp(1, n);
    let base = n / p;
    let extra = n % p;
    let mut out = Vec::with_capacity(p);
    let mut at = 0;
    for i in 0..p {
        let take = base + usize::from(i < extra);
        out.push(ids[at..at + take].to_vec());
        at += take;
    }
    debug_assert_eq!(at, n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_ids_exactly_once() {
        let mut rng = Rng::seed_from(1);
        let parts = initial_partition(103, 4, &mut rng);
        assert_eq!(parts.len(), 4);
        let mut all: Vec<usize> = parts.concat();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
    }

    #[test]
    fn sizes_differ_by_at_most_one() {
        let mut rng = Rng::seed_from(2);
        for (n, p) in [(100, 7), (5, 5), (13, 3), (8, 1)] {
            let parts = initial_partition(n, p, &mut rng);
            let sizes: Vec<usize> = parts.iter().map(|s| s.len()).collect();
            let max = *sizes.iter().max().unwrap();
            let min = *sizes.iter().min().unwrap();
            assert!(max - min <= 1, "n={n} p={p}: {sizes:?}");
        }
    }

    #[test]
    fn p_clamped_to_n() {
        let mut rng = Rng::seed_from(3);
        let parts = initial_partition(3, 10, &mut rng);
        assert_eq!(parts.len(), 3);
        assert!(parts.iter().all(|s| s.len() == 1));
    }

    #[test]
    fn deterministic_in_seed() {
        let a = initial_partition(50, 5, &mut Rng::seed_from(7));
        let b = initial_partition(50, 5, &mut Rng::seed_from(7));
        assert_eq!(a, b);
    }

    #[test]
    fn empty_input() {
        assert!(even_partition(&[], 4).is_empty());
    }

    #[test]
    fn partition_ids_matches_initial_partition_on_full_range() {
        let full: Vec<usize> = (0..64).collect();
        let a = initial_partition(64, 5, &mut Rng::seed_from(11));
        let b = partition_ids(&full, 5, &mut Rng::seed_from(11));
        assert_eq!(a, b);
    }

    #[test]
    fn partition_ids_covers_arbitrary_id_sets() {
        let ids: Vec<usize> = (0..90).filter(|i| i % 3 != 0).collect();
        let parts = partition_ids(&ids, 4, &mut Rng::seed_from(5));
        assert_eq!(parts.len(), 4);
        let mut all: Vec<usize> = parts.concat();
        all.sort_unstable();
        let mut want = ids.clone();
        want.sort_unstable();
        assert_eq!(all, want);
    }
}
