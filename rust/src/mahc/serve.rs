//! Concurrent multi-stream serve mode: many [`StreamSession`]s
//! multiplexed over one shared [`WorkerPool`] and (optionally) one
//! shared fleet [`PairCache`].
//!
//! # Scheduling model
//!
//! Each admitted session is stepped **one shard at a time** as a job on
//! the shared pool; between steps the session travels back to the
//! scheduler through a completion channel.  A session never has more
//! than one step in flight, so the per-session shard order — and with
//! it every bitwise determinism pin on [`StreamSession`] — is preserved
//! no matter how the fleet interleaves.  Concretely:
//!
//! - **Admission** — specs are considered in submission order.  The
//!   first `fleet_cap` become active, the next `queue_cap` wait in a
//!   FIFO queue (promoted as active sessions finish), and the rest are
//!   rejected deterministically.  The β guarantee composes: peak fleet
//!   matrix memory is bounded by `fleet_cap` times the largest admitted
//!   session's β(β−1)/2·4 B.
//! - **Backpressure** — at most `pool.size()` steps are in flight; when
//!   runnable sessions outnumber free workers the scheduler blocks on
//!   the completion channel and counts a stall.
//! - **Panic isolation** — each step job catches unwinds itself and
//!   reports through the channel.  A panicking step loses only its own
//!   session (the session state unwinds with the job); the pool worker
//!   survives ([`WorkerPool`] pins that) and every other session is
//!   unaffected.
//! - **Cache budgets** — with `ServeConfig::cache_bytes > 0` sessions
//!   share one fleet cache through scoped handles
//!   ([`PairCache::scoped`]): disjoint id offsets keep corpora from
//!   colliding, and each session's `algo.cache_bytes` becomes its
//!   residency budget within the shared capacity.  Cache contents never
//!   change results, so sharing is invisible to every session's output.
//!
//! Fleet telemetry ([`FleetHistory`]) samples occupancy, queue depth,
//! cache pressure and aggregate pairs/sec at every scheduler event,
//! through the same JSON machinery as per-session `RunHistory`s.
//! Event *timing* (and thus `step` interleaving in the log) is
//! nondeterministic under concurrency; session outcomes are not —
//! [`ServeReport::sessions`] is ordered by submission and each entry is
//! bitwise what a sequential run of that spec produces.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use super::streaming::{StreamResult, StreamSession};
use crate::config::{ServeConfig, StreamConfig};
use crate::corpus::SegmentSet;
use crate::distance::{PairwiseBackend, IdNamespaceError, PairCache};
use crate::telemetry::{pairs_rate, FleetHistory, FleetRecord, Stopwatch};
use crate::util::json::{self, Json};
use crate::util::pool::{panic_message, WorkerPool};

/// One session request: a corpus, its stream configuration, and an
/// optional injected fault for robustness tests.
#[derive(Clone)]
pub struct SessionSpec {
    /// Display name (fleet telemetry and the CLI table key on this).
    pub name: String,
    /// The session's corpus, shared so the spec can outlive the caller.
    pub set: Arc<SegmentSet>,
    /// Per-session stream knobs.  `algo.cache_bytes` doubles as the
    /// session's residency budget inside the shared fleet cache.
    pub cfg: StreamConfig,
    /// Fault injection: panic inside the step job once this many shards
    /// have completed.  `None` (the default) never fires; tests and the
    /// serve-smoke example use it to pin panic isolation.
    pub panic_after_shards: Option<usize>,
}

impl SessionSpec {
    pub fn new(name: &str, set: Arc<SegmentSet>, cfg: StreamConfig) -> Self {
        SessionSpec {
            name: name.to_string(),
            set,
            cfg,
            panic_after_shards: None,
        }
    }

    /// Arm the injected fault (see `panic_after_shards`).
    pub fn with_panic_after_shards(mut self, shards: usize) -> Self {
        self.panic_after_shards = Some(shards);
        self
    }
}

/// Terminal state of one submitted spec.
#[derive(Debug, Clone)]
pub struct SessionOutcome {
    /// The spec's name, copied through for reporting.
    pub name: String,
    /// The session's result, or why it produced none: rejected at
    /// admission, failed validation, errored, or panicked (the panic
    /// payload is captured as the message).
    pub result: Result<StreamResult, String>,
}

/// Everything a serve run produced: per-session outcomes in submission
/// order plus the fleet-wide event log.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub sessions: Vec<SessionOutcome>,
    pub fleet: FleetHistory,
}

impl ServeReport {
    /// Sessions that finished with a result.
    pub fn completed(&self) -> usize {
        self.sessions.iter().filter(|s| s.result.is_ok()).count()
    }

    /// Sessions that did not (rejected, errored, or panicked).
    pub fn failed(&self) -> usize {
        self.sessions.len() - self.completed()
    }

    pub fn to_json(&self) -> Json {
        let sessions = self
            .sessions
            .iter()
            .map(|s| match &s.result {
                Ok(r) => json::obj(vec![
                    ("name", json::s(&s.name)),
                    ("status", json::s("ok")),
                    ("k", json::num(r.k as f64)),
                    ("f_measure", json::num(r.f_measure)),
                    ("shards", json::num(r.shards as f64)),
                    ("pairs", json::num(r.pairs as f64)),
                    ("history", r.history.to_json()),
                ]),
                Err(e) => json::obj(vec![
                    ("name", json::s(&s.name)),
                    ("status", json::s("failed")),
                    ("error", json::s(e)),
                ]),
            })
            .collect();
        json::obj(vec![
            ("sessions", json::arr(sessions)),
            (
                "fleet",
                json::arr(self.fleet.records.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }
}

/// What a step job sends back through the completion channel.
enum StepOut {
    /// The session consumed one shard and has more to go.
    Progress(Box<StreamSession<'static>>),
    /// The session drained its stream and resolved its result.
    Done(Box<StreamResult>),
}

/// Run one step (or the final resolve) of a session inside a pool job.
/// Ownership of the session round-trips through the return value; a
/// panic drops it mid-unwind, which is exactly the isolation contract —
/// the session is lost, the worker and every other session are not.
fn step_once(
    mut session: Box<StreamSession<'static>>,
    fault: Option<usize>,
) -> anyhow::Result<StepOut> {
    if fault.is_some_and(|k| session.shards_done() >= k) {
        // lint: allow(R002) injected fault; tests pin that it is confined to its own session
        panic!(
            "injected session fault after {} shards",
            session.shards_done()
        );
    }
    match session.step()? {
        Some(_) => Ok(StepOut::Progress(session)),
        None => Ok(StepOut::Done(Box::new(session.finish()?))),
    }
}

/// Namespace admission check: reserve `n` contiguous ids starting at
/// `offset` for one session's corpus in the shared fleet cache.  The
/// pair-key id field is 32-bit per side, so the running corpus total
/// must stay inside it — typed and release-mode, because the per-pair
/// debug assertion in the cache is a tripwire, not the guard.  Returns
/// the next free offset.
fn reserve_ids(offset: usize, n: usize) -> Result<usize, IdNamespaceError> {
    match offset.checked_add(n) {
        Some(end) if end <= (1usize << 32) => Ok(end),
        _ => Err(IdNamespaceError { offset, span: n }),
    }
}

/// Scheduler gauges snapshotted into every [`FleetRecord`].
#[derive(Default)]
struct Gauges {
    active: usize,
    inflight: usize,
    completed: usize,
    failed: usize,
    rejected: usize,
    stalls: usize,
}

#[allow(clippy::too_many_arguments)]
fn sample(
    seq: usize,
    event: &str,
    session: &str,
    g: &Gauges,
    waiting: usize,
    cache_resident_bytes: usize,
    pairs_total: usize,
    wall: Duration,
) -> FleetRecord {
    FleetRecord {
        seq,
        event: event.to_string(),
        session: session.to_string(),
        active: g.active,
        waiting,
        inflight: g.inflight,
        completed: g.completed,
        failed: g.failed,
        rejected: g.rejected,
        stalls: g.stalls,
        cache_resident_bytes,
        pairs_total,
        wall_secs: wall.as_secs_f64(),
        pairs_per_sec: pairs_rate(pairs_total, wall),
    }
}

/// Multiplexes [`StreamSession`]s over a shared worker pool — see the
/// module docs for the scheduling model.
pub struct ServeDriver {
    cfg: ServeConfig,
    backend: Arc<dyn PairwiseBackend + Send + Sync>,
}

impl ServeDriver {
    /// The backend must be `Send + Sync` because session steps hop
    /// across pool workers; this rules out host-handle backends like
    /// XLA at compile time rather than at first dispatch.
    pub fn new(
        cfg: ServeConfig,
        backend: Arc<dyn PairwiseBackend + Send + Sync>,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        Ok(ServeDriver { cfg, backend })
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Drive every spec to a terminal state and return the report.
    ///
    /// Outcomes are in submission order and bitwise independent of the
    /// interleaving; only the fleet log's event timing varies run to
    /// run.
    pub fn run(&self, specs: Vec<SessionSpec>) -> anyhow::Result<ServeReport> {
        let n_specs = specs.len();
        let pool = WorkerPool::new(self.cfg.workers)?;
        let workers = pool.size();
        let fleet_cache = (self.cfg.cache_bytes > 0)
            .then(|| PairCache::with_capacity_bytes(self.cfg.cache_bytes));
        let cache_resident = |c: &Option<PairCache>| c.as_ref().map_or(0, |cache| cache.bytes());

        let t0 = Stopwatch::start();
        let mut fleet = FleetHistory::new();
        let mut seq = 0usize;
        let mut next_seq = move || {
            let s = seq;
            seq += 1;
            s
        };

        let mut results: Vec<Option<Result<StreamResult, String>>> =
            (0..n_specs).map(|_| None).collect();
        let mut names: Vec<String> = Vec::with_capacity(n_specs);
        let mut faults: Vec<Option<usize>> = Vec::with_capacity(n_specs);
        let mut pairs_seen: Vec<usize> = vec![0; n_specs];
        let mut runnable: VecDeque<(usize, Box<StreamSession<'static>>)> = VecDeque::new();
        let mut waiting: VecDeque<(usize, Box<StreamSession<'static>>)> = VecDeque::new();
        let mut g = Gauges::default();
        let mut pairs_total = 0usize;

        // Admission, in submission order.  Id namespaces in the shared
        // cache are disjoint ranges: session i's offset is the running
        // sum of all earlier corpora's sizes.
        let mut offset = 0usize;
        for (idx, spec) in specs.into_iter().enumerate() {
            names.push(spec.name.clone());
            faults.push(spec.panic_after_shards);
            let my_offset = offset;
            let n = spec.set.len();
            // A rejected spec claims no ids, so later specs still fit.
            let ns_err: Option<IdNamespaceError> = match reserve_ids(my_offset, n) {
                Ok(end) => {
                    offset = end;
                    None
                }
                Err(e) => Some(e),
            };

            let has_active_slot = g.active < self.cfg.fleet_cap;
            if !has_active_slot && waiting.len() >= self.cfg.queue_cap {
                if let Some(slot) = results.get_mut(idx) {
                    *slot = Some(Err(format!(
                        "rejected at admission: {} active sessions at the fleet cap and {} \
                         waiting at the queue cap",
                        g.active,
                        waiting.len()
                    )));
                }
                g.rejected += 1;
                fleet.push(sample(
                    next_seq(),
                    "reject",
                    &spec.name,
                    &g,
                    waiting.len(),
                    cache_resident(&fleet_cache),
                    pairs_total,
                    t0.elapsed(),
                ));
                continue;
            }

            let budget = spec.cfg.algo.cache_bytes;
            let built = (|| -> anyhow::Result<Box<StreamSession<'static>>> {
                if let Some(e) = ns_err {
                    return Err(anyhow::Error::new(e));
                }
                let mut session =
                    StreamSession::shared(spec.set, spec.cfg, Arc::clone(&self.backend))?;
                if budget > 0 {
                    if let Some(fc) = &fleet_cache {
                        session = session.with_cache(fc.scoped(my_offset, Some(budget))?);
                    }
                }
                Ok(Box::new(session))
            })();
            match built {
                Err(e) => {
                    if let Some(slot) = results.get_mut(idx) {
                        *slot = Some(Err(format!("{e:#}")));
                    }
                    g.failed += 1;
                    fleet.push(sample(
                        next_seq(),
                        "failed",
                        &spec.name,
                        &g,
                        waiting.len(),
                        cache_resident(&fleet_cache),
                        pairs_total,
                        t0.elapsed(),
                    ));
                }
                Ok(session) => {
                    let event = if has_active_slot {
                        g.active += 1;
                        runnable.push_back((idx, session));
                        "admit"
                    } else {
                        waiting.push_back((idx, session));
                        "queue"
                    };
                    fleet.push(sample(
                        next_seq(),
                        event,
                        &spec.name,
                        &g,
                        waiting.len(),
                        cache_resident(&fleet_cache),
                        pairs_total,
                        t0.elapsed(),
                    ));
                }
            }
        }

        // Event loop: keep up to `workers` steps in flight, harvest
        // completions, promote waiters as active sessions finish.
        let (tx, rx) = mpsc::channel::<(usize, Result<StepOut, String>)>();
        while results.iter().any(|r| r.is_none()) {
            while g.inflight < workers {
                let Some((idx, session)) = runnable.pop_front() else {
                    break;
                };
                let job_tx = tx.clone();
                let fault = faults.get(idx).copied().flatten();
                pool.execute(move || {
                    let out =
                        match catch_unwind(AssertUnwindSafe(move || step_once(session, fault))) {
                            Ok(Ok(v)) => Ok(v),
                            Ok(Err(e)) => Err(format!("{e:#}")),
                            Err(p) => Err(panic_message(p)),
                        };
                    let _ = job_tx.send((idx, out));
                })?;
                g.inflight += 1;
            }
            anyhow::ensure!(
                g.inflight > 0,
                "serve scheduler stuck: sessions outstanding with nothing in flight"
            );
            if !runnable.is_empty() {
                // Pool saturated with sessions still ready to step:
                // this blocking recv is the backpressure path.
                g.stalls += 1;
            }

            let (idx, out) = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("serve completion channel closed early"))?;
            g.inflight -= 1;
            let name = names.get(idx).cloned().unwrap_or_default();
            let (event, freed_slot) = match out {
                Ok(StepOut::Progress(session)) => {
                    if let Some(seen) = pairs_seen.get_mut(idx) {
                        pairs_total += session.pairs().saturating_sub(*seen);
                        *seen = session.pairs();
                    }
                    runnable.push_back((idx, session));
                    ("step", false)
                }
                Ok(StepOut::Done(result)) => {
                    if let Some(seen) = pairs_seen.get_mut(idx) {
                        pairs_total += result.pairs.saturating_sub(*seen);
                        *seen = result.pairs;
                    }
                    if let Some(slot) = results.get_mut(idx) {
                        *slot = Some(Ok(*result));
                    }
                    g.active -= 1;
                    g.completed += 1;
                    ("done", true)
                }
                Err(msg) => {
                    if let Some(slot) = results.get_mut(idx) {
                        *slot = Some(Err(msg));
                    }
                    g.active -= 1;
                    g.failed += 1;
                    ("failed", true)
                }
            };
            fleet.push(sample(
                next_seq(),
                event,
                &name,
                &g,
                waiting.len(),
                cache_resident(&fleet_cache),
                pairs_total,
                t0.elapsed(),
            ));
            if freed_slot && g.active < self.cfg.fleet_cap {
                if let Some((widx, wsession)) = waiting.pop_front() {
                    g.active += 1;
                    let wname = names.get(widx).cloned().unwrap_or_default();
                    runnable.push_back((widx, wsession));
                    fleet.push(sample(
                        next_seq(),
                        "admit",
                        &wname,
                        &g,
                        waiting.len(),
                        cache_resident(&fleet_cache),
                        pairs_total,
                        t0.elapsed(),
                    ));
                }
            }
        }
        drop(tx);

        let sessions = names
            .into_iter()
            .zip(results)
            .map(|(name, r)| SessionOutcome {
                name,
                result: r
                    .unwrap_or_else(|| Err("session never reached a terminal state".to_string())),
            })
            .collect();
        Ok(ServeReport { sessions, fleet })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgoConfig, Convergence, DatasetSpec};
    use crate::corpus::generate;
    use crate::distance::NativeBackend;
    use crate::mahc::StreamingDriver;

    fn algo(p0: usize, beta: Option<usize>, iters: usize, cache_bytes: usize) -> AlgoConfig {
        AlgoConfig {
            p0,
            beta,
            convergence: Convergence::FixedIters(iters),
            cache_bytes,
            ..Default::default()
        }
    }

    fn backend() -> Arc<dyn PairwiseBackend + Send + Sync> {
        Arc::new(NativeBackend::new())
    }

    /// A small multi-shard spec plus the sequential result it must
    /// reproduce bitwise under serve-mode interleaving.
    fn spec_and_expected(i: usize, cache_bytes: usize) -> (SessionSpec, StreamResult) {
        let set = Arc::new(generate(&DatasetSpec::tiny(56 + 8 * i, 4, 90 + i as u64)));
        let cfg = StreamConfig::new(algo(2, Some(22), 2, cache_bytes), 24);
        let expected = StreamingDriver::new(&set, cfg.clone(), &NativeBackend::new())
            .unwrap()
            .run()
            .unwrap();
        (SessionSpec::new(&format!("s{i}"), set, cfg), expected)
    }

    #[test]
    fn concurrent_fleet_reproduces_sequential_sessions_bitwise() {
        let mut specs = Vec::new();
        let mut expected = Vec::new();
        for i in 0..4 {
            let (s, e) = spec_and_expected(i, 16 << 10);
            specs.push(s);
            expected.push(e);
        }
        let driver = ServeDriver::new(
            ServeConfig {
                workers: 3,
                fleet_cap: 4,
                queue_cap: 0,
                cache_bytes: 1 << 20,
            },
            backend(),
        )
        .unwrap();
        let report = driver.run(specs).unwrap();
        assert_eq!(report.sessions.len(), 4);
        assert_eq!(report.completed(), 4);
        for (out, exp) in report.sessions.iter().zip(&expected) {
            let got = out.result.as_ref().expect("session should complete");
            assert_eq!(got.labels, exp.labels, "labels diverged for {}", out.name);
            assert_eq!(got.k, exp.k);
            assert_eq!(got.f_measure.to_bits(), exp.f_measure.to_bits());
            assert_eq!(got.shards, exp.shards);
        }
        assert!(report.fleet.peak_active() <= 4);
        let recs = &report.fleet.records;
        assert_eq!(recs.iter().filter(|r| r.event == "done").count(), 4);
        assert!(report.fleet.final_pairs_per_sec() >= 0.0);
    }

    #[test]
    fn injected_panic_fails_only_its_own_session() {
        let mut specs = Vec::new();
        let mut expected = Vec::new();
        for i in 0..4 {
            let (s, e) = spec_and_expected(i, 0);
            specs.push(s);
            expected.push(e);
        }
        // Session 1 blows up inside its second step job.
        if let Some(s) = specs.get_mut(1) {
            s.panic_after_shards = Some(1);
        }
        let driver = ServeDriver::new(
            ServeConfig {
                workers: 2,
                fleet_cap: 4,
                queue_cap: 0,
                cache_bytes: 0,
            },
            backend(),
        )
        .unwrap();
        let report = driver.run(specs).unwrap();
        assert_eq!(report.completed(), 3);
        assert_eq!(report.failed(), 1);
        for (i, (out, exp)) in report.sessions.iter().zip(&expected).enumerate() {
            if i == 1 {
                let msg = out.result.as_ref().expect_err("session 1 must fail");
                assert!(
                    msg.contains("injected session fault"),
                    "unexpected failure message: {msg}"
                );
            } else {
                let got = out.result.as_ref().expect("other sessions must survive");
                assert_eq!(got.labels, exp.labels, "bystander {} perturbed", out.name);
                assert_eq!(got.f_measure.to_bits(), exp.f_measure.to_bits());
            }
        }
        let recs = &report.fleet.records;
        assert_eq!(recs.iter().filter(|r| r.event == "failed").count(), 1);
    }

    #[test]
    fn admission_queues_then_rejects_deterministically() {
        let mut specs = Vec::new();
        let mut expected = Vec::new();
        for i in 0..3 {
            let (s, e) = spec_and_expected(i, 0);
            specs.push(s);
            expected.push(e);
        }
        let driver = ServeDriver::new(
            ServeConfig {
                workers: 2,
                fleet_cap: 1,
                queue_cap: 1,
                cache_bytes: 0,
            },
            backend(),
        )
        .unwrap();
        let report = driver.run(specs).unwrap();
        // Spec 0 admitted, spec 1 queued then promoted, spec 2 rejected.
        assert_eq!(report.completed(), 2);
        let msg = report.sessions[2]
            .result
            .as_ref()
            .expect_err("third spec must be rejected");
        assert!(msg.contains("rejected at admission"), "got: {msg}");
        for (out, exp) in report.sessions.iter().zip(&expected).take(2) {
            let got = out.result.as_ref().expect("admitted sessions complete");
            assert_eq!(got.labels, exp.labels);
        }
        assert!(report.fleet.peak_active() <= 1, "fleet cap violated");
        let recs = &report.fleet.records;
        let events: Vec<&str> = recs.iter().map(|r| r.event.as_str()).collect();
        assert!(events.contains(&"queue"));
        assert!(events.contains(&"reject"));
        // Two admissions: the initial one and the promotion.
        assert_eq!(events.iter().filter(|e| **e == "admit").count(), 2);
    }

    #[test]
    fn per_session_budgets_bound_fleet_cache_residency() {
        let budget = 2048usize; // 64 cache entries per session
        let mut specs = Vec::new();
        for i in 0..3 {
            let (s, _) = spec_and_expected(i, budget);
            specs.push(s);
        }
        let driver = ServeDriver::new(
            ServeConfig {
                workers: 3,
                fleet_cap: 3,
                queue_cap: 0,
                cache_bytes: 1 << 20,
            },
            backend(),
        )
        .unwrap();
        let report = driver.run(specs).unwrap();
        assert_eq!(report.completed(), 3);
        let peak = report.fleet.peak_cache_bytes();
        assert!(peak > 0, "sessions never touched the fleet cache");
        assert!(
            peak <= 3 * budget,
            "fleet residency {peak} exceeds the sum of session budgets {}",
            3 * budget
        );
    }

    #[test]
    fn admission_rejects_id_namespace_overflow_with_a_typed_error() {
        // Boundary: a corpus ending exactly at 2³² fits; one id more —
        // or an offset sum that would overflow usize itself — is
        // rejected with the typed error, in release builds too (the
        // per-pair key check is only a debug assertion).
        let full = 1usize << 32;
        assert_eq!(reserve_ids(0, full).unwrap(), full);
        assert_eq!(reserve_ids(full - 7, 7).unwrap(), full);
        let e = reserve_ids(full - 7, 8).unwrap_err();
        assert_eq!(e.offset, full - 7);
        assert_eq!(e.span, 8);
        assert!(e.to_string().contains("id namespace exhausted"));
        let e = reserve_ids(usize::MAX, 2).unwrap_err();
        assert_eq!(e.offset, usize::MAX);
        // Chained reservations walk the running sum exactly like serve
        // admission does.
        let mut off = 0usize;
        for n in [56, 64, 72] {
            off = reserve_ids(off, n).unwrap();
        }
        assert_eq!(off, 56 + 64 + 72);
    }

    #[test]
    fn invalid_spec_fails_alone_and_empty_fleet_is_ok() {
        let empty = ServeDriver::new(ServeConfig::default(), backend())
            .unwrap()
            .run(Vec::new())
            .unwrap();
        assert!(empty.sessions.is_empty());

        let (good, exp) = spec_and_expected(0, 0);
        let (mut bad, _) = spec_and_expected(1, 0);
        bad.cfg.shard_size = 0; // rejected by session validation
        let report = ServeDriver::new(
            ServeConfig {
                workers: 2,
                fleet_cap: 2,
                queue_cap: 0,
                cache_bytes: 0,
            },
            backend(),
        )
        .unwrap()
        .run(vec![good, bad])
        .unwrap();
        assert_eq!(report.completed(), 1);
        assert_eq!(report.failed(), 1);
        let got = report.sessions[0].result.as_ref().expect("good spec runs");
        assert_eq!(got.labels, exp.labels);
        assert!(report.sessions[1].result.is_err());
    }
}
