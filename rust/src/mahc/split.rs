//! Cluster size management (Algorithm 1 step 9) — the paper's
//! contribution — plus the merge ablation.
//!
//! *Split*: any subset whose occupancy exceeds β is subdivided "evenly
//! to ensure that the limit β is not exceeded": ⌈n/β⌉ chunks whose
//! sizes differ by at most one, over a seeded shuffle so the pieces are
//! class-mixed rather than order-biased.  This guarantees every subset
//! delivered to the next iteration satisfies the memory bound the
//! paper's β encodes.
//!
//! *Merge*: the complementary step the paper considers and rejects
//! (§7, Fig. 11: minimum occupancy never vanishes).  Kept behind
//! `AlgoConfig::merge_min` as an ablation switch.

use super::partition::even_partition;
use crate::util::rng::Rng;

/// Outcome counters for telemetry.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SplitOutcome {
    /// Subsets that exceeded β and were subdivided.
    pub subsets_split: usize,
    /// Net increase in subset count.
    pub subsets_added: usize,
}

/// Enforce β over `subsets` in place.  Deterministic given `rng` state.
///
/// Pieces are *contiguous* chunks of the member list.  The refine step
/// appends members cluster-by-cluster, so contiguous chunks keep whole
/// stage-1 clusters together and only the few clusters straddling chunk
/// boundaries are divided — the next refine re-unites them.  Set
/// `shuffle` (the ablation knob `AlgoConfig::split_shuffle`) to
/// randomise membership first instead; this scatters every class in the
/// oversized subset across all pieces — clearly worse at small scales
/// where single classes dominate subsets, within noise at larger ones
/// (see EXPERIMENTS.md §Runs ablation).
pub fn split_oversized(
    subsets: &mut Vec<Vec<usize>>,
    beta: usize,
    rng: &mut Rng,
    shuffle: bool,
) -> SplitOutcome {
    assert!(beta >= 1);
    let mut out = SplitOutcome::default();
    let mut result: Vec<Vec<usize>> = Vec::with_capacity(subsets.len());
    for mut subset in subsets.drain(..) {
        if subset.len() <= beta {
            result.push(subset);
            continue;
        }
        let parts = subset.len().div_ceil(beta);
        if shuffle {
            rng.shuffle(&mut subset);
        }
        let pieces = even_partition(&subset, parts);
        out.subsets_split += 1;
        out.subsets_added += pieces.len() - 1;
        result.extend(pieces);
    }
    *subsets = result;
    debug_assert!(subsets.iter().all(|s| s.len() <= beta));
    out
}

/// Merge ablation: absorb subsets smaller than `min_size` into the
/// smallest other subset (keeping the β bound if one is given).
/// Returns the number of merges performed.
///
/// A subset that fits nowhere under β is *set aside* and the scan
/// continues with the remaining small subsets — the historical
/// implementation pushed it back and returned immediately, silently
/// skipping every other candidate still in the queue.  Unmergeable
/// subsets rejoin the pool at the end, so membership is preserved and
/// they stay visible to the next iteration's refine step.
pub fn merge_small(
    subsets: &mut Vec<Vec<usize>>,
    min_size: usize,
    beta: Option<usize>,
) -> usize {
    let mut merges = 0;
    // Subsets proven unmergeable this pass.  They are withheld from
    // further selection (retrying them cannot succeed: candidate
    // targets only grow) but remain valid merge *inputs* conceptually —
    // appending them back at the end keeps the function idempotent.
    let mut unmergeable: Vec<Vec<usize>> = Vec::new();
    loop {
        if subsets.len() < 2 {
            break;
        }
        // Find the smallest subset below the threshold.
        let (idx, len) = match subsets
            .iter()
            .enumerate()
            .map(|(i, s)| (i, s.len()))
            .min_by_key(|&(_, l)| l)
        {
            Some(x) => x,
            None => break,
        };
        if len >= min_size {
            break;
        }
        let small = subsets.swap_remove(idx);
        // Merge into the now-smallest subset that stays within β.
        let target = subsets
            .iter()
            .enumerate()
            .filter(|(_, s)| match beta {
                Some(b) => s.len() + small.len() <= b,
                None => true,
            })
            .min_by_key(|(_, s)| s.len())
            .map(|(i, _)| i);
        match target {
            Some(t) => {
                subsets[t].extend(small);
                merges += 1;
            }
            None => {
                // No target fits within β: set this one aside and keep
                // scanning the other small subsets.
                unmergeable.push(small);
            }
        }
    }
    subsets.append(&mut unmergeable);
    merges
}

#[cfg(test)]
mod tests {
    use super::*;

    fn subset(range: std::ops::Range<usize>) -> Vec<usize> {
        range.collect()
    }

    #[test]
    fn respects_beta_exactly() {
        let mut subsets = vec![subset(0..250), subset(250..300), subset(300..1000)];
        let mut rng = Rng::seed_from(1);
        let out = split_oversized(&mut subsets, 100, &mut rng, true);
        assert!(subsets.iter().all(|s| s.len() <= 100));
        assert_eq!(out.subsets_split, 2); // 250 and 700 both split
        // 250 -> 3 pieces, 700 -> 7 pieces: added (3-1)+(7-1)=8.
        assert_eq!(out.subsets_added, 8);
        assert_eq!(subsets.len(), 3 + 8);
    }

    #[test]
    fn preserves_membership() {
        let mut subsets = vec![subset(0..777)];
        let mut rng = Rng::seed_from(2);
        split_oversized(&mut subsets, 50, &mut rng, true);
        let mut all: Vec<usize> = subsets.concat();
        all.sort_unstable();
        assert_eq!(all, (0..777).collect::<Vec<_>>());
    }

    #[test]
    fn noop_when_under_threshold() {
        let mut subsets = vec![subset(0..10), subset(10..30)];
        let before = subsets.clone();
        let out = split_oversized(&mut subsets, 100, &mut Rng::seed_from(3), true);
        assert_eq!(out, SplitOutcome::default());
        assert_eq!(subsets, before);
    }

    #[test]
    fn pieces_are_balanced() {
        let mut subsets = vec![subset(0..101)];
        split_oversized(&mut subsets, 25, &mut Rng::seed_from(4), false);
        // 101 / 25 -> 5 pieces of 20/21.
        assert_eq!(subsets.len(), 5);
        for s in &subsets {
            assert!(s.len() == 20 || s.len() == 21);
        }
    }

    #[test]
    fn deterministic() {
        let mut a = vec![subset(0..300)];
        let mut b = vec![subset(0..300)];
        split_oversized(&mut a, 70, &mut Rng::seed_from(9), true);
        split_oversized(&mut b, 70, &mut Rng::seed_from(9), true);
        assert_eq!(a, b);
    }

    #[test]
    fn merge_absorbs_small_subsets() {
        let mut subsets = vec![subset(0..2), subset(2..50), subset(50..90)];
        let merges = merge_small(&mut subsets, 5, None);
        assert_eq!(merges, 1);
        assert_eq!(subsets.len(), 2);
        let mut all: Vec<usize> = subsets.concat();
        all.sort_unstable();
        assert_eq!(all.len(), 90);
    }

    #[test]
    fn merge_respects_beta() {
        // Small subset can't merge anywhere without breaching β=40.
        let mut subsets = vec![subset(0..3), subset(3..43), subset(43..83)];
        let merges = merge_small(&mut subsets, 5, Some(40));
        assert_eq!(merges, 0);
        assert_eq!(subsets.len(), 3);
    }

    #[test]
    fn merge_continues_past_unmergeable_subsets() {
        // Two unmergeable smalls (nothing fits under β=6) plus one
        // mergeable pair: the scan must process all of them instead of
        // aborting at the first failure, and every member must survive.
        let mut subsets = vec![
            subset(0..6),   // full
            subset(6..11),  // 5 — would breach β with any small
            subset(11..15), // 4 — unmergeable (4+4=8, 4+2=6 ≤ β merges!)
            subset(15..19), // 4 — unmergeable after the 2 is absorbed
            subset(19..21), // 2 — merges into a 4 (4+2=6 ≤ β)
        ];
        let merges = merge_small(&mut subsets, 5, Some(6));
        assert_eq!(merges, 1, "only the pair fits anywhere under β");
        // Membership preserved exactly.
        let mut all: Vec<usize> = subsets.concat();
        all.sort_unstable();
        assert_eq!(all, (0..21).collect::<Vec<_>>());
        // The unmergeable 4 survived as its own subset (not dropped by
        // an early abort) and the 2 was absorbed somewhere.
        let mut sizes: Vec<usize> = subsets.iter().map(|s| s.len()).collect();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![4, 5, 6, 6]);
    }

    #[test]
    fn merge_chains_until_threshold_met() {
        let mut subsets = vec![subset(0..1), subset(1..2), subset(2..3), subset(3..100)];
        let merges = merge_small(&mut subsets, 4, None);
        assert!(merges >= 2);
        assert!(subsets.iter().all(|s| s.len() >= 3) || subsets.len() == 1);
    }
}
