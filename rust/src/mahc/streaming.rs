//! Streaming MAHC: shard-at-a-time clustering under the β bound.
//!
//! The batch driver needs the whole corpus up front; this module
//! consumes it as a sequence of bounded shards ([`Shards`]) and keeps
//! clustering state *O(shard) + O(medoids)* no matter how long the
//! stream runs:
//!
//! 1. **Episode** — each arriving shard is clustered together with the
//!    carried-forward medoid set by one episode of the batch iteration
//!    loop ([`run_episode`]): same stage 1, same L-method, same β
//!    enforcement via `split_oversized`, same optional `merge_small`.
//!    Peak matrix bytes therefore stay bounded by β(β−1)/2·4 B exactly
//!    as in batch runs.
//! 2. **Carry** — the final iteration's stage-1 medoids become the
//!    carried set for the next shard.  Because the L-method caps each
//!    subset's clusters at `max_clusters_frac`·n, the carried set
//!    reaches a bounded fixed point (≈ frac/(1−frac) · shard_size)
//!    instead of growing with the stream.
//! 0. **Aggregate (optional)** — with `AlgoConfig::aggregate` active,
//!    the stage-0 leader pass ([`crate::aggregate`]) runs once up
//!    front and the *stream consists of representatives*: shards are
//!    drawn from the m leaders instead of the N raw segments, and every
//!    member attaches to its leader through the same forwarding pointer
//!    retirement uses.  ε = 0 skips the pass, bitwise.
//! 3. **Retire** — every active object that is *not* carried forward is
//!    assigned to its nearest surviving medoid via the medoid × batch
//!    rectangle ([`build_cross_cached`]): with the pair cache enabled,
//!    medoid–member pairs computed by the episode's condensed builds
//!    are served from cache instead of reaching the DTW backend again.
//!    The assignment is a forwarding pointer; when later episodes merge
//!    medoids, retired members follow transitively.
//!
//! # Session state machine
//!
//! The loop above is factored into a resumable per-session state
//! machine, [`StreamSession`]: `step()` consumes one shard and returns
//! that shard's [`IterationRecord`]; carry/retire/attach state lives in
//! the session; `finish()` drains any remaining shards and resolves the
//! forwarding chains into the final [`StreamResult`].
//! [`StreamingDriver::run`] is a thin loop over one session, so every
//! bitwise pin on the blocking driver holds for stepped execution too —
//! and the serve multiplexer ([`crate::mahc::serve`]) interleaves many
//! sessions' steps over one worker pool and one shared [`PairCache`]
//! without perturbing any of them (cache contents never change
//! results).
//!
//! A single shard containing the whole corpus runs exactly one episode
//! with an empty carried set and the same RNG stream as the batch
//! driver, so its labels, K and F-measure are bitwise identical to
//! [`MahcDriver::run`] — pinned by tests here and in
//! `rust/tests/pipeline.rs`.
//!
//! [`MahcDriver::run`]: super::MahcDriver::run

use std::sync::Arc;

use super::driver::{run_episode, EpisodeOutcome};
use crate::aggregate;
use crate::config::{PruneMode, StreamConfig};
use crate::corpus::{Segment, SegmentSet, Shards};
use crate::distance::{
    build_cross_cached, build_cross_cached_pruned, CascadeBackend, CascadeMode, PairwiseBackend,
    PairCache,
};
use crate::metrics;
use crate::telemetry::{
    pairs_rate, CacheStats, IterationRecord, PruneStats, RunHistory, Stopwatch,
};
use crate::util::rng::Rng;

/// Final output of a streaming clustering run.
#[derive(Debug, Clone)]
pub struct StreamResult {
    /// Final cluster label per segment id (dense, 0..k).
    pub labels: Vec<usize>,
    /// Final number of clusters K.
    pub k: usize,
    /// F-measure of the final clustering against ground truth.
    pub f_measure: f64,
    /// One [`IterationRecord`] per shard: `iteration` is the shard
    /// index, `carried_medoids` the carried set entering that shard,
    /// occupancy/splits/peak-bytes aggregated over the shard's episode.
    pub history: RunHistory,
    /// Number of shards the stream delivered.
    pub shards: usize,
    /// Pair-cache counters of the retirement rectangles alone (subset
    /// of the per-shard totals): nonzero hits here mean medoid × batch
    /// assignment was served from pairs the episodes already computed.
    pub assign_cache: CacheStats,
    /// Total pair distances produced across the stream (episode builds
    /// plus retirement rectangles; cache hits included) — the numerator
    /// of fleet-level pairs/sec accounting in serve mode.
    pub pairs: usize,
}

/// Corpus handle: borrowed for the in-process driver, shared for
/// sessions that must be `'static + Send` (serve-mode pool jobs).
enum SetRef<'a> {
    Borrowed(&'a SegmentSet),
    Shared(Arc<SegmentSet>),
}

impl SetRef<'_> {
    fn get(&self) -> &SegmentSet {
        match self {
            SetRef::Borrowed(s) => s,
            SetRef::Shared(s) => s,
        }
    }
}

/// Backend handle, mirroring [`SetRef`].  The `Owned` variant holds the
/// session's private [`CascadeBackend`] pruning wrapper (its envelope
/// table and counters belong to this session alone); `PairwiseBackend: Sync`
/// and the cascade's inner handle is a shared/borrowed reference, so the
/// box is `Send + Sync` for any lifetime and `StreamSession<'static>`
/// stays movable into worker-pool jobs.
enum BackendRef<'a> {
    Borrowed(&'a dyn PairwiseBackend),
    Shared(Arc<dyn PairwiseBackend + Send + Sync>),
    Owned(Box<dyn PairwiseBackend + Send + Sync + 'a>),
}

impl BackendRef<'_> {
    fn get(&self) -> &dyn PairwiseBackend {
        match self {
            BackendRef::Borrowed(b) => *b,
            BackendRef::Shared(b) => b.as_ref(),
            BackendRef::Owned(b) => b.as_ref(),
        }
    }
}

/// Stream-position state built lazily on the first step (stage-0
/// aggregation runs here, so constructing a session — e.g. while queued
/// for admission — costs nothing).
struct Prepared {
    agg: Option<aggregate::Aggregation>,
    /// Leader-probe counter movement, folded into shard 0's record so
    /// the stream's cache totals include the pass that warmed it.
    agg_cache: CacheStats,
    /// Cascade counter movement of the leader pass, folded into shard
    /// 0's record like `agg_cache` (all zero when pruning is off).
    agg_prune: PruneStats,
    /// Per-segment group counts for count-weighted stage 1 (`None`
    /// when aggregation collapsed nothing — the bitwise plain path).
    counts: Option<Vec<usize>>,
    rng: Rng,
    plan: Shards,
    total_shards: usize,
    /// Next shard index.
    t: usize,
    /// Forwarding pointer per segment id: the medoid a retired object
    /// was assigned to, or the leader an aggregated member follows
    /// (usize::MAX while unset / still active).  Resolved transitively
    /// once the stream ends.
    attach: Vec<usize>,
    carried: Vec<usize>,
    last_episode: Option<(Vec<usize>, EpisodeOutcome)>,
}

/// Resumable per-session streaming state machine: feed a shard with
/// [`StreamSession::step`], get back that shard's [`IterationRecord`];
/// resolve the run with [`StreamSession::finish`].
///
/// Constructed over borrowed state by [`StreamSession::new`] (the
/// [`StreamingDriver`] path) or over `Arc`-shared state by
/// [`StreamSession::shared`], which yields a `StreamSession<'static>`
/// that is `Send` — movable into worker-pool jobs by the serve
/// multiplexer.
pub struct StreamSession<'a> {
    set: SetRef<'a>,
    cfg: StreamConfig,
    backend: BackendRef<'a>,
    /// Private per-session cache (from `algo.cache_bytes`), or a scoped
    /// handle onto a shared fleet cache installed via
    /// [`StreamSession::with_cache`].
    cache: Option<PairCache>,
    history: RunHistory,
    assign_cache: CacheStats,
    pairs: usize,
    state: Option<Prepared>,
    done: bool,
}

impl<'a> StreamSession<'a> {
    /// Session over borrowed corpus + backend (single-tenant use).
    pub fn new(
        set: &'a SegmentSet,
        cfg: StreamConfig,
        backend: &'a dyn PairwiseBackend,
    ) -> anyhow::Result<Self> {
        Self::from_parts(SetRef::Borrowed(set), cfg, BackendRef::Borrowed(backend))
    }

    fn from_parts(
        set: SetRef<'a>,
        cfg: StreamConfig,
        backend: BackendRef<'a>,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        if set.get().is_empty() {
            anyhow::bail!("empty dataset");
        }
        let algo = &cfg.algo;
        let base_name = if algo.beta.is_some() {
            "mahc+m-stream"
        } else {
            "mahc-stream"
        };
        let algo_name = if algo.aggregate.is_active() {
            format!("{base_name}+agg")
        } else {
            base_name.to_string()
        };
        let history = RunHistory::new(&set.get().name, &algo_name);
        let cache =
            (algo.cache_bytes > 0).then(|| PairCache::with_capacity_bytes(algo.cache_bytes));
        // Lower-bound pruning cascade: wrap whatever handle we were
        // given, so the leader pass and the retirement argmin can bound
        // pairs out before the DTW recurrence (off = the raw handle,
        // the bitwise reference).
        let backend = if algo.prune.is_active() {
            let mode = match algo.prune {
                PruneMode::Debug => CascadeMode::Debug,
                _ => CascadeMode::On,
            };
            let boxed: Box<dyn PairwiseBackend + Send + Sync + 'a> = match backend {
                BackendRef::Borrowed(b) => {
                    Box::new(CascadeBackend::borrowed(b, set.get(), mode))
                }
                BackendRef::Shared(b) => Box::new(CascadeBackend::shared(b, set.get(), mode)),
                BackendRef::Owned(b) => b,
            };
            BackendRef::Owned(boxed)
        } else {
            backend
        };
        Ok(StreamSession {
            set,
            cfg,
            backend,
            cache,
            history,
            assign_cache: CacheStats::default(),
            pairs: 0,
            state: None,
            done: false,
        })
    }

    /// Replace the session's cache with `cache` — typically a scoped,
    /// budgeted handle onto a shared fleet cache
    /// ([`PairCache::scoped`]).  Call before the first `step()`;
    /// because cache contents never change results, the swap affects
    /// hit rates and residency accounting only.
    pub fn with_cache(mut self, cache: PairCache) -> Self {
        self.cache = Some(cache);
        self
    }

    pub fn config(&self) -> &StreamConfig {
        &self.cfg
    }

    /// The session's cache handle, if caching is enabled.
    pub fn cache(&self) -> Option<&PairCache> {
        self.cache.as_ref()
    }

    /// Shards consumed so far.
    pub fn shards_done(&self) -> usize {
        self.state.as_ref().map_or(0, |s| s.t)
    }

    /// Total shards the plan yields (known after the first step).
    pub fn total_shards(&self) -> Option<usize> {
        self.state.as_ref().map(|s| s.total_shards)
    }

    /// Whether the stream is exhausted (`step()` would return `None`).
    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Pair distances produced so far (episodes + retirement).
    pub fn pairs(&self) -> usize {
        self.pairs
    }

    /// Per-shard records pushed so far.
    pub fn history(&self) -> &RunHistory {
        &self.history
    }

    /// Stage 0 + stream planning; runs once, on the first step.
    fn prepare(&self) -> anyhow::Result<Prepared> {
        let set = self.set.get();
        let algo = &self.cfg.algo;
        let backend = self.backend.get();
        let cache = self.cache.as_ref();

        // Stage 0: leader-pass aggregation over the whole corpus, so
        // the *stream consists of representatives* (ε = 0 skips this
        // and the stream is bitwise the historical one).  Members
        // attach to their leader up front — the same forwarding-pointer
        // mechanism retirement uses — and resolve transitively with the
        // retired objects once the stream ends.
        let agg_snapshot = cache.map(|c| c.stats()).unwrap_or_default();
        let agg_prune_snapshot = backend.prune_stats().unwrap_or_default();
        let agg = algo
            .aggregate
            .is_active()
            .then(|| aggregate::aggregate(set, &algo.aggregate, backend, algo.threads, cache))
            .transpose()?;
        let agg_cache = cache
            .map(|c| c.stats().delta(&agg_snapshot))
            .unwrap_or_default();
        let agg_prune = backend
            .prune_stats()
            .unwrap_or_default()
            .delta(&agg_prune_snapshot);
        let m = agg.as_ref().map_or(set.len(), |a| a.reps());
        // The corpus is nonempty (rejected at construction), so the
        // leader pass must elect at least one representative: every
        // segment either becomes a leader or joins one.
        anyhow::ensure!(
            m > 0,
            "aggregation over a nonempty corpus produced no representatives"
        );

        // Debug-mode admissibility recheck, mirroring the batch driver:
        // recluster the full corpus and verify the representative run's
        // merge heights stay within the reported deviation bound.
        if algo.deviation.is_debug() {
            if let Some(a) = &agg {
                aggregate::check_deviation(set, a, backend, algo.threads, cache)?;
            }
        }

        // Count-weighted stage 1: each representative enters every
        // episode's linkage carrying its group's mass (None when
        // nothing collapsed, keeping the plain path bitwise).
        let counts: Option<Vec<usize>> = agg.as_ref().and_then(|a| {
            if a.members.iter().all(|g| g.len() <= 1) {
                return None;
            }
            let mut c = vec![1usize; set.len()];
            for (pos, &rep) in a.rep_ids.iter().enumerate() {
                c[rep] = a.members[pos].len().max(1); // lint: in-bounds rep ids and member groups come from the same pass
            }
            Some(c)
        });

        // Seeded *after* aggregation so the episode RNG stream is
        // identical whether or not stage 0 ran.
        let rng = Rng::seed_from(algo.seed);
        let plan = Shards::new(m, self.cfg.shard_size, self.cfg.shard_seed);
        let total_shards = plan.total();

        let mut attach: Vec<usize> = vec![usize::MAX; set.len()];
        if let Some(a) = &agg {
            for (pos, &rep) in a.rep_ids.iter().enumerate() {
                for &id in &a.members[pos] {
                    if id != rep {
                        attach[id] = rep;
                    }
                }
            }
        }
        Ok(Prepared {
            agg,
            agg_cache,
            agg_prune,
            counts,
            rng,
            plan,
            total_shards,
            t: 0,
            attach,
            carried: Vec::new(),
            last_episode: None,
        })
    }

    /// Consume the next shard: run its episode, retire non-carried
    /// objects, and return the shard's telemetry record — or `None`
    /// when the stream is exhausted.
    pub fn step(&mut self) -> anyhow::Result<Option<IterationRecord>> {
        if self.done {
            return Ok(None);
        }
        if self.state.is_none() {
            self.state = Some(self.prepare()?);
        }
        let Some(st) = self.state.as_mut() else {
            anyhow::bail!("session state missing after prepare");
        };
        let Some(shard) = st.plan.next() else {
            self.done = true;
            return Ok(None);
        };
        let set = self.set.get();
        let backend = self.backend.get();
        let algo = &self.cfg.algo;
        let cache = self.cache.as_ref();
        let n = set.len();
        let t = st.t;
        let total_shards = st.total_shards;

        let t0 = Stopwatch::start();
        let carried_in = st.carried.len();
        // Shard entries are stream positions 0..m; map them to global
        // segment ids (identity when aggregation is off).
        let active: Vec<usize> = st
            .carried
            .iter()
            .copied()
            .chain(shard.iter().map(|&p| match &st.agg {
                Some(a) => a.rep_ids[p],
                None => p,
            }))
            .collect();

        let shard_snapshot = cache.map(|c| c.stats()).unwrap_or_default();
        let prune_snapshot = backend.prune_stats().unwrap_or_default();
        let ep = run_episode(
            set,
            &active,
            algo,
            backend,
            cache,
            st.counts.as_deref(),
            &mut st.rng,
            None,
        )?;

        let mut rect_bytes = 0usize;
        let mut rect_pairs = 0usize;
        let mut rect_delta = CacheStats::default();
        if t + 1 < total_shards {
            // Retire: everything not carried forward follows its
            // nearest surviving medoid (medoid × batch rectangle).
            let mut is_medoid = vec![false; n];
            for &m in &ep.medoid_ids {
                is_medoid[m] = true;
            }
            let retired: Vec<usize> =
                active.iter().copied().filter(|&id| !is_medoid[id]).collect();
            if !retired.is_empty() {
                let xs: Vec<&Segment> =
                    ep.medoid_ids.iter().map(|&i| &set.segments[i]).collect();
                let ys: Vec<&Segment> = retired.iter().map(|&i| &set.segments[i]).collect();
                let rect_snapshot = cache.map(|c| c.stats()).unwrap_or_default();
                // Column argmin over the rows=medoids rectangle,
                // walking each row contiguously.  Strict < on rows in
                // increasing order keeps ties on the first medoid —
                // deterministic under any thread count.
                let ny = ys.len();
                let mut best = vec![0usize; ny];
                let mut best_d = vec![f32::INFINITY; ny];
                if backend.supports_pruning() {
                    // Row-cascaded argmin: each medoid row prunes
                    // against the loosest per-column incumbent so far.
                    // A bound-answered cell carries lb > max_j best_d[j]
                    // ≥ best_d[j], so it loses the strict < exactly as
                    // its exact value would — selections are bitwise
                    // the one-rectangle path's.
                    for (i, x) in xs.iter().enumerate() {
                        let threshold = if i == 0 {
                            None
                        } else {
                            let mut t = 0.0f32;
                            for &b in &best_d {
                                t = t.max(b);
                            }
                            Some(t)
                        };
                        let row = build_cross_cached_pruned(
                            &[*x],
                            &ys,
                            backend,
                            algo.threads,
                            cache,
                            threshold,
                        )?;
                        anyhow::ensure!(
                            row.len() == ny,
                            "backend returned {} retirement distances for {} objects",
                            row.len(),
                            ny
                        );
                        for ((bd, b), &v) in
                            best_d.iter_mut().zip(best.iter_mut()).zip(&row)
                        {
                            if v < *bd {
                                *bd = v;
                                *b = i;
                            }
                        }
                    }
                } else {
                    let d = build_cross_cached(&xs, &ys, backend, algo.threads, cache)?;
                    for (i, row) in d.chunks_exact(ny).enumerate() {
                        for (j, &v) in row.iter().enumerate() {
                            if v < best_d[j] {
                                best_d[j] = v;
                                best[j] = i;
                            }
                        }
                    }
                }
                if let Some(c) = cache {
                    rect_delta = c.stats().delta(&rect_snapshot);
                }
                rect_pairs = xs.len() * ys.len();
                rect_bytes = rect_pairs * std::mem::size_of::<f32>();
                for (j, &id) in retired.iter().enumerate() {
                    st.attach[id] = ep.medoid_ids[best[j]];
                }
            }
            st.carried = ep.medoid_ids.clone();
        }
        self.assign_cache.hits += rect_delta.hits;
        self.assign_cache.misses += rect_delta.misses;
        self.assign_cache.evictions += rect_delta.evictions;

        let mut shard_delta = match cache {
            Some(c) => c.stats().delta(&shard_snapshot),
            None => CacheStats::default(),
        };
        if t == 0 {
            shard_delta.hits += st.agg_cache.hits;
            shard_delta.misses += st.agg_cache.misses;
            shard_delta.evictions += st.agg_cache.evictions;
        }
        // Cascade counters for this shard; the stage-0 aggregation
        // pass's counters fold into the first shard's record, mirroring
        // the agg_cache treatment above.
        let mut prune_delta = backend
            .prune_stats()
            .unwrap_or_default()
            .delta(&prune_snapshot);
        if t == 0 {
            prune_delta.lb_pairs += st.agg_prune.lb_pairs;
            prune_delta.lb_pruned += st.agg_prune.lb_pruned;
            prune_delta.exact_pairs += st.agg_prune.exact_pairs;
        }
        // Stage-0 probe-engine stamps, carried by the first shard's
        // record only (the pass runs once, before the stream).
        let (probe_rounds, rect_rows, rect_cols, supers, eps_eff) = match (&st.agg, t) {
            (Some(a), 0) => (
                a.probe_rounds,
                a.rect_rows,
                a.rect_cols,
                a.super_leaders,
                a.epsilon as f64,
            ),
            _ => (0, 0, 0, 0, 0.0),
        };
        let wall = t0.elapsed();
        let record = IterationRecord {
            iteration: t,
            subsets: ep.summary.final_subsets,
            max_occupancy: ep.summary.max_occupancy,
            min_occupancy: ep.summary.min_occupancy,
            max_occupancy_pre_split: ep.summary.max_occupancy_pre_split,
            splits: ep.summary.splits,
            total_clusters: ep.summary.total_clusters,
            f_measure: ep.f_measure,
            wall,
            peak_matrix_bytes: ep.summary.peak_matrix_bytes.max(rect_bytes),
            cache: shard_delta,
            carried_medoids: carried_in,
            representatives: st.agg.as_ref().map_or(0, |a| a.reps()),
            compression_ratio: st.agg.as_ref().map_or(1.0, |a| a.compression_ratio()),
            assignment_pairs: match (&st.agg, t) {
                (Some(a), 0) => a.probe_pairs,
                _ => 0,
            },
            sample_pairs: match (&st.agg, t) {
                (Some(a), 0) => a.sample_pairs,
                _ => 0,
            },
            sample_segments: match (&st.agg, t) {
                (Some(a), 0) => a.sample_segments,
                _ => 0,
            },
            lb_pairs: prune_delta.lb_pairs,
            lb_pruned: prune_delta.lb_pruned,
            exact_pairs: prune_delta.exact_pairs,
            probe_rounds,
            probe_rect_rows: rect_rows,
            probe_rect_cols: rect_cols,
            super_leaders: supers,
            aggregate_epsilon: eps_eff,
            deviation_bound: match (&st.agg, t) {
                (Some(a), 0) => a.deviation_bound(),
                _ => 0.0,
            },
            backend: backend.name().to_string(),
            // Shard throughput counts the episode's pairs plus the
            // retirement rectangle's.
            pairs_per_sec: pairs_rate(ep.summary.pairs + rect_pairs, wall),
            metric: backend.metric_name().to_string(),
            silhouette_score: ep.summary.silhouette,
        };
        self.pairs += ep.summary.pairs + rect_pairs;
        self.history.push(record.clone());
        st.last_episode = Some((active, ep));
        st.t += 1;
        if st.t >= total_shards {
            self.done = true;
        }
        Ok(Some(record))
    }

    /// Drain any remaining shards and resolve the stream: final labels
    /// via the forwarding chains, final K and F-measure.
    pub fn finish(mut self) -> anyhow::Result<StreamResult> {
        while self.step()?.is_some() {}
        let st = self
            .state
            .take()
            .ok_or_else(|| anyhow::anyhow!("stream delivered no shards"))?;
        let (final_active, final_ep) = st
            .last_episode
            .ok_or_else(|| anyhow::anyhow!("stream delivered no shards"))?;
        let set = self.set.get();
        let n = set.len();

        // Labels of the final episode's active objects, by segment id.
        let mut labels = vec![usize::MAX; n];
        for (pos, &id) in final_active.iter().enumerate() {
            labels[id] = final_ep.labels[pos];
        }

        // Quality-bump retirement (`--retire medoid`): aggregated
        // members re-home to their nearest *final* medoid instead of
        // inheriting their stage-0 leader's label — one rectangle over
        // segments the leader pass never compared, trading probe work
        // for assignment accuracy.  Leader mode (the default) skips
        // this block entirely and stays the bitwise forwarding oracle.
        if self.cfg.algo.retire.is_medoid() {
            if let Some(a) = &st.agg {
                let pending: Vec<usize> = a
                    .rep_ids
                    .iter()
                    .enumerate()
                    .flat_map(|(pos, &rep)| {
                        a.members[pos].iter().copied().filter(move |&id| id != rep) // lint: in-bounds groups are parallel to rep_ids
                    })
                    .filter(|&id| labels[id] == usize::MAX) // lint: in-bounds labels is sized n
                    .collect();
                if !pending.is_empty() {
                    let backend = self.backend.get();
                    let cache = self.cache.as_ref();
                    let threads = self.cfg.algo.threads;
                    let xs: Vec<&Segment> = final_ep
                        .medoid_ids
                        .iter()
                        .map(|&i| &set.segments[i]) // lint: in-bounds pending holds segment ids
                        .collect();
                    let ys: Vec<&Segment> =
                        pending.iter().map(|&i| &set.segments[i]).collect(); // lint: in-bounds pending holds segment ids
                    let rect_snapshot = cache.map(|c| c.stats()).unwrap_or_default();
                    // Column argmin, strict < over rows in increasing
                    // order — the same deterministic tie rule as the
                    // per-shard retirement rectangle in `step()`.
                    let ny = ys.len();
                    let mut best = vec![0usize; ny];
                    let mut best_d = vec![f32::INFINITY; ny];
                    if backend.supports_pruning() {
                        for (i, x) in xs.iter().enumerate() {
                            let threshold = if i == 0 {
                                None
                            } else {
                                let mut t = 0.0f32;
                                for &b in &best_d {
                                    t = t.max(b);
                                }
                                Some(t)
                            };
                            let row = build_cross_cached_pruned(
                                &[*x],
                                &ys,
                                backend,
                                threads,
                                cache,
                                threshold,
                            )?;
                            anyhow::ensure!(
                                row.len() == ny,
                                "backend returned {} medoid-retirement distances for {} objects",
                                row.len(),
                                ny
                            );
                            for ((bd, b), &v) in
                                best_d.iter_mut().zip(best.iter_mut()).zip(&row)
                            {
                                if v < *bd {
                                    *bd = v;
                                    *b = i;
                                }
                            }
                        }
                    } else {
                        let d = build_cross_cached(&xs, &ys, backend, threads, cache)?;
                        for (i, row) in d.chunks_exact(ny).enumerate() {
                            for (j, &v) in row.iter().enumerate() {
                                if v < best_d[j] { // lint: in-bounds best_d is sized pending.len()
                                    best_d[j] = v; // lint: in-bounds best_d is sized pending.len()
                                    best[j] = i; // lint: in-bounds best is sized pending.len()
                                }
                            }
                        }
                    }
                    if let Some(c) = cache {
                        let delta = c.stats().delta(&rect_snapshot);
                        self.assign_cache.hits += delta.hits;
                        self.assign_cache.misses += delta.misses;
                        self.assign_cache.evictions += delta.evictions;
                    }
                    self.pairs += xs.len() * ny;
                    for (j, &id) in pending.iter().enumerate() {
                        labels[id] = labels[final_ep.medoid_ids[best[j]]]; // lint: in-bounds best[j] picks a final medoid; labels is sized n
                    }
                }
            }
        }

        // Retired objects follow their forwarding chain: each hop lands
        // on a medoid that stayed active at least one more shard, so
        // every chain terminates at a finally-labelled object.
        // Aggregated members prepend one hop (member → leader) to their
        // leader's chain, hence the +1 on the bound.
        let max_hops = st.total_shards + usize::from(st.agg.is_some());
        let attach = st.attach;
        for id in 0..n {
            if labels[id] != usize::MAX {
                continue;
            }
            let mut cur = id;
            let mut hops = 0usize;
            while labels[cur] == usize::MAX {
                anyhow::ensure!(
                    attach[cur] != usize::MAX,
                    "segment {cur} neither labelled nor attached"
                );
                cur = attach[cur];
                hops += 1;
                anyhow::ensure!(
                    hops <= max_hops,
                    "forwarding chain longer than the stream"
                );
            }
            labels[id] = labels[cur];
        }

        let f_measure = metrics::f_measure(&labels, &set.labels());
        Ok(StreamResult {
            labels,
            k: final_ep.k,
            f_measure,
            history: self.history,
            shards: st.total_shards,
            assign_cache: self.assign_cache,
            pairs: self.pairs,
        })
    }
}

impl StreamSession<'static> {
    /// Session over `Arc`-shared corpus + backend: the result is
    /// `'static` and `Send`, movable into worker-pool jobs (the serve
    /// multiplexer's unit of scheduling).
    pub fn shared(
        set: Arc<SegmentSet>,
        cfg: StreamConfig,
        backend: Arc<dyn PairwiseBackend + Send + Sync>,
    ) -> anyhow::Result<Self> {
        Self::from_parts(SetRef::Shared(set), cfg, BackendRef::Shared(backend))
    }
}

/// Shard-at-a-time MAHC over a [`Shards`] stream: a thin blocking loop
/// over one [`StreamSession`].
pub struct StreamingDriver<'a> {
    set: &'a SegmentSet,
    cfg: StreamConfig,
    backend: &'a dyn PairwiseBackend,
}

impl<'a> StreamingDriver<'a> {
    pub fn new(
        set: &'a SegmentSet,
        cfg: StreamConfig,
        backend: &'a dyn PairwiseBackend,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        if set.is_empty() {
            anyhow::bail!("empty dataset");
        }
        Ok(StreamingDriver { set, cfg, backend })
    }

    pub fn config(&self) -> &StreamConfig {
        &self.cfg
    }

    /// Consume the whole stream; returns the final clustering + one
    /// telemetry record per shard.
    pub fn run(&self) -> anyhow::Result<StreamResult> {
        StreamSession::new(self.set, self.cfg.clone(), self.backend)?.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgoConfig, Convergence, DatasetSpec};
    use crate::corpus::generate;
    use crate::distance::NativeBackend;
    use crate::mahc::MahcDriver;

    fn algo(p0: usize, beta: Option<usize>, iters: usize) -> AlgoConfig {
        AlgoConfig {
            p0,
            beta,
            convergence: Convergence::FixedIters(iters),
            ..Default::default()
        }
    }

    #[test]
    fn single_shard_is_bitwise_equal_to_batch() {
        let set = generate(&DatasetSpec::tiny(90, 6, 41));
        let backend = NativeBackend::new();
        let cfg = algo(3, Some(30), 3);
        let batch = MahcDriver::new(&set, cfg.clone(), &backend)
            .unwrap()
            .run()
            .unwrap();
        // Shard large enough to hold the whole corpus → one episode,
        // empty carried set, same RNG stream as the batch driver.
        let stream = StreamingDriver::new(&set, StreamConfig::new(cfg, set.len()), &backend)
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(stream.shards, 1);
        assert_eq!(stream.labels, batch.labels);
        assert_eq!(stream.k, batch.k);
        assert_eq!(stream.f_measure, batch.f_measure);
    }

    #[test]
    fn multi_shard_respects_beta_and_labels_everyone() {
        let set = generate(&DatasetSpec::tiny(120, 6, 42));
        let backend = NativeBackend::new();
        let beta = 25;
        let stream = StreamingDriver::new(
            &set,
            StreamConfig::new(algo(2, Some(beta), 3), 40),
            &backend,
        )
        .unwrap()
        .run()
        .unwrap();
        assert_eq!(stream.shards, 3);
        assert_eq!(stream.history.records.len(), 3);
        assert_eq!(stream.labels.len(), 120);
        assert!(stream.k >= 1);
        assert!(stream.labels.iter().all(|&l| l < stream.k));
        assert!(stream.f_measure > 0.0 && stream.f_measure <= 1.0);
        for r in &stream.history.records {
            assert!(
                r.max_occupancy <= beta,
                "shard {} occupancy {} > β",
                r.iteration,
                r.max_occupancy
            );
        }
        // Nothing carried into the first shard; something carried after.
        assert_eq!(stream.history.records[0].carried_medoids, 0);
        for r in &stream.history.records[1..] {
            assert!(r.carried_medoids > 0, "later shards must carry medoids");
        }
    }

    #[test]
    fn any_shard_size_at_least_n_reproduces_batch_bitwise() {
        // The bitwise-batch guarantee must not depend on shard_size
        // being *exactly* n: any capacity that swallows the corpus in
        // one shard runs one episode on the same RNG stream.
        let set = generate(&DatasetSpec::tiny(70, 5, 47));
        let backend = NativeBackend::new();
        let cfg = algo(3, Some(28), 3);
        let batch = MahcDriver::new(&set, cfg.clone(), &backend)
            .unwrap()
            .run()
            .unwrap();
        for shard_size in [set.len(), set.len() + 1, 10 * set.len()] {
            let stream = StreamingDriver::new(
                &set,
                StreamConfig::new(cfg.clone(), shard_size),
                &backend,
            )
            .unwrap()
            .run()
            .unwrap();
            assert_eq!(stream.shards, 1, "shard_size={shard_size}");
            assert_eq!(stream.labels, batch.labels, "shard_size={shard_size}");
            assert_eq!(stream.k, batch.k, "shard_size={shard_size}");
            assert_eq!(
                stream.f_measure.to_bits(),
                batch.f_measure.to_bits(),
                "shard_size={shard_size}"
            );
        }
    }

    #[test]
    fn unit_shards_run_cleanly_and_label_everyone() {
        // shard_size = 1 is the most degenerate legal stream: every
        // episode is (carried medoids ∪ one arrival), the first over a
        // single object.  Pinned behaviour: no panic, every segment
        // labelled, β and the carried bound still hold.
        let set = generate(&DatasetSpec::tiny(14, 3, 48));
        let backend = NativeBackend::new();
        let stream = StreamingDriver::new(
            &set,
            StreamConfig::new(algo(2, Some(8), 2), 1),
            &backend,
        )
        .unwrap()
        .run()
        .unwrap();
        assert_eq!(stream.shards, 14);
        assert_eq!(stream.history.records.len(), 14);
        assert_eq!(stream.labels.len(), 14);
        assert!(stream.k >= 1);
        assert!(stream.labels.iter().all(|&l| l < stream.k));
        for r in &stream.history.records {
            assert!(r.max_occupancy <= 8, "shard {}: β violated", r.iteration);
        }
    }

    #[test]
    fn empty_corpus_fails_cleanly_not_panicking() {
        // Both the shard planner and the driver must degrade to "no
        // work" / a descriptive error, never a panic.
        let plan = Shards::new(0, 5, None);
        assert_eq!(plan.total(), 0);
        assert!(plan.collect::<Vec<_>>().is_empty());
        let empty = SegmentSet {
            name: "empty".into(),
            dim: 3,
            segments: Vec::new(),
            num_classes: 0,
        };
        let backend = NativeBackend::new();
        let err = StreamingDriver::new(
            &empty,
            StreamConfig::new(algo(2, Some(8), 2), 4),
            &backend,
        )
        .err()
        .expect("empty corpus must be rejected at construction");
        assert!(err.to_string().contains("empty"), "got: {err}");
        // The session constructor rejects it the same way.
        assert!(StreamSession::new(
            &empty,
            StreamConfig::new(algo(2, Some(8), 2), 4),
            &backend
        )
        .is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let set = generate(&DatasetSpec::tiny(100, 5, 43));
        let backend = NativeBackend::new();
        let cfg = StreamConfig::new(algo(2, Some(30), 3), 35).with_shard_seed(7);
        let a = StreamingDriver::new(&set, cfg.clone(), &backend)
            .unwrap()
            .run()
            .unwrap();
        let b = StreamingDriver::new(&set, cfg, &backend)
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.k, b.k);
        assert_eq!(a.f_measure, b.f_measure);
    }

    #[test]
    fn retirement_rectangle_reuses_episode_pairs() {
        // With the pair cache on, the medoid × batch rectangle must see
        // hits: medoid–member pairs inside one final subset were just
        // computed by that subset's condensed build.
        let set = generate(&DatasetSpec::tiny(120, 6, 44));
        let backend = NativeBackend::new();
        let mut a = algo(2, Some(30), 3);
        a.cache_bytes = 8 << 20;
        let stream = StreamingDriver::new(&set, StreamConfig::new(a, 40), &backend)
            .unwrap()
            .run()
            .unwrap();
        assert!(stream.shards > 1);
        assert!(
            stream.assign_cache.hits > 0,
            "rectangle should be served partly from cache ({:?})",
            stream.assign_cache
        );
        // And the cache must not change the clustering itself.
        let plain = StreamingDriver::new(
            &set,
            StreamConfig::new(algo(2, Some(30), 3), 40),
            &backend,
        )
        .unwrap()
        .run()
        .unwrap();
        assert_eq!(plain.labels, stream.labels);
        assert_eq!(plain.k, stream.k);
    }

    #[test]
    fn carried_set_stays_bounded() {
        // The L-method cap keeps carried medoids at a fixed point
        // instead of growing with the stream.
        let set = generate(&DatasetSpec::tiny(200, 8, 45));
        let backend = NativeBackend::new();
        let stream = StreamingDriver::new(
            &set,
            StreamConfig::new(algo(2, Some(25), 2), 25),
            &backend,
        )
        .unwrap()
        .run()
        .unwrap();
        assert_eq!(stream.shards, 8);
        let carried = stream.history.carried_series();
        // Fixed point ≈ frac/(1-frac)·(shard+carried); with frac=0.25
        // that is well under one shard of medoids.
        let cap = 2 * 25;
        for (t, &c) in carried.iter().enumerate() {
            assert!(c <= cap, "shard {t} carried {c} > {cap}");
        }
    }

    #[test]
    fn aggregate_epsilon_zero_stream_is_bitwise_the_plain_stream() {
        let set = generate(&DatasetSpec::tiny(100, 5, 49));
        let backend = NativeBackend::new();
        let plain_cfg = StreamConfig::new(algo(2, Some(30), 3), 35);
        let mut agg_algo = algo(2, Some(30), 3);
        agg_algo.aggregate = crate::config::AggregateConfig {
            epsilon: 0.0,
            cap: Some(9),
            ..Default::default()
        };
        let agg_cfg = StreamConfig::new(agg_algo, 35);
        let plain = StreamingDriver::new(&set, plain_cfg, &backend)
            .unwrap()
            .run()
            .unwrap();
        let agg = StreamingDriver::new(&set, agg_cfg, &backend)
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(plain.labels, agg.labels);
        assert_eq!(plain.k, agg.k);
        assert_eq!(plain.f_measure.to_bits(), agg.f_measure.to_bits());
        assert_eq!(plain.shards, agg.shards);
        assert_eq!(plain.history.algo, agg.history.algo);
        for r in &agg.history.records {
            assert_eq!(r.representatives, 0);
            assert_eq!(r.compression_ratio, 1.0);
            assert_eq!(r.assignment_pairs, 0);
        }
    }

    #[test]
    fn aggregated_stream_shards_representatives_and_labels_everyone() {
        // A radius past every pair distance collapses the corpus onto
        // one leader: the stream then has exactly one single-rep shard
        // and the members resolve through their attach pointers.
        let set = generate(&DatasetSpec::tiny(60, 4, 50));
        let backend = NativeBackend::new();
        let mut a = algo(2, Some(20), 2);
        a.aggregate = crate::config::AggregateConfig::new(1e30);
        let res = StreamingDriver::new(&set, StreamConfig::new(a, 25), &backend)
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(res.shards, 1, "one representative fills one shard");
        assert_eq!(res.labels.len(), 60);
        assert_eq!(res.k, 1);
        assert!(res.labels.iter().all(|&l| l == 0));
        assert_eq!(res.history.records.len(), 1);
        let r = &res.history.records[0];
        assert_eq!(r.representatives, 1);
        assert!((r.compression_ratio - 1.0 / 60.0).abs() < 1e-12);
        assert_eq!(r.assignment_pairs, 59);
        assert_eq!(res.history.algo, "mahc+m-stream+agg");
    }

    #[test]
    fn prune_modes_reproduce_the_exact_stream_bitwise() {
        // The cascade is a pure evaluation-order optimisation: every
        // retirement argmin and every stage-0 probe decision must come
        // out bitwise the exact path's, across shard boundaries.
        let set = generate(&DatasetSpec::tiny(120, 6, 57));
        let backend = NativeBackend::new();
        let mut base = algo(2, Some(30), 3);
        base.aggregate = crate::config::AggregateConfig::new(0.5);
        let exact = StreamingDriver::new(
            &set,
            StreamConfig::new(base.clone(), 40),
            &backend,
        )
        .unwrap()
        .run()
        .unwrap();
        assert!(exact.shards > 1, "need retirement rectangles");
        for r in &exact.history.records {
            assert_eq!(r.lb_pairs, 0, "exact mode must not touch the bound");
            assert_eq!(r.lb_pruned, 0);
            assert_eq!(r.exact_pairs, 0);
            assert_eq!(r.backend, "native");
        }
        for mode in [PruneMode::On, PruneMode::Debug] {
            let mut a = base.clone();
            a.prune = mode;
            let pruned = StreamingDriver::new(&set, StreamConfig::new(a, 40), &backend)
                .unwrap()
                .run()
                .unwrap();
            assert_eq!(pruned.labels, exact.labels, "mode={mode:?}");
            assert_eq!(pruned.k, exact.k, "mode={mode:?}");
            assert_eq!(
                pruned.f_measure.to_bits(),
                exact.f_measure.to_bits(),
                "mode={mode:?}"
            );
            assert_eq!(pruned.shards, exact.shards, "mode={mode:?}");
            assert!(
                pruned.history.records[0].lb_pairs > 0,
                "mode={mode:?}: stage-0 probes should exercise the bound"
            );
            for r in &pruned.history.records {
                assert_eq!(r.backend, "native+lb", "mode={mode:?}");
                // exact_pairs also counts threshold-free queries
                // (condensed builds), so it can exceed the survivors;
                // the pruned count can never exceed the bounded count.
                assert!(
                    r.lb_pruned <= r.lb_pairs,
                    "mode={mode:?} shard {}: pruned {} > bounded {}",
                    r.iteration,
                    r.lb_pruned,
                    r.lb_pairs
                );
                assert!(
                    r.exact_pairs >= r.lb_pairs - r.lb_pruned,
                    "mode={mode:?} shard {}: survivors must run the DP",
                    r.iteration
                );
            }
        }
    }

    #[test]
    fn rejects_bad_configs_and_empty_sets() {
        let set = generate(&DatasetSpec::tiny(20, 2, 46));
        let backend = NativeBackend::new();
        assert!(StreamingDriver::new(
            &set,
            StreamConfig::new(AlgoConfig::default(), 0),
            &backend
        )
        .is_err());
        assert!(StreamSession::new(
            &set,
            StreamConfig::new(AlgoConfig::default(), 0),
            &backend
        )
        .is_err());
        let empty = SegmentSet {
            name: "empty".into(),
            dim: 3,
            segments: Vec::new(),
            num_classes: 0,
        };
        assert!(StreamingDriver::new(
            &empty,
            StreamConfig::new(AlgoConfig::default(), 8),
            &backend
        )
        .is_err());
    }

    #[test]
    fn stepwise_session_reproduces_run_bitwise() {
        // The state machine IS the loop: stepping shard by shard and
        // finishing must equal StreamingDriver::run exactly, record for
        // record.
        let set = generate(&DatasetSpec::tiny(120, 6, 52));
        let backend = NativeBackend::new();
        let cfg = StreamConfig::new(algo(2, Some(30), 3), 40);
        let run = StreamingDriver::new(&set, cfg.clone(), &backend)
            .unwrap()
            .run()
            .unwrap();
        let mut session = StreamSession::new(&set, cfg, &backend).unwrap();
        assert_eq!(session.shards_done(), 0);
        assert_eq!(session.total_shards(), None, "plan is lazy");
        let mut steps = 0usize;
        while let Some(r) = session.step().unwrap() {
            assert_eq!(r.iteration, steps);
            steps += 1;
            assert_eq!(session.shards_done(), steps);
        }
        assert!(session.is_done());
        assert_eq!(session.total_shards(), Some(run.shards));
        assert!(session.step().unwrap().is_none(), "idempotent at end");
        let res = session.finish().unwrap();
        assert_eq!(steps, run.shards);
        assert_eq!(res.labels, run.labels);
        assert_eq!(res.k, run.k);
        assert_eq!(res.f_measure.to_bits(), run.f_measure.to_bits());
        assert_eq!(res.pairs, run.pairs);
        assert_eq!(res.history.records.len(), run.history.records.len());
        for (a, b) in res.history.records.iter().zip(&run.history.records) {
            assert_eq!(a.total_clusters, b.total_clusters);
            assert_eq!(a.carried_medoids, b.carried_medoids);
            assert_eq!(a.f_measure.to_bits(), b.f_measure.to_bits());
        }
    }

    #[test]
    fn finish_drains_a_partially_stepped_session() {
        let set = generate(&DatasetSpec::tiny(100, 5, 55));
        let backend = NativeBackend::new();
        let cfg = StreamConfig::new(algo(2, Some(30), 3), 30);
        let run = StreamingDriver::new(&set, cfg.clone(), &backend)
            .unwrap()
            .run()
            .unwrap();
        let mut session = StreamSession::new(&set, cfg, &backend).unwrap();
        session.step().unwrap().expect("first shard");
        let res = session.finish().unwrap();
        assert_eq!(res.labels, run.labels);
        assert_eq!(res.k, run.k);
        assert_eq!(res.f_measure.to_bits(), run.f_measure.to_bits());
        assert_eq!(res.shards, run.shards);
    }

    #[test]
    fn scoped_shared_cache_session_is_bitwise_identical() {
        // A session running over a budgeted scoped handle of a shared
        // fleet cache must reproduce the plain run exactly: cache
        // contents and budgets change hit rates, never results.
        let set = generate(&DatasetSpec::tiny(120, 6, 56));
        let backend = NativeBackend::new();
        let cfg = StreamConfig::new(algo(2, Some(30), 3), 40);
        let plain = StreamingDriver::new(&set, cfg.clone(), &backend)
            .unwrap()
            .run()
            .unwrap();
        let fleet = PairCache::with_capacity_bytes(4 << 20);
        let handle = fleet.scoped(0, Some(64 << 10)).unwrap();
        let res = StreamSession::new(&set, cfg, &backend)
            .unwrap()
            .with_cache(handle)
            .finish()
            .unwrap();
        assert_eq!(res.labels, plain.labels);
        assert_eq!(res.k, plain.k);
        assert_eq!(res.f_measure.to_bits(), plain.f_measure.to_bits());
        assert!(fleet.len() > 0, "session warmed the shared cache");
        assert!(
            fleet.bytes() <= fleet.capacity_entries() * crate::distance::cache::ENTRY_BYTES,
            "fleet capacity respected"
        );
    }

    #[test]
    fn aggregation_on_nonempty_corpus_always_yields_representatives() {
        // The real invariant behind the old `m > 0 || n == 0` guard
        // (whose n == 0 arm was dead — empty corpora are rejected at
        // construction): a leader pass over a nonempty corpus elects at
        // least one representative for any legal ε/cap, because every
        // segment either becomes a leader or joins one.
        let set = generate(&DatasetSpec::tiny(30, 3, 53));
        let backend = NativeBackend::new();
        for eps in [0.5_f32, 10.0, 1e30] {
            for cap in [None, Some(1), Some(5)] {
                let mut a = algo(2, Some(12), 2);
                a.aggregate = crate::config::AggregateConfig {
                    epsilon: eps,
                    cap,
                    ..Default::default()
                };
                let res = StreamingDriver::new(&set, StreamConfig::new(a, 10), &backend)
                    .unwrap()
                    .run()
                    .unwrap();
                let reps = res.history.records[0].representatives;
                assert!(reps >= 1, "eps={eps} cap={cap:?}: no representatives");
                assert_eq!(res.labels.len(), 30, "everyone labelled");
                assert!(res.labels.iter().all(|&l| l < res.k));
            }
        }
    }

    #[test]
    fn shared_session_is_send_and_movable_across_threads() {
        // The serve multiplexer moves sessions into worker-pool jobs:
        // a shared-ownership session must be Send, and running it on
        // another thread must be bitwise the sequential run.
        fn assert_send<T: Send>(_: &T) {}
        let set = Arc::new(generate(&DatasetSpec::tiny(60, 4, 54)));
        let backend: Arc<dyn PairwiseBackend + Send + Sync> = Arc::new(NativeBackend::new());
        let cfg = StreamConfig::new(algo(2, Some(20), 2), 20);
        let seq = StreamingDriver::new(&set, cfg.clone(), backend.as_ref())
            .unwrap()
            .run()
            .unwrap();
        let mut session =
            StreamSession::shared(Arc::clone(&set), cfg, Arc::clone(&backend)).unwrap();
        assert_send(&session);
        session.step().unwrap().expect("first shard on this thread");
        let res = std::thread::spawn(move || session.finish())
            .join()
            .expect("no panic")
            .unwrap();
        assert_eq!(res.labels, seq.labels);
        assert_eq!(res.k, seq.k);
        assert_eq!(res.f_measure.to_bits(), seq.f_measure.to_bits());
    }
}
