//! Stage 1 of each MAHC iteration (Algorithm 1 steps 3-5): independent
//! AHC over every subset, model selection (L-method knee or
//! silhouette), medoid extraction — dispatched to the worker pool.

use crate::ahc::{self, SelectionMethod};
use crate::aggregate::scale_condensed_by_counts;
use crate::corpus::{Segment, SegmentSet};
use crate::distance::{build_condensed_cached, PairwiseBackend, PairCache};
use crate::util::pool::parallel_map;

/// Result of clustering one subset.
#[derive(Debug, Clone)]
pub struct SubsetOutcome {
    /// Global segment ids of this subset's members.
    pub ids: Vec<usize>,
    /// Per-member cluster label (0..k), parallel to `ids`.
    pub labels: Vec<usize>,
    /// Number of clusters the L method chose (K_p).
    pub k: usize,
    /// Global segment id of each cluster's medoid.
    pub medoid_ids: Vec<usize>,
    /// Condensed-matrix size for this subset (memory telemetry).
    pub matrix_bytes: usize,
}

impl SubsetOutcome {
    /// Member ids of each cluster, as global segment ids.
    pub fn cluster_members(&self) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.k];
        for (pos, &label) in self.labels.iter().enumerate() {
            out[label].push(self.ids[pos]);
        }
        out
    }
}

/// Run stage 1 over all subsets on up to `threads` workers with the
/// default L-method selection.  Thin wrapper over [`run_stage1_with`],
/// kept for the historical call sites.
pub fn run_stage1(
    set: &SegmentSet,
    subsets: &[Vec<usize>],
    backend: &dyn PairwiseBackend,
    threads: usize,
    max_clusters_frac: f64,
    cache: Option<&PairCache>,
) -> anyhow::Result<Vec<SubsetOutcome>> {
    run_stage1_with(
        set,
        subsets,
        backend,
        threads,
        max_clusters_frac,
        cache,
        SelectionMethod::LMethod,
        None,
    )
}

/// Run stage 1 over all subsets on up to `threads` workers, choosing
/// each subset's cluster count with `selection`.
///
/// `counts`, indexed by global segment id, marks each object as a
/// stage-0 group of that many members (the cluster-feature path):
/// subset linkage then runs count-weighted over the Ward2-rescaled
/// condensed matrix, so representative merges honour the mass behind
/// them.  `None` — or all-ones counts — is the historical unweighted
/// path, bitwise (the raw matrix is always built through the shared
/// cache first; scaling is a per-subset copy).
#[allow(clippy::too_many_arguments)]
pub fn run_stage1_with(
    set: &SegmentSet,
    subsets: &[Vec<usize>],
    backend: &dyn PairwiseBackend,
    threads: usize,
    max_clusters_frac: f64,
    cache: Option<&PairCache>,
    selection: SelectionMethod,
    counts: Option<&[usize]>,
) -> anyhow::Result<Vec<SubsetOutcome>> {
    let results: Vec<anyhow::Result<SubsetOutcome>> =
        parallel_map(subsets.len(), threads, |s| {
            cluster_one_subset(
                set,
                &subsets[s],
                backend,
                max_clusters_frac,
                cache,
                selection,
                counts,
            )
        })?;
    results.into_iter().collect()
}

fn cluster_one_subset(
    set: &SegmentSet,
    ids: &[usize],
    backend: &dyn PairwiseBackend,
    max_clusters_frac: f64,
    cache: Option<&PairCache>,
    selection: SelectionMethod,
    counts: Option<&[usize]>,
) -> anyhow::Result<SubsetOutcome> {
    let refs: Vec<&Segment> = ids.iter().map(|&i| &set.segments[i]).collect();
    // Distance build is itself single-threaded here: parallelism is
    // across subsets (matching the paper's "in parallel" stage 1).
    // Pairs kept together by the refine step hit the cross-iteration
    // cache and never reach the backend again.
    let cond = build_condensed_cached(&refs, backend, 1, cache)?;
    let max_k = ((ids.len() as f64 * max_clusters_frac).ceil() as usize).max(2);
    // Count-weighted path only when some member of this subset actually
    // stands for a collapsed group; otherwise the scale factor is √1
    // everywhere and the unweighted code is the same answer, bitwise.
    let sizes: Option<Vec<usize>> = counts.and_then(|c| {
        let s: Vec<usize> = ids.iter().map(|&i| c[i]).collect(); // lint: in-bounds counts is indexed by global segment id
        s.iter().any(|&n| n > 1).then_some(s)
    });
    let clustering = match &sizes {
        Some(s) => {
            let scaled = scale_condensed_by_counts(&cond, s);
            ahc::cluster_subset_sized(&scaled, max_k, None, selection, Some(s))
        }
        None => ahc::cluster_subset_with(&cond, max_k, None, selection),
    };
    let medoid_ids = clustering
        .medoids
        .iter()
        .map(|&m| {
            debug_assert!(m != usize::MAX, "empty cluster has no medoid");
            ids[m]
        })
        .collect();
    Ok(SubsetOutcome {
        ids: ids.to_vec(),
        labels: clustering.labels,
        k: clustering.k,
        medoid_ids,
        matrix_bytes: cond.bytes(),
    })
}

/// Assemble the global clustering implied by stage-1 outcomes: every
/// (subset, cluster) pair becomes one global cluster.  Returns labels
/// indexed by segment id plus the number of global clusters.
pub fn global_labels(n: usize, outcomes: &[SubsetOutcome]) -> (Vec<usize>, usize) {
    let mut labels = vec![usize::MAX; n];
    let mut next = 0;
    for o in outcomes {
        for (pos, &id) in o.ids.iter().enumerate() {
            labels[id] = next + o.labels[pos];
        }
        next += o.k;
    }
    debug_assert!(labels.iter().all(|&l| l != usize::MAX));
    (labels, next)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetSpec;
    use crate::corpus::generate;
    use crate::distance::NativeBackend;

    #[test]
    fn outcomes_cover_subsets() {
        let set = generate(&DatasetSpec::tiny(60, 4, 11));
        let subsets = vec![(0..30).collect::<Vec<_>>(), (30..60).collect::<Vec<_>>()];
        let out = run_stage1(&set, &subsets, &NativeBackend::new(), 2, 0.4, None).unwrap();
        assert_eq!(out.len(), 2);
        for (o, s) in out.iter().zip(&subsets) {
            assert_eq!(&o.ids, s);
            assert_eq!(o.labels.len(), s.len());
            assert!(o.k >= 1);
            assert_eq!(o.medoid_ids.len(), o.k);
            // Medoids are members of the subset.
            for m in &o.medoid_ids {
                assert!(s.contains(m));
            }
            assert_eq!(o.matrix_bytes, s.len() * (s.len() - 1) / 2 * 4);
        }
    }

    #[test]
    fn cluster_members_partition_ids() {
        let set = generate(&DatasetSpec::tiny(40, 3, 12));
        let subsets = vec![(0..40).collect::<Vec<_>>()];
        let out = run_stage1(&set, &subsets, &NativeBackend::new(), 1, 0.4, None).unwrap();
        let members = out[0].cluster_members();
        let mut all: Vec<usize> = members.concat();
        all.sort_unstable();
        assert_eq!(all, (0..40).collect::<Vec<_>>());
        assert!(members.iter().all(|m| !m.is_empty()));
    }

    #[test]
    fn global_labels_dense_and_disjoint() {
        let set = generate(&DatasetSpec::tiny(50, 4, 13));
        let subsets = vec![
            (0..20).collect::<Vec<_>>(),
            (20..35).collect::<Vec<_>>(),
            (35..50).collect::<Vec<_>>(),
        ];
        let out = run_stage1(&set, &subsets, &NativeBackend::new(), 3, 0.4, None).unwrap();
        let (labels, k) = global_labels(50, &out);
        assert_eq!(labels.len(), 50);
        assert_eq!(k, out.iter().map(|o| o.k).sum::<usize>());
        assert!(labels.iter().all(|&l| l < k));
        // Labels from different subsets never collide.
        let used: std::collections::HashSet<usize> = labels.iter().copied().collect();
        assert_eq!(used.len(), k, "every global cluster non-empty");
    }

    #[test]
    fn silhouette_selection_produces_valid_outcomes() {
        let set = generate(&DatasetSpec::tiny(40, 3, 15));
        let subsets = vec![(0..40).collect::<Vec<_>>()];
        let out = run_stage1_with(
            &set,
            &subsets,
            &NativeBackend::new(),
            2,
            0.4,
            None,
            SelectionMethod::Silhouette,
            None,
        )
        .unwrap();
        assert_eq!(out.len(), 1);
        let o = &out[0];
        // Silhouette candidates live in [2, min(max_k, n−1)].
        assert!(o.k >= 2 && o.k <= 16, "k = {}", o.k);
        assert_eq!(o.medoid_ids.len(), o.k);
        assert_eq!(o.labels.len(), 40);
    }

    #[test]
    fn parallel_equals_serial() {
        let set = generate(&DatasetSpec::tiny(48, 4, 14));
        let subsets = vec![
            (0..16).collect::<Vec<_>>(),
            (16..32).collect::<Vec<_>>(),
            (32..48).collect::<Vec<_>>(),
        ];
        let a = run_stage1(&set, &subsets, &NativeBackend::new(), 1, 0.4, None).unwrap();
        let b = run_stage1(&set, &subsets, &NativeBackend::new(), 4, 0.4, None).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.labels, y.labels);
            assert_eq!(x.medoid_ids, y.medoid_ids);
        }
    }
}
