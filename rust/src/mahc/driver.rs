//! The MAHC+M iteration loop (Algorithm 1) and its result type.
//!
//! The loop itself is factored as an *episode* over an explicit id set
//! ([`run_episode`]): the batch driver runs one episode over the whole
//! corpus, the streaming driver ([`super::streaming`]) runs one episode
//! per arriving shard (shard members ∪ carried medoids).  Both therefore
//! execute bit-identical arithmetic — a single-shard stream reproduces
//! [`MahcDriver::run`] exactly.

use super::partition::partition_ids;
use super::split::{merge_small, split_oversized};
use super::stage::{run_stage1_with, SubsetOutcome};
use crate::aggregate;
use crate::ahc::{self, SelectionMethod};
use crate::config::{AlgoConfig, Convergence, FinalK, PruneMode};
use crate::corpus::{Segment, SegmentSet};
use crate::distance::{build_condensed_cached, CascadeBackend, CascadeMode, PairwiseBackend, PairCache};
use crate::metrics;
use crate::telemetry::{
    pairs_rate, CacheStats, IterationRecord, PruneStats, RunHistory, Stopwatch,
};
use crate::util::rng::Rng;

/// Final output of a clustering run.
#[derive(Debug, Clone)]
pub struct MahcResult {
    /// Final cluster label per segment id (dense, 0..k).
    pub labels: Vec<usize>,
    /// Final number of clusters K.
    pub k: usize,
    /// F-measure of the final clustering against ground truth.
    pub f_measure: f64,
    /// Per-iteration telemetry (the figures' source data).
    pub history: RunHistory,
}

/// Orchestrates Algorithm 1 over a dataset and a DTW backend.
pub struct MahcDriver<'a> {
    set: &'a SegmentSet,
    cfg: AlgoConfig,
    backend: &'a dyn PairwiseBackend,
}

impl<'a> MahcDriver<'a> {
    pub fn new(
        set: &'a SegmentSet,
        cfg: AlgoConfig,
        backend: &'a dyn PairwiseBackend,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        if set.is_empty() {
            anyhow::bail!("empty dataset");
        }
        Ok(MahcDriver { set, cfg, backend })
    }

    pub fn config(&self) -> &AlgoConfig {
        &self.cfg
    }

    /// Run the full algorithm; returns the final clustering + history.
    pub fn run(&self) -> anyhow::Result<MahcResult> {
        let cfg = &self.cfg;
        let base_name = if cfg.beta.is_some() { "mahc+m" } else { "mahc" };
        let algo_name = if cfg.aggregate.is_active() {
            format!("{base_name}+agg")
        } else {
            base_name.to_string()
        };
        let mut history = RunHistory::new(&self.set.name, &algo_name);

        // Lower-bound pruning cascade: wraps the backend so threshold
        // consumers (the stage-0 leader pass) can bound pairs out
        // before the DTW recurrence runs.  Off = the raw backend, the
        // bitwise reference (`rust/tests/pruning.rs`).
        let cascade = cfg.prune.is_active().then(|| {
            let mode = match cfg.prune {
                PruneMode::Debug => CascadeMode::Debug,
                _ => CascadeMode::On,
            };
            CascadeBackend::borrowed(self.backend, self.set, mode)
        });
        let backend: &dyn PairwiseBackend = match &cascade {
            Some(c) => c,
            None => self.backend,
        };

        // Cross-iteration DTW pair cache (the time-side dual of β's
        // space bound — see `distance::cache`).  One cache per run:
        // refine keeps stage-1 cluster members together, so recurring
        // within-subset and medoid pairs are served from here instead
        // of the backend from iteration 2 onwards.
        let cache = (cfg.cache_bytes > 0).then(|| PairCache::with_capacity_bytes(cfg.cache_bytes));
        let cache = cache.as_ref();

        // Stage 0: leader-pass aggregation (identity when ε = 0, in
        // which case this block is skipped and the run is bitwise the
        // historical unaggregated pipeline).  Probes share the run's
        // pair cache, so stage 1 never recomputes a probed (rep, rep)
        // distance; the probes' counter movement is folded into the
        // first record below so the run's hit rate stays honest.
        let agg_snapshot = cache.map(|c| c.stats()).unwrap_or_default();
        let agg_prune_snapshot = backend.prune_stats().unwrap_or_default();
        let agg = cfg
            .aggregate
            .is_active()
            .then(|| aggregate::aggregate(self.set, &cfg.aggregate, backend, cfg.threads, cache))
            .transpose()?;
        let agg_cache = cache
            .map(|c| c.stats().delta(&agg_snapshot))
            .unwrap_or_default();
        let agg_prune = backend
            .prune_stats()
            .unwrap_or_default()
            .delta(&agg_prune_snapshot);

        // Debug-mode admissibility recheck: recluster the full corpus
        // and verify the representative run's merge heights stay within
        // the reported deviation bound.  Opt-in (O(N²)) — the Report
        // default only stamps the closed-form bound.
        if cfg.deviation.is_debug() {
            if let Some(a) = &agg {
                aggregate::check_deviation(self.set, a, backend, cfg.threads, cache)?;
            }
        }

        // Count-weighted stage 1: each representative enters linkage
        // carrying its group's mass (None when nothing collapsed, which
        // keeps the historical unweighted path bitwise).
        let counts: Option<Vec<usize>> = agg.as_ref().and_then(|a| {
            if a.members.iter().all(|m| m.len() <= 1) {
                return None;
            }
            let mut c = vec![1usize; self.set.len()];
            for (pos, &rep) in a.rep_ids.iter().enumerate() {
                c[rep] = a.members[pos].len().max(1); // lint: in-bounds rep ids and member groups come from the same pass
            }
            Some(c)
        });

        let mut rng = Rng::seed_from(cfg.seed);
        let ids: Vec<usize> = match &agg {
            Some(a) => a.rep_ids.clone(),
            None => (0..self.set.len()).collect(),
        };
        let ep = run_episode(
            self.set,
            &ids,
            cfg,
            backend,
            cache,
            counts.as_deref(),
            &mut rng,
            Some(&mut history),
        )?;

        let Some(a) = agg else {
            // `ep.labels` is parallel to `ids` == indexed by segment
            // id, and the episode's truth slice was the full ground
            // truth, so its F-measure is the run's F-measure.
            return Ok(MahcResult {
                labels: ep.labels,
                k: ep.k,
                f_measure: ep.f_measure,
                history,
            });
        };

        // Resolve members to final clusters: each aggregated member
        // follows its representative — the same forwarding idea the
        // streaming driver uses for retired objects, with one-hop
        // chains because every leader stayed active to the end.
        let n = self.set.len();
        let mut labels = vec![usize::MAX; n];
        for (pos, &rep) in a.rep_ids.iter().enumerate() {
            for &id in &a.members[pos] {
                labels[id] = ep.labels[pos];
            }
            debug_assert_eq!(labels[rep], ep.labels[pos]);
        }
        debug_assert!(labels.iter().all(|&l| l != usize::MAX));
        // The per-iteration records scored representatives only; the
        // run's F-measure covers all N resolved labels.
        let f_measure = metrics::f_measure(&labels, &self.set.labels());

        for (idx, r) in history.records.iter_mut().enumerate() {
            r.representatives = a.reps();
            r.compression_ratio = a.compression_ratio();
            r.assignment_pairs = if idx == 0 { a.probe_pairs } else { 0 };
            if idx == 0 {
                // Stage-0 probe-engine shape, stamped once.
                r.sample_pairs = a.sample_pairs;
                r.sample_segments = a.sample_segments;
                r.probe_rounds = a.probe_rounds;
                r.probe_rect_rows = a.rect_rows;
                r.probe_rect_cols = a.rect_cols;
                r.super_leaders = a.super_leaders;
                r.aggregate_epsilon = a.epsilon as f64;
                r.deviation_bound = a.deviation_bound();
                // The leader pass ran before the episode's first cache
                // snapshot; without this, its misses — single-row probes
                // and batched rectangles alike — would be invisible and
                // cache_total() would overstate the hit rate.
                r.cache.hits += agg_cache.hits;
                r.cache.misses += agg_cache.misses;
                r.cache.evictions += agg_cache.evictions;
                // Same honesty rule for the pruning cascade: the leader
                // pass is the thresholded consumer, so its bound/exact
                // movement belongs to the first record too.
                r.lb_pairs += agg_prune.lb_pairs;
                r.lb_pruned += agg_prune.lb_pruned;
                r.exact_pairs += agg_prune.exact_pairs;
            }
        }
        Ok(MahcResult {
            labels,
            k: ep.k,
            f_measure,
            history,
        })
    }
}

/// Aggregates of one episode, for per-shard telemetry records.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct EpisodeSummary {
    /// Iterations the episode actually ran.
    pub iterations: usize,
    /// Subset count entering the final iteration.
    pub final_subsets: usize,
    /// Largest subset occupancy over all iterations (≤ β when set).
    pub max_occupancy: usize,
    /// Smallest subset occupancy over all iterations.
    pub min_occupancy: usize,
    /// Largest post-refine, pre-split occupancy over all iterations.
    pub max_occupancy_pre_split: usize,
    /// Total subsets split over all iterations.
    pub splits: usize,
    /// ΣKⱼ of the final iteration's stage 1.
    pub total_clusters: usize,
    /// Peak condensed-matrix bytes over the episode.
    pub peak_matrix_bytes: usize,
    /// Pair distances produced over the episode (stage-1 condensed
    /// builds + medoid matrices; cache hits included).
    pub pairs: usize,
    /// Mean silhouette of the final iteration's evaluation cut (0.0
    /// under L-method selection, where the medoid matrix is dropped).
    pub silhouette: f64,
}

/// Result of one episode of the iteration loop over an id set.
#[derive(Debug, Clone)]
pub(crate) struct EpisodeOutcome {
    /// Final cluster label per active object, parallel to the episode's
    /// `ids` argument (dense, 0..k).
    pub labels: Vec<usize>,
    /// Final number of clusters K among the active objects.
    pub k: usize,
    /// F-measure of the final clustering over the active objects only.
    pub f_measure: f64,
    /// Global segment id of each stage-1 cluster medoid from the final
    /// iteration — the representatives a streaming run carries forward.
    pub medoid_ids: Vec<usize>,
    pub summary: EpisodeSummary,
}

/// One episode of Algorithm 1 over the objects in `ids` (global segment
/// ids into `set`).  Consumes `rng` exactly as the historical batch loop
/// did, so with `ids == 0..n` this *is* [`MahcDriver::run`]'s loop; the
/// streaming driver calls it with (shard ∪ carried medoids).  Pushes one
/// [`IterationRecord`] per iteration into `history` when given.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_episode(
    set: &SegmentSet,
    ids: &[usize],
    cfg: &AlgoConfig,
    backend: &dyn PairwiseBackend,
    cache: Option<&PairCache>,
    counts: Option<&[usize]>,
    rng: &mut Rng,
    mut history: Option<&mut RunHistory>,
) -> anyhow::Result<EpisodeOutcome> {
    anyhow::ensure!(!ids.is_empty(), "episode over an empty id set");
    let n_active = ids.len();
    // Position of each global id inside `ids` (usize::MAX = inactive).
    let mut pos_of = vec![usize::MAX; set.len()];
    for (p, &id) in ids.iter().enumerate() {
        pos_of[id] = p;
    }
    let truth = set.labels();
    let truth_active: Vec<usize> = ids.iter().map(|&id| truth[id]).collect();

    let mut cache_snapshot = match cache {
        Some(c) => c.stats(),
        None => CacheStats::default(),
    };
    let mut prune_snapshot = backend.prune_stats().unwrap_or_default();

    let mut subsets = partition_ids(ids, cfg.p0, rng);
    // If β is already violated by the initial division, enforce it
    // before the first iteration (the paper chooses P₀ so that this
    // does not happen; we guarantee it regardless).
    if let Some(beta) = cfg.beta {
        split_oversized(&mut subsets, beta, rng, cfg.split_shuffle);
    }

    let max_iters = match cfg.convergence {
        Convergence::FixedIters(k) => k.max(1),
        Convergence::SettledSubsets { max_iters } => max_iters.max(1),
    };

    let mut first_stage_total: Option<usize> = None;
    let mut prev_p = usize::MAX;
    let mut summary = EpisodeSummary {
        min_occupancy: usize::MAX,
        ..Default::default()
    };

    for i in 0..max_iters {
        let t0 = Stopwatch::start();
        let p_i = subsets.len();
        let occ_max = subsets.iter().map(|s| s.len()).max().unwrap_or(0);
        let occ_min = subsets.iter().map(|s| s.len()).min().unwrap_or(0);

        // Steps 3-5: per-subset AHC, model selection (L-method knee or
        // silhouette), medoids.
        let outcomes = run_stage1_with(
            set,
            &subsets,
            backend,
            cfg.threads,
            cfg.max_clusters_frac,
            cache,
            cfg.selection,
            counts,
        )?;
        let total_clusters: usize = outcomes.iter().map(|o| o.k).sum();
        first_stage_total.get_or_insert(total_clusters);
        let stage1_bytes = outcomes.iter().map(|o| o.matrix_bytes).max().unwrap_or(0);

        // One medoid dendrogram per iteration serves three cuts:
        // the per-iteration evaluation clustering (steps 13-15 as
        // if concluding now — the F the paper plots), the final
        // clustering, and the refine grouping (step 7).  Under
        // silhouette selection the medoid condensed matrix is retained
        // so the evaluation cut can be scored for telemetry.
        let stage2 = MedoidStage::build(
            set,
            &outcomes,
            backend,
            cfg.threads,
            cache,
            cfg.selection == SelectionMethod::Silhouette,
        )?;

        // Per-iteration cache counter movement (zeros when off).
        let cache_iter = match cache {
            Some(c) => {
                let now = c.stats();
                let delta = now.delta(&cache_snapshot);
                cache_snapshot = now;
                delta
            }
            None => CacheStats::default(),
        };
        // Per-iteration cascade counter movement (zeros without the
        // pruning wrapper).  Stage-1 builds are threshold-free, so this
        // mostly tallies `exact_pairs` — it exists so a run can prove
        // at a glance that no bound leaked into an exact phase.
        let prune_iter = match backend.prune_stats() {
            Some(now) => {
                let delta = now.delta(&prune_snapshot);
                prune_snapshot = now;
                delta
            }
            None => PruneStats::default(),
        };

        // Evaluation / conclusion clustering: K = ΣKⱼ (paper §5
        // validates the first-stage total as the final K estimate).
        let k_target = match cfg.final_k {
            FinalK::StageOneTotal => first_stage_total.unwrap_or(1),
            FinalK::Fixed(k) => k,
        };
        let (labels_iter, k_iter) = stage2.cut_to_labels(&pos_of, n_active, k_target);
        let f = metrics::f_measure(&labels_iter, &truth_active);
        // Silhouette of the evaluation cut over the medoid matrix — the
        // model-selection quality signal; 0.0 under L-method selection
        // (the matrix is not retained there).
        let sil = stage2.silhouette_of_cut(k_target);

        // Step 6: convergence test (i > 2 in the paper's 1-based
        // numbering — we require at least 3 completed iterations).
        let converged = match cfg.convergence {
            Convergence::FixedIters(k) => i + 1 >= k,
            Convergence::SettledSubsets { .. } => i >= 3 && p_i == prev_p,
        };
        let last = converged || i + 1 == max_iters;

        // Pair distances this iteration produced: one condensed
        // triangle per subset plus the medoid triangle (served by the
        // backend or the cache; either way a pair was delivered).
        let iter_pairs: usize = subsets
            .iter()
            .map(|s| s.len() * (s.len().saturating_sub(1)) / 2)
            .sum::<usize>()
            + stage2.s * (stage2.s - 1) / 2;

        let iter_bytes = stage1_bytes.max(stage2.bytes);
        summary.iterations = i + 1;
        summary.pairs += iter_pairs;
        summary.final_subsets = p_i;
        summary.max_occupancy = summary.max_occupancy.max(occ_max);
        summary.min_occupancy = summary.min_occupancy.min(occ_min);
        summary.total_clusters = total_clusters;
        summary.peak_matrix_bytes = summary.peak_matrix_bytes.max(iter_bytes);
        summary.silhouette = sil;

        if last {
            summary.max_occupancy_pre_split = summary.max_occupancy_pre_split.max(occ_max);
            if let Some(h) = history.as_mut() {
                let wall = t0.elapsed();
                h.push(IterationRecord {
                    iteration: i,
                    subsets: p_i,
                    max_occupancy: occ_max,
                    min_occupancy: occ_min,
                    max_occupancy_pre_split: occ_max,
                    splits: 0,
                    total_clusters,
                    f_measure: f,
                    wall,
                    peak_matrix_bytes: iter_bytes,
                    cache: cache_iter,
                    carried_medoids: 0,
                    representatives: 0,
                    compression_ratio: 1.0,
                    assignment_pairs: 0,
                    sample_pairs: 0,
                    sample_segments: 0,
                    lb_pairs: prune_iter.lb_pairs,
                    lb_pruned: prune_iter.lb_pruned,
                    exact_pairs: prune_iter.exact_pairs,
                    probe_rounds: 0,
                    probe_rect_rows: 0,
                    probe_rect_cols: 0,
                    super_leaders: 0,
                    aggregate_epsilon: 0.0,
                    deviation_bound: 0.0,
                    backend: backend.name().to_string(),
                    pairs_per_sec: pairs_rate(iter_pairs, wall),
                    metric: backend.metric_name().to_string(),
                    silhouette_score: sil,
                });
            }
            return Ok(EpisodeOutcome {
                labels: labels_iter,
                k: k_iter,
                f_measure: f,
                medoid_ids: stage2.medoid_ids,
                summary,
            });
        }

        // Steps 7-8 (refine): group medoids into P_i clusters; every
        // stage-1 cluster's members follow their medoid.
        let (group_labels, groups) = stage2.cut_groups(p_i);
        let mut new_subsets: Vec<Vec<usize>> = vec![Vec::new(); groups];
        for (m, members) in stage2.clusters_members.iter().enumerate() {
            new_subsets[group_labels[m]].extend(members.iter().copied());
        }
        new_subsets.retain(|s| !s.is_empty());
        let pre_split_max = new_subsets.iter().map(|s| s.len()).max().unwrap_or(0);

        // Step 9: cluster size management (the contribution).
        let split_out = match cfg.beta {
            Some(beta) => split_oversized(&mut new_subsets, beta, rng, cfg.split_shuffle),
            None => Default::default(),
        };
        if let Some(min) = cfg.merge_min {
            merge_small(&mut new_subsets, min, cfg.beta);
        }

        summary.max_occupancy_pre_split = summary.max_occupancy_pre_split.max(pre_split_max);
        summary.splits += split_out.subsets_split;

        if let Some(h) = history.as_mut() {
            let wall = t0.elapsed();
            h.push(IterationRecord {
                iteration: i,
                subsets: p_i,
                max_occupancy: occ_max,
                min_occupancy: occ_min,
                max_occupancy_pre_split: pre_split_max,
                splits: split_out.subsets_split,
                total_clusters,
                f_measure: f,
                wall,
                peak_matrix_bytes: iter_bytes,
                cache: cache_iter,
                carried_medoids: 0,
                representatives: 0,
                compression_ratio: 1.0,
                assignment_pairs: 0,
                sample_pairs: 0,
                sample_segments: 0,
                lb_pairs: prune_iter.lb_pairs,
                lb_pruned: prune_iter.lb_pruned,
                exact_pairs: prune_iter.exact_pairs,
                probe_rounds: 0,
                probe_rect_rows: 0,
                probe_rect_cols: 0,
                super_leaders: 0,
                aggregate_epsilon: 0.0,
                deviation_bound: 0.0,
                backend: backend.name().to_string(),
                pairs_per_sec: pairs_rate(iter_pairs, wall),
                metric: backend.metric_name().to_string(),
                silhouette_score: sil,
            });
        }

        prev_p = p_i;
        subsets = new_subsets;
    }

    anyhow::bail!("mahc episode loop ended without converging (max_iters = {max_iters})");
}

/// Stage 2 state shared by refine / evaluation / finalisation: the
/// medoid set, the member lists their clusters carry, and the Ward
/// dendrogram over the medoid distance matrix — built once per
/// iteration, cut as many times as needed.
struct MedoidStage {
    /// Global segment id of each medoid, parallel to the dendrogram's
    /// leaf order.
    medoid_ids: Vec<usize>,
    /// Member ids (global) of each stage-1 cluster, parallel to the
    /// medoid order used in the dendrogram.
    clusters_members: Vec<Vec<usize>>,
    dendro: crate::ahc::Dendrogram,
    /// The medoid condensed matrix, retained only when the evaluation
    /// cut must be silhouette-scored (silhouette selection).
    cond: Option<crate::distance::Condensed>,
    /// Medoid-matrix footprint (memory telemetry).
    bytes: usize,
    s: usize,
}

impl MedoidStage {
    fn build(
        set: &SegmentSet,
        outcomes: &[SubsetOutcome],
        backend: &dyn PairwiseBackend,
        threads: usize,
        cache: Option<&PairCache>,
        retain_cond: bool,
    ) -> anyhow::Result<MedoidStage> {
        let medoid_ids: Vec<usize> = outcomes
            .iter()
            .flat_map(|o| o.medoid_ids.iter().copied())
            .collect();
        let clusters_members: Vec<Vec<usize>> = outcomes
            .iter()
            .flat_map(|o| o.cluster_members())
            .collect();
        debug_assert_eq!(medoid_ids.len(), clusters_members.len());
        anyhow::ensure!(!medoid_ids.is_empty(), "no medoids from stage 1");

        // Medoids recur across iterations (a settled subset re-elects
        // the same representatives), so stage 2 reuses the same cache.
        let medoid_segs: Vec<&Segment> =
            medoid_ids.iter().map(|&i| &set.segments[i]).collect();
        let cond = build_condensed_cached(&medoid_segs, backend, threads, cache)?;
        let bytes = cond.bytes();
        let dendro = ahc::ward_linkage(&cond);
        Ok(MedoidStage {
            s: medoid_ids.len(),
            medoid_ids,
            clusters_members,
            dendro,
            cond: retain_cond.then_some(cond),
            bytes,
        })
    }

    /// Mean silhouette of the evaluation cut over the medoid matrix, or
    /// 0.0 when the matrix was not retained (L-method selection).
    fn silhouette_of_cut(&self, k_target: usize) -> f64 {
        match &self.cond {
            Some(cond) => {
                let (labels, k) = self.cut_groups(k_target);
                ahc::mean_silhouette(cond, &labels, k)
            }
            None => 0.0,
        }
    }

    /// Cut the medoid dendrogram into `target` groups (clamped to S).
    /// Returns per-medoid group labels and the group count.
    fn cut_groups(&self, target: usize) -> (Vec<usize>, usize) {
        let k = target.clamp(1, self.s);
        let labels = self.dendro.cut(k);
        let groups = labels.iter().copied().max().map_or(0, |m| m + 1);
        (labels, groups)
    }

    /// Steps 13-15: cut into `k_target` clusters and propagate labels
    /// to every member; returns (labels parallel to the episode's
    /// active-id order, actual k).  `pos_of` maps global segment id to
    /// position among the `n_active` active objects.
    fn cut_to_labels(
        &self,
        pos_of: &[usize],
        n_active: usize,
        k_target: usize,
    ) -> (Vec<usize>, usize) {
        let (group_labels, k) = self.cut_groups(k_target);
        let mut labels = vec![usize::MAX; n_active];
        for (m, members) in self.clusters_members.iter().enumerate() {
            for &id in members {
                labels[pos_of[id]] = group_labels[m];
            }
        }
        debug_assert!(labels.iter().all(|&l| l != usize::MAX));
        (labels, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetSpec;
    use crate::corpus::generate;
    use crate::distance::NativeBackend;

    fn run(cfg: AlgoConfig, n: usize, c: usize, seed: u64) -> MahcResult {
        let set = generate(&DatasetSpec::tiny(n, c, seed));
        let backend = NativeBackend::new();
        MahcDriver::new(&set, cfg, &backend).unwrap().run().unwrap()
    }

    #[test]
    fn produces_valid_partition() {
        let cfg = AlgoConfig {
            p0: 3,
            convergence: Convergence::FixedIters(3),
            ..Default::default()
        };
        let res = run(cfg, 90, 6, 21);
        assert_eq!(res.labels.len(), 90);
        assert!(res.k >= 1);
        assert!(res.labels.iter().all(|&l| l < res.k));
        assert_eq!(res.history.records.len(), 3);
        assert!(res.f_measure > 0.0 && res.f_measure <= 1.0);
        for r in &res.history.records {
            assert_eq!(r.backend, "native", "records name the serving backend");
            assert!(
                r.pairs_per_sec > 0.0,
                "every iteration computes pairs over nonzero wall"
            );
        }
    }

    #[test]
    fn beta_bound_holds_every_iteration() {
        let cfg = AlgoConfig {
            p0: 2,
            beta: Some(25),
            convergence: Convergence::FixedIters(4),
            ..Default::default()
        };
        let res = run(cfg, 100, 5, 22);
        for rec in &res.history.records {
            assert!(
                rec.max_occupancy <= 25,
                "iteration {} occupancy {} > β",
                rec.iteration,
                rec.max_occupancy
            );
        }
    }

    #[test]
    fn mahc_without_beta_can_exceed_initial_occupancy() {
        // Skewed data under plain MAHC: occupancy is free to grow past
        // N/P (this is Fig. 1's phenomenon; with tiny data we just check
        // the series is recorded and plausible).
        let cfg = AlgoConfig {
            p0: 4,
            beta: None,
            convergence: Convergence::FixedIters(4),
            ..Default::default()
        };
        let res = run(cfg, 80, 4, 23);
        assert_eq!(res.history.records.len(), 4);
        for rec in &res.history.records {
            assert!(rec.splits == 0, "no splits without β");
            assert!(rec.max_occupancy >= rec.min_occupancy);
            assert_eq!(rec.carried_medoids, 0, "batch runs carry nothing");
        }
    }

    #[test]
    fn clustering_beats_random_baseline() {
        let cfg = AlgoConfig {
            p0: 2,
            beta: Some(40),
            convergence: Convergence::FixedIters(4),
            ..Default::default()
        };
        let res = run(cfg, 100, 5, 24);
        // Random labels on this data score well under 0.4; structure
        // recovery should clear it comfortably.
        assert!(
            res.f_measure > 0.5,
            "F-measure {:.3} too low for separable data",
            res.f_measure
        );
    }

    #[test]
    fn settled_convergence_stops_early() {
        let cfg = AlgoConfig {
            p0: 3,
            convergence: Convergence::SettledSubsets { max_iters: 12 },
            ..Default::default()
        };
        let res = run(cfg, 60, 4, 25);
        assert!(res.history.records.len() <= 12);
        assert!(res.history.records.len() >= 4);
    }

    #[test]
    fn fixed_k_respected() {
        let cfg = AlgoConfig {
            p0: 2,
            final_k: FinalK::Fixed(7),
            convergence: Convergence::FixedIters(3),
            ..Default::default()
        };
        let res = run(cfg, 80, 5, 26);
        assert!(res.k <= 7);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = AlgoConfig {
            p0: 3,
            beta: Some(30),
            convergence: Convergence::FixedIters(3),
            ..Default::default()
        };
        let a = run(cfg.clone(), 70, 4, 27);
        let b = run(cfg, 70, 4, 27);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.k, b.k);
    }

    #[test]
    fn cache_changes_nothing_but_serves_hits() {
        let cfg = AlgoConfig {
            p0: 3,
            beta: Some(30),
            convergence: Convergence::FixedIters(4),
            ..Default::default()
        };
        let plain = run(cfg.clone(), 90, 5, 31);
        let cached = run(
            AlgoConfig {
                cache_bytes: 8 << 20,
                ..cfg
            },
            90,
            5,
            31,
        );
        // Identical clustering, bit for bit.
        assert_eq!(plain.labels, cached.labels);
        assert_eq!(plain.k, cached.k);
        assert_eq!(plain.f_measure, cached.f_measure);
        // The plain run reports a silent cache; the cached run reports
        // probes and, from iteration 2 on, reuse.
        assert_eq!(plain.history.cache_total().hits, 0);
        assert_eq!(plain.history.cache_total().misses, 0);
        let total = cached.history.cache_total();
        assert!(total.misses > 0);
        assert!(total.hits > 0, "recurring pairs must be served from cache");
        assert!(
            cached.history.records[1..]
                .iter()
                .any(|r| r.cache.hits > 0),
            "later iterations see warm pairs"
        );
    }

    #[test]
    fn prune_modes_reproduce_the_exact_run_bitwise() {
        // The cascade only answers threshold queries with bounds, and
        // every threshold consumer rejects above-radius values before
        // comparing magnitudes — so labels, K and F must be bit-equal
        // to the exact run, in both On and Debug (self-checking) modes.
        let base = AlgoConfig {
            p0: 3,
            beta: Some(30),
            convergence: Convergence::FixedIters(3),
            aggregate: crate::config::AggregateConfig::new(0.5),
            ..Default::default()
        };
        let exact = run(base.clone(), 80, 5, 33);
        assert!(
            exact
                .history
                .records
                .iter()
                .all(|r| r.lb_pairs == 0 && r.lb_pruned == 0 && r.exact_pairs == 0),
            "exact runs report silent prune counters"
        );
        for mode in [crate::config::PruneMode::On, crate::config::PruneMode::Debug] {
            let pruned = run(
                AlgoConfig {
                    prune: mode,
                    ..base.clone()
                },
                80,
                5,
                33,
            );
            assert_eq!(exact.labels, pruned.labels, "mode {mode:?}");
            assert_eq!(exact.k, pruned.k);
            assert_eq!(exact.f_measure.to_bits(), pruned.f_measure.to_bits());
            let first = &pruned.history.records[0];
            assert!(
                first.lb_pairs > 0,
                "leader probes must route through the bound (mode {mode:?})"
            );
            assert_eq!(first.backend, "native+lb");
        }
    }

    #[test]
    fn aggregate_epsilon_zero_is_bitwise_the_plain_run() {
        // The zero-risk opt-in pin: ε = 0 must take the identical code
        // path, so labels, K, F bits and telemetry all match the run
        // that never heard of aggregation.
        let plain_cfg = AlgoConfig {
            p0: 3,
            beta: Some(30),
            convergence: Convergence::FixedIters(3),
            ..Default::default()
        };
        let agg_cfg = AlgoConfig {
            aggregate: crate::config::AggregateConfig {
                epsilon: 0.0,
                cap: Some(5),
                ..Default::default()
            },
            ..plain_cfg.clone()
        };
        let plain = run(plain_cfg, 80, 5, 29);
        let agg = run(agg_cfg, 80, 5, 29);
        assert_eq!(plain.labels, agg.labels);
        assert_eq!(plain.k, agg.k);
        assert_eq!(plain.f_measure.to_bits(), agg.f_measure.to_bits());
        assert_eq!(plain.history.algo, agg.history.algo, "no +agg suffix at ε=0");
        assert_eq!(
            plain.history.records.len(),
            agg.history.records.len()
        );
        for (a, b) in plain.history.records.iter().zip(&agg.history.records) {
            assert_eq!(a.subsets, b.subsets);
            assert_eq!(a.max_occupancy, b.max_occupancy);
            assert_eq!(a.splits, b.splits);
            assert_eq!(a.total_clusters, b.total_clusters);
            assert_eq!(a.f_measure.to_bits(), b.f_measure.to_bits());
            assert_eq!(a.representatives, 0);
            assert_eq!(b.representatives, 0);
            assert_eq!(b.compression_ratio, 1.0);
            assert_eq!(b.assignment_pairs, 0);
        }
    }

    #[test]
    fn aggregated_run_covers_the_corpus_and_stamps_telemetry() {
        // A radius past every pair distance collapses the corpus onto
        // one representative — the most degenerate active aggregation —
        // and the run must still label all N and record the stage-0
        // series.
        let cfg = AlgoConfig {
            p0: 3,
            convergence: Convergence::FixedIters(2),
            aggregate: crate::config::AggregateConfig::new(1e30),
            ..Default::default()
        };
        let res = run(cfg, 40, 3, 30);
        assert_eq!(res.labels.len(), 40);
        assert_eq!(res.k, 1, "one representative yields one cluster");
        assert!(res.labels.iter().all(|&l| l == 0));
        assert_eq!(res.history.algo, "mahc+agg");
        for (idx, r) in res.history.records.iter().enumerate() {
            assert_eq!(r.representatives, 1);
            assert!((r.compression_ratio - 1.0 / 40.0).abs() < 1e-12);
            if idx == 0 {
                assert_eq!(r.assignment_pairs, 39, "one probe per later segment");
            } else {
                assert_eq!(r.assignment_pairs, 0);
            }
        }
        assert_eq!(res.history.assignment_pairs_total(), 39);
        assert_eq!(res.history.compression_ratio(), 1.0 / 40.0);
    }

    #[test]
    fn silhouette_selection_stamps_score_telemetry() {
        let base = AlgoConfig {
            p0: 3,
            convergence: Convergence::FixedIters(3),
            ..Default::default()
        };
        let lmethod = run(base.clone(), 90, 6, 35);
        for r in &lmethod.history.records {
            assert_eq!(r.metric, "dtw", "DTW backends report the dtw metric");
            assert_eq!(
                r.silhouette_score, 0.0,
                "no silhouette without silhouette selection"
            );
        }
        let sil = run(
            AlgoConfig {
                selection: crate::ahc::SelectionMethod::Silhouette,
                ..base
            },
            90,
            6,
            35,
        );
        assert!(sil.f_measure > 0.0 && sil.f_measure <= 1.0);
        assert!(
            sil.history.records.iter().all(|r| r.silhouette_score > 0.0),
            "separable data scores a positive silhouette each iteration"
        );
    }

    #[test]
    fn rejects_empty_dataset() {
        let set = SegmentSet {
            name: "empty".into(),
            dim: 3,
            segments: Vec::new(),
            num_classes: 0,
        };
        let backend = NativeBackend::new();
        assert!(MahcDriver::new(&set, AlgoConfig::default(), &backend).is_err());
    }

    #[test]
    fn episode_over_subset_of_ids_is_self_contained() {
        // The streaming building block: an episode over a strict subset
        // of the corpus must label exactly those objects, pick medoids
        // from them, and leave the rest untouched.
        let set = generate(&DatasetSpec::tiny(80, 5, 28));
        let backend = NativeBackend::new();
        let cfg = AlgoConfig {
            p0: 2,
            beta: Some(20),
            convergence: Convergence::FixedIters(3),
            ..Default::default()
        };
        let ids: Vec<usize> = (0..80).filter(|i| i % 2 == 0).collect();
        let mut rng = Rng::seed_from(cfg.seed);
        let ep = run_episode(&set, &ids, &cfg, &backend, None, None, &mut rng, None).unwrap();
        assert_eq!(ep.labels.len(), ids.len());
        assert!(ep.labels.iter().all(|&l| l < ep.k));
        assert!(!ep.medoid_ids.is_empty());
        for m in &ep.medoid_ids {
            assert!(ids.contains(m), "medoid {m} outside the episode's ids");
        }
        assert!(ep.summary.max_occupancy <= 20);
        assert!(ep.summary.iterations == 3);
        assert!(ep.summary.min_occupancy <= ep.summary.max_occupancy);
    }
}
