//! Multi-stage AHC with cluster size management — the paper's system.
//!
//! Algorithm 1 in module form:
//!
//! * [`partition`] — step 2: the initial division of 𝒳 into P₀ subsets
//!   (and the even subdivision primitive the split step reuses);
//! * [`stage`] — steps 3-5: per-subset AHC + L-method + medoids, run on
//!   the worker pool;
//! * [`split`] — step 9, the contribution: β enforcement by even
//!   subdivision of oversized subsets (plus the merge ablation the
//!   paper's Fig. 11 argues is unnecessary);
//! * [`driver`] — the iteration loop: stage 1 → medoid clustering
//!   (step 7) → refine (step 8) → split (step 9) → convergence test →
//!   final clustering (steps 13-15), with telemetry per iteration;
//! * [`streaming`] — the online form: one episode of the same loop per
//!   arriving shard, carrying medoids forward so peak memory stays
//!   bounded by β for streams of any length;
//! * [`serve`] — the multi-tenant form: many streaming sessions
//!   interleaved over one worker pool and one shared pair cache, with
//!   admission control and per-session budgets.
//!
//! Both drivers accept a stage-0 aggregation front-end
//! ([`crate::aggregate`]): with `AlgoConfig::aggregate` active they
//! cluster leader-pass representatives instead of raw segments and
//! resolve members through forwarding pointers, so labels still cover
//! the full corpus.  ε = 0 is bitwise the unaggregated pipeline.

pub mod driver;
pub mod partition;
pub mod serve;
pub mod split;
pub mod stage;
pub mod streaming;

pub use driver::{MahcDriver, MahcResult};
pub use partition::{even_partition, initial_partition, partition_ids};
pub use serve::{ServeDriver, ServeReport, SessionOutcome, SessionSpec};
pub use split::{merge_small, split_oversized};
pub use streaming::{StreamResult, StreamSession, StreamingDriver};
