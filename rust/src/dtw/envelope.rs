//! Per-segment feature envelopes for LB_Keogh-style DTW lower bounds.
//!
//! An [`Envelope`] holds the global per-dimension min/max of one
//! segment's frames.  Because DTW's local cost is the Euclidean frame
//! distance and every monotone warping path visits every frame of each
//! side at least once, clamping a frame against the other side's
//! envelope yields a cost no cell of the DP can undercut — summing
//! those clamped costs over one side's frames lower-bounds the
//! alignment total (banded or not: narrowing the band only removes
//! candidate paths, and the `INFEASIBLE` sentinel dominates any finite
//! bound).
//!
//! Float rigour matters here because the cascade's admissibility is
//! asserted bitwise: [`lb_one_sided`] accumulates squared clamps per
//! frame in the same ascending-dimension order as the DP's cell fill
//! (`classic::dtw_transposed`), and IEEE-754 round-to-nearest is
//! monotone under subtraction, multiplication of non-negatives,
//! addition of non-negatives, and square root — so the *floating-point*
//! bound never exceeds the *floating-point* DP total, not merely the
//! real-valued one.  `rust/tests/pruning.rs` fuzzes this inequality.

/// Global per-dimension bounds of one segment's frames.
#[derive(Debug, Clone)]
pub struct Envelope {
    /// Feature dimensionality (`lo.len() == hi.len() == dim`).
    pub dim: usize,
    /// Per-dimension minimum over all frames.
    pub lo: Vec<f32>,
    /// Per-dimension maximum over all frames.
    pub hi: Vec<f32>,
}

impl Envelope {
    /// Envelope of a flat row-major `(len, dim)` feature buffer.  An
    /// empty buffer yields an all-zero envelope (no segment has zero
    /// frames in practice; the kernel treats it as unboundedly loose).
    pub fn of_frames(feats: &[f32], dim: usize) -> Envelope {
        let mut frames = feats.chunks_exact(dim);
        let (mut lo, mut hi) = match frames.next() {
            Some(first) => (first.to_vec(), first.to_vec()),
            None => (vec![0.0f32; dim], vec![0.0f32; dim]),
        };
        for frame in frames {
            for ((l, h), &v) in lo.iter_mut().zip(hi.iter_mut()).zip(frame) {
                if v < *l {
                    *l = v;
                }
                if v > *h {
                    *h = v;
                }
            }
        }
        Envelope { dim, lo, hi }
    }
}

/// Unnormalised one-sided lower bound: Σ over frames of
/// `sqrt(Σ_d clamp_d²)`, where `clamp_d` is the distance from the
/// frame's value to the envelope's `[lo, hi]` interval in dimension
/// `d`.  Accumulation over `d` is sequential and ascending — the same
/// association order as the DP cell fill — so the bound is comparable
/// to the exact total bit for bit (see the module docs).
pub fn lb_one_sided(feats: &[f32], dim: usize, env: &Envelope) -> f32 {
    debug_assert_eq!(dim, env.dim);
    let mut total = 0.0f32;
    for frame in feats.chunks_exact(dim) {
        let mut acc = 0.0f32;
        for ((&v, &lo), &hi) in frame.iter().zip(&env.lo).zip(&env.hi) {
            let t = if v > hi {
                v - hi
            } else if v < lo {
                lo - v
            } else {
                0.0
            };
            acc += t * t;
        }
        total += acc.sqrt();
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn envelope_tracks_per_dim_extrema() {
        // 3 frames of dim 2.
        let feats = [0.0f32, 5.0, -2.0, 7.0, 1.0, 6.0];
        let env = Envelope::of_frames(&feats, 2);
        assert_eq!(env.lo, vec![-2.0, 5.0]);
        assert_eq!(env.hi, vec![1.0, 7.0]);
    }

    #[test]
    fn frames_inside_the_envelope_bound_to_zero() {
        let feats = [0.0f32, 1.0, 2.0, 3.0];
        let env = Envelope::of_frames(&feats, 1);
        assert_eq!(lb_one_sided(&feats, 1, &env), 0.0);
    }

    #[test]
    fn one_sided_bound_matches_hand_computation() {
        // Envelope of y = [1, 2] (dim 1): [1, 2].  x = [0, 3, 1.5]:
        // clamps 1, 1, 0 → total 2.
        let env = Envelope::of_frames(&[1.0f32, 2.0], 1);
        let x = [0.0f32, 3.0, 1.5];
        assert_eq!(lb_one_sided(&x, 1, &env), 2.0);
    }

    #[test]
    fn bound_never_exceeds_exact_dtw() {
        let dim = 3;
        let mk = |seed: usize, len: usize| -> Vec<f32> {
            (0..len * dim)
                .map(|k| ((k * 13 + seed * 7) as f32 * 0.37).sin() * 2.0)
                .collect()
        };
        for (sx, lx) in [(1usize, 4usize), (2, 7), (3, 11)] {
            for (sy, ly) in [(4usize, 5usize), (5, 9), (6, 3)] {
                let x = mk(sx, lx);
                let y = mk(sy, ly);
                let exact = crate::dtw::dtw(&x, &y, dim, lx, ly);
                let env_y = Envelope::of_frames(&y, dim);
                let env_x = Envelope::of_frames(&x, dim);
                let norm = (lx + ly) as f32;
                let lb_xy = lb_one_sided(&x, dim, &env_y) / norm;
                let lb_yx = lb_one_sided(&y, dim, &env_x) / norm;
                assert!(lb_xy <= exact, "lb {lb_xy} > exact {exact}");
                assert!(lb_yx <= exact, "lb {lb_yx} > exact {exact}");
            }
        }
    }
}
