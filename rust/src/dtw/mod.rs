//! Native DTW substrate — the reference backend and test oracle for the
//! AOT XLA path.
//!
//! Semantics are pinned to `python/compile/kernels/ref.py` (and thereby
//! to the Pallas kernel): unweighted step set {(1,0),(0,1),(1,1)},
//! Euclidean local distance, cost normalised by (lx + ly), optional
//! Sakoe-Chiba band.  The `rust-vs-artifact` integration test holds all
//! three implementations together.

pub mod classic;
pub mod envelope;

pub use classic::{dtw, dtw_banded, INFEASIBLE};
pub use envelope::{lb_one_sided, Envelope};
