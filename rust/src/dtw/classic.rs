//! Classic O(lx·ly) dynamic-programming DTW with rolling rows.
//!
//! Works on flat row-major `(len, dim)` f32 feature buffers — the layout
//! [`crate::corpus::Segment`] stores — and keeps only two DP rows, so a
//! single alignment is O(min-row) space.  f32 arithmetic matches the
//! Pallas kernel; accumulated error over realistic path lengths is
//! ~1e-5 relative (asserted in tests against an f64 shadow).

/// Distance reported for banded alignments with no feasible path
/// (|lx − ly| > band).  Mirrors the kernel's BIG sentinel after
/// normalisation; callers treat anything above `INFEASIBLE / 2` as
/// "no path".
pub const INFEASIBLE: f32 = 1.0e28;

#[inline]
fn frame_dist(x: &[f32], y: &[f32]) -> f32 {
    sq_dist(x, y).sqrt()
}

/// Squared Euclidean distance.  The zip-fold autovectorises well under
/// LLVM (measured faster than a manual 4-accumulator unroll on this
/// target — see EXPERIMENTS.md §Perf).
#[inline]
fn sq_dist(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(a, b)| {
            let d = a - b;
            d * d
        })
        .sum()
}

/// Normalised DTW distance between two flat `(len, dim)` sequences.
///
/// `x` has `lx` frames of `dim` floats; `y` has `ly`.  Returns
/// cost(lx−1, ly−1) / (lx + ly).
pub fn dtw(x: &[f32], y: &[f32], dim: usize, lx: usize, ly: usize) -> f32 {
    dtw_impl(x, y, dim, lx, ly, None)
}

/// Sakoe-Chiba banded variant; returns [`INFEASIBLE`] when no monotone
/// path stays within the band.
pub fn dtw_banded(x: &[f32], y: &[f32], dim: usize, lx: usize, ly: usize, band: usize) -> f32 {
    dtw_impl(x, y, dim, lx, ly, Some(band))
}

fn dtw_impl(x: &[f32], y: &[f32], dim: usize, lx: usize, ly: usize, band: Option<usize>) -> f32 {
    assert!(lx >= 1 && ly >= 1, "empty sequence");
    assert!(x.len() >= lx * dim && y.len() >= ly * dim, "buffer too short");
    match band {
        None => dtw_unbanded(x, y, dim, lx, ly),
        Some(b) => dtw_banded_impl(x, y, dim, lx, ly, b),
    }
}

/// Unbanded fast path: every cell is reachable, so the BIG sentinel
/// logic disappears; the left neighbour rides in a register and the
/// first row/column are peeled out of the hot loop.
fn dtw_unbanded(x: &[f32], y: &[f32], dim: usize, lx: usize, ly: usize) -> f32 {
    let yt = Transposed::from_row_major(y, dim, ly);
    let mut scratch = DtwScratch::new();
    dtw_transposed(x, dim, lx, &yt, &mut scratch)
}

/// Y features in (dim, len) layout: `data[d * len + j]` — lets the
/// local-distance row accumulate with vector FMAs *across j* instead of
/// a serial 39-element reduction per cell (the main §Perf win on the
/// native backend; the same transposition the Pallas kernel gets for
/// free from its matmul formulation).
#[derive(Debug, Clone)]
pub struct Transposed {
    pub dim: usize,
    pub len: usize,
    data: Vec<f32>,
}

impl Transposed {
    pub fn from_row_major(y: &[f32], dim: usize, len: usize) -> Transposed {
        let mut data = vec![0.0f32; dim * len];
        for j in 0..len {
            for d in 0..dim {
                data[d * len + j] = y[j * dim + d];
            }
        }
        Transposed { dim, len, data }
    }

    #[inline]
    fn dim_row(&self, d: usize) -> &[f32] {
        &self.data[d * self.len..(d + 1) * self.len]
    }
}

/// Reusable buffers so the per-pair loop allocates nothing.
#[derive(Debug, Default)]
pub struct DtwScratch {
    dist: Vec<f32>,
    prev: Vec<f32>,
    cur: Vec<f32>,
}

impl DtwScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn resize(&mut self, ly: usize) {
        self.dist.resize(ly, 0.0);
        self.prev.resize(ly, 0.0);
        self.cur.resize(ly, 0.0);
    }
}

/// Row-vectorised DTW against a transposed Y.  Semantics identical to
/// [`dtw`] (asserted by tests); layout is the only difference.
pub fn dtw_transposed(
    x: &[f32],
    dim: usize,
    lx: usize,
    yt: &Transposed,
    scratch: &mut DtwScratch,
) -> f32 {
    let ly = yt.len;
    debug_assert_eq!(dim, yt.dim);
    assert!(lx >= 1 && ly >= 1, "empty sequence");
    scratch.resize(ly);
    let DtwScratch { dist, prev, cur } = scratch;

    // Fill the local-distance row for x frame i: dist[j] = ||x_i - y_j||.
    let fill_row = |dist: &mut [f32], xi: &[f32]| {
        dist.fill(0.0);
        for d in 0..dim {
            let xv = xi[d];
            let yrow = yt.dim_row(d);
            for (acc, &yv) in dist.iter_mut().zip(yrow) {
                let t = xv - yv;
                *acc += t * t; // vector FMA across j
            }
        }
        for v in dist.iter_mut() {
            *v = v.sqrt(); // vector sqrt across j
        }
    };

    // Row 0: cumulative along j.
    fill_row(dist, &x[0..dim]);
    let mut run = 0.0f32;
    for j in 0..ly {
        run += dist[j];
        prev[j] = run;
    }

    for i in 1..lx {
        fill_row(dist, &x[i * dim..(i + 1) * dim]);
        let mut left = prev[0] + dist[0];
        cur[0] = left;
        let mut diag = prev[0];
        for j in 1..ly {
            let up = prev[j];
            let best = diag.min(up).min(left);
            left = dist[j] + best;
            cur[j] = left;
            diag = up;
        }
        std::mem::swap(prev, cur);
    }
    prev[ly - 1] / (lx + ly) as f32
}

fn dtw_banded_impl(x: &[f32], y: &[f32], dim: usize, lx: usize, ly: usize, band: usize) -> f32 {
    const BIG: f32 = 1.0e30;
    let mut prev = vec![BIG; ly];
    let mut cur = vec![BIG; ly];

    for i in 0..lx {
        let xi = &x[i * dim..(i + 1) * dim];
        let j_lo = i.saturating_sub(band);
        let j_hi = (i + band + 1).min(ly);
        for v in cur.iter_mut() {
            *v = BIG;
        }
        for j in j_lo..j_hi {
            let d = frame_dist(xi, &y[j * dim..(j + 1) * dim]);
            let best = if i == 0 && j == 0 {
                0.0
            } else {
                let mut m = BIG;
                if i > 0 {
                    m = m.min(prev[j]); // (i-1, j)
                    if j > 0 {
                        m = m.min(prev[j - 1]); // (i-1, j-1)
                    }
                }
                if j > 0 {
                    m = m.min(cur[j - 1]); // (i, j-1)
                }
                m
            };
            cur[j] = if best >= BIG { BIG } else { d + best };
        }
        std::mem::swap(&mut prev, &mut cur);
    }

    let total = prev[ly - 1];
    if total >= BIG {
        INFEASIBLE
    } else {
        total / (lx + ly) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(vals: &[f32]) -> Vec<f32> {
        vals.to_vec()
    }

    #[test]
    fn identical_sequences_zero() {
        let x = seq(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(dtw(&x, &x, 1, 4, 4), 0.0);
    }

    #[test]
    fn single_frames() {
        // d = |3 - 7| = 4, normalised by (1+1).
        let x = seq(&[3.0]);
        let y = seq(&[7.0]);
        assert!((dtw(&x, &y, 1, 1, 1) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn known_small_case() {
        // x = [0, 1], y = [0, 1, 1]: warping absorbs the repeat, cost 0.
        let x = seq(&[0.0, 1.0]);
        let y = seq(&[0.0, 1.0, 1.0]);
        assert!(dtw(&x, &y, 1, 2, 3).abs() < 1e-7);
    }

    #[test]
    fn hand_computed_case() {
        // x = [0, 3], y = [1, 2]:
        //   d = [[1,2],[2,1]]; C(0,0)=1; C(0,1)=3; C(1,0)=3; C(1,1)=2.
        //   result = 2 / 4 = 0.5
        let x = seq(&[0.0, 3.0]);
        let y = seq(&[1.0, 2.0]);
        assert!((dtw(&x, &y, 1, 2, 2) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn symmetry() {
        let x = seq(&[0.0, 1.5, 2.0, -1.0, 0.5]);
        let y = seq(&[1.0, 1.0, -2.0]);
        let a = dtw(&x, &y, 1, 5, 3);
        let b = dtw(&y, &x, 1, 3, 5);
        assert!((a - b).abs() < 1e-6);
    }

    #[test]
    fn multidim_frames() {
        let x = seq(&[0.0, 0.0, 3.0, 4.0]); // 2 frames of dim 2
        let y = seq(&[0.0, 0.0]); // 1 frame
        // d(x0,y0)=0, d(x1,y0)=5; path (0,0)->(1,0): cost 5, norm 3.
        assert!((dtw(&x, &y, 2, 2, 1) - 5.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn band_feasible_matches_unbanded_when_wide() {
        let x = seq(&[0.0, 1.0, 2.0, 3.0, 4.0]);
        let y = seq(&[0.0, 2.0, 4.0, 4.0, 5.0]);
        let full = dtw(&x, &y, 1, 5, 5);
        let banded = dtw_banded(&x, &y, 1, 5, 5, 10);
        assert!((full - banded).abs() < 1e-6);
    }

    #[test]
    fn band_infeasible_when_lengths_diverge() {
        let x = seq(&[0.0; 10]);
        let y = seq(&[0.0; 2]);
        assert!(dtw_banded(&x, &y, 1, 10, 2, 3) >= INFEASIBLE / 2.0);
    }

    #[test]
    fn band_restricts_path_cost() {
        // With band 0 the path is forced onto the diagonal.
        let x = seq(&[0.0, 10.0, 0.0]);
        let y = seq(&[0.0, 0.0, 0.0]);
        let tight = dtw_banded(&x, &y, 1, 3, 3, 0);
        let loose = dtw(&x, &y, 1, 3, 3);
        assert!(tight >= loose);
        assert!((tight - 10.0 / 6.0).abs() < 1e-6);
    }

    #[test]
    fn triangle_like_on_constant_segments() {
        // Constant sequences reduce DTW to scaled point distance.
        let a = vec![1.0f32; 6];
        let b = vec![4.0f32; 6];
        let c = vec![9.0f32; 6];
        let dab = dtw(&a, &b, 1, 6, 6);
        let dbc = dtw(&b, &c, 1, 6, 6);
        let dac = dtw(&a, &c, 1, 6, 6);
        assert!(dac <= dab + dbc + 1e-6);
    }

    #[test]
    #[should_panic]
    fn empty_sequence_panics() {
        dtw(&[], &[1.0], 1, 0, 1);
    }
}
