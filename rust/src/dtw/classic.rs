//! Classic O(lx·ly) dynamic-programming DTW with rolling rows.
//!
//! Works on flat row-major `(len, dim)` f32 feature buffers — the layout
//! [`crate::corpus::Segment`] stores — and keeps only two DP rows, so a
//! single alignment is O(min-row) space.  f32 arithmetic matches the
//! Pallas kernel; accumulated error over realistic path lengths is
//! ~1e-5 relative (asserted in tests against an f64 shadow).

/// Distance reported for banded alignments with no feasible path
/// (|lx − ly| > band).  Mirrors the kernel's BIG sentinel after
/// normalisation; callers treat anything above `INFEASIBLE / 2` as
/// "no path".
pub const INFEASIBLE: f32 = 1.0e28;

/// Frame distance ||x − y||, used by tests as the scalar oracle for the
/// row-vectorised fills.  The zip-fold accumulation order is the
/// contract: both the unbanded and banded band fills sum squared
/// differences in the same `d` order, so their sums are bitwise equal
/// to this fold (see EXPERIMENTS.md §Perf).
#[cfg(test)]
fn frame_dist(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(a, b)| {
            let d = a - b;
            d * d
        })
        .sum::<f32>()
        .sqrt()
}

/// Normalised DTW distance between two flat `(len, dim)` sequences.
///
/// `x` has `lx` frames of `dim` floats; `y` has `ly`.  Returns
/// cost(lx−1, ly−1) / (lx + ly).
pub fn dtw(x: &[f32], y: &[f32], dim: usize, lx: usize, ly: usize) -> f32 {
    dtw_impl(x, y, dim, lx, ly, None)
}

/// Sakoe-Chiba banded variant; returns [`INFEASIBLE`] when no monotone
/// path stays within the band.
pub fn dtw_banded(x: &[f32], y: &[f32], dim: usize, lx: usize, ly: usize, band: usize) -> f32 {
    dtw_impl(x, y, dim, lx, ly, Some(band))
}

fn dtw_impl(x: &[f32], y: &[f32], dim: usize, lx: usize, ly: usize, band: Option<usize>) -> f32 {
    assert!(lx >= 1 && ly >= 1, "empty sequence");
    assert!(x.len() >= lx * dim && y.len() >= ly * dim, "buffer too short");
    match band {
        None => dtw_unbanded(x, y, dim, lx, ly),
        Some(b) => dtw_banded_impl(x, y, dim, lx, ly, b),
    }
}

/// Unbanded fast path: every cell is reachable, so the BIG sentinel
/// logic disappears; the left neighbour rides in a register and the
/// first row/column are peeled out of the hot loop.
fn dtw_unbanded(x: &[f32], y: &[f32], dim: usize, lx: usize, ly: usize) -> f32 {
    let yt = Transposed::from_row_major(y, dim, ly);
    let mut scratch = DtwScratch::new();
    dtw_transposed(x, dim, lx, &yt, &mut scratch)
}

/// Y features in (dim, len) layout: `data[d * len + j]` — lets the
/// local-distance row accumulate with vector FMAs *across j* instead of
/// a serial 39-element reduction per cell (the main §Perf win on the
/// native backend; the same transposition the Pallas kernel gets for
/// free from its matmul formulation).
#[derive(Debug, Clone)]
pub struct Transposed {
    pub dim: usize,
    pub len: usize,
    data: Vec<f32>,
}

impl Transposed {
    pub fn from_row_major(y: &[f32], dim: usize, len: usize) -> Transposed {
        let mut data = vec![0.0f32; dim * len];
        for j in 0..len {
            for d in 0..dim {
                data[d * len + j] = y[j * dim + d];
            }
        }
        Transposed { dim, len, data }
    }

    #[inline]
    fn dim_row(&self, d: usize) -> &[f32] {
        &self.data[d * self.len..(d + 1) * self.len]
    }
}

/// Reusable buffers so the per-pair loop allocates nothing.
#[derive(Debug, Default)]
pub struct DtwScratch {
    dist: Vec<f32>,
    prev: Vec<f32>,
    cur: Vec<f32>,
}

impl DtwScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn resize(&mut self, ly: usize) {
        self.dist.resize(ly, 0.0);
        self.prev.resize(ly, 0.0);
        self.cur.resize(ly, 0.0);
    }
}

/// Row-vectorised DTW against a transposed Y.  Semantics identical to
/// [`dtw`] (asserted by tests); layout is the only difference.
pub fn dtw_transposed(
    x: &[f32],
    dim: usize,
    lx: usize,
    yt: &Transposed,
    scratch: &mut DtwScratch,
) -> f32 {
    let ly = yt.len;
    debug_assert_eq!(dim, yt.dim);
    assert!(lx >= 1 && ly >= 1, "empty sequence");
    scratch.resize(ly);
    let DtwScratch { dist, prev, cur } = scratch;

    // Fill the local-distance row for x frame i: dist[j] = ||x_i - y_j||.
    let fill_row = |dist: &mut [f32], xi: &[f32]| {
        dist.fill(0.0);
        for d in 0..dim {
            let xv = xi[d];
            let yrow = yt.dim_row(d);
            for (acc, &yv) in dist.iter_mut().zip(yrow) {
                let t = xv - yv;
                *acc += t * t; // vector FMA across j
            }
        }
        for v in dist.iter_mut() {
            *v = v.sqrt(); // vector sqrt across j
        }
    };

    // Row 0: cumulative along j.
    fill_row(dist, &x[0..dim]);
    let mut run = 0.0f32;
    for j in 0..ly {
        run += dist[j];
        prev[j] = run;
    }

    for i in 1..lx {
        fill_row(dist, &x[i * dim..(i + 1) * dim]);
        let mut left = prev[0] + dist[0];
        cur[0] = left;
        let mut diag = prev[0];
        for j in 1..ly {
            let up = prev[j];
            let best = diag.min(up).min(left);
            left = dist[j] + best;
            cur[j] = left;
            diag = up;
        }
        std::mem::swap(prev, cur);
    }
    prev[ly - 1] / (lx + ly) as f32
}

fn dtw_banded_impl(x: &[f32], y: &[f32], dim: usize, lx: usize, ly: usize, band: usize) -> f32 {
    let yt = Transposed::from_row_major(y, dim, ly);
    let mut scratch = DtwScratch::new();
    dtw_banded_transposed(x, dim, lx, &yt, band, &mut scratch)
}

/// Banded DTW with the same [`Transposed`]/[`DtwScratch`] treatment as
/// [`dtw_transposed`]: the band slice of the local-distance row fills
/// with vector FMAs across j and the DP reuses the scratch rows, so the
/// pair loop allocates nothing.  Semantics — including the [`INFEASIBLE`]
/// sentinel and f32 summation order — are identical to the historical
/// two-`Vec`-per-pair implementation (pinned by tests), so cached and
/// uncached banded builds stay bitwise comparable.
pub fn dtw_banded_transposed(
    x: &[f32],
    dim: usize,
    lx: usize,
    yt: &Transposed,
    band: usize,
    scratch: &mut DtwScratch,
) -> f32 {
    const BIG: f32 = 1.0e30;
    let ly = yt.len;
    debug_assert_eq!(dim, yt.dim);
    assert!(lx >= 1 && ly >= 1, "empty sequence");
    scratch.resize(ly);
    let DtwScratch { dist, prev, cur } = scratch;

    // Band slice of the local-distance row for x frame i:
    // dist[j] = ||x_i − y_j|| for j in [j_lo, j_hi).  Accumulation
    // order over d matches `frame_dist`'s fold, so sums are bitwise
    // equal to the scalar path.
    let fill_band = |dist: &mut [f32], xi: &[f32], j_lo: usize, j_hi: usize| {
        let dw = &mut dist[j_lo..j_hi];
        dw.fill(0.0);
        for (d, &xv) in xi.iter().enumerate() {
            let yrow = &yt.dim_row(d)[j_lo..j_hi];
            for (acc, &yv) in dw.iter_mut().zip(yrow) {
                let t = xv - yv;
                *acc += t * t; // vector FMA across j
            }
        }
        for v in dw.iter_mut() {
            *v = v.sqrt(); // vector sqrt across j
        }
    };

    for i in 0..lx {
        let xi = &x[i * dim..(i + 1) * dim];
        let j_lo = i.saturating_sub(band);
        let j_hi = (i + band + 1).min(ly);
        for v in cur.iter_mut() {
            *v = BIG;
        }
        if j_lo >= j_hi {
            // Band left the matrix entirely: no reachable cell this row.
            std::mem::swap(prev, cur);
            continue;
        }
        fill_band(dist, xi, j_lo, j_hi);
        for j in j_lo..j_hi {
            let d = dist[j];
            let best = if i == 0 && j == 0 {
                0.0
            } else {
                let mut m = BIG;
                if i > 0 {
                    m = m.min(prev[j]); // (i-1, j)
                    if j > 0 {
                        m = m.min(prev[j - 1]); // (i-1, j-1)
                    }
                }
                if j > 0 {
                    m = m.min(cur[j - 1]); // (i, j-1)
                }
                m
            };
            cur[j] = if best >= BIG { BIG } else { d + best };
        }
        std::mem::swap(prev, cur);
    }

    let total = prev[ly - 1];
    if total >= BIG {
        INFEASIBLE
    } else {
        total / (lx + ly) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(vals: &[f32]) -> Vec<f32> {
        vals.to_vec()
    }

    #[test]
    fn identical_sequences_zero() {
        let x = seq(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(dtw(&x, &x, 1, 4, 4), 0.0);
    }

    #[test]
    fn single_frames() {
        // d = |3 - 7| = 4, normalised by (1+1).
        let x = seq(&[3.0]);
        let y = seq(&[7.0]);
        assert!((dtw(&x, &y, 1, 1, 1) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn known_small_case() {
        // x = [0, 1], y = [0, 1, 1]: warping absorbs the repeat, cost 0.
        let x = seq(&[0.0, 1.0]);
        let y = seq(&[0.0, 1.0, 1.0]);
        assert!(dtw(&x, &y, 1, 2, 3).abs() < 1e-7);
    }

    #[test]
    fn hand_computed_case() {
        // x = [0, 3], y = [1, 2]:
        //   d = [[1,2],[2,1]]; C(0,0)=1; C(0,1)=3; C(1,0)=3; C(1,1)=2.
        //   result = 2 / 4 = 0.5
        let x = seq(&[0.0, 3.0]);
        let y = seq(&[1.0, 2.0]);
        assert!((dtw(&x, &y, 1, 2, 2) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn symmetry() {
        let x = seq(&[0.0, 1.5, 2.0, -1.0, 0.5]);
        let y = seq(&[1.0, 1.0, -2.0]);
        let a = dtw(&x, &y, 1, 5, 3);
        let b = dtw(&y, &x, 1, 3, 5);
        assert!((a - b).abs() < 1e-6);
    }

    #[test]
    fn multidim_frames() {
        let x = seq(&[0.0, 0.0, 3.0, 4.0]); // 2 frames of dim 2
        let y = seq(&[0.0, 0.0]); // 1 frame
        // d(x0,y0)=0, d(x1,y0)=5; path (0,0)->(1,0): cost 5, norm 3.
        assert!((dtw(&x, &y, 2, 2, 1) - 5.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn band_feasible_matches_unbanded_when_wide() {
        let x = seq(&[0.0, 1.0, 2.0, 3.0, 4.0]);
        let y = seq(&[0.0, 2.0, 4.0, 4.0, 5.0]);
        let full = dtw(&x, &y, 1, 5, 5);
        let banded = dtw_banded(&x, &y, 1, 5, 5, 10);
        assert!((full - banded).abs() < 1e-6);
    }

    #[test]
    fn band_infeasible_when_lengths_diverge() {
        let x = seq(&[0.0; 10]);
        let y = seq(&[0.0; 2]);
        assert!(dtw_banded(&x, &y, 1, 10, 2, 3) >= INFEASIBLE / 2.0);
    }

    #[test]
    fn band_restricts_path_cost() {
        // With band 0 the path is forced onto the diagonal.
        let x = seq(&[0.0, 10.0, 0.0]);
        let y = seq(&[0.0, 0.0, 0.0]);
        let tight = dtw_banded(&x, &y, 1, 3, 3, 0);
        let loose = dtw(&x, &y, 1, 3, 3);
        assert!(tight >= loose);
        assert!((tight - 10.0 / 6.0).abs() < 1e-6);
    }

    #[test]
    fn triangle_like_on_constant_segments() {
        // Constant sequences reduce DTW to scaled point distance.
        let a = vec![1.0f32; 6];
        let b = vec![4.0f32; 6];
        let c = vec![9.0f32; 6];
        let dab = dtw(&a, &b, 1, 6, 6);
        let dbc = dtw(&b, &c, 1, 6, 6);
        let dac = dtw(&a, &c, 1, 6, 6);
        assert!(dac <= dab + dbc + 1e-6);
    }

    #[test]
    #[should_panic]
    fn empty_sequence_panics() {
        dtw(&[], &[1.0], 1, 0, 1);
    }

    /// Deterministic multi-dim test sequences of assorted lengths.
    fn multidim_seqs(dim: usize) -> Vec<(Vec<f32>, usize)> {
        [3usize, 5, 9, 12]
            .iter()
            .map(|&len| {
                let feats: Vec<f32> = (0..len * dim)
                    .map(|k| ((k * 7 + len) as f32 * 0.31).sin() * 2.0)
                    .collect();
                (feats, len)
            })
            .collect()
    }

    #[test]
    fn banded_scratch_reuse_bitwise_matches_one_shot() {
        // One scratch shared across pairs of different shapes must give
        // exactly the per-pair-allocating API's results — this is the
        // NativeBackend::banded hot-path contract.
        let dim = 3;
        let seqs = multidim_seqs(dim);
        let mut scratch = DtwScratch::new();
        for (xf, lx) in &seqs {
            for (yf, ly) in &seqs {
                let yt = Transposed::from_row_major(yf, dim, *ly);
                for band in [0usize, 2, 100] {
                    let shared = dtw_banded_transposed(xf, dim, *lx, &yt, band, &mut scratch);
                    let fresh = dtw_banded(xf, yf, dim, *lx, *ly, band);
                    assert_eq!(shared.to_bits(), fresh.to_bits(), "band {band}");
                }
            }
        }
    }

    #[test]
    fn full_cover_band_is_bitwise_equal_to_unbanded() {
        // band ≥ max(lx, ly) makes every cell reachable, and the banded
        // DP's min chain — min(min(min(BIG, up), diag), left) with all
        // operands finite, non-negative and below BIG — selects the same
        // value as the unbanded diag.min(up).min(left); additions
        // commute bitwise in IEEE 754.  So full coverage is not merely
        // close: it is bit-for-bit the unbanded result.
        let dim = 3;
        let seqs = multidim_seqs(dim);
        for (xf, lx) in &seqs {
            for (yf, ly) in &seqs {
                let full = dtw(xf, yf, dim, *lx, *ly);
                let band = (*lx).max(*ly);
                let banded = dtw_banded(xf, yf, dim, *lx, *ly, band);
                assert_eq!(
                    full.to_bits(),
                    banded.to_bits(),
                    "lx={lx} ly={ly} band={band}: {full} vs {banded}"
                );
            }
        }
    }

    #[test]
    fn band_cost_monotone_non_increasing_as_band_widens() {
        // Widening the band only adds candidate paths, so the optimum
        // can never get worse; INFEASIBLE (no path) dominates any
        // feasible cost, so the monotone chain holds from band 0 up.
        let dim = 2;
        let seqs = multidim_seqs(dim);
        for (xf, lx) in &seqs {
            for (yf, ly) in &seqs {
                let mut prev = f32::INFINITY;
                for band in 0..=(*lx).max(*ly) + 1 {
                    let cost = dtw_banded(xf, yf, dim, *lx, *ly, band);
                    assert!(
                        cost <= prev,
                        "lx={lx} ly={ly}: band {band} cost {cost} > narrower {prev}"
                    );
                    prev = cost;
                }
            }
        }
    }

    #[test]
    fn length_one_segments_band_semantics() {
        // 1×m with band 0 reaches only cell (0,0): no path to the final
        // column, so the alignment is infeasible; a covering band must
        // reproduce the unbanded result exactly.
        let dim = 2;
        let x = seq(&[0.5, -1.0]); // 1 frame
        let y: Vec<f32> = (0..4 * dim).map(|k| (k as f32 * 0.3).sin()).collect();
        assert!(dtw_banded(&x, &y, dim, 1, 4, 0) >= INFEASIBLE / 2.0);
        assert_eq!(
            dtw_banded(&x, &y, dim, 1, 4, 4).to_bits(),
            dtw(&x, &y, dim, 1, 4).to_bits()
        );
        // 1×1 is feasible even at band 0 and equals the unbanded pair.
        let z = seq(&[2.0, 2.0]);
        assert_eq!(
            dtw_banded(&x, &z, dim, 1, 1, 0).to_bits(),
            dtw(&x, &z, dim, 1, 1).to_bits()
        );
    }

    #[test]
    #[should_panic(expected = "buffer too short")]
    fn short_buffer_for_claimed_shape_panics() {
        // A dim/len claim larger than the buffer (the dim-mismatch
        // failure mode) must be a loud panic, not a quiet misread.
        dtw(&[1.0], &[1.0, 2.0, 3.0, 4.0], 2, 1, 2);
    }

    #[test]
    #[should_panic(expected = "buffer too short")]
    fn banded_short_buffer_panics_too() {
        dtw_banded(&[1.0, 2.0, 3.0], &[1.0, 2.0], 2, 2, 1, 1);
    }

    #[test]
    fn banded_wide_band_matches_unbanded_multidim() {
        let dim = 3;
        let seqs = multidim_seqs(dim);
        for (xf, lx) in &seqs {
            for (yf, ly) in &seqs {
                let full = dtw(xf, yf, dim, *lx, *ly);
                let banded = dtw_banded(xf, yf, dim, *lx, *ly, 64);
                assert!(
                    (full - banded).abs() < 1e-5,
                    "full {full} vs banded {banded}"
                );
            }
        }
    }

    #[test]
    fn banded_band_fill_matches_frame_dist_oracle() {
        // The vectorised band fill must agree with the scalar frame
        // distance bit for bit (same accumulation order over d).
        let dim = 4;
        let x: Vec<f32> = (0..dim).map(|d| d as f32 * 0.7 - 1.0).collect();
        let y: Vec<f32> = (0..3 * dim).map(|k| (k as f32 * 0.13).cos()).collect();
        let yt = Transposed::from_row_major(&y, dim, 3);
        // Degenerate 1-frame x against 3-frame y with a full band: the
        // DP total is min over a monotone path; with lx=1 the path must
        // visit every j, so the result is Σ_j d(x, y_j) / 4.
        let mut scratch = DtwScratch::new();
        let got = dtw_banded_transposed(&x, dim, 1, &yt, 8, &mut scratch);
        let want: f32 = (0..3)
            .map(|j| frame_dist(&x, &y[j * dim..(j + 1) * dim]))
            .sum::<f32>()
            / 4.0;
        assert_eq!(got.to_bits(), want.to_bits());
    }
}
