//! Condensed (packed lower-triangular) symmetric distance matrix.
//!
//! Stores the n(n−1)/2 distinct pairwise distances of an n-object set —
//! exactly the structure whose size the paper's β threshold bounds.
//! Entry (i, j), i ≠ j, lives at `tri(max) + min` where
//! `tri(i) = i(i−1)/2`; the diagonal is implicitly zero.

/// Packed symmetric distance matrix with implicit zero diagonal.
#[derive(Debug, Clone)]
pub struct Condensed {
    n: usize,
    data: Vec<f32>,
}

#[inline]
fn tri(i: usize) -> usize {
    i * (i - 1) / 2
}

impl Condensed {
    /// All-zero matrix for `n` objects.
    pub fn zeros(n: usize) -> Self {
        let m = if n < 2 { 0 } else { n * (n - 1) / 2 };
        Condensed {
            n,
            data: vec![0.0; m],
        }
    }

    /// Construct from a full row-major n×n matrix (must be symmetric;
    /// only the lower triangle is read).
    pub fn from_full(n: usize, full: &[f32]) -> Self {
        assert_eq!(full.len(), n * n);
        let mut c = Condensed::zeros(n);
        for i in 1..n {
            for j in 0..i {
                c.set(i, j, full[i * n + j]);
            }
        }
        c
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of stored entries.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Bytes of storage — the quantity β guards (telemetry).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    #[inline]
    fn idx(&self, i: usize, j: usize) -> usize {
        debug_assert!(i != j && i < self.n && j < self.n);
        let (hi, lo) = if i > j { (i, j) } else { (j, i) };
        tri(hi) + lo
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        if i == j {
            0.0
        } else {
            self.data[self.idx(i, j)]
        }
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        let k = self.idx(i, j);
        self.data[k] = v;
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Row `i` of the lower triangle as a slice: distances (i, 0..i).
    /// Contiguous by construction — the AHC inner loops scan these.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[tri(i)..tri(i) + i]
    }

    /// Mean of all stored distances (telemetry / tests).
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            0.0
        } else {
            super::fixed_order_sum(&self.data) / self.data.len() as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_and_symmetry() {
        let mut c = Condensed::zeros(4);
        assert_eq!(c.len(), 6);
        c.set(1, 0, 0.5);
        c.set(2, 1, 1.5);
        c.set(0, 3, 3.0); // reversed order works too
        assert_eq!(c.get(0, 1), 0.5);
        assert_eq!(c.get(1, 2), 1.5);
        assert_eq!(c.get(3, 0), 3.0);
        assert_eq!(c.get(2, 2), 0.0);
    }

    #[test]
    fn row_slices() {
        let mut c = Condensed::zeros(4);
        for i in 1..4 {
            for j in 0..i {
                c.set(i, j, (i * 10 + j) as f32);
            }
        }
        assert_eq!(c.row(1), &[10.0]);
        assert_eq!(c.row(2), &[20.0, 21.0]);
        assert_eq!(c.row(3), &[30.0, 31.0, 32.0]);
    }

    #[test]
    fn from_full_round_trip() {
        let full = vec![
            0.0, 1.0, 2.0, //
            1.0, 0.0, 3.0, //
            2.0, 3.0, 0.0,
        ];
        let c = Condensed::from_full(3, &full);
        assert_eq!(c.get(0, 1), 1.0);
        assert_eq!(c.get(0, 2), 2.0);
        assert_eq!(c.get(1, 2), 3.0);
    }

    #[test]
    fn small_ns() {
        assert_eq!(Condensed::zeros(0).len(), 0);
        assert_eq!(Condensed::zeros(1).len(), 0);
        assert_eq!(Condensed::zeros(2).len(), 1);
    }

    #[test]
    fn bytes_accounting() {
        let c = Condensed::zeros(100);
        assert_eq!(c.bytes(), 100 * 99 / 2 * 4);
    }
}
