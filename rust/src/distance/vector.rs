//! Fixed-dimension vector metrics: cosine and Euclidean backends.
//!
//! The diarization-embedding workload (SNIPPETS.md exemplars) clusters
//! fixed-dimension speaker embeddings instead of variable-length frame
//! sequences.  [`VectorBackend`] serves it behind the same
//! [`PairwiseBackend`] trait as the DTW kernels: a segment's flat
//! `feats` buffer (`len · dim` values) is treated as one vector, so an
//! embedding corpus is simply a [`Segment`] set with `len == 1`.  Every
//! consumer — cached builders, cascade, drivers, serve — works
//! unchanged at a fraction of DTW's per-pair cost.
//!
//! **Backend-invariance contract** (mirrors `blocked.rs`, verified by
//! `rust/tests/metric_parity.rs`): the scalar and 8-lane blocked
//! variants execute the *same* per-pair f32 operation sequence — the
//! same ascending-element accumulation into an independent per-pair
//! chain, the same shared finalisation — so their results are bitwise
//! identical and the two variants share one cache
//! [`kernel_tag`](PairwiseBackend::kernel_tag) per metric.  Vector tags
//! live in a reserved namespace (`0x1000_0000` cosine, `0x2000_0000`
//! Euclidean) that can never collide with the DTW convention
//! (`0` full band, `1 + b` banded).

use super::{BoundFamily, PairwiseBackend};
use crate::corpus::Segment;

/// Lanes per blocked kernel call — same width as the DTW lane kernel
/// ([`super::blocked::LANES`]) so one vector register holds a chunk.
pub const LANES: usize = super::blocked::LANES;

/// Cache kernel tag for the cosine metric (both scalar and blocked:
/// bitwise-equal results may share a tag).
pub const COSINE_TAG: u32 = 0x1000_0000;

/// Cache kernel tag for the Euclidean metric.
pub const EUCLIDEAN_TAG: u32 = 0x2000_0000;

/// Which vector metric a [`VectorBackend`] computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VectorMetric {
    /// 1 − cos(x, y).  Zero-norm convention: two zero vectors are
    /// identical (distance 0); a zero vector against a non-zero one is
    /// maximally dissimilar (distance 1).
    Cosine,
    /// ‖x − y‖₂.
    Euclidean,
}

impl VectorMetric {
    pub fn name(&self) -> &'static str {
        match self {
            VectorMetric::Cosine => "cosine",
            VectorMetric::Euclidean => "euclidean",
        }
    }
}

/// Cosine/Euclidean distance backend over fixed-dimension vectors.
///
/// `blocked == false` is the scalar reference path; `blocked == true`
/// evaluates [`LANES`] pairs per inner loop with the lane layout of
/// `blocked.rs`, bitwise-pinned to the scalar path.  Both report the
/// kernel-implementation axis through
/// [`name`](PairwiseBackend::name) ("native"/"blocked") and the metric
/// axis through [`metric_name`](PairwiseBackend::metric_name).
pub struct VectorBackend {
    pub metric: VectorMetric,
    pub blocked: bool,
}

impl VectorBackend {
    /// Scalar reference variant.
    pub fn native(metric: VectorMetric) -> Self {
        VectorBackend { metric, blocked: false }
    }

    /// 8-lane blocked variant (bitwise-equal to [`Self::native`]).
    pub fn blocked(metric: VectorMetric) -> Self {
        VectorBackend { metric, blocked: true }
    }
}

/// Ascending-order squared-norm accumulation — the one reduction order
/// every kernel and the cascade's norm bound share, so norms computed
/// anywhere in the engine are bitwise interchangeable.
pub fn squared_norm(v: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for &x in v {
        acc += x * x;
    }
    acc
}

/// ‖v‖₂ with the shared accumulation order.
pub fn l2_norm(v: &[f32]) -> f32 {
    squared_norm(v).sqrt()
}

/// Shared cosine finalisation: both the scalar and blocked paths feed
/// their accumulators through this exact expression, so finalisation
/// can never diverge between variants.
#[inline]
fn finish_cosine(dot: f32, nx2: f32, ny2: f32) -> f32 {
    let nx = nx2.sqrt();
    let ny = ny2.sqrt();
    if nx == 0.0 && ny == 0.0 {
        0.0
    } else if nx == 0.0 || ny == 0.0 {
        1.0
    } else {
        1.0 - dot / (nx * ny)
    }
}

/// Scalar cosine distance: one ascending pass accumulating dot and both
/// squared norms in independent chains.
fn cosine_pair(x: &[f32], y: &[f32]) -> f32 {
    let mut dot = 0.0f32;
    let mut nx2 = 0.0f32;
    let mut ny2 = 0.0f32;
    for (&a, &b) in x.iter().zip(y) {
        dot += a * b;
        nx2 += a * a;
        ny2 += b * b;
    }
    finish_cosine(dot, nx2, ny2)
}

/// Scalar Euclidean distance: ascending squared-difference fold, one
/// final sqrt.
fn euclidean_pair(x: &[f32], y: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (&a, &b) in x.iter().zip(y) {
        let t = a - b;
        acc += t * t;
    }
    acc.sqrt()
}

/// Up to [`LANES`] Y vectors packed `[d][lane]`-interleaved (row `d` is
/// `data[d·LANES .. (d+1)·LANES]`), zero beyond the real lane count.
/// Unlike the DTW [`super::blocked`] grouping there is no length
/// sorting — every vector shares one flat length — so lanes keep the
/// caller's column order and outputs land in consecutive slots.
struct VecLanes {
    lanes: usize,
    data: Vec<f32>,
}

impl VecLanes {
    fn pack(ys: &[&Segment], flat: usize) -> VecLanes {
        debug_assert!(!ys.is_empty() && ys.len() <= LANES);
        let mut data = Vec::with_capacity(flat * LANES);
        for d in 0..flat {
            for y in ys {
                data.push(y.feats.get(d).copied().unwrap_or(0.0));
            }
            for _ in ys.len()..LANES {
                data.push(0.0);
            }
        }
        VecLanes { lanes: ys.len(), data }
    }
}

/// Cosine accumulators for one query against every lane: per lane the
/// dot and squared-norm chains accumulate over ascending `d`, exactly
/// the scalar [`cosine_pair`] order (padded lanes carry zeros and are
/// never read).
fn cosine_lanes(x: &[f32], g: &VecLanes) -> ([f32; LANES], [f32; LANES]) {
    let mut dot = [0.0f32; LANES];
    let mut ny2 = [0.0f32; LANES];
    for (&xv, row) in x.iter().zip(g.data.chunks_exact(LANES)) {
        for ((d, n2), &yv) in dot.iter_mut().zip(ny2.iter_mut()).zip(row) {
            *d += xv * yv;
            *n2 += yv * yv;
        }
    }
    (dot, ny2)
}

/// Euclidean accumulators for one query against every lane — the scalar
/// squared-difference fold widened by [`LANES`].
fn euclidean_lanes(x: &[f32], g: &VecLanes) -> [f32; LANES] {
    let mut acc2 = [0.0f32; LANES];
    for (&xv, row) in x.iter().zip(g.data.chunks_exact(LANES)) {
        for (acc, &yv) in acc2.iter_mut().zip(row) {
            let t = xv - yv;
            *acc += t * t;
        }
    }
    acc2
}

/// Every segment on both sides must carry the same non-empty flat
/// feature length — vector metrics have no alignment step to absorb a
/// mismatch.  Returns that shared length.
fn check_flat(xs: &[&Segment], ys: &[&Segment]) -> anyhow::Result<usize> {
    let flat = xs
        .iter()
        .chain(ys.iter())
        .next()
        .map(|s| s.feats.len())
        .unwrap_or(0);
    for s in xs.iter().chain(ys.iter()) {
        if s.feats.len() != flat || flat == 0 {
            anyhow::bail!(
                "vector metric requires equal fixed-dimension segments: \
                 segment {} has {} features, expected {} (non-zero)",
                s.id,
                s.feats.len(),
                flat
            );
        }
    }
    Ok(flat)
}

impl PairwiseBackend for VectorBackend {
    fn pairwise(&self, xs: &[&Segment], ys: &[&Segment]) -> anyhow::Result<Vec<f32>> {
        let ny = ys.len();
        let mut out = vec![0.0f32; xs.len() * ny];
        if xs.is_empty() || ny == 0 {
            return Ok(out);
        }
        let flat = check_flat(xs, ys)?;

        if !self.blocked {
            for (x, row) in xs.iter().zip(out.chunks_exact_mut(ny)) {
                for (y, o) in ys.iter().zip(row.iter_mut()) {
                    *o = match self.metric {
                        VectorMetric::Cosine => cosine_pair(&x.feats, &y.feats),
                        VectorMetric::Euclidean => euclidean_pair(&x.feats, &y.feats),
                    };
                }
            }
            return Ok(out);
        }

        // Blocked path: pack each LANES-wide column group once, reuse it
        // across every X row (amortisation mirrors `blocked.rs`).  The
        // groups keep the caller's column order, so each group's outputs
        // are exactly one `chunks_mut(LANES)` slot of the row.
        let groups: Vec<VecLanes> = ys.chunks(LANES).map(|c| VecLanes::pack(c, flat)).collect();
        for (x, row) in xs.iter().zip(out.chunks_exact_mut(ny)) {
            match self.metric {
                VectorMetric::Cosine => {
                    // The query's squared norm is one ascending chain —
                    // bitwise the same value the scalar path accumulates
                    // per pair — so it is hoisted out of the group loop.
                    let nx2 = squared_norm(&x.feats);
                    for (g, out_chunk) in groups.iter().zip(row.chunks_mut(LANES)) {
                        let (dot, ny2) = cosine_lanes(&x.feats, g);
                        debug_assert_eq!(g.lanes, out_chunk.len());
                        for ((o, &d), &n2) in
                            out_chunk.iter_mut().zip(dot.iter()).zip(ny2.iter())
                        {
                            *o = finish_cosine(d, nx2, n2);
                        }
                    }
                }
                VectorMetric::Euclidean => {
                    for (g, out_chunk) in groups.iter().zip(row.chunks_mut(LANES)) {
                        let acc2 = euclidean_lanes(&x.feats, g);
                        debug_assert_eq!(g.lanes, out_chunk.len());
                        for (o, &a2) in out_chunk.iter_mut().zip(acc2.iter()) {
                            *o = a2.sqrt();
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        // The `backend` telemetry axis stays the *implementation* name;
        // the metric travels through `metric_name`.
        if self.blocked {
            "blocked"
        } else {
            "native"
        }
    }

    fn metric_name(&self) -> &'static str {
        self.metric.name()
    }

    fn bound_family(&self) -> BoundFamily {
        match self.metric {
            // Reverse-triangle norm bound (see `lb.rs`).
            VectorMetric::Euclidean => BoundFamily::VectorNorm,
            // No admissible cosine bound is known here; config
            // validation rejects `--prune` for it.
            VectorMetric::Cosine => BoundFamily::None,
        }
    }

    fn kernel_tag(&self) -> u32 {
        match self.metric {
            VectorMetric::Cosine => COSINE_TAG,
            VectorMetric::Euclidean => EUCLIDEAN_TAG,
        }
    }

    fn preferred_rows(&self) -> usize {
        // Must match the DTW backends: equal builder block shapes keep
        // cache probe order invariant across every backend and metric.
        16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(id: usize, feats: Vec<f32>) -> Segment {
        let dim = feats.len();
        Segment { id, class_id: 0, len: 1, dim, feats }
    }

    fn corpus(n: usize, dim: usize, seed: u64) -> Vec<Segment> {
        let mut rng = crate::util::rng::Rng::seed_from(seed);
        (0..n)
            .map(|id| {
                let feats = (0..dim).map(|_| rng.normal() as f32).collect();
                seg(id, feats)
            })
            .collect()
    }

    fn assert_bitwise(a: &[f32], b: &[f32], ctx: &str) {
        assert_eq!(a.len(), b.len(), "{ctx}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: pair {i}: {x} vs {y}");
        }
    }

    #[test]
    fn blocked_bitwise_equals_native_for_both_metrics() {
        for metric in [VectorMetric::Cosine, VectorMetric::Euclidean] {
            for (n, dim, seed) in [(3usize, 1usize, 1u64), (9, 8, 2), (21, 37, 3)] {
                let segs = corpus(n, dim, seed);
                let refs: Vec<&Segment> = segs.iter().collect();
                let split = n / 2;
                let native = VectorBackend::native(metric)
                    .pairwise(&refs[..split], &refs[split..])
                    .unwrap();
                let blocked = VectorBackend::blocked(metric)
                    .pairwise(&refs[..split], &refs[split..])
                    .unwrap();
                assert_bitwise(&native, &blocked, &format!("{:?} n={n} dim={dim}", metric));
            }
        }
    }

    #[test]
    fn distances_are_symmetric_bitwise() {
        for metric in [VectorMetric::Cosine, VectorMetric::Euclidean] {
            let segs = corpus(8, 5, 7);
            let refs: Vec<&Segment> = segs.iter().collect();
            let b = VectorBackend::native(metric);
            let fwd = b.pairwise(&refs[..4], &refs[4..]).unwrap();
            let rev = b.pairwise(&refs[4..], &refs[..4]).unwrap();
            for (i, f) in fwd.iter().enumerate() {
                let (r, c) = (i / 4, i % 4);
                let g = rev.iter().nth(c * 4 + r).unwrap();
                assert_eq!(f.to_bits(), g.to_bits(), "pair ({r},{c})");
            }
        }
    }

    #[test]
    fn cosine_zero_norm_convention() {
        let z = seg(0, vec![0.0, 0.0]);
        let a = seg(1, vec![1.0, 0.0]);
        let z2 = seg(2, vec![0.0, 0.0]);
        let b = VectorBackend::native(VectorMetric::Cosine);
        let d = b.pairwise(&[&z], &[&z2, &a]).unwrap();
        assert_eq!(d, vec![0.0, 1.0]);
    }

    #[test]
    fn cosine_identical_vectors_are_near_zero_and_opposite_near_two() {
        let a = seg(0, vec![0.6, 0.8]);
        let na = seg(1, vec![-0.6, -0.8]);
        let b = VectorBackend::native(VectorMetric::Cosine);
        let d = b.pairwise(&[&a], &[&a, &na]).unwrap();
        assert!(d.first().unwrap().abs() < 1e-6, "self distance {}", d.first().unwrap());
        assert!((d.last().unwrap() - 2.0).abs() < 1e-6, "antipodal {}", d.last().unwrap());
    }

    #[test]
    fn euclidean_matches_reference_formula() {
        let a = seg(0, vec![1.0, 2.0, 2.0]);
        let b = seg(1, vec![1.0, 0.0, 0.0]);
        let d = VectorBackend::native(VectorMetric::Euclidean)
            .pairwise(&[&a], &[&b])
            .unwrap();
        assert!((d.first().unwrap() - 8.0f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn mismatched_flat_lengths_error() {
        let a = seg(0, vec![1.0, 2.0]);
        let b = seg(1, vec![1.0, 2.0, 3.0]);
        for metric in [VectorMetric::Cosine, VectorMetric::Euclidean] {
            let err = VectorBackend::native(metric).pairwise(&[&a], &[&b]);
            assert!(err.is_err(), "{metric:?} must reject mismatched dims");
        }
    }

    #[test]
    fn tags_and_axes_are_disjoint_from_dtw() {
        let cos = VectorBackend::blocked(VectorMetric::Cosine);
        let euc = VectorBackend::native(VectorMetric::Euclidean);
        assert_ne!(cos.kernel_tag(), euc.kernel_tag());
        // DTW tags are 0 (full) or 1 + band; the vector namespace starts
        // far above any plausible band radius.
        assert!(cos.kernel_tag() >= 0x1000_0000);
        assert_eq!(cos.name(), "blocked");
        assert_eq!(euc.name(), "native");
        assert_eq!(cos.metric_name(), "cosine");
        assert_eq!(euc.metric_name(), "euclidean");
        assert_eq!(cos.kernel_tag(), VectorBackend::native(VectorMetric::Cosine).kernel_tag());
        assert_eq!(euc.bound_family(), BoundFamily::VectorNorm);
        assert_eq!(cos.bound_family(), BoundFamily::None);
        assert_eq!(
            euc.preferred_rows(),
            super::super::NativeBackend::new().preferred_rows()
        );
    }
}
