//! The cascading lower-bound pruning layer: [`CascadeBackend`] wraps
//! any exact [`DtwBackend`] and answers threshold-carrying pair queries
//! (`pairwise_pruned`) through a cascade — cheap LB_Keogh-style
//! envelope bound first, exact DP only when the bound cannot decide.
//!
//! # Decision-parity contract
//!
//! A pruned entry carries the *lower bound itself* as its value, with
//! its flag cleared.  The bound is admissible in floating point
//! (`lb ≤ exact` bitwise, see [`crate::dtw::envelope`]), so
//! `lb > threshold` implies `exact > threshold`: any consumer that only
//! compares returned values against that same threshold — the stage-0
//! leader pass's ε-join rule, the streaming retirement argmin's
//! strict-`<` update — makes exactly the decisions the exact backend
//! would, and the clustering output is bitwise identical to the
//! `prune = off` oracle (pinned in `rust/tests/pruning.rs`).
//!
//! DTW is not a metric (no triangle inequality), but nothing here leans
//! on one: admissibility of the envelope bound against each individual
//! alignment total is all the cascade needs.
//!
//! Plain `pairwise` calls (condensed matrix builds, tree-mode probe
//! rectangles whose values feed orderings rather than threshold tests)
//! delegate to the inner backend untouched, and the wrapper reuses the
//! inner backend's cache kernel tag, so exact values cached by pruned
//! and unpruned runs interchange freely.  Lower bounds are never
//! cached.
//!
//! [`CascadeMode::Debug`] additionally computes the exact distance for
//! *every* pair of a pruned query and verifies `lb ≤ exact`, returning
//! the same values and flags as [`CascadeMode::On`] — an admissibility
//! tripwire for new backends or feature pipelines.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::DtwBackend;
use crate::corpus::{Segment, SegmentSet};
use crate::dtw::envelope::{lb_one_sided, Envelope};
use crate::telemetry::PruneStats;

/// How the cascade treats pruned pairs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CascadeMode {
    /// Prune: bound out pairs without running the DP.
    On,
    /// Prune, but also run the DP on every pair and verify `lb ≤ exact`
    /// (values and flags returned are identical to `On`).
    Debug,
}

/// The wrapped exact backend: borrowed for driver-scoped runs, shared
/// for streaming/serve sessions that must own their backend.
enum InnerRef<'a> {
    Borrowed(&'a dyn DtwBackend),
    Shared(Arc<dyn DtwBackend + Send + Sync>),
}

impl InnerRef<'_> {
    fn get(&self) -> &dyn DtwBackend {
        match self {
            InnerRef::Borrowed(b) => *b,
            InnerRef::Shared(s) => s.as_ref(),
        }
    }
}

/// Lower-bound cascade over an exact backend, with per-segment
/// envelopes precomputed once for the whole corpus at construction.
pub struct CascadeBackend<'a> {
    inner: InnerRef<'a>,
    /// Envelope per global segment id.
    envelopes: Vec<Envelope>,
    dim: usize,
    mode: CascadeMode,
    lb_pairs: AtomicU64,
    lb_pruned: AtomicU64,
    exact_pairs: AtomicU64,
}

impl<'a> CascadeBackend<'a> {
    /// Wrap a borrowed backend (driver episodes).
    pub fn borrowed(inner: &'a dyn DtwBackend, set: &SegmentSet, mode: CascadeMode) -> Self {
        Self::build(InnerRef::Borrowed(inner), set, mode)
    }

    /// Wrap a shared backend (streaming sessions and serve fleets,
    /// which need the wrapper to be `Send`).
    pub fn shared(
        inner: Arc<dyn DtwBackend + Send + Sync>,
        set: &SegmentSet,
        mode: CascadeMode,
    ) -> CascadeBackend<'static> {
        CascadeBackend::build(InnerRef::Shared(inner), set, mode)
    }

    fn build(inner: InnerRef<'_>, set: &SegmentSet, mode: CascadeMode) -> CascadeBackend<'_> {
        let mut envelopes = vec![Envelope::of_frames(&[], set.dim); set.len()];
        for seg in &set.segments {
            if let Some(slot) = envelopes.get_mut(seg.id) {
                *slot = Envelope::of_frames(&seg.feats, seg.dim);
            }
        }
        CascadeBackend {
            inner,
            envelopes,
            dim: set.dim,
            mode,
            lb_pairs: AtomicU64::new(0),
            lb_pruned: AtomicU64::new(0),
            exact_pairs: AtomicU64::new(0),
        }
    }

    fn envelope_of(&self, seg: &Segment) -> anyhow::Result<&Envelope> {
        self.envelopes.get(seg.id).ok_or_else(|| {
            anyhow::anyhow!(
                "segment id {} outside the cascade's envelope table ({} segments)",
                seg.id,
                self.envelopes.len()
            )
        })
    }

    /// Normalised symmetric envelope bound for one pair: the larger of
    /// the two one-sided sums over the shared `(lx + ly)` denominator,
    /// never above the exact normalised DTW distance (bitwise).
    pub fn lb_pair(&self, x: &Segment, y: &Segment) -> anyhow::Result<f32> {
        anyhow::ensure!(
            x.dim == self.dim && y.dim == self.dim,
            "segment dim {}/{} does not match the cascade's corpus dim {}",
            x.dim,
            y.dim,
            self.dim
        );
        let env_y = self.envelope_of(y)?;
        let env_x = self.envelope_of(x)?;
        let fwd = lb_one_sided(&x.feats, self.dim, env_y);
        let bwd = lb_one_sided(&y.feats, self.dim, env_x);
        Ok(fwd.max(bwd) / (x.len + y.len) as f32)
    }

    /// Counter snapshot (cumulative since construction); the drivers
    /// delta consecutive snapshots into per-iteration telemetry.
    pub fn stats(&self) -> PruneStats {
        PruneStats {
            lb_pairs: self.lb_pairs.load(Ordering::Relaxed),
            lb_pruned: self.lb_pruned.load(Ordering::Relaxed),
            exact_pairs: self.exact_pairs.load(Ordering::Relaxed),
        }
    }
}

impl DtwBackend for CascadeBackend<'_> {
    /// Threshold-free queries are exact: the cascade only engages where
    /// a caller can state what "too far" means.
    fn pairwise(&self, xs: &[&Segment], ys: &[&Segment]) -> anyhow::Result<Vec<f32>> {
        self.exact_pairs
            .fetch_add((xs.len() * ys.len()) as u64, Ordering::Relaxed);
        self.inner.get().pairwise(xs, ys)
    }

    fn pairwise_pruned(
        &self,
        xs: &[&Segment],
        ys: &[&Segment],
        threshold: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<bool>)> {
        let ny = ys.len();
        let mut vals: Vec<f32> = Vec::with_capacity(xs.len() * ny);
        let mut flags: Vec<bool> = Vec::with_capacity(xs.len() * ny);
        for x in xs {
            let mut lbs: Vec<f32> = Vec::with_capacity(ny);
            for y in ys {
                lbs.push(self.lb_pair(x, y)?);
            }
            let survive: Vec<usize> = lbs
                .iter()
                .enumerate()
                .filter(|&(_, &lb)| lb <= threshold)
                .map(|(j, _)| j)
                .collect();
            let mut row_vals = lbs.clone();
            let mut row_flags = vec![false; ny];
            if !survive.is_empty() {
                let sub: Vec<&Segment> = survive.iter().filter_map(|&j| ys.get(j).copied()).collect();
                let d = self.inner.get().pairwise(&[*x], &sub)?;
                anyhow::ensure!(
                    d.len() == sub.len(),
                    "inner backend returned {} distances for {} surviving pairs",
                    d.len(),
                    sub.len()
                );
                for (&j, &v) in survive.iter().zip(&d) {
                    if let Some(slot) = row_vals.get_mut(j) {
                        *slot = v;
                    }
                    if let Some(flag) = row_flags.get_mut(j) {
                        *flag = true;
                    }
                }
            }
            if self.mode == CascadeMode::Debug {
                // Admissibility tripwire: every pair's bound must sit at
                // or below its exact distance, pruned or not.
                let exact = self.inner.get().pairwise(&[*x], ys)?;
                for ((&lb, &ex), y) in lbs.iter().zip(&exact).zip(ys) {
                    anyhow::ensure!(
                        lb <= ex,
                        "inadmissible bound: lb {} > exact {} for pair ({}, {})",
                        lb,
                        ex,
                        x.id,
                        y.id
                    );
                }
            }
            self.lb_pairs.fetch_add(ny as u64, Ordering::Relaxed);
            self.lb_pruned
                .fetch_add((ny - survive.len()) as u64, Ordering::Relaxed);
            self.exact_pairs
                .fetch_add(survive.len() as u64, Ordering::Relaxed);
            vals.extend_from_slice(&row_vals);
            flags.extend_from_slice(&row_flags);
        }
        Ok((vals, flags))
    }

    fn supports_pruning(&self) -> bool {
        true
    }

    fn prune_stats(&self) -> Option<PruneStats> {
        Some(self.stats())
    }

    fn name(&self) -> &'static str {
        match self.inner.get().name() {
            "native" => "native+lb",
            "blocked" => "blocked+lb",
            _ => "cascade+lb",
        }
    }

    /// Exact values cached by pruned and unpruned runs interchange:
    /// the cascade computes with the inner kernel and never caches
    /// lower bounds.
    fn kernel_tag(&self) -> u32 {
        self.inner.get().kernel_tag()
    }

    fn preferred_rows(&self) -> usize {
        self.inner.get().preferred_rows()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetSpec;
    use crate::corpus::generate;
    use crate::distance::NativeBackend;

    fn refs(set: &SegmentSet) -> Vec<&Segment> {
        set.segments.iter().collect()
    }

    #[test]
    fn plain_pairwise_is_exact_and_counts() {
        let set = generate(&DatasetSpec::tiny(12, 3, 41));
        let inner = NativeBackend::new();
        let cascade = CascadeBackend::borrowed(&inner, &set, CascadeMode::On);
        let rs = refs(&set);
        let want = inner.pairwise(&rs[..4], &rs[4..9]).unwrap();
        let got = cascade.pairwise(&rs[..4], &rs[4..9]).unwrap();
        assert_eq!(got, want);
        assert_eq!(cascade.stats().exact_pairs, 20);
        assert_eq!(cascade.stats().lb_pairs, 0);
    }

    #[test]
    fn pruned_query_survivors_are_exact_and_prunes_carry_the_bound() {
        let set = generate(&DatasetSpec::tiny(20, 3, 42));
        let inner = NativeBackend::new();
        let cascade = CascadeBackend::borrowed(&inner, &set, CascadeMode::On);
        let rs = refs(&set);
        let exact = inner.pairwise(&rs[..6], &rs[6..]).unwrap();
        // A mid-range threshold so both branches of the cascade fire.
        let mut sorted = exact.clone();
        sorted.sort_unstable_by(f32::total_cmp);
        let threshold = sorted[sorted.len() / 2];
        let (vals, flags) = cascade.pairwise_pruned(&rs[..6], &rs[6..], threshold).unwrap();
        assert_eq!(vals.len(), exact.len());
        let mut pruned = 0usize;
        for ((&v, &f), &ex) in vals.iter().zip(&flags).zip(&exact) {
            if f {
                assert_eq!(v.to_bits(), ex.to_bits(), "survivors are exact");
            } else {
                pruned += 1;
                assert!(v > threshold, "pruned value must exceed the threshold");
                assert!(v <= ex, "pruned value is an admissible bound");
            }
        }
        let s = cascade.stats();
        assert_eq!(s.lb_pairs as usize, exact.len());
        assert_eq!(s.lb_pruned as usize, pruned);
        assert_eq!(s.exact_pairs as usize, exact.len() - pruned);
    }

    #[test]
    fn debug_mode_returns_on_mode_results() {
        let set = generate(&DatasetSpec::tiny(16, 2, 43));
        let inner = NativeBackend::new();
        let on = CascadeBackend::borrowed(&inner, &set, CascadeMode::On);
        let dbg = CascadeBackend::borrowed(&inner, &set, CascadeMode::Debug);
        let rs = refs(&set);
        let (v1, f1) = on.pairwise_pruned(&rs[..5], &rs[5..], 0.4).unwrap();
        let (v2, f2) = dbg.pairwise_pruned(&rs[..5], &rs[5..], 0.4).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&v1), bits(&v2));
        assert_eq!(f1, f2);
    }

    #[test]
    fn threshold_below_every_bound_prunes_everything() {
        // Negative threshold: every finite bound exceeds it except pairs
        // whose bound is exactly 0 (which survive and compute).
        let set = generate(&DatasetSpec::tiny(10, 2, 44));
        let inner = NativeBackend::new();
        let cascade = CascadeBackend::borrowed(&inner, &set, CascadeMode::On);
        let rs = refs(&set);
        let (_, flags) = cascade.pairwise_pruned(&rs[..3], &rs[3..], -1.0).unwrap();
        assert!(flags.iter().all(|&f| !f), "nothing survives a negative threshold");
        assert_eq!(cascade.stats().exact_pairs, 0);
    }
}
