//! The cascading lower-bound pruning layer: [`CascadeBackend`] wraps
//! any exact [`PairwiseBackend`] and answers threshold-carrying pair queries
//! (`pairwise_pruned`) through a cascade — a cheap per-pair lower
//! bound first, the exact kernel only when the bound cannot decide.
//!
//! The bound itself is metric-specific, selected by the inner
//! backend's [`super::BoundFamily`]: DTW kernels get the LB_Keogh-style
//! envelope bound, Euclidean vector backends get the reverse-triangle
//! norm bound |‖x‖−‖y‖| (with an absolute rounding slack subtracted so
//! the computed bound stays admissible against the computed distance),
//! and backends that advertise no bound (cosine) degrade to the exact
//! path: `supports_pruning` reports `false` and every threshold-aware
//! call site stays on the historical exact code, bit for bit.
//!
//! # Decision-parity contract
//!
//! A pruned entry carries the *lower bound itself* as its value, with
//! its flag cleared.  The bound is admissible in floating point
//! (`lb ≤ exact` bitwise, see [`crate::dtw::envelope`]), so
//! `lb > threshold` implies `exact > threshold`: any consumer that only
//! compares returned values against that same threshold — the stage-0
//! leader pass's ε-join rule, the streaming retirement argmin's
//! strict-`<` update — makes exactly the decisions the exact backend
//! would, and the clustering output is bitwise identical to the
//! `prune = off` oracle (pinned in `rust/tests/pruning.rs`).
//!
//! DTW is not a metric (no triangle inequality), but nothing here leans
//! on one: admissibility of the envelope bound against each individual
//! alignment total is all the cascade needs.
//!
//! Plain `pairwise` calls (condensed matrix builds, tree-mode probe
//! rectangles whose values feed orderings rather than threshold tests)
//! delegate to the inner backend untouched, and the wrapper reuses the
//! inner backend's cache kernel tag, so exact values cached by pruned
//! and unpruned runs interchange freely.  Lower bounds are never
//! cached.
//!
//! [`CascadeMode::Debug`] additionally computes the exact distance for
//! *every* pair of a pruned query and verifies `lb ≤ exact`, returning
//! the same values and flags as [`CascadeMode::On`] — an admissibility
//! tripwire for new backends or feature pipelines.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::vector::l2_norm;
use super::{BoundFamily, PairwiseBackend};
use crate::corpus::{Segment, SegmentSet};
use crate::dtw::envelope::{lb_one_sided, Envelope};
use crate::telemetry::PruneStats;

/// How the cascade treats pruned pairs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CascadeMode {
    /// Prune: bound out pairs without running the DP.
    On,
    /// Prune, but also run the DP on every pair and verify `lb ≤ exact`
    /// (values and flags returned are identical to `On`).
    Debug,
}

/// The wrapped exact backend: borrowed for driver-scoped runs, shared
/// for streaming/serve sessions that must own their backend.
enum InnerRef<'a> {
    Borrowed(&'a dyn PairwiseBackend),
    Shared(Arc<dyn PairwiseBackend + Send + Sync>),
}

impl InnerRef<'_> {
    fn get(&self) -> &dyn PairwiseBackend {
        match self {
            InnerRef::Borrowed(b) => *b,
            InnerRef::Shared(s) => s.as_ref(),
        }
    }
}

/// Precomputed per-segment bound tables, one variant per
/// [`BoundFamily`] (all indexed by global segment id).
enum Bounds {
    /// LB_Keogh-style min/max envelopes over DTW frames.
    Envelopes { envelopes: Vec<Envelope>, dim: usize },
    /// Euclidean vector norms plus per-segment rounding slack: the
    /// real-arithmetic bound ‖x−y‖ ≥ |‖x‖−‖y‖| can be violated by an
    /// ulp in f32 when x ≈ y, so each segment carries an absolute
    /// slack of `‖s‖ · flat_len · ε · 2` that is subtracted from the
    /// norm difference (clamped at zero) before it is used as a bound.
    Norms { norms: Vec<f32>, slacks: Vec<f32> },
    /// The inner backend advertises no admissible bound (cosine): the
    /// cascade degrades to the exact path.
    Unbounded,
}

/// Lower-bound cascade over an exact backend, with per-segment bound
/// tables (envelopes or norms, per the inner backend's
/// [`BoundFamily`]) precomputed once for the whole corpus at
/// construction.
pub struct CascadeBackend<'a> {
    inner: InnerRef<'a>,
    bounds: Bounds,
    mode: CascadeMode,
    lb_pairs: AtomicU64,
    lb_pruned: AtomicU64,
    exact_pairs: AtomicU64,
}

impl<'a> CascadeBackend<'a> {
    /// Wrap a borrowed backend (driver episodes).
    pub fn borrowed(inner: &'a dyn PairwiseBackend, set: &SegmentSet, mode: CascadeMode) -> Self {
        Self::build(InnerRef::Borrowed(inner), set, mode)
    }

    /// Wrap a shared backend (streaming sessions and serve fleets,
    /// which need the wrapper to be `Send`).
    pub fn shared(
        inner: Arc<dyn PairwiseBackend + Send + Sync>,
        set: &SegmentSet,
        mode: CascadeMode,
    ) -> CascadeBackend<'static> {
        CascadeBackend::build(InnerRef::Shared(inner), set, mode)
    }

    fn build(inner: InnerRef<'_>, set: &SegmentSet, mode: CascadeMode) -> CascadeBackend<'_> {
        let bounds = match inner.get().bound_family() {
            BoundFamily::DtwEnvelope => {
                let mut envelopes = vec![Envelope::of_frames(&[], set.dim); set.len()];
                for seg in &set.segments {
                    if let Some(slot) = envelopes.get_mut(seg.id) {
                        *slot = Envelope::of_frames(&seg.feats, seg.dim);
                    }
                }
                Bounds::Envelopes { envelopes, dim: set.dim }
            }
            BoundFamily::VectorNorm => {
                let mut norms = vec![0.0f32; set.len()];
                let mut slacks = vec![0.0f32; set.len()];
                for seg in &set.segments {
                    let n = l2_norm(&seg.feats);
                    if let Some(slot) = norms.get_mut(seg.id) {
                        *slot = n;
                    }
                    if let Some(slot) = slacks.get_mut(seg.id) {
                        *slot = n * seg.feats.len() as f32 * f32::EPSILON * 2.0;
                    }
                }
                Bounds::Norms { norms, slacks }
            }
            BoundFamily::None => Bounds::Unbounded,
        };
        CascadeBackend {
            inner,
            bounds,
            mode,
            lb_pairs: AtomicU64::new(0),
            lb_pruned: AtomicU64::new(0),
            exact_pairs: AtomicU64::new(0),
        }
    }

    fn table_entry<'t, T>(table: &'t [T], seg: &Segment) -> anyhow::Result<&'t T> {
        table.get(seg.id).ok_or_else(|| {
            anyhow::anyhow!(
                "segment id {} outside the cascade's bound table ({} segments)",
                seg.id,
                table.len()
            )
        })
    }

    /// Admissible lower bound for one pair, per the active
    /// [`BoundFamily`].
    ///
    /// * Envelopes: the larger of the two one-sided LB_Keogh sums over
    ///   the shared `(lx + ly)` denominator, never above the exact
    ///   normalised DTW distance (bitwise).
    /// * Norms: `max(0, |‖x‖−‖y‖| − slack_x − slack_y)` — the
    ///   reverse-triangle bound with the rounding slack of
    ///   [`Bounds::Norms`], fuzz-pinned against the exact kernel in
    ///   `rust/tests/metric_parity.rs`.
    /// * Unbounded: 0, trivially admissible for a non-negative
    ///   distance (the cascade reports `supports_pruning() == false`,
    ///   so threshold-aware call sites never reach this).
    pub fn lb_pair(&self, x: &Segment, y: &Segment) -> anyhow::Result<f32> {
        match &self.bounds {
            Bounds::Envelopes { envelopes, dim } => {
                anyhow::ensure!(
                    x.dim == *dim && y.dim == *dim,
                    "segment dim {}/{} does not match the cascade's corpus dim {}",
                    x.dim,
                    y.dim,
                    dim
                );
                let env_y = Self::table_entry(envelopes, y)?;
                let env_x = Self::table_entry(envelopes, x)?;
                let fwd = lb_one_sided(&x.feats, *dim, env_y);
                let bwd = lb_one_sided(&y.feats, *dim, env_x);
                Ok(fwd.max(bwd) / (x.len + y.len) as f32)
            }
            Bounds::Norms { norms, slacks } => {
                let nx = *Self::table_entry(norms, x)?;
                let ny = *Self::table_entry(norms, y)?;
                let slack = *Self::table_entry(slacks, x)? + *Self::table_entry(slacks, y)?;
                Ok(((nx - ny).abs() - slack).max(0.0))
            }
            Bounds::Unbounded => Ok(0.0),
        }
    }

    /// Counter snapshot (cumulative since construction); the drivers
    /// delta consecutive snapshots into per-iteration telemetry.
    pub fn stats(&self) -> PruneStats {
        PruneStats {
            lb_pairs: self.lb_pairs.load(Ordering::Relaxed),
            lb_pruned: self.lb_pruned.load(Ordering::Relaxed),
            exact_pairs: self.exact_pairs.load(Ordering::Relaxed),
        }
    }
}

impl PairwiseBackend for CascadeBackend<'_> {
    /// Threshold-free queries are exact: the cascade only engages where
    /// a caller can state what "too far" means.
    fn pairwise(&self, xs: &[&Segment], ys: &[&Segment]) -> anyhow::Result<Vec<f32>> {
        self.exact_pairs
            .fetch_add((xs.len() * ys.len()) as u64, Ordering::Relaxed);
        self.inner.get().pairwise(xs, ys)
    }

    fn pairwise_pruned(
        &self,
        xs: &[&Segment],
        ys: &[&Segment],
        threshold: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<bool>)> {
        let ny = ys.len();
        let mut vals: Vec<f32> = Vec::with_capacity(xs.len() * ny);
        let mut flags: Vec<bool> = Vec::with_capacity(xs.len() * ny);
        for x in xs {
            let mut lbs: Vec<f32> = Vec::with_capacity(ny);
            for y in ys {
                lbs.push(self.lb_pair(x, y)?);
            }
            let survive: Vec<usize> = lbs
                .iter()
                .enumerate()
                .filter(|&(_, &lb)| lb <= threshold)
                .map(|(j, _)| j)
                .collect();
            let mut row_vals = lbs.clone();
            let mut row_flags = vec![false; ny];
            if !survive.is_empty() {
                let sub: Vec<&Segment> = survive.iter().filter_map(|&j| ys.get(j).copied()).collect();
                let d = self.inner.get().pairwise(&[*x], &sub)?;
                anyhow::ensure!(
                    d.len() == sub.len(),
                    "inner backend returned {} distances for {} surviving pairs",
                    d.len(),
                    sub.len()
                );
                for (&j, &v) in survive.iter().zip(&d) {
                    if let Some(slot) = row_vals.get_mut(j) {
                        *slot = v;
                    }
                    if let Some(flag) = row_flags.get_mut(j) {
                        *flag = true;
                    }
                }
            }
            if self.mode == CascadeMode::Debug {
                // Admissibility tripwire: every pair's bound must sit at
                // or below its exact distance, pruned or not.
                let exact = self.inner.get().pairwise(&[*x], ys)?;
                for ((&lb, &ex), y) in lbs.iter().zip(&exact).zip(ys) {
                    anyhow::ensure!(
                        lb <= ex,
                        "inadmissible bound: lb {} > exact {} for pair ({}, {})",
                        lb,
                        ex,
                        x.id,
                        y.id
                    );
                }
            }
            self.lb_pairs.fetch_add(ny as u64, Ordering::Relaxed);
            self.lb_pruned
                .fetch_add((ny - survive.len()) as u64, Ordering::Relaxed);
            self.exact_pairs
                .fetch_add(survive.len() as u64, Ordering::Relaxed);
            vals.extend_from_slice(&row_vals);
            flags.extend_from_slice(&row_flags);
        }
        Ok((vals, flags))
    }

    fn supports_pruning(&self) -> bool {
        // Without an admissible bound the cascade is a pass-through:
        // reporting `false` keeps every threshold-aware call site on
        // the exact code path, bit for bit.
        !matches!(self.bounds, Bounds::Unbounded)
    }

    fn prune_stats(&self) -> Option<PruneStats> {
        Some(self.stats())
    }

    fn name(&self) -> &'static str {
        match self.inner.get().name() {
            "native" => "native+lb",
            "blocked" => "blocked+lb",
            _ => "cascade+lb",
        }
    }

    fn metric_name(&self) -> &'static str {
        self.inner.get().metric_name()
    }

    fn bound_family(&self) -> BoundFamily {
        self.inner.get().bound_family()
    }

    /// Exact values cached by pruned and unpruned runs interchange:
    /// the cascade computes with the inner kernel and never caches
    /// lower bounds.
    fn kernel_tag(&self) -> u32 {
        self.inner.get().kernel_tag()
    }

    fn preferred_rows(&self) -> usize {
        self.inner.get().preferred_rows()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetSpec;
    use crate::corpus::generate;
    use crate::distance::NativeBackend;

    fn refs(set: &SegmentSet) -> Vec<&Segment> {
        set.segments.iter().collect()
    }

    #[test]
    fn plain_pairwise_is_exact_and_counts() {
        let set = generate(&DatasetSpec::tiny(12, 3, 41));
        let inner = NativeBackend::new();
        let cascade = CascadeBackend::borrowed(&inner, &set, CascadeMode::On);
        let rs = refs(&set);
        let want = inner.pairwise(&rs[..4], &rs[4..9]).unwrap();
        let got = cascade.pairwise(&rs[..4], &rs[4..9]).unwrap();
        assert_eq!(got, want);
        assert_eq!(cascade.stats().exact_pairs, 20);
        assert_eq!(cascade.stats().lb_pairs, 0);
    }

    #[test]
    fn pruned_query_survivors_are_exact_and_prunes_carry_the_bound() {
        let set = generate(&DatasetSpec::tiny(20, 3, 42));
        let inner = NativeBackend::new();
        let cascade = CascadeBackend::borrowed(&inner, &set, CascadeMode::On);
        let rs = refs(&set);
        let exact = inner.pairwise(&rs[..6], &rs[6..]).unwrap();
        // A mid-range threshold so both branches of the cascade fire.
        let mut sorted = exact.clone();
        sorted.sort_unstable_by(f32::total_cmp);
        let threshold = sorted[sorted.len() / 2];
        let (vals, flags) = cascade.pairwise_pruned(&rs[..6], &rs[6..], threshold).unwrap();
        assert_eq!(vals.len(), exact.len());
        let mut pruned = 0usize;
        for ((&v, &f), &ex) in vals.iter().zip(&flags).zip(&exact) {
            if f {
                assert_eq!(v.to_bits(), ex.to_bits(), "survivors are exact");
            } else {
                pruned += 1;
                assert!(v > threshold, "pruned value must exceed the threshold");
                assert!(v <= ex, "pruned value is an admissible bound");
            }
        }
        let s = cascade.stats();
        assert_eq!(s.lb_pairs as usize, exact.len());
        assert_eq!(s.lb_pruned as usize, pruned);
        assert_eq!(s.exact_pairs as usize, exact.len() - pruned);
    }

    #[test]
    fn debug_mode_returns_on_mode_results() {
        let set = generate(&DatasetSpec::tiny(16, 2, 43));
        let inner = NativeBackend::new();
        let on = CascadeBackend::borrowed(&inner, &set, CascadeMode::On);
        let dbg = CascadeBackend::borrowed(&inner, &set, CascadeMode::Debug);
        let rs = refs(&set);
        let (v1, f1) = on.pairwise_pruned(&rs[..5], &rs[5..], 0.4).unwrap();
        let (v2, f2) = dbg.pairwise_pruned(&rs[..5], &rs[5..], 0.4).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&v1), bits(&v2));
        assert_eq!(f1, f2);
    }

    #[test]
    fn threshold_below_every_bound_prunes_everything() {
        // Negative threshold: every finite bound exceeds it except pairs
        // whose bound is exactly 0 (which survive and compute).
        let set = generate(&DatasetSpec::tiny(10, 2, 44));
        let inner = NativeBackend::new();
        let cascade = CascadeBackend::borrowed(&inner, &set, CascadeMode::On);
        let rs = refs(&set);
        let (_, flags) = cascade.pairwise_pruned(&rs[..3], &rs[3..], -1.0).unwrap();
        assert!(flags.iter().all(|&f| !f), "nothing survives a negative threshold");
        assert_eq!(cascade.stats().exact_pairs, 0);
    }
}
