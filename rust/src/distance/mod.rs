//! Distance-matrix construction: condensed storage + pluggable
//! pairwise backends + the parallel builder.
//!
//! The MAHC space constraint the paper is about lives here: a subset of
//! n segments needs an n(n−1)/2-entry condensed matrix ([`Condensed`]),
//! so β (the subset occupancy threshold) directly bounds peak memory.
//! [`build_condensed`] fills one by tiling pair blocks over a
//! [`PairwiseBackend`].  The *metric* is a pluggable axis: DTW over
//! variable-length segments — the native scalar Rust DP
//! ([`NativeBackend`]), the lane-parallel multi-pair kernel
//! ([`BlockedBackend`], bitwise-equal results, see `blocked`), or the
//! AOT XLA executable (`runtime::XlaDtwBackend`) — sits beside
//! cosine/Euclidean over fixed-dimension embedding vectors
//! ([`VectorBackend`], see `vector`) behind the same trait, so every
//! consumer (cached builders, the pruning cascade, stage-0 probing,
//! linkage, both drivers, serve mode) is metric-generic.

pub mod blocked;
pub mod cache;
pub mod condensed;
pub mod lb;
pub mod vector;

pub use blocked::BlockedBackend;
pub use cache::{IdNamespaceError, PairCache};
pub use condensed::Condensed;
pub use lb::{CascadeBackend, CascadeMode};
pub use vector::{VectorBackend, VectorMetric};

use crate::corpus::Segment;
use crate::telemetry::PruneStats;
use crate::util::pool::parallel_map;

/// Strict left-to-right f32 accumulation — the fixed-order reduction
/// kernel lint rule R003 requires for float sums in `distance/` and
/// `ahc/`.  The explicit loop pins the association order, so the result
/// is bitwise-identical across backends, thread counts, and batch
/// shapes (`Iterator::sum` happens to do the same today, but nothing in
/// its contract promises it; this kernel does).
pub fn fixed_order_sum(xs: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for &x in xs {
        acc += x;
    }
    acc
}

/// Which DTW implementation computes pair distances.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust rolling-row DP (reference; fully deterministic).
    Native,
    /// Lane-parallel multi-pair DP ([`BlockedBackend`]): vectorises
    /// across pairs, bitwise-equal to `Native` (full band; banded via
    /// the shared scalar kernel).
    Blocked,
    /// AOT-compiled Pallas kernel through PJRT (`artifacts/dtw_*.hlo.txt`).
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            // "scalar" is the conventional alias the conformance/CI
            // matrix uses for the reference backend.
            "native" | "scalar" => Ok(BackendKind::Native),
            "blocked" => Ok(BackendKind::Blocked),
            "xla" => Ok(BackendKind::Xla),
            other => anyhow::bail!("unknown backend '{other}' (native|blocked|xla)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Blocked => "blocked",
            BackendKind::Xla => "xla",
        }
    }
}

/// Which distance metric a backend computes over segment pairs.
///
/// Orthogonal to [`BackendKind`] (the kernel *implementation*:
/// native/blocked/xla): `--backend blocked --metric cosine` selects the
/// 8-lane cosine kernel, `--backend native --metric dtw` the scalar DP.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    /// Dynamic time warping over variable-length frame sequences (the
    /// historical metric; path-normalized as in the paper).
    Dtw,
    /// Cosine distance (1 − cosine similarity) over fixed-dimension
    /// vectors — the diarization-embedding workload.
    Cosine,
    /// Euclidean (L2) distance over fixed-dimension vectors.
    Euclidean,
}

impl MetricKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "dtw" => Ok(MetricKind::Dtw),
            "cosine" => Ok(MetricKind::Cosine),
            "euclidean" | "l2" => Ok(MetricKind::Euclidean),
            other => anyhow::bail!("unknown metric '{other}' (dtw|cosine|euclidean)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            MetricKind::Dtw => "dtw",
            MetricKind::Cosine => "cosine",
            MetricKind::Euclidean => "euclidean",
        }
    }

    /// Whether an admissible lower bound exists for the pruning
    /// cascade: DTW has the LB_Keogh-style envelope bound, Euclidean
    /// the reverse-triangle norm bound; cosine has none, so `--prune`
    /// is rejected at config validation (see
    /// `config::MetricConfigError`).
    pub fn has_lower_bound(&self) -> bool {
        !matches!(self, MetricKind::Cosine)
    }
}

/// Which family of admissible lower bounds [`lb::CascadeBackend`] can
/// precompute for a backend, advertised via
/// [`PairwiseBackend::bound_family`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoundFamily {
    /// LB_Keogh-style per-segment min/max envelopes over DTW frames
    /// (the trait default: every pre-existing backend is a DTW
    /// kernel).
    DtwEnvelope,
    /// Reverse-triangle-inequality bound from per-segment vector norms
    /// (Euclidean over fixed-dimension vectors): ‖x−y‖ ≥ |‖x‖−‖y‖|,
    /// with an absolute rounding-slack subtracted so the *computed*
    /// bound stays admissible against the *computed* distance.
    VectorNorm,
    /// No admissible bound is known (cosine).  The cascade refuses to
    /// wrap such a backend; config validation rejects `--prune` for it
    /// with a typed error.
    None,
}

/// A pairwise-distance engine — the metric-generic trait every
/// consumer (condensed/cross builders, [`PairCache`], the pruning
/// cascade, stage-0 leader probing, NN-chain linkage, both drivers,
/// serve mode) operates through.  The DTW backends are one
/// instantiation; [`VectorBackend`] adds cosine/Euclidean over
/// fixed-dimension vectors.
///
/// # Contract
///
/// * **Bitwise determinism.**  For a given segment pair, `pairwise`
///   must return the same f32 bit pattern on every call, regardless of
///   batch shape, row grouping, thread count, or which other pairs
///   share the call.  The whole pin suite (backend parity, cache
///   determinism, streaming-vs-batch) rests on this: results are
///   cached by segment-id pair and replayed across iterations.
/// * **Symmetry.**  `d(x, y)` must equal `d(y, x)` bit for bit — the
///   shared [`PairCache`] stores one value per unordered id pair.
/// * **`pairwise_pruned` admissibility.**  When a pair is bounded out
///   (flag `false`), the reported value must be a true lower bound on
///   the exact distance *and* strictly above the carried threshold, so
///   every threshold comparison decides identically to the exact path.
/// * **Kernel-tag discipline.**  Two backends may share a
///   [`kernel_tag`](PairwiseBackend::kernel_tag) only if they are
///   bitwise-interchangeable for every pair (e.g. scalar and blocked
///   variants of the same metric).  Any change that can flip a single
///   bit — a different band radius, a different metric — must change
///   the tag, or the cache would alias stale values across kernels.
///
/// Implementations must be `Sync`: the builder calls them from worker
/// threads.
pub trait PairwiseBackend: Sync {
    /// Distances between all (x, y) segment pairs: returns a
    /// row-major `xs.len() × ys.len()` buffer.
    fn pairwise(&self, xs: &[&Segment], ys: &[&Segment]) -> anyhow::Result<Vec<f32>>;

    /// Human-readable kernel name for telemetry ("native", "blocked",
    /// "xla", "native+lb", …).  Identifies the *implementation*, not
    /// the metric — see
    /// [`metric_name`](PairwiseBackend::metric_name).
    fn name(&self) -> &'static str;

    /// Name of the metric family this backend computes ("dtw",
    /// "cosine", "euclidean") — carried into the `metric` telemetry
    /// field.  Defaults to "dtw": every pre-existing backend is a DTW
    /// kernel.
    fn metric_name(&self) -> &'static str {
        "dtw"
    }

    /// Which lower-bound family the pruning cascade should precompute
    /// when wrapping this backend.  Defaults to
    /// [`BoundFamily::DtwEnvelope`] (the historical behaviour for
    /// every DTW kernel); vector metrics override with
    /// [`BoundFamily::VectorNorm`] (Euclidean) or [`BoundFamily::None`]
    /// (cosine).
    fn bound_family(&self) -> BoundFamily {
        BoundFamily::DtwEnvelope
    }

    /// Threshold-carrying pair query for consumers that only compare
    /// distances against `threshold`: returns the row-major value
    /// buffer plus a parallel flag per pair — `true` means the value is
    /// the exact distance, `false` means the pair was bounded out and
    /// the value is an admissible lower bound (strictly above
    /// `threshold`, so threshold comparisons decide identically).  The
    /// default computes everything exactly; only
    /// [`lb::CascadeBackend`] prunes.
    fn pairwise_pruned(
        &self,
        xs: &[&Segment],
        ys: &[&Segment],
        threshold: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<bool>)> {
        let _ = threshold;
        let d = self.pairwise(xs, ys)?;
        let flags = vec![true; d.len()];
        Ok((d, flags))
    }

    /// Whether `pairwise_pruned` can actually bound pairs out.  `false`
    /// keeps threshold-aware call sites on the exact code path, bit for
    /// bit.
    fn supports_pruning(&self) -> bool {
        false
    }

    /// Cascade counter snapshot, if this backend prunes.  Lets drivers
    /// read per-iteration deltas through `&dyn PairwiseBackend` without
    /// widening any signatures.
    fn prune_stats(&self) -> Option<PruneStats> {
        None
    }

    /// Distinguishes distance *kernels* in the shared [`PairCache`]:
    /// backends whose values can differ for the same segment pair must
    /// return different tags.  Convention: 0 is the exact full-band
    /// kernel; a Sakoe-Chiba radius `b` (which can additionally return
    /// the `INFEASIBLE` sentinel) maps to `1 + b`.
    fn kernel_tag(&self) -> u32 {
        0
    }

    /// Preferred number of X rows per `pairwise` call.  The condensed
    /// builder groups triangle rows into blocks of this size: batched
    /// backends (the XLA tile executor) amortise dispatch and avoid
    /// padding an entire tile for a single row, while the native DP
    /// backend is block-size-indifferent (1 keeps work stealing fine-
    /// grained).
    fn preferred_rows(&self) -> usize {
        1
    }
}

/// Native rolling-row DP backend.
pub struct NativeBackend {
    /// Optional Sakoe-Chiba band radius.
    pub band: Option<usize>,
}

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend { band: None }
    }

    pub fn banded(band: usize) -> Self {
        NativeBackend { band: Some(band) }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl PairwiseBackend for NativeBackend {
    fn pairwise(&self, xs: &[&Segment], ys: &[&Segment]) -> anyhow::Result<Vec<f32>> {
        let mut out = Vec::with_capacity(xs.len() * ys.len());
        match self.band {
            Some(b) => {
                // Same Transposed/DtwScratch treatment as the unbanded
                // path: transpose each Y once per call, reuse one
                // scratch — zero allocation in the pair loop.
                let yts: Vec<crate::dtw::classic::Transposed> = ys
                    .iter()
                    .map(|y| {
                        crate::dtw::classic::Transposed::from_row_major(&y.feats, y.dim, y.len)
                    })
                    .collect();
                let mut scratch = crate::dtw::classic::DtwScratch::new();
                for x in xs {
                    for yt in &yts {
                        out.push(crate::dtw::classic::dtw_banded_transposed(
                            &x.feats,
                            x.dim,
                            x.len,
                            yt,
                            b,
                            &mut scratch,
                        ));
                    }
                }
            }
            None => {
                // Row-vectorised path: transpose each Y once per call
                // (amortised over the X block the builder hands us) and
                // reuse one scratch across all pairs — zero allocation
                // in the pair loop.
                let yts: Vec<crate::dtw::classic::Transposed> = ys
                    .iter()
                    .map(|y| {
                        crate::dtw::classic::Transposed::from_row_major(&y.feats, y.dim, y.len)
                    })
                    .collect();
                let mut scratch = crate::dtw::classic::DtwScratch::new();
                for x in xs {
                    for yt in &yts {
                        out.push(crate::dtw::classic::dtw_transposed(
                            &x.feats,
                            x.dim,
                            x.len,
                            yt,
                            &mut scratch,
                        ));
                    }
                }
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn kernel_tag(&self) -> u32 {
        // Full band shares tag 0 with every exact full-band kernel
        // (blocked is bitwise-equal, so sharing is legitimate); each
        // band radius is its own kernel — banded values can differ and
        // can be the INFEASIBLE sentinel.
        match self.band {
            None => 0,
            Some(b) => u32::try_from(b).unwrap_or(u32::MAX - 1).saturating_add(1),
        }
    }

    fn preferred_rows(&self) -> usize {
        // Amortise per-call Y transposition across a block of X rows
        // while keeping work-stealing granularity reasonable.
        16
    }
}

/// Build the condensed distance matrix for `segments` over `backend`,
/// splitting the row range across `threads` workers.
///
/// Work is divided by *rows of the triangle*; since row i holds i
/// entries, rows are dealt in strides so the load per worker is even.
pub fn build_condensed(
    segments: &[&Segment],
    backend: &dyn PairwiseBackend,
    threads: usize,
) -> anyhow::Result<Condensed> {
    let n = segments.len();
    let mut cond = Condensed::zeros(n);
    if n < 2 {
        return Ok(cond);
    }

    // Triangle rows 1..n are grouped into blocks of the backend's
    // preferred size; each task computes the rectangle
    // (rows i0..i1) × (cols 0..i1) and the assembler keeps only the
    // strictly-lower-triangular entries.  The rectangle over-computes
    // at most block²/2 pairs per block — negligible against the i·block
    // useful pairs — and lets batched backends fill whole tiles.
    let block = backend.preferred_rows().max(1);
    let nblocks = (n - 1).div_ceil(block);
    let rows: Vec<anyhow::Result<(usize, usize, Vec<f32>)>> =
        parallel_map(nblocks, threads, |b| {
            let i0 = 1 + b * block;
            let i1 = (i0 + block).min(n);
            let xs: Vec<&Segment> = segments[i0..i1].to_vec();
            let ys: Vec<&Segment> = segments[..i1].to_vec();
            let d = backend.pairwise(&xs, &ys)?;
            Ok((i0, i1, d))
        })?;

    for r in rows {
        let (i0, i1, d) = r?;
        let width = i1; // ys span 0..i1
        for i in i0..i1 {
            let row = &d[(i - i0) * width..(i - i0) * width + i];
            for (j, &v) in row.iter().enumerate() {
                cond.set(i, j, v);
            }
        }
    }
    Ok(cond)
}

/// [`build_condensed`] with a cross-iteration [`PairCache`] above the
/// backend: only cache-miss pairs reach `backend.pairwise`.
///
/// `cache = None` is exactly [`build_condensed`].  With a cache, each
/// row block first probes every triangle pair by *global segment id*
/// ([`Segment::id`]); fully-cold blocks fall back to the same single
/// rectangle dispatch as the uncached builder (so cold-path batching is
/// unchanged), fully-warm blocks touch the backend not at all, and
/// partially-warm blocks compute one row-shaped request per row that
/// still has gaps.  Because a cached value is the value the backend
/// would return for that pair (the native backend is batch-shape
/// independent), the resulting matrix is bitwise identical to the
/// uncached build regardless of cache state.
pub fn build_condensed_cached(
    segments: &[&Segment],
    backend: &dyn PairwiseBackend,
    threads: usize,
    cache: Option<&PairCache>,
) -> anyhow::Result<Condensed> {
    let Some(cache) = cache else {
        return build_condensed(segments, backend, threads);
    };
    let n = segments.len();
    let mut cond = Condensed::zeros(n);
    if n < 2 {
        return Ok(cond);
    }

    // Kernel tag keys this backend's values apart from any other
    // kernel sharing the cache (banded vs unbanded, say).
    let tag = backend.kernel_tag();
    let block = backend.preferred_rows().max(1);
    let nblocks = (n - 1).div_ceil(block);
    type BlockRows = (usize, Vec<Vec<f32>>);
    let rows: Vec<anyhow::Result<BlockRows>> = parallel_map(nblocks, threads, |b| {
        let i0 = 1 + b * block;
        let i1 = (i0 + block).min(n);

        // Probe every triangle pair of the block up front.
        let mut vals: Vec<Vec<f32>> = Vec::with_capacity(i1 - i0);
        let mut missing: Vec<Vec<usize>> = Vec::with_capacity(i1 - i0);
        let (mut any_hit, mut any_miss) = (false, false);
        for i in i0..i1 {
            let mut row = vec![0.0f32; i];
            let mut miss = Vec::new();
            for (j, slot) in row.iter_mut().enumerate() {
                match cache.get_tagged(tag, segments[i].id, segments[j].id) {
                    Some(v) => {
                        *slot = v;
                        any_hit = true;
                    }
                    None => {
                        miss.push(j);
                        any_miss = true;
                    }
                }
            }
            vals.push(row);
            missing.push(miss);
        }

        if !any_miss {
            return Ok((i0, vals));
        }
        if !any_hit {
            // Cold block: identical batching to the uncached builder —
            // one rectangle dispatch — then publish every pair.
            let xs: Vec<&Segment> = segments[i0..i1].to_vec();
            let ys: Vec<&Segment> = segments[..i1].to_vec();
            let d = backend.pairwise(&xs, &ys)?;
            let width = i1;
            for i in i0..i1 {
                let src = &d[(i - i0) * width..(i - i0) * width + i];
                for (j, &v) in src.iter().enumerate() {
                    vals[i - i0][j] = v;
                    cache.insert_tagged(tag, segments[i].id, segments[j].id, v);
                }
            }
            return Ok((i0, vals));
        }
        // Partially warm: compute only the gaps, one request per row.
        for (r, miss) in missing.iter().enumerate() {
            if miss.is_empty() {
                continue;
            }
            let i = i0 + r;
            let ys: Vec<&Segment> = miss.iter().map(|&j| segments[j]).collect();
            let d = backend.pairwise(&segments[i..i + 1], &ys)?;
            anyhow::ensure!(
                d.len() == ys.len(),
                "backend returned {} distances for {} pairs",
                d.len(),
                ys.len()
            );
            for (&j, &v) in miss.iter().zip(&d) {
                vals[r][j] = v;
                cache.insert_tagged(tag, segments[i].id, segments[j].id, v);
            }
        }
        Ok((i0, vals))
    })?;

    for r in rows {
        let (i0, vals) = r?;
        for (r_idx, row) in vals.into_iter().enumerate() {
            let i = i0 + r_idx;
            for (j, v) in row.into_iter().enumerate() {
                cond.set(i, j, v);
            }
        }
    }
    Ok(cond)
}

/// Cross-set distance matrix (rows = xs, cols = ys), parallel over
/// row blocks of the backend's preferred size.
pub fn build_cross(
    xs: &[&Segment],
    ys: &[&Segment],
    backend: &dyn PairwiseBackend,
    threads: usize,
) -> anyhow::Result<Vec<f32>> {
    let block = backend.preferred_rows().max(1);
    let nblocks = xs.len().div_ceil(block);
    let rows: Vec<anyhow::Result<Vec<f32>>> = parallel_map(nblocks, threads, |b| {
        let i0 = b * block;
        let i1 = (i0 + block).min(xs.len());
        backend.pairwise(&xs[i0..i1], ys)
    })?;
    let mut out = Vec::with_capacity(xs.len() * ys.len());
    for r in rows {
        out.extend(r?);
    }
    Ok(out)
}

/// [`build_cross`] with the same [`PairCache`] policy as
/// [`build_condensed_cached`].  Pairs where both sides carry the same
/// global id (possible when `xs` and `ys` overlap) bypass the cache and
/// are always computed, so the symmetric `(min, max)` key stays
/// well-defined.
///
/// Two production consumers: the streaming driver's retirement step —
/// each shard's medoid × batch assignment rectangle (`mahc::streaming`)
/// probes this cache first, so medoid–member pairs the episode's
/// condensed builds just computed never reach the DTW backend a second
/// time — and the stage-0 leader pass (`crate::aggregate`), whose
/// single-row probe rectangles publish every (segment, rep) distance
/// here so stage 1's condensed builds over representatives start warm.
pub fn build_cross_cached(
    xs: &[&Segment],
    ys: &[&Segment],
    backend: &dyn PairwiseBackend,
    threads: usize,
    cache: Option<&PairCache>,
) -> anyhow::Result<Vec<f32>> {
    let Some(cache) = cache else {
        return build_cross(xs, ys, backend, threads);
    };
    if xs.is_empty() || ys.is_empty() {
        return Ok(Vec::new());
    }
    let tag = backend.kernel_tag();
    let block = backend.preferred_rows().max(1);
    let nblocks = xs.len().div_ceil(block);
    let rows: Vec<anyhow::Result<Vec<f32>>> = parallel_map(nblocks, threads, |b| {
        let i0 = b * block;
        let i1 = (i0 + block).min(xs.len());
        let ny = ys.len();
        let mut vals = vec![0.0f32; (i1 - i0) * ny];
        let mut missing: Vec<Vec<usize>> = Vec::with_capacity(i1 - i0);
        let (mut any_hit, mut any_miss) = (false, false);
        for i in i0..i1 {
            let mut miss = Vec::new();
            for (j, y) in ys.iter().enumerate() {
                let cached = if xs[i].id == y.id {
                    None
                } else {
                    cache.get_tagged(tag, xs[i].id, y.id)
                };
                match cached {
                    Some(v) => {
                        vals[(i - i0) * ny + j] = v;
                        any_hit = true;
                    }
                    None => {
                        miss.push(j);
                        any_miss = true;
                    }
                }
            }
            missing.push(miss);
        }

        if !any_miss {
            return Ok(vals);
        }
        if !any_hit {
            // Cold block: one rectangle dispatch, as build_cross does.
            let d = backend.pairwise(&xs[i0..i1], ys)?;
            anyhow::ensure!(
                d.len() == (i1 - i0) * ny,
                "backend returned {} distances for {} pairs",
                d.len(),
                (i1 - i0) * ny
            );
            for i in i0..i1 {
                for (j, y) in ys.iter().enumerate() {
                    let v = d[(i - i0) * ny + j];
                    if xs[i].id != y.id {
                        cache.insert_tagged(tag, xs[i].id, y.id, v);
                    }
                }
            }
            return Ok(d);
        }
        for (r, miss) in missing.iter().enumerate() {
            if miss.is_empty() {
                continue;
            }
            let i = i0 + r;
            let sub: Vec<&Segment> = miss.iter().map(|&j| ys[j]).collect();
            let d = backend.pairwise(&xs[i..i + 1], &sub)?;
            anyhow::ensure!(
                d.len() == sub.len(),
                "backend returned {} distances for {} pairs",
                d.len(),
                sub.len()
            );
            for (&j, &v) in miss.iter().zip(&d) {
                vals[r * ny + j] = v;
                if xs[i].id != ys[j].id {
                    cache.insert_tagged(tag, xs[i].id, ys[j].id, v);
                }
            }
        }
        Ok(vals)
    })?;
    let mut out = Vec::with_capacity(xs.len() * ys.len());
    for r in rows {
        out.extend(r?);
    }
    Ok(out)
}

/// [`build_cross_cached`] with a decision threshold: when the backend
/// prunes ([`PairwiseBackend::supports_pruning`]) and a threshold is given,
/// pairs the cascade bounds out above `threshold` come back as lower
/// bounds (still above `threshold`) instead of exact distances, and
/// only exact values are published to the cache.
///
/// `threshold = None` — or a backend that cannot prune — is *literally*
/// [`build_cross_cached`]: the exact path stays the bitwise oracle for
/// the pruned one.  Consumers must only compare returned values against
/// the same `threshold` (the stage-0 leader ε-join rule does exactly
/// this), which is what makes pruning invisible to results.
pub fn build_cross_cached_pruned(
    xs: &[&Segment],
    ys: &[&Segment],
    backend: &dyn PairwiseBackend,
    threads: usize,
    cache: Option<&PairCache>,
    threshold: Option<f32>,
) -> anyhow::Result<Vec<f32>> {
    let Some(threshold) = threshold else {
        return build_cross_cached(xs, ys, backend, threads, cache);
    };
    if !backend.supports_pruning() {
        return build_cross_cached(xs, ys, backend, threads, cache);
    }
    if xs.is_empty() || ys.is_empty() {
        return Ok(Vec::new());
    }
    let tag = backend.kernel_tag();
    let block = backend.preferred_rows().max(1);
    let nblocks = xs.len().div_ceil(block);
    let rows: Vec<anyhow::Result<Vec<f32>>> = parallel_map(nblocks, threads, |b| {
        let i0 = b * block;
        let i1 = (i0 + block).min(xs.len());
        let ny = ys.len();
        let block_xs = xs
            .get(i0..i1)
            .ok_or_else(|| anyhow::anyhow!("row block {i0}..{i1} out of range"))?;
        let mut vals = vec![0.0f32; (i1 - i0) * ny];
        // Cached exact values first — the cascade's cheapest tier.
        let mut missing: Vec<Vec<usize>> = Vec::with_capacity(i1 - i0);
        for (x, row) in block_xs.iter().zip(vals.chunks_exact_mut(ny)) {
            let mut miss = Vec::new();
            for ((j, y), slot) in ys.iter().enumerate().zip(row.iter_mut()) {
                let cached = if x.id == y.id {
                    None
                } else {
                    cache.and_then(|c| c.get_tagged(tag, x.id, y.id))
                };
                match cached {
                    Some(v) => *slot = v,
                    None => miss.push(j),
                }
            }
            missing.push(miss);
        }
        // Gaps go through the pruned query, one row-shaped request per
        // row (the cascade batches DP survivors per row itself, so this
        // shape adds no extra exact calls).  Only exact values — flag
        // set — are published; a lower bound must never be cached.
        for ((x, row), miss) in block_xs
            .iter()
            .zip(vals.chunks_exact_mut(ny))
            .zip(&missing)
        {
            if miss.is_empty() {
                continue;
            }
            let sub: Vec<&Segment> = miss.iter().filter_map(|&j| ys.get(j).copied()).collect();
            let (d, flags) = backend.pairwise_pruned(&[*x], &sub, threshold)?;
            anyhow::ensure!(
                d.len() == sub.len() && flags.len() == sub.len(),
                "backend returned {} distances / {} flags for {} pairs",
                d.len(),
                flags.len(),
                sub.len()
            );
            for (((&j, &v), &exact), y) in miss.iter().zip(&d).zip(&flags).zip(&sub) {
                if let Some(slot) = row.get_mut(j) {
                    *slot = v;
                }
                if exact && x.id != y.id {
                    if let Some(c) = cache {
                        c.insert_tagged(tag, x.id, y.id, v);
                    }
                }
            }
        }
        Ok(vals)
    })?;
    let mut out = Vec::with_capacity(xs.len() * ys.len());
    for r in rows {
        out.extend(r?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetSpec;
    use crate::corpus::generate;

    #[test]
    fn condensed_matches_direct_dtw() {
        let set = generate(&DatasetSpec::tiny(20, 3, 1));
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let cond = build_condensed(&refs, &NativeBackend::new(), 4).unwrap();
        for i in 0..20 {
            for j in 0..i {
                let want = crate::dtw::dtw(
                    &refs[i].feats,
                    &refs[j].feats,
                    set.dim,
                    refs[i].len,
                    refs[j].len,
                );
                assert_eq!(cond.get(i, j), want);
            }
        }
    }

    #[test]
    fn single_thread_equals_parallel() {
        let set = generate(&DatasetSpec::tiny(16, 3, 2));
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let a = build_condensed(&refs, &NativeBackend::new(), 1).unwrap();
        let b = build_condensed(&refs, &NativeBackend::new(), 8).unwrap();
        assert_eq!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn trivial_sizes() {
        let set = generate(&DatasetSpec::tiny(8, 2, 3));
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let c0 = build_condensed(&refs[..1], &NativeBackend::new(), 2).unwrap();
        assert_eq!(c0.n(), 1);
        let c2 = build_condensed(&refs[..2], &NativeBackend::new(), 2).unwrap();
        assert!(c2.get(1, 0) >= 0.0);
    }

    #[test]
    fn cross_matrix_shape_and_values() {
        let set = generate(&DatasetSpec::tiny(10, 2, 4));
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let m = build_cross(&refs[..3], &refs[3..7], &NativeBackend::new(), 2).unwrap();
        assert_eq!(m.len(), 3 * 4);
        let want = crate::dtw::dtw(
            &refs[1].feats,
            &refs[5].feats,
            set.dim,
            refs[1].len,
            refs[5].len,
        );
        assert_eq!(m[1 * 4 + 2], want);
    }

    #[test]
    fn cached_condensed_matches_uncached_across_states() {
        let set = generate(&DatasetSpec::tiny(30, 4, 7));
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let backend = NativeBackend::new();
        let want = build_condensed(&refs, &backend, 3).unwrap();

        // Cold, warm, and byte-starved (evicting) caches all reproduce
        // the uncached matrix bit for bit.
        let cache = PairCache::with_capacity_bytes(1 << 20);
        let cold = build_condensed_cached(&refs, &backend, 3, Some(&cache)).unwrap();
        assert_eq!(cold.as_slice(), want.as_slice());
        let warm = build_condensed_cached(&refs, &backend, 3, Some(&cache)).unwrap();
        assert_eq!(warm.as_slice(), want.as_slice());
        let stats = cache.stats();
        assert_eq!(stats.hits as usize, want.len(), "warm pass fully served");

        let tiny = PairCache::with_capacity_bytes(1); // forces eviction
        for _ in 0..3 {
            let got = build_condensed_cached(&refs, &backend, 2, Some(&tiny)).unwrap();
            assert_eq!(got.as_slice(), want.as_slice());
        }
        assert!(tiny.stats().evictions > 0, "tiny budget must evict");

        // None delegates to the plain builder.
        let none = build_condensed_cached(&refs, &backend, 3, None).unwrap();
        assert_eq!(none.as_slice(), want.as_slice());
    }

    #[test]
    fn cached_partial_warm_blocks_fill_gaps() {
        // Pre-seed the cache with a *subset* of rows' pairs so blocks
        // are partially warm, exercising the per-row gap path.
        let set = generate(&DatasetSpec::tiny(24, 3, 8));
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let backend = NativeBackend::new();
        let want = build_condensed(&refs, &backend, 2).unwrap();

        let cache = PairCache::with_capacity_bytes(1 << 20);
        for i in 1..refs.len() {
            for j in 0..i {
                if (i + j) % 3 == 0 {
                    cache.insert(refs[i].id, refs[j].id, want.get(i, j));
                }
            }
        }
        let got = build_condensed_cached(&refs, &backend, 2, Some(&cache)).unwrap();
        assert_eq!(got.as_slice(), want.as_slice());
        assert!(cache.stats().hits > 0);
        assert!(cache.stats().misses > 0);
    }

    #[test]
    fn cached_cross_matches_uncached_and_skips_self_pairs() {
        let set = generate(&DatasetSpec::tiny(20, 3, 9));
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let backend = NativeBackend::new();
        // Overlapping xs/ys: shared segments have equal ids, which must
        // bypass the cache rather than hit the symmetric-key assert.
        let (xs, ys) = (&refs[..8], &refs[4..16]);
        let want = build_cross(xs, ys, &backend, 2).unwrap();

        let cache = PairCache::with_capacity_bytes(1 << 20);
        let cold = build_cross_cached(xs, ys, &backend, 2, Some(&cache)).unwrap();
        assert_eq!(cold, want);
        let warm = build_cross_cached(xs, ys, &backend, 2, Some(&cache)).unwrap();
        assert_eq!(warm, want);
        let none = build_cross_cached(xs, ys, &backend, 2, None).unwrap();
        assert_eq!(none, want);
    }

    #[test]
    fn cached_condensed_thread_count_invariant() {
        let set = generate(&DatasetSpec::tiny(26, 3, 10));
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let backend = NativeBackend::new();
        let want = build_condensed(&refs, &backend, 1).unwrap();
        for threads in [1usize, 2, 8] {
            let cache = PairCache::with_capacity_bytes(1 << 18);
            let a = build_condensed_cached(&refs, &backend, threads, Some(&cache)).unwrap();
            let b = build_condensed_cached(&refs, &backend, threads, Some(&cache)).unwrap();
            assert_eq!(a.as_slice(), want.as_slice(), "threads={threads}");
            assert_eq!(b.as_slice(), want.as_slice(), "threads={threads}");
        }
    }

    #[test]
    fn banded_and_unbanded_builds_share_a_cache_without_aliasing() {
        // The regression this PR's keying fix pins: a banded build
        // (whose values differ and can be the INFEASIBLE sentinel) and
        // an unbanded build sharing one physical cache must each see
        // exactly their own kernel's values.
        let set = generate(&DatasetSpec::tiny(24, 3, 11));
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let full = NativeBackend::new();
        let banded = NativeBackend::banded(1);
        assert_ne!(full.kernel_tag(), banded.kernel_tag());
        let want_full = build_condensed(&refs, &full, 2).unwrap();
        let want_band = build_condensed(&refs, &banded, 2).unwrap();
        assert_ne!(
            want_full.as_slice(),
            want_band.as_slice(),
            "band 1 must actually change some distances for this pin to bite"
        );

        let cache = PairCache::with_capacity_bytes(1 << 20);
        // Warm with the banded kernel first, then build unbanded (and
        // vice versa): each must reproduce its own uncached matrix.
        let b1 = build_condensed_cached(&refs, &banded, 2, Some(&cache)).unwrap();
        let f1 = build_condensed_cached(&refs, &full, 2, Some(&cache)).unwrap();
        let b2 = build_condensed_cached(&refs, &banded, 2, Some(&cache)).unwrap();
        let f2 = build_condensed_cached(&refs, &full, 2, Some(&cache)).unwrap();
        assert_eq!(b1.as_slice(), want_band.as_slice());
        assert_eq!(f1.as_slice(), want_full.as_slice());
        assert_eq!(b2.as_slice(), want_band.as_slice(), "warm banded pass");
        assert_eq!(f2.as_slice(), want_full.as_slice(), "warm unbanded pass");

        // The blocked backend's full-band kernel is bitwise-equal to
        // the native one, so sharing tag 0 serves it the same values.
        assert_eq!(BlockedBackend::new().kernel_tag(), full.kernel_tag());
        let fb = build_condensed_cached(&refs, &BlockedBackend::new(), 2, Some(&cache)).unwrap();
        assert_eq!(fb.as_slice(), want_full.as_slice());
    }

    #[test]
    fn pruned_cross_builder_without_pruning_backend_is_the_exact_path() {
        let set = generate(&DatasetSpec::tiny(18, 3, 12));
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let backend = NativeBackend::new();
        let (xs, ys) = (&refs[..6], &refs[6..]);
        let want = build_cross(xs, ys, &backend, 2).unwrap();
        // A non-pruning backend ignores the threshold entirely.
        let got = build_cross_cached_pruned(xs, ys, &backend, 2, None, Some(0.1)).unwrap();
        assert_eq!(got, want);
        // threshold = None delegates even for pruning backends.
        let cascade = lb::CascadeBackend::borrowed(&backend, &set, lb::CascadeMode::On);
        let none = build_cross_cached_pruned(xs, ys, &cascade, 2, None, None).unwrap();
        assert_eq!(none, want);
    }

    #[test]
    fn pruned_cross_builder_matches_exact_decisions_and_skips_lb_cache_inserts() {
        let set = generate(&DatasetSpec::tiny(26, 3, 13));
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let backend = NativeBackend::new();
        let cascade = lb::CascadeBackend::borrowed(&backend, &set, lb::CascadeMode::Debug);
        let (xs, ys) = (&refs[..10], &refs[10..]);
        let want = build_cross(xs, ys, &backend, 2).unwrap();
        let mut sorted = want.clone();
        sorted.sort_unstable_by(f32::total_cmp);
        let threshold = sorted[sorted.len() / 3];

        let cache = PairCache::with_capacity_bytes(1 << 20);
        let got =
            build_cross_cached_pruned(xs, ys, &cascade, 2, Some(&cache), Some(threshold)).unwrap();
        assert_eq!(got.len(), want.len());
        for (&g, &w) in got.iter().zip(&want) {
            // Threshold decisions agree pair for pair; surviving values
            // are bitwise exact.
            assert_eq!(g <= threshold, w <= threshold);
            if g <= threshold {
                assert_eq!(g.to_bits(), w.to_bits());
            }
        }
        let stats = cascade.stats();
        assert!(stats.lb_pruned > 0, "threshold must prune something");
        // Every cached entry is exact: a warm exact rebuild over the
        // same cache reproduces the oracle bit for bit.
        let warm = build_cross_cached(xs, ys, &backend, 2, Some(&cache)).unwrap();
        assert_eq!(warm, want);
    }

    #[test]
    fn backend_kind_parse() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("scalar").unwrap(), BackendKind::Native);
        assert_eq!(
            BackendKind::parse("blocked").unwrap(),
            BackendKind::Blocked
        );
        assert_eq!(BackendKind::Blocked.name(), "blocked");
        assert_eq!(BackendKind::parse("xla").unwrap(), BackendKind::Xla);
        assert!(BackendKind::parse("gpu").is_err());
    }
}
