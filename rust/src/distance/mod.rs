//! Distance-matrix construction: condensed storage + pluggable DTW
//! backends + the parallel builder.
//!
//! The MAHC space constraint the paper is about lives here: a subset of
//! n segments needs an n(n−1)/2-entry condensed matrix ([`Condensed`]),
//! so β (the subset occupancy threshold) directly bounds peak memory.
//! [`build_condensed`] fills one by tiling pair blocks over a
//! [`DtwBackend`] — either the native Rust DP ([`NativeBackend`]) or
//! the AOT XLA executable (`runtime::XlaDtwBackend`) — in parallel.

pub mod condensed;

pub use condensed::Condensed;

use crate::corpus::Segment;
use crate::util::pool::parallel_map;

/// Which DTW implementation computes pair distances.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust rolling-row DP (reference; fully deterministic).
    Native,
    /// AOT-compiled Pallas kernel through PJRT (`artifacts/dtw_*.hlo.txt`).
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "native" => Ok(BackendKind::Native),
            "xla" => Ok(BackendKind::Xla),
            other => anyhow::bail!("unknown backend '{other}' (native|xla)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }
}

/// A pairwise-DTW engine.  Implementations must be `Sync`: the builder
/// calls them from worker threads.
pub trait DtwBackend: Sync {
    /// Distances between all (x, y) segment pairs: returns a
    /// row-major `xs.len() × ys.len()` buffer.
    fn pairwise(&self, xs: &[&Segment], ys: &[&Segment]) -> anyhow::Result<Vec<f32>>;

    /// Human-readable name for telemetry.
    fn name(&self) -> &'static str;

    /// Preferred number of X rows per `pairwise` call.  The condensed
    /// builder groups triangle rows into blocks of this size: batched
    /// backends (the XLA tile executor) amortise dispatch and avoid
    /// padding an entire tile for a single row, while the native DP
    /// backend is block-size-indifferent (1 keeps work stealing fine-
    /// grained).
    fn preferred_rows(&self) -> usize {
        1
    }
}

/// Native rolling-row DP backend.
pub struct NativeBackend {
    /// Optional Sakoe-Chiba band radius.
    pub band: Option<usize>,
}

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend { band: None }
    }

    pub fn banded(band: usize) -> Self {
        NativeBackend { band: Some(band) }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl DtwBackend for NativeBackend {
    fn pairwise(&self, xs: &[&Segment], ys: &[&Segment]) -> anyhow::Result<Vec<f32>> {
        let mut out = Vec::with_capacity(xs.len() * ys.len());
        match self.band {
            Some(b) => {
                for x in xs {
                    for y in ys {
                        out.push(crate::dtw::dtw_banded(
                            &x.feats, &y.feats, x.dim, x.len, y.len, b,
                        ));
                    }
                }
            }
            None => {
                // Row-vectorised path: transpose each Y once per call
                // (amortised over the X block the builder hands us) and
                // reuse one scratch across all pairs — zero allocation
                // in the pair loop.
                let yts: Vec<crate::dtw::classic::Transposed> = ys
                    .iter()
                    .map(|y| {
                        crate::dtw::classic::Transposed::from_row_major(&y.feats, y.dim, y.len)
                    })
                    .collect();
                let mut scratch = crate::dtw::classic::DtwScratch::new();
                for x in xs {
                    for yt in &yts {
                        out.push(crate::dtw::classic::dtw_transposed(
                            &x.feats,
                            x.dim,
                            x.len,
                            yt,
                            &mut scratch,
                        ));
                    }
                }
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn preferred_rows(&self) -> usize {
        // Amortise per-call Y transposition across a block of X rows
        // while keeping work-stealing granularity reasonable.
        16
    }
}

/// Build the condensed distance matrix for `segments` over `backend`,
/// splitting the row range across `threads` workers.
///
/// Work is divided by *rows of the triangle*; since row i holds i
/// entries, rows are dealt in strides so the load per worker is even.
pub fn build_condensed(
    segments: &[&Segment],
    backend: &dyn DtwBackend,
    threads: usize,
) -> anyhow::Result<Condensed> {
    let n = segments.len();
    let mut cond = Condensed::zeros(n);
    if n < 2 {
        return Ok(cond);
    }

    // Triangle rows 1..n are grouped into blocks of the backend's
    // preferred size; each task computes the rectangle
    // (rows i0..i1) × (cols 0..i1) and the assembler keeps only the
    // strictly-lower-triangular entries.  The rectangle over-computes
    // at most block²/2 pairs per block — negligible against the i·block
    // useful pairs — and lets batched backends fill whole tiles.
    let block = backend.preferred_rows().max(1);
    let nblocks = (n - 1).div_ceil(block);
    let rows: Vec<anyhow::Result<(usize, usize, Vec<f32>)>> =
        parallel_map(nblocks, threads, |b| {
            let i0 = 1 + b * block;
            let i1 = (i0 + block).min(n);
            let xs: Vec<&Segment> = segments[i0..i1].to_vec();
            let ys: Vec<&Segment> = segments[..i1].to_vec();
            let d = backend.pairwise(&xs, &ys)?;
            Ok((i0, i1, d))
        });

    for r in rows {
        let (i0, i1, d) = r?;
        let width = i1; // ys span 0..i1
        for i in i0..i1 {
            let row = &d[(i - i0) * width..(i - i0) * width + i];
            for (j, &v) in row.iter().enumerate() {
                cond.set(i, j, v);
            }
        }
    }
    Ok(cond)
}

/// Cross-set distance matrix (rows = xs, cols = ys), parallel over
/// row blocks of the backend's preferred size.
pub fn build_cross(
    xs: &[&Segment],
    ys: &[&Segment],
    backend: &dyn DtwBackend,
    threads: usize,
) -> anyhow::Result<Vec<f32>> {
    let block = backend.preferred_rows().max(1);
    let nblocks = xs.len().div_ceil(block);
    let rows: Vec<anyhow::Result<Vec<f32>>> = parallel_map(nblocks, threads, |b| {
        let i0 = b * block;
        let i1 = (i0 + block).min(xs.len());
        backend.pairwise(&xs[i0..i1], ys)
    });
    let mut out = Vec::with_capacity(xs.len() * ys.len());
    for r in rows {
        out.extend(r?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetSpec;
    use crate::corpus::generate;

    #[test]
    fn condensed_matches_direct_dtw() {
        let set = generate(&DatasetSpec::tiny(20, 3, 1));
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let cond = build_condensed(&refs, &NativeBackend::new(), 4).unwrap();
        for i in 0..20 {
            for j in 0..i {
                let want = crate::dtw::dtw(
                    &refs[i].feats,
                    &refs[j].feats,
                    set.dim,
                    refs[i].len,
                    refs[j].len,
                );
                assert_eq!(cond.get(i, j), want);
            }
        }
    }

    #[test]
    fn single_thread_equals_parallel() {
        let set = generate(&DatasetSpec::tiny(16, 3, 2));
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let a = build_condensed(&refs, &NativeBackend::new(), 1).unwrap();
        let b = build_condensed(&refs, &NativeBackend::new(), 8).unwrap();
        assert_eq!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn trivial_sizes() {
        let set = generate(&DatasetSpec::tiny(8, 2, 3));
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let c0 = build_condensed(&refs[..1], &NativeBackend::new(), 2).unwrap();
        assert_eq!(c0.n(), 1);
        let c2 = build_condensed(&refs[..2], &NativeBackend::new(), 2).unwrap();
        assert!(c2.get(1, 0) >= 0.0);
    }

    #[test]
    fn cross_matrix_shape_and_values() {
        let set = generate(&DatasetSpec::tiny(10, 2, 4));
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let m = build_cross(&refs[..3], &refs[3..7], &NativeBackend::new(), 2).unwrap();
        assert_eq!(m.len(), 3 * 4);
        let want = crate::dtw::dtw(
            &refs[1].feats,
            &refs[5].feats,
            set.dim,
            refs[1].len,
            refs[5].len,
        );
        assert_eq!(m[1 * 4 + 2], want);
    }

    #[test]
    fn backend_kind_parse() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("xla").unwrap(), BackendKind::Xla);
        assert!(BackendKind::parse("gpu").is_err());
    }
}
