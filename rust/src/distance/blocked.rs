//! Lane-parallel multi-pair DTW backend.
//!
//! [`super::NativeBackend`] aligns one (x, y) pair at a time: its inner
//! DP loop is a serial dependence chain through `left`, so the recurrence
//! runs at scalar latency no matter how wide the machine's vector units
//! are.  [`BlockedBackend`] instead evaluates up to [`LANES`] pairs that
//! share one query segment per kernel call, laying the local-distance and
//! DP rows out struct-of-arrays (`[j][lane]` interleaved) so every
//! per-cell operation becomes a fixed-width lane loop over a plain
//! `[f32; LANES]` chunk — a shape LLVM autovectorises on stable Rust,
//! no `std::simd` required.
//!
//! **Backend-invariance contract** (verified by
//! `rust/tests/backend_parity.rs`, documented in EXPERIMENTS.md
//! §Backends): each lane executes *exactly* the scalar kernel's per-cell
//! operation sequence — the same ascending-`d` squared-difference fold,
//! the same `diag.min(up).min(left)` operand order, the same
//! `dist + best` add — and lanes never mix, so full-band results are
//! **bitwise identical** to [`super::NativeBackend`].  Banded alignments
//! go through the very same scalar kernel
//! ([`crate::dtw::classic::dtw_banded_transposed`]) the native backend
//! uses, so the banded deviation bound is trivially zero ulp.
//!
//! Lanes are grouped by descending segment length (a stable sort, so
//! grouping is deterministic) to keep the zero-padding to each group's
//! longest member small; padded columns sit *after* a lane's own final
//! column and the DP is causal in `j`, so they can never influence the
//! cell the lane's result is read from.

use super::{PairwiseBackend, NativeBackend};
use crate::corpus::Segment;

/// Pairs aligned per kernel call.  Eight f32 lanes fill one AVX2 vector
/// (two NEON vectors); the lane loops below are written over
/// `chunks_exact(LANES)` so the width is a compile-time constant.
pub const LANES: usize = 8;

/// Lane-parallel multi-pair DTW backend.
pub struct BlockedBackend {
    /// Optional Sakoe-Chiba band radius.  Banded calls are delegated to
    /// the shared scalar band kernel (zero-ulp parity with
    /// [`super::NativeBackend`]); only full-band alignments take the
    /// lane-parallel path.
    pub band: Option<usize>,
}

impl BlockedBackend {
    pub fn new() -> Self {
        BlockedBackend { band: None }
    }

    pub fn banded(band: usize) -> Self {
        BlockedBackend { band: Some(band) }
    }
}

impl Default for BlockedBackend {
    fn default() -> Self {
        Self::new()
    }
}

/// Up to [`LANES`] Y segments packed `[d][j][lane]`-interleaved:
/// `data[(d * ly_max + j) * LANES + l]` holds frame `j`, dimension `d`
/// of lane `l`'s segment, zero beyond that lane's length.  One group is
/// packed per lane set and reused across every X row of the call block,
/// so packing cost amortises exactly like
/// [`crate::dtw::classic::Transposed`] does for the scalar backend.
struct LaneGroup {
    dim: usize,
    ly_max: usize,
    lens: [usize; LANES],
    lanes: usize,
    data: Vec<f32>,
}

impl LaneGroup {
    fn pack(ys: &[&Segment]) -> LaneGroup {
        debug_assert!(!ys.is_empty() && ys.len() <= LANES);
        let dim = ys[0].dim;
        let ly_max = ys.iter().map(|y| y.len).max().unwrap_or(1).max(1);
        let mut lens = [0usize; LANES];
        let mut data = vec![0.0f32; dim * ly_max * LANES];
        for (l, y) in ys.iter().enumerate() {
            debug_assert_eq!(y.dim, dim);
            // Same loud failures as the scalar kernel's asserts; without
            // them a zero-length lane would underflow the result index
            // in dtw_lanes, and a short buffer would die on an anonymous
            // slice-index panic instead of the documented message.
            assert!(y.len >= 1, "empty sequence");
            assert!(y.feats.len() >= y.len * dim, "buffer too short");
            lens[l] = y.len;
            for j in 0..y.len {
                for d in 0..dim {
                    data[(d * ly_max + j) * LANES + l] = y.feats[j * dim + d];
                }
            }
        }
        LaneGroup {
            dim,
            ly_max,
            lens,
            lanes: ys.len(),
            data,
        }
    }

    #[inline]
    fn dim_rows(&self, d: usize) -> &[f32] {
        &self.data[d * self.ly_max * LANES..(d + 1) * self.ly_max * LANES]
    }
}

/// Reusable SoA rows so the pair-group loop allocates nothing.
#[derive(Debug, Default)]
struct LaneScratch {
    dist: Vec<f32>,
    prev: Vec<f32>,
    cur: Vec<f32>,
}

impl LaneScratch {
    fn resize(&mut self, width: usize) {
        self.dist.resize(width, 0.0);
        self.prev.resize(width, 0.0);
        self.cur.resize(width, 0.0);
    }
}

/// Align one query against every lane of `g` simultaneously, writing one
/// normalised distance per real lane into `out[..g.lanes]`.
///
/// Per lane this is exactly [`crate::dtw::classic::dtw_transposed`]:
/// the local-distance fold accumulates over `d` in ascending order, row
/// 0 is a running prefix sum, and interior cells compute
/// `dist + diag.min(up).min(left)` — operand order preserved, so every
/// lane's f32 result is bitwise equal to the scalar kernel's.  Padded
/// columns (`j >= lens[l]`) and padded lanes (`l >= g.lanes`) carry
/// zeros; the DP is causal in `j`, so they never reach the cell
/// `(lx-1, lens[l]-1)` a lane's answer is read from.
fn dtw_lanes(
    x: &[f32],
    dim: usize,
    lx: usize,
    g: &LaneGroup,
    scratch: &mut LaneScratch,
    out: &mut [f32; LANES],
) {
    debug_assert_eq!(dim, g.dim);
    assert!(lx >= 1, "empty sequence");
    assert!(x.len() >= lx * dim, "buffer too short");
    // `resize` pins each row buffer to exactly ly_max·LANES, so the
    // chunked lane loops below see no stale tail from a larger group.
    scratch.resize(g.ly_max * LANES);
    let LaneScratch { dist, prev, cur } = scratch;

    // Local-distance rows for x frame i: dist[j·LANES + l] =
    // ||x_i − y_l[j]||.  Vector FMAs across the contiguous (j, lane)
    // axis, one vector sqrt at the end — the scalar `fill_row` widened
    // by LANES, same ascending-d accumulation order per cell.
    let fill_rows = |dist: &mut [f32], xi: &[f32]| {
        dist.fill(0.0);
        for (d, &xv) in xi.iter().enumerate() {
            for (acc, &yv) in dist.iter_mut().zip(g.dim_rows(d)) {
                let t = xv - yv;
                *acc += t * t;
            }
        }
        for v in dist.iter_mut() {
            *v = v.sqrt();
        }
    };

    // Row 0: per-lane running prefix sum along j.
    fill_rows(dist, &x[0..dim]);
    let mut run = [0.0f32; LANES];
    for (pj, dj) in prev
        .chunks_exact_mut(LANES)
        .zip(dist.chunks_exact(LANES))
    {
        for l in 0..LANES {
            run[l] += dj[l];
            pj[l] = run[l];
        }
    }

    for i in 1..lx {
        fill_rows(dist, &x[i * dim..(i + 1) * dim]);
        // Column 0, then the interior recurrence with `left` and `diag`
        // riding in fixed-width lane registers.
        let mut left = [0.0f32; LANES];
        let mut diag = [0.0f32; LANES];
        for l in 0..LANES {
            left[l] = prev[l] + dist[l];
            cur[l] = left[l];
            diag[l] = prev[l];
        }
        for j in 1..g.ly_max {
            let pj = &prev[j * LANES..(j + 1) * LANES];
            let dj = &dist[j * LANES..(j + 1) * LANES];
            let cj = &mut cur[j * LANES..(j + 1) * LANES];
            for l in 0..LANES {
                let up = pj[l];
                let best = diag[l].min(up).min(left[l]);
                left[l] = dj[l] + best;
                cj[l] = left[l];
                diag[l] = up;
            }
        }
        std::mem::swap(prev, cur);
    }

    for l in 0..g.lanes {
        let ly = g.lens[l];
        out[l] = prev[(ly - 1) * LANES + l] / (lx + ly) as f32;
    }
}

impl PairwiseBackend for BlockedBackend {
    fn pairwise(&self, xs: &[&Segment], ys: &[&Segment]) -> anyhow::Result<Vec<f32>> {
        if self.band.is_some() {
            // Banded path: delegate to NativeBackend outright so the
            // zero-ulp banded parity is structural (one kernel, one
            // call path) rather than a copy kept in sync by hand.
            return NativeBackend { band: self.band }.pairwise(xs, ys);
        }

        let ny = ys.len();
        let mut out = vec![0.0f32; xs.len() * ny];
        if xs.is_empty() || ny == 0 {
            return Ok(out);
        }
        // Group lanes by descending length (stable, hence deterministic)
        // so each group pads only to its own longest member; results are
        // scattered back through the original column index, so the
        // output layout — and every individual value — is independent of
        // the grouping.
        let mut order: Vec<usize> = (0..ny).collect();
        order.sort_by_key(|&j| std::cmp::Reverse(ys[j].len));

        let mut scratch = LaneScratch::default();
        let mut lane_out = [0.0f32; LANES];
        for cols in order.chunks(LANES) {
            let group_ys: Vec<&Segment> = cols.iter().map(|&j| ys[j]).collect();
            let group = LaneGroup::pack(&group_ys);
            for (xi, x) in xs.iter().enumerate() {
                dtw_lanes(&x.feats, x.dim, x.len, &group, &mut scratch, &mut lane_out);
                for (l, &j) in cols.iter().enumerate() {
                    out[xi * ny + j] = lane_out[l];
                }
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "blocked"
    }

    fn kernel_tag(&self) -> u32 {
        // Same convention as NativeBackend: full band is the shared
        // exact tag 0 (the lane kernel is bitwise-equal to the scalar
        // DP), banded delegates to the scalar band kernel and tags by
        // radius.
        match self.band {
            None => 0,
            Some(b) => u32::try_from(b).unwrap_or(u32::MAX - 1).saturating_add(1),
        }
    }

    fn preferred_rows(&self) -> usize {
        // Must match NativeBackend: the condensed/cross builders block
        // triangle rows by this size, and the cached builders probe the
        // PairCache per block — equal block shapes keep probe order and
        // hit patterns backend-invariant (asserted in backend_parity).
        16
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetSpec;
    use crate::corpus::generate;
    use crate::distance::NativeBackend;

    fn corpus(n: usize, dim: usize, len_range: (usize, usize), seed: u64) -> Vec<Segment> {
        let mut spec = DatasetSpec::tiny(n, 3, seed);
        spec.feat_dim = dim;
        spec.len_range = len_range;
        generate(&spec).segments
    }

    fn assert_bitwise(a: &[f32], b: &[f32], ctx: &str) {
        assert_eq!(a.len(), b.len(), "{ctx}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: pair {i}: {x} vs {y}");
        }
    }

    #[test]
    fn full_band_bitwise_equals_native_across_shapes() {
        for (dim, lr, seed) in [(1usize, (2, 7), 1u64), (4, (3, 12), 2), (13, (6, 24), 3)] {
            let segs = corpus(20, dim, lr, seed);
            let refs: Vec<&Segment> = segs.iter().collect();
            let native = NativeBackend::new().pairwise(&refs[..9], &refs[9..]).unwrap();
            let blocked = BlockedBackend::new().pairwise(&refs[..9], &refs[9..]).unwrap();
            assert_bitwise(&native, &blocked, &format!("dim={dim}"));
        }
    }

    #[test]
    fn remainder_lane_groups_are_exact() {
        // ys counts around the LANES boundary exercise full groups,
        // a final short group, and a lone lane.
        let segs = corpus(24, 5, (4, 16), 9);
        let refs: Vec<&Segment> = segs.iter().collect();
        for ny in [1usize, 3, 7, 8, 9, 15, 17] {
            let native = NativeBackend::new().pairwise(&refs[..4], &refs[4..4 + ny]).unwrap();
            let blocked = BlockedBackend::new().pairwise(&refs[..4], &refs[4..4 + ny]).unwrap();
            assert_bitwise(&native, &blocked, &format!("ny={ny}"));
        }
    }

    #[test]
    fn single_frame_segments_align() {
        let mut segs = corpus(10, 3, (1, 5), 12);
        // Force a genuine length-1 segment into the mix.
        segs[0].len = 1;
        segs[0].feats.truncate(3);
        let refs: Vec<&Segment> = segs.iter().collect();
        let native = NativeBackend::new().pairwise(&refs[..3], &refs[3..]).unwrap();
        let blocked = BlockedBackend::new().pairwise(&refs[..3], &refs[3..]).unwrap();
        assert_bitwise(&native, &blocked, "len-1");
        let swapped = BlockedBackend::new().pairwise(&refs[3..], &refs[..3]).unwrap();
        let native_sw = NativeBackend::new().pairwise(&refs[3..], &refs[..3]).unwrap();
        assert_bitwise(&native_sw, &swapped, "len-1 swapped");
    }

    #[test]
    fn banded_shares_the_scalar_kernel_bitwise() {
        let segs = corpus(16, 4, (5, 20), 13);
        let refs: Vec<&Segment> = segs.iter().collect();
        for band in [0usize, 2, 8, 100] {
            let native = NativeBackend::banded(band).pairwise(&refs[..6], &refs[6..]).unwrap();
            let blocked = BlockedBackend::banded(band).pairwise(&refs[..6], &refs[6..]).unwrap();
            assert_bitwise(&native, &blocked, &format!("band={band}"));
        }
    }

    #[test]
    fn empty_inputs_yield_empty_output() {
        let segs = corpus(4, 3, (3, 8), 14);
        let refs: Vec<&Segment> = segs.iter().collect();
        let b = BlockedBackend::new();
        assert!(b.pairwise(&refs[..0], &refs).unwrap().is_empty());
        assert!(b.pairwise(&refs, &refs[..0]).unwrap().is_empty());
    }

    #[test]
    fn block_shape_matches_native() {
        assert_eq!(
            BlockedBackend::new().preferred_rows(),
            NativeBackend::new().preferred_rows(),
            "builder blocking (and with it cache probe order) must be backend-invariant"
        );
    }
}
