//! Cross-iteration DTW pair-distance cache.
//!
//! The MAHC refine step deliberately keeps stage-1 cluster members
//! together, so the vast majority of within-subset segment pairs recur
//! from one iteration to the next (and medoid pairs recur in stage 2) —
//! yet the driver used to recompute every condensed matrix from
//! scratch.  [`PairCache`] closes that gap: a sharded, capacity-bounded
//! map from `(kernel tag, min_id, max_id)` triples to their DTW
//! distance, sitting *above* the [`super::PairwiseBackend`] trait so both
//! the native DP and the XLA tile executor benefit.  The kernel tag
//! ([`super::PairwiseBackend::kernel_tag`]) folds the distance semantics —
//! full-band vs each Sakoe-Chiba radius, which can differ by the
//! `INFEASIBLE` sentinel alone — into the key, so backends with
//! different kernels can share one physical cache without serving each
//! other aliased values.
//!
//! The capacity bound is the time-side companion of the paper's space
//! bound: β caps any single resident condensed matrix at
//! β(β−1)/2 · 4 bytes, and `capacity_bytes` caps the resident
//! cross-iteration distance pool, so total distance memory stays
//! thresholded in the same spirit (see EXPERIMENTS.md §Perf for the
//! measured budget/hit-rate trade-off).  Eviction is per-shard FIFO —
//! deterministic in insertion order and cheap; because cached values
//! equal the values the backend would recompute, *results are bitwise
//! identical to the uncached path regardless of hit or eviction
//! pattern* (asserted by `rust/tests/cache_determinism.rs` for the
//! native backend, whose per-pair results are independent of call
//! batching).
//!
//! # Scoped handles (multi-tenant serve mode)
//!
//! One physical cache can be shared by many concurrent streaming
//! sessions: [`PairCache::scoped`] returns a lightweight handle onto
//! the *same* shard array with
//!
//! * an **id offset** — session-local segment ids are namespaced by the
//!   handle's offset before keying, so sessions over different corpora
//!   never collide even though each corpus numbers its segments from 0;
//! * **fresh counters** — hits/misses/evictions accumulate per handle,
//!   giving per-session cache telemetry over shared storage;
//! * an optional **residency budget** — a per-handle FIFO of the keys
//!   this handle inserted; once more than `budget / ENTRY_BYTES` are
//!   resident, the handle evicts its *own* oldest entries from the
//!   shared map.  Budget-evicted keys leave their slot in the shard
//!   FIFO behind (removing from the middle would be linear); stale
//!   slots are skipped and pruned lazily, and a 2× FIFO length bound
//!   keeps queue memory proportional to the byte budget regardless of
//!   churn.
//!
//! Because cache contents never change results (the determinism pin
//! above), neither the per-session budget nor cross-session eviction
//! interference can perturb any session's output — only its hit rate.

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::telemetry::CacheStats;

/// Shards: enough to keep worker threads from serialising on one lock,
/// few enough that the per-shard FIFO stays cache-friendly.
const SHARDS: usize = 16;

/// Approximate resident cost of one cached pair: 20 bytes of payload
/// (u128 tagged key + f32 value) plus hash-table control/load-factor
/// overhead and the FIFO queue slot.  Deliberately conservative so the
/// configured byte budget is an upper bound, not a target to overrun.
pub const ENTRY_BYTES: usize = 32;

/// The cache keys ids into a 32-bit field per side; a scoped handle (or
/// a serve-fleet admission) whose offset + corpus span would leave that
/// range must be rejected with this error — in release builds too —
/// rather than silently aliasing another session's pairs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IdNamespaceError {
    /// First global id of the rejected namespace range.
    pub offset: usize,
    /// Ids the caller needs above `offset` (0: the offset alone is
    /// already out of range).
    pub span: usize,
}

impl fmt::Display for IdNamespaceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "pair-cache id namespace exhausted: offset {} + span {} leaves the \
             32-bit pair-key field",
            self.offset, self.span
        )
    }
}

impl std::error::Error for IdNamespaceError {}

struct Shard {
    map: HashMap<u128, f32>,
    fifo: VecDeque<u128>,
}

/// Per-handle residency ledger for budgeted scoped handles: the keys
/// this handle inserted, oldest first.
struct SessionFifo {
    fifo: VecDeque<u128>,
    budget_entries: usize,
}

/// Sharded, capacity-bounded map `(min_id, max_id) → distance`.
///
/// `Sync`: lookups and inserts take a per-shard mutex; counters are
/// relaxed atomics.  Shared by reference across the distance builder's
/// worker threads and across MAHC iterations — and, via
/// [`PairCache::scoped`], across concurrent serve-mode sessions.
pub struct PairCache {
    shards: Arc<Vec<Mutex<Shard>>>,
    /// Maximum entries per shard (capacity_bytes / ENTRY_BYTES, split
    /// evenly; at least one so the cache is never pathological).
    per_shard: usize,
    /// Added to both segment ids before keying: the id namespace of a
    /// scoped handle (0 for the root cache).
    offset: usize,
    /// Present only on budgeted scoped handles.
    session: Option<Mutex<SessionFifo>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl PairCache {
    /// Cache bounded to roughly `capacity_bytes` of resident distance
    /// state ([`ENTRY_BYTES`] per pair).
    pub fn with_capacity_bytes(capacity_bytes: usize) -> PairCache {
        let total_entries = (capacity_bytes / ENTRY_BYTES).max(SHARDS);
        let per_shard = (total_entries / SHARDS).max(1);
        // Shards grow lazily: the FIFO bound enforces the budget, so
        // preallocating the full capacity would charge the whole byte
        // budget up front even for runs that never fill it.
        let seed_capacity = per_shard.min(1024);
        PairCache {
            shards: Arc::new(
                (0..SHARDS)
                    .map(|_| {
                        Mutex::new(Shard {
                            map: HashMap::with_capacity(seed_capacity),
                            fifo: VecDeque::with_capacity(seed_capacity),
                        })
                    })
                    .collect(),
            ),
            per_shard,
            offset: 0,
            session: None,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// A handle onto the same physical shards, keying ids through
    /// `offset` and (when `budget_bytes` is `Some`) holding this
    /// handle's resident entries to roughly that many bytes.  Counters
    /// start at zero, so `stats()` on the handle is per-session.
    ///
    /// Callers pick offsets so that session id ranges are disjoint
    /// (session *i* gets the running sum of earlier corpus sizes);
    /// `offset + local_id` must stay below 2³², and an offset already
    /// outside that range is rejected here with a typed error — the
    /// guard holds in release builds, unlike the debug assertion on the
    /// per-pair key path.
    pub fn scoped(
        &self,
        offset: usize,
        budget_bytes: Option<usize>,
    ) -> Result<PairCache, IdNamespaceError> {
        if offset >= (1usize << 32) {
            return Err(IdNamespaceError { offset, span: 0 });
        }
        Ok(PairCache {
            shards: Arc::clone(&self.shards),
            per_shard: self.per_shard,
            offset,
            session: budget_bytes.map(|b| {
                Mutex::new(SessionFifo {
                    fifo: VecDeque::new(),
                    budget_entries: (b / ENTRY_BYTES).max(1),
                })
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        })
    }

    /// This handle's id-namespace offset.
    pub fn offset(&self) -> usize {
        self.offset
    }

    /// Symmetric pair key under an id offset: order-free, unique while
    /// offset ids stay below 2³² (validated at [`PairCache::scoped`]
    /// and serve admission; debug-asserted here).
    #[inline]
    fn key_at(offset: usize, a: usize, b: usize) -> u64 {
        debug_assert!(a != b, "diagonal pairs are implicitly zero");
        let (a, b) = (a + offset, b + offset);
        debug_assert!(a < (1 << 32) && b < (1 << 32), "offset segment id exceeds u32");
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        ((lo as u64) << 32) | hi as u64
    }

    /// Full cache key: the kernel tag in the high 64 bits, the
    /// symmetric pair key in the low 64 — so distances computed under
    /// different kernels never alias even in a shared cache.
    #[inline]
    fn key_tagged(tag: u32, offset: usize, a: usize, b: usize) -> u128 {
        ((tag as u128) << 64) | Self::key_at(offset, a, b) as u128
    }

    #[inline]
    fn key(&self, tag: u32, a: usize, b: usize) -> u128 {
        Self::key_tagged(tag, self.offset, a, b)
    }

    #[inline]
    fn shard_of(key: u128) -> usize {
        // SplitMix64-style finaliser: id pairs are highly structured,
        // so fold the tag half in and mix before taking the shard
        // index.
        let folded = (key as u64) ^ ((key >> 64) as u64).wrapping_mul(0xd1b5_4a32_d192_ed03);
        let mut z = folded.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        (z >> 59) as usize % SHARDS
    }

    #[inline]
    fn shard(&self, key: u128) -> &Mutex<Shard> {
        &self.shards[Self::shard_of(key)] // lint: allow(R002) shard_of is a residue mod SHARDS == shards.len()
    }

    /// Look up the distance between segment ids `a` and `b` (in this
    /// handle's namespace) under the default kernel tag 0.
    pub fn get(&self, a: usize, b: usize) -> Option<f32> {
        self.get_tagged(0, a, b)
    }

    /// Look up the distance for `(a, b)` computed under kernel `tag`,
    /// counting the probe as a hit or miss.
    pub fn get_tagged(&self, tag: u32, a: usize, b: usize) -> Option<f32> {
        let key = self.key(tag, a, b);
        // Lock poisoning only means another worker panicked mid-access;
        // shard state is a plain map + FIFO with no torn invariants, so
        // recovering the guard is safe and keeps the cache panic-free.
        let shard = self.shard(key).lock().unwrap_or_else(|p| p.into_inner());
        let found = shard.map.get(&key).copied();
        drop(shard);
        match found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Insert the distance for `(a, b)` under the default kernel tag 0.
    pub fn insert(&self, a: usize, b: usize, v: f32) {
        self.insert_tagged(0, a, b, v)
    }

    /// Insert the distance for `(a, b)` computed under kernel `tag`,
    /// evicting FIFO-oldest entries of the shard when its capacity
    /// share is exhausted — and, on a budgeted handle, this handle's
    /// own oldest entries when its session budget is exhausted.
    /// Re-inserting an existing key overwrites in place (values for a
    /// tagged pair never differ, so this is a no-op in practice).
    pub fn insert_tagged(&self, tag: u32, a: usize, b: usize, v: f32) {
        let key = self.key(tag, a, b);
        let mut newly_inserted = false;
        {
            let mut shard = self.shard(key).lock().unwrap_or_else(|p| p.into_inner());
            if shard.map.insert(key, v).is_none() {
                newly_inserted = true;
                shard.fifo.push_back(key);
                // Session-budget evictions leave their FIFO slot behind
                // (removing from the middle of the queue would be
                // linear); drop any stale prefix so the queue tracks
                // the resident map.
                while let Some(&front) = shard.fifo.front() {
                    if shard.map.contains_key(&front) {
                        break;
                    }
                    shard.fifo.pop_front();
                }
                let mut evicted = 0u64;
                // Two bounds: the resident map obeys the byte budget,
                // and the FIFO (which may still carry stale slots in
                // the middle) stays within 2× so queue memory is
                // bounded even under heavy session churn.  Without
                // scoped handles the FIFO never goes stale and this is
                // exactly the classic `len > per_shard` FIFO eviction.
                while shard.map.len() > self.per_shard
                    || shard.fifo.len() > self.per_shard.saturating_mul(2)
                {
                    match shard.fifo.pop_front() {
                        Some(old) => {
                            if shard.map.remove(&old).is_some() {
                                evicted += 1;
                            }
                        }
                        None => break,
                    }
                }
                drop(shard);
                if evicted > 0 {
                    self.evictions.fetch_add(evicted, Ordering::Relaxed);
                }
            }
        }
        if !newly_inserted {
            return;
        }
        if let Some(session) = &self.session {
            // Lock order is always session → shard (the insert above
            // released its shard guard), so budget eviction cannot
            // deadlock against concurrent get/insert on any handle.
            let mut own = session.lock().unwrap_or_else(|p| p.into_inner());
            own.fifo.push_back(key);
            let mut evicted = 0u64;
            while own.fifo.len() > own.budget_entries {
                match own.fifo.pop_front() {
                    Some(old) => {
                        let mut s = self.shard(old).lock().unwrap_or_else(|p| p.into_inner());
                        if s.map.remove(&old).is_some() {
                            evicted += 1;
                        }
                    }
                    None => break,
                }
            }
            drop(own);
            if evicted > 0 {
                self.evictions.fetch_add(evicted, Ordering::Relaxed);
            }
        }
    }

    /// Number of resident pairs across the whole shared cache (all
    /// handles' entries).
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap_or_else(|p| p.into_inner()).map.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Maximum resident pairs across all shards.
    pub fn capacity_entries(&self) -> usize {
        self.per_shard * SHARDS
    }

    /// Approximate resident bytes across the whole shared cache
    /// ([`ENTRY_BYTES`] accounting).
    pub fn bytes(&self) -> usize {
        self.len() * ENTRY_BYTES
    }

    /// Pairs inserted by *this handle* that are still resident.  On the
    /// root (unbudgeted) handle this is just [`PairCache::len`].
    /// Prunes the handle's ledger of entries that global FIFO pressure
    /// or shard churn already displaced.
    pub fn session_resident(&self) -> usize {
        match &self.session {
            None => self.len(),
            Some(session) => {
                let mut own = session.lock().unwrap_or_else(|p| p.into_inner());
                let mut seen = std::collections::HashSet::new();
                own.fifo.retain(|k| {
                    let resident = self
                        .shard(*k)
                        .lock()
                        .unwrap_or_else(|p| p.into_inner())
                        .map
                        .contains_key(k);
                    resident && seen.insert(*k)
                });
                own.fifo.len()
            }
        }
    }

    /// Approximate resident bytes attributable to this handle.
    pub fn session_bytes(&self) -> usize {
        self.session_resident() * ENTRY_BYTES
    }

    /// This handle's residency budget in entries, if budgeted.
    pub fn session_budget_entries(&self) -> Option<usize> {
        self.session
            .as_ref()
            .map(|s| s.lock().unwrap_or_else(|p| p.into_inner()).budget_entries)
    }

    /// Cumulative counters since this handle was created (per-handle:
    /// a scoped handle starts from zero even though storage is shared).
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// Drop every entry in the shared storage (counters are preserved;
    /// other handles' ledgers are pruned lazily on their next use).
    pub fn clear(&self) {
        for s in self.shards.iter() {
            let mut s = s.lock().unwrap_or_else(|p| p.into_inner());
            s.map.clear();
            s.fifo.clear();
        }
        if let Some(session) = &self.session {
            session
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .fifo
                .clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_insert_round_trip_and_symmetry() {
        let c = PairCache::with_capacity_bytes(1 << 20);
        assert_eq!(c.get(3, 9), None);
        c.insert(3, 9, 1.25);
        assert_eq!(c.get(3, 9), Some(1.25));
        assert_eq!(c.get(9, 3), Some(1.25), "key is order-free");
        let s = c.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
        assert_eq!(s.evictions, 0);
    }

    #[test]
    fn capacity_bound_is_enforced() {
        // Tiny budget: SHARDS entries minimum, one per shard.
        let c = PairCache::with_capacity_bytes(1);
        assert_eq!(c.capacity_entries(), SHARDS);
        for i in 0..1000usize {
            c.insert(i, i + 1000, i as f32);
        }
        assert!(c.len() <= c.capacity_entries());
        assert!(c.stats().evictions >= 1000 - SHARDS as u64);
        assert!(c.bytes() <= c.capacity_entries() * ENTRY_BYTES);
    }

    #[test]
    fn eviction_is_fifo_within_a_shard() {
        let c = PairCache::with_capacity_bytes(1);
        // Find two keys landing in the same shard; inserting per_shard+1
        // of them must evict the oldest.
        let base = PairCache::shard_of(PairCache::key_tagged(0, 0, 0, 1_000_000));
        let mut same: Vec<usize> = Vec::new();
        let mut i = 0usize;
        while same.len() < 2 {
            if PairCache::shard_of(PairCache::key_tagged(0, 0, i, i + 1_000_000)) == base {
                same.push(i);
            }
            i += 1;
        }
        c.insert(same[0], same[0] + 1_000_000, 1.0);
        c.insert(same[1], same[1] + 1_000_000, 2.0);
        // per_shard == 1 here: the first insert was displaced.
        assert_eq!(c.get(same[0], same[0] + 1_000_000), None);
        assert_eq!(c.get(same[1], same[1] + 1_000_000), Some(2.0));
    }

    #[test]
    fn reinsert_does_not_duplicate_fifo_slots() {
        let c = PairCache::with_capacity_bytes(1 << 20);
        for _ in 0..100 {
            c.insert(1, 2, 0.5);
        }
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn concurrent_use_is_safe_and_consistent() {
        let c = PairCache::with_capacity_bytes(1 << 20);
        std::thread::scope(|scope| {
            for t in 0..4usize {
                let c = &c;
                scope.spawn(move || {
                    for i in 0..500usize {
                        let (a, b) = (i, i + 10_000);
                        c.insert(a, b, (a + b) as f32);
                        assert_eq!(c.get(a, b), Some((a + b) as f32));
                        let _ = t;
                    }
                });
            }
        });
        assert_eq!(c.len(), 500);
    }

    #[test]
    fn clear_preserves_counters() {
        let c = PairCache::with_capacity_bytes(1 << 20);
        c.insert(1, 2, 3.0);
        let _ = c.get(1, 2);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.get(1, 2), None);
        assert_eq!(c.stats().hits, 1);
        assert_eq!(c.stats().misses, 1);
    }

    #[test]
    fn scoped_handles_namespace_local_ids() {
        let root = PairCache::with_capacity_bytes(1 << 20);
        let a = root.scoped(0, None).unwrap();
        let b = root.scoped(100, None).unwrap();
        // Same local pair, different namespaces, different corpora.
        a.insert(0, 1, 1.0);
        b.insert(0, 1, 2.0);
        assert_eq!(a.get(0, 1), Some(1.0));
        assert_eq!(b.get(0, 1), Some(2.0));
        assert_eq!(root.len(), 2, "two distinct shared entries");
        // A same-offset handle sees the other's entries (shared shards).
        assert_eq!(root.get(0, 1), Some(1.0));
    }

    #[test]
    fn scoped_counters_are_per_handle() {
        let root = PairCache::with_capacity_bytes(1 << 20);
        root.insert(1, 2, 0.5);
        let _ = root.get(1, 2);
        let before = root.stats();
        let s = root.scoped(0, None).unwrap();
        assert_eq!(s.get(1, 2), Some(0.5));
        assert_eq!(s.get(7, 8), None);
        let ss = s.stats();
        assert_eq!((ss.hits, ss.misses), (1, 1), "handle counts its own probes");
        let after = root.stats();
        assert_eq!(after.hits, before.hits, "root counters untouched by handle");
        assert_eq!(after.misses, before.misses);
    }

    #[test]
    fn session_budget_bounds_handle_residency() {
        let root = PairCache::with_capacity_bytes(1 << 20);
        let s = root.scoped(0, Some(2 * ENTRY_BYTES)).unwrap();
        assert_eq!(s.session_budget_entries(), Some(2));
        for i in 0..10usize {
            s.insert(i, i + 100, i as f32);
        }
        assert!(s.session_resident() <= 2, "budget caps resident entries");
        assert_eq!(root.len(), s.session_resident(), "only inserter is the handle");
        assert!(s.stats().evictions >= 8, "oldest entries were displaced");
        // The newest insert is still resident.
        assert_eq!(s.get(9, 109), Some(9.0));
    }

    #[test]
    fn budget_churn_keeps_shared_fifo_bounded() {
        // A tiny shared cache plus a heavily churning budgeted session:
        // stale FIFO slots from session evictions must not break the
        // global bound or leak queue memory.
        let root = PairCache::with_capacity_bytes(1);
        let s = root.scoped(0, Some(ENTRY_BYTES)).unwrap(); // one-entry budget
        for i in 0..2000usize {
            s.insert(i, i + 5_000, i as f32);
        }
        assert!(root.len() <= root.capacity_entries());
        assert!(s.session_resident() <= 1);
        for shard in root.shards.iter() {
            let g = shard.lock().unwrap();
            assert!(
                g.fifo.len() <= root.per_shard * 2,
                "stale slots pruned: fifo {} > 2*per_shard {}",
                g.fifo.len(),
                root.per_shard
            );
        }
        // The shared cache still works for other handles afterwards.
        root.insert(1, 3, 0.25);
        assert_eq!(root.get(1, 3), Some(0.25));
    }

    #[test]
    fn kernel_tags_partition_the_key_space() {
        // Same pair, different kernel tags: both values stay resident
        // and each probe sees only its own kernel's distance.
        let c = PairCache::with_capacity_bytes(1 << 20);
        c.insert_tagged(0, 3, 9, 1.0);
        c.insert_tagged(1, 3, 9, 2.0);
        c.insert_tagged(7, 3, 9, 3.0);
        assert_eq!(c.get_tagged(0, 3, 9), Some(1.0));
        assert_eq!(c.get_tagged(1, 9, 3), Some(2.0), "tagged key stays order-free");
        assert_eq!(c.get_tagged(7, 3, 9), Some(3.0));
        assert_eq!(c.get_tagged(2, 3, 9), None, "unseen tag misses");
        assert_eq!(c.len(), 3, "tags are distinct entries");
        // The untagged API is exactly tag 0.
        assert_eq!(c.get(3, 9), Some(1.0));
    }

    #[test]
    fn scoped_rejects_offsets_outside_the_id_field() {
        let root = PairCache::with_capacity_bytes(1 << 20);
        assert!(root.scoped((1usize << 32) - 1, None).is_ok());
        let err = root.scoped(1usize << 32, None).unwrap_err();
        assert_eq!(err.offset, 1usize << 32);
        assert!(err.to_string().contains("id namespace exhausted"));
    }

    #[test]
    fn concurrent_budgeted_sessions_stay_disjoint() {
        let root = PairCache::with_capacity_bytes(1 << 20);
        std::thread::scope(|scope| {
            for t in 0..4usize {
                let s = root.scoped(t * 10_000, Some(64 * ENTRY_BYTES)).unwrap();
                scope.spawn(move || {
                    for i in 0..300usize {
                        s.insert(i, i + 1_000, (t * 10_000 + i) as f32);
                    }
                    // The 64 newest of this session's entries survive;
                    // every surviving value is this session's own.
                    assert!(s.session_resident() <= 64);
                    for i in 0..300usize {
                        if let Some(v) = s.get(i, i + 1_000) {
                            assert_eq!(v, (t * 10_000 + i) as f32);
                        }
                    }
                });
            }
        });
        assert!(root.len() <= 4 * 64);
    }
}
