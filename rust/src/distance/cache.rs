//! Cross-iteration DTW pair-distance cache.
//!
//! The MAHC refine step deliberately keeps stage-1 cluster members
//! together, so the vast majority of within-subset segment pairs recur
//! from one iteration to the next (and medoid pairs recur in stage 2) —
//! yet the driver used to recompute every condensed matrix from
//! scratch.  [`PairCache`] closes that gap: a sharded, capacity-bounded
//! map from global segment-id pairs `(min, max)` to their DTW distance,
//! sitting *above* the [`super::DtwBackend`] trait so both the native
//! DP and the XLA tile executor benefit.
//!
//! The capacity bound is the time-side companion of the paper's space
//! bound: β caps any single resident condensed matrix at
//! β(β−1)/2 · 4 bytes, and `capacity_bytes` caps the resident
//! cross-iteration distance pool, so total distance memory stays
//! thresholded in the same spirit (see EXPERIMENTS.md §Perf for the
//! measured budget/hit-rate trade-off).  Eviction is per-shard FIFO —
//! deterministic in insertion order and cheap; because cached values
//! equal the values the backend would recompute, *results are bitwise
//! identical to the uncached path regardless of hit or eviction
//! pattern* (asserted by `rust/tests/cache_determinism.rs` for the
//! native backend, whose per-pair results are independent of call
//! batching).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::telemetry::CacheStats;

/// Shards: enough to keep worker threads from serialising on one lock,
/// few enough that the per-shard FIFO stays cache-friendly.
const SHARDS: usize = 16;

/// Approximate resident cost of one cached pair: 12 bytes of payload
/// (u64 key + f32 value) plus hash-table control/load-factor overhead
/// and the FIFO queue slot.  Deliberately conservative so the
/// configured byte budget is an upper bound, not a target to overrun.
pub const ENTRY_BYTES: usize = 32;

struct Shard {
    map: HashMap<u64, f32>,
    fifo: VecDeque<u64>,
}

/// Sharded, capacity-bounded map `(min_id, max_id) → distance`.
///
/// `Sync`: lookups and inserts take a per-shard mutex; counters are
/// relaxed atomics.  Shared by reference across the distance builder's
/// worker threads and across MAHC iterations.
pub struct PairCache {
    shards: Vec<Mutex<Shard>>,
    /// Maximum entries per shard (capacity_bytes / ENTRY_BYTES, split
    /// evenly; at least one so the cache is never pathological).
    per_shard: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl PairCache {
    /// Cache bounded to roughly `capacity_bytes` of resident distance
    /// state ([`ENTRY_BYTES`] per pair).
    pub fn with_capacity_bytes(capacity_bytes: usize) -> PairCache {
        let total_entries = (capacity_bytes / ENTRY_BYTES).max(SHARDS);
        let per_shard = (total_entries / SHARDS).max(1);
        // Shards grow lazily: the FIFO bound enforces the budget, so
        // preallocating the full capacity would charge the whole byte
        // budget up front even for runs that never fill it.
        let seed_capacity = per_shard.min(1024);
        PairCache {
            shards: (0..SHARDS)
                .map(|_| {
                    Mutex::new(Shard {
                        map: HashMap::with_capacity(seed_capacity),
                        fifo: VecDeque::with_capacity(seed_capacity),
                    })
                })
                .collect(),
            per_shard,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Symmetric pair key: order-free, unique for ids < 2³².
    #[inline]
    fn key(a: usize, b: usize) -> u64 {
        debug_assert!(a != b, "diagonal pairs are implicitly zero");
        debug_assert!(a < (1 << 32) && b < (1 << 32), "segment id exceeds u32");
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        ((lo as u64) << 32) | hi as u64
    }

    #[inline]
    fn shard_of(key: u64) -> usize {
        // SplitMix64-style finaliser: id pairs are highly structured,
        // so mix before taking the shard index.
        let mut z = key.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        (z >> 59) as usize % SHARDS
    }

    /// Look up the distance between global segment ids `a` and `b`,
    /// counting the probe as a hit or miss.
    pub fn get(&self, a: usize, b: usize) -> Option<f32> {
        let key = Self::key(a, b);
        // Lock poisoning only means another worker panicked mid-access;
        // shard state is a plain map + FIFO with no torn invariants, so
        // recovering the guard is safe and keeps the cache panic-free.
        let shard = self.shards[Self::shard_of(key)]
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        let found = shard.map.get(&key).copied();
        drop(shard);
        match found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Insert the distance for `(a, b)`, evicting FIFO-oldest entries
    /// of the shard when its capacity share is exhausted.  Re-inserting
    /// an existing key overwrites in place (values for a pair never
    /// differ, so this is a no-op in practice).
    pub fn insert(&self, a: usize, b: usize, v: f32) {
        let key = Self::key(a, b);
        let mut shard = self.shards[Self::shard_of(key)]
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        if shard.map.insert(key, v).is_none() {
            shard.fifo.push_back(key);
            let mut evicted = 0u64;
            while shard.fifo.len() > self.per_shard {
                if let Some(old) = shard.fifo.pop_front() {
                    shard.map.remove(&old);
                    evicted += 1;
                }
            }
            drop(shard);
            if evicted > 0 {
                self.evictions.fetch_add(evicted, Ordering::Relaxed);
            }
        }
    }

    /// Number of resident pairs.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap_or_else(|p| p.into_inner()).map.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Maximum resident pairs across all shards.
    pub fn capacity_entries(&self) -> usize {
        self.per_shard * SHARDS
    }

    /// Approximate resident bytes ([`ENTRY_BYTES`] accounting).
    pub fn bytes(&self) -> usize {
        self.len() * ENTRY_BYTES
    }

    /// Cumulative counters since construction.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// Drop every entry (counters are preserved).
    pub fn clear(&self) {
        for s in &self.shards {
            let mut s = s.lock().unwrap_or_else(|p| p.into_inner());
            s.map.clear();
            s.fifo.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_insert_round_trip_and_symmetry() {
        let c = PairCache::with_capacity_bytes(1 << 20);
        assert_eq!(c.get(3, 9), None);
        c.insert(3, 9, 1.25);
        assert_eq!(c.get(3, 9), Some(1.25));
        assert_eq!(c.get(9, 3), Some(1.25), "key is order-free");
        let s = c.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
        assert_eq!(s.evictions, 0);
    }

    #[test]
    fn capacity_bound_is_enforced() {
        // Tiny budget: SHARDS entries minimum, one per shard.
        let c = PairCache::with_capacity_bytes(1);
        assert_eq!(c.capacity_entries(), SHARDS);
        for i in 0..1000usize {
            c.insert(i, i + 1000, i as f32);
        }
        assert!(c.len() <= c.capacity_entries());
        assert!(c.stats().evictions >= 1000 - SHARDS as u64);
        assert!(c.bytes() <= c.capacity_entries() * ENTRY_BYTES);
    }

    #[test]
    fn eviction_is_fifo_within_a_shard() {
        let c = PairCache::with_capacity_bytes(1);
        // Find two keys landing in the same shard; inserting per_shard+1
        // of them must evict the oldest.
        let base = PairCache::shard_of(PairCache::key(0, 1_000_000));
        let mut same: Vec<usize> = Vec::new();
        let mut i = 0usize;
        while same.len() < 2 {
            if PairCache::shard_of(PairCache::key(i, i + 1_000_000)) == base {
                same.push(i);
            }
            i += 1;
        }
        c.insert(same[0], same[0] + 1_000_000, 1.0);
        c.insert(same[1], same[1] + 1_000_000, 2.0);
        // per_shard == 1 here: the first insert was displaced.
        assert_eq!(c.get(same[0], same[0] + 1_000_000), None);
        assert_eq!(c.get(same[1], same[1] + 1_000_000), Some(2.0));
    }

    #[test]
    fn reinsert_does_not_duplicate_fifo_slots() {
        let c = PairCache::with_capacity_bytes(1 << 20);
        for _ in 0..100 {
            c.insert(1, 2, 0.5);
        }
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn concurrent_use_is_safe_and_consistent() {
        let c = PairCache::with_capacity_bytes(1 << 20);
        std::thread::scope(|scope| {
            for t in 0..4usize {
                let c = &c;
                scope.spawn(move || {
                    for i in 0..500usize {
                        let (a, b) = (i, i + 10_000);
                        c.insert(a, b, (a + b) as f32);
                        assert_eq!(c.get(a, b), Some((a + b) as f32));
                        let _ = t;
                    }
                });
            }
        });
        assert_eq!(c.len(), 500);
    }

    #[test]
    fn clear_preserves_counters() {
        let c = PairCache::with_capacity_bytes(1 << 20);
        c.insert(1, 2, 3.0);
        let _ = c.get(1, 2);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.get(1, 2), None);
        assert_eq!(c.stats().hits, 1);
        assert_eq!(c.stats().misses, 1);
    }
}
