//! Formant-style waveform synthesis for the end-to-end audio path.
//!
//! The end-to-end example must exercise the full pipeline including the
//! AOT MFCC front-end, which needs raw audio.  Segments are rendered as
//! a sum of three "formant" sinusoids whose frequencies follow the
//! class's prototype trajectory (mapping the first feature dimensions
//! to formant positions), with continuous phase across frames so the
//! signal is free of frame-boundary clicks.  This is not a speech
//! synthesiser — it is the minimal signal family whose MFCCs vary
//! smoothly with the underlying trajectory, which is exactly the
//! property the clustering pipeline consumes.

use super::generator::TriphoneClass;
use crate::util::rng::Rng;

pub const SAMPLE_RATE: usize = 16_000;
pub const FRAME_HOP: usize = 80; // matches the MFCC front-end
pub const FRAME_LEN: usize = 160;

/// Map a feature value (roughly N(0, 2²)) into a formant band.
fn to_freq(v: f64, lo: f64, hi: f64) -> f64 {
    // Squash to (0, 1) then scale; tanh keeps outliers in-band.
    let u = 0.5 * ((v / 4.0).tanh() + 1.0);
    lo + u * (hi - lo)
}

/// Samples needed for `frames` analysis frames.
pub fn num_samples(frames: usize) -> usize {
    FRAME_LEN + frames.saturating_sub(1) * FRAME_HOP
}

/// Render `len` frames of audio following the prototype of `class`,
/// time-warped the same way the feature instance was (positions in
/// [0,1] per frame), with additive noise.
pub fn render(
    class: &TriphoneClass,
    positions: &[f64],
    noise: f64,
    rng: &mut Rng,
) -> Vec<f64> {
    let len = positions.len();
    let n = num_samples(len);
    let mut wav = vec![0.0f64; n];
    // Three formant oscillators with continuous phase.
    let bands = [(250.0, 900.0), (900.0, 2400.0), (2400.0, 3800.0)];
    let amps = [1.0, 0.6, 0.35];
    for (f_idx, (&(lo, hi), &amp)) in bands.iter().zip(&amps).enumerate() {
        let mut phase = rng.uniform(0.0, 2.0 * std::f64::consts::PI);
        for t in 0..n {
            // Which analysis frame does this sample belong to (centre)?
            let frame = (t / FRAME_HOP).min(len - 1);
            let u = positions[frame];
            let x = u * (class.proto_len - 1) as f64;
            let i0 = x.floor() as usize;
            let i1 = (i0 + 1).min(class.proto_len - 1);
            let frac = x - i0 as f64;
            let dim = class.dim;
            let d = f_idx.min(dim - 1);
            let v = class.proto[i0 * dim + d] * (1.0 - frac) + class.proto[i1 * dim + d] * frac;
            let freq = to_freq(v, lo, hi);
            phase += 2.0 * std::f64::consts::PI * freq / SAMPLE_RATE as f64;
            wav[t] += amp * phase.sin();
        }
    }
    for v in wav.iter_mut() {
        *v = *v * 0.2 + rng.normal() * noise;
    }
    wav
}

/// Uniform warp positions for a `len`-frame instance (linear map).
pub fn linear_positions(len: usize) -> Vec<f64> {
    (0..len)
        .map(|t| t as f64 / (len - 1).max(1) as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp;

    fn test_class() -> TriphoneClass {
        let dim = 4;
        let proto_len = 12;
        let mut proto = Vec::new();
        for t in 0..proto_len {
            for d in 0..dim {
                proto.push((t as f64 / 11.0) * 2.0 - 1.0 + d as f64 * 0.1);
            }
        }
        TriphoneClass {
            name: "t-t+t".into(),
            proto,
            proto_len,
            dim,
        }
    }

    #[test]
    fn sample_count_matches_frames() {
        assert_eq!(num_samples(1), 160);
        assert_eq!(num_samples(64), 5200);
    }

    #[test]
    fn renders_finite_audio_of_right_length() {
        let mut rng = Rng::seed_from(1);
        let c = test_class();
        let wav = render(&c, &linear_positions(20), 0.01, &mut rng);
        assert_eq!(wav.len(), num_samples(20));
        assert!(wav.iter().all(|v| v.is_finite()));
        // Non-silent.
        assert!(wav.iter().map(|v| v * v).sum::<f64>() > 1.0);
    }

    #[test]
    fn mfcc_of_rendered_audio_tracks_trajectory() {
        // Same class rendered twice -> MFCCs closer than a different
        // trajectory (the property the end-to-end path needs).
        let c = test_class();
        let mut other = test_class();
        for v in other.proto.iter_mut() {
            *v = -*v + 3.0;
        }
        let mut rng = Rng::seed_from(2);
        let pos = linear_positions(24);
        let a = dsp::mfcc(&render(&c, &pos, 0.005, &mut rng));
        let b = dsp::mfcc(&render(&c, &pos, 0.005, &mut rng));
        let o = dsp::mfcc(&render(&other, &pos, 0.005, &mut rng));
        let dist = |x: &Vec<Vec<f64>>, y: &Vec<Vec<f64>>| {
            x.iter()
                .zip(y)
                .map(|(fx, fy)| {
                    fx[..12]
                        .iter()
                        .zip(&fy[..12])
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum::<f64>()
                        .sqrt()
                })
                .sum::<f64>()
        };
        let same = dist(&a, &b);
        let diff = dist(&a, &o);
        assert!(same < diff, "same {same:.2} !< diff {diff:.2}");
    }
}
