//! Composition statistics: the Table 1 row and Fig. 3 histogram for a
//! generated dataset.

use super::dataset::SegmentSet;

/// Summary of a dataset's composition (one Table 1 row).
#[derive(Debug, Clone)]
pub struct CompositionStats {
    pub name: String,
    pub segments: usize,
    pub classes: usize,
    /// (min, max) class cardinality — Table 1 "Frequency".
    pub freq_range: (usize, usize),
    /// Total feature vectors.
    pub vectors: usize,
    /// N(N−1)/2 similarities full AHC would need.
    pub similarities: u64,
    /// Per-class cardinalities (Fig. 3 histogram source), descending.
    pub class_sizes: Vec<usize>,
}

impl CompositionStats {
    pub fn of(set: &SegmentSet) -> CompositionStats {
        let mut counts = vec![0usize; set.num_classes];
        for s in &set.segments {
            counts[s.class_id] += 1;
        }
        let mut sorted = counts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let min = *counts.iter().min().unwrap_or(&0);
        let max = *counts.iter().max().unwrap_or(&0);
        CompositionStats {
            name: set.name.clone(),
            segments: set.len(),
            classes: set.num_classes,
            freq_range: (min, max),
            vectors: set.total_vectors(),
            similarities: set.total_similarities(),
            class_sizes: sorted,
        }
    }

    /// Table-1-style row: name, segments, classes, freq, vectors, sims.
    pub fn table_row(&self) -> String {
        format!(
            "{:<12} {:>9} {:>8} {:>6}-{:<6} {:>10} {:>14}",
            self.name,
            self.segments,
            self.classes,
            self.freq_range.0,
            self.freq_range.1,
            self.vectors,
            self.similarities
        )
    }

    /// Histogram of class sizes with `bins` buckets (Fig. 3 series):
    /// returns (bucket upper edge, class count) pairs.
    pub fn size_histogram(&self, bins: usize) -> Vec<(usize, usize)> {
        if self.class_sizes.is_empty() {
            return Vec::new();
        }
        let max = self.class_sizes[0].max(1);
        let width = (max + bins - 1) / bins;
        let mut hist = vec![0usize; bins];
        for &s in &self.class_sizes {
            let b = ((s.saturating_sub(1)) / width.max(1)).min(bins - 1);
            hist[b] += 1;
        }
        hist.iter()
            .enumerate()
            .map(|(i, &c)| ((i + 1) * width.max(1), c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetSpec;
    use crate::corpus::generate;

    #[test]
    fn stats_consistent_with_set() {
        let set = generate(&DatasetSpec::tiny(100, 6, 3));
        let st = CompositionStats::of(&set);
        assert_eq!(st.segments, 100);
        assert_eq!(st.classes, 6);
        assert_eq!(st.class_sizes.iter().sum::<usize>(), 100);
        assert_eq!(st.similarities, 100 * 99 / 2);
        assert!(st.freq_range.0 <= st.freq_range.1);
        assert_eq!(st.vectors, set.total_vectors());
    }

    #[test]
    fn histogram_partitions_classes() {
        let set = generate(&DatasetSpec::tiny(200, 10, 4));
        let st = CompositionStats::of(&set);
        let hist = st.size_histogram(5);
        assert_eq!(hist.len(), 5);
        assert_eq!(hist.iter().map(|&(_, c)| c).sum::<usize>(), 10);
    }

    #[test]
    fn class_sizes_sorted_descending() {
        let set = generate(&DatasetSpec::tiny(150, 7, 5));
        let st = CompositionStats::of(&set);
        for w in st.class_sizes.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }
}
