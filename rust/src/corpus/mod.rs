//! Synthetic TIMIT-like acoustic segment corpus.
//!
//! TIMIT itself is licensed and unavailable in this environment, so the
//! corpus is *simulated* (DESIGN.md §5): a 42-phone inventory
//! ([`phones`]), triphone classes whose prototype trajectories move
//! through feature space from the left-context phone towards the centre
//! and on to the right context ([`generator`]), instance-level time
//! warping / duration jitter / additive noise, and skew-controlled
//! class cardinalities that reproduce the Small A vs Small B contrast
//! of paper Fig. 3.  The properties MAHC's dynamics depend on —
//! variable-length sequences, DTW-recoverable class structure, skewed
//! class sizes — are all explicit, controlled parameters.
//!
//! [`waveform`] additionally synthesises formant-style audio per
//! segment so the end-to-end example can exercise the AOT MFCC
//! front-end; [`stats`] computes the Table-1/Fig-3 composition
//! summaries; [`shards`] presents a corpus as a bounded stream of id
//! batches for the streaming driver.

//! [`embedding`] generates fixed-dimensional embedding corpora
//! (single-frame segments) for the cosine/Euclidean vector metrics,
//! including a diarization-style scenario with an unknown speaker
//! count.

pub mod dataset;
pub mod embedding;
pub mod generator;
pub mod phones;
pub mod shards;
pub mod stats;
pub mod waveform;

pub use dataset::{Segment, SegmentSet};
pub use embedding::{diarization, generate_embeddings, DiarizationSpec, EmbeddingSpec};
pub use generator::generate;
pub use shards::Shards;
pub use stats::CompositionStats;
