//! Shard iterator: present a corpus as a bounded stream of id batches.
//!
//! The streaming driver ([`crate::mahc::streaming`]) consumes a corpus
//! shard by shard instead of all at once.  [`Shards`] yields successive
//! id batches of at most `shard_size` segments, either in corpus order
//! (`seed = None`, the arrival order of a real stream) or over a seeded
//! shuffle (`seed = Some(_)`, which simulates an order-randomised stream
//! for ablations).  Every id appears in exactly one shard; the final
//! shard may be short.

use crate::util::rng::Rng;

/// Iterator over id shards of a corpus of `n` segments.
#[derive(Debug, Clone)]
pub struct Shards {
    order: Vec<usize>,
    shard_size: usize,
    at: usize,
}

impl Shards {
    /// Plan a shard sequence over ids `0..n`.  `shard_size` is clamped
    /// to at least 1; `seed` shuffles the stream order when given.
    pub fn new(n: usize, shard_size: usize, seed: Option<u64>) -> Shards {
        let mut order: Vec<usize> = (0..n).collect();
        if let Some(s) = seed {
            Rng::seed_from(s).shuffle(&mut order);
        }
        Shards {
            order,
            shard_size: shard_size.max(1),
            at: 0,
        }
    }

    /// Total number of shards this plan yields.
    pub fn total(&self) -> usize {
        self.order.len().div_ceil(self.shard_size)
    }

    /// Shards still to be yielded.
    pub fn remaining(&self) -> usize {
        (self.order.len() - self.at).div_ceil(self.shard_size)
    }
}

impl Iterator for Shards {
    type Item = Vec<usize>;

    fn next(&mut self) -> Option<Vec<usize>> {
        if self.at >= self.order.len() {
            return None;
        }
        let end = (self.at + self.shard_size).min(self.order.len());
        let shard = self.order[self.at..end].to_vec();
        self.at = end;
        Some(shard)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_id_exactly_once() {
        for seed in [None, Some(7u64)] {
            let shards: Vec<Vec<usize>> = Shards::new(103, 25, seed).collect();
            assert_eq!(shards.len(), 5);
            let mut all: Vec<usize> = shards.concat();
            all.sort_unstable();
            assert_eq!(all, (0..103).collect::<Vec<_>>());
        }
    }

    #[test]
    fn unseeded_preserves_corpus_order() {
        let shards: Vec<Vec<usize>> = Shards::new(10, 4, None).collect();
        assert_eq!(
            shards,
            vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7], vec![8, 9]]
        );
    }

    #[test]
    fn seeded_order_is_deterministic_and_shuffled() {
        let a: Vec<Vec<usize>> = Shards::new(64, 16, Some(3)).collect();
        let b: Vec<Vec<usize>> = Shards::new(64, 16, Some(3)).collect();
        assert_eq!(a, b);
        let c: Vec<Vec<usize>> = Shards::new(64, 16, None).collect();
        assert_ne!(a, c, "seeded stream must differ from corpus order");
    }

    #[test]
    fn counts_and_degenerate_sizes() {
        let plan = Shards::new(10, 100, None);
        assert_eq!(plan.total(), 1);
        // shard_size == n is the exact single-shard boundary, and a
        // unit shard size yields one id per shard in order.
        let exact: Vec<Vec<usize>> = Shards::new(10, 10, None).collect();
        assert_eq!(exact, vec![(0..10).collect::<Vec<_>>()]);
        let unit: Vec<Vec<usize>> = Shards::new(4, 1, None).collect();
        assert_eq!(unit, vec![vec![0], vec![1], vec![2], vec![3]]);
        let plan = Shards::new(0, 5, None);
        assert_eq!(plan.total(), 0);
        assert_eq!(plan.collect::<Vec<_>>().len(), 0);
        // shard_size 0 is clamped to 1 rather than looping forever.
        let plan = Shards::new(3, 0, None);
        assert_eq!(plan.total(), 3);
        let mut plan = Shards::new(7, 3, Some(1));
        assert_eq!(plan.remaining(), 3);
        plan.next();
        assert_eq!(plan.remaining(), 2);
    }
}
