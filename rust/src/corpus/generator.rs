//! Triphone class construction and segment instance sampling.
//!
//! A *class* is a triphone (left, centre, right): its prototype
//! trajectory starts at a blend of left-context and centre targets,
//! dwells at the centre phone's target, and exits towards the right
//! context — a coarse coarticulation model that gives DTW real temporal
//! structure to align.  *Instances* of a class are monotone time-warps
//! of the prototype with duration jitter, additive noise, and a small
//! per-instance offset (a speaker-like effect).
//!
//! Class cardinalities follow a Zipf(skew) draw floored at
//! `min_class_size`, reproducing the Small-A/Small-B skew contrast of
//! paper Fig. 3 (skew = 0 gives the flat Small-B shape).

use super::dataset::{Segment, SegmentSet};
use super::phones::{inventory, Phone};
use crate::config::DatasetSpec;
use crate::util::rng::{Rng, Zipf};

/// How far apart phone targets sit (feature-space units).
const TARGET_SPREAD: f64 = 2.0;
/// Per-frame additive noise on instances.
const NOISE_STD: f64 = 0.55;
/// Per-instance constant offset ("speaker" shift).
const SPEAKER_STD: f64 = 0.25;
/// Smoothing of the prototype random walk.
const WALK_STD: f64 = 0.18;

/// A triphone class: prototype trajectory plus its cardinality.
#[derive(Debug, Clone)]
pub struct TriphoneClass {
    pub name: String,
    /// Prototype trajectory, (proto_len, dim) row-major f64.
    pub proto: Vec<f64>,
    pub proto_len: usize,
    pub dim: usize,
}

/// Generate a full [`SegmentSet`] from a [`DatasetSpec`].
pub fn generate(spec: &DatasetSpec) -> SegmentSet {
    let mut rng = Rng::seed_from(spec.seed);
    let phones = inventory(spec.feat_dim, spec.seed, TARGET_SPREAD);
    let classes = build_classes(spec, &phones, &mut rng);
    let counts = class_cardinalities(spec, &mut rng);

    let mut segments = Vec::with_capacity(spec.segments);
    for (class_id, (class, &count)) in classes.iter().zip(&counts).enumerate() {
        for _ in 0..count {
            let id = segments.len();
            segments.push(sample_instance(id, class_id, class, spec, &mut rng));
        }
    }
    // Interleave classes so contiguous id ranges are not single-class
    // (initial MAHC partitions slice by position).
    rng.shuffle(&mut segments);
    for (i, s) in segments.iter_mut().enumerate() {
        s.id = i;
    }

    let set = SegmentSet {
        name: spec.name.clone(),
        dim: spec.feat_dim,
        segments,
        num_classes: classes.len(),
    };
    debug_assert!(set.validate().is_ok());
    set
}

/// Build `spec.classes` distinct triphone classes.
fn build_classes(spec: &DatasetSpec, phones: &[Phone], rng: &mut Rng) -> Vec<TriphoneClass> {
    let mut used = std::collections::HashSet::new();
    let mut classes = Vec::with_capacity(spec.classes);
    while classes.len() < spec.classes {
        let l = rng.range(0, phones.len());
        let c = rng.range(0, phones.len());
        let r = rng.range(0, phones.len());
        if !used.insert((l, c, r)) {
            continue; // triphone already taken
        }
        classes.push(build_prototype(&phones[l], &phones[c], &phones[r], spec, rng));
    }
    classes
}

/// Prototype: left-blend → centre dwell → right-blend, plus a smooth
/// random walk so no two classes sharing a centre phone are identical.
fn build_prototype(
    left: &Phone,
    centre: &Phone,
    right: &Phone,
    spec: &DatasetSpec,
    rng: &mut Rng,
) -> TriphoneClass {
    let dim = spec.feat_dim;
    let (dlo, dhi) = centre.class.duration_frames();
    // Prototype length: centre-phone tendency + transition frames,
    // clamped to the spec's range.
    let core = rng.range(dlo, dhi + 1);
    let trans = 3 + rng.range(0, 3);
    let proto_len = (trans + core + trans)
        .clamp(spec.len_range.0, spec.len_range.1);

    let mut proto = Vec::with_capacity(proto_len * dim);
    let mut walk = vec![0.0f64; dim];
    for t in 0..proto_len {
        let u = t as f64 / (proto_len - 1).max(1) as f64;
        // Piecewise blend: 0..0.3 left→centre, 0.3..0.7 centre,
        // 0.7..1 centre→right.
        let (a, b, w) = if u < 0.3 {
            (&left.target, &centre.target, u / 0.3)
        } else if u < 0.7 {
            (&centre.target, &centre.target, 0.5)
        } else {
            (&centre.target, &right.target, (u - 0.7) / 0.3)
        };
        for d in 0..dim {
            walk[d] += rng.normal() * WALK_STD;
            // Contexts influence the edges at half strength.
            let edge_damp = 0.5;
            let base = a[d] * (1.0 - w * edge_damp) + b[d] * (w * edge_damp);
            proto.push(base + walk[d]);
        }
    }
    TriphoneClass {
        name: format!("{}-{}+{}", left.label, centre.label, right.label),
        proto,
        proto_len,
        dim,
    }
}

/// Zipf-distributed class cardinalities summing exactly to N.
fn class_cardinalities(spec: &DatasetSpec, rng: &mut Rng) -> Vec<usize> {
    let c = spec.classes;
    let mut counts = vec![spec.min_class_size.max(1); c];
    let mut remaining = spec.segments.saturating_sub(counts.iter().sum());
    if spec.skew <= 1e-9 {
        // Uniform: spread the remainder evenly (Small Set B shape).
        let per = remaining / c;
        for cnt in counts.iter_mut() {
            *cnt += per;
        }
        remaining -= per * c;
        for i in 0..remaining {
            counts[i % c] += 1;
        }
    } else {
        // Skewed: drop the remainder Zipf-wise over class ranks.
        let zipf = Zipf::new(c, spec.skew);
        for _ in 0..remaining {
            counts[zipf.sample(rng) - 1] += 1;
        }
    }
    counts
}

/// Instance duration with ±30% jitter around the prototype length.
fn instance_len(class: &TriphoneClass, spec: &DatasetSpec, rng: &mut Rng) -> usize {
    let lo = ((class.proto_len as f64 * 0.7).round() as usize).max(spec.len_range.0);
    let hi = ((class.proto_len as f64 * 1.3).round() as usize).min(spec.len_range.1);
    if lo >= hi {
        lo
    } else {
        rng.range(lo, hi + 1)
    }
}

/// Monotone warp: sorted jittered positions over [0,1], endpoints pinned
/// so on/offset structure is preserved.
fn warp_positions(len: usize, rng: &mut Rng) -> Vec<f64> {
    let mut pos: Vec<f64> = (0..len)
        .map(|t| {
            let u = t as f64 / (len - 1).max(1) as f64;
            let jitter = if t == 0 || t == len - 1 {
                0.0
            } else {
                rng.normal() * 0.35 / len as f64
            };
            (u + jitter).clamp(0.0, 1.0)
        })
        .collect();
    pos.sort_by(|a, b| a.total_cmp(b));
    pos
}

/// Sample one instance: monotone time warp + noise + speaker offset.
fn sample_instance(
    id: usize,
    class_id: usize,
    class: &TriphoneClass,
    spec: &DatasetSpec,
    rng: &mut Rng,
) -> Segment {
    let dim = class.dim;
    let len = instance_len(class, spec, rng);
    let pos = warp_positions(len, rng);

    let speaker: Vec<f64> = (0..dim).map(|_| rng.normal() * SPEAKER_STD).collect();
    let mut feats = Vec::with_capacity(len * dim);
    for &u in &pos {
        // Linear interpolation into the prototype.
        let x = u * (class.proto_len - 1) as f64;
        let i0 = x.floor() as usize;
        let i1 = (i0 + 1).min(class.proto_len - 1);
        let frac = x - i0 as f64;
        for d in 0..dim {
            let a = class.proto[i0 * dim + d];
            let b = class.proto[i1 * dim + d];
            let v = a * (1.0 - frac) + b * frac + speaker[d] + rng.normal() * NOISE_STD;
            feats.push(v as f32);
        }
    }
    Segment {
        id,
        class_id,
        len,
        dim,
        feats,
    }
}

/// A corpus delivered as raw audio (the end-to-end ingestion path):
/// waveforms must first pass through the MFCC front-end — native
/// (`dsp::mfcc`) or the AOT artifact (`runtime::mfcc_exec`) — before
/// clustering.
#[derive(Debug, Clone)]
pub struct AudioCorpus {
    pub name: String,
    /// Per-segment waveform at 16 kHz.
    pub wavs: Vec<Vec<f64>>,
    /// Ground-truth class per segment.
    pub labels: Vec<usize>,
    pub num_classes: usize,
}

/// Generate a corpus as waveforms: same classes/cardinalities/warps as
/// [`generate`], but each instance is rendered as formant-style audio
/// following its warped prototype trajectory (`waveform::render`).
///
/// `audio_noise` is the additive sample-noise level (0.005 ≈ clean).
pub fn generate_audio(spec: &DatasetSpec, audio_noise: f64) -> AudioCorpus {
    let mut rng = Rng::seed_from(spec.seed ^ 0x4155_4449_4f);
    let phones = inventory(spec.feat_dim.max(4), spec.seed, TARGET_SPREAD);
    let classes = build_classes(spec, &phones, &mut rng);
    let counts = class_cardinalities(spec, &mut rng);

    let mut items: Vec<(usize, Vec<f64>)> = Vec::with_capacity(spec.segments);
    for (class_id, (class, &count)) in classes.iter().zip(&counts).enumerate() {
        for _ in 0..count {
            let len = instance_len(class, spec, &mut rng);
            let pos = warp_positions(len, &mut rng);
            let wav = super::waveform::render(class, &pos, audio_noise, &mut rng);
            items.push((class_id, wav));
        }
    }
    rng.shuffle(&mut items);
    let (labels, wavs) = items.into_iter().unzip();
    AudioCorpus {
        name: format!("{}_audio", spec.name),
        wavs,
        labels,
        num_classes: classes.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetSpec, NamedDataset};
    use crate::dtw;

    fn tiny() -> DatasetSpec {
        DatasetSpec::tiny(120, 8, 42)
    }

    #[test]
    fn generates_requested_composition() {
        let spec = tiny();
        let set = generate(&spec);
        assert_eq!(set.len(), 120);
        assert_eq!(set.num_classes, 8);
        set.validate().unwrap();
        // Every class non-empty, all lengths within range.
        let mut seen = vec![0usize; 8];
        for s in &set.segments {
            seen[s.class_id] += 1;
            assert!(s.len >= spec.len_range.0 && s.len <= spec.len_range.1);
        }
        assert!(seen.iter().all(|&c| c >= spec.min_class_size));
    }

    #[test]
    fn deterministic() {
        let a = generate(&tiny());
        let b = generate(&tiny());
        assert_eq!(a.segments[7].feats, b.segments[7].feats);
        assert_eq!(a.segments[7].class_id, b.segments[7].class_id);
    }

    #[test]
    fn different_seed_differs() {
        let a = generate(&tiny());
        let mut spec = tiny();
        spec.seed = 43;
        let b = generate(&spec);
        assert_ne!(a.segments[0].feats, b.segments[0].feats);
    }

    #[test]
    fn within_class_closer_than_between() {
        // The property clustering depends on: mean within-class DTW
        // distance < mean between-class distance.
        let set = generate(&DatasetSpec::tiny(60, 5, 9));
        let mut within = (0.0f64, 0usize);
        let mut between = (0.0f64, 0usize);
        for i in 0..set.len() {
            for j in i + 1..set.len() {
                let (a, b) = (&set.segments[i], &set.segments[j]);
                let d =
                    dtw::dtw(&a.feats, &b.feats, set.dim, a.len, b.len) as f64;
                if a.class_id == b.class_id {
                    within.0 += d;
                    within.1 += 1;
                } else {
                    between.0 += d;
                    between.1 += 1;
                }
            }
        }
        let w = within.0 / within.1 as f64;
        let b = between.0 / between.1 as f64;
        assert!(
            w * 1.3 < b,
            "within {w:.3} not clearly below between {b:.3}"
        );
    }

    #[test]
    fn skewed_vs_flat_cardinalities() {
        let a = DatasetSpec::named(NamedDataset::SmallA, 0.02);
        let b = DatasetSpec::named(NamedDataset::SmallB, 0.02);
        let seta = generate(&a);
        let setb = generate(&b);
        let spread = |set: &SegmentSet, c: usize| {
            let mut counts = vec![0usize; c];
            for s in &set.segments {
                counts[s.class_id] += 1;
            }
            let max = *counts.iter().max().unwrap() as f64;
            let min = *counts.iter().min().unwrap().max(&1) as f64;
            max / min
        };
        let ra = spread(&seta, seta.num_classes);
        let rb = spread(&setb, setb.num_classes);
        assert!(ra > 2.0 * rb, "skew ratio A={ra:.1} vs B={rb:.1}");
    }

    #[test]
    fn ids_are_dense_after_shuffle() {
        let set = generate(&tiny());
        for (i, s) in set.segments.iter().enumerate() {
            assert_eq!(s.id, i);
        }
        // Shuffle actually interleaved classes: first 20 ids not all
        // the same class.
        let first: Vec<usize> = set.segments[..20].iter().map(|s| s.class_id).collect();
        assert!(first.iter().any(|&c| c != first[0]));
    }
}
