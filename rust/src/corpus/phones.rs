//! The 42-phone inventory underlying the synthetic triphone classes.
//!
//! The paper uses 42 base phones from TIMIT's reduced set (§6.1).  Each
//! synthetic phone gets (a) a fixed *target* vector in feature space —
//! the acoustic "colour" the trajectory passes through — and (b) a
//! duration tendency.  Targets are drawn once from a seeded stream, so
//! the inventory is a pure function of the seed: every dataset built on
//! the same seed shares acoustics, like datasets cut from one corpus.

use crate::util::rng::Rng;

/// TIMIT-style reduced phone labels (42, pauses excluded as in §6.1).
pub const PHONE_LABELS: [&str; 42] = [
    "aa", "ae", "ah", "aw", "ay", "b", "ch", "d", "dh", "dx", "eh", "er", "ey", "f", "g", "hh",
    "ih", "iy", "jh", "k", "l", "m", "n", "ng", "ow", "oy", "p", "r", "s", "sh", "t", "th", "uh",
    "uw", "v", "w", "y", "z", "zh", "el", "en", "ax",
];

/// Broad phonetic class — controls duration tendency and trajectory
/// dynamics (vowels are long and slow-moving; stops short and abrupt).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PhoneClass {
    Vowel,
    Stop,
    Fricative,
    Nasal,
    Glide,
}

impl PhoneClass {
    pub fn of(label: &str) -> PhoneClass {
        match label {
            "aa" | "ae" | "ah" | "aw" | "ay" | "eh" | "er" | "ey" | "ih" | "iy" | "ow" | "oy"
            | "uh" | "uw" | "ax" => PhoneClass::Vowel,
            "b" | "d" | "dx" | "g" | "k" | "p" | "t" | "ch" | "jh" => PhoneClass::Stop,
            "dh" | "f" | "hh" | "s" | "sh" | "th" | "v" | "z" | "zh" => PhoneClass::Fricative,
            "m" | "n" | "ng" | "en" => PhoneClass::Nasal,
            _ => PhoneClass::Glide, // l, r, w, y, el
        }
    }

    /// Typical duration range in 10ms frames (pre-warp).
    pub fn duration_frames(&self) -> (usize, usize) {
        match self {
            PhoneClass::Vowel => (8, 16),
            PhoneClass::Stop => (2, 6),
            PhoneClass::Fricative => (5, 12),
            PhoneClass::Nasal => (4, 10),
            PhoneClass::Glide => (4, 10),
        }
    }
}

/// One phone: label, broad class, and its feature-space target.
#[derive(Debug, Clone)]
pub struct Phone {
    pub label: &'static str,
    pub class: PhoneClass,
    /// Target point in `dim`-dimensional feature space.
    pub target: Vec<f64>,
}

/// The full inventory, deterministic in (seed, dim).
pub fn inventory(dim: usize, seed: u64, spread: f64) -> Vec<Phone> {
    let mut rng = Rng::seed_from(seed ^ 0x5048_4f4e_4553); // "PHONES"
    PHONE_LABELS
        .iter()
        .map(|&label| Phone {
            label,
            class: PhoneClass::of(label),
            target: (0..dim).map(|_| rng.normal() * spread).collect(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forty_two_phones() {
        assert_eq!(PHONE_LABELS.len(), 42);
        let inv = inventory(13, 1, 2.0);
        assert_eq!(inv.len(), 42);
        assert!(inv.iter().all(|p| p.target.len() == 13));
    }

    #[test]
    fn deterministic_in_seed() {
        let a = inventory(13, 7, 2.0);
        let b = inventory(13, 7, 2.0);
        assert_eq!(a[5].target, b[5].target);
        let c = inventory(13, 8, 2.0);
        assert_ne!(a[5].target, c[5].target);
    }

    #[test]
    fn targets_are_spread_out() {
        let inv = inventory(39, 3, 2.0);
        // Mean pairwise target distance well above zero: classes will be
        // separable in feature space.
        let mut total = 0.0;
        let mut count = 0;
        for i in 0..inv.len() {
            for j in i + 1..inv.len() {
                let d: f64 = inv[i]
                    .target
                    .iter()
                    .zip(&inv[j].target)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt();
                total += d;
                count += 1;
            }
        }
        assert!(total / count as f64 > 5.0);
    }

    #[test]
    fn class_assignment_sane() {
        assert_eq!(PhoneClass::of("iy"), PhoneClass::Vowel);
        assert_eq!(PhoneClass::of("t"), PhoneClass::Stop);
        assert_eq!(PhoneClass::of("s"), PhoneClass::Fricative);
        assert_eq!(PhoneClass::of("m"), PhoneClass::Nasal);
        assert_eq!(PhoneClass::of("r"), PhoneClass::Glide);
        let (lo, hi) = PhoneClass::Vowel.duration_frames();
        assert!(lo >= 2 && hi > lo);
    }
}
