//! Fixed-dimensional embedding corpora for the vector metrics
//! (cosine / Euclidean), alongside the variable-length triphone
//! corpora that feed DTW.
//!
//! Each segment is a single `dim`-dimensional frame (`len == 1`), so
//! the flat feature buffer *is* the embedding vector — exactly the
//! layout [`crate::distance::VectorBackend`] expects.  Two generators
//! are provided:
//!
//! * [`generate_embeddings`] — a labelled Gaussian-mixture corpus with
//!   Zipf-skewed class cardinalities, the embedding analogue of the
//!   triphone generator (same shuffle/re-id discipline).
//! * [`diarization`] — a speaker-diarization-style scenario: the true
//!   speaker count is itself drawn from the seeded RNG (unknown a
//!   priori, as in real diarization), with per-speaker session offsets
//!   so utterances from one speaker form a tight but non-degenerate
//!   cloud.

use super::dataset::{Segment, SegmentSet};
use crate::util::rng::{Rng, Zipf};

/// How far apart class centroids sit (feature-space units).
const CENTROID_SPREAD: f64 = 3.0;
/// Per-speaker session drift in the diarization scenario.
const SESSION_STD: f64 = 0.15;

/// Parameters for a Gaussian-mixture embedding corpus.
#[derive(Debug, Clone)]
pub struct EmbeddingSpec {
    pub name: String,
    /// Total number of embedding vectors.
    pub segments: usize,
    /// Number of mixture components (ground-truth classes).
    pub classes: usize,
    /// Embedding dimensionality.
    pub dim: usize,
    /// Within-class noise stddev (centroids sit ~[`CENTROID_SPREAD`]
    /// apart per axis, so 0.3–0.6 gives separable-but-touching blobs).
    pub spread: f64,
    /// Zipf exponent for class cardinalities (0 = uniform).
    pub skew: f64,
    pub seed: u64,
}

impl EmbeddingSpec {
    /// Small spec for tests: separable blobs, mild skew.
    pub fn tiny(segments: usize, classes: usize, seed: u64) -> Self {
        EmbeddingSpec {
            name: format!("embed_tiny_{segments}x{classes}"),
            segments,
            classes,
            dim: 16,
            spread: 0.4,
            skew: 0.7,
            seed,
        }
    }
}

/// Generate a labelled embedding corpus from an [`EmbeddingSpec`].
pub fn generate_embeddings(spec: &EmbeddingSpec) -> SegmentSet {
    let mut rng = Rng::seed_from(spec.seed ^ 0x454d_4245_44);
    let centroids = class_centroids(spec.classes, spec.dim, &mut rng);
    let counts = cardinalities(spec.segments, spec.classes, spec.skew, &mut rng);

    let mut segments = Vec::with_capacity(spec.segments);
    for (class_id, (centroid, &count)) in centroids.iter().zip(&counts).enumerate() {
        for _ in 0..count {
            let id = segments.len();
            segments.push(sample_embedding(id, class_id, centroid, spec.spread, &mut rng));
        }
    }
    // Interleave classes so contiguous id ranges are not single-class
    // (initial MAHC partitions slice by position).
    rng.shuffle(&mut segments);
    for (i, s) in segments.iter_mut().enumerate() {
        s.id = i;
    }

    let set = SegmentSet {
        name: spec.name.clone(),
        dim: spec.dim,
        segments,
        num_classes: spec.classes,
    };
    debug_assert!(set.validate().is_ok());
    set
}

/// Parameters for the diarization-style scenario.
#[derive(Debug, Clone)]
pub struct DiarizationSpec {
    /// Total number of utterance embeddings in the session.
    pub utterances: usize,
    /// Upper bound on the (randomly drawn) true speaker count.
    pub max_speakers: usize,
    /// Speaker-embedding dimensionality.
    pub dim: usize,
    pub seed: u64,
}

impl DiarizationSpec {
    pub fn tiny(utterances: usize, max_speakers: usize, seed: u64) -> Self {
        DiarizationSpec {
            utterances,
            max_speakers,
            dim: 32,
            seed,
        }
    }
}

/// Generate a diarization-style corpus: the speaker count is drawn in
/// `[2, max_speakers]` from the seeded RNG, speaking time follows a
/// Zipf draw (a few dominant speakers, a long tail), and each
/// utterance is its speaker's embedding plus session drift.  The true
/// count is recoverable as `set.num_classes`.
pub fn diarization(spec: &DiarizationSpec) -> SegmentSet {
    let mut rng = Rng::seed_from(spec.seed ^ 0x4449_4152);
    let speakers = 2 + rng.range(0, spec.max_speakers.max(3) - 1);
    let centroids = class_centroids(speakers, spec.dim, &mut rng);
    let counts = cardinalities(spec.utterances, speakers, 1.1, &mut rng);

    let mut segments = Vec::with_capacity(spec.utterances);
    for (class_id, (centroid, &count)) in centroids.iter().zip(&counts).enumerate() {
        // A per-speaker session offset: this speaker's utterances share
        // channel/prosody drift on top of the identity embedding.
        let session: Vec<f64> = (0..spec.dim).map(|_| rng.normal() * SESSION_STD).collect();
        for _ in 0..count {
            let id = segments.len();
            let feats: Vec<f32> = centroid
                .iter()
                .zip(&session)
                .map(|(&c, &s)| (c + s + rng.normal() * 0.35) as f32)
                .collect();
            segments.push(Segment {
                id,
                class_id,
                len: 1,
                dim: spec.dim,
                feats,
            });
        }
    }
    rng.shuffle(&mut segments);
    for (i, s) in segments.iter_mut().enumerate() {
        s.id = i;
    }

    let set = SegmentSet {
        name: format!("diarization_{}spk", speakers),
        dim: spec.dim,
        segments,
        num_classes: speakers,
    };
    debug_assert!(set.validate().is_ok());
    set
}

/// Class centroids spread over the embedding space.
fn class_centroids(classes: usize, dim: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
    (0..classes)
        .map(|_| (0..dim).map(|_| rng.normal() * CENTROID_SPREAD).collect())
        .collect()
}

/// Zipf-distributed class cardinalities summing exactly to `total`,
/// floored at one member per class.
fn cardinalities(total: usize, classes: usize, skew: f64, rng: &mut Rng) -> Vec<usize> {
    let mut counts = vec![1usize; classes];
    let mut remaining = total.saturating_sub(classes);
    if skew <= 1e-9 {
        let per = remaining / classes;
        remaining -= per * classes;
        // After the even share, fewer than `classes` singles remain.
        for (i, cnt) in counts.iter_mut().enumerate() {
            *cnt += per + usize::from(i < remaining);
        }
    } else {
        let zipf = Zipf::new(classes, skew);
        for _ in 0..remaining {
            // sample() ranks are 1-based in [1, classes].
            if let Some(cnt) = counts.get_mut(zipf.sample(rng) - 1) {
                *cnt += 1;
            }
        }
    }
    counts
}

/// One embedding: centroid plus isotropic Gaussian noise, as a
/// single-frame segment.
fn sample_embedding(
    id: usize,
    class_id: usize,
    centroid: &[f64],
    spread: f64,
    rng: &mut Rng,
) -> Segment {
    let feats: Vec<f32> = centroid
        .iter()
        .map(|&c| (c + rng.normal() * spread) as f32)
        .collect();
    Segment {
        id,
        class_id,
        len: 1,
        dim: centroid.len(),
        feats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_composition() {
        let spec = EmbeddingSpec::tiny(120, 6, 7);
        let set = generate_embeddings(&spec);
        assert_eq!(set.len(), 120);
        assert_eq!(set.num_classes, 6);
        set.validate().unwrap();
        let mut seen = vec![0usize; 6];
        for s in &set.segments {
            assert_eq!(s.len, 1);
            assert_eq!(s.feats.len(), spec.dim);
            seen[s.class_id] += 1;
        }
        assert!(seen.iter().all(|&c| c >= 1));
    }

    #[test]
    fn deterministic() {
        let a = generate_embeddings(&EmbeddingSpec::tiny(80, 5, 3));
        let b = generate_embeddings(&EmbeddingSpec::tiny(80, 5, 3));
        assert_eq!(a.segments[11].feats, b.segments[11].feats);
        assert_eq!(a.labels(), b.labels());
    }

    #[test]
    fn different_seed_differs() {
        let a = generate_embeddings(&EmbeddingSpec::tiny(80, 5, 3));
        let b = generate_embeddings(&EmbeddingSpec::tiny(80, 5, 4));
        assert_ne!(a.segments[0].feats, b.segments[0].feats);
    }

    #[test]
    fn within_class_closer_than_between() {
        // The property vector-metric clustering depends on: mean
        // within-class Euclidean distance < mean between-class.
        let set = generate_embeddings(&EmbeddingSpec::tiny(60, 5, 9));
        let dist = |a: &Segment, b: &Segment| -> f64 {
            a.feats
                .iter()
                .zip(&b.feats)
                .map(|(&x, &y)| ((x - y) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let mut within = (0.0f64, 0usize);
        let mut between = (0.0f64, 0usize);
        for i in 0..set.len() {
            for j in i + 1..set.len() {
                let d = dist(&set.segments[i], &set.segments[j]);
                if set.segments[i].class_id == set.segments[j].class_id {
                    within.0 += d;
                    within.1 += 1;
                } else {
                    between.0 += d;
                    between.1 += 1;
                }
            }
        }
        let w = within.0 / within.1 as f64;
        let b = between.0 / between.1 as f64;
        assert!(w * 1.5 < b, "within {w:.3} not clearly below between {b:.3}");
    }

    #[test]
    fn diarization_draws_unknown_speaker_count() {
        let set = diarization(&DiarizationSpec::tiny(100, 8, 21));
        set.validate().unwrap();
        assert_eq!(set.len(), 100);
        assert!(set.num_classes >= 2 && set.num_classes <= 8);
        // Different seeds can land on different true counts.
        let distinct: std::collections::HashSet<usize> = (0..16)
            .map(|s| diarization(&DiarizationSpec::tiny(20, 8, s)).num_classes)
            .collect();
        assert!(distinct.len() > 1, "speaker count never varied");
    }

    #[test]
    fn diarization_deterministic_and_skewed() {
        let a = diarization(&DiarizationSpec::tiny(90, 6, 5));
        let b = diarization(&DiarizationSpec::tiny(90, 6, 5));
        assert_eq!(a.labels(), b.labels());
        assert_eq!(a.segments[4].feats, b.segments[4].feats);
        // Zipf speaking time: the dominant speaker holds a plurality.
        let mut counts = vec![0usize; a.num_classes];
        for s in &a.segments {
            counts[s.class_id] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max > min, "speaking time unexpectedly uniform");
    }

    #[test]
    fn ids_are_dense_after_shuffle() {
        let set = generate_embeddings(&EmbeddingSpec::tiny(64, 4, 2));
        for (i, s) in set.segments.iter().enumerate() {
            assert_eq!(s.id, i);
        }
        let first: Vec<usize> = set.segments[..16].iter().map(|s| s.class_id).collect();
        assert!(first.iter().any(|&c| c != first[0]));
    }
}
