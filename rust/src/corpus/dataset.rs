//! Core dataset types: variable-length feature segments with ground-
//! truth class labels.

/// One acoustic segment: a variable-length sequence of `dim`-dimensional
/// feature vectors, stored flat row-major (`len * dim` floats).
#[derive(Debug, Clone)]
pub struct Segment {
    /// Stable id within its [`SegmentSet`] (== index).
    pub id: usize,
    /// Ground-truth class (triphone) label.
    pub class_id: usize,
    /// Number of frames.
    pub len: usize,
    /// Feature dimensionality.
    pub dim: usize,
    /// Flat `(len, dim)` row-major feature buffer.
    pub feats: Vec<f32>,
}

impl Segment {
    pub fn frame(&self, i: usize) -> &[f32] {
        &self.feats[i * self.dim..(i + 1) * self.dim]
    }
}

/// A labelled collection of segments (the dataset 𝒳 of paper §3).
#[derive(Debug, Clone)]
pub struct SegmentSet {
    pub name: String,
    pub dim: usize,
    pub segments: Vec<Segment>,
    /// Number of distinct ground-truth classes.
    pub num_classes: usize,
}

impl SegmentSet {
    pub fn len(&self) -> usize {
        self.segments.len()
    }

    pub fn is_empty(&self) -> bool {
        self.segments.is_empty()
    }

    /// Ground-truth labels, indexable by segment id.
    pub fn labels(&self) -> Vec<usize> {
        self.segments.iter().map(|s| s.class_id).collect()
    }

    /// Total number of feature vectors (Table 1 "Vectors" column).
    pub fn total_vectors(&self) -> usize {
        self.segments.iter().map(|s| s.len).sum()
    }

    /// Number of pairwise similarities N(N−1)/2 full AHC would need
    /// (Table 1 "Similarities" column).
    pub fn total_similarities(&self) -> u64 {
        let n = self.len() as u64;
        n * (n - 1) / 2
    }

    /// Longest segment, in frames.
    pub fn max_len(&self) -> usize {
        self.segments.iter().map(|s| s.len).max().unwrap_or(0)
    }

    /// Validate internal consistency (used by tests and after generation).
    pub fn validate(&self) -> anyhow::Result<()> {
        for (i, s) in self.segments.iter().enumerate() {
            if s.id != i {
                anyhow::bail!("segment {i} has id {}", s.id);
            }
            if s.dim != self.dim {
                anyhow::bail!("segment {i} dim {} != set dim {}", s.dim, self.dim);
            }
            if s.len == 0 {
                anyhow::bail!("segment {i} empty");
            }
            if s.feats.len() != s.len * s.dim {
                anyhow::bail!(
                    "segment {i} buffer {} != len*dim {}",
                    s.feats.len(),
                    s.len * s.dim
                );
            }
            if s.class_id >= self.num_classes {
                anyhow::bail!("segment {i} class {} >= {}", s.class_id, self.num_classes);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_set() -> SegmentSet {
        SegmentSet {
            name: "t".into(),
            dim: 2,
            segments: vec![
                Segment {
                    id: 0,
                    class_id: 0,
                    len: 3,
                    dim: 2,
                    feats: vec![0.0; 6],
                },
                Segment {
                    id: 1,
                    class_id: 1,
                    len: 2,
                    dim: 2,
                    feats: vec![1.0; 4],
                },
            ],
            num_classes: 2,
        }
    }

    #[test]
    fn accessors() {
        let s = tiny_set();
        assert_eq!(s.len(), 2);
        assert_eq!(s.total_vectors(), 5);
        assert_eq!(s.total_similarities(), 1);
        assert_eq!(s.max_len(), 3);
        assert_eq!(s.labels(), vec![0, 1]);
        assert_eq!(s.segments[1].frame(1), &[1.0, 1.0]);
        s.validate().unwrap();
    }

    #[test]
    fn validate_catches_bad_buffer() {
        let mut s = tiny_set();
        s.segments[0].feats.pop();
        assert!(s.validate().is_err());
    }

    #[test]
    fn validate_catches_bad_class() {
        let mut s = tiny_set();
        s.segments[1].class_id = 9;
        assert!(s.validate().is_err());
    }
}
