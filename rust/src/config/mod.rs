//! Typed configuration for datasets and the clustering algorithm.
//!
//! Two layers: [`DatasetSpec`] describes a synthetic corpus to generate
//! (mirroring the paper's Table 1 compositions, scaled), and
//! [`AlgoConfig`] carries every knob of Algorithm 1 (P₀, β, K, linkage,
//! convergence policy) plus execution choices (backend, threads).
//! Config files use a minimal `key = value` TOML subset parsed by
//! [`parse_kv`]; every key can also be overridden from the CLI.

use crate::ahc::SelectionMethod;
use crate::distance::{BackendKind, MetricKind};

/// Typed rejection for incoherent metric/backend/prune combinations.
///
/// Surfaced through `anyhow` by [`AlgoConfig::validate`], so callers
/// that care (CLI error formatting, serve admission) can
/// `downcast_ref::<MetricConfigError>()` instead of string-matching —
/// and an incoherent `--prune debug --metric cosine` is a clean
/// validation error, never a runtime panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MetricConfigError {
    /// The pruning cascade needs an admissible lower bound and the
    /// metric has none (cosine).
    PruneUnsupported { metric: MetricKind, prune: PruneMode },
    /// The backend kernel only implements DTW (the XLA artifact).
    BackendUnsupported {
        metric: MetricKind,
        backend: BackendKind,
    },
}

impl std::fmt::Display for MetricConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MetricConfigError::PruneUnsupported { metric, prune } => write!(
                f,
                "prune = {} needs an admissible lower bound, but metric '{}' has none \
                 (use --prune off, or a metric with a bound: dtw, euclidean)",
                prune.name(),
                metric.name()
            ),
            MetricConfigError::BackendUnsupported { metric, backend } => write!(
                f,
                "backend '{}' only implements the dtw metric (got metric '{}'); \
                 use --backend native or --backend blocked for vector metrics",
                backend.name(),
                metric.name()
            ),
        }
    }
}

impl std::error::Error for MetricConfigError {}

/// Which of the paper's four TIMIT-derived compositions to mirror.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NamedDataset {
    SmallA,
    SmallB,
    Medium,
    Large,
}

impl NamedDataset {
    pub fn all() -> [NamedDataset; 4] {
        [
            NamedDataset::SmallA,
            NamedDataset::SmallB,
            NamedDataset::Medium,
            NamedDataset::Large,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            NamedDataset::SmallA => "small_a",
            NamedDataset::SmallB => "small_b",
            NamedDataset::Medium => "medium",
            NamedDataset::Large => "large",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "small_a" | "smalla" | "a" => Ok(NamedDataset::SmallA),
            "small_b" | "smallb" | "b" => Ok(NamedDataset::SmallB),
            "medium" | "m" => Ok(NamedDataset::Medium),
            "large" | "l" => Ok(NamedDataset::Large),
            other => anyhow::bail!("unknown dataset '{other}' (small_a|small_b|medium|large)"),
        }
    }
}

/// Synthetic corpus composition (paper Table 1, scaled by `scale`).
///
/// The defaults reproduce the paper's compositions at 1/10 scale; shape
/// (skew, length distribution, class counts) is preserved — see
/// DESIGN.md §5 for why the reproduction target is scale-free.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: String,
    /// Total number of segments N.
    pub segments: usize,
    /// Number of ground-truth classes (unique triphones).
    pub classes: usize,
    /// Zipf exponent for class cardinalities (0 = uniform, Small B).
    pub skew: f64,
    /// Minimum members a class may have (paper: 50/26/20/1).
    pub min_class_size: usize,
    /// Frame-length range of segments [min, max], in 10ms frames.
    pub len_range: (usize, usize),
    /// Feature dimensionality (39 = 12 MFCC + logE + Δ + ΔΔ).
    pub feat_dim: usize,
    /// Generator seed.
    pub seed: u64,
}

impl DatasetSpec {
    /// Paper Table 1 composition at `scale` (1.0 = paper size).
    pub fn named(which: NamedDataset, scale: f64) -> DatasetSpec {
        let s = |n: usize| ((n as f64 * scale).round() as usize).max(8);
        let c = |n: usize| ((n as f64 * scale).round() as usize).max(4);
        match which {
            // 17 611 segments / 280 classes / freq 50-373 (skewed)
            NamedDataset::SmallA => DatasetSpec {
                name: "small_a".into(),
                segments: s(17_611),
                classes: c(280),
                skew: 1.1,
                min_class_size: 5,
                len_range: (8, 64),
                feat_dim: 39,
                seed: 0xA,
            },
            // 17 640 / 636 / freq 26-49 (flat)
            NamedDataset::SmallB => DatasetSpec {
                name: "small_b".into(),
                segments: s(17_640),
                classes: c(636),
                skew: 0.0,
                min_class_size: 3,
                len_range: (8, 64),
                feat_dim: 39,
                seed: 0xB,
            },
            // 54 787 / 1 387 / 20-373 (skewed like Small A)
            NamedDataset::Medium => DatasetSpec {
                name: "medium".into(),
                segments: s(54_787),
                classes: c(1_387),
                skew: 1.1,
                min_class_size: 2,
                len_range: (8, 64),
                feat_dim: 39,
                seed: 0xC,
            },
            // 123 182 / 19 223 / 1-373 (long tail of singletons)
            NamedDataset::Large => DatasetSpec {
                name: "large".into(),
                segments: s(123_182),
                classes: c(19_223),
                skew: 1.4,
                min_class_size: 1,
                len_range: (8, 64),
                feat_dim: 39,
                seed: 0xD,
            },
        }
    }

    /// A tiny spec for tests and the quickstart example.
    pub fn tiny(segments: usize, classes: usize, seed: u64) -> DatasetSpec {
        DatasetSpec {
            name: format!("tiny_{segments}x{classes}"),
            segments,
            classes,
            skew: 0.8,
            min_class_size: 2,
            len_range: (6, 24),
            feat_dim: 13,
            seed,
        }
    }
}

/// Stage-0 distance-space aggregation knobs ([`crate::aggregate`]).
///
/// A deterministic leader pass groups segments whose DTW distance to an
/// already-chosen representative is at most `epsilon`, so the drivers
/// cluster `m ≪ N` representatives instead of raw segments.  `epsilon =
/// 0` (with no quantile) disables the pass entirely (identity — the
/// pipeline is bitwise the unaggregated run), giving the same zero-risk
/// opt-in story as the blocked backend.
///
/// Probe-engine knobs: `batch_rows` groups pending segments into probe
/// rounds dispatched as one cross rectangle (1 = the serial per-row
/// reference path, bitwise-identical groups either way); `tree_factor`
/// enables the two-level leader tree (super-leaders at radius
/// `tree_factor`·ε, each segment descending into its `tree_probe`
/// nearest super-groups); `quantile` derives ε from the pair-distance
/// quantile of a seeded corpus sample instead of an absolute radius.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AggregateConfig {
    /// Leader radius ε in DTW distance units.  A segment joins the
    /// nearest representative with distance ≤ ε; 0.0 = aggregation off
    /// (unless `quantile` derives a radius instead).
    pub epsilon: f32,
    /// Hard per-group occupancy cap (None = unbounded) — the β idea
    /// applied to stage 0: a full group accepts no more members, so no
    /// representative's member list can grow without bound.
    pub cap: Option<usize>,
    /// Pending segments probed per round as one cross rectangle through
    /// the blocked backend's lane-parallel kernel.  1 degenerates to
    /// the historical serial per-row path — the bitwise reference the
    /// parity suite compares against.
    pub batch_rows: usize,
    /// Super-leader coarse radius as a multiple of ε (the two-level
    /// leader tree).  0.0 = flat probing: every segment considers every
    /// open leader.
    pub tree_factor: f32,
    /// Nearest super-groups each segment descends into when the tree is
    /// active (the probe fan-out).
    pub tree_probe: usize,
    /// Leader-tree depth D: number of levels including the leaders
    /// themselves.  1 forces the flat pass (bitwise, even with
    /// `tree_factor > 0`); 2 is the historical two-level tree; deeper
    /// trees add node levels at radius `tree_factor`ˡ·ε.
    pub tree_depth: usize,
    /// Derive ε as this quantile of the pair distances of a seeded
    /// corpus sample (overrides `epsilon`; None = absolute radius).
    /// Must lie strictly inside (0, 1).
    pub quantile: Option<f64>,
    /// Segments drawn for the quantile estimate (clamped to N; the
    /// estimate is exact when the sample covers the corpus).
    pub quantile_sample: usize,
    /// Seed of the quantile sampler (the estimate is deterministic
    /// given seed, sample size and corpus).
    pub quantile_seed: u64,
}

impl Default for AggregateConfig {
    fn default() -> Self {
        AggregateConfig {
            epsilon: 0.0,
            cap: None,
            batch_rows: 64,
            tree_factor: 0.0,
            tree_probe: 2,
            tree_depth: 2,
            quantile: None,
            quantile_sample: 256,
            quantile_seed: 0xE5,
        }
    }
}

impl AggregateConfig {
    pub fn new(epsilon: f32) -> Self {
        AggregateConfig {
            epsilon,
            ..Default::default()
        }
    }

    pub fn with_cap(mut self, cap: usize) -> Self {
        self.cap = Some(cap);
        self
    }

    /// Set the probe-round rectangle height (1 = per-row reference).
    pub fn with_batch_rows(mut self, rows: usize) -> Self {
        self.batch_rows = rows;
        self
    }

    /// Enable the two-level leader tree: super-leaders at radius
    /// `factor`·ε, each segment probing its `probe` nearest super-groups.
    pub fn with_tree(mut self, factor: f32, probe: usize) -> Self {
        self.tree_factor = factor;
        self.tree_probe = probe;
        self
    }

    /// Set the leader-tree depth D (1 = flat pass, 2 = two-level tree).
    pub fn with_depth(mut self, depth: usize) -> Self {
        self.tree_depth = depth;
        self
    }

    /// Derive ε from the pair-distance quantile `q` of a seeded corpus
    /// sample instead of an absolute radius.
    pub fn with_quantile(mut self, q: f64) -> Self {
        self.quantile = Some(q);
        self
    }

    /// Sample size for the quantile estimate.
    pub fn with_quantile_sample(mut self, sample: usize) -> Self {
        self.quantile_sample = sample;
        self
    }

    /// Whether the leader pass runs at all (ε > 0 or a quantile-derived
    /// radius is requested).
    pub fn is_active(&self) -> bool {
        self.epsilon > 0.0 || self.quantile.is_some()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if !self.epsilon.is_finite() || self.epsilon < 0.0 {
            anyhow::bail!(
                "aggregate epsilon must be finite and >= 0 (got {})",
                self.epsilon
            );
        }
        if self.cap == Some(0) {
            anyhow::bail!("aggregate cap must be >= 1 (a group holds at least its leader)");
        }
        if self.batch_rows == 0 {
            anyhow::bail!("aggregate batch_rows must be >= 1 (1 = per-row reference path)");
        }
        if !self.tree_factor.is_finite() || self.tree_factor < 0.0 {
            anyhow::bail!(
                "aggregate tree_factor must be finite and >= 0 (got {})",
                self.tree_factor
            );
        }
        if self.tree_probe == 0 {
            anyhow::bail!("aggregate tree_probe must be >= 1 (descend into at least one group)");
        }
        if self.tree_depth == 0 {
            anyhow::bail!("aggregate tree_depth must be >= 1 (1 = flat pass, 2 = two-level tree)");
        }
        if let Some(q) = self.quantile {
            if !q.is_finite() || q <= 0.0 || q >= 1.0 {
                anyhow::bail!("aggregate quantile must lie strictly inside (0, 1) (got {q})");
            }
            if self.quantile_sample < 2 {
                anyhow::bail!("aggregate quantile_sample must be >= 2 (need at least one pair)");
            }
        }
        Ok(())
    }
}

/// Whether the lower-bound pruning cascade
/// ([`crate::distance::CascadeBackend`]) wraps the distance backend.
///
/// `Off` is the exact path, unchanged — the bitwise reference the
/// pruning parity suite compares against.  `On` answers threshold
/// queries through an LB_Keogh-style envelope bound first and runs the
/// DTW recurrence only when the bound cannot decide; clusterings are
/// bitwise identical because the bound is admissible (never exceeds the
/// exact distance) and threshold consumers reject any value above their
/// radius before comparing magnitudes.  `Debug` additionally computes
/// the exact distance for every bounded pair and fails the run if a
/// bound ever exceeds it — the admissibility oracle, for tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PruneMode {
    /// Exact distances everywhere (default).
    #[default]
    Off,
    /// Cascade lower bounds before DTW on threshold queries.
    On,
    /// Cascade *and* verify every bound against the exact distance.
    Debug,
}

impl PruneMode {
    pub fn name(&self) -> &'static str {
        match self {
            PruneMode::Off => "off",
            PruneMode::On => "on",
            PruneMode::Debug => "debug",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "off" | "exact" | "false" | "0" => Ok(PruneMode::Off),
            "on" | "lb" | "true" | "1" => Ok(PruneMode::On),
            "debug" | "verify" => Ok(PruneMode::Debug),
            other => anyhow::bail!("unknown prune mode '{other}' (off|on|debug)"),
        }
    }

    /// Whether the cascade wraps the backend at all.
    pub fn is_active(&self) -> bool {
        !matches!(self, PruneMode::Off)
    }
}

/// How the per-run aggregation deviation bound
/// ([`crate::aggregate::summary`]) is handled.
///
/// `Report` (default) computes the bound from the cluster-feature
/// summaries and stamps it on the stage-0 [`crate::telemetry`] record —
/// free.  `Debug` additionally rebuilds the full-corpus Ward dendrogram
/// (O(N²) — the admissibility oracle, for tests and small corpora) and
/// fails the run if any representative-level merge height deviates from
/// its full-AHC counterpart by more than the reported bound.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DeviationMode {
    /// Compute and report the bound (default).
    #[default]
    Report,
    /// Report *and* verify every merge against the full-AHC oracle.
    Debug,
}

impl DeviationMode {
    pub fn name(&self) -> &'static str {
        match self {
            DeviationMode::Report => "report",
            DeviationMode::Debug => "debug",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "report" | "on" | "default" => Ok(DeviationMode::Report),
            "debug" | "verify" => Ok(DeviationMode::Debug),
            other => anyhow::bail!("unknown deviation mode '{other}' (report|debug)"),
        }
    }

    /// Whether the O(N²) per-merge recheck runs.
    pub fn is_debug(&self) -> bool {
        matches!(self, DeviationMode::Debug)
    }
}

/// How streaming retirement resolves aggregated members to final
/// clusters ([`crate::mahc::streaming`]).
///
/// `Leader` (default) follows the member → leader forwarding pointer —
/// the historical path and the bitwise oracle.  `Medoid` reassigns
/// every aggregated member to its nearest *final* medoid through the
/// retirement rectangle at stream end: members a leader dragged to the
/// wrong side of a cluster boundary are recovered, so F-measure can
/// only benefit (pinned ≥ leader mode on the discovery fixture).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RetireMode {
    /// Members inherit their leader's final cluster (default).
    #[default]
    Leader,
    /// Members are reassigned to their nearest final medoid.
    Medoid,
}

impl RetireMode {
    pub fn name(&self) -> &'static str {
        match self {
            RetireMode::Leader => "leader",
            RetireMode::Medoid => "medoid",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "leader" | "default" => Ok(RetireMode::Leader),
            "medoid" | "nearest" => Ok(RetireMode::Medoid),
            other => anyhow::bail!("unknown retire mode '{other}' (leader|medoid)"),
        }
    }

    /// Whether the nearest-final-medoid reassignment runs.
    pub fn is_medoid(&self) -> bool {
        matches!(self, RetireMode::Medoid)
    }
}

/// How the final number of clusters K is chosen (paper §5: K = ΣKⱼ from
/// the first stage is empirically a good approximation).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FinalK {
    /// Use the first-stage total ΣKⱼ (paper default).
    StageOneTotal,
    /// Fixed K supplied by the user.
    Fixed(usize),
}

/// Convergence policy for the MAHC loop.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Convergence {
    /// Stop when i > 2 and Pᵢ == Pᵢ₋₁ (paper: "settling in the number
    /// of subsets"), with a hard iteration cap as backstop.
    SettledSubsets { max_iters: usize },
    /// Fixed number of iterations (paper: "simply terminating ... after
    /// a fixed number of iterations").
    FixedIters(usize),
}

/// All knobs of Algorithm 1 plus execution choices.
#[derive(Debug, Clone)]
pub struct AlgoConfig {
    /// Initial number of subsets P₀.
    pub p0: usize,
    /// Cluster size threshold β (None = no management, plain MAHC).
    pub beta: Option<usize>,
    /// Final-K policy.
    pub final_k: FinalK,
    /// Convergence policy.
    pub convergence: Convergence,
    /// Merge undersized subsets (paper §7 concludes this is unnecessary;
    /// kept as an ablation switch, Fig. 11).
    pub merge_min: Option<usize>,
    /// Distance backend (scalar native, the lane-parallel blocked
    /// kernel, or the PJRT XLA artifact).  Native and blocked produce
    /// bitwise-identical clusterings (`rust/tests/backend_parity.rs`,
    /// `rust/tests/metric_parity.rs`).
    pub backend: BackendKind,
    /// Distance metric: DTW over variable-length segments (historical
    /// default) or cosine/Euclidean over fixed-dimension vectors.
    /// Orthogonal to `backend` — both kernel variants exist for every
    /// metric (XLA is DTW-only; [`AlgoConfig::validate`] rejects the
    /// combination with a typed [`MetricConfigError`]).
    pub metric: MetricKind,
    /// How the cluster count is chosen per subset: the paper's
    /// L-method knee or mean-silhouette argmax
    /// (`ahc::SelectionMethod`).
    pub selection: SelectionMethod,
    /// Worker threads for per-subset stage-1 jobs.
    pub threads: usize,
    /// Shuffle subset membership before splitting (ablation; default
    /// false = contiguous, cluster-preserving pieces — see
    /// `mahc::split::split_oversized`).
    pub split_shuffle: bool,
    /// Seed for the initial partition and split shuffles.
    pub seed: u64,
    /// L-method: cap on clusters per subset as a fraction of subset size.
    pub max_clusters_frac: f64,
    /// Byte budget of the cross-iteration DTW pair cache (0 disables
    /// it).  The companion bound to β: β caps any single resident
    /// condensed matrix, `cache_bytes` caps the resident pool of reused
    /// pair distances, so total distance memory stays thresholded
    /// either way.  Results are identical with the cache on or off
    /// (`distance::build_condensed_cached`); only wall-clock changes.
    pub cache_bytes: usize,
    /// Stage-0 aggregation front-end ([`crate::aggregate`]): with
    /// `epsilon > 0` the drivers cluster leader-pass representatives
    /// instead of raw segments.  Off (ε = 0) by default.
    pub aggregate: AggregateConfig,
    /// Lower-bound pruning cascade around the backend (off = exact
    /// path, bitwise the historical behaviour).
    pub prune: PruneMode,
    /// Aggregation deviation bound: report it (free) or verify it
    /// against the O(N²) full-AHC oracle per merge (debug).
    pub deviation: DeviationMode,
    /// Streaming member retirement: inherit the leader's cluster
    /// (bitwise oracle) or reassign to the nearest final medoid.
    pub retire: RetireMode,
}

impl Default for AlgoConfig {
    fn default() -> Self {
        AlgoConfig {
            p0: 4,
            beta: None,
            final_k: FinalK::StageOneTotal,
            convergence: Convergence::FixedIters(5),
            merge_min: None,
            backend: BackendKind::Native,
            metric: MetricKind::Dtw,
            selection: SelectionMethod::LMethod,
            threads: crate::util::pool::default_threads(),
            split_shuffle: false,
            seed: 1234,
            max_clusters_frac: 0.25,
            cache_bytes: 0,
            aggregate: AggregateConfig::default(),
            prune: PruneMode::Off,
            deviation: DeviationMode::Report,
            retire: RetireMode::Leader,
        }
    }
}

impl AlgoConfig {
    pub fn with_beta(mut self, beta: usize) -> Self {
        self.beta = Some(beta);
        self
    }

    pub fn with_p0(mut self, p0: usize) -> Self {
        self.p0 = p0;
        self
    }

    /// Enable the cross-iteration pair cache with a byte budget.
    pub fn with_cache_bytes(mut self, bytes: usize) -> Self {
        self.cache_bytes = bytes;
        self
    }

    /// Enable stage-0 aggregation with leader radius `epsilon`.
    pub fn with_aggregate(mut self, aggregate: AggregateConfig) -> Self {
        self.aggregate = aggregate;
        self
    }

    /// Select the lower-bound pruning mode.
    pub fn with_prune(mut self, prune: PruneMode) -> Self {
        self.prune = prune;
        self
    }

    /// Select the distance metric.
    pub fn with_metric(mut self, metric: MetricKind) -> Self {
        self.metric = metric;
        self
    }

    /// Select the cluster-count selection method.
    pub fn with_selection(mut self, selection: SelectionMethod) -> Self {
        self.selection = selection;
        self
    }

    /// Select the aggregation deviation-bound mode.
    pub fn with_deviation(mut self, deviation: DeviationMode) -> Self {
        self.deviation = deviation;
        self
    }

    /// Select the streaming member-retirement mode.
    pub fn with_retire(mut self, retire: RetireMode) -> Self {
        self.retire = retire;
        self
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if self.p0 == 0 {
            anyhow::bail!("p0 must be >= 1");
        }
        if let Some(b) = self.beta {
            if b < 4 {
                anyhow::bail!("beta must be >= 4 (got {b}); AHC needs a few objects per subset");
            }
        }
        if let FinalK::Fixed(k) = self.final_k {
            if k == 0 {
                anyhow::bail!("fixed K must be >= 1");
            }
        }
        if !(0.0..=1.0).contains(&self.max_clusters_frac) {
            anyhow::bail!("max_clusters_frac must be in [0,1]");
        }
        if self.prune.is_active() && !self.metric.has_lower_bound() {
            return Err(MetricConfigError::PruneUnsupported {
                metric: self.metric,
                prune: self.prune,
            }
            .into());
        }
        if self.metric != MetricKind::Dtw && self.backend == BackendKind::Xla {
            return Err(MetricConfigError::BackendUnsupported {
                metric: self.metric,
                backend: self.backend,
            }
            .into());
        }
        self.aggregate.validate()?;
        Ok(())
    }
}

/// Knobs of the streaming driver ([`crate::mahc::streaming`]): the
/// batch algorithm configuration plus the shape of the arriving stream.
#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// Per-episode algorithm knobs (β, P₀, convergence, cache, ...).
    pub algo: AlgoConfig,
    /// Segments per arriving shard.  Together with β this bounds the
    /// active set of every episode, and with it peak matrix memory —
    /// independent of how long the stream runs.
    pub shard_size: usize,
    /// Stream-order seed: `None` consumes the corpus in id order (the
    /// arrival order of a real stream), `Some(s)` shuffles the stream
    /// for order-sensitivity ablations.
    pub shard_seed: Option<u64>,
}

impl StreamConfig {
    pub fn new(algo: AlgoConfig, shard_size: usize) -> Self {
        StreamConfig {
            algo,
            shard_size,
            shard_seed: None,
        }
    }

    pub fn with_shard_seed(mut self, seed: u64) -> Self {
        self.shard_seed = Some(seed);
        self
    }

    /// Validate the algo knobs plus the stream shape.  A shard larger
    /// than β is legal — `split_oversized` repairs the initial division
    /// of every episode — so only outright contradictions are errors.
    pub fn validate(&self) -> anyhow::Result<()> {
        self.algo.validate()?;
        if self.shard_size == 0 {
            anyhow::bail!("shard_size must be >= 1");
        }
        Ok(())
    }
}

/// Knobs of the serve multiplexer ([`crate::mahc::serve`]): fleet-wide
/// resource bounds over many concurrent streaming sessions.  Each
/// session keeps its own [`StreamConfig`] — β and `cache_bytes` there
/// are *per-session* budgets; the fields here bound the fleet.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads in the shared pool all sessions step on.
    pub workers: usize,
    /// Maximum concurrently-active sessions (admission control): the
    /// per-session space guarantee β(β−1)/2·4 B composes into a fleet
    /// bound of `fleet_cap` times the largest admitted session's.
    pub fleet_cap: usize,
    /// Sessions allowed to queue behind the cap before admission
    /// rejects outright.
    pub queue_cap: usize,
    /// Capacity of the shared fleet [`crate::distance::PairCache`]
    /// (0 disables it; sessions then run their private caches).  Each
    /// session's `algo.cache_bytes` becomes its residency budget
    /// *within* this shared capacity.
    pub cache_bytes: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: crate::util::pool::default_threads(),
            fleet_cap: 4,
            queue_cap: 16,
            cache_bytes: 0,
        }
    }
}

impl ServeConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.workers == 0 {
            anyhow::bail!("serve workers must be >= 1");
        }
        if self.fleet_cap == 0 {
            anyhow::bail!("fleet_cap must be >= 1");
        }
        Ok(())
    }
}

/// Apply `key=value` overrides onto a [`ServeConfig`] (the `serve_*`
/// namespace, so serve and algo sections can share a config file).
/// Unknown keys are left for [`apply_overrides`] — the two appliers
/// partition the namespace.
pub fn apply_serve_overrides(
    cfg: &mut ServeConfig,
    kv: &[(String, String)],
) -> anyhow::Result<Vec<(String, String)>> {
    let mut rest = Vec::new();
    for (k, v) in kv {
        match k.as_str() {
            "serve_workers" => cfg.workers = v.parse()?,
            "serve_fleet_cap" => cfg.fleet_cap = v.parse()?,
            "serve_queue_cap" => cfg.queue_cap = v.parse()?,
            "serve_cache_bytes" => cfg.cache_bytes = v.parse()?,
            "serve_cache_mb" => cfg.cache_bytes = v.parse::<usize>()? << 20,
            _ => rest.push((k.clone(), v.clone())),
        }
    }
    Ok(rest)
}

/// Parse a minimal `key = value` config file (TOML subset: comments with
/// `#`, bare scalars, no tables).  Returns key/value pairs for the
/// caller to interpret; unknown keys are the caller's concern so that
/// dataset and algo sections can share a file.
pub fn parse_kv(text: &str) -> anyhow::Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() || line.starts_with('[') {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
        out.push((
            k.trim().to_string(),
            v.trim().trim_matches('"').to_string(),
        ));
    }
    Ok(out)
}

/// Apply `key=value` overrides onto an [`AlgoConfig`].
pub fn apply_overrides(cfg: &mut AlgoConfig, kv: &[(String, String)]) -> anyhow::Result<()> {
    for (k, v) in kv {
        match k.as_str() {
            "p0" => cfg.p0 = v.parse()?,
            "beta" => {
                cfg.beta = if v == "none" {
                    None
                } else {
                    Some(v.parse()?)
                }
            }
            "k" => cfg.final_k = FinalK::Fixed(v.parse()?),
            "iters" => cfg.convergence = Convergence::FixedIters(v.parse()?),
            "max_iters" => {
                cfg.convergence = Convergence::SettledSubsets {
                    max_iters: v.parse()?,
                }
            }
            "threads" => cfg.threads = v.parse()?,
            "seed" => cfg.seed = v.parse()?,
            "backend" => cfg.backend = BackendKind::parse(v)?,
            "metric" => cfg.metric = MetricKind::parse(v)?,
            "selection" => cfg.selection = SelectionMethod::parse(v)?,
            "merge_min" => cfg.merge_min = Some(v.parse()?),
            "split_shuffle" => cfg.split_shuffle = v.parse()?,
            "max_clusters_frac" => cfg.max_clusters_frac = v.parse()?,
            "cache_bytes" => cfg.cache_bytes = v.parse()?,
            "cache_mb" => cfg.cache_bytes = v.parse::<usize>()? << 20,
            "prune" => cfg.prune = PruneMode::parse(v)?,
            "aggregate_eps" => cfg.aggregate.epsilon = v.parse()?,
            "aggregate_cap" => {
                cfg.aggregate.cap = if v == "none" {
                    None
                } else {
                    Some(v.parse()?)
                }
            }
            "aggregate_batch" => cfg.aggregate.batch_rows = v.parse()?,
            "aggregate_tree" => cfg.aggregate.tree_factor = v.parse()?,
            "aggregate_probe" => cfg.aggregate.tree_probe = v.parse()?,
            "aggregate_depth" => cfg.aggregate.tree_depth = v.parse()?,
            "deviation" => cfg.deviation = DeviationMode::parse(v)?,
            "retire" => cfg.retire = RetireMode::parse(v)?,
            "aggregate_quantile" => {
                cfg.aggregate.quantile = if v == "none" {
                    None
                } else {
                    Some(v.parse()?)
                }
            }
            "aggregate_sample" => cfg.aggregate.quantile_sample = v.parse()?,
            "aggregate_quantile_seed" => cfg.aggregate.quantile_seed = v.parse()?,
            other => anyhow::bail!("unknown config key '{other}'"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn named_specs_mirror_table1_shape() {
        let a = DatasetSpec::named(NamedDataset::SmallA, 0.1);
        let b = DatasetSpec::named(NamedDataset::SmallB, 0.1);
        assert!(a.skew > b.skew);
        assert!((a.segments as f64 - 1761.0).abs() < 2.0);
        assert!(b.classes > a.classes); // B has many more, smaller classes
        let l = DatasetSpec::named(NamedDataset::Large, 0.1);
        assert!(l.segments > 4 * a.segments);
        assert_eq!(l.min_class_size, 1);
    }

    #[test]
    fn validate_catches_bad_configs() {
        let mut c = AlgoConfig::default();
        c.p0 = 0;
        assert!(c.validate().is_err());
        let mut c = AlgoConfig::default();
        c.beta = Some(1);
        assert!(c.validate().is_err());
        assert!(AlgoConfig::default().validate().is_ok());
    }

    #[test]
    fn kv_parsing_and_overrides() {
        let text = "
            # comment
            p0 = 6
            beta = 900     # inline comment
            iters = 8
            backend = \"native\"
        ";
        let kv = parse_kv(text).unwrap();
        let mut cfg = AlgoConfig::default();
        apply_overrides(&mut cfg, &kv).unwrap();
        assert_eq!(cfg.p0, 6);
        assert_eq!(cfg.beta, Some(900));
        assert_eq!(cfg.convergence, Convergence::FixedIters(8));
    }

    #[test]
    fn cache_keys_parse() {
        let mut cfg = AlgoConfig::default();
        assert_eq!(cfg.cache_bytes, 0, "cache off by default");
        apply_overrides(
            &mut cfg,
            &[("cache_mb".to_string(), "64".to_string())],
        )
        .unwrap();
        assert_eq!(cfg.cache_bytes, 64 << 20);
        apply_overrides(
            &mut cfg,
            &[("cache_bytes".to_string(), "4096".to_string())],
        )
        .unwrap();
        assert_eq!(cfg.cache_bytes, 4096);
        assert_eq!(
            AlgoConfig::default().with_cache_bytes(123).cache_bytes,
            123
        );
    }

    #[test]
    fn backend_key_accepts_all_kinds() {
        let mut cfg = AlgoConfig::default();
        for (value, want) in [
            ("blocked", BackendKind::Blocked),
            ("scalar", BackendKind::Native),
            ("native", BackendKind::Native),
            ("xla", BackendKind::Xla),
        ] {
            apply_overrides(
                &mut cfg,
                &[("backend".to_string(), value.to_string())],
            )
            .unwrap();
            assert_eq!(cfg.backend, want, "backend = {value}");
        }
        assert!(apply_overrides(
            &mut cfg,
            &[("backend".to_string(), "gpu".to_string())]
        )
        .is_err());
    }

    #[test]
    fn aggregate_config_defaults_and_validation() {
        let off = AggregateConfig::default();
        assert_eq!(off.epsilon, 0.0);
        assert_eq!(off.cap, None);
        assert_eq!(off.batch_rows, 64, "rectangle probing is the default");
        assert_eq!(off.tree_factor, 0.0, "flat probing is the default");
        assert_eq!(off.tree_probe, 2);
        assert_eq!(off.quantile, None);
        assert!(!off.is_active(), "epsilon 0 means aggregation off");
        assert!(off.validate().is_ok());

        let on = AggregateConfig::new(1.5).with_cap(32);
        assert!(on.is_active());
        assert_eq!(on.cap, Some(32));
        assert!(on.validate().is_ok());

        assert!(AggregateConfig::new(-0.1).validate().is_err());
        assert!(AggregateConfig::new(f32::NAN).validate().is_err());
        assert!(AggregateConfig::new(f32::INFINITY).validate().is_err());
        assert!(AggregateConfig::new(1.0).with_cap(0).validate().is_err());
        let bad_batch = AggregateConfig::new(1.0).with_batch_rows(0);
        assert!(bad_batch.validate().is_err());
        for (factor, probe) in [(-1.0, 2), (f32::NAN, 2), (3.0, 0)] {
            let bad_tree = AggregateConfig::new(1.0).with_tree(factor, probe);
            assert!(bad_tree.validate().is_err(), "factor {factor} probe {probe}");
        }
        let ok_tree = AggregateConfig::new(1.0).with_tree(3.0, 2);
        assert!(ok_tree.validate().is_ok());

        // Quantile mode: q must lie strictly inside (0, 1), the sample
        // must contain at least one pair, and any in-range q activates
        // the pass even at ε = 0.
        for q in [0.0, 1.0, -0.25, 1.5, f64::NAN] {
            let bad = AggregateConfig::default().with_quantile(q);
            assert!(bad.validate().is_err(), "q = {q} must be rejected");
        }
        let quant = AggregateConfig::default().with_quantile(0.25);
        assert!(quant.validate().is_ok());
        assert!(quant.is_active(), "a quantile radius activates the pass");
        assert!(quant.with_quantile_sample(1).validate().is_err());

        // AlgoConfig validation surfaces aggregate errors too.
        let mut cfg = AlgoConfig::default();
        cfg.aggregate.epsilon = -1.0;
        assert!(cfg.validate().is_err());
        assert_eq!(
            AlgoConfig::default()
                .with_aggregate(AggregateConfig::new(2.0))
                .aggregate
                .epsilon,
            2.0
        );
    }

    #[test]
    fn aggregate_keys_parse() {
        let mut cfg = AlgoConfig::default();
        apply_overrides(
            &mut cfg,
            &[
                ("aggregate_eps".to_string(), "3.25".to_string()),
                ("aggregate_cap".to_string(), "40".to_string()),
            ],
        )
        .unwrap();
        assert_eq!(cfg.aggregate.epsilon, 3.25);
        assert_eq!(cfg.aggregate.cap, Some(40));
        apply_overrides(
            &mut cfg,
            &[("aggregate_cap".to_string(), "none".to_string())],
        )
        .unwrap();
        assert_eq!(cfg.aggregate.cap, None);
    }

    #[test]
    fn aggregate_probe_engine_keys_parse() {
        let mut cfg = AlgoConfig::default();
        apply_overrides(
            &mut cfg,
            &[
                ("aggregate_batch".to_string(), "1".to_string()),
                ("aggregate_tree".to_string(), "3.0".to_string()),
                ("aggregate_probe".to_string(), "4".to_string()),
                ("aggregate_quantile".to_string(), "0.25".to_string()),
                ("aggregate_sample".to_string(), "128".to_string()),
                ("aggregate_quantile_seed".to_string(), "99".to_string()),
            ],
        )
        .unwrap();
        assert_eq!(cfg.aggregate.batch_rows, 1);
        assert_eq!(cfg.aggregate.tree_factor, 3.0);
        assert_eq!(cfg.aggregate.tree_probe, 4);
        assert_eq!(cfg.aggregate.quantile, Some(0.25));
        assert_eq!(cfg.aggregate.quantile_sample, 128);
        assert_eq!(cfg.aggregate.quantile_seed, 99);
        apply_overrides(
            &mut cfg,
            &[("aggregate_quantile".to_string(), "none".to_string())],
        )
        .unwrap();
        assert_eq!(cfg.aggregate.quantile, None);
        // Builder forms mirror the keys.
        let b = AggregateConfig::new(1.0)
            .with_batch_rows(8)
            .with_tree(2.5, 3)
            .with_quantile(0.5)
            .with_quantile_sample(64);
        assert_eq!(b.batch_rows, 8);
        assert_eq!(b.tree_factor, 2.5);
        assert_eq!(b.tree_probe, 3);
        assert_eq!(b.quantile, Some(0.5));
        assert_eq!(b.quantile_sample, 64);
    }

    #[test]
    fn aggregate_depth_key_parses_and_validates() {
        let d = AggregateConfig::default();
        assert_eq!(d.tree_depth, 2, "historical two-level tree by default");
        let mut cfg = AlgoConfig::default();
        apply_overrides(
            &mut cfg,
            &[("aggregate_depth".to_string(), "3".to_string())],
        )
        .unwrap();
        assert_eq!(cfg.aggregate.tree_depth, 3);
        assert_eq!(AggregateConfig::new(1.0).with_depth(4).tree_depth, 4);
        assert!(AggregateConfig::new(1.0).with_depth(1).validate().is_ok());
        assert!(AggregateConfig::new(1.0).with_depth(0).validate().is_err());
    }

    #[test]
    fn deviation_mode_parses_and_defaults_report() {
        assert_eq!(AlgoConfig::default().deviation, DeviationMode::Report);
        assert!(!DeviationMode::default().is_debug());
        for (value, want) in [
            ("report", DeviationMode::Report),
            ("on", DeviationMode::Report),
            ("debug", DeviationMode::Debug),
            ("verify", DeviationMode::Debug),
        ] {
            let mut cfg = AlgoConfig::default();
            apply_overrides(
                &mut cfg,
                &[("deviation".to_string(), value.to_string())],
            )
            .unwrap();
            assert_eq!(cfg.deviation, want, "deviation = {value}");
            assert_eq!(DeviationMode::parse(want.name()).unwrap(), want, "round-trip");
        }
        assert!(DeviationMode::parse("maybe").is_err());
        assert!(DeviationMode::Debug.is_debug());
        assert_eq!(
            AlgoConfig::default()
                .with_deviation(DeviationMode::Debug)
                .deviation,
            DeviationMode::Debug
        );
    }

    #[test]
    fn retire_mode_parses_and_defaults_leader() {
        assert_eq!(AlgoConfig::default().retire, RetireMode::Leader);
        assert!(!RetireMode::default().is_medoid());
        for (value, want) in [
            ("leader", RetireMode::Leader),
            ("default", RetireMode::Leader),
            ("medoid", RetireMode::Medoid),
            ("nearest", RetireMode::Medoid),
        ] {
            let mut cfg = AlgoConfig::default();
            apply_overrides(&mut cfg, &[("retire".to_string(), value.to_string())]).unwrap();
            assert_eq!(cfg.retire, want, "retire = {value}");
            assert_eq!(RetireMode::parse(want.name()).unwrap(), want, "round-trip");
        }
        assert!(RetireMode::parse("drop").is_err());
        assert!(RetireMode::Medoid.is_medoid());
        assert_eq!(
            AlgoConfig::default().with_retire(RetireMode::Medoid).retire,
            RetireMode::Medoid
        );
    }

    #[test]
    fn prune_mode_parses_and_defaults_off() {
        assert_eq!(AlgoConfig::default().prune, PruneMode::Off);
        assert!(!PruneMode::default().is_active());
        for (value, want) in [
            ("off", PruneMode::Off),
            ("exact", PruneMode::Off),
            ("on", PruneMode::On),
            ("lb", PruneMode::On),
            ("debug", PruneMode::Debug),
            ("verify", PruneMode::Debug),
        ] {
            let mut cfg = AlgoConfig::default();
            apply_overrides(
                &mut cfg,
                &[("prune".to_string(), value.to_string())],
            )
            .unwrap();
            assert_eq!(cfg.prune, want, "prune = {value}");
            assert_eq!(PruneMode::parse(want.name()).unwrap(), want, "round-trip");
        }
        assert!(PruneMode::parse("sometimes").is_err());
        assert!(PruneMode::On.is_active() && PruneMode::Debug.is_active());
        assert_eq!(
            AlgoConfig::default().with_prune(PruneMode::On).prune,
            PruneMode::On
        );
    }

    #[test]
    fn metric_and_selection_keys_round_trip() {
        assert_eq!(AlgoConfig::default().metric, MetricKind::Dtw);
        assert_eq!(AlgoConfig::default().selection, SelectionMethod::LMethod);
        for (value, want) in [
            ("dtw", MetricKind::Dtw),
            ("cosine", MetricKind::Cosine),
            ("euclidean", MetricKind::Euclidean),
            ("l2", MetricKind::Euclidean),
        ] {
            let mut cfg = AlgoConfig::default();
            apply_overrides(&mut cfg, &[("metric".to_string(), value.to_string())]).unwrap();
            assert_eq!(cfg.metric, want, "metric = {value}");
            assert_eq!(MetricKind::parse(want.name()).unwrap(), want, "round-trip");
        }
        for (value, want) in [
            ("lmethod", SelectionMethod::LMethod),
            ("l-method", SelectionMethod::LMethod),
            ("silhouette", SelectionMethod::Silhouette),
        ] {
            let mut cfg = AlgoConfig::default();
            apply_overrides(&mut cfg, &[("selection".to_string(), value.to_string())]).unwrap();
            assert_eq!(cfg.selection, want, "selection = {value}");
            assert_eq!(SelectionMethod::parse(want.name()).unwrap(), want, "round-trip");
        }
        assert!(MetricKind::parse("hamming").is_err());
        assert!(SelectionMethod::parse("gap").is_err());
        let built = AlgoConfig::default()
            .with_metric(MetricKind::Cosine)
            .with_selection(SelectionMethod::Silhouette);
        assert_eq!(built.metric, MetricKind::Cosine);
        assert_eq!(built.selection, SelectionMethod::Silhouette);
    }

    #[test]
    fn incoherent_metric_combos_reject_with_typed_errors() {
        // Cosine has no admissible lower bound: every active prune mode
        // must be rejected, and the error must downcast to the typed
        // variant (no panic, no stringly-typed matching).
        for prune in [PruneMode::On, PruneMode::Debug] {
            let cfg = AlgoConfig::default()
                .with_metric(MetricKind::Cosine)
                .with_prune(prune);
            let err = cfg.validate().unwrap_err();
            match err.downcast_ref::<MetricConfigError>() {
                Some(MetricConfigError::PruneUnsupported { metric, prune: p }) => {
                    assert_eq!(*metric, MetricKind::Cosine);
                    assert_eq!(*p, prune);
                }
                other => panic!("expected PruneUnsupported, got {other:?}"),
            }
        }
        // Euclidean has the norm bound, DTW the envelope bound: both
        // accept pruning.
        for metric in [MetricKind::Dtw, MetricKind::Euclidean] {
            let cfg = AlgoConfig::default()
                .with_metric(metric)
                .with_prune(PruneMode::Debug);
            assert!(cfg.validate().is_ok(), "{} + prune", metric.name());
        }
        // The XLA kernel is DTW-only.
        let mut cfg = AlgoConfig::default().with_metric(MetricKind::Euclidean);
        cfg.backend = BackendKind::Xla;
        let err = cfg.validate().unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<MetricConfigError>(),
                Some(MetricConfigError::BackendUnsupported { .. })
            ),
            "expected BackendUnsupported, got {err:?}"
        );
        cfg.metric = MetricKind::Dtw;
        assert!(cfg.validate().is_ok(), "xla + dtw stays legal");
    }

    #[test]
    fn unknown_key_rejected() {
        let mut cfg = AlgoConfig::default();
        let kv = vec![("bogus".to_string(), "1".to_string())];
        assert!(apply_overrides(&mut cfg, &kv).is_err());
    }

    #[test]
    fn stream_config_validation() {
        let ok = StreamConfig::new(AlgoConfig::default(), 64);
        assert!(ok.validate().is_ok());
        assert_eq!(ok.shard_seed, None, "corpus order by default");
        let seeded = StreamConfig::new(AlgoConfig::default(), 64).with_shard_seed(9);
        assert_eq!(seeded.shard_seed, Some(9));
        let bad = StreamConfig::new(AlgoConfig::default(), 0);
        assert!(bad.validate().is_err());
        // Algo-level errors surface through the stream validator too.
        let mut algo = AlgoConfig::default();
        algo.p0 = 0;
        assert!(StreamConfig::new(algo, 64).validate().is_err());
    }

    #[test]
    fn dataset_parse_aliases() {
        assert_eq!(NamedDataset::parse("a").unwrap(), NamedDataset::SmallA);
        assert_eq!(NamedDataset::parse("medium").unwrap(), NamedDataset::Medium);
        assert!(NamedDataset::parse("nope").is_err());
    }

    #[test]
    fn serve_config_defaults_validation_and_overrides() {
        let d = ServeConfig::default();
        assert!(d.workers >= 1);
        assert_eq!(d.fleet_cap, 4);
        assert_eq!(d.cache_bytes, 0, "fleet cache off by default");
        assert!(d.validate().is_ok());

        let mut bad = ServeConfig::default();
        bad.workers = 0;
        assert!(bad.validate().is_err());
        let mut bad = ServeConfig::default();
        bad.fleet_cap = 0;
        assert!(bad.validate().is_err());

        // The serve applier consumes its namespace and hands the rest
        // to the algo applier untouched.
        let mut cfg = ServeConfig::default();
        let kv = parse_kv(
            "serve_workers = 3\nserve_fleet_cap = 8\nserve_queue_cap = 2\n\
             serve_cache_mb = 16\nbeta = 64\n",
        )
        .unwrap();
        let rest = apply_serve_overrides(&mut cfg, &kv).unwrap();
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.fleet_cap, 8);
        assert_eq!(cfg.queue_cap, 2);
        assert_eq!(cfg.cache_bytes, 16 << 20);
        assert_eq!(rest, vec![("beta".to_string(), "64".to_string())]);
        let mut algo = AlgoConfig::default();
        apply_overrides(&mut algo, &rest).unwrap();
        assert_eq!(algo.beta, Some(64));

        let mut cfg = ServeConfig::default();
        let kv = vec![("serve_cache_bytes".to_string(), "4096".to_string())];
        apply_serve_overrides(&mut cfg, &kv).unwrap();
        assert_eq!(cfg.cache_bytes, 4096);
    }
}
