//! Cluster-feature summaries for stage-0 groups, and the deviation
//! bound they buy (Schubert & Lang 2023, arXiv 2309.02552).
//!
//! A leader group is no longer just a representative segment: it
//! carries a [`GroupSummary`] `(count, radius, spread)` where `radius`
//! is the largest join distance any member was absorbed at (≤ ε by the
//! join rule) and `spread` is the fixed-order f32 sum of those join
//! distances.  Both are maintained *incrementally* at the single place
//! a member joins a group, so the summation order is the deterministic
//! join order — the same left-to-right fixed order
//! [`crate::distance::fixed_order_sum`] prescribes, making the values
//! bitwise reproducible across thread counts and backends (R003-clean
//! by construction: there is no parallel reduction to reorder).
//!
//! Summaries compose up the leader tree with [`GroupSummary::merge`]:
//! folding child `b` into parent `a` whose leaders sit `link` apart
//! gives `radius' = max(r_a, link + r_b)` and
//! `spread' = s_a + count_b·link + s_b` — triangle-inequality upper
//! bounds on the true member-to-parent-leader quantities, exact when
//! the backend's distance is a metric (the vector metrics; DTW violates
//! the triangle inequality, so for DTW the folded values are the same
//! principled estimate the tree itself is).
//!
//! Deviation bound.  Replacing every member by its leader perturbs any
//! inter-group distance by at most `r_a + r_b ≤ 2·r_max`; the Ward2
//! count-scaling `√(2·n_a·n_b/(n_a+n_b)) ≤ √(2·min(n_a,n_b))` amplifies
//! that by at most `√(2·c_max)`.  The bound reported per run is
//! therefore `2·r_max·√(2·c_max)` — zero exactly when aggregation is
//! off, the pass collapsed nothing, or every group has zero radius
//! (duplicate collapse), in which case count-weighted linkage over
//! representatives reproduces the full-corpus Ward heights and
//! [`check_deviation`] (the `--deviation debug` tripwire) verifies that
//! merge by merge against the O(N²) full-AHC oracle.

use crate::ahc::{ward_linkage, ward_linkage_weighted};
use crate::corpus::{Segment, SegmentSet};
use crate::distance::{build_condensed_cached, Condensed, PairwiseBackend, PairCache};

use super::Aggregation;

/// Cluster-feature summary of one leader group: member count, the
/// largest member→leader join distance, and the fixed-order sum of all
/// join distances.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupSummary {
    /// Members in the group, the leader included.
    pub count: usize,
    /// Max distance from any member to the group leader (0 for a
    /// singleton; ≤ ε for flat-pass groups).
    pub radius: f32,
    /// Sum of member→leader distances in join order (fixed-order f32).
    pub spread: f32,
}

impl GroupSummary {
    /// The summary of a freshly-founded group: the leader alone.
    pub fn singleton() -> GroupSummary {
        GroupSummary {
            count: 1,
            radius: 0.0,
            spread: 0.0,
        }
    }

    /// Absorb one member that joined at distance `dist` from the
    /// leader.  Called exactly once per join, in join order, so the
    /// f32 accumulation order is the deterministic visit order.
    pub fn absorb(&mut self, dist: f32) {
        self.count += 1;
        self.radius = self.radius.max(dist);
        self.spread += dist;
    }

    /// Fold child summary `b` into `self` when the two leaders sit
    /// `link` apart; the merged summary is anchored at `self`'s leader.
    /// Triangle inequality: every member of `b` is within
    /// `link + b.radius` of `self`'s leader, and its distance is at
    /// most `link` plus its own join distance.
    pub fn merge(&self, b: &GroupSummary, link: f32) -> GroupSummary {
        GroupSummary {
            count: self.count + b.count,
            radius: self.radius.max(link + b.radius),
            spread: self.spread + (b.count as f32 * link + b.spread),
        }
    }
}

/// Rescale a condensed distance matrix so unweighted Ward2 linkage
/// initialised with `sizes` reproduces full-corpus Ward over the
/// groups each object stands for: `d'_ab = √(2·n_a·n_b/(n_a+n_b))·d_ab`
/// (the Ward2 inter-cluster distance of two pre-merged clusters whose
/// members all sit at their representative).  All-ones sizes give the
/// factor √1 = 1 exactly, so the identity path is bitwise unscaled.
/// Elementwise (no reduction), f64 intermediates — R003-safe.
pub fn scale_condensed_by_counts(cond: &Condensed, sizes: &[usize]) -> Condensed {
    let n = cond.n();
    debug_assert_eq!(sizes.len(), n);
    let mut out = cond.clone();
    for i in 0..n {
        for j in 0..i {
            let (ni, nj) = (sizes[i] as f64, sizes[j] as f64); // lint: in-bounds sizes is parallel to the condensed row order
            let w = (2.0 * ni * nj / (ni + nj)).sqrt();
            out.set(i, j, (w * cond.get(i, j) as f64) as f32);
        }
    }
    out
}

/// The `--deviation debug` tripwire: rebuild the full-corpus Ward
/// dendrogram (O(N²) — debug mode only) and the count-weighted
/// representative dendrogram, and verify every representative-level
/// merge height sits within the reported deviation bound of its
/// full-AHC counterpart.  Returns the largest observed |Δheight|;
/// errors on the first violating merge.
///
/// The comparison pairs the sorted representative heights with the top
/// `m − 1` sorted full-corpus heights (the merges above the
/// aggregation level; the `N − m` below are the intra-group joins).
/// An f32 slack of `1e-4 · max(|h_full|, |h_agg|, 1)` per merge covers
/// accumulation noise in the Lance-Williams recursion, mirroring the
/// linkage test tolerance.
pub fn check_deviation(
    set: &SegmentSet,
    agg: &Aggregation,
    backend: &dyn PairwiseBackend,
    threads: usize,
    cache: Option<&PairCache>,
) -> anyhow::Result<f64> {
    let n = set.len();
    let m = agg.reps();
    if m < 2 || n < 2 || agg.is_identity() {
        return Ok(0.0);
    }
    anyhow::ensure!(
        n == agg.total,
        "aggregation covers {} segments but the corpus has {n}",
        agg.total
    );
    let bound = agg.deviation_bound();

    let full_refs: Vec<&Segment> = set.segments.iter().collect();
    let full_cond = build_condensed_cached(&full_refs, backend, threads, cache)?;
    let mut full_h = ward_linkage(&full_cond).merge_heights();
    full_h.sort_unstable_by(f32::total_cmp);

    let rep_refs: Vec<&Segment> = agg.rep_ids.iter().map(|&id| &set.segments[id]).collect(); // lint: in-bounds rep_ids are segment ids of this corpus
    let rep_cond = build_condensed_cached(&rep_refs, backend, threads, cache)?;
    let sizes: Vec<usize> = agg.members.iter().map(|ms| ms.len()).collect();
    let scaled = scale_condensed_by_counts(&rep_cond, &sizes);
    let mut agg_h = ward_linkage_weighted(&scaled, &sizes).merge_heights();
    agg_h.sort_unstable_by(f32::total_cmp);

    anyhow::ensure!(
        full_h.len() == n - 1 && agg_h.len() == m - 1,
        "dendrogram sizes {} / {} for corpus {n} aggregated to {m}",
        full_h.len(),
        agg_h.len()
    );
    let mut max_delta = 0.0f64;
    for (rank, (&hf, &ha)) in full_h[(n - 1) - (m - 1)..].iter().zip(&agg_h).enumerate() { // lint: in-bounds slice start: n >= m so n-1 >= m-1
        let delta = (hf as f64 - ha as f64).abs();
        let slack = 1e-4 * (hf.abs() as f64).max(ha.abs() as f64).max(1.0);
        anyhow::ensure!(
            delta <= bound + slack,
            "deviation bound violated at merge {rank}: full height {hf} vs \
             aggregated {ha} (|Δ| = {delta:.6e} > bound {bound:.6e} + slack {slack:.6e})"
        );
        max_delta = max_delta.max(delta);
    }
    Ok(max_delta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn singleton_absorb_tracks_count_radius_and_ordered_spread() {
        let mut s = GroupSummary::singleton();
        assert_eq!((s.count, s.radius, s.spread), (1, 0.0, 0.0));
        s.absorb(0.3);
        s.absorb(0.1);
        s.absorb(0.2);
        assert_eq!(s.count, 4);
        assert_eq!(s.radius, 0.3);
        // Fixed-order sum: ((0.3 + 0.1) + 0.2), bitwise.
        assert_eq!(s.spread, (0.3f32 + 0.1) + 0.2);
    }

    #[test]
    fn merge_adds_counts_and_upper_bounds_radius_and_spread() {
        let mut a = GroupSummary::singleton();
        a.absorb(0.2);
        let mut b = GroupSummary::singleton();
        b.absorb(0.4);
        b.absorb(0.1);
        let m = a.merge(&b, 1.0);
        assert_eq!(m.count, 5);
        assert_eq!(m.radius, 1.0 + 0.4);
        assert_eq!(m.spread, a.spread + (3.0 * 1.0 + b.spread));
        // Merging a distant singleton only moves the radius if the link
        // exceeds it.
        let far = a.merge(&GroupSummary::singleton(), 0.05);
        assert_eq!(far.radius, 0.2);
        assert_eq!(far.count, 3);
    }

    #[test]
    fn scaling_is_identity_for_unit_counts_and_ward_exact_for_pairs() {
        let mut cond = Condensed::zeros(3);
        cond.set(1, 0, 2.0);
        cond.set(2, 0, 5.0);
        cond.set(2, 1, 4.0);
        let unit = scale_condensed_by_counts(&cond, &[1, 1, 1]);
        for i in 0..3 {
            for j in 0..i {
                assert_eq!(unit.get(i, j).to_bits(), cond.get(i, j).to_bits());
            }
        }
        // Two duplicate-pairs at distance d merge at √2·d under full
        // Ward; the scaled representative distance must equal that.
        let scaled = scale_condensed_by_counts(&cond, &[2, 2, 1]);
        let want = (2.0f64 * 2.0 * 2.0 / 4.0).sqrt() * 2.0;
        assert!((scaled.get(1, 0) as f64 - want).abs() < 1e-6);
        // Size-2 vs size-1 group: factor √(4/3).
        let want21 = (2.0f64 * 2.0 * 1.0 / 3.0).sqrt() * 5.0;
        assert!((scaled.get(2, 0) as f64 - want21).abs() < 1e-6);
    }
}
