//! Quantile-derived leader radius: estimate ε from the data instead of
//! asking the user for an absolute DTW distance.
//!
//! ε is corpus-dependent — the sweep harnesses have always derived
//! their radii from pair-distance quantiles of the corpus itself — so
//! `--aggregate-quantile q` moves that derivation into the product: a
//! seeded sample of segments is drawn, the condensed distance matrix
//! over the sample is built (through the run's backend and cache, so
//! the estimate is backend-invariant and its pairs pre-warm stage 1),
//! and ε is read off the sorted pair distances at the empirical
//! quantile rank.
//!
//! The estimator is exact when the sample covers the corpus and
//! deterministic for any (seed, sample size, corpus) triple — pinned in
//! `rust/tests/aggregation.rs` together with the sampling tolerance.

use crate::corpus::{Segment, SegmentSet};
use crate::distance::{build_condensed_cached, PairwiseBackend, PairCache};
use crate::util::rng::Rng;

/// Empirical quantile of a sorted slice: the value at the lower rank
/// ⌊(P−1)·q⌋ — the same rule the sweep example and bench use, so a
/// quantile-configured run reproduces their radii bit for bit.  Total
/// over its whole domain: an empty slice yields 0.0 and q is clamped
/// to [0, 1], so the public export cannot index out of bounds.
pub fn quantile_of_sorted(sorted: &[f32], q: f64) -> f32 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((sorted.len() - 1) as f64 * q.clamp(0.0, 1.0)) as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// What [`derive_epsilon`] measured: the radius plus the effective
/// sample the estimate was computed over, so telemetry can report how
/// much evidence backed the ε a run used.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpsilonEstimate {
    /// The derived leader radius.
    pub epsilon: f32,
    /// Pair distances the estimate consumed (C(sample_segments, 2)).
    pub sample_pairs: usize,
    /// Segments actually sampled after clamping to the corpus size —
    /// may be smaller than the configured sample, never larger.
    pub sample_segments: usize,
}

/// Estimate the leader radius ε as the `q` pair-distance quantile of a
/// seeded corpus sample.
///
/// Draws `sample` distinct segments with the repo RNG seeded from
/// `seed` (the whole corpus when `sample >= n`), builds the condensed
/// matrix over the sample, and returns the estimate together with its
/// effective sample size.  A `sample` below 2 is a configuration error
/// (one segment has no pairs, so the caller would silently get a radius
/// backed by whatever this function substituted — reject instead of
/// clamping up).  A corpus with fewer than two segments has no pairs;
/// the estimate degrades to 0.
pub fn derive_epsilon(
    set: &SegmentSet,
    q: f64,
    sample: usize,
    seed: u64,
    backend: &dyn PairwiseBackend,
    threads: usize,
    cache: Option<&PairCache>,
) -> anyhow::Result<EpsilonEstimate> {
    anyhow::ensure!(
        q.is_finite() && q > 0.0 && q < 1.0,
        "aggregate quantile must lie strictly inside (0, 1) (got {q})"
    );
    anyhow::ensure!(
        sample >= 2,
        "aggregate sample must cover at least 2 segments to have a pair \
         distance (got {sample})"
    );
    let n = set.len();
    if n < 2 {
        return Ok(EpsilonEstimate {
            epsilon: 0.0,
            sample_pairs: 0,
            sample_segments: n,
        });
    }
    let s = sample.min(n);
    // Sorted sample ids: the multiset of pair distances is order-free,
    // sorting just keeps the condensed build's probe order canonical.
    let mut ids = Rng::seed_from(seed).sample_indices(n, s);
    ids.sort_unstable();
    let segs: Vec<&Segment> = ids.iter().map(|&i| &set.segments[i]).collect();
    let cond = build_condensed_cached(&segs, backend, threads, cache)?;
    let mut dists: Vec<f32> = cond.as_slice().to_vec();
    dists.sort_unstable_by(f32::total_cmp);
    Ok(EpsilonEstimate {
        epsilon: quantile_of_sorted(&dists, q),
        sample_pairs: dists.len(),
        sample_segments: s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetSpec;
    use crate::corpus::generate;
    use crate::distance::{build_condensed, NativeBackend};

    #[test]
    fn full_sample_is_the_exact_corpus_quantile() {
        let set = generate(&DatasetSpec::tiny(30, 3, 301));
        let backend = NativeBackend::new();
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let cond = build_condensed(&refs, &backend, 4).unwrap();
        let mut exact: Vec<f32> = cond.as_slice().to_vec();
        exact.sort_unstable_by(f32::total_cmp);
        for q in [0.05, 0.25, 0.5, 0.9] {
            let est = derive_epsilon(&set, q, set.len(), 7, &backend, 4, None).unwrap();
            assert_eq!(est.sample_pairs, exact.len());
            assert_eq!(est.sample_segments, set.len());
            assert_eq!(
                est.epsilon.to_bits(),
                quantile_of_sorted(&exact, q).to_bits(),
                "q = {q}"
            );
        }
    }

    #[test]
    fn estimate_is_seed_and_thread_deterministic() {
        let set = generate(&DatasetSpec::tiny(40, 4, 302));
        let backend = NativeBackend::new();
        let a = derive_epsilon(&set, 0.5, 16, 11, &backend, 1, None).unwrap();
        for threads in [1usize, 4, 8] {
            let b = derive_epsilon(&set, 0.5, 16, 11, &backend, threads, None).unwrap();
            assert_eq!(a.epsilon.to_bits(), b.epsilon.to_bits(), "threads = {threads}");
            assert_eq!(a.sample_pairs, b.sample_pairs);
            assert_eq!(a.sample_segments, b.sample_segments);
        }
        assert_eq!(a.sample_pairs, 16 * 15 / 2, "sample of 16 has C(16,2) pairs");
        assert_eq!(a.sample_segments, 16);
    }

    #[test]
    fn rejects_degenerate_quantiles() {
        let set = generate(&DatasetSpec::tiny(10, 2, 303));
        let backend = NativeBackend::new();
        for q in [0.0, 1.0, -0.5, 2.0, f64::NAN] {
            assert!(
                derive_epsilon(&set, q, 10, 1, &backend, 1, None).is_err(),
                "q = {q} must be rejected"
            );
        }
    }

    #[test]
    fn rejects_pairless_samples_instead_of_clamping_up() {
        let set = generate(&DatasetSpec::tiny(10, 2, 303));
        let backend = NativeBackend::new();
        for sample in [0usize, 1] {
            let err = derive_epsilon(&set, 0.5, sample, 1, &backend, 1, None)
                .unwrap_err()
                .to_string();
            assert!(
                err.contains("at least 2 segments"),
                "sample = {sample}: {err}"
            );
        }
        // Oversized samples still clamp *down* to the corpus.
        let est = derive_epsilon(&set, 0.5, 1_000, 1, &backend, 1, None).unwrap();
        assert_eq!(est.sample_segments, set.len());
        assert_eq!(est.sample_pairs, set.len() * (set.len() - 1) / 2);
    }

    #[test]
    fn quantile_of_sorted_is_total() {
        assert_eq!(quantile_of_sorted(&[], 0.5), 0.0);
        let one = [2.5f32];
        assert_eq!(quantile_of_sorted(&one, 0.0), 2.5);
        assert_eq!(quantile_of_sorted(&one, 2.0), 2.5, "q is clamped");
        let four = [1.0f32, 2.0, 3.0, 4.0];
        assert_eq!(quantile_of_sorted(&four, 0.5), 2.0, "lower rank ⌊(P−1)q⌋");
        assert_eq!(quantile_of_sorted(&four, -1.0), 1.0);
        assert_eq!(quantile_of_sorted(&four, 1.0), 4.0);
    }

    #[test]
    fn tiny_corpora_degrade_to_zero() {
        let mut set = generate(&DatasetSpec::tiny(8, 2, 304));
        set.segments.truncate(1);
        let est = derive_epsilon(&set, 0.5, 64, 1, &NativeBackend::new(), 1, None).unwrap();
        assert_eq!(est.epsilon, 0.0);
        assert_eq!(est.sample_pairs, 0);
        assert_eq!(est.sample_segments, 1);
    }
}
