//! The deterministic leader (canopy) pass over a segment corpus, with a
//! rectangle-batched probe engine and an optional two-level leader tree.
//!
//! Segments are visited in id order.  Each segment probes the DTW
//! distance to candidate representatives (through [`build_cross_cached`],
//! so probes land in the cross-iteration [`PairCache`] and stage 1 never
//! recomputes them) and joins the *nearest* candidate with distance ≤ ε
//! under the occupancy cap; otherwise it becomes a new representative.
//! Visit order and the strict `<` nearest rule (ties to the earliest
//! representative) make the grouping independent of thread count and —
//! because the scalar and blocked backends are bitwise equal — of
//! backend choice.
//!
//! Probe engine.  Pending segments are grouped into rounds of
//! `batch_rows` and dispatched against the candidate set as *one cross
//! rectangle*, so the blocked backend's 8-lane kernel engages instead
//! of degenerating to one serial row per segment.  Leaders born inside
//! a round are probed by the round's later segments as short incremental
//! rows, which keeps the decision sequence — and therefore the groups —
//! bitwise identical to the historical per-row path (`batch_rows = 1`
//! *is* that path, kept reachable as the parity suite's reference).
//!
//! Two-level tree.  With `tree_factor > 0`, every leader is attached to
//! its nearest *super-leader* within radius `tree_factor`·ε (or founds a
//! new one), and a segment only probes the leaders under its
//! `tree_probe` nearest super-groups — probe cost scales with the tree
//! fan-out instead of m.  DTW is not a metric, so the tree may prune a
//! would-be leader out of sight; degenerate configurations where it
//! cannot prune (one covering super-group, singleton super-groups with
//! an unambiguous nearest, cap-saturated groups) reproduce the flat
//! pass exactly and are pinned in `rust/tests/aggregation.rs`.
//!
//! ε itself is either given absolutely or derived from a pair-distance
//! quantile of a seeded corpus sample ([`super::quantile`]).

use crate::config::AggregateConfig;
use crate::corpus::{Segment, SegmentSet};
use crate::distance::{build_cross_cached, build_cross_cached_pruned, PairwiseBackend, PairCache};

/// Result of the leader pass: `m` representatives plus the membership
/// lists that map them back onto the full corpus, and the probe-engine
/// telemetry the drivers surface per run.
#[derive(Debug, Clone)]
pub struct Aggregation {
    /// Global segment id of each representative, in discovery (= id)
    /// order.
    pub rep_ids: Vec<usize>,
    /// Member ids (global, leader first) per representative, parallel
    /// to `rep_ids`.
    pub members: Vec<Vec<usize>>,
    /// Representative index (into `rep_ids`) per segment id.
    pub rep_of: Vec<usize>,
    /// DTW pair probes the pass issued (rectangle cells plus incremental
    /// rows; a cache-served probe still counts — it was issued).
    pub probe_pairs: usize,
    /// Pair distances consumed by the quantile-ε estimate (0 when ε was
    /// given absolutely).
    pub sample_pairs: usize,
    /// Segments the quantile-ε estimate sampled after clamping to the
    /// corpus (0 when ε was given absolutely).
    pub sample_segments: usize,
    /// Probe rounds the pass ran (= N on the per-row reference path).
    pub probe_rounds: usize,
    /// Rows of the largest probe rectangle dispatched.
    pub rect_rows: usize,
    /// Columns of the largest probe rectangle dispatched.
    pub rect_cols: usize,
    /// Super-leaders of the two-level tree (0 = flat probing).
    pub super_leaders: usize,
    /// Effective leader radius ε (quantile-derived when configured).
    pub epsilon: f32,
    /// Corpus size N the pass ran over.
    pub total: usize,
}

impl Aggregation {
    /// The no-op aggregation (ε = 0): every segment represents itself.
    pub fn identity(n: usize) -> Aggregation {
        Aggregation {
            rep_ids: (0..n).collect(),
            members: (0..n).map(|i| vec![i]).collect(),
            rep_of: (0..n).collect(),
            probe_pairs: 0,
            sample_pairs: 0,
            sample_segments: 0,
            probe_rounds: 0,
            rect_rows: 0,
            rect_cols: 0,
            super_leaders: 0,
            epsilon: 0.0,
            total: n,
        }
    }

    /// Number of representatives m.
    pub fn reps(&self) -> usize {
        self.rep_ids.len()
    }

    /// m / N — 1.0 means no compression, smaller is better.
    pub fn compression_ratio(&self) -> f64 {
        if self.total == 0 {
            1.0
        } else {
            self.reps() as f64 / self.total as f64
        }
    }

    /// Whether every segment is its own representative.
    pub fn is_identity(&self) -> bool {
        self.reps() == self.total
    }
}

/// Super-leader state of the two-level tree.
struct Tree {
    /// Coarse radius `tree_factor`·ε.
    coarse: f32,
    /// Super-groups a segment descends into (the fan-out).
    probe: usize,
    /// Leader index of each super-leader, in founding order.
    supers: Vec<usize>,
    /// Leader indices under each super-leader, parallel to `supers`.
    groups: Vec<Vec<usize>>,
}

/// Mutable state of one pass, shared by the flat and tree resolvers.
struct Pass<'a> {
    set: &'a SegmentSet,
    epsilon: f32,
    cap: Option<usize>,
    rep_ids: Vec<usize>,
    members: Vec<Vec<usize>>,
    rep_of: Vec<usize>,
    probe_pairs: usize,
    rect_rows: usize,
    rect_cols: usize,
    tree: Option<Tree>,
}

/// Indices of the `k` nearest entries (strict `<`, earliest wins ties),
/// in pick order.  O(k·n) — k is the tree fan-out, a small constant.
fn nearest_indices(dists: &[f32], k: usize) -> Vec<usize> {
    let take = k.min(dists.len());
    let mut picked: Vec<usize> = Vec::with_capacity(take);
    while picked.len() < take {
        let mut best: Option<(usize, f32)> = None;
        for (i, &v) in dists.iter().enumerate() {
            if picked.contains(&i) {
                continue;
            }
            let closer = match best {
                Some((_, b)) => v < b,
                None => true,
            };
            if closer {
                best = Some((i, v));
            }
        }
        match best {
            Some((i, _)) => picked.push(i),
            None => break, // picked.len() == take; loop guard re-proves this
        }
    }
    picked
}

impl Pass<'_> {
    fn has_room(&self, r: usize) -> bool {
        match self.cap {
            Some(cap) => self.members[r].len() < cap,
            None => true,
        }
    }

    /// Consider `(r, dist)` as a join target: within ε, strictly closer
    /// than the incumbent (ties keep the earliest representative).
    fn consider(&self, best: &mut Option<(usize, f32)>, r: usize, dist: f32) {
        if dist > self.epsilon {
            return;
        }
        let closer = match *best {
            Some((_, b)) => dist < b,
            None => true,
        };
        if closer {
            *best = Some((r, dist));
        }
    }

    /// Register segment `id` as a fresh leader; returns its index.
    fn push_leader(&mut self, id: usize) -> usize {
        let r = self.rep_ids.len();
        self.rep_of[id] = r;
        self.rep_ids.push(id);
        self.members.push(vec![id]);
        r
    }

    /// Attach leader `r` to the tree: nearest super-leader within the
    /// coarse radius (strict `<`, earliest wins), else found a new
    /// super-group.  `sdist` holds `r`'s distance to every current
    /// super-leader — already probed while `r` was still a pending
    /// segment, so attachment issues no DTW of its own.
    fn attach_leader(&mut self, r: usize, sdist: &[f32]) {
        let Some(tree) = self.tree.as_mut() else {
            return;
        };
        debug_assert_eq!(sdist.len(), tree.supers.len());
        let mut best: Option<(usize, f32)> = None;
        for (g, &dist) in sdist.iter().enumerate() {
            if dist > tree.coarse {
                continue;
            }
            let closer = match best {
                Some((_, b)) => dist < b,
                None => true,
            };
            if closer {
                best = Some((g, dist));
            }
        }
        match best {
            Some((g, _)) => tree.groups[g].push(r),
            None => {
                tree.supers.push(r);
                tree.groups.push(vec![r]);
            }
        }
    }

    /// One probe round over segments `lo..hi`: a single cross rectangle
    /// against the candidate columns as of round start, then an in-order
    /// resolution sweep with short incremental rows for mid-round
    /// arrivals.
    fn round(
        &mut self,
        lo: usize,
        hi: usize,
        backend: &dyn PairwiseBackend,
        threads: usize,
        cache: Option<&PairCache>,
    ) -> anyhow::Result<()> {
        let base_leaders = self.rep_ids.len();
        // Rectangle columns: open leaders (flat; kept as indices for
        // the resolver) or every super-leader (tree) as of round start,
        // ascending, mapped to global ids.
        let (flat_cols, col_ids): (Vec<usize>, Vec<usize>) = match &self.tree {
            Some(t) => {
                let ids = t.supers.iter().map(|&s| self.rep_ids[s]).collect();
                (Vec::new(), ids)
            }
            None => {
                let c: Vec<usize> = (0..base_leaders).filter(|&r| self.has_room(r)).collect();
                let ids = c.iter().map(|&r| self.rep_ids[r]).collect();
                (c, ids)
            }
        };
        let ncols = col_ids.len();
        let rect: Vec<f32> = if ncols == 0 {
            Vec::new()
        } else {
            let xs: Vec<&Segment> = self.set.segments[lo..hi].iter().collect();
            let ys: Vec<&Segment> = col_ids.iter().map(|&g| &self.set.segments[g]).collect();
            // Flat probing only ever compares rectangle cells against ε
            // (`consider` rejects dist > ε before looking at the value),
            // so the pruning cascade may answer cells it can bound out
            // with the bound itself — decisions are unchanged.  Tree
            // rectangles feed `nearest_indices` *ordering* and must stay
            // exact.
            let threshold = if self.tree.is_none() {
                Some(self.epsilon)
            } else {
                None
            };
            let d = build_cross_cached_pruned(&xs, &ys, backend, threads, cache, threshold)?;
            anyhow::ensure!(
                d.len() == (hi - lo) * ncols,
                "backend returned {} probe distances for a {}x{} rectangle",
                d.len(),
                hi - lo,
                ncols
            );
            self.probe_pairs += d.len();
            if (hi - lo) * ncols > self.rect_rows * self.rect_cols {
                self.rect_rows = hi - lo;
                self.rect_cols = ncols;
            }
            d
        };
        for id in lo..hi {
            let row = &rect[(id - lo) * ncols..(id - lo) * ncols + ncols];
            if self.tree.is_some() {
                self.resolve_tree(id, row, ncols, backend, cache)?;
            } else {
                self.resolve_flat(id, row, &flat_cols, base_leaders, backend, cache)?;
            }
        }
        Ok(())
    }

    /// Flat resolution of segment `id`: every open leader is a
    /// candidate.  Round-start leaders come from the rectangle `row`
    /// (skipping groups that filled mid-round); leaders born earlier in
    /// this round are probed as one incremental row.  Candidates are
    /// visited in ascending leader index — rectangle columns first, then
    /// the strictly-younger arrivals — so the strict-`<` rule resolves
    /// ties exactly as the per-row reference does.
    fn resolve_flat(
        &mut self,
        id: usize,
        row: &[f32],
        cols: &[usize],
        base_leaders: usize,
        backend: &dyn PairwiseBackend,
        cache: Option<&PairCache>,
    ) -> anyhow::Result<()> {
        let mut best: Option<(usize, f32)> = None;
        for (j, &r) in cols.iter().enumerate() {
            if !self.has_room(r) {
                continue;
            }
            self.consider(&mut best, r, row[j]);
        }
        let fresh: Vec<usize> = (base_leaders..self.rep_ids.len())
            .filter(|&r| self.has_room(r))
            .collect();
        if !fresh.is_empty() {
            let xs = [&self.set.segments[id]];
            let ys: Vec<&Segment> = fresh
                .iter()
                .map(|&r| &self.set.segments[self.rep_ids[r]])
                .collect();
            // Like the rectangle: values only ever meet `consider`'s
            // ε gate, so bound-answered cells are decision-safe.
            let d = build_cross_cached_pruned(&xs, &ys, backend, 1, cache, Some(self.epsilon))?;
            anyhow::ensure!(
                d.len() == ys.len(),
                "backend returned {} probe distances for {} fresh leaders",
                d.len(),
                ys.len()
            );
            self.probe_pairs += d.len();
            for (&r, &dist) in fresh.iter().zip(&d) {
                self.consider(&mut best, r, dist);
            }
        }
        match best {
            Some((r, _)) => {
                self.members[r].push(id);
                self.rep_of[id] = r;
            }
            None => {
                self.push_leader(id);
            }
        }
        Ok(())
    }

    /// Tree resolution of segment `id`: complete the super-leader
    /// distance vector (rectangle `row` covers the `base_supers` known
    /// at round start, mid-round foundings get one incremental row),
    /// descend into the `probe` nearest super-groups, and probe only
    /// their open leaders — reusing the super distances already in hand.
    fn resolve_tree(
        &mut self,
        id: usize,
        row: &[f32],
        base_supers: usize,
        backend: &dyn PairwiseBackend,
        cache: Option<&PairCache>,
    ) -> anyhow::Result<()> {
        let mut sdist: Vec<f32> = row.to_vec();
        let nsupers = self.tree.as_ref().map_or(0, |t| t.supers.len());
        if nsupers > base_supers {
            let fresh_ids: Vec<usize> = {
                let t = self
                    .tree
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("tree resolver invoked without tree state"))?;
                t.supers[base_supers..].iter().map(|&s| self.rep_ids[s]).collect()
            };
            let xs = [&self.set.segments[id]];
            let ys: Vec<&Segment> = fresh_ids.iter().map(|&g| &self.set.segments[g]).collect();
            let d = build_cross_cached(&xs, &ys, backend, 1, cache)?;
            anyhow::ensure!(
                d.len() == ys.len(),
                "backend returned {} probe distances for {} fresh super-leaders",
                d.len(),
                ys.len()
            );
            self.probe_pairs += d.len();
            sdist.extend_from_slice(&d);
        }
        let fan = self.tree.as_ref().map_or(1, |t| t.probe);
        let picked = nearest_indices(&sdist, fan);
        // Open leaders under the picked groups, ascending; super-leader
        // distances are already known.
        let mut cand: Vec<usize> = Vec::new();
        let mut known: Vec<(usize, f32)> = Vec::new();
        {
            let t = self
                .tree
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("tree resolver invoked without tree state"))?;
            for &g in &picked {
                known.push((t.supers[g], sdist[g]));
                for &r in &t.groups[g] {
                    if self.has_room(r) {
                        cand.push(r);
                    }
                }
            }
        }
        cand.sort_unstable();
        let mut dist: Vec<Option<f32>> = Vec::with_capacity(cand.len());
        for &r in &cand {
            let mut known_d = None;
            for &(kr, kd) in &known {
                if kr == r {
                    known_d = Some(kd);
                    break;
                }
            }
            dist.push(known_d);
        }
        let need: Vec<usize> = (0..cand.len()).filter(|&i| dist[i].is_none()).collect();
        if !need.is_empty() {
            let xs = [&self.set.segments[id]];
            let ys: Vec<&Segment> = need
                .iter()
                .map(|&i| &self.set.segments[self.rep_ids[cand[i]]])
                .collect();
            let d = build_cross_cached(&xs, &ys, backend, 1, cache)?;
            anyhow::ensure!(
                d.len() == ys.len(),
                "backend returned {} probe distances for {} group leaders",
                d.len(),
                ys.len()
            );
            self.probe_pairs += d.len();
            for (&i, &v) in need.iter().zip(&d) {
                dist[i] = Some(v);
            }
        }
        let mut best: Option<(usize, f32)> = None;
        for (i, &r) in cand.iter().enumerate() {
            let dv = dist[i].ok_or_else(|| {
                anyhow::anyhow!("candidate distance {i} unresolved after probe round")
            })?;
            self.consider(&mut best, r, dv);
        }
        match best {
            Some((r, _)) => {
                self.members[r].push(id);
                self.rep_of[id] = r;
            }
            None => {
                let r = self.push_leader(id);
                // `sdist` covers every current super-leader, so the new
                // leader attaches without another probe.
                self.attach_leader(r, &sdist);
            }
        }
        Ok(())
    }
}

/// Run the leader pass over the whole corpus.
///
/// `cache` is the same [`PairCache`] the drivers hand to stage 1: every
/// probe distance is published to it, so the (rep, rep) pairs a new
/// representative was probed against are already warm when stage 1
/// builds its condensed matrices over representatives.  `threads`
/// splits each probe rectangle's rows exactly as the distance builders
/// do — the assembled rectangle is thread-count invariant, so the
/// grouping is too.  With `cfg.epsilon == 0` and no quantile the pass
/// is skipped and [`Aggregation::identity`] is returned without
/// touching the backend.
pub fn aggregate(
    set: &SegmentSet,
    cfg: &AggregateConfig,
    backend: &dyn PairwiseBackend,
    threads: usize,
    cache: Option<&PairCache>,
) -> anyhow::Result<Aggregation> {
    cfg.validate()?;
    let n = set.len();
    if !cfg.is_active() || n == 0 {
        return Ok(Aggregation::identity(n));
    }
    let (epsilon, sample_pairs, sample_segments) = match cfg.quantile {
        Some(q) => {
            let est = super::quantile::derive_epsilon(
                set,
                q,
                cfg.quantile_sample,
                cfg.quantile_seed,
                backend,
                threads,
                cache,
            )?;
            (est.epsilon, est.sample_pairs, est.sample_segments)
        }
        None => (cfg.epsilon, 0, 0),
    };

    let mut pass = Pass {
        set,
        epsilon,
        cap: cfg.cap,
        rep_ids: Vec::new(),
        members: Vec::new(),
        rep_of: vec![usize::MAX; n],
        probe_pairs: 0,
        rect_rows: 0,
        rect_cols: 0,
        tree: (cfg.tree_factor > 0.0).then(|| Tree {
            coarse: cfg.tree_factor * epsilon,
            probe: cfg.tree_probe.max(1),
            supers: Vec::new(),
            groups: Vec::new(),
        }),
    };

    let batch = cfg.batch_rows.max(1);
    let mut probe_rounds = 0usize;
    let mut lo = 0usize;
    while lo < n {
        let hi = (lo + batch).min(n);
        pass.round(lo, hi, backend, threads, cache)?;
        probe_rounds += 1;
        lo = hi;
    }

    debug_assert_eq!(pass.members.iter().map(|m| m.len()).sum::<usize>(), n);
    Ok(Aggregation {
        rep_ids: pass.rep_ids,
        members: pass.members,
        rep_of: pass.rep_of,
        probe_pairs: pass.probe_pairs,
        sample_pairs,
        sample_segments,
        probe_rounds,
        rect_rows: pass.rect_rows,
        rect_cols: pass.rect_cols,
        super_leaders: pass.tree.as_ref().map_or(0, |t| t.supers.len()),
        epsilon,
        total: n,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::NativeBackend;

    /// One-frame one-dim segments: DTW distance is exactly |a − b| / 2
    /// (the kernel normalises by lx + ly), so group structure can be
    /// computed by hand.
    fn scalar_set(vals: &[f32]) -> SegmentSet {
        SegmentSet {
            name: "scalar".into(),
            dim: 1,
            segments: vals
                .iter()
                .enumerate()
                .map(|(id, &v)| Segment {
                    id,
                    class_id: 0,
                    len: 1,
                    dim: 1,
                    feats: vec![v],
                })
                .collect(),
            num_classes: 1,
        }
    }

    #[test]
    fn groups_by_nearest_leader_within_epsilon() {
        let set = scalar_set(&[0.0, 0.1, 0.9, 1.0, 0.05]);
        let cfg = AggregateConfig::new(0.2);
        let agg = aggregate(&set, &cfg, &NativeBackend::new(), 1, None).unwrap();
        assert_eq!(agg.rep_ids, vec![0, 2]);
        assert_eq!(agg.members, vec![vec![0, 1, 4], vec![2, 3]]);
        assert_eq!(agg.rep_of, vec![0, 0, 1, 1, 0]);
        // Probes: 0 + 1 + 1 + 2 + 2 (one round, all leaders mid-round).
        assert_eq!(agg.probe_pairs, 6);
        assert_eq!(agg.probe_rounds, 1);
        assert_eq!(agg.sample_pairs, 0);
        assert_eq!(agg.sample_segments, 0);
        assert_eq!(agg.super_leaders, 0);
        assert_eq!(agg.epsilon, 0.2);
        assert_eq!(agg.reps(), 2);
        assert!((agg.compression_ratio() - 0.4).abs() < 1e-12);
        assert!(!agg.is_identity());
    }

    #[test]
    fn batched_rounds_match_the_per_row_reference() {
        let set = scalar_set(&[0.0, 0.1, 0.9, 1.0, 0.05]);
        let reference = aggregate(
            &set,
            &AggregateConfig::new(0.2).with_batch_rows(1),
            &NativeBackend::new(),
            1,
            None,
        )
        .unwrap();
        assert_eq!(reference.probe_rounds, 5, "per-row = one round per segment");
        assert_eq!(reference.probe_pairs, 6);
        for batch in [2usize, 3, 64] {
            let agg = aggregate(
                &set,
                &AggregateConfig::new(0.2).with_batch_rows(batch),
                &NativeBackend::new(),
                4,
                None,
            )
            .unwrap();
            assert_eq!(agg.rep_ids, reference.rep_ids, "batch = {batch}");
            assert_eq!(agg.members, reference.members, "batch = {batch}");
            assert_eq!(agg.rep_of, reference.rep_of, "batch = {batch}");
            assert_eq!(agg.probe_rounds, 5usize.div_ceil(batch));
        }
        // batch = 2 dispatches the rectangles 2x1 (round 1) and 1x2
        // (round 2); the earliest largest-area one is recorded.
        let two = aggregate(
            &set,
            &AggregateConfig::new(0.2).with_batch_rows(2),
            &NativeBackend::new(),
            1,
            None,
        )
        .unwrap();
        assert_eq!((two.rect_rows, two.rect_cols), (2, 1));
    }

    #[test]
    fn two_level_tree_groups_far_clusters_under_separate_supers() {
        // Three well-separated pairs: ε groups each pair, the coarse
        // radius 10ε spans the first two pair-leaders but not the third.
        let set = scalar_set(&[0.0, 0.05, 1.0, 1.05, 5.0, 5.05]);
        let cfg = AggregateConfig::new(0.2).with_tree(10.0, 1);
        let agg = aggregate(&set, &cfg, &NativeBackend::new(), 1, None).unwrap();
        assert_eq!(agg.rep_ids, vec![0, 2, 4]);
        assert_eq!(agg.members, vec![vec![0, 1], vec![2, 3], vec![4, 5]]);
        assert_eq!(agg.super_leaders, 2, "leaders 0,2 share a super; 4 founds one");
    }

    #[test]
    fn ties_go_to_the_earliest_representative() {
        // 0.5 is exactly 0.25 (= 0.5/2 normalised) from both
        // representatives; strict < keeps the first.
        let set = scalar_set(&[0.0, 1.0, 0.5]);
        let cfg = AggregateConfig::new(0.3);
        let agg = aggregate(&set, &cfg, &NativeBackend::new(), 1, None).unwrap();
        assert_eq!(agg.rep_ids, vec![0, 1]);
        assert_eq!(agg.members, vec![vec![0, 2], vec![1]]);
    }

    #[test]
    fn cap_saturated_groups_spill_into_new_representatives() {
        // Five identical segments, cap 2: groups fill to the cap and
        // the overflow elects fresh leaders.
        let set = scalar_set(&[0.0; 5]);
        let cfg = AggregateConfig::new(0.5).with_cap(2);
        let agg = aggregate(&set, &cfg, &NativeBackend::new(), 1, None).unwrap();
        assert_eq!(agg.rep_ids, vec![0, 2, 4]);
        assert_eq!(agg.members, vec![vec![0, 1], vec![2, 3], vec![4]]);
        for m in &agg.members {
            assert!(m.len() <= 2, "cap violated: {m:?}");
        }
    }

    #[test]
    fn all_identical_segments_collapse_to_one_group_without_cap() {
        let set = scalar_set(&[2.5; 7]);
        let cfg = AggregateConfig::new(0.01);
        let agg = aggregate(&set, &cfg, &NativeBackend::new(), 1, None).unwrap();
        assert_eq!(agg.rep_ids, vec![0]);
        assert_eq!(agg.members, vec![vec![0, 1, 2, 3, 4, 5, 6]]);
        assert!((agg.compression_ratio() - 1.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn single_segment_and_empty_corpus() {
        let one = scalar_set(&[1.0]);
        let agg = aggregate(
            &one,
            &AggregateConfig::new(5.0),
            &NativeBackend::new(),
            1,
            None,
        )
        .unwrap();
        assert_eq!(agg.rep_ids, vec![0]);
        assert_eq!(agg.members, vec![vec![0]]);
        assert_eq!(agg.probe_pairs, 0);
        assert!(agg.is_identity());

        let empty = scalar_set(&[]);
        let agg = aggregate(
            &empty,
            &AggregateConfig::new(5.0),
            &NativeBackend::new(),
            1,
            None,
        )
        .unwrap();
        assert_eq!(agg.reps(), 0);
        assert_eq!(agg.compression_ratio(), 1.0);
    }

    #[test]
    fn epsilon_zero_is_identity_and_never_probes() {
        let set = scalar_set(&[0.0, 0.0, 0.0]);
        let agg = aggregate(
            &set,
            &AggregateConfig::default(),
            &NativeBackend::new(),
            1,
            None,
        )
        .unwrap();
        assert!(agg.is_identity());
        assert_eq!(agg.rep_ids, vec![0, 1, 2]);
        assert_eq!(agg.rep_of, vec![0, 1, 2]);
        assert_eq!(agg.probe_pairs, 0);
        assert_eq!(agg.probe_rounds, 0);
    }

    #[test]
    fn probes_warm_the_shared_pair_cache() {
        let set = scalar_set(&[0.0, 0.1, 0.9, 1.0, 0.05]);
        let cfg = AggregateConfig::new(0.2);
        let cache = PairCache::with_capacity_bytes(1 << 20);
        let backend = NativeBackend::new();
        let a = aggregate(&set, &cfg, &backend, 1, Some(&cache)).unwrap();
        let cold = cache.stats();
        assert_eq!(cold.hits, 0, "first pass sees only misses");
        assert_eq!(cold.misses as usize, a.probe_pairs);
        // A second pass re-probes the same pairs fully from cache, and
        // the cache cannot change the grouping.
        let b = aggregate(&set, &cfg, &backend, 1, Some(&cache)).unwrap();
        assert_eq!(a.rep_ids, b.rep_ids);
        assert_eq!(a.members, b.members);
        assert_eq!(cache.stats().hits as usize, a.probe_pairs);
    }

    #[test]
    fn nearest_indices_orders_and_breaks_ties_deterministically() {
        assert_eq!(nearest_indices(&[0.5, 0.1, 0.3], 2), vec![1, 2]);
        assert_eq!(nearest_indices(&[0.2, 0.2, 0.1], 3), vec![2, 0, 1]);
        assert_eq!(nearest_indices(&[0.4], 5), vec![0]);
        assert!(nearest_indices(&[], 2).is_empty());
    }

    #[test]
    fn invalid_config_rejected() {
        let set = scalar_set(&[0.0]);
        assert!(aggregate(
            &set,
            &AggregateConfig::new(-1.0),
            &NativeBackend::new(),
            1,
            None
        )
        .is_err());
    }
}
