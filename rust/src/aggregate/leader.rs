//! The deterministic leader (canopy) pass over a segment corpus.
//!
//! Segments are visited in id order.  Each segment probes the DTW
//! distance to every representative whose group still has room under
//! the occupancy cap (through [`build_cross_cached`], so probes land in
//! the cross-iteration [`PairCache`] and stage 1 never recomputes
//! them — full groups are not probed at all, since their distances
//! could never be used) and joins the *nearest* such representative
//! with distance ≤ ε; otherwise it becomes a new representative itself.
//! Visit order, the strict `<` nearest rule and the single-row probe
//! shape make the result independent of thread count and — because the
//! scalar and blocked backends are bitwise equal — of backend choice.

use crate::config::AggregateConfig;
use crate::corpus::{Segment, SegmentSet};
use crate::distance::{build_cross_cached, DtwBackend, PairCache};

/// Result of the leader pass: `m` representatives plus the membership
/// lists that map them back onto the full corpus.
#[derive(Debug, Clone)]
pub struct Aggregation {
    /// Global segment id of each representative, in discovery (= id)
    /// order.
    pub rep_ids: Vec<usize>,
    /// Member ids (global, leader first) per representative, parallel
    /// to `rep_ids`.
    pub members: Vec<Vec<usize>>,
    /// Representative index (into `rep_ids`) per segment id.
    pub rep_of: Vec<usize>,
    /// DTW pair probes the pass performed (Σ per segment of the
    /// representatives whose groups still had room when it arrived).
    pub probe_pairs: usize,
    /// Corpus size N the pass ran over.
    pub total: usize,
}

impl Aggregation {
    /// The no-op aggregation (ε = 0): every segment represents itself.
    pub fn identity(n: usize) -> Aggregation {
        Aggregation {
            rep_ids: (0..n).collect(),
            members: (0..n).map(|i| vec![i]).collect(),
            rep_of: (0..n).collect(),
            probe_pairs: 0,
            total: n,
        }
    }

    /// Number of representatives m.
    pub fn reps(&self) -> usize {
        self.rep_ids.len()
    }

    /// m / N — 1.0 means no compression, smaller is better.
    pub fn compression_ratio(&self) -> f64 {
        if self.total == 0 {
            1.0
        } else {
            self.reps() as f64 / self.total as f64
        }
    }

    /// Whether every segment is its own representative.
    pub fn is_identity(&self) -> bool {
        self.reps() == self.total
    }
}

/// Run the leader pass over the whole corpus.
///
/// `cache` is the same [`PairCache`] the drivers hand to stage 1: every
/// probe distance is published to it, so the (rep, rep) pairs a new
/// representative was probed against are already warm when stage 1
/// builds its condensed matrices over representatives.  With
/// `cfg.epsilon == 0` the pass is skipped and [`Aggregation::identity`]
/// is returned without touching the backend.
pub fn aggregate(
    set: &SegmentSet,
    cfg: &AggregateConfig,
    backend: &dyn DtwBackend,
    cache: Option<&PairCache>,
) -> anyhow::Result<Aggregation> {
    cfg.validate()?;
    let n = set.len();
    if !cfg.is_active() || n == 0 {
        return Ok(Aggregation::identity(n));
    }

    let mut rep_ids: Vec<usize> = Vec::new();
    let mut members: Vec<Vec<usize>> = Vec::new();
    let mut rep_of = vec![usize::MAX; n];
    let mut probe_pairs = 0usize;

    for id in 0..n {
        let mut best: Option<(usize, f32)> = None;
        // Only groups with room are candidates: a distance to a full
        // group could never be used (the β idea at stage 0), so probing
        // it would be pure waste — quadratic waste in the saturated
        // regime the cap exists for.  The trade: a new rep admitted
        // after saturation never probes full groups, so those (rep,
        // full-rep) pairs are not pre-warmed in the cache (see
        // EXPERIMENTS.md §Aggregation).
        let candidates: Vec<usize> = match cfg.cap {
            Some(cap) => (0..rep_ids.len())
                .filter(|&r| members[r].len() < cap)
                .collect(),
            None => (0..rep_ids.len()).collect(),
        };
        if !candidates.is_empty() {
            let xs = [&set.segments[id]];
            let ys: Vec<&Segment> = candidates
                .iter()
                .map(|&r| &set.segments[rep_ids[r]])
                .collect();
            // One probe row per segment: a single-row cross build is one
            // block whatever the thread count, so the pass is serial and
            // scheduling-invariant by construction.
            let d = build_cross_cached(&xs, &ys, backend, 1, cache)?;
            anyhow::ensure!(
                d.len() == ys.len(),
                "backend returned {} probe distances for {} representatives",
                d.len(),
                ys.len()
            );
            probe_pairs += ys.len();
            for (&r, &dist) in candidates.iter().zip(&d) {
                if dist > cfg.epsilon {
                    continue;
                }
                // Strict < keeps ties on the earliest representative:
                // deterministic under any backend or thread count.
                let closer = match best {
                    Some((_, b)) => dist < b,
                    None => true,
                };
                if closer {
                    best = Some((r, dist));
                }
            }
        }
        match best {
            Some((r, _)) => {
                members[r].push(id);
                rep_of[id] = r;
            }
            None => {
                rep_of[id] = rep_ids.len();
                rep_ids.push(id);
                members.push(vec![id]);
            }
        }
    }

    debug_assert_eq!(members.iter().map(|m| m.len()).sum::<usize>(), n);
    Ok(Aggregation {
        rep_ids,
        members,
        rep_of,
        probe_pairs,
        total: n,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::NativeBackend;

    /// One-frame one-dim segments: DTW distance is exactly |a − b| / 2
    /// (the kernel normalises by lx + ly), so group structure can be
    /// computed by hand.
    fn scalar_set(vals: &[f32]) -> SegmentSet {
        SegmentSet {
            name: "scalar".into(),
            dim: 1,
            segments: vals
                .iter()
                .enumerate()
                .map(|(id, &v)| Segment {
                    id,
                    class_id: 0,
                    len: 1,
                    dim: 1,
                    feats: vec![v],
                })
                .collect(),
            num_classes: 1,
        }
    }

    #[test]
    fn groups_by_nearest_leader_within_epsilon() {
        let set = scalar_set(&[0.0, 0.1, 0.9, 1.0, 0.05]);
        let cfg = AggregateConfig::new(0.2);
        let agg = aggregate(&set, &cfg, &NativeBackend::new(), None).unwrap();
        assert_eq!(agg.rep_ids, vec![0, 2]);
        assert_eq!(agg.members, vec![vec![0, 1, 4], vec![2, 3]]);
        assert_eq!(agg.rep_of, vec![0, 0, 1, 1, 0]);
        // Probes: 0 + 1 + 1 + 2 + 2.
        assert_eq!(agg.probe_pairs, 6);
        assert_eq!(agg.reps(), 2);
        assert!((agg.compression_ratio() - 0.4).abs() < 1e-12);
        assert!(!agg.is_identity());
    }

    #[test]
    fn ties_go_to_the_earliest_representative() {
        // 0.5 is exactly 0.25 (= 0.5/2 normalised) from both
        // representatives; strict < keeps the first.
        let set = scalar_set(&[0.0, 1.0, 0.5]);
        let cfg = AggregateConfig::new(0.3);
        let agg = aggregate(&set, &cfg, &NativeBackend::new(), None).unwrap();
        assert_eq!(agg.rep_ids, vec![0, 1]);
        assert_eq!(agg.members, vec![vec![0, 2], vec![1]]);
    }

    #[test]
    fn cap_saturated_groups_spill_into_new_representatives() {
        // Five identical segments, cap 2: groups fill to the cap and
        // the overflow elects fresh leaders.
        let set = scalar_set(&[0.0; 5]);
        let cfg = AggregateConfig::new(0.5).with_cap(2);
        let agg = aggregate(&set, &cfg, &NativeBackend::new(), None).unwrap();
        assert_eq!(agg.rep_ids, vec![0, 2, 4]);
        assert_eq!(agg.members, vec![vec![0, 1], vec![2, 3], vec![4]]);
        for m in &agg.members {
            assert!(m.len() <= 2, "cap violated: {m:?}");
        }
    }

    #[test]
    fn all_identical_segments_collapse_to_one_group_without_cap() {
        let set = scalar_set(&[2.5; 7]);
        let cfg = AggregateConfig::new(0.01);
        let agg = aggregate(&set, &cfg, &NativeBackend::new(), None).unwrap();
        assert_eq!(agg.rep_ids, vec![0]);
        assert_eq!(agg.members, vec![vec![0, 1, 2, 3, 4, 5, 6]]);
        assert!((agg.compression_ratio() - 1.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn single_segment_and_empty_corpus() {
        let one = scalar_set(&[1.0]);
        let agg = aggregate(
            &one,
            &AggregateConfig::new(5.0),
            &NativeBackend::new(),
            None,
        )
        .unwrap();
        assert_eq!(agg.rep_ids, vec![0]);
        assert_eq!(agg.members, vec![vec![0]]);
        assert_eq!(agg.probe_pairs, 0);
        assert!(agg.is_identity());

        let empty = scalar_set(&[]);
        let agg = aggregate(
            &empty,
            &AggregateConfig::new(5.0),
            &NativeBackend::new(),
            None,
        )
        .unwrap();
        assert_eq!(agg.reps(), 0);
        assert_eq!(agg.compression_ratio(), 1.0);
    }

    #[test]
    fn epsilon_zero_is_identity_and_never_probes() {
        let set = scalar_set(&[0.0, 0.0, 0.0]);
        let agg = aggregate(
            &set,
            &AggregateConfig::default(),
            &NativeBackend::new(),
            None,
        )
        .unwrap();
        assert!(agg.is_identity());
        assert_eq!(agg.rep_ids, vec![0, 1, 2]);
        assert_eq!(agg.rep_of, vec![0, 1, 2]);
        assert_eq!(agg.probe_pairs, 0);
    }

    #[test]
    fn probes_warm_the_shared_pair_cache() {
        let set = scalar_set(&[0.0, 0.1, 0.9, 1.0, 0.05]);
        let cfg = AggregateConfig::new(0.2);
        let cache = PairCache::with_capacity_bytes(1 << 20);
        let backend = NativeBackend::new();
        let a = aggregate(&set, &cfg, &backend, Some(&cache)).unwrap();
        let cold = cache.stats();
        assert_eq!(cold.hits, 0, "first pass sees only misses");
        assert_eq!(cold.misses as usize, a.probe_pairs);
        // A second pass re-probes the same pairs fully from cache, and
        // the cache cannot change the grouping.
        let b = aggregate(&set, &cfg, &backend, Some(&cache)).unwrap();
        assert_eq!(a.rep_ids, b.rep_ids);
        assert_eq!(a.members, b.members);
        assert_eq!(cache.stats().hits as usize, a.probe_pairs);
    }

    #[test]
    fn invalid_config_rejected() {
        let set = scalar_set(&[0.0]);
        assert!(aggregate(
            &set,
            &AggregateConfig::new(-1.0),
            &NativeBackend::new(),
            None
        )
        .is_err());
    }
}
