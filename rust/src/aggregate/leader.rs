//! The deterministic leader (canopy) pass over a segment corpus, with a
//! rectangle-batched probe engine and an optional two-level leader tree.
//!
//! Segments are visited in id order.  Each segment probes the DTW
//! distance to candidate representatives (through [`build_cross_cached`],
//! so probes land in the cross-iteration [`PairCache`] and stage 1 never
//! recomputes them) and joins the *nearest* candidate with distance ≤ ε
//! under the occupancy cap; otherwise it becomes a new representative.
//! Visit order and the strict `<` nearest rule (ties to the earliest
//! representative) make the grouping independent of thread count and —
//! because the scalar and blocked backends are bitwise equal — of
//! backend choice.
//!
//! Probe engine.  Pending segments are grouped into rounds of
//! `batch_rows` and dispatched against the candidate set as *one cross
//! rectangle*, so the blocked backend's 8-lane kernel engages instead
//! of degenerating to one serial row per segment.  Leaders born inside
//! a round are probed by the round's later segments as short incremental
//! rows, which keeps the decision sequence — and therefore the groups —
//! bitwise identical to the historical per-row path (`batch_rows = 1`
//! *is* that path, kept reachable as the parity suite's reference).
//!
//! Leader tree.  With `tree_factor > 0` and `tree_depth ≥ 2`, every
//! leader is attached to its nearest level-1 node within radius
//! `tree_factor`·ε (or founds a new one), and so on up `tree_depth − 1`
//! node levels whose radii grow geometrically (`tree_factor`ˡ·ε for
//! level ℓ — the per-level ε inherits whatever the quantile machinery
//! derived for the base radius).  A segment descends from the top
//! level, keeping its `tree_probe` nearest nodes per level, and probes
//! only the open leaders under the level-1 nodes it reaches — probe
//! cost scales with the tree fan-out instead of m.  `tree_depth = 1`
//! *is* the flat pass (the tree is never built) and `tree_depth = 2`
//! reproduces the historical two-level tree bitwise: the descent issues
//! the same probes in the same order (parity-pinned in
//! `rust/tests/aggregation_quality.rs`).  DTW is not a metric, so the
//! tree may prune a would-be leader out of sight; degenerate
//! configurations where it cannot prune (one covering super-group,
//! singleton super-groups with an unambiguous nearest, cap-saturated
//! groups) reproduce the flat pass exactly and are pinned in
//! `rust/tests/aggregation.rs`.
//!
//! Cluster features.  Each group carries a [`GroupSummary`]
//! `(count, radius, spread)` absorbed incrementally at the single join
//! site, and the tree records every leader→node link distance so the
//! pass can fold leaf summaries upward into per-level summaries
//! ([`Aggregation::level_summaries`]) — see [`super::summary`].
//!
//! ε itself is either given absolutely or derived from a pair-distance
//! quantile of a seeded corpus sample ([`super::quantile`]).

use crate::config::AggregateConfig;
use crate::corpus::{Segment, SegmentSet};
use crate::distance::{build_cross_cached, build_cross_cached_pruned, PairwiseBackend, PairCache};

use super::summary::GroupSummary;

/// Result of the leader pass: `m` representatives plus the membership
/// lists that map them back onto the full corpus, and the probe-engine
/// telemetry the drivers surface per run.
#[derive(Debug, Clone)]
pub struct Aggregation {
    /// Global segment id of each representative, in discovery (= id)
    /// order.
    pub rep_ids: Vec<usize>,
    /// Member ids (global, leader first) per representative, parallel
    /// to `rep_ids`.
    pub members: Vec<Vec<usize>>,
    /// Representative index (into `rep_ids`) per segment id.
    pub rep_of: Vec<usize>,
    /// DTW pair probes the pass issued (rectangle cells plus incremental
    /// rows; a cache-served probe still counts — it was issued).
    pub probe_pairs: usize,
    /// Pair distances consumed by the quantile-ε estimate (0 when ε was
    /// given absolutely).
    pub sample_pairs: usize,
    /// Segments the quantile-ε estimate sampled after clamping to the
    /// corpus (0 when ε was given absolutely).
    pub sample_segments: usize,
    /// Probe rounds the pass ran (= N on the per-row reference path).
    pub probe_rounds: usize,
    /// Rows of the largest probe rectangle dispatched.
    pub rect_rows: usize,
    /// Columns of the largest probe rectangle dispatched.
    pub rect_cols: usize,
    /// Top-level tree nodes (0 = flat probing).
    pub super_leaders: usize,
    /// Effective leader radius ε (quantile-derived when configured).
    pub epsilon: f32,
    /// Corpus size N the pass ran over.
    pub total: usize,
    /// Cluster-feature summary per group, parallel to `rep_ids`.
    pub summaries: Vec<GroupSummary>,
    /// Summaries folded per tree level (index 0 = level-1 nodes, …,
    /// last = top level); empty on the flat pass.
    pub level_summaries: Vec<Vec<GroupSummary>>,
}

impl Aggregation {
    /// The no-op aggregation (ε = 0): every segment represents itself.
    pub fn identity(n: usize) -> Aggregation {
        Aggregation {
            rep_ids: (0..n).collect(),
            members: (0..n).map(|i| vec![i]).collect(),
            rep_of: (0..n).collect(),
            probe_pairs: 0,
            sample_pairs: 0,
            sample_segments: 0,
            probe_rounds: 0,
            rect_rows: 0,
            rect_cols: 0,
            super_leaders: 0,
            epsilon: 0.0,
            total: n,
            summaries: vec![GroupSummary::singleton(); n],
            level_summaries: Vec::new(),
        }
    }

    /// Number of representatives m.
    pub fn reps(&self) -> usize {
        self.rep_ids.len()
    }

    /// m / N — 1.0 means no compression, smaller is better.
    pub fn compression_ratio(&self) -> f64 {
        if self.total == 0 {
            1.0
        } else {
            self.reps() as f64 / self.total as f64
        }
    }

    /// Whether every segment is its own representative.
    pub fn is_identity(&self) -> bool {
        self.reps() == self.total
    }

    /// The reported linkage-height deviation bound vs full AHC:
    /// `2·r_max·√(2·c_max)` over the group summaries (see
    /// [`super::summary`] for the derivation).  Exactly 0 when the pass
    /// collapsed nothing or every group has zero radius.
    pub fn deviation_bound(&self) -> f64 {
        let mut r_max = 0.0f32;
        let mut c_max = 0usize;
        for s in &self.summaries {
            r_max = r_max.max(s.radius);
            c_max = c_max.max(s.count);
        }
        if r_max == 0.0 || c_max <= 1 {
            return 0.0;
        }
        2.0 * r_max as f64 * (2.0 * c_max as f64).sqrt()
    }
}

/// One node level of the leader tree.  Level 1 (index 0) groups
/// leaders; level ℓ ≥ 2 groups the nodes one level down.
struct TreeLevel {
    /// Attachment radius `tree_factor`ˡ·ε for this level.
    radius: f32,
    /// Leader index heading each node, in founding order.
    nodes: Vec<usize>,
    /// Children per node, parallel to `nodes`: leader indices at level
    /// 1, node indices into the level below otherwise.  The founding
    /// child is always first.
    children: Vec<Vec<usize>>,
    /// Distance from each child's leader to the node's leader, parallel
    /// to `children` (0 for the founding child).
    links: Vec<Vec<f32>>,
}

/// Node-level state of the leader tree (depth ≥ 2).
struct Tree {
    /// Nodes a segment keeps per level while descending (the fan-out).
    probe: usize,
    /// Levels bottom-up: `levels[0]` is level 1, `levels.last()` the
    /// top level the probe rectangles run against.
    levels: Vec<TreeLevel>,
}

/// Mutable state of one pass, shared by the flat and tree resolvers.
struct Pass<'a> {
    set: &'a SegmentSet,
    epsilon: f32,
    cap: Option<usize>,
    rep_ids: Vec<usize>,
    members: Vec<Vec<usize>>,
    rep_of: Vec<usize>,
    summaries: Vec<GroupSummary>,
    probe_pairs: usize,
    rect_rows: usize,
    rect_cols: usize,
    tree: Option<Tree>,
}

/// Indices of the `k` nearest entries (strict `<`, earliest wins ties),
/// in pick order.  O(k·n) — k is the tree fan-out, a small constant.
fn nearest_indices(dists: &[f32], k: usize) -> Vec<usize> {
    let take = k.min(dists.len());
    let mut picked: Vec<usize> = Vec::with_capacity(take);
    while picked.len() < take {
        let mut best: Option<(usize, f32)> = None;
        for (i, &v) in dists.iter().enumerate() {
            if picked.contains(&i) {
                continue;
            }
            let closer = match best {
                Some((_, b)) => v < b,
                None => true,
            };
            if closer {
                best = Some((i, v));
            }
        }
        match best {
            Some((i, _)) => picked.push(i),
            None => break, // picked.len() == take; loop guard re-proves this
        }
    }
    picked
}

impl Pass<'_> {
    fn has_room(&self, r: usize) -> bool {
        match self.cap {
            Some(cap) => self.members[r].len() < cap,
            None => true,
        }
    }

    /// Consider `(r, dist)` as a join target: within ε, strictly closer
    /// than the incumbent (ties keep the earliest representative).
    fn consider(&self, best: &mut Option<(usize, f32)>, r: usize, dist: f32) {
        if dist > self.epsilon {
            return;
        }
        let closer = match *best {
            Some((_, b)) => dist < b,
            None => true,
        };
        if closer {
            *best = Some((r, dist));
        }
    }

    /// Register segment `id` as a fresh leader; returns its index.
    fn push_leader(&mut self, id: usize) -> usize {
        let r = self.rep_ids.len();
        self.rep_of[id] = r;
        self.rep_ids.push(id);
        self.members.push(vec![id]);
        self.summaries.push(GroupSummary::singleton());
        r
    }

    /// Attach fresh leader `r` to the tree, bottom-up: nearest probed
    /// node within each level's radius (strict `<`, earliest wins),
    /// founding a new node per level until one accepts.  `pnodes` /
    /// `pdist` hold, per level, the node indices the segment probed on
    /// its way down and their distances — already in hand, so
    /// attachment issues no DTW of its own.  At depth 2 the probed set
    /// is every top node, reproducing the historical super-leader
    /// attachment bitwise.
    fn attach_leader(&mut self, r: usize, pnodes: &[Vec<usize>], pdist: &[Vec<f32>]) {
        let Some(tree) = self.tree.as_mut() else {
            return;
        };
        // `child` is what attaches at the current level: the leader
        // itself at level 1, then the freshly-founded node index.
        let mut child = r;
        for (level, (nodes, dists)) in tree.levels.iter_mut().zip(pnodes.iter().zip(pdist)) {
            debug_assert_eq!(nodes.len(), dists.len());
            let mut best: Option<(usize, f32)> = None;
            for (&g, &dist) in nodes.iter().zip(dists) {
                if dist > level.radius {
                    continue;
                }
                let closer = match best {
                    Some((_, b)) => dist < b,
                    None => true,
                };
                if closer {
                    best = Some((g, dist));
                }
            }
            match best {
                Some((g, dist)) => {
                    level.children[g].push(child); // lint: in-bounds children is parallel to nodes
                    level.links[g].push(dist); // lint: in-bounds links is parallel to nodes
                    return;
                }
                None => {
                    level.nodes.push(r);
                    level.children.push(vec![child]);
                    level.links.push(vec![0.0]);
                    child = level.nodes.len() - 1;
                }
            }
        }
    }

    /// One probe round over segments `lo..hi`: a single cross rectangle
    /// against the candidate columns as of round start, then an in-order
    /// resolution sweep with short incremental rows for mid-round
    /// arrivals.
    fn round(
        &mut self,
        lo: usize,
        hi: usize,
        backend: &dyn PairwiseBackend,
        threads: usize,
        cache: Option<&PairCache>,
    ) -> anyhow::Result<()> {
        let base_leaders = self.rep_ids.len();
        // Rectangle columns: open leaders (flat; kept as indices for
        // the resolver) or every top-level tree node as of round start,
        // ascending, mapped to global ids.
        let (flat_cols, col_ids): (Vec<usize>, Vec<usize>) = match &self.tree {
            Some(t) => {
                let top = t.levels.last().map_or(&[][..], |l| &l.nodes); // lint: in-bounds full-range slice of the empty literal
                let ids = top.iter().map(|&s| self.rep_ids[s]).collect(); // lint: in-bounds tree node ids index rep_ids
                (Vec::new(), ids)
            }
            None => {
                let c: Vec<usize> = (0..base_leaders).filter(|&r| self.has_room(r)).collect();
                let ids = c.iter().map(|&r| self.rep_ids[r]).collect();
                (c, ids)
            }
        };
        let ncols = col_ids.len();
        let rect: Vec<f32> = if ncols == 0 {
            Vec::new()
        } else {
            let xs: Vec<&Segment> = self.set.segments[lo..hi].iter().collect();
            let ys: Vec<&Segment> = col_ids.iter().map(|&g| &self.set.segments[g]).collect();
            // Flat probing only ever compares rectangle cells against ε
            // (`consider` rejects dist > ε before looking at the value),
            // so the pruning cascade may answer cells it can bound out
            // with the bound itself — decisions are unchanged.  Tree
            // rectangles feed `nearest_indices` *ordering* and must stay
            // exact.
            let threshold = if self.tree.is_none() {
                Some(self.epsilon)
            } else {
                None
            };
            let d = build_cross_cached_pruned(&xs, &ys, backend, threads, cache, threshold)?;
            anyhow::ensure!(
                d.len() == (hi - lo) * ncols,
                "backend returned {} probe distances for a {}x{} rectangle",
                d.len(),
                hi - lo,
                ncols
            );
            self.probe_pairs += d.len();
            if (hi - lo) * ncols > self.rect_rows * self.rect_cols {
                self.rect_rows = hi - lo;
                self.rect_cols = ncols;
            }
            d
        };
        for id in lo..hi {
            let row = &rect[(id - lo) * ncols..(id - lo) * ncols + ncols];
            if self.tree.is_some() {
                self.resolve_tree(id, row, ncols, backend, cache)?;
            } else {
                self.resolve_flat(id, row, &flat_cols, base_leaders, backend, cache)?;
            }
        }
        Ok(())
    }

    /// Flat resolution of segment `id`: every open leader is a
    /// candidate.  Round-start leaders come from the rectangle `row`
    /// (skipping groups that filled mid-round); leaders born earlier in
    /// this round are probed as one incremental row.  Candidates are
    /// visited in ascending leader index — rectangle columns first, then
    /// the strictly-younger arrivals — so the strict-`<` rule resolves
    /// ties exactly as the per-row reference does.
    fn resolve_flat(
        &mut self,
        id: usize,
        row: &[f32],
        cols: &[usize],
        base_leaders: usize,
        backend: &dyn PairwiseBackend,
        cache: Option<&PairCache>,
    ) -> anyhow::Result<()> {
        let mut best: Option<(usize, f32)> = None;
        for (j, &r) in cols.iter().enumerate() {
            if !self.has_room(r) {
                continue;
            }
            self.consider(&mut best, r, row[j]);
        }
        let fresh: Vec<usize> = (base_leaders..self.rep_ids.len())
            .filter(|&r| self.has_room(r))
            .collect();
        if !fresh.is_empty() {
            let xs = [&self.set.segments[id]];
            let ys: Vec<&Segment> = fresh
                .iter()
                .map(|&r| &self.set.segments[self.rep_ids[r]])
                .collect();
            // Like the rectangle: values only ever meet `consider`'s
            // ε gate, so bound-answered cells are decision-safe.
            let d = build_cross_cached_pruned(&xs, &ys, backend, 1, cache, Some(self.epsilon))?;
            anyhow::ensure!(
                d.len() == ys.len(),
                "backend returned {} probe distances for {} fresh leaders",
                d.len(),
                ys.len()
            );
            self.probe_pairs += d.len();
            for (&r, &dist) in fresh.iter().zip(&d) {
                self.consider(&mut best, r, dist);
            }
        }
        match best {
            Some((r, dist)) => {
                self.members[r].push(id);
                self.rep_of[id] = r;
                self.summaries[r].absorb(dist); // lint: in-bounds summaries is parallel to rep_ids
            }
            None => {
                self.push_leader(id);
            }
        }
        Ok(())
    }

    /// Tree resolution of segment `id`: complete the top-level node
    /// distance vector (rectangle `row` covers the `base_supers` nodes
    /// known at round start, mid-round foundings get one incremental
    /// row), descend level by level into the `probe` nearest nodes, and
    /// probe only the open leaders under the level-1 nodes reached —
    /// reusing distances to node leaders already in hand.
    fn resolve_tree(
        &mut self,
        id: usize,
        row: &[f32],
        base_supers: usize,
        backend: &dyn PairwiseBackend,
        cache: Option<&PairCache>,
    ) -> anyhow::Result<()> {
        let (nlevels, fan) = match self.tree.as_ref() {
            Some(t) => (t.levels.len(), t.probe),
            None => anyhow::bail!("tree resolver invoked without tree state"),
        };
        let top = nlevels - 1;
        // Per level: the node indices the segment probed and their
        // distances, kept for attachment if `id` becomes a leader.
        let mut pnodes: Vec<Vec<usize>> = vec![Vec::new(); nlevels];
        let mut pdist: Vec<Vec<f32>> = vec![Vec::new(); nlevels];

        let mut sdist: Vec<f32> = row.to_vec();
        let ntop = self.tree.as_ref().map_or(0, |t| t.levels[top].nodes.len()); // lint: in-bounds top < levels.len() by the active-tree guard
        if ntop > base_supers {
            let fresh_ids: Vec<usize> = {
                let t = self
                    .tree
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("tree resolver invoked without tree state"))?;
                t.levels[top].nodes[base_supers..] // lint: in-bounds base_supers counts nodes already present
                    .iter()
                    .map(|&s| self.rep_ids[s]) // lint: in-bounds tree node ids index rep_ids
                    .collect()
            };
            let xs = [&self.set.segments[id]];
            let ys: Vec<&Segment> = fresh_ids.iter().map(|&g| &self.set.segments[g]).collect();
            let d = build_cross_cached(&xs, &ys, backend, 1, cache)?;
            anyhow::ensure!(
                d.len() == ys.len(),
                "backend returned {} probe distances for {} fresh top-level nodes",
                d.len(),
                ys.len()
            );
            self.probe_pairs += d.len();
            sdist.extend_from_slice(&d);
        }
        pnodes[top] = (0..ntop).collect(); // lint: in-bounds pnodes is sized levels.len()
        pdist[top] = sdist; // lint: in-bounds pdist is sized levels.len()

        // Descend: at each level keep the `probe` nearest probed nodes,
        // then resolve their children's distances (reusing any child
        // headed by an already-probed leader) one level down.
        let mut known: Vec<(usize, f32)> = Vec::new();
        let mut picked = nearest_indices(&pdist[top], fan); // lint: in-bounds pdist[top] just initialised
        for l in (1..=top).rev() {
            let mut cnodes: Vec<usize> = Vec::new();
            {
                let t = self
                    .tree
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("tree resolver invoked without tree state"))?;
                for &p in &picked {
                    let g = pnodes[l][p]; // lint: in-bounds picked indexes pnodes[l]
                    known.push((t.levels[l].nodes[g], pdist[l][p])); // lint: in-bounds node ids and pdist are parallel
                    cnodes.extend_from_slice(&t.levels[l].children[g]); // lint: in-bounds children is parallel to nodes
                }
            }
            cnodes.sort_unstable();
            let leaders: Vec<usize> = {
                let t = self
                    .tree
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("tree resolver invoked without tree state"))?;
                cnodes.iter().map(|&c| t.levels[l - 1].nodes[c]).collect() // lint: in-bounds child ids index the level below
            };
            let d = self.probe_leaders(id, &leaders, &known, backend, cache)?;
            pnodes[l - 1] = cnodes; // lint: in-bounds l >= 1 inside the descent loop
            pdist[l - 1] = d; // lint: in-bounds l >= 1 inside the descent loop
            picked = nearest_indices(&pdist[l - 1], fan); // lint: in-bounds pdist[l - 1] just assigned
        }

        // Level 1: open leaders under the picked nodes, ascending.
        let mut cand: Vec<usize> = Vec::new();
        {
            let t = self
                .tree
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("tree resolver invoked without tree state"))?;
            for &p in &picked {
                let g = pnodes[0][p]; // lint: in-bounds picked indexes pnodes[0]
                known.push((t.levels[0].nodes[g], pdist[0][p])); // lint: in-bounds node ids and pdist are parallel
                for &r in &t.levels[0].children[g] { // lint: in-bounds children is parallel to nodes
                    if self.has_room(r) {
                        cand.push(r);
                    }
                }
            }
        }
        cand.sort_unstable();
        let dvals = self.probe_leaders(id, &cand, &known, backend, cache)?;
        let mut best: Option<(usize, f32)> = None;
        for (&r, &dv) in cand.iter().zip(&dvals) {
            self.consider(&mut best, r, dv);
        }
        match best {
            Some((r, dist)) => {
                self.members[r].push(id); // lint: in-bounds r is a leader index
                self.rep_of[id] = r; // lint: in-bounds rep_of is sized n
                self.summaries[r].absorb(dist); // lint: in-bounds summaries is parallel to rep_ids
            }
            None => {
                let r = self.push_leader(id);
                // The probed node distances cover every attachment
                // candidate, so the new leader attaches without another
                // probe.
                self.attach_leader(r, &pnodes, &pdist);
            }
        }
        Ok(())
    }

    /// Distances from segment `id` to each of `leaders` (leader
    /// indices, in order): reuse any distance already probed on the way
    /// down (`known`, scanned in insertion order) and resolve the rest
    /// with one incremental row.
    fn probe_leaders(
        &mut self,
        id: usize,
        leaders: &[usize],
        known: &[(usize, f32)],
        backend: &dyn PairwiseBackend,
        cache: Option<&PairCache>,
    ) -> anyhow::Result<Vec<f32>> {
        let mut dist: Vec<Option<f32>> = Vec::with_capacity(leaders.len());
        for &r in leaders {
            let mut known_d = None;
            for &(kr, kd) in known {
                if kr == r {
                    known_d = Some(kd);
                    break;
                }
            }
            dist.push(known_d);
        }
        let need: Vec<usize> = (0..leaders.len()).filter(|&i| dist[i].is_none()).collect(); // lint: in-bounds dist is sized leaders.len()
        if !need.is_empty() {
            let xs = [&self.set.segments[id]];
            let ys: Vec<&Segment> = need
                .iter()
                .map(|&i| &self.set.segments[self.rep_ids[leaders[i]]]) // lint: in-bounds leader ids index rep_ids
                .collect();
            let d = build_cross_cached(&xs, &ys, backend, 1, cache)?;
            anyhow::ensure!(
                d.len() == ys.len(),
                "backend returned {} probe distances for {} group leaders",
                d.len(),
                ys.len()
            );
            self.probe_pairs += d.len();
            for (&i, &v) in need.iter().zip(&d) {
                dist[i] = Some(v);
            }
        }
        dist.into_iter()
            .enumerate()
            .map(|(i, v)| {
                v.ok_or_else(|| {
                    anyhow::anyhow!("candidate distance {i} unresolved after probe round")
                })
            })
            .collect()
    }
}

/// Run the leader pass over the whole corpus.
///
/// `cache` is the same [`PairCache`] the drivers hand to stage 1: every
/// probe distance is published to it, so the (rep, rep) pairs a new
/// representative was probed against are already warm when stage 1
/// builds its condensed matrices over representatives.  `threads`
/// splits each probe rectangle's rows exactly as the distance builders
/// do — the assembled rectangle is thread-count invariant, so the
/// grouping is too.  With `cfg.epsilon == 0` and no quantile the pass
/// is skipped and [`Aggregation::identity`] is returned without
/// touching the backend.
pub fn aggregate(
    set: &SegmentSet,
    cfg: &AggregateConfig,
    backend: &dyn PairwiseBackend,
    threads: usize,
    cache: Option<&PairCache>,
) -> anyhow::Result<Aggregation> {
    cfg.validate()?;
    let n = set.len();
    if !cfg.is_active() || n == 0 {
        return Ok(Aggregation::identity(n));
    }
    let (epsilon, sample_pairs, sample_segments) = match cfg.quantile {
        Some(q) => {
            let est = super::quantile::derive_epsilon(
                set,
                q,
                cfg.quantile_sample,
                cfg.quantile_seed,
                backend,
                threads,
                cache,
            )?;
            (est.epsilon, est.sample_pairs, est.sample_segments)
        }
        None => (cfg.epsilon, 0, 0),
    };

    // Depth 1 never builds the tree: it *is* the flat pass, bitwise.
    let mut pass = Pass {
        set,
        epsilon,
        cap: cfg.cap,
        rep_ids: Vec::new(),
        members: Vec::new(),
        rep_of: vec![usize::MAX; n],
        summaries: Vec::new(),
        probe_pairs: 0,
        rect_rows: 0,
        rect_cols: 0,
        tree: (cfg.tree_factor > 0.0 && cfg.tree_depth >= 2).then(|| {
            let mut levels = Vec::with_capacity(cfg.tree_depth - 1);
            let mut radius = epsilon;
            for _ in 1..cfg.tree_depth {
                radius *= cfg.tree_factor;
                levels.push(TreeLevel {
                    radius,
                    nodes: Vec::new(),
                    children: Vec::new(),
                    links: Vec::new(),
                });
            }
            Tree {
                probe: cfg.tree_probe.max(1),
                levels,
            }
        }),
    };

    let batch = cfg.batch_rows.max(1);
    let mut probe_rounds = 0usize;
    let mut lo = 0usize;
    while lo < n {
        let hi = (lo + batch).min(n);
        pass.round(lo, hi, backend, threads, cache)?;
        probe_rounds += 1;
        lo = hi;
    }

    debug_assert_eq!(pass.members.iter().map(|m| m.len()).sum::<usize>(), n);

    // Fold group summaries up the tree: each node's summary merges its
    // children in attachment order through the recorded link distances
    // (fixed-order sums — deterministic like the pass itself).
    let level_summaries: Vec<Vec<GroupSummary>> = match &pass.tree {
        None => Vec::new(),
        Some(t) => {
            let mut out: Vec<Vec<GroupSummary>> = Vec::with_capacity(t.levels.len());
            let mut prev: Vec<GroupSummary> = pass.summaries.clone();
            for level in &t.levels {
                let mut cur = Vec::with_capacity(level.nodes.len());
                for (kids, links) in level.children.iter().zip(&level.links) {
                    let mut acc: Option<GroupSummary> = None;
                    for (&k, &link) in kids.iter().zip(links) {
                        acc = Some(match acc {
                            // Founding child: the node's own anchor.
                            None => prev[k], // lint: in-bounds child ids index the level below
                            Some(a) => a.merge(&prev[k], link), // lint: in-bounds child ids index the level below
                        });
                    }
                    cur.push(acc.unwrap_or_else(GroupSummary::singleton));
                }
                prev.clone_from(&cur);
                out.push(cur);
            }
            out
        }
    };

    Ok(Aggregation {
        rep_ids: pass.rep_ids,
        members: pass.members,
        rep_of: pass.rep_of,
        probe_pairs: pass.probe_pairs,
        sample_pairs,
        sample_segments,
        probe_rounds,
        rect_rows: pass.rect_rows,
        rect_cols: pass.rect_cols,
        super_leaders: pass
            .tree
            .as_ref()
            .map_or(0, |t| t.levels.last().map_or(0, |l| l.nodes.len())),
        epsilon,
        total: n,
        summaries: pass.summaries,
        level_summaries,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::NativeBackend;

    /// One-frame one-dim segments: DTW distance is exactly |a − b| / 2
    /// (the kernel normalises by lx + ly), so group structure can be
    /// computed by hand.
    fn scalar_set(vals: &[f32]) -> SegmentSet {
        SegmentSet {
            name: "scalar".into(),
            dim: 1,
            segments: vals
                .iter()
                .enumerate()
                .map(|(id, &v)| Segment {
                    id,
                    class_id: 0,
                    len: 1,
                    dim: 1,
                    feats: vec![v],
                })
                .collect(),
            num_classes: 1,
        }
    }

    #[test]
    fn groups_by_nearest_leader_within_epsilon() {
        let set = scalar_set(&[0.0, 0.1, 0.9, 1.0, 0.05]);
        let cfg = AggregateConfig::new(0.2);
        let agg = aggregate(&set, &cfg, &NativeBackend::new(), 1, None).unwrap();
        assert_eq!(agg.rep_ids, vec![0, 2]);
        assert_eq!(agg.members, vec![vec![0, 1, 4], vec![2, 3]]);
        assert_eq!(agg.rep_of, vec![0, 0, 1, 1, 0]);
        // Probes: 0 + 1 + 1 + 2 + 2 (one round, all leaders mid-round).
        assert_eq!(agg.probe_pairs, 6);
        assert_eq!(agg.probe_rounds, 1);
        assert_eq!(agg.sample_pairs, 0);
        assert_eq!(agg.sample_segments, 0);
        assert_eq!(agg.super_leaders, 0);
        assert_eq!(agg.epsilon, 0.2);
        assert_eq!(agg.reps(), 2);
        assert!((agg.compression_ratio() - 0.4).abs() < 1e-12);
        assert!(!agg.is_identity());
    }

    #[test]
    fn batched_rounds_match_the_per_row_reference() {
        let set = scalar_set(&[0.0, 0.1, 0.9, 1.0, 0.05]);
        let reference = aggregate(
            &set,
            &AggregateConfig::new(0.2).with_batch_rows(1),
            &NativeBackend::new(),
            1,
            None,
        )
        .unwrap();
        assert_eq!(reference.probe_rounds, 5, "per-row = one round per segment");
        assert_eq!(reference.probe_pairs, 6);
        for batch in [2usize, 3, 64] {
            let agg = aggregate(
                &set,
                &AggregateConfig::new(0.2).with_batch_rows(batch),
                &NativeBackend::new(),
                4,
                None,
            )
            .unwrap();
            assert_eq!(agg.rep_ids, reference.rep_ids, "batch = {batch}");
            assert_eq!(agg.members, reference.members, "batch = {batch}");
            assert_eq!(agg.rep_of, reference.rep_of, "batch = {batch}");
            assert_eq!(agg.probe_rounds, 5usize.div_ceil(batch));
        }
        // batch = 2 dispatches the rectangles 2x1 (round 1) and 1x2
        // (round 2); the earliest largest-area one is recorded.
        let two = aggregate(
            &set,
            &AggregateConfig::new(0.2).with_batch_rows(2),
            &NativeBackend::new(),
            1,
            None,
        )
        .unwrap();
        assert_eq!((two.rect_rows, two.rect_cols), (2, 1));
    }

    #[test]
    fn two_level_tree_groups_far_clusters_under_separate_supers() {
        // Three well-separated pairs: ε groups each pair, the coarse
        // radius 10ε spans the first two pair-leaders but not the third.
        let set = scalar_set(&[0.0, 0.05, 1.0, 1.05, 5.0, 5.05]);
        let cfg = AggregateConfig::new(0.2).with_tree(10.0, 1);
        let agg = aggregate(&set, &cfg, &NativeBackend::new(), 1, None).unwrap();
        assert_eq!(agg.rep_ids, vec![0, 2, 4]);
        assert_eq!(agg.members, vec![vec![0, 1], vec![2, 3], vec![4, 5]]);
        assert_eq!(agg.super_leaders, 2, "leaders 0,2 share a super; 4 founds one");
    }

    #[test]
    fn ties_go_to_the_earliest_representative() {
        // 0.5 is exactly 0.25 (= 0.5/2 normalised) from both
        // representatives; strict < keeps the first.
        let set = scalar_set(&[0.0, 1.0, 0.5]);
        let cfg = AggregateConfig::new(0.3);
        let agg = aggregate(&set, &cfg, &NativeBackend::new(), 1, None).unwrap();
        assert_eq!(agg.rep_ids, vec![0, 1]);
        assert_eq!(agg.members, vec![vec![0, 2], vec![1]]);
    }

    #[test]
    fn cap_saturated_groups_spill_into_new_representatives() {
        // Five identical segments, cap 2: groups fill to the cap and
        // the overflow elects fresh leaders.
        let set = scalar_set(&[0.0; 5]);
        let cfg = AggregateConfig::new(0.5).with_cap(2);
        let agg = aggregate(&set, &cfg, &NativeBackend::new(), 1, None).unwrap();
        assert_eq!(agg.rep_ids, vec![0, 2, 4]);
        assert_eq!(agg.members, vec![vec![0, 1], vec![2, 3], vec![4]]);
        for m in &agg.members {
            assert!(m.len() <= 2, "cap violated: {m:?}");
        }
    }

    #[test]
    fn all_identical_segments_collapse_to_one_group_without_cap() {
        let set = scalar_set(&[2.5; 7]);
        let cfg = AggregateConfig::new(0.01);
        let agg = aggregate(&set, &cfg, &NativeBackend::new(), 1, None).unwrap();
        assert_eq!(agg.rep_ids, vec![0]);
        assert_eq!(agg.members, vec![vec![0, 1, 2, 3, 4, 5, 6]]);
        assert!((agg.compression_ratio() - 1.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn single_segment_and_empty_corpus() {
        let one = scalar_set(&[1.0]);
        let agg = aggregate(
            &one,
            &AggregateConfig::new(5.0),
            &NativeBackend::new(),
            1,
            None,
        )
        .unwrap();
        assert_eq!(agg.rep_ids, vec![0]);
        assert_eq!(agg.members, vec![vec![0]]);
        assert_eq!(agg.probe_pairs, 0);
        assert!(agg.is_identity());

        let empty = scalar_set(&[]);
        let agg = aggregate(
            &empty,
            &AggregateConfig::new(5.0),
            &NativeBackend::new(),
            1,
            None,
        )
        .unwrap();
        assert_eq!(agg.reps(), 0);
        assert_eq!(agg.compression_ratio(), 1.0);
    }

    #[test]
    fn epsilon_zero_is_identity_and_never_probes() {
        let set = scalar_set(&[0.0, 0.0, 0.0]);
        let agg = aggregate(
            &set,
            &AggregateConfig::default(),
            &NativeBackend::new(),
            1,
            None,
        )
        .unwrap();
        assert!(agg.is_identity());
        assert_eq!(agg.rep_ids, vec![0, 1, 2]);
        assert_eq!(agg.rep_of, vec![0, 1, 2]);
        assert_eq!(agg.probe_pairs, 0);
        assert_eq!(agg.probe_rounds, 0);
    }

    #[test]
    fn probes_warm_the_shared_pair_cache() {
        let set = scalar_set(&[0.0, 0.1, 0.9, 1.0, 0.05]);
        let cfg = AggregateConfig::new(0.2);
        let cache = PairCache::with_capacity_bytes(1 << 20);
        let backend = NativeBackend::new();
        let a = aggregate(&set, &cfg, &backend, 1, Some(&cache)).unwrap();
        let cold = cache.stats();
        assert_eq!(cold.hits, 0, "first pass sees only misses");
        assert_eq!(cold.misses as usize, a.probe_pairs);
        // A second pass re-probes the same pairs fully from cache, and
        // the cache cannot change the grouping.
        let b = aggregate(&set, &cfg, &backend, 1, Some(&cache)).unwrap();
        assert_eq!(a.rep_ids, b.rep_ids);
        assert_eq!(a.members, b.members);
        assert_eq!(cache.stats().hits as usize, a.probe_pairs);
    }

    #[test]
    fn summaries_track_joins_and_bound_reflects_them() {
        let set = scalar_set(&[0.0, 0.1, 0.9, 1.0, 0.05]);
        let agg = aggregate(
            &set,
            &AggregateConfig::new(0.2),
            &NativeBackend::new(),
            1,
            None,
        )
        .unwrap();
        assert_eq!(agg.summaries.len(), 2);
        // Group 0 absorbed ids 1 (at 0.05) and 4 (at 0.025); group 1
        // absorbed id 3 (at 0.05).
        assert_eq!(agg.summaries[0].count, 3);
        assert!((agg.summaries[0].radius - 0.05).abs() < 1e-6);
        assert!((agg.summaries[0].spread - 0.075).abs() < 1e-6);
        assert_eq!(agg.summaries[1].count, 2);
        assert!((agg.summaries[1].radius - 0.05).abs() < 1e-6);
        let want = 2.0 * agg.summaries[0].radius as f64 * (2.0 * 3.0f64).sqrt();
        assert!((agg.deviation_bound() - want).abs() < 1e-9);
        assert!(agg.level_summaries.is_empty(), "flat pass has no levels");
        // Identity aggregations report a zero bound.
        assert_eq!(Aggregation::identity(5).deviation_bound(), 0.0);
    }

    #[test]
    fn depth_one_is_the_flat_pass_even_with_a_tree_factor() {
        let set = scalar_set(&[0.0, 0.05, 1.0, 1.05, 5.0, 5.05]);
        let flat = aggregate(
            &set,
            &AggregateConfig::new(0.2),
            &NativeBackend::new(),
            1,
            None,
        )
        .unwrap();
        let mut cfg = AggregateConfig::new(0.2).with_tree(10.0, 1);
        cfg.tree_depth = 1;
        let depth1 = aggregate(&set, &cfg, &NativeBackend::new(), 1, None).unwrap();
        assert_eq!(depth1.rep_ids, flat.rep_ids);
        assert_eq!(depth1.members, flat.members);
        assert_eq!(depth1.probe_pairs, flat.probe_pairs);
        assert_eq!(depth1.super_leaders, 0, "no tree is ever built");
        assert!(depth1.level_summaries.is_empty());
    }

    #[test]
    fn depth_three_tree_covers_the_corpus_and_folds_summaries() {
        // Three separation scales under ε = 0.2, factor 5 (level-1
        // radius 1.0, level-2 radius 5.0): pairs ~0.05 apart join at ε,
        // pair leaders 0.5 apart share a level-1 node, and the block at
        // 40 (distance 20) founds its own top-level node.
        let set = scalar_set(&[0.0, 0.05, 1.0, 1.05, 40.0, 40.05, 41.0]);
        let mut cfg = AggregateConfig::new(0.2).with_tree(5.0, 2);
        cfg.tree_depth = 3;
        let agg = aggregate(&set, &cfg, &NativeBackend::new(), 1, None).unwrap();
        // Everyone is grouped exactly once.
        assert_eq!(agg.members.iter().map(|m| m.len()).sum::<usize>(), 7);
        assert_eq!(agg.level_summaries.len(), 2, "depth 3 = two node levels");
        for level in &agg.level_summaries {
            assert_eq!(
                level.iter().map(|s| s.count).sum::<usize>(),
                7,
                "every level's summaries cover the corpus"
            );
        }
        assert_eq!(
            agg.super_leaders,
            agg.level_summaries.last().unwrap().len(),
            "super_leaders reports the top level"
        );
        // Leaf summaries cover the corpus too.
        assert_eq!(agg.summaries.iter().map(|s| s.count).sum::<usize>(), 7);
    }

    #[test]
    fn depth_two_matches_the_with_tree_builder_bitwise() {
        // `with_tree` leaves tree_depth at its default of 2, so an
        // explicit depth-2 config is the same object; this pins that the
        // generalized descent at depth 2 reproduces the classic tree.
        let set = scalar_set(&[0.0, 0.05, 1.0, 1.05, 5.0, 5.05, 0.5, 4.8]);
        let classic = AggregateConfig::new(0.2).with_tree(10.0, 1);
        let mut explicit = classic.clone();
        explicit.tree_depth = 2;
        let a = aggregate(&set, &classic, &NativeBackend::new(), 1, None).unwrap();
        let b = aggregate(&set, &explicit, &NativeBackend::new(), 1, None).unwrap();
        assert_eq!(a.rep_ids, b.rep_ids);
        assert_eq!(a.members, b.members);
        assert_eq!(a.probe_pairs, b.probe_pairs);
        assert_eq!(a.super_leaders, b.super_leaders);
        assert_eq!(a.level_summaries.len(), 1);
        assert_eq!(
            a.level_summaries[0].iter().map(|s| s.count).sum::<usize>(),
            8
        );
    }

    #[test]
    fn nearest_indices_orders_and_breaks_ties_deterministically() {
        assert_eq!(nearest_indices(&[0.5, 0.1, 0.3], 2), vec![1, 2]);
        assert_eq!(nearest_indices(&[0.2, 0.2, 0.1], 3), vec![2, 0, 1]);
        assert_eq!(nearest_indices(&[0.4], 5), vec![0]);
        assert!(nearest_indices(&[], 2).is_empty());
    }

    #[test]
    fn invalid_config_rejected() {
        let set = scalar_set(&[0.0]);
        assert!(aggregate(
            &set,
            &AggregateConfig::new(-1.0),
            &NativeBackend::new(),
            1,
            None
        )
        .is_err());
    }
}
