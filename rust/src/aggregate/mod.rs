//! Stage-0 distance-space aggregation: shrink N segments to m ≪ N
//! representatives *before* the MAHC pipeline runs.
//!
//! The paper bounds MAHC's space cost by managing subset sizes, but
//! every segment still enters the pipeline individually, so wall-clock
//! cost is driven by raw N.  Following the data-aggregation-for-HAC
//! idea (Schubert & Lang 2023) adapted to the paper's DTW-only setting
//! — there is no vector space to average in, so representatives must be
//! *actual segments* — a deterministic leader pass ([`leader`]) groups
//! segments whose DTW distance to an already-chosen representative is
//! at most ε, with an optional hard per-group occupancy cap (the β idea
//! applied to stage 0).  The batch and streaming drivers then cluster
//! only the representatives; aggregated members are resolved to final
//! clusters through the same forwarding-pointer mechanism the streaming
//! driver uses to retire objects, so labels cover the full corpus and
//! the final F-measure is computed over all N.
//!
//! Opt-in is zero-risk: `epsilon = 0` skips the pass entirely and the
//! pipeline is bitwise the unaggregated run (pinned in
//! `rust/tests/aggregation.rs`), exactly the story the blocked backend
//! established for kernels.
//!
//! The probe engine ([`leader`]) batches pending segments into cross
//! rectangles so the blocked backend's lane-parallel kernel engages,
//! optionally prunes the candidate set through a two-level leader tree
//! (super-leaders at radius `tree_factor`·ε), and can derive ε itself
//! from a pair-distance quantile of a seeded corpus sample
//! ([`quantile`]) instead of asking the user for an absolute radius.

pub mod leader;
pub mod quantile;
pub mod summary;

pub use leader::{aggregate, Aggregation};
pub use quantile::{derive_epsilon, quantile_of_sorted, EpsilonEstimate};
pub use summary::{check_deviation, scale_condensed_by_counts, GroupSummary};
