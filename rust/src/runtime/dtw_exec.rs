//! XLA-backed DTW backend: tiles pair blocks over the AOT Pallas
//! kernel executable.
//!
//! The planner pads each block of segments to the artifact's (T, D)
//! bucket, dispatches `(bx, by)` pair tiles to the engine, and writes
//! the returned distances into the caller's buffer.  Remainder blocks
//! are padded with length-1 dummies whose outputs are discarded, so a
//! single tile shape serves every subset size; the small exported tile
//! is used when the whole request fits it (less padding waste on the
//! medoid stage's small matrices).

use super::engine::{HostTensor, Runtime};
use super::manifest::DtwEntry;
use crate::corpus::Segment;
use crate::distance::PairwiseBackend;

/// [`PairwiseBackend`] over the AOT DTW tile artifacts.
pub struct XlaDtwBackend<'rt> {
    rt: &'rt Runtime,
    tiles: Vec<DtwEntry>,
}

impl<'rt> XlaDtwBackend<'rt> {
    /// Select the unbanded tiles from the runtime's manifest.
    pub fn new(rt: &'rt Runtime) -> anyhow::Result<Self> {
        let tiles: Vec<DtwEntry> = rt.manifest().dtw_tiles().into_iter().cloned().collect();
        anyhow::ensure!(!tiles.is_empty(), "no DTW artifacts in manifest");
        Ok(XlaDtwBackend { rt, tiles })
    }

    /// Pick the cheapest exported tile for a request.  Cost model per
    /// tile: number of dispatches × per-dispatch work, where work ∝
    /// bx·by·T² (the local-distance matmul dominates and the wavefront
    /// scales with T).  Only tiles whose T bucket covers the longest
    /// segment are eligible.
    fn pick_tile(&self, nx: usize, ny: usize, max_len: usize) -> anyhow::Result<&DtwEntry> {
        self.tiles
            .iter()
            .filter(|t| t.t >= max_len)
            .min_by_key(|t| {
                let dispatches = nx.div_ceil(t.bx) * ny.div_ceil(t.by);
                dispatches * t.bx * t.by * t.t * t.t
            })
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no DTW artifact covers segment length {max_len} \
                     (largest bucket T={})",
                    self.tiles.iter().map(|t| t.t).max().unwrap_or(0)
                )
            })
    }

    /// Pack `segs` (a block of at most `b` segments) into the padded
    /// (b, t, d) buffer + length vector the artifact expects.
    fn pack(
        segs: &[&Segment],
        b: usize,
        t: usize,
        d: usize,
    ) -> anyhow::Result<(Vec<f32>, Vec<i32>)> {
        let mut buf = vec![0.0f32; b * t * d];
        // Dummy lanes must still satisfy len >= 1 for the kernel's DP.
        let mut lens = vec![1i32; b];
        for (k, s) in segs.iter().enumerate() {
            anyhow::ensure!(
                s.len <= t,
                "segment {} has {} frames > artifact bucket T={}",
                s.id,
                s.len,
                t
            );
            anyhow::ensure!(
                s.dim == d,
                "segment {} dim {} != artifact D={}",
                s.id,
                s.dim,
                d
            );
            buf[k * t * d..k * t * d + s.feats.len()].copy_from_slice(&s.feats);
            lens[k] = s.len as i32;
        }
        Ok((buf, lens))
    }
}

impl<'rt> PairwiseBackend for XlaDtwBackend<'rt> {
    fn pairwise(&self, xs: &[&Segment], ys: &[&Segment]) -> anyhow::Result<Vec<f32>> {
        let (nx, ny) = (xs.len(), ys.len());
        let mut out = vec![0.0f32; nx * ny];
        if nx == 0 || ny == 0 {
            return Ok(out);
        }
        let max_len = xs
            .iter()
            .chain(ys.iter())
            .map(|s| s.len)
            .max()
            .unwrap_or(1);
        let tile = self.pick_tile(nx, ny, max_len)?;
        let (bx, by, t, d) = (tile.bx, tile.by, tile.t, tile.d);

        for x0 in (0..nx).step_by(bx) {
            let xb = &xs[x0..(x0 + bx).min(nx)];
            let (xbuf, xlens) = Self::pack(xb, bx, t, d)?;
            for y0 in (0..ny).step_by(by) {
                let yb = &ys[y0..(y0 + by).min(ny)];
                let (ybuf, ylens) = Self::pack(yb, by, t, d)?;
                let res = self.rt.execute(
                    &tile.name,
                    vec![
                        HostTensor::F32(xbuf.clone(), vec![bx as i64, t as i64, d as i64]),
                        HostTensor::F32(ybuf, vec![by as i64, t as i64, d as i64]),
                        HostTensor::I32(xlens.clone(), vec![bx as i64]),
                        HostTensor::I32(ylens, vec![by as i64]),
                    ],
                )?;
                anyhow::ensure!(
                    res.len() == bx * by,
                    "tile returned {} values, expected {}",
                    res.len(),
                    bx * by
                );
                for (i, x) in (x0..(x0 + bx).min(nx)).enumerate() {
                    for (j, y) in (y0..(y0 + by).min(ny)).enumerate() {
                        out[x * ny + y] = res[i * by + j];
                    }
                }
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "xla"
    }

    fn preferred_rows(&self) -> usize {
        // Fill the largest exported tile's X dimension so the condensed
        // builder never pads a whole tile for a single row.
        self.tiles.first().map(|t| t.bx).unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Segment;

    fn seg(id: usize, len: usize, dim: usize, val: f32) -> Segment {
        Segment {
            id,
            class_id: 0,
            len,
            dim,
            feats: vec![val; len * dim],
        }
    }

    #[test]
    fn pack_layout_and_lengths() {
        let a = seg(0, 2, 3, 1.0);
        let b = seg(1, 1, 3, 2.0);
        let (buf, lens) = XlaDtwBackend::pack(&[&a, &b], 4, 5, 3).unwrap();
        assert_eq!(buf.len(), 4 * 5 * 3);
        assert_eq!(lens, vec![2, 1, 1, 1]); // dummies get len 1
        assert_eq!(&buf[0..6], &[1.0; 6]); // a's 2 frames
        assert_eq!(buf[6], 0.0); // a's padding
        assert_eq!(&buf[15..18], &[2.0; 3]); // b starts at 5*3
    }

    #[test]
    fn pack_rejects_oversized_segment() {
        let a = seg(0, 10, 3, 1.0);
        assert!(XlaDtwBackend::pack(&[&a], 1, 5, 3).is_err());
    }

    #[test]
    fn pack_rejects_dim_mismatch() {
        let a = seg(0, 2, 4, 1.0);
        assert!(XlaDtwBackend::pack(&[&a], 1, 5, 3).is_err());
    }
}
