//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on
//! the CPU PJRT client from the Rust hot path.
//!
//! The `xla` crate's handles (client, executables, literals) wrap raw
//! C++ pointers and are neither `Send` nor `Sync`, so the runtime runs
//! a dedicated **engine thread** that owns the client and the compiled-
//! executable cache ([`engine`]).  Callers — including worker threads
//! inside the distance builder — talk to it over a channel using plain
//! host buffers; literals never cross threads.  This also matches the
//! coordinator architecture: one process-wide PJRT engine, many
//! requesting workers.
//!
//! [`manifest`] parses `artifacts/manifest.json` (written by
//! `python/compile/aot.py`); [`dtw_exec`] implements the
//! [`crate::distance::PairwiseBackend`] trait over DTW tile executables;
//! [`mfcc_exec`] wraps the MFCC front-end executable for the audio
//! ingestion path.

pub mod dtw_exec;
pub mod engine;
pub mod manifest;
pub mod mfcc_exec;

pub use dtw_exec::XlaDtwBackend;
pub use engine::{HostTensor, Runtime};
pub use manifest::{ArtifactManifest, DtwEntry, MfccEntry};
