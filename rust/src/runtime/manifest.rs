//! Artifact manifest: what `aot.py` exported and with which shapes.

use crate::util::json;
use std::path::{Path, PathBuf};

/// One exported DTW tile executable.
#[derive(Debug, Clone)]
pub struct DtwEntry {
    pub name: String,
    pub file: String,
    pub bx: usize,
    pub by: usize,
    pub t: usize,
    pub d: usize,
    /// Sakoe-Chiba band radius baked into this variant (None = full).
    pub band: Option<usize>,
}

/// One exported MFCC front-end executable.
#[derive(Debug, Clone)]
pub struct MfccEntry {
    pub name: String,
    pub file: String,
    pub b: usize,
    pub s: usize,
    pub t_out: usize,
    pub feat: usize,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub dtw: Vec<DtwEntry>,
    pub mfcc: Vec<MfccEntry>,
}

impl ArtifactManifest {
    /// Load and validate the manifest in `dir`.
    pub fn load(dir: &Path) -> anyhow::Result<ArtifactManifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} ({e}); run `make artifacts` first",
                path.display()
            )
        })?;
        let doc = json::parse(&text)?;
        let format = doc
            .get("format")
            .and_then(|f| f.as_str())
            .unwrap_or_default();
        anyhow::ensure!(
            format == "hlo-text",
            "unsupported artifact format '{format}' (expected hlo-text)"
        );
        let entries = doc
            .get("entries")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'entries'"))?;

        let mut dtw = Vec::new();
        let mut mfcc = Vec::new();
        for e in entries {
            let kind = e
                .get("kind")
                .and_then(|k| k.as_str())
                .ok_or_else(|| anyhow::anyhow!("entry missing 'kind'"))?;
            let name = req_str(e, "name")?;
            let file = req_str(e, "file")?;
            anyhow::ensure!(
                dir.join(&file).exists(),
                "artifact file {} missing; re-run `make artifacts`",
                file
            );
            match kind {
                "dtw" => dtw.push(DtwEntry {
                    name,
                    file,
                    bx: req_usize(e, "bx")?,
                    by: req_usize(e, "by")?,
                    t: req_usize(e, "t")?,
                    d: req_usize(e, "d")?,
                    band: e.get("band").and_then(|b| b.as_usize()),
                }),
                "mfcc" => mfcc.push(MfccEntry {
                    name,
                    file,
                    b: req_usize(e, "b")?,
                    s: req_usize(e, "s")?,
                    t_out: req_usize(e, "t_out")?,
                    feat: req_usize(e, "feat")?,
                }),
                other => anyhow::bail!("unknown artifact kind '{other}'"),
            }
        }
        Ok(ArtifactManifest {
            dir: dir.to_path_buf(),
            dtw,
            mfcc,
        })
    }

    /// Unbanded DTW tiles, largest first (the planner's preference).
    pub fn dtw_tiles(&self) -> Vec<&DtwEntry> {
        let mut tiles: Vec<&DtwEntry> = self.dtw.iter().filter(|e| e.band.is_none()).collect();
        tiles.sort_by(|a, b| (b.bx * b.by).cmp(&(a.bx * a.by)));
        tiles
    }

    pub fn path_of(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

fn req_str(e: &json::Json, key: &str) -> anyhow::Result<String> {
    e.get(key)
        .and_then(|v| v.as_str())
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow::anyhow!("entry missing '{key}'"))
}

fn req_usize(e: &json::Json, key: &str) -> anyhow::Result<usize> {
    e.get(key)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow::anyhow!("entry missing '{key}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str, files: &[&str]) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
        for f in files {
            std::fs::write(dir.join(f), "ENTRY stub").unwrap();
        }
    }

    #[test]
    fn parses_valid_manifest() {
        let dir = std::env::temp_dir().join("mahc_manifest_ok");
        write_manifest(
            &dir,
            r#"{"format":"hlo-text","entries":[
                {"name":"dtw_a","file":"a.hlo.txt","kind":"dtw","bx":32,"by":32,"t":64,"d":39,"band":null},
                {"name":"dtw_b","file":"b.hlo.txt","kind":"dtw","bx":8,"by":8,"t":64,"d":39,"band":16},
                {"name":"m","file":"m.hlo.txt","kind":"mfcc","b":16,"s":5200,"t_out":64,"feat":39}
            ]}"#,
            &["a.hlo.txt", "b.hlo.txt", "m.hlo.txt"],
        );
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.dtw.len(), 2);
        assert_eq!(m.mfcc.len(), 1);
        let tiles = m.dtw_tiles();
        assert_eq!(tiles.len(), 1); // banded variant excluded
        assert_eq!(tiles[0].bx, 32);
        assert_eq!(m.mfcc[0].t_out, 64);
    }

    #[test]
    fn missing_file_rejected() {
        let dir = std::env::temp_dir().join("mahc_manifest_missing");
        write_manifest(
            &dir,
            r#"{"format":"hlo-text","entries":[
                {"name":"x","file":"nope.hlo.txt","kind":"dtw","bx":8,"by":8,"t":64,"d":39,"band":null}
            ]}"#,
            &[],
        );
        assert!(ArtifactManifest::load(&dir).is_err());
    }

    #[test]
    fn bad_format_rejected() {
        let dir = std::env::temp_dir().join("mahc_manifest_fmt");
        write_manifest(&dir, r#"{"format":"proto","entries":[]}"#, &[]);
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("hlo-text"));
    }

    #[test]
    fn absent_dir_hints_make_artifacts() {
        let err = ArtifactManifest::load(Path::new("/definitely/not/here"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("make artifacts"));
    }
}
