//! The PJRT engine thread: owns the client and executable cache,
//! serves execute requests over a channel.
//!
//! Design constraints (see module docs in `runtime/mod.rs`): the `xla`
//! crate's wrappers are thread-bound, so exactly one OS thread touches
//! them.  Requests carry plain `Vec<f32>` / `Vec<i32>` host tensors and
//! replies carry `Vec<f32>` outputs; compile results are cached by
//! artifact name, so each executable is compiled once per process.

#[cfg(feature = "xla")]
use std::collections::HashMap;
#[cfg(feature = "xla")]
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Mutex;

use super::manifest::ArtifactManifest;

/// A host-side tensor crossing the channel boundary.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
}

impl HostTensor {
    #[cfg(feature = "xla")]
    fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32(data, dims) => xla::Literal::vec1(data).reshape(dims)?,
            HostTensor::I32(data, dims) => xla::Literal::vec1(data).reshape(dims)?,
        };
        Ok(lit)
    }
}

// Without the xla feature no engine thread exists to read requests, so
// the fields are write-only; keep the type unchanged so `execute`
// compiles identically under both configurations.
#[cfg_attr(not(feature = "xla"), allow(dead_code))]
struct Request {
    /// Artifact name (manifest key); resolved to a file + executable.
    name: String,
    inputs: Vec<HostTensor>,
    reply: mpsc::Sender<anyhow::Result<Vec<f32>>>,
}

/// Handle to the engine thread.  Cheap to share behind `&`; `Sync` via
/// the mutex-guarded sender.
pub struct Runtime {
    manifest: ArtifactManifest,
    tx: Mutex<Option<mpsc::Sender<Request>>>,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Runtime {
    /// Start the engine over the artifacts in `dir` (validates the
    /// manifest up front; compiles lazily on first use of each entry).
    ///
    /// Without the `xla` cargo feature this is a stub that fails with a
    /// descriptive error: the crate builds and tests offline, and every
    /// artifact-dependent path degrades to "rebuild with --features xla".
    #[cfg(not(feature = "xla"))]
    pub fn new(dir: &std::path::Path) -> anyhow::Result<Runtime> {
        // Validate the manifest anyway so `inspect`-style callers get
        // the more specific error when artifacts are absent.
        let _ = ArtifactManifest::load(dir)?;
        anyhow::bail!(
            "built without the 'xla' feature: the PJRT runtime is unavailable \
             (rebuild with `--features xla` to execute AOT artifacts)"
        )
    }

    /// Start the engine over the artifacts in `dir` (validates the
    /// manifest up front; compiles lazily on first use of each entry).
    #[cfg(feature = "xla")]
    pub fn new(dir: &std::path::Path) -> anyhow::Result<Runtime> {
        let manifest = ArtifactManifest::load(dir)?;
        let files: HashMap<String, PathBuf> = manifest
            .dtw
            .iter()
            .map(|e| (e.name.clone(), manifest.path_of(&e.file)))
            .chain(
                manifest
                    .mfcc
                    .iter()
                    .map(|e| (e.name.clone(), manifest.path_of(&e.file))),
            )
            .collect();

        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || engine_main(files, rx, ready_tx))?;
        // Surface client construction errors at startup, not first call.
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))??;
        Ok(Runtime {
            manifest,
            tx: Mutex::new(Some(tx)),
            join: Mutex::new(Some(join)),
        })
    }

    /// Default artifacts location (`$MAHC_ARTIFACTS` or `./artifacts`).
    pub fn from_default_dir() -> anyhow::Result<Runtime> {
        let dir = std::env::var("MAHC_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Runtime::new(std::path::Path::new(&dir))
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Execute artifact `name` with `inputs`; returns the flat f32
    /// output (graphs are lowered with return_tuple=True and exactly
    /// one result tensor).
    pub fn execute(&self, name: &str, inputs: Vec<HostTensor>) -> anyhow::Result<Vec<f32>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        {
            let guard = self.tx.lock().unwrap_or_else(|p| p.into_inner());
            let tx = guard
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("runtime already shut down"))?;
            tx.send(Request {
                name: name.to_string(),
                inputs,
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        }
        reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine dropped reply"))?
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        // Close the queue, then join the engine thread.  Poison just
        // means a sender panicked; shutdown must still complete.
        *self.tx.lock().unwrap_or_else(|p| p.into_inner()) = None;
        let join = self.join.lock().unwrap_or_else(|p| p.into_inner()).take();
        if let Some(j) = join {
            let _ = j.join();
        }
    }
}

/// Engine thread body: compile-on-demand + execute loop.
#[cfg(feature = "xla")]
fn engine_main(
    files: HashMap<String, PathBuf>,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<anyhow::Result<()>>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow::anyhow!("PjRtClient::cpu failed: {e}")));
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    while let Ok(req) = rx.recv() {
        let result = serve_one(&client, &files, &mut cache, &req);
        let _ = req.reply.send(result);
    }
}

#[cfg(feature = "xla")]
fn serve_one(
    client: &xla::PjRtClient,
    files: &HashMap<String, PathBuf>,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    req: &Request,
) -> anyhow::Result<Vec<f32>> {
    if !cache.contains_key(&req.name) {
        let path = files
            .get(&req.name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact '{}'", req.name))?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", req.name))?;
        cache.insert(req.name.clone(), exe);
    }
    let exe = cache
        .get(&req.name)
        .ok_or_else(|| anyhow::anyhow!("executable cache lost '{}'", req.name))?;

    let literals: Vec<xla::Literal> = req
        .inputs
        .iter()
        .map(|t| t.to_literal())
        .collect::<anyhow::Result<_>>()?;
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow::anyhow!("execute {}: {e}", req.name))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
    // Graphs are lowered with return_tuple=True: unwrap the 1-tuple.
    let out = lit
        .to_tuple1()
        .map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
    out.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("to_vec: {e}"))
}
