//! MFCC front-end executor: waveforms → 39-dim feature segments through
//! the AOT Layer-2 graph.
//!
//! The artifact processes fixed (B, S) waveform batches producing
//! (B, T, 39) features.  Shorter signals are zero-padded to S and the
//! feature rows beyond the signal's true frame count are dropped on the
//! way out, so callers see exactly `num_frames(len)` frames — matching
//! the native `dsp::mfcc` path frame-for-frame.

use super::engine::{HostTensor, Runtime};
use super::manifest::MfccEntry;
use crate::dsp::window::num_frames;

/// Frame geometry must match the artifact (pinned in `kernels/ref.py`).
const FRAME_LEN: usize = 160;
const FRAME_HOP: usize = 80;

/// Executor over the exported MFCC batch graph.
pub struct MfccFrontend<'rt> {
    rt: &'rt Runtime,
    entry: MfccEntry,
}

impl<'rt> MfccFrontend<'rt> {
    pub fn new(rt: &'rt Runtime) -> anyhow::Result<Self> {
        let entry = rt
            .manifest()
            .mfcc
            .first()
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("no MFCC artifact in manifest"))?;
        Ok(MfccFrontend { rt, entry })
    }

    /// Max waveform samples one lane accepts.
    pub fn max_samples(&self) -> usize {
        self.entry.s
    }

    /// Extract features for a batch of waveforms of arbitrary (≤ S)
    /// lengths.  Returns per-waveform `(frames, 39)` flat f32 buffers.
    pub fn extract(&self, wavs: &[Vec<f32>]) -> anyhow::Result<Vec<(usize, Vec<f32>)>> {
        let (b, s, t_out, feat) = (self.entry.b, self.entry.s, self.entry.t_out, self.entry.feat);
        let mut out = Vec::with_capacity(wavs.len());
        for chunk in wavs.chunks(b) {
            let mut buf = vec![0.0f32; b * s];
            // Per-lane true frame counts: the graph's deltas replicate
            // each lane's own last real frame (len >= 1 for dummies).
            let mut lens = vec![1i32; b];
            for (lane, w) in chunk.iter().enumerate() {
                anyhow::ensure!(
                    w.len() <= s,
                    "waveform of {} samples exceeds artifact bucket S={s}",
                    w.len()
                );
                anyhow::ensure!(
                    w.len() >= FRAME_LEN,
                    "waveform of {} samples shorter than one frame",
                    w.len()
                );
                buf[lane * s..lane * s + w.len()].copy_from_slice(w);
                lens[lane] = num_frames(w.len(), FRAME_LEN, FRAME_HOP).min(t_out) as i32;
            }
            let res = self.rt.execute(
                &self.entry.name,
                vec![
                    HostTensor::F32(buf, vec![b as i64, s as i64]),
                    HostTensor::I32(lens, vec![b as i64]),
                ],
            )?;
            anyhow::ensure!(
                res.len() == b * t_out * feat,
                "mfcc artifact returned {} values, expected {}",
                res.len(),
                b * t_out * feat
            );
            for (lane, w) in chunk.iter().enumerate() {
                let frames = num_frames(w.len(), FRAME_LEN, FRAME_HOP).min(t_out);
                let start = lane * t_out * feat;
                out.push((frames, res[start..start + frames * feat].to_vec()));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_geometry_matches_dsp() {
        // The truncation rule must agree with the native front-end.
        assert_eq!(num_frames(5200, FRAME_LEN, FRAME_HOP), 64);
        assert_eq!(num_frames(1000, FRAME_LEN, FRAME_HOP), 11);
    }
}
