//! Mel filterbank (HTK-style mel scale), mirroring `kernels/ref.py`.

pub fn hz_to_mel(f: f64) -> f64 {
    2595.0 * (1.0 + f / 700.0).log10()
}

pub fn mel_to_hz(m: f64) -> f64 {
    700.0 * (10f64.powf(m / 2595.0) - 1.0)
}

/// Triangular filterbank: rows are filters over `nfft/2 + 1` power bins.
pub fn mel_filterbank(n_mels: usize, nfft: usize, sample_rate: usize) -> Vec<Vec<f64>> {
    let lo = hz_to_mel(0.0);
    let hi = hz_to_mel(sample_rate as f64 / 2.0);
    let pts: Vec<f64> = (0..n_mels + 2)
        .map(|i| mel_to_hz(lo + (hi - lo) * i as f64 / (n_mels + 1) as f64))
        .collect();
    let nbins = nfft / 2 + 1;
    let bin_hz: Vec<f64> = (0..nbins)
        .map(|i| i as f64 * sample_rate as f64 / nfft as f64)
        .collect();
    (0..n_mels)
        .map(|m| {
            let (left, center, right) = (pts[m], pts[m + 1], pts[m + 2]);
            bin_hz
                .iter()
                .map(|&f| {
                    let up = (f - left) / (center - left).max(1e-12);
                    let down = (right - f) / (right - center).max(1e-12);
                    up.min(down).max(0.0)
                })
                .collect()
        })
        .collect()
}

/// Apply the filterbank to a power spectrum and take the floored log.
pub fn log_mel(power: &[f64], fb: &[Vec<f64>], floor: f64) -> Vec<f64> {
    fb.iter()
        .map(|filt| {
            let e: f64 = filt.iter().zip(power).map(|(w, p)| w * p).sum();
            e.max(floor).ln()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mel_scale_round_trip() {
        for f in [0.0, 100.0, 1000.0, 8000.0] {
            assert!((mel_to_hz(hz_to_mel(f)) - f).abs() < 1e-9);
        }
    }

    #[test]
    fn filterbank_shape_and_support() {
        let fb = mel_filterbank(26, 256, 16_000);
        assert_eq!(fb.len(), 26);
        assert_eq!(fb[0].len(), 129);
        // Every filter is nonnegative with nonempty support.
        for filt in &fb {
            assert!(filt.iter().all(|&v| v >= 0.0));
            assert!(filt.iter().any(|&v| v > 0.0));
        }
    }

    #[test]
    fn filters_are_ordered_in_frequency() {
        let fb = mel_filterbank(26, 256, 16_000);
        let centers: Vec<usize> = fb
            .iter()
            .map(|f| {
                f.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            })
            .collect();
        for w in centers.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn log_mel_floors() {
        let fb = mel_filterbank(4, 16, 16_000);
        let power = vec![0.0; 9];
        let lm = log_mel(&power, &fb, 1e-10);
        for &v in &lm {
            assert!((v - (1e-10f64).ln()).abs() < 1e-12);
        }
    }
}
