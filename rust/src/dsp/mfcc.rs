//! Full MFCC pipeline: waveform → 39-dim feature sequence.
//!
//! Composition of the sibling modules, parameter-for-parameter identical
//! to `python/compile/model.py::mfcc_frontend` (asserted in the
//! `artifact_crosscheck` integration test).

use super::{dct, delta, fft, mel, window};

/// Feature dimensionality: 12 MFCC + logE, with Δ and ΔΔ appended.
pub const FEAT_DIM: usize = 39;

/// Front-end parameters (paper §6.1 defaults).
#[derive(Debug, Clone)]
pub struct MfccConfig {
    pub sample_rate: usize,
    pub frame_len: usize,
    pub frame_hop: usize,
    pub nfft: usize,
    pub n_mels: usize,
    pub n_ceps: usize,
    pub preemph: f64,
    pub delta_win: usize,
    pub floor: f64,
}

impl Default for MfccConfig {
    fn default() -> Self {
        MfccConfig {
            sample_rate: 16_000,
            frame_len: 160, // 10 ms
            frame_hop: 80,  // 5 ms (50% overlap)
            nfft: 256,
            n_mels: 26,
            n_ceps: 12,
            preemph: 0.97,
            delta_win: 2,
            floor: 1.0e-10,
        }
    }
}

/// Precomputed tables for repeated extraction.
pub struct MfccExtractor {
    cfg: MfccConfig,
    window: Vec<f64>,
    fb: Vec<Vec<f64>>,
    dct: Vec<Vec<f64>>,
}

impl MfccExtractor {
    pub fn new(cfg: MfccConfig) -> Self {
        let window = window::hamming(cfg.frame_len);
        let fb = mel::mel_filterbank(cfg.n_mels, cfg.nfft, cfg.sample_rate);
        let dct = dct::dct_matrix(cfg.n_ceps, cfg.n_mels);
        MfccExtractor {
            cfg,
            window,
            fb,
            dct,
        }
    }

    /// Extract (T, 39) features from a waveform.  Returns an empty Vec
    /// if the signal is shorter than one frame.
    pub fn extract(&self, wav: &[f64]) -> Vec<Vec<f64>> {
        let cfg = &self.cfg;
        let pre = window::preemphasis(wav, cfg.preemph);
        let frames = window::frames(&pre, cfg.frame_len, cfg.frame_hop, &self.window);
        if frames.is_empty() {
            return Vec::new();
        }
        let mut base: Vec<Vec<f64>> = frames
            .iter()
            .map(|frame| {
                let power = fft::power_spectrum(frame, cfg.nfft);
                let lm = mel::log_mel(&power, &self.fb, cfg.floor);
                let mut row = dct::apply(&self.dct, &lm);
                let energy: f64 = frame.iter().map(|v| v * v).sum();
                row.push(energy.max(cfg.floor).ln());
                row
            })
            .collect();
        let d1 = delta::delta(&base, cfg.delta_win);
        let d2 = delta::delta(&d1, cfg.delta_win);
        for (i, row) in base.iter_mut().enumerate() {
            row.extend_from_slice(&d1[i]);
            row.extend_from_slice(&d2[i]);
        }
        base
    }
}

/// One-shot extraction with default parameters.
pub fn mfcc(wav: &[f64]) -> Vec<Vec<f64>> {
    MfccExtractor::new(MfccConfig::default()).extract(wav)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_for_standard_input() {
        let wav = vec![0.1; 5200];
        let f = mfcc(&wav);
        assert_eq!(f.len(), 64);
        assert_eq!(f[0].len(), FEAT_DIM);
    }

    #[test]
    fn too_short_signal_is_empty() {
        assert!(mfcc(&vec![0.0; 100]).is_empty());
    }

    #[test]
    fn silence_hits_floor_and_zero_deltas() {
        let f = mfcc(&vec![0.0; 1000]);
        for row in &f {
            assert!((row[12] - (1e-10f64).ln()).abs() < 1e-9); // logE at floor
            for &v in &row[13..] {
                assert!(v.abs() < 1e-9); // deltas of constant are zero
            }
        }
    }

    #[test]
    fn tone_produces_stable_cepstra() {
        // Tone + deterministic broadband floor: a bare sinusoid leaves
        // most mel filters at the log floor, where leakage makes the
        // cepstra flutter; the broadband term pins them, so interior
        // frames of a steady signal must agree closely.
        let mut lcg = 123456789u64;
        let wav: Vec<f64> = (0..5200)
            .map(|i| {
                lcg = lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let noise = ((lcg >> 33) as f64 / (1u64 << 31) as f64) - 0.5;
                0.5 * (2.0 * std::f64::consts::PI * 440.0 * i as f64 / 16_000.0).sin()
                    + 0.02 * noise
            })
            .collect();
        let f = mfcc(&wav);
        let mid = &f[20][..12];
        for row in &f[21..40] {
            let mean_abs: f64 = row[..12]
                .iter()
                .zip(mid)
                .map(|(a, b)| (a - b).abs())
                .sum::<f64>()
                / 12.0;
            assert!(mean_abs < 1.0, "mean |Δcepstra| {mean_abs:.3}");
        }
    }

    #[test]
    fn amplitude_shifts_only_log_energy() {
        let wav: Vec<f64> = (0..2000)
            .map(|i| (i as f64 * 0.1).sin() * 0.2 + (i as f64 * 0.037).cos() * 0.1)
            .collect();
        let a = mfcc(&wav);
        let b = mfcc(&wav.iter().map(|v| 4.0 * v).collect::<Vec<_>>());
        for (ra, rb) in a.iter().zip(&b) {
            for k in 0..12 {
                assert!((ra[k] - rb[k]).abs() < 1e-6);
            }
            assert!((rb[12] - ra[12] - (16.0f64).ln()).abs() < 1e-6);
        }
    }
}
