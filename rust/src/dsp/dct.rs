//! DCT-II cepstral transform, rows 1..n_ceps (c0 is replaced by log
//! energy in the 39-dim feature), HTK √(2/N) scaling — mirrors
//! `kernels/ref.py::dct_matrix`.

/// (n_ceps, n_mels) DCT-II matrix.
pub fn dct_matrix(n_ceps: usize, n_mels: usize) -> Vec<Vec<f64>> {
    (1..=n_ceps)
        .map(|k| {
            (0..n_mels)
                .map(|m| {
                    (2.0 / n_mels as f64).sqrt()
                        * (std::f64::consts::PI * k as f64 * (m as f64 + 0.5) / n_mels as f64)
                            .cos()
                })
                .collect()
        })
        .collect()
}

/// Apply the DCT matrix to a log-mel vector.
pub fn apply(dct: &[Vec<f64>], log_mel: &[f64]) -> Vec<f64> {
    dct.iter()
        .map(|row| row.iter().zip(log_mel).map(|(a, b)| a * b).sum())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape() {
        let m = dct_matrix(12, 26);
        assert_eq!(m.len(), 12);
        assert_eq!(m[0].len(), 26);
    }

    #[test]
    fn rows_orthogonal() {
        let m = dct_matrix(12, 26);
        for i in 0..12 {
            for j in 0..12 {
                let dot: f64 = m[i].iter().zip(&m[j]).map(|(a, b)| a * b).sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-9, "rows {i},{j}: {dot}");
            }
        }
    }

    #[test]
    fn constant_input_gives_zero_cepstra() {
        // Rows k >= 1 integrate cos over full periods -> 0 for constants.
        let m = dct_matrix(12, 26);
        let ceps = apply(&m, &vec![3.7; 26]);
        for &c in &ceps {
            assert!(c.abs() < 1e-9);
        }
    }
}
