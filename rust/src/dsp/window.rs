//! Analysis windows and framing.

/// Hamming window of length `n` (matches `kernels/ref.py::hamming`).
pub fn hamming(n: usize) -> Vec<f64> {
    assert!(n >= 2);
    (0..n)
        .map(|i| 0.54 - 0.46 * (2.0 * std::f64::consts::PI * i as f64 / (n - 1) as f64).cos())
        .collect()
}

/// Number of frames produced by framing `num_samples` with the given
/// frame length and hop (no padding; matches `model.mfcc_num_frames`).
pub fn num_frames(num_samples: usize, frame_len: usize, hop: usize) -> usize {
    if num_samples < frame_len {
        0
    } else {
        1 + (num_samples - frame_len) / hop
    }
}

/// Pre-emphasis filter y[t] = x[t] − a·x[t−1], y[0] = x[0]·(1−a).
pub fn preemphasis(x: &[f64], a: f64) -> Vec<f64> {
    if x.is_empty() {
        return Vec::new();
    }
    let mut out = Vec::with_capacity(x.len());
    out.push(x[0] * (1.0 - a));
    for t in 1..x.len() {
        out.push(x[t] - a * x[t - 1]);
    }
    out
}

/// Extract windowed frames: (num_frames, frame_len), row-major flat.
pub fn frames(x: &[f64], frame_len: usize, hop: usize, window: &[f64]) -> Vec<Vec<f64>> {
    assert_eq!(window.len(), frame_len);
    let t = num_frames(x.len(), frame_len, hop);
    (0..t)
        .map(|i| {
            x[i * hop..i * hop + frame_len]
                .iter()
                .zip(window)
                .map(|(s, w)| s * w)
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hamming_endpoints_and_symmetry() {
        let w = hamming(160);
        assert!((w[0] - 0.08).abs() < 1e-12);
        assert!((w[159] - 0.08).abs() < 1e-12);
        for i in 0..80 {
            assert!((w[i] - w[159 - i]).abs() < 1e-12);
        }
        // Peak at the middle region.
        assert!(w[80] > 0.99);
    }

    #[test]
    fn frame_count_matches_python() {
        assert_eq!(num_frames(5200, 160, 80), 64);
        assert_eq!(num_frames(160, 160, 80), 1);
        assert_eq!(num_frames(240, 160, 80), 2);
        assert_eq!(num_frames(100, 160, 80), 0);
    }

    #[test]
    fn preemphasis_dc_removal() {
        let x = vec![1.0; 100];
        let y = preemphasis(&x, 0.97);
        assert!((y[0] - 0.03).abs() < 1e-12);
        for &v in &y[1..] {
            assert!((v - 0.03).abs() < 1e-12);
        }
    }

    #[test]
    fn frames_overlap() {
        let x: Vec<f64> = (0..400).map(|i| i as f64).collect();
        let w = vec![1.0; 160];
        let f = frames(&x, 160, 80, &w);
        assert_eq!(f.len(), 4);
        assert_eq!(f[0][0], 0.0);
        assert_eq!(f[1][0], 80.0);
        assert_eq!(f[3][159], 399.0);
    }
}
