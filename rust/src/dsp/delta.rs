//! HTK regression deltas with edge replication, mirroring
//! `kernels/ref.py::delta`.

/// Delta features over the time axis.  `feat` is (T, F) row-major.
pub fn delta(feat: &[Vec<f64>], win: usize) -> Vec<Vec<f64>> {
    let t = feat.len();
    if t == 0 {
        return Vec::new();
    }
    let f = feat[0].len();
    let denom: f64 = 2.0 * (1..=win).map(|th| (th * th) as f64).sum::<f64>();
    (0..t)
        .map(|i| {
            let mut acc = vec![0.0; f];
            for th in 1..=win {
                let fwd = &feat[(i + th).min(t - 1)];
                let bwd = &feat[i.saturating_sub(th)];
                for (a, (x, y)) in acc.iter_mut().zip(fwd.iter().zip(bwd)) {
                    *a += th as f64 * (x - y);
                }
            }
            acc.iter().map(|a| a / denom).collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_gives_zero() {
        let feat = vec![vec![1.0, -2.0]; 8];
        for row in delta(&feat, 2) {
            assert!(row.iter().all(|v| v.abs() < 1e-12));
        }
    }

    #[test]
    fn linear_ramp_gives_slope_interior() {
        let feat: Vec<Vec<f64>> = (0..20).map(|t| vec![3.0 * t as f64]).collect();
        let d = delta(&feat, 2);
        for row in &d[2..18] {
            assert!((row[0] - 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn empty_and_single_frame() {
        assert!(delta(&[], 2).is_empty());
        let d = delta(&[vec![5.0]], 2);
        assert_eq!(d.len(), 1);
        assert!(d[0][0].abs() < 1e-12); // fwd == bwd == the only frame
    }
}
