//! Acoustic front-end substrate: HTK-style MFCC extraction.
//!
//! Native Rust mirror of the Layer-2 JAX graph (`python/compile/model.py
//! :: mfcc_frontend`) and of the numpy oracle (`kernels/ref.py`).  Used
//! (a) as the feature extractor when running without artifacts, (b) as
//! the cross-check for the AOT MFCC executable in integration tests,
//! and (c) by the corpus generator's waveform path.
//!
//! Parameters are pinned to paper §6.1: 12 MFCCs + log energy + Δ + ΔΔ
//! (39 dims), 10 ms frames, 5 ms hop (50% overlap), 16 kHz.

pub mod dct;
pub mod delta;
pub mod fft;
pub mod mel;
pub mod mfcc;
pub mod window;

pub use mfcc::{mfcc, MfccConfig, FEAT_DIM};
