//! Iterative radix-2 Cooley-Tukey FFT (power-of-two sizes).
//!
//! Only the real-input forward transform is needed (power spectrum of
//! 256-sample frames); it is implemented as a complex FFT over the
//! zero-padded frame followed by magnitude extraction of the first
//! N/2+1 bins.  f64 throughout — the front-end runs once per segment at
//! corpus-build time, so numerical fidelity beats speed here.

/// Complex number as (re, im); a full complex type is overkill here.
pub type C = (f64, f64);

#[inline]
fn c_add(a: C, b: C) -> C {
    (a.0 + b.0, a.1 + b.1)
}

#[inline]
fn c_sub(a: C, b: C) -> C {
    (a.0 - b.0, a.1 - b.1)
}

#[inline]
fn c_mul(a: C, b: C) -> C {
    (a.0 * b.0 - a.1 * b.1, a.0 * b.1 + a.1 * b.0)
}

/// In-place iterative radix-2 FFT.  `data.len()` must be a power of two.
pub fn fft_inplace(data: &mut [C]) {
    let n = data.len();
    assert!(n.is_power_of_two(), "FFT size {n} not a power of two");
    if n <= 1 {
        return;
    }

    // Bit-reversal permutation.
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i as u32).reverse_bits() >> (32 - bits);
        let j = j as usize;
        if i < j {
            data.swap(i, j);
        }
    }

    // Butterfly passes.
    let mut len = 2;
    while len <= n {
        let ang = -2.0 * std::f64::consts::PI / len as f64;
        let wlen: C = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let mut w: C = (1.0, 0.0);
            for k in 0..len / 2 {
                let u = data[i + k];
                let v = c_mul(data[i + k + len / 2], w);
                data[i + k] = c_add(u, v);
                data[i + k + len / 2] = c_sub(u, v);
                w = c_mul(w, wlen);
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Power spectrum |rFFT(x, nfft)|² — first nfft/2+1 bins.
///
/// `x` is zero-padded (or truncated) to `nfft`.
pub fn power_spectrum(x: &[f64], nfft: usize) -> Vec<f64> {
    let mut buf: Vec<C> = (0..nfft)
        .map(|i| (x.get(i).copied().unwrap_or(0.0), 0.0))
        .collect();
    fft_inplace(&mut buf);
    buf[..nfft / 2 + 1]
        .iter()
        .map(|&(re, im)| re * re + im * im)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// O(n²) reference DFT.
    fn dft(x: &[C]) -> Vec<C> {
        let n = x.len();
        (0..n)
            .map(|k| {
                let mut acc = (0.0, 0.0);
                for (j, &v) in x.iter().enumerate() {
                    let ang = -2.0 * std::f64::consts::PI * (k * j) as f64 / n as f64;
                    acc = c_add(acc, c_mul(v, (ang.cos(), ang.sin())));
                }
                acc
            })
            .collect()
    }

    #[test]
    fn matches_reference_dft() {
        for &n in &[2usize, 4, 8, 64, 256] {
            let mut x: Vec<C> = (0..n)
                .map(|i| ((i as f64 * 0.37).sin(), (i as f64 * 0.11).cos()))
                .collect();
            let want = dft(&x);
            fft_inplace(&mut x);
            for (got, want) in x.iter().zip(&want) {
                assert!((got.0 - want.0).abs() < 1e-9, "n={n}");
                assert!((got.1 - want.1).abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn impulse_is_flat() {
        let mut x = vec![(0.0, 0.0); 16];
        x[0] = (1.0, 0.0);
        fft_inplace(&mut x);
        for &(re, im) in &x {
            assert!((re - 1.0).abs() < 1e-12 && im.abs() < 1e-12);
        }
    }

    #[test]
    fn pure_tone_peaks_at_bin() {
        let n = 256;
        let k0 = 19;
        let x: Vec<f64> = (0..n)
            .map(|i| (2.0 * std::f64::consts::PI * k0 as f64 * i as f64 / n as f64).cos())
            .collect();
        let p = power_spectrum(&x, n);
        let peak = p
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(peak, k0);
    }

    #[test]
    fn parseval() {
        let n = 128;
        let x: Vec<f64> = (0..n).map(|i| ((i * i) as f64 * 0.013).sin()).collect();
        let time_energy: f64 = x.iter().map(|v| v * v).sum();
        let mut buf: Vec<C> = x.iter().map(|&v| (v, 0.0)).collect();
        fft_inplace(&mut buf);
        let freq_energy: f64 =
            buf.iter().map(|&(re, im)| re * re + im * im).sum::<f64>() / n as f64;
        assert!((time_energy - freq_energy).abs() < 1e-9 * time_energy.max(1.0));
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_panics() {
        let mut x = vec![(0.0, 0.0); 12];
        fft_inplace(&mut x);
    }
}
