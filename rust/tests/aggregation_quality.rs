//! Aggregation-quality conformance: the oracle wall for the
//! cluster-feature path.
//!
//! * group summaries `(count, radius, spread)` fuzz-checked against a
//!   brute-force recomputation through the backend under test, bitwise,
//!   across backends × threads (the CI backend matrix sweeps
//!   `MAHC_TEST_BACKEND` / `MAHC_TEST_THREADS` over this suite);
//! * `GroupSummary::merge` invariants: count additivity independent of
//!   merge order, radius/spread monotone upper bounds;
//! * tree-folded level summaries upper-bound the true descendant
//!   member→anchor distances on a metric corpus (1-frame scalars, where
//!   DTW *is* a metric: `d = |a − b| / 2`);
//! * arbitrary-depth parity pins: depth 1 is the flat pass bitwise even
//!   with a tree factor configured, depth 2 is the historical two-level
//!   tree bitwise on a non-covering factor, and covering trees of depth
//!   2..4 reproduce the flat grouping;
//! * deviation-bound admissibility: duplicate collapse has bound 0 and
//!   count-weighted Ward over representatives reproduces the
//!   full-corpus heights (`--deviation debug` re-checks this inline in
//!   both drivers); jittered duplicates report a strictly positive
//!   bound through telemetry;
//! * medoid retirement: on a corpus crafted so a member strays into a
//!   wrong-class leader group within ε, retiring to the nearest final
//!   medoid relabels exactly the aggregated members and never scores
//!   below leader forwarding.

mod common;

use mahc::aggregate::{aggregate, check_deviation, GroupSummary};
use mahc::config::{
    AggregateConfig, AlgoConfig, Convergence, DatasetSpec, DeviationMode, RetireMode, StreamConfig,
};
use mahc::corpus::{generate, Segment, SegmentSet};
use mahc::distance::{build_condensed, BackendKind, BlockedBackend, NativeBackend, PairwiseBackend};
use mahc::mahc::{MahcDriver, StreamingDriver};

/// 1-frame scalar corpus: DTW distance is `|a − b| / 2` (the kernel
/// normalises by the summed lengths), which satisfies the triangle
/// inequality — the metric setting the summary-fold bounds are exact in.
fn scalar_set(vals: &[(f32, usize)], num_classes: usize) -> SegmentSet {
    let set = SegmentSet {
        name: "scalar_quality".into(),
        dim: 1,
        segments: vals
            .iter()
            .enumerate()
            .map(|(id, &(v, class_id))| Segment {
                id,
                class_id,
                len: 1,
                dim: 1,
                feats: vec![v],
            })
            .collect(),
        num_classes,
    };
    set.validate().expect("scalar corpus is well-formed");
    set
}

/// Deterministic LCG so the fuzz corpora are identical in every matrix
/// cell (the seeds, not the OS, drive the sweep).
fn lcg(state: &mut u64) -> f32 {
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    ((*state >> 33) as f32) / ((1u64 << 31) as f32)
}

/// Distance oracle: one pairwise call through the same backend the
/// pass used, so expected and actual values share every rounding step.
fn dist(backend: &dyn PairwiseBackend, a: &Segment, b: &Segment) -> f32 {
    backend.pairwise(&[a], &[b]).unwrap()[0]
}

fn agg_cfg(eps: f32) -> AlgoConfig {
    AlgoConfig {
        p0: 3,
        beta: Some(40),
        convergence: Convergence::FixedIters(3),
        aggregate: AggregateConfig::new(eps),
        ..Default::default()
    }
}

/// A corpus where segment `n + i` duplicates segment `i`, optionally
/// jittered by `jitter` on the first feature (0.0 = exact duplicate).
fn duplicated_corpus(n: usize, classes: usize, seed: u64, jitter: f32) -> SegmentSet {
    let base = generate(&DatasetSpec::tiny(n, classes, seed));
    let mut segments = base.segments.clone();
    for i in 0..n {
        let mut dup = base.segments[i].clone();
        dup.id = n + i;
        if jitter > 0.0 {
            dup.feats[0] += jitter;
        }
        segments.push(dup);
    }
    let set = SegmentSet {
        name: format!("{}_doubled", base.name),
        dim: base.dim,
        segments,
        num_classes: base.num_classes,
    };
    set.validate().expect("duplicated corpus is well-formed");
    set
}

/// ε strictly between 0 and the smallest nonzero pair distance.
fn below_min_nonzero_distance(set: &SegmentSet) -> f32 {
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let cond = build_condensed(&refs, &NativeBackend::new(), 4).unwrap();
    let min_nonzero = cond
        .as_slice()
        .iter()
        .copied()
        .filter(|&d| d > 0.0)
        .fold(f32::INFINITY, f32::min);
    assert!(min_nonzero.is_finite() && min_nonzero > 0.0);
    min_nonzero * 0.5
}

#[test]
fn summaries_match_brute_force_bitwise_across_the_matrix() {
    let backend = common::backend_under_test(BackendKind::Native);
    let native = NativeBackend::new();
    let blocked = BlockedBackend::new();
    let mut state = 0x5eed_cafe_u64;
    for (n, eps) in [(12usize, 0.03f32), (30, 0.01), (30, 0.08)] {
        let vals: Vec<(f32, usize)> = (0..n).map(|_| (lcg(&mut state), 0)).collect();
        let set = scalar_set(&vals, 1);
        let mut reference: Option<Vec<GroupSummary>> = None;
        for threads in common::thread_matrix(&[1, 8]) {
            let agg = aggregate(&set, &AggregateConfig::new(eps), backend.as_ref(), threads, None)
                .unwrap();
            // Counts partition the corpus.
            assert_eq!(agg.summaries.len(), agg.reps());
            assert_eq!(agg.summaries.iter().map(|s| s.count).sum::<usize>(), n);
            for (g, s) in agg.summaries.iter().enumerate() {
                assert_eq!(s.count, agg.members[g].len(), "group {g} count");
                // Brute force through the same backend: radius is the
                // max member→leader distance, spread the fixed-order
                // sum in join order — bitwise, not approximately.
                let leader = &set.segments[agg.rep_ids[g]];
                let mut radius = 0.0f32;
                let mut spread = 0.0f32;
                for &id in &agg.members[g][1..] {
                    let d = dist(backend.as_ref(), &set.segments[id], leader);
                    assert!(d <= eps, "member {id} joined outside ε");
                    radius = radius.max(d);
                    spread += d;
                }
                assert_eq!(s.radius.to_bits(), radius.to_bits(), "group {g} radius");
                assert_eq!(s.spread.to_bits(), spread.to_bits(), "group {g} spread");
            }
            // Summaries are part of the determinism contract: bitwise
            // across threads and across the scalar/blocked backends.
            let others: [(&str, &dyn PairwiseBackend); 2] =
                [("scalar", &native), ("blocked", &blocked)];
            for (bname, other) in others {
                let again = aggregate(&set, &AggregateConfig::new(eps), other, threads, None)
                    .unwrap();
                assert_eq!(again.summaries.len(), agg.summaries.len());
                for (a, b) in agg.summaries.iter().zip(&again.summaries) {
                    assert_eq!(a.count, b.count, "{bname}/t{threads}");
                    assert_eq!(a.radius.to_bits(), b.radius.to_bits(), "{bname}/t{threads}");
                    assert_eq!(a.spread.to_bits(), b.spread.to_bits(), "{bname}/t{threads}");
                }
            }
            match &reference {
                None => reference = Some(agg.summaries.clone()),
                Some(r) => assert_eq!(r, &agg.summaries, "thread sweep changed summaries"),
            }
        }
    }
}

#[test]
fn merge_is_count_order_invariant_and_monotone() {
    let mut state = 0xfu64;
    for _ in 0..200 {
        let mut a = GroupSummary::singleton();
        let mut b = GroupSummary::singleton();
        for _ in 0..(1 + (lcg(&mut state) * 4.0) as usize) {
            a.absorb(lcg(&mut state));
        }
        for _ in 0..(1 + (lcg(&mut state) * 4.0) as usize) {
            b.absorb(lcg(&mut state));
        }
        let link = lcg(&mut state);
        let ab = a.merge(&b, link);
        let ba = b.merge(&a, link);
        // Count additivity is exact and order-invariant; radius/spread
        // are anchored (at the left operand) so only their bound
        // properties are order-free.
        assert_eq!(ab.count, a.count + b.count);
        assert_eq!(ab.count, ba.count);
        assert!(ab.radius >= a.radius, "merge may not shrink the anchor radius");
        assert!(ab.radius >= link + b.radius - 1e-6, "folded child escapes the radius");
        assert!(ab.spread >= a.spread, "merge may not shrink the anchor spread");
        assert!(ba.radius >= b.radius);
    }
}

#[test]
fn tree_fold_upper_bounds_descendant_distances_on_a_metric() {
    // Covering tree: tree_factor·ε exceeds the corpus diameter, so every
    // level has exactly one node, anchored at the first leader — the
    // brute-force member→anchor distances are then directly computable.
    let mut state = 0xabcdu64;
    let vals: Vec<(f32, usize)> = (0..40).map(|_| (4.0 * lcg(&mut state), 0)).collect();
    let set = scalar_set(&vals, 1);
    let backend = NativeBackend::new();
    let eps = 0.1f32;
    let cfg = AggregateConfig::new(eps).with_tree(100.0, 2).with_depth(3);
    let agg = aggregate(&set, &cfg, &backend, 4, None).unwrap();
    assert!(agg.reps() >= 2, "corpus must actually aggregate");
    assert_eq!(agg.level_summaries.len(), 2, "depth 3 folds two node levels");
    let anchor = &set.segments[agg.rep_ids[0]];
    for (l, level) in agg.level_summaries.iter().enumerate() {
        assert_eq!(level.len(), 1, "covering tree has one node per level");
        assert_eq!(level[0].count, set.len(), "level {l} counts must cover the corpus");
        let mut true_max = 0.0f32;
        let mut true_sum = 0.0f64;
        for seg in &set.segments {
            let d = dist(&backend, seg, anchor);
            true_max = true_max.max(d);
            true_sum += d as f64;
        }
        // Triangle-inequality upper bounds, with an f32 slack for the
        // fold's own rounding.
        let slack = 1e-5 * (1.0 + true_max as f64);
        assert!(
            level[0].radius as f64 + slack >= true_max as f64,
            "level {l}: folded radius {} < true max {}",
            level[0].radius,
            true_max
        );
        assert!(
            level[0].spread as f64 + 1e-4 * (1.0 + true_sum) >= true_sum,
            "level {l}: folded spread {} < true sum {}",
            level[0].spread,
            true_sum
        );
    }
    assert_eq!(agg.super_leaders, 1, "top level is the single covering node");
}

#[test]
fn depth_one_is_the_flat_pass_bitwise_across_the_matrix() {
    let backend = common::backend_under_test(BackendKind::Native);
    let mut state = 0x1234u64;
    let vals: Vec<(f32, usize)> = (0..50).map(|_| (2.0 * lcg(&mut state), 0)).collect();
    let set = scalar_set(&vals, 1);
    let flat = AggregateConfig::new(0.05);
    // Depth 1 with a tree factor configured must never build the tree.
    let depth1 = AggregateConfig::new(0.05).with_tree(8.0, 2).with_depth(1);
    for threads in common::thread_matrix(&[1, 8]) {
        let a = aggregate(&set, &flat, backend.as_ref(), threads, None).unwrap();
        let b = aggregate(&set, &depth1, backend.as_ref(), threads, None).unwrap();
        assert_eq!(a.rep_ids, b.rep_ids, "t{threads}");
        assert_eq!(a.members, b.members, "t{threads}");
        assert_eq!(a.rep_of, b.rep_of, "t{threads}");
        assert_eq!(a.probe_pairs, b.probe_pairs, "t{threads}: probe sequence");
        assert_eq!(a.probe_rounds, b.probe_rounds, "t{threads}");
        assert_eq!((a.rect_rows, a.rect_cols), (b.rect_rows, b.rect_cols), "t{threads}");
        assert_eq!(a.super_leaders, 0, "flat pass has no nodes");
        assert_eq!(b.super_leaders, 0, "depth 1 has no nodes");
        assert!(b.level_summaries.is_empty(), "depth 1 folds nothing");
        for (x, y) in a.summaries.iter().zip(&b.summaries) {
            assert_eq!(x.count, y.count);
            assert_eq!(x.radius.to_bits(), y.radius.to_bits());
            assert_eq!(x.spread.to_bits(), y.spread.to_bits());
        }
    }
}

#[test]
fn depth_two_is_the_historical_tree_bitwise_across_the_matrix() {
    // `with_tree` alone is the historical two-level configuration
    // (default depth 2); spelling the depth out must change nothing —
    // on a *non*-covering factor, so the tree actually prunes probes.
    let backend = common::backend_under_test(BackendKind::Native);
    let mut state = 0x2222u64;
    let vals: Vec<(f32, usize)> = (0..60).map(|_| (3.0 * lcg(&mut state), 0)).collect();
    let set = scalar_set(&vals, 1);
    let historical = AggregateConfig::new(0.06).with_tree(3.0, 2);
    let explicit = AggregateConfig::new(0.06).with_tree(3.0, 2).with_depth(2);
    for threads in common::thread_matrix(&[1, 8]) {
        let a = aggregate(&set, &historical, backend.as_ref(), threads, None).unwrap();
        let b = aggregate(&set, &explicit, backend.as_ref(), threads, None).unwrap();
        assert_eq!(a.rep_ids, b.rep_ids, "t{threads}");
        assert_eq!(a.members, b.members, "t{threads}");
        assert_eq!(a.rep_of, b.rep_of, "t{threads}");
        assert_eq!(a.probe_pairs, b.probe_pairs, "t{threads}: probe sequence");
        assert_eq!(a.super_leaders, b.super_leaders, "t{threads}");
        assert!(a.super_leaders >= 1, "non-degenerate tree must have nodes");
        assert_eq!(a.level_summaries.len(), 1, "depth 2 folds one node level");
        assert_eq!(b.level_summaries.len(), 1);
        for (x, y) in a.level_summaries[0].iter().zip(&b.level_summaries[0]) {
            assert_eq!(x.count, y.count, "t{threads}");
            assert_eq!(x.radius.to_bits(), y.radius.to_bits(), "t{threads}");
            assert_eq!(x.spread.to_bits(), y.spread.to_bits(), "t{threads}");
        }
    }
}

#[test]
fn covering_trees_of_any_depth_reproduce_the_flat_grouping() {
    // One covering node per level cannot prune any leader out of sight,
    // so the grouping — though not the probe count — matches flat.
    let mut state = 0x77u64;
    let vals: Vec<(f32, usize)> = (0..60).map(|_| (3.0 * lcg(&mut state), 0)).collect();
    let set = scalar_set(&vals, 1);
    let backend = NativeBackend::new();
    let flat = aggregate(&set, &AggregateConfig::new(0.06), &backend, 4, None).unwrap();
    for depth in [2usize, 3, 4] {
        let cfg = AggregateConfig::new(0.06).with_tree(200.0, 2).with_depth(depth);
        let got = aggregate(&set, &cfg, &backend, 4, None).unwrap();
        assert_eq!(got.rep_ids, flat.rep_ids, "depth {depth}: rep set");
        assert_eq!(got.members, flat.members, "depth {depth}: memberships");
        assert_eq!(got.rep_of, flat.rep_of, "depth {depth}: rep_of");
        assert_eq!(got.level_summaries.len(), depth - 1, "depth {depth}: level count");
        for (l, level) in got.level_summaries.iter().enumerate() {
            assert_eq!(
                level.iter().map(|s| s.count).sum::<usize>(),
                set.len(),
                "depth {depth} level {l}: counts must partition the corpus"
            );
        }
        assert_eq!(got.super_leaders, 1, "depth {depth}: single covering top node");
        for (x, y) in flat.summaries.iter().zip(&got.summaries) {
            assert_eq!(x.radius.to_bits(), y.radius.to_bits(), "depth {depth}");
            assert_eq!(x.spread.to_bits(), y.spread.to_bits(), "depth {depth}");
        }
    }
}

#[test]
fn duplicate_collapse_has_zero_bound_and_exact_weighted_heights() {
    let set = duplicated_corpus(30, 4, 4242, 0.0);
    let eps = below_min_nonzero_distance(&set);
    let backend = NativeBackend::new();
    let agg = aggregate(&set, &AggregateConfig::new(eps), &backend, 4, None).unwrap();
    assert!(agg.reps() < set.len(), "duplicates must collapse");
    // Zero-distance joins only: every group radius is 0, so the bound
    // is exactly 0 and count-weighted Ward over representatives is the
    // full dendrogram (the classic weighted-objects identity).
    assert!(agg.summaries.iter().all(|s| s.radius == 0.0));
    assert_eq!(agg.deviation_bound(), 0.0);
    let max_delta = check_deviation(&set, &agg, &backend, 4, None).unwrap();
    assert!(
        max_delta.is_finite() && max_delta >= 0.0,
        "admissibility oracle returned {max_delta}"
    );
}

#[test]
fn deviation_debug_mode_holds_end_to_end_on_duplicate_collapse() {
    let set = duplicated_corpus(24, 3, 777, 0.0);
    let eps = below_min_nonzero_distance(&set);
    let backend = NativeBackend::new();
    let mut cfg = agg_cfg(eps);
    cfg.deviation = DeviationMode::Debug;
    // Batch driver: the inline per-merge recheck must pass.
    let run = MahcDriver::new(&set, cfg.clone(), &backend).unwrap().run().unwrap();
    assert_eq!(run.labels.len(), set.len());
    // Zero-radius groups report a zero bound in telemetry.
    assert_eq!(run.history.records[0].deviation_bound, 0.0);
    assert_eq!(run.history.deviation_bound(), 0.0);
    // Streaming driver: same tripwire at prepare time.
    let stream = StreamingDriver::new(&set, StreamConfig::new(cfg, 24), &backend)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(stream.labels.len(), set.len());
    assert_eq!(stream.history.records[0].deviation_bound, 0.0);
}

#[test]
fn jittered_duplicates_report_a_positive_bound_in_telemetry() {
    let base = duplicated_corpus(24, 3, 909, 0.0);
    let eps = below_min_nonzero_distance(&base);
    // Jitter well inside ε: groups still form, now with radius > 0.
    let set = duplicated_corpus(24, 3, 909, eps * 0.5);
    let backend = NativeBackend::new();
    let agg = aggregate(&set, &AggregateConfig::new(eps), &backend, 4, None).unwrap();
    assert!(agg.reps() < set.len(), "jittered duplicates must still collapse");
    assert!(agg.summaries.iter().any(|s| s.radius > 0.0));
    let bound = agg.deviation_bound();
    assert!(bound > 0.0, "nonzero radii must report a nonzero bound");
    // The bound reaches telemetry on record 0 of both drivers, bitwise
    // the same value.
    let run = MahcDriver::new(&set, agg_cfg(eps), &backend).unwrap().run().unwrap();
    assert_eq!(run.history.records[0].deviation_bound, bound);
    assert_eq!(run.history.deviation_bound(), bound);
    for r in run.history.records.iter().skip(1) {
        assert_eq!(r.deviation_bound, 0.0, "only record 0 carries the bound");
    }
    let stream = StreamingDriver::new(&set, StreamConfig::new(agg_cfg(eps), 24), &backend)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(stream.history.records[0].deviation_bound, bound);
}

/// The medoid-retirement fixture: class-1 segment `1` (value 0.35) sits
/// within ε = 0.2 of the class-0 leader at 0.0 (scalar DTW halves the
/// gap: 0.175), so the leader pass absorbs it into the wrong-class
/// group; the class-1 leader at 0.5 is 0.075 away — strictly nearer —
/// so nearest-final-medoid retirement can only move it toward (never
/// away from) its own class, whatever cluster count the pipeline picks.
fn stray_member_corpus() -> SegmentSet {
    scalar_set(
        &[
            (0.0, 0),
            (0.35, 1), // the stray: joins the 0.0 leader, nearer to 0.5
            (0.02, 0),
            (0.04, 0),
            (0.5, 1),
            (0.52, 1),
            (0.54, 1),
            (2.0, 2),
            (2.02, 2),
            (2.04, 2),
            (3.0, 3),
            (3.02, 3),
            (3.04, 3),
        ],
        4,
    )
}

#[test]
fn medoid_retirement_relabels_only_aggregated_members_and_never_degrades_f() {
    let set = stray_member_corpus();
    let backend = NativeBackend::new();
    let eps = 0.2f32;
    // Pin the fixture's geometry: four leader groups, the stray in the
    // first one.
    let agg = aggregate(&set, &AggregateConfig::new(eps), &backend, 1, None).unwrap();
    assert_eq!(agg.rep_ids, vec![0, 4, 7, 10]);
    assert_eq!(agg.rep_of[1], 0, "the stray must join the class-0 leader");

    let mk = |retire: RetireMode| {
        let mut cfg = agg_cfg(eps);
        cfg.retire = retire;
        StreamingDriver::new(&set, StreamConfig::new(cfg, 16), &backend)
            .unwrap()
            .run()
            .unwrap()
    };
    let leader = mk(RetireMode::Leader);
    let medoid = mk(RetireMode::Medoid);

    // Leader mode is the bitwise oracle for everything that was active:
    // representatives keep identical labels, and only aggregated
    // non-representative members may move.
    assert_eq!(leader.labels.len(), set.len());
    assert_eq!(medoid.labels.len(), set.len());
    assert_eq!(leader.k, medoid.k, "retirement happens after clustering");
    let reps: Vec<usize> = agg.rep_ids.clone();
    for &r in &reps {
        assert_eq!(leader.labels[r], medoid.labels[r], "rep {r} must not move");
    }
    for id in 0..set.len() {
        if leader.labels[id] != medoid.labels[id] {
            assert!(!reps.contains(&id), "only aggregated members may be relabeled");
        }
    }
    // The quality guarantee this fixture was built to prove.
    assert!(
        medoid.f_measure >= leader.f_measure,
        "medoid retirement degraded F: {} < {}",
        medoid.f_measure,
        leader.f_measure
    );
    // Determinism: a second medoid run is bitwise identical.
    let again = mk(RetireMode::Medoid);
    assert_eq!(again.labels, medoid.labels);
    assert_eq!(again.f_measure.to_bits(), medoid.f_measure.to_bits());
}
