//! Shared plumbing for the CI backend-matrix test crates
//! (`backend_parity.rs`, `cache_determinism.rs`): one definition of how
//! a matrix cell is read from the environment, so the suites can never
//! silently test different matrices.
//!
//! Not a test target itself — Cargo only builds the `[[test]]` paths
//! spelled out in Cargo.toml, and each suite pulls this in with
//! `mod common;`.

// Each consumer uses the subset it needs; unused items in the other
// crate's compilation must not fail `clippy -D warnings`.
#![allow(dead_code)]

use mahc::distance::{BackendKind, BlockedBackend, PairwiseBackend, NativeBackend};

/// Backend under test for this matrix cell: `MAHC_TEST_BACKEND`
/// (`scalar`|`native`|`blocked`), or `default` when unset.
pub fn backend_under_test(default: BackendKind) -> Box<dyn PairwiseBackend> {
    let kind = match std::env::var("MAHC_TEST_BACKEND").ok() {
        None => default,
        Some(s) => BackendKind::parse(&s).expect("MAHC_TEST_BACKEND"),
    };
    match kind {
        BackendKind::Native => Box::new(NativeBackend::new()),
        BackendKind::Blocked => Box::new(BlockedBackend::new()),
        BackendKind::Xla => panic!("the backend matrix covers native|blocked only"),
    }
}

/// The suite's built-in thread sweep plus this matrix cell's
/// `MAHC_TEST_THREADS`, if any.
pub fn thread_matrix(base: &[usize]) -> Vec<usize> {
    let mut t = base.to_vec();
    if let Some(extra) = std::env::var("MAHC_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    {
        if !t.contains(&extra) {
            t.push(extra);
        }
    }
    t
}

/// Bitwise f32 comparison with an identifying context (equality of
/// floats would also pass on -0.0 vs +0.0; parity means the *bits*).
pub fn assert_bitwise(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{ctx}: entry {i} differs: {x} vs {y}"
        );
    }
}
