//! Tier-1 pins for the lower-bound pruning engine (`--prune`).
//!
//! The contract under test: `prune = off` IS today's exact code path,
//! and `prune = on` / `prune = debug` reproduce it **bitwise** — labels,
//! K, F-measure — across thread counts and backends, because the
//! envelope bound is admissible in floating point and every consumer of
//! a pruned value only compares it against the threshold that pruned
//! it.  The suite runs inside the CI backend matrix (`--test pruning`),
//! so each matrix cell re-checks parity under its own
//! `MAHC_TEST_BACKEND` / `MAHC_TEST_THREADS` pair on top of the sweep
//! built in here.

mod common;

use mahc::config::{
    AggregateConfig, AlgoConfig, Convergence, DatasetSpec, PruneMode, StreamConfig,
};
use mahc::corpus::{generate, Segment, SegmentSet};
use mahc::distance::{
    build_cross, build_cross_cached_pruned, BackendKind, BlockedBackend, CascadeBackend,
    CascadeMode, PairwiseBackend, NativeBackend, PairCache,
};
use mahc::dtw::INFEASIBLE;
use mahc::mahc::{MahcDriver, StreamingDriver};

fn matrix_backends() -> Vec<Box<dyn PairwiseBackend>> {
    // The scalar reference and the lane-parallel kernel, plus whatever
    // cell the CI matrix pins via MAHC_TEST_BACKEND (dedup'd by name).
    let mut backends: Vec<Box<dyn PairwiseBackend>> =
        vec![Box::new(NativeBackend::new()), Box::new(BlockedBackend::new())];
    let env = common::backend_under_test(BackendKind::Native);
    if backends.iter().all(|b| b.name() != env.name()) {
        backends.push(env);
    }
    backends
}

fn base_cfg(threads: usize) -> AlgoConfig {
    let mut cfg = AlgoConfig {
        p0: 3,
        beta: Some(30),
        convergence: Convergence::FixedIters(3),
        threads,
        ..Default::default()
    };
    // Stage-0 aggregation is the driver's threshold-carrying consumer:
    // without it every query is a condensed build, which stays exact by
    // design, and the cascade would have nothing to do.
    cfg.aggregate = AggregateConfig::new(0.5);
    cfg
}

/// Hand-built corpus with controlled lengths and features; ids are
/// positional, as [`generate`] produces them.
fn synth_set(dim: usize, lens: &[usize], gen: impl Fn(usize, usize) -> f32) -> SegmentSet {
    let segments: Vec<Segment> = lens
        .iter()
        .enumerate()
        .map(|(id, &len)| Segment {
            id,
            class_id: 0,
            len,
            dim,
            feats: (0..len * dim).map(|k| gen(id, k)).collect(),
        })
        .collect();
    SegmentSet {
        name: "synth".into(),
        dim,
        segments,
        num_classes: 1,
    }
}

#[test]
fn batch_prune_modes_are_bitwise_the_exact_run_across_the_matrix() {
    let set = generate(&DatasetSpec::tiny(80, 5, 33));
    for backend in matrix_backends() {
        for threads in common::thread_matrix(&[1, 8]) {
            let cfg = base_cfg(threads);
            let exact = MahcDriver::new(&set, cfg.clone(), backend.as_ref())
                .unwrap()
                .run()
                .unwrap();
            for r in &exact.history.records {
                assert_eq!(r.lb_pairs, 0, "exact mode must never touch the bound");
                assert_eq!(r.lb_pruned, 0);
            }
            for mode in [PruneMode::On, PruneMode::Debug] {
                let mut pruned_cfg = cfg.clone();
                pruned_cfg.prune = mode;
                let got = MahcDriver::new(&set, pruned_cfg, backend.as_ref())
                    .unwrap()
                    .run()
                    .unwrap();
                let ctx = format!("{}/t{threads}/{mode:?}", backend.name());
                assert_eq!(got.labels, exact.labels, "{ctx}: labels diverged");
                assert_eq!(got.k, exact.k, "{ctx}: K diverged");
                assert_eq!(
                    got.f_measure.to_bits(),
                    exact.f_measure.to_bits(),
                    "{ctx}: F diverged"
                );
                let r0 = got.history.records.first().expect("records");
                assert!(r0.lb_pairs > 0, "{ctx}: the cascade never engaged");
                assert!(
                    r0.backend.ends_with("+lb"),
                    "{ctx}: backend stamp is {}",
                    r0.backend
                );
            }
        }
    }
}

#[test]
fn stream_prune_modes_are_bitwise_the_exact_run_across_the_matrix() {
    let set = generate(&DatasetSpec::tiny(120, 6, 34));
    for backend in matrix_backends() {
        for threads in common::thread_matrix(&[1, 8]) {
            let cfg = StreamConfig::new(base_cfg(threads), 40);
            let exact = StreamingDriver::new(&set, cfg.clone(), backend.as_ref())
                .unwrap()
                .run()
                .unwrap();
            assert!(exact.shards > 1, "need retirement rectangles to prune");
            for mode in [PruneMode::On, PruneMode::Debug] {
                let mut pruned_cfg = cfg.clone();
                pruned_cfg.algo.prune = mode;
                let got = StreamingDriver::new(&set, pruned_cfg, backend.as_ref())
                    .unwrap()
                    .run()
                    .unwrap();
                let ctx = format!("{}/t{threads}/{mode:?}", backend.name());
                assert_eq!(got.labels, exact.labels, "{ctx}: labels diverged");
                assert_eq!(got.k, exact.k, "{ctx}: K diverged");
                assert_eq!(
                    got.f_measure.to_bits(),
                    exact.f_measure.to_bits(),
                    "{ctx}: F diverged"
                );
                assert_eq!(got.shards, exact.shards, "{ctx}: shard count diverged");
                let total_lb: u64 = got.history.records.iter().map(|r| r.lb_pairs).sum();
                assert!(total_lb > 0, "{ctx}: the cascade never engaged");
            }
        }
    }
}

#[test]
fn fuzzed_lb_admissibility_never_exceeds_exact_dtw() {
    // Pseudo-random corpora over several dims, lengths and scales: the
    // float bound must sit at or below the float DP total for every
    // pair — a plain f32 <=, which is exactly what the Debug cascade
    // asserts in production.
    let native = NativeBackend::new();
    for (dim, seed) in [(1usize, 101u64), (3, 102), (13, 103)] {
        let lens: Vec<usize> = (0..18).map(|i| 3 + (i * 7 + dim) % 21).collect();
        let set = synth_set(dim, &lens, |id, k| {
            let t = (k as f32 * 0.37 + id as f32 * 1.7 + seed as f32 * 0.11).sin();
            t * (1.0 + (id % 5) as f32)
        });
        let cascade = CascadeBackend::borrowed(&native, &set, CascadeMode::On);
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let exact = build_cross(&refs, &refs, &native, 4).unwrap();
        let n = refs.len();
        for (i, x) in refs.iter().enumerate() {
            for (j, y) in refs.iter().enumerate() {
                if i == j {
                    continue;
                }
                let lb = cascade.lb_pair(x, y).unwrap();
                let ex = exact[i * n + j];
                assert!(
                    lb <= ex,
                    "dim={dim}: inadmissible bound {lb} > exact {ex} for pair ({i}, {j})"
                );
            }
        }
        // A real corpus from the generator, through the Debug tripwire
        // (which verifies lb <= exact for every pair internally).
        let real = generate(&DatasetSpec::tiny(30, 4, seed));
        let dbg = CascadeBackend::borrowed(&native, &real, CascadeMode::Debug);
        let rr: Vec<&Segment> = real.segments.iter().collect();
        for threshold in [0.0f32, 0.2, 0.5, 2.0] {
            dbg.pairwise_pruned(&rr[..10], &rr[10..], threshold)
                .expect("admissibility tripwire must not fire");
        }
    }
}

#[test]
fn banded_inner_with_infeasible_pairs_keeps_the_bound_admissible() {
    // Band narrower than the length gap: the exact banded DP returns
    // the INFEASIBLE sentinel for those pairs, which dominates any
    // finite envelope bound — the Debug tripwire must stay quiet and
    // decisions must match the exact banded path.
    let dim = 2;
    let lens = [4usize, 16, 5, 20, 6, 12];
    let set = synth_set(dim, &lens, |id, k| ((k + id * 3) as f32 * 0.29).cos());
    let banded = NativeBackend::banded(1);
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let exact = build_cross(&refs[..3], &refs[3..], &banded, 1).unwrap();
    assert!(
        exact.iter().any(|&v| v >= INFEASIBLE / 2.0),
        "the length gaps must make some pairs infeasible for this pin to bite"
    );
    let cascade = CascadeBackend::borrowed(&banded, &set, CascadeMode::Debug);
    for threshold in [0.0f32, 0.5, 10.0] {
        let (vals, flags) = cascade
            .pairwise_pruned(&refs[..3], &refs[3..], threshold)
            .expect("infeasible pairs must not trip admissibility");
        for ((&v, &f), &ex) in vals.iter().zip(&flags).zip(&exact) {
            assert_eq!(
                v <= threshold,
                ex <= threshold,
                "threshold decision diverged at t={threshold}"
            );
            if f {
                assert_eq!(v.to_bits(), ex.to_bits(), "survivors are exact");
            }
        }
    }
    // And the wrapper keys the banded kernel's cache tag, so pruned
    // banded values never alias full-band entries.
    assert_eq!(cascade.kernel_tag(), banded.kernel_tag());
    assert_ne!(cascade.kernel_tag(), NativeBackend::new().kernel_tag());
}

#[test]
fn degenerate_thresholds_and_identical_corpora_stay_exact() {
    let native = NativeBackend::new();

    // All-identical corpus: every pair distance and every bound is 0,
    // so an ε = 0 threshold prunes nothing and everything stays exact.
    let same = synth_set(3, &[7; 12], |_, k| ((k % 3) as f32) * 0.5);
    let cascade = CascadeBackend::borrowed(&native, &same, CascadeMode::Debug);
    let refs: Vec<&Segment> = same.segments.iter().collect();
    let (vals, flags) = cascade.pairwise_pruned(&refs[..4], &refs[4..], 0.0).unwrap();
    assert!(flags.iter().all(|&f| f), "zero bounds survive an ε = 0 threshold");
    assert!(vals.iter().all(|&v| v == 0.0));

    // ε = 0 end to end: aggregation at radius 0 with pruning on is
    // still bitwise the unaggregated exact run (every segment leads).
    let set = generate(&DatasetSpec::tiny(50, 4, 35));
    let mut off = base_cfg(2);
    off.aggregate = AggregateConfig::new(0.0);
    let mut on = off.clone();
    on.prune = PruneMode::On;
    let exact = MahcDriver::new(&set, off, &native).unwrap().run().unwrap();
    let pruned = MahcDriver::new(&set, on, &native).unwrap().run().unwrap();
    assert_eq!(pruned.labels, exact.labels);
    assert_eq!(pruned.k, exact.k);
    assert_eq!(pruned.f_measure.to_bits(), exact.f_measure.to_bits());

    // The pruned cross builder at a mid-range threshold: decisions
    // match the oracle pair for pair, survivors bitwise, and a warm
    // exact rebuild over the same cache is untouched by lower bounds.
    let rs: Vec<&Segment> = set.segments.iter().collect();
    let cas = CascadeBackend::borrowed(&native, &set, CascadeMode::On);
    let (xs, ys) = (&rs[..20], &rs[20..]);
    let want = build_cross(xs, ys, &native, 2).unwrap();
    let mut sorted = want.clone();
    sorted.sort_unstable_by(f32::total_cmp);
    let threshold = sorted[sorted.len() / 2];
    let cache = PairCache::with_capacity_bytes(1 << 20);
    let got =
        build_cross_cached_pruned(xs, ys, &cas, 2, Some(&cache), Some(threshold)).unwrap();
    common::assert_bitwise(
        &got.iter()
            .zip(&want)
            .map(|(&g, &w)| if g <= threshold { g } else { w })
            .collect::<Vec<_>>(),
        &want,
        "survivor values",
    );
    for (&g, &w) in got.iter().zip(&want) {
        assert_eq!(g <= threshold, w <= threshold, "decision parity");
    }
    assert!(cas.stats().lb_pruned > 0, "mid-range threshold must prune");
    let warm = mahc::distance::build_cross_cached(xs, ys, &native, 2, Some(&cache)).unwrap();
    common::assert_bitwise(&warm, &want, "warm exact rebuild over pruned cache");
}
