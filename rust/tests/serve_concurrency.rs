//! Serve-mode contract: interleaving many streaming sessions over one
//! worker pool and one shared pair cache changes *no* output bit of any
//! session, and every fleet-level protection — admission caps, β, the
//! per-session cache budgets, panic isolation — holds while the fleet
//! actually runs concurrently.
//!
//! The CI TSan job runs this suite: the scheduler, the shared cache's
//! scoped handles, and the worker pool all cross threads here, so any
//! unsynchronised access shows up as a data-race report rather than a
//! flaky bit.

use std::sync::Arc;

use mahc::config::{AlgoConfig, Convergence, DatasetSpec, ServeConfig, StreamConfig};
use mahc::corpus::{generate, SegmentSet};
use mahc::distance::{PairwiseBackend, NativeBackend};
use mahc::mahc::{ServeDriver, SessionSpec, StreamingDriver};
use mahc::StreamResult;

fn algo(beta: usize, cache_bytes: usize) -> AlgoConfig {
    AlgoConfig {
        p0: 2,
        beta: Some(beta),
        convergence: Convergence::FixedIters(2),
        cache_bytes,
        ..Default::default()
    }
}

fn backend() -> Arc<dyn PairwiseBackend + Send + Sync> {
    Arc::new(NativeBackend::new())
}

/// One spec plus the sequential (private-cache) result it must
/// reproduce under fleet interleaving.
fn spec_and_expected(i: usize, cache_bytes: usize) -> (SessionSpec, StreamResult) {
    let set: Arc<SegmentSet> =
        Arc::new(generate(&DatasetSpec::tiny(60 + 10 * i, 4, 700 + i as u64)));
    let cfg = StreamConfig::new(algo(24, cache_bytes), 24);
    let expected = StreamingDriver::new(&set, cfg.clone(), &NativeBackend::new())
        .unwrap()
        .run()
        .unwrap();
    (SessionSpec::new(&format!("s{i}"), set, cfg), expected)
}

#[test]
fn five_interleaved_sessions_reproduce_sequential_results_bitwise() {
    let beta = 24;
    let mut specs = Vec::new();
    let mut expected = Vec::new();
    for i in 0..5 {
        let (s, e) = spec_and_expected(i, 16 << 10);
        specs.push(s);
        expected.push(e);
    }
    let report = ServeDriver::new(
        ServeConfig {
            workers: 4,
            fleet_cap: 5,
            queue_cap: 0,
            cache_bytes: 1 << 20,
        },
        backend(),
    )
    .unwrap()
    .run(specs)
    .unwrap();

    assert_eq!(report.completed(), 5);
    for (out, exp) in report.sessions.iter().zip(&expected) {
        let got = out.result.as_ref().expect("session must complete");
        assert_eq!(got.labels, exp.labels, "labels diverged for {}", out.name);
        assert_eq!(got.k, exp.k, "K diverged for {}", out.name);
        assert_eq!(
            got.f_measure.to_bits(),
            exp.f_measure.to_bits(),
            "F diverged for {}",
            out.name
        );
        assert_eq!(got.shards, exp.shards);
        assert_eq!(got.history.records.len(), exp.history.records.len());
        // β is a per-session guarantee and must survive fleet
        // concurrency: every episode of every session stays under it.
        for r in &got.history.records {
            assert!(
                r.max_occupancy <= beta,
                "{} shard {} occupancy {} > β under concurrency",
                out.name,
                r.iteration,
                r.max_occupancy
            );
        }
    }
    assert!(report.fleet.peak_active() <= 5);
}

#[test]
fn per_session_cache_budgets_hold_while_the_fleet_runs() {
    let budget = 4096usize; // 128 entries per session
    let mut specs = Vec::new();
    for i in 0..4 {
        let (s, _) = spec_and_expected(i, budget);
        specs.push(s);
    }
    let report = ServeDriver::new(
        ServeConfig {
            workers: 4,
            fleet_cap: 4,
            queue_cap: 0,
            cache_bytes: 8 << 20,
        },
        backend(),
    )
    .unwrap()
    .run(specs)
    .unwrap();
    assert_eq!(report.completed(), 4);
    let peak = report.fleet.peak_cache_bytes();
    assert!(peak > 0, "fleet cache never used");
    assert!(
        peak <= 4 * budget,
        "fleet residency {peak} B exceeds the sum of per-session budgets {} B",
        4 * budget
    );
}

#[test]
fn a_panicking_session_leaves_the_rest_of_the_fleet_bitwise_intact() {
    let mut specs = Vec::new();
    let mut expected = Vec::new();
    for i in 0..4 {
        let (s, e) = spec_and_expected(i, 8 << 10);
        specs.push(s);
        expected.push(e);
    }
    specs[2].panic_after_shards = Some(1);
    let report = ServeDriver::new(
        ServeConfig {
            workers: 2,
            fleet_cap: 4,
            queue_cap: 0,
            cache_bytes: 1 << 20,
        },
        backend(),
    )
    .unwrap()
    .run(specs)
    .unwrap();

    assert_eq!(report.completed(), 3);
    assert_eq!(report.failed(), 1);
    for (i, (out, exp)) in report.sessions.iter().zip(&expected).enumerate() {
        if i == 2 {
            let msg = out.result.as_ref().expect_err("faulted session must fail");
            assert!(msg.contains("injected session fault"), "got: {msg}");
            continue;
        }
        let got = out.result.as_ref().expect("bystander must complete");
        assert_eq!(got.labels, exp.labels, "bystander {} perturbed", out.name);
        assert_eq!(
            got.f_measure.to_bits(),
            exp.f_measure.to_bits(),
            "bystander {} F perturbed",
            out.name
        );
    }
}

#[test]
fn admission_control_caps_the_fleet_deterministically() {
    let mut specs = Vec::new();
    let mut expected = Vec::new();
    for i in 0..5 {
        let (s, e) = spec_and_expected(i, 0);
        specs.push(s);
        expected.push(e);
    }
    let report = ServeDriver::new(
        ServeConfig {
            workers: 2,
            fleet_cap: 2,
            queue_cap: 1,
            cache_bytes: 0,
        },
        backend(),
    )
    .unwrap()
    .run(specs)
    .unwrap();

    // Specs 0-1 fill the fleet cap, spec 2 queues (promoted later),
    // specs 3-4 are rejected — decided at submission, so always the
    // same specs regardless of scheduling timing.
    assert_eq!(report.completed(), 3);
    for (i, (out, exp)) in report.sessions.iter().zip(&expected).enumerate() {
        if i < 3 {
            let got = out.result.as_ref().expect("admitted session completes");
            assert_eq!(got.labels, exp.labels, "session {} diverged", out.name);
        } else {
            let msg = out.result.as_ref().expect_err("overflow spec rejected");
            assert!(msg.contains("rejected at admission"), "got: {msg}");
        }
    }
    assert!(
        report.fleet.peak_active() <= 2,
        "fleet cap breached: peak {}",
        report.fleet.peak_active()
    );
    let rejects = report
        .fleet
        .records
        .iter()
        .filter(|r| r.event == "reject")
        .count();
    assert_eq!(rejects, 2);
}
