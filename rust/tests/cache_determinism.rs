//! The pair cache's correctness contract: enabling it — at any budget,
//! under any hit/eviction pattern, on any thread count, over any
//! backend — changes *no* output bit anywhere in the system.
//! Wall-clock is the only observable allowed to move.
//!
//! The CI backend-matrix job re-runs this suite per cell: the backend
//! under test comes from `MAHC_TEST_BACKEND` (default native) and
//! `MAHC_TEST_THREADS` extends the built-in thread sweeps.

mod common;

use common::{backend_under_test, thread_matrix};
use mahc::config::{AlgoConfig, Convergence, DatasetSpec, StreamConfig};
use mahc::corpus::{generate, Segment};
use mahc::distance::{
    build_condensed, build_condensed_cached, build_cross, build_cross_cached, BackendKind,
    PairCache,
};
use mahc::mahc::{MahcDriver, StreamSession, StreamingDriver};

/// Backend under test: native by default, or the CI matrix cell.
fn backend() -> Box<dyn mahc::distance::PairwiseBackend> {
    backend_under_test(BackendKind::Native)
}

#[test]
fn condensed_bitwise_identical_across_cache_states_and_threads() {
    let set = generate(&DatasetSpec::tiny(60, 5, 2024));
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let backend = backend();
    let backend = backend.as_ref();
    let want = build_condensed(&refs, backend, 1).unwrap();

    // Budgets from "evicts almost everything" to "holds everything";
    // for each, repeated builds on several thread counts must reproduce
    // the uncached matrix bit for bit whatever the cache contains.
    for budget in [1usize, 512, 64 << 10, 8 << 20] {
        let cache = PairCache::with_capacity_bytes(budget);
        for threads in thread_matrix(&[1, 2, 4, 8]) {
            for pass in 0..3 {
                let got =
                    build_condensed_cached(&refs, backend, threads, Some(&cache)).unwrap();
                assert_eq!(
                    got.as_slice(),
                    want.as_slice(),
                    "budget={budget} threads={threads} pass={pass}"
                );
            }
        }
    }
}

#[test]
fn condensed_identical_with_partially_poisoned_warmth() {
    // Warm the cache from a *different* segment subset first so a later
    // build sees a mixture of hits, misses, and unrelated entries.
    let set = generate(&DatasetSpec::tiny(80, 6, 2025));
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let backend = backend();
    let backend = backend.as_ref();
    let cache = PairCache::with_capacity_bytes(1 << 20);

    let first: Vec<&Segment> = refs[..50].to_vec();
    let overlap: Vec<&Segment> = refs[30..].to_vec();
    let _ = build_condensed_cached(&first, backend, 4, Some(&cache)).unwrap();

    let want = build_condensed(&overlap, backend, 1).unwrap();
    let got = build_condensed_cached(&overlap, backend, 4, Some(&cache)).unwrap();
    assert_eq!(got.as_slice(), want.as_slice());
    // The overlapping id range [30, 50) really was served from cache.
    let s = cache.stats();
    assert!(s.hits >= (50 - 30) * (50 - 30 - 1) / 2, "hits {}", s.hits);
}

#[test]
fn cross_bitwise_identical_across_cache_states() {
    let set = generate(&DatasetSpec::tiny(40, 4, 2026));
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let backend = backend();
    let backend = backend.as_ref();
    let (xs, ys) = (&refs[..15], &refs[10..40]);
    let want = build_cross(xs, ys, backend, 1).unwrap();
    for budget in [1usize, 1 << 20] {
        let cache = PairCache::with_capacity_bytes(budget);
        for threads in thread_matrix(&[1, 3]) {
            for _ in 0..2 {
                let got = build_cross_cached(xs, ys, backend, threads, Some(&cache)).unwrap();
                assert_eq!(got, want, "budget={budget} threads={threads}");
            }
        }
    }
}

#[test]
fn full_mahc_m_run_is_unchanged_by_the_cache() {
    // The end-to-end guarantee: labels, K, F-measure, and the entire
    // occupancy/split telemetry are identical with the cache off, amply
    // budgeted, or starved into constant eviction.
    let set = generate(&DatasetSpec::tiny(150, 8, 2027));
    let backend = backend();
    let backend = backend.as_ref();
    let base = AlgoConfig {
        p0: 4,
        beta: Some(50),
        convergence: Convergence::FixedIters(4),
        threads: *thread_matrix(&[2]).last().unwrap(),
        ..Default::default()
    };

    let off = MahcDriver::new(&set, base.clone(), backend)
        .unwrap()
        .run()
        .unwrap();
    for budget in [64usize, 16 << 20] {
        let cfg = AlgoConfig {
            cache_bytes: budget,
            ..base.clone()
        };
        let on = MahcDriver::new(&set, cfg, backend).unwrap().run().unwrap();
        assert_eq!(on.labels, off.labels, "budget={budget}");
        assert_eq!(on.k, off.k, "budget={budget}");
        assert_eq!(
            on.f_measure.to_bits(),
            off.f_measure.to_bits(),
            "budget={budget}"
        );
        for (a, b) in on.history.records.iter().zip(&off.history.records) {
            assert_eq!(a.subsets, b.subsets, "budget={budget}");
            assert_eq!(a.max_occupancy, b.max_occupancy, "budget={budget}");
            assert_eq!(a.splits, b.splits, "budget={budget}");
            assert_eq!(a.total_clusters, b.total_clusters, "budget={budget}");
            assert_eq!(
                a.f_measure.to_bits(),
                b.f_measure.to_bits(),
                "budget={budget}"
            );
        }
    }
}

#[test]
fn ample_cache_reaches_high_hit_rate_by_iteration_three() {
    // The perf claim behind the feature, pinned at test scale: once the
    // subsets settle, most pair distances recur, so from iteration 3 on
    // a comfortably-budgeted cache serves a large share of lookups.
    let set = generate(&DatasetSpec::tiny(160, 8, 2028));
    let backend = backend();
    let backend = backend.as_ref();
    let cfg = AlgoConfig {
        p0: 4,
        beta: Some(55),
        convergence: Convergence::FixedIters(5),
        cache_bytes: 16 << 20,
        ..Default::default()
    };
    let res = MahcDriver::new(&set, cfg, backend).unwrap().run().unwrap();
    assert!(res.history.records.len() >= 3);
    let rates: Vec<f64> = res
        .history
        .records
        .iter()
        .map(|r| r.cache.hit_rate())
        .collect();
    let best_from_third = rates[2..].iter().cloned().fold(0.0f64, f64::max);
    assert!(
        best_from_third >= 0.30,
        "no iteration from the third on reached a 30% hit rate: {rates:?}"
    );
    // Iteration 1's stage-1 builds are necessarily all misses (subsets
    // partition the ids, so no pair repeats within the iteration); any
    // first-iteration hits come from same-subset medoid pairs alone.
    let first = &res.history.records[0].cache;
    assert!(first.misses > 0);
}

#[test]
fn interleaved_sessions_on_one_shared_cache_match_private_cache_runs() {
    // The serve-mode form of the cache contract: several streaming
    // sessions sharing one fleet cache through scoped, budgeted handles
    // — their steps interleaved shard by shard — must each reproduce
    // their private-cache sequential run bit for bit.  (The scheduler
    // itself is exercised in `serve_concurrency`; this pins the cache
    // invariance in isolation, deterministically on one thread.)
    let backend = backend();
    let backend = backend.as_ref();
    let budget = 32 << 10;
    let sets: Vec<_> = (0..3)
        .map(|i| generate(&DatasetSpec::tiny(54 + 12 * i, 4, 3030 + i as u64)))
        .collect();
    let cfgs: Vec<StreamConfig> = (0..3)
        .map(|_| {
            StreamConfig::new(
                AlgoConfig {
                    p0: 2,
                    beta: Some(22),
                    convergence: Convergence::FixedIters(2),
                    cache_bytes: budget,
                    ..Default::default()
                },
                20,
            )
        })
        .collect();
    let expected: Vec<_> = sets
        .iter()
        .zip(&cfgs)
        .map(|(set, cfg)| {
            StreamingDriver::new(set, cfg.clone(), backend)
                .unwrap()
                .run()
                .unwrap()
        })
        .collect();

    let fleet = PairCache::with_capacity_bytes(4 << 20);
    let mut offset = 0;
    let mut sessions: Vec<StreamSession> = sets
        .iter()
        .zip(&cfgs)
        .map(|(set, cfg)| {
            let s = StreamSession::new(set, cfg.clone(), backend)
                .unwrap()
                .with_cache(fleet.scoped(offset, Some(budget)).unwrap());
            offset += set.len();
            s
        })
        .collect();
    // Round-robin: one shard of each session per lap, so the shared
    // cache sees the sessions' insertions and evictions interleaved.
    loop {
        let mut progressed = false;
        for s in sessions.iter_mut() {
            if !s.is_done() {
                s.step().unwrap();
                progressed = true;
            }
        }
        if !progressed {
            break;
        }
    }
    for (session, exp) in sessions.into_iter().zip(&expected) {
        let got = session.finish().unwrap();
        assert_eq!(got.labels, exp.labels);
        assert_eq!(got.k, exp.k);
        assert_eq!(got.f_measure.to_bits(), exp.f_measure.to_bits());
    }
}
