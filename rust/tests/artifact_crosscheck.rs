//! Cross-layer integration: the AOT XLA artifacts must agree with the
//! native Rust implementations on real corpus data.
//!
//! These tests hold the three DTW implementations (numpy oracle ↔
//! Pallas kernel — pinned by pytest — and Pallas kernel ↔ native Rust,
//! pinned here) and the two MFCC front-ends together.  They need
//! `artifacts/` built (`make artifacts`); without it they skip with a
//! note so plain `cargo test` still passes.

use mahc::config::DatasetSpec;
use mahc::corpus::{generate, waveform, Segment};
use mahc::distance::{build_condensed, PairwiseBackend, NativeBackend};
use mahc::dsp;
use mahc::runtime::{mfcc_exec::MfccFrontend, Runtime, XlaDtwBackend};
use std::path::Path;

fn runtime_or_skip() -> Option<Runtime> {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(Path::new("artifacts")).expect("runtime"))
}

#[test]
fn xla_dtw_matches_native_backend() {
    let Some(rt) = runtime_or_skip() else { return };
    let xla = XlaDtwBackend::new(&rt).unwrap();
    let native = NativeBackend::new();

    let mut spec = DatasetSpec::tiny(40, 4, 77);
    spec.feat_dim = 39; // artifact bucket D
    spec.len_range = (6, 60); // within artifact bucket T=64
    let set = generate(&spec);
    let refs: Vec<&Segment> = set.segments.iter().collect();

    let a = build_condensed(&refs, &native, 4).unwrap();
    let b = build_condensed(&refs, &xla, 4).unwrap();
    assert_eq!(a.len(), b.len());
    let mut max_err = 0.0f32;
    for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
        max_err = max_err.max((x - y).abs() / y.abs().max(1.0));
    }
    assert!(
        max_err < 5e-3,
        "native vs xla relative deviation {max_err}"
    );
}

#[test]
fn xla_dtw_cross_block_sizes_consistent() {
    // Requests larger than one tile must tile seamlessly: compare a
    // 40x40 request (tiled over 32+8) against per-pair native values.
    let Some(rt) = runtime_or_skip() else { return };
    let xla = XlaDtwBackend::new(&rt).unwrap();

    let mut spec = DatasetSpec::tiny(40, 3, 78);
    spec.feat_dim = 39;
    spec.len_range = (6, 50);
    let set = generate(&spec);
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let flat = xla.pairwise(&refs, &refs).unwrap();
    assert_eq!(flat.len(), 40 * 40);
    for i in 0..40 {
        // Diagonal ~0 (float noise from the matmul identity only).
        assert!(flat[i * 40 + i].abs() < 5e-3, "diag {i}: {}", flat[i * 40 + i]);
        for j in 0..40 {
            // Symmetry across independently computed tiles.
            let (a, b) = (flat[i * 40 + j], flat[j * 40 + i]);
            assert!((a - b).abs() < 5e-3, "({i},{j}): {a} vs {b}");
        }
    }
}

#[test]
fn xla_mfcc_matches_native_frontend() {
    let Some(rt) = runtime_or_skip() else { return };
    let fe = MfccFrontend::new(&rt).unwrap();

    // Render a couple of synthetic-formant waveforms of different
    // lengths and compare against the native dsp pipeline.
    let mut rng = mahc::util::rng::Rng::seed_from(5);
    let class = {
        let spec = DatasetSpec::tiny(4, 2, 9);
        // Build a prototype by hand via the public corpus API: reuse a
        // generated segment's class trajectory indirectly by rendering
        // from a synthetic class.
        let dim = 4;
        let proto_len = 16;
        let mut proto = Vec::new();
        for t in 0..proto_len {
            for d in 0..dim {
                proto.push(((t * (d + 1)) as f64 * 0.2).sin() * 2.0);
            }
        }
        let _ = spec;
        mahc::corpus::generator::TriphoneClass {
            name: "x-y+z".into(),
            proto,
            proto_len,
            dim,
        }
    };

    for frames in [12usize, 40, 64] {
        let wav = waveform::render(
            &class,
            &waveform::linear_positions(frames),
            0.005,
            &mut rng,
        );
        let wav_f32: Vec<f32> = wav.iter().map(|&v| v as f32).collect();
        let out = fe.extract(&[wav_f32]).unwrap();
        let (t, feats) = &out[0];
        assert_eq!(*t, frames);

        let native = dsp::mfcc(&wav);
        assert_eq!(native.len(), frames);
        for (i, row) in native.iter().enumerate() {
            for (d, &want) in row.iter().enumerate() {
                let got = feats[i * 39 + d] as f64;
                assert!(
                    (got - want).abs() < 2e-2 * want.abs().max(1.0),
                    "frame {i} dim {d}: {got} vs {want}"
                );
            }
        }
    }
}

#[test]
fn oversized_segment_rejected_cleanly() {
    let Some(rt) = runtime_or_skip() else { return };
    let xla = XlaDtwBackend::new(&rt).unwrap();
    let too_long = Segment {
        id: 0,
        class_id: 0,
        len: 100, // > T=64 bucket
        dim: 39,
        feats: vec![0.0; 100 * 39],
    };
    let err = xla.pairwise(&[&too_long], &[&too_long]).unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("frames") || msg.contains("covers segment length"),
        "{msg}"
    );
}
