//! Metric-parity conformance suite: the vector metrics (cosine /
//! Euclidean) behind [`VectorBackend`] must honour the same contract
//! the DTW backends pin in `backend_parity.rs`.
//!
//! Guarantees pinned here (and documented in EXPERIMENTS.md §Metrics):
//!
//! * scalar and 8-lane blocked vector kernels are **bitwise
//!   identical** across dims, lane-remainder shapes, and thread
//!   counts, for condensed triangles, cross rectangles, and the cached
//!   builders (including PairCache hit/miss/eviction counters);
//! * the Euclidean norm lower bound is **admissible**: fuzzed
//!   `lb ≤ exact` over random embedding pairs, and every pair the
//!   cascade bounds out is genuinely above the carried threshold
//!   (cosine advertises no bound and must keep pruning off);
//! * silhouette selection recovers the planted cluster count on a
//!   labelled embedding corpus and agrees with the L-method knee where
//!   both are computable;
//! * a full MAHC run and a serve-mode session complete end to end on
//!   an embedding metric, stamping `metric` / `silhouette_score`
//!   telemetry, bitwise-reproduced under the blocked kernel;
//! * the shared `PairwiseBackend` trait stays object-safe over every
//!   vector metric.
//!
//! The CI backend-matrix job sweeps `MAHC_TEST_BACKEND` ∈ {scalar,
//! blocked} × `MAHC_TEST_THREADS` ∈ {1, 4} over this suite too.

mod common;

use std::sync::Arc;

use common::{assert_bitwise, thread_matrix};
use mahc::ahc::{self, SelectionMethod};
use mahc::config::{AlgoConfig, Convergence, ServeConfig, StreamConfig};
use mahc::corpus::{generate_embeddings, EmbeddingSpec, Segment, SegmentSet};
use mahc::distance::{
    build_condensed, build_condensed_cached, build_cross, CascadeBackend, CascadeMode,
    PairCache, PairwiseBackend, VectorBackend, VectorMetric,
};
use mahc::mahc::{MahcDriver, ServeDriver, SessionSpec};

/// Embedding corpus with `dim`-dimensional single-frame segments.
fn embeddings(n: usize, classes: usize, dim: usize, seed: u64) -> SegmentSet {
    let mut spec = EmbeddingSpec::tiny(n, classes, seed);
    spec.dim = dim;
    generate_embeddings(&spec)
}

/// Matrix cell for the vector kernels: `MAHC_TEST_BACKEND=blocked`
/// selects the 8-lane variant, anything else (scalar/native/unset)
/// the scalar reference.
fn vector_backend_under_test(metric: VectorMetric) -> VectorBackend {
    match std::env::var("MAHC_TEST_BACKEND").ok().as_deref() {
        Some("blocked") => VectorBackend::blocked(metric),
        _ => VectorBackend::native(metric),
    }
}

#[test]
fn vector_condensed_and_cross_bitwise_scalar_vs_blocked() {
    // Dims straddling the 8-lane group width, both metrics, a thread
    // sweep: the blocked kernel must reproduce the scalar bits.
    for metric in [VectorMetric::Cosine, VectorMetric::Euclidean] {
        for (dim, seed) in [(1usize, 201u64), (7, 202), (8, 203), (16, 204), (37, 205)] {
            let set = embeddings(45, 5, dim, seed);
            let refs: Vec<&Segment> = set.segments.iter().collect();
            let scalar = VectorBackend::native(metric);
            let blocked = VectorBackend::blocked(metric);
            let want = build_condensed(&refs, &scalar, 1).unwrap();
            for threads in thread_matrix(&[1, 2, 4]) {
                let got = build_condensed(&refs, &blocked, threads).unwrap();
                assert_bitwise(
                    want.as_slice(),
                    got.as_slice(),
                    &format!("{} dim={dim} threads={threads}", metric.name()),
                );
            }
            // Cross rectangles around the lane boundary: full groups,
            // remainder groups, a lone lane.
            for ny in [1usize, 5, 8, 9, 16, 23] {
                let (xs, ys) = (&refs[..7], &refs[7..7 + ny]);
                let want = build_cross(xs, ys, &scalar, 1).unwrap();
                let got = build_cross(xs, ys, &blocked, 2).unwrap();
                assert_bitwise(&want, &got, &format!("{} ny={ny}", metric.name()));
            }
        }
    }
}

#[test]
fn cached_builds_and_hit_patterns_are_variant_invariant() {
    // Scalar and blocked vector kernels share preferred_rows and
    // kernel_tag, so the cached builder must probe the cache in the
    // same block order — counters, not just matrices, must agree.
    for metric in [VectorMetric::Cosine, VectorMetric::Euclidean] {
        let set = embeddings(56, 5, 12, 206);
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let scalar = VectorBackend::native(metric);
        let blocked = VectorBackend::blocked(metric);
        assert_eq!(scalar.preferred_rows(), blocked.preferred_rows());
        assert_eq!(scalar.kernel_tag(), blocked.kernel_tag());

        let want = build_condensed(&refs, &scalar, 1).unwrap();
        for budget in [1usize << 8, 1 << 20] {
            let cs = PairCache::with_capacity_bytes(budget);
            let cb = PairCache::with_capacity_bytes(budget);
            for pass in 0..3 {
                let a = build_condensed_cached(&refs, &scalar, 1, Some(&cs)).unwrap();
                let b = build_condensed_cached(&refs, &blocked, 1, Some(&cb)).unwrap();
                assert_bitwise(
                    want.as_slice(),
                    a.as_slice(),
                    &format!("{} scalar budget={budget} pass={pass}", metric.name()),
                );
                assert_bitwise(
                    want.as_slice(),
                    b.as_slice(),
                    &format!("{} blocked budget={budget} pass={pass}", metric.name()),
                );
            }
            assert_eq!(
                cs.stats(),
                cb.stats(),
                "{} budget={budget}: counters must not depend on the variant",
                metric.name()
            );
        }
    }
}

#[test]
fn euclidean_norm_bound_admissible_fuzz() {
    // The reverse-triangle bound with rounding slack must never exceed
    // the exact kernel value, for any pair — including near-identical
    // segments where the real-arithmetic bound is tightest.
    let set = embeddings(60, 4, 16, 207);
    let backend = vector_backend_under_test(VectorMetric::Euclidean);
    let cascade = CascadeBackend::borrowed(&backend, &set, CascadeMode::Debug);
    assert!(cascade.supports_pruning());

    let refs: Vec<&Segment> = set.segments.iter().collect();
    let exact = build_cross(&refs[..30], &refs[30..], &backend, 1).unwrap();
    for (i, x) in refs[..30].iter().enumerate() {
        for (j, y) in refs[30..].iter().enumerate() {
            let lb = cascade.lb_pair(x, y).unwrap();
            let d = exact[i * 30 + j];
            assert!(
                lb <= d,
                "inadmissible bound: lb {lb} > exact {d} for pair ({}, {})",
                x.id,
                y.id
            );
        }
    }

    // Threshold sweep through the distance distribution: pruned pairs
    // (flag false) must carry a bound strictly above the threshold and
    // still below the exact value; surviving pairs must be exact bits.
    let mut sorted = exact.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let mut pruned_total = 0usize;
    for q in [0.0, 0.1, 0.5, 0.9] {
        // q = 0 carries threshold 0.0: any pair whose norms differ by
        // more than the rounding slack must be bounded out, so the
        // sweep provably exercises the pruning path.
        let threshold = if q == 0.0 {
            0.0
        } else {
            sorted[(sorted.len() as f64 * q) as usize]
        };
        let (vals, flags) = cascade
            .pairwise_pruned(&refs[..30], &refs[30..], threshold)
            .unwrap();
        for (k, (&v, &is_exact)) in vals.iter().zip(&flags).enumerate() {
            if is_exact {
                assert_eq!(v.to_bits(), exact[k].to_bits(), "q={q} pair {k}");
            } else {
                pruned_total += 1;
                assert!(v > threshold, "q={q} pair {k}: bound {v} <= {threshold}");
                assert!(v <= exact[k], "q={q} pair {k}: bound {v} > exact");
            }
        }
    }
    // Debug mode re-ran the kernel on every pair and verified lb ≤
    // exact internally; some pairs must actually have been bounded out
    // for the sweep to mean anything.
    assert!(pruned_total > 0, "norm bound never fired across the sweep");

    // Cosine advertises no admissible bound: the cascade must keep
    // threshold-aware call sites on the exact path.
    let cos = VectorBackend::native(VectorMetric::Cosine);
    let cos_cascade = CascadeBackend::borrowed(&cos, &set, CascadeMode::On);
    assert!(!cos_cascade.supports_pruning());
}

#[test]
fn silhouette_recovers_planted_count_and_agrees_with_lmethod() {
    // Well-separated equal-size blobs: both selectors are computable
    // and must land on the planted class count.
    let spec = EmbeddingSpec {
        name: "sil_pin".into(),
        segments: 72,
        classes: 4,
        dim: 8,
        spread: 0.25,
        skew: 0.0,
        seed: 208,
    };
    let set = generate_embeddings(&spec);
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let backend = vector_backend_under_test(VectorMetric::Euclidean);
    let cond = build_condensed(&refs, &backend, 1).unwrap();

    let sil = ahc::cluster_subset_with(&cond, 12, None, SelectionMethod::Silhouette);
    let lm = ahc::cluster_subset_with(&cond, 12, None, SelectionMethod::LMethod);
    assert_eq!(sil.k, 4, "silhouette missed the planted count");
    assert_eq!(lm.k, sil.k, "selectors disagree on separated blobs");
    assert_eq!(sil.labels.len(), 72);
}

fn embedding_cfg(selection: SelectionMethod) -> AlgoConfig {
    AlgoConfig {
        p0: 3,
        beta: Some(40),
        convergence: Convergence::FixedIters(3),
        threads: 2,
        selection,
        ..Default::default()
    }
}

#[test]
fn full_mahc_embedding_run_stamps_metric_telemetry() {
    // The acceptance path: a complete MAHC run on an embedding corpus
    // under cosine with silhouette selection, emitting the new
    // telemetry fields — and bitwise-reproduced by the blocked kernel.
    let set = embeddings(96, 6, 16, 209);
    let scalar = VectorBackend::native(VectorMetric::Cosine);
    let want = MahcDriver::new(&set, embedding_cfg(SelectionMethod::Silhouette), &scalar)
        .unwrap()
        .run()
        .unwrap();
    assert!(want.k >= 2);
    assert!(
        want.f_measure > 0.5,
        "cosine MAHC degenerated: F = {}",
        want.f_measure
    );
    for r in &want.history.records {
        assert_eq!(r.metric, "cosine");
        assert!(
            r.silhouette_score > 0.0,
            "iteration {} lost its silhouette score",
            r.iteration
        );
    }
    let json = want.history.to_json().to_string();
    assert!(json.contains("\"metric\""), "metric missing from JSON");
    assert!(
        json.contains("\"silhouette_score\""),
        "silhouette_score missing from JSON"
    );

    let blocked = VectorBackend::blocked(VectorMetric::Cosine);
    let got = MahcDriver::new(&set, embedding_cfg(SelectionMethod::Silhouette), &blocked)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(got.labels, want.labels);
    assert_eq!(got.k, want.k);
    assert_eq!(got.f_measure.to_bits(), want.f_measure.to_bits());
}

#[test]
fn serve_sessions_run_embedding_metric_end_to_end() {
    // Two concurrent streaming sessions over one shared embedding
    // corpus and a Send + Sync vector backend.
    let set = Arc::new(embeddings(80, 5, 16, 210));
    let backend: Arc<dyn PairwiseBackend + Send + Sync> =
        Arc::new(VectorBackend::native(VectorMetric::Cosine));
    let serve_cfg = ServeConfig {
        workers: 2,
        fleet_cap: 2,
        queue_cap: 2,
        cache_bytes: 0,
    };
    let mut specs = Vec::new();
    for i in 0..2u64 {
        let cfg = StreamConfig::new(embedding_cfg(SelectionMethod::Silhouette), 40)
            .with_shard_seed(300 + i);
        specs.push(SessionSpec::new(
            &format!("emb{i}"),
            Arc::clone(&set),
            cfg,
        ));
    }
    let report = ServeDriver::new(serve_cfg, backend).unwrap().run(specs).unwrap();
    assert_eq!(report.completed(), 2);
    assert_eq!(report.failed(), 0);
    for s in &report.sessions {
        let r = s.result.as_ref().expect("session failed");
        assert!(r.k >= 2, "{}: degenerate clustering", s.name);
        assert!(r.pairs > 0, "{}: no pair work recorded", s.name);
        assert!(r.shards >= 2, "{}: stream never sharded", s.name);
    }
}

#[test]
fn pairwise_backend_is_object_safe_over_vector_metrics() {
    // The shared trait must stay usable as an owned trait object over
    // any backend, bitwise with the concrete type's answer.
    let set = embeddings(10, 2, 8, 211);
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let boxed: Box<dyn PairwiseBackend> =
        Box::new(VectorBackend::native(VectorMetric::Euclidean));
    let via_object = boxed.pairwise(&refs[..5], &refs[5..]).unwrap();
    let direct = VectorBackend::native(VectorMetric::Euclidean)
        .pairwise(&refs[..5], &refs[5..])
        .unwrap();
    assert_bitwise(&via_object, &direct, "trait object");
    assert_eq!(boxed.metric_name(), "euclidean");
}
