//! Exact-linkage oracle: `ward_linkage` (NN-chain, O(n²)) against a
//! naive O(n³) global-minimum Lance-Williams agglomerator.
//!
//! Both implementations apply the identical Ward2 update — f64
//! arithmetic on f32-stored working distances, `.max(0.0).sqrt()`, cast
//! back to f32 — so for tie-free inputs they must build the *same tree*:
//! after the shared height-sort relabelling (`Dendrogram::from_raw_merges`)
//! every flat cut and every merge size must match **bitwise**.
//!
//! Merge *heights* carry one caveat: NN-chain may pop a mutual pair
//! before the global minimum, so later Lance-Williams updates fold the
//! same clusters in a different order.  The recursions are equal in
//! exact arithmetic but reassociate differently through the f32 stores,
//! so a height may differ in its last bits (measured ≤ 2 ulp over this
//! test's whole grid; 45/117 grid cells agree exactly).  The test
//! therefore pins heights to ≤ 16 ulp — tight enough to catch any real
//! formula or bookkeeping divergence (wrong size weighting shifts
//! heights by whole percents) while honest about reassociation.

use mahc::ahc::{ward_linkage, Dendrogram};
use mahc::distance::Condensed;
use mahc::util::rng::Rng;

/// Condensed |xi − xj| matrix over random 1-D normal points (continuous
/// coordinates: ties have essentially zero probability, which the
/// same-tree contract requires).
fn random_condensed(n: usize, rng: &mut Rng) -> Condensed {
    let pts: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 4.0).collect();
    let mut cond = Condensed::zeros(n);
    for i in 0..n {
        for j in 0..i {
            cond.set(i, j, (pts[i] - pts[j]).abs());
        }
    }
    cond
}

/// Naive Ward: repeatedly merge the globally closest pair, applying the
/// same Lance-Williams Ward2 update as `ahc::nnchain::merge_into` —
/// operation for operation, including the f64/f32 boundaries.  Returns
/// raw (a, b, height) merges with a < b, in merge order.
fn naive_ward(cond: &Condensed) -> Vec<(usize, usize, f32)> {
    let n = cond.n();
    let mut d = cond.clone();
    let mut size = vec![1usize; n];
    let mut alive = vec![true; n];
    let mut raw = Vec::new();
    for _ in 0..n.saturating_sub(1) {
        let mut best = (usize::MAX, usize::MAX, f32::INFINITY);
        for i in 0..n {
            if !alive[i] {
                continue;
            }
            for j in 0..i {
                if !alive[j] {
                    continue;
                }
                let v = d.get(i, j);
                if v < best.2 {
                    best = (j, i, v);
                }
            }
        }
        let (a, b, h) = best;
        assert!(a < b, "no mergeable pair found");
        let (na, nb) = (size[a] as f64, size[b] as f64);
        let dab2 = (h as f64) * (h as f64);
        for k in 0..n {
            if k == a || k == b || !alive[k] {
                continue;
            }
            let nk = size[k] as f64;
            let dak = d.get(a, k) as f64;
            let dbk = d.get(b, k) as f64;
            let num = (na + nk) * dak * dak + (nb + nk) * dbk * dbk - nk * dab2;
            let new = (num / (na + nb + nk)).max(0.0).sqrt();
            d.set(a, k, new as f32);
        }
        alive[b] = false;
        size[a] += size[b];
        raw.push((a, b, h));
    }
    raw
}

fn sorted_heights(mut h: Vec<f32>) -> Vec<f32> {
    h.sort_by(|x, y| x.partial_cmp(y).unwrap());
    h
}

/// Distance in units-in-the-last-place between two same-sign finite
/// floats (heights are non-negative by construction).
fn ulp_diff(a: f32, b: f32) -> u32 {
    (a.to_bits() as i64 - b.to_bits() as i64).unsigned_abs() as u32
}

#[test]
fn chain_matches_naive_reference() {
    for n in 2..=40usize {
        for seed in [1u64, 71, 913] {
            let mut rng = Rng::seed_from(seed.wrapping_mul(n as u64 + 1));
            let cond = random_condensed(n, &mut rng);

            let chain = ward_linkage(&cond);
            let raw = naive_ward(&cond);
            assert_eq!(chain.merges().len(), n - 1, "n={n} seed={seed}");
            assert_eq!(raw.len(), n - 1, "n={n} seed={seed}");

            // Merge heights: same multiset up to Lance-Williams
            // reassociation (see module docs) — a handful of ulps, far
            // below anything a formula bug could produce.
            let got = sorted_heights(chain.merge_heights());
            let want = sorted_heights(raw.iter().map(|&(_, _, h)| h).collect());
            for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    ulp_diff(g, w) <= 16,
                    "n={n} seed={seed} height[{i}]: chain {g} vs naive {w} \
                     ({} ulp apart)",
                    ulp_diff(g, w)
                );
            }

            // Flat cuts: run the naive merge list through the same
            // height-sort relabelling and compare every cut bitwise.
            let reference = Dendrogram::from_raw_merges(n, raw);
            for k in [1usize, 2, 3, n / 2, n.saturating_sub(1), n] {
                let k = k.clamp(1, n);
                assert_eq!(
                    chain.cut(k),
                    reference.cut(k),
                    "n={n} seed={seed} cut k={k}"
                );
            }
        }
    }
}

#[test]
fn merge_sizes_agree_with_reference() {
    // The relabelled trees must agree on cluster sizes at each merge,
    // not just on cuts: size bookkeeping is what the Ward2 update
    // weights by, so a silent divergence here would skew every later
    // height by whole factors.
    for seed in [5u64, 6, 7] {
        let mut rng = Rng::seed_from(seed);
        let n = 33;
        let cond = random_condensed(n, &mut rng);
        let chain = ward_linkage(&cond);
        let reference = Dendrogram::from_raw_merges(n, naive_ward(&cond));
        let a: Vec<usize> = chain.merges().iter().map(|m| m.size).collect();
        let b: Vec<usize> = reference.merges().iter().map(|m| m.size).collect();
        assert_eq!(a, b, "seed={seed}");
    }
}

#[test]
fn cut_labels_are_dense_first_appearance() {
    // Regression pin for the R001 audit (PR 6): `Dendrogram::cut` used
    // to label components through a HashMap keyed by DSU roots.  The
    // labels it produced were already first-appearance dense — but only
    // because of how entry() was being driven, not by construction, and
    // a hasher-order iteration slipping in would have silently permuted
    // label ids everywhere downstream (memberships, F-measure tables,
    // carried-medoid sets).  The table is now a flat Vec indexed by
    // object id; this pin makes the contract explicit: label 0 appears
    // first, and every new label is exactly prev_max + 1 at its first
    // appearance, for every cut size.
    for seed in [11u64, 12, 13] {
        let mut rng = Rng::seed_from(seed);
        let n = 41;
        let cond = random_condensed(n, &mut rng);
        let dendro = ward_linkage(&cond);
        for k in 1..=n {
            let labels = dendro.cut(k);
            assert_eq!(labels.len(), n, "seed={seed} k={k}");
            assert_eq!(labels[0], 0, "seed={seed} k={k}: first label not 0");
            let mut max_seen = 0usize;
            for (i, &l) in labels.iter().enumerate() {
                assert!(
                    l <= max_seen + 1,
                    "seed={seed} k={k}: label {l} at position {i} skips ids"
                );
                if l > max_seen {
                    assert_eq!(l, max_seen + 1, "seed={seed} k={k}");
                    max_seen = l;
                }
            }
            assert_eq!(max_seen + 1, k, "seed={seed} k={k}: wrong label count");
        }
    }
}
